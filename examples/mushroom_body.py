"""The paper's second benchmark network: the insect-olfaction mushroom body
(PN -> LHI/KC -> DN), with Poisson input neurons and Traub-Miles HH units.
Shows odor-driven sparse KC coding and the NaN guard tripping when the
PN->KC conductance is over-scaled (the paper's float-overflow discussion).

The network is declared through ModelSpec (see repro.core.models.
mushroom_body.spec) and the gScale table below is ONE vmapped compile via
CompiledModel.sweep_gscale — no hand-rolled jit(vmap(...)).

  PYTHONPATH=src python examples/mushroom_body.py
"""

import numpy as np

from repro.core.models.mushroom_body import MushroomBodyConfig, compile_model

cfg = MushroomBodyConfig(n_pn=24, n_lhi=6, n_kc=150, n_dn=12)
model = compile_model(cfg)

print(model)
print("synapse representations:")
for rep in model.memory_report():
    print(f"  {rep['name']}: {rep['representation']}")

sweep = model.sweep_gscale("PN_KC", [0.5, 1.0, 2.0, 8.0, 50.0], n_steps=2500)

print("\n gScale |  PN Hz |  KC Hz |  DN Hz | finite (NaN guard)")
for i, g in enumerate(np.asarray(sweep.values)):
    r = {k: float(v[i]) for k, v in sweep.rates_hz.items()}
    print(f" {g:6.1f} | {r['PN']:6.1f} | {r['KC']:6.1f} | {r['DN']:6.1f} "
          f"| {bool(sweep.finite[i])}")

print("\nKC population sparseness at gScale=1:")
kc_rate = float(sweep.rates_hz["KC"][1])
pn_rate = float(sweep.rates_hz["PN"][1])
counts = np.asarray(sweep.spike_counts["KC"][1])
# temporal sparseness: each KC's duty cycle (expected spikes per 5 ms
# window) stays far below the PN drive despite every KC receiving PN input
duty = min(kc_rate * 5e-3, 1.0)
print(f"  mean KC rate {kc_rate:.1f} Hz vs PN drive {pn_rate:.1f} Hz "
      f"(each KC spikes in ~{100 * duty:.0f}% of 5 ms windows); "
      f"{np.mean(counts > 0):.2f} of KCs fired at least once")
