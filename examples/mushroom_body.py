"""The paper's second benchmark network: the insect-olfaction mushroom body
(PN -> LHI/KC -> DN), with Poisson input neurons and Traub-Miles HH units.
Shows odor-driven sparse KC coding and the NaN guard tripping when the
PN->KC conductance is over-scaled (the paper's float-overflow discussion).

  PYTHONPATH=src python examples/mushroom_body.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.models.mushroom_body import MushroomBodyConfig, build

cfg = MushroomBodyConfig(n_pn=24, n_lhi=6, n_kc=150, n_dn=12)
net, sim = build(cfg)

print("populations:", {k: p.n for k, p in net.populations.items()})
print("synapse representations:")
for rep in net.memory_report():
    print(f"  {rep['name']}: {rep['representation']}")

state = sim.init_state()
run = jax.jit(lambda s, g: sim.run(s, 2500, {"PN_KC": g}))

print("\n gScale |  PN Hz |  KC Hz |  DN Hz | finite (NaN guard)")
for g in (0.5, 1.0, 2.0, 8.0, 50.0):
    res = run(state, jnp.float32(g))
    r = {k: float(v) for k, v in res.rates_hz.items()}
    print(f" {g:6.1f} | {r['PN']:6.1f} | {r['KC']:6.1f} | {r['DN']:6.1f} "
          f"| {bool(res.finite)}")

print("\nKC population sparseness at gScale=1 (fraction active):")
res = run(state, jnp.float32(1.0))
counts = np.asarray(res.spike_counts["KC"])
print(f"  {np.mean(counts > 0):.2f} of KCs fired at least once; "
      f"mean rate {float(res.rates_hz['KC']):.1f} Hz")
