"""The paper's second benchmark network: the insect-olfaction mushroom body
(PN -> LHI/KC -> DN), with Poisson input neurons and Traub-Miles HH units.
Shows odor-driven sparse KC coding and the NaN guard tripping when the
PN->KC conductance is over-scaled (the paper's float-overflow discussion).

The network is declared through ModelSpec (see repro.core.models.
mushroom_body.spec) and the gScale table below is ONE vmapped compile via
CompiledModel.sweep_gscale — no hand-rolled jit(vmap(...)).  The config
also declares the observation/intervention surface: a KC membrane-voltage
probe (device-resident recording, returned per sweep candidate) and the
KC->DN incoming-weight normalization as a custom update, applied on demand
without rebuilding.

  PYTHONPATH=src python examples/mushroom_body.py
"""

import numpy as np

from repro.core.models.mushroom_body import MushroomBodyConfig, compile_model

cfg = MushroomBodyConfig(n_pn=24, n_lhi=6, n_kc=150, n_dn=12,
                         kc_probe_every=25, kc_dn_normalize=True)
model = compile_model(cfg)

print(model)
print("synapse representations:")
for rep in model.memory_report():
    if rep.get("kind", "synapse_group") != "synapse_group":
        continue
    print(f"  {rep['name']}: {rep['representation']}")

sweep = model.sweep_gscale("PN_KC", [0.5, 1.0, 2.0, 8.0, 50.0], n_steps=2500)

print("\n gScale |  PN Hz |  KC Hz |  DN Hz | finite (NaN guard)")
for i, g in enumerate(np.asarray(sweep.values)):
    r = {k: float(v[i]) for k, v in sweep.rates_hz.items()}
    print(f" {g:6.1f} | {r['PN']:6.1f} | {r['KC']:6.1f} | {r['DN']:6.1f} "
          f"| {bool(sweep.finite[i])}")

print("\nKC population sparseness at gScale=1:")
kc_rate = float(sweep.rates_hz["KC"][1])
pn_rate = float(sweep.rates_hz["PN"][1])
counts = np.asarray(sweep.spike_counts["KC"][1])
# temporal sparseness: each KC's duty cycle (expected spikes per 5 ms
# window) stays far below the PN drive despite every KC receiving PN input
duty = min(kc_rate * 5e-3, 1.0)
print(f"  mean KC rate {kc_rate:.1f} Hz vs PN drive {pn_rate:.1f} Hz "
      f"(each KC spikes in ~{100 * duty:.0f}% of 5 ms windows); "
      f"{np.mean(counts > 0):.2f} of KCs fired at least once")

# --- probes: the KC membrane voltage, recorded per sweep candidate --------
kc_v = np.asarray(sweep.recordings["kc_v"])       # [cand, samples, n_kc]
n_samp = int(np.asarray(sweep.recordings.counts["kc_v"])[0])
print(f"\nKC V probe ('kc_v', every {cfg.kc_probe_every} steps): "
      f"{n_samp} samples x {kc_v.shape[-1]} KCs per candidate")
print("  mean KC V (last sample) per gScale: "
      + str(kc_v[:, n_samp - 1].mean(axis=1).round(1)))

# --- custom update: KC->DN weight normalization on demand -----------------
grp = next(g for g in model.network.synapses if g.name == "KC_DN")
valid = np.asarray(grp.ell.valid)
post = np.asarray(grp.ell.post_ind)


def dn_totals(g):
    tot = np.zeros(cfg.n_dn, np.float32)
    np.add.at(tot, post[valid], np.asarray(g)[valid])
    return tot


state = model.init_state()
before = dn_totals(state.syn["KC_DN"].g)
state = model.custom_update("normalize_kc_dn", state)
after = dn_totals(state.syn["KC_DN"].g)
print("\nKC->DN normalization (custom update 'normalize_kc_dn'):")
print(f"  per-DN incoming conductance before: "
      f"{before.min():.3f}..{before.max():.3f} uS")
print(f"  after: {after.min():.3f}..{after.max():.3f} uS "
      f"(target {cfg.n_kc * cfg.g_kc_dn / 2.0:.3f})")
