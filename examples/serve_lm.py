"""Batched serving example: submit concurrent requests, watch the scheduler
prefill + decode them as a batch (KV caches, ring buffers for windowed
archs, O(1) state for SSM archs).

  PYTHONPATH=src python examples/serve_lm.py --arch qwen2-0.5b
  PYTHONPATH=src python examples/serve_lm.py --arch mamba2-2.7b
"""

import argparse
import time

import numpy as np

from repro.launch.serve import Request, Server


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    srv = Server(args.arch, use_reduced=True, max_batch=3, max_seq=128)
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        prompt = rng.integers(3, srv.cfg.vocab,
                              size=int(rng.integers(4, 16))).tolist()
        r = Request(rid=i, prompt=prompt, max_new=args.max_new,
                    temperature=args.temperature)
        reqs.append(r)
        srv.submit(r)

    t0 = time.time()
    srv.run()
    dt = time.time() - t0
    tokens = sum(len(r.out) for r in reqs)
    print(f"arch={args.arch} ({srv.cfg.family}): {args.requests} requests, "
          f"{tokens} tokens in {dt:.1f}s -> {tokens/dt:.1f} tok/s")
    for r in reqs:
        print(f"  req{r.rid}: {len(r.prompt)}-token prompt -> "
              f"{r.out[:10]}{'...' if len(r.out) > 10 else ''}")


if __name__ == "__main__":
    main()
