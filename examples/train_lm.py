"""End-to-end LM training driver on the framework's full substrate:
deterministic data pipeline, sharded AdamW, checkpoint/restart, NaN guard.

Default: a ~20M-param qwen2-family model, 150 steps on CPU (a few minutes).
--hundred-m selects a ~100M-param config (the brief's end-to-end target;
sized for real accelerators — it runs here too, just slowly).

  PYTHONPATH=src python examples/train_lm.py
  PYTHONPATH=src python examples/train_lm.py --hundred-m --steps 300
"""

import argparse
import dataclasses

from repro.configs import get_config
from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--hundred-m", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # family: qwen2 (GQA + qkv-bias + tied embeddings)
    import repro.configs as configs
    base = get_config("qwen2-0.5b")
    if args.hundred_m:
        cfg = dataclasses.replace(
            base, n_layers=10, d_model=640, n_heads=10, n_kv=2,
            head_dim=64, d_ff=2560, vocab=50304, dtype="float32",
            remat=False)
    else:
        cfg = dataclasses.replace(
            base, n_layers=6, d_model=320, n_heads=5, n_kv=1, head_dim=64,
            d_ff=1280, vocab=16384, dtype="float32", remat=False)

    # register the custom config under a temp name so train.run finds it
    configs.ARCHS["_example_lm"] = cfg
    losses = train_mod.run(
        "_example_lm", steps=args.steps, batch=8, seq=256,
        use_reduced=False, ckpt_dir=args.ckpt_dir, ckpt_every=50,
        lr=1e-3, log_every=10)
    print(f"\nfirst-10 mean loss {sum(losses[:10])/10:.3f} -> "
          f"last-10 mean {sum(losses[-10:])/10:.3f}")


if __name__ == "__main__":
    main()
