"""Quickstart: define a spiking network in the GeNN-style equation DSL,
let the framework generate its simulator, run it, and inspect the paper's
machinery (sparse representation choice + conductance scaling guard).

  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.codegen import NeuronModel, generated_source
from repro.core.snn.network import Network
from repro.core.snn.simulator import Simulator
from repro.core.snn.synapses import make_group

# 1. Declare a neuron model AS CODE (this is GeNN's defining workflow) -----
izhi = NeuronModel(
    name="izhi",
    state={"V": -65.0, "U": -13.0},
    params={"a": 0.02, "b": 0.2, "c": -65.0, "d": 8.0},
    sim_code="""
V = V + 0.5*dt*(0.04*V*V + 5.0*V + 140.0 - U + Isyn)
V = V + 0.5*dt*(0.04*V*V + 5.0*V + 140.0 - U + Isyn)
U = U + dt*a*(b*V - U)
V = minimum(V, 30.0)
""",
    threshold_code="V >= 29.99",
    reset_code="V = c\nU = U + d",
)
print("=== generated update function ===")
print(generated_source(izhi))

# 2. Build a 2-population network ------------------------------------------
rng = np.random.default_rng(0)
net = Network(name="quickstart")
net.add_population("exc", izhi, 160,
                   input_fn=lambda k, t, n: 5.0 * jax.random.normal(k, (n,)))
net.add_population("inh", izhi, 40,
                   params={"a": 0.1, "d": 2.0},
                   input_fn=lambda k, t, n: 2.0 * jax.random.normal(k, (n,)))

net.add_synapse(make_group(rng, "ee", "exc", "exc", 160, 160, 40,
                           weight_fn=lambda r, s: 0.5 * r.random(s)))
net.add_synapse(make_group(rng, "ei", "exc", "inh", 160, 40, 10,
                           weight_fn=lambda r, s: 0.5 * r.random(s)))
net.add_synapse(make_group(rng, "ie", "inh", "exc", 40, 160, 40,
                           weight_fn=lambda r, s: -r.random(s)))

print("\n=== representation choice (paper eq 1/2) ===")
for rep in net.memory_report():
    print(f"  {rep['name']}: {rep['representation']} "
          f"(sparse {rep['sparse_elements']} vs dense "
          f"{rep['dense_elements']} elements)")

# 3. Simulate (the step function is generated + jitted) ---------------------
sim = Simulator(net, dt=1.0, seed=0)
state = sim.init_state()
res = jax.jit(lambda s: sim.run(s, 400, record_raster=True))(state)

print("\n=== results (400 ms) ===")
for pop, rate in res.rates_hz.items():
    print(f"  {pop}: {float(rate):.1f} Hz, finite={bool(res.finite)}")

print("\n=== exc raster (first 40 neurons x 80 ms) ===")
raster = np.asarray(res.raster["exc"])[:80, :40]
for t in range(0, 80, 2):
    print("  " + "".join("|" if raster[t, i] else "." for i in range(40)))
