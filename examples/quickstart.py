"""Quickstart: declare a spiking network — neuron models, synapse models AND
connectivity — as data + code snippets in the GeNN-style ModelSpec, build it
(validation, seeded connectivity, representation choice), run it, and sweep
the paper's conductance scaling factor in one vmapped compile.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import numpy as np

from repro.core.codegen import NeuronModel, generated_source
from repro.core.snn.spec import ModelSpec
from repro.core.snn.synapses import ExpDecay
from repro.sparse.formats import FixedFanout, FixedProbability

# 1. Declare a neuron model AS CODE (this is GeNN's defining workflow) -----
izhi = NeuronModel(
    name="izhi",
    state={"V": -65.0, "U": -13.0},
    params={"a": 0.02, "b": 0.2, "c": -65.0, "d": 8.0},
    sim_code="""
V = V + 0.5*dt*(0.04*V*V + 5.0*V + 140.0 - U + Isyn)
V = V + 0.5*dt*(0.04*V*V + 5.0*V + 140.0 - U + Isyn)
U = U + dt*a*(b*V - U)
V = minimum(V, 30.0)
""",
    threshold_code="V >= 29.99",
    reset_code="V = c\nU = U + d",
)
print("=== generated update function ===")
print(generated_source(izhi))

# 2. Declare the network: populations + synapse populations ----------------
#    Connectivity is data (FixedFanout / FixedProbability initializers,
#    resolved at build time from the build seed); synapse dynamics are
#    generated code (ExpDecay here; default is an instantaneous Pulse).
spec = ModelSpec("quickstart")
spec.add_neuron_population(
    "exc", 160, izhi,
    input_fn=lambda k, t, n: 5.0 * jax.random.normal(k, (n,)))
spec.add_neuron_population(
    "inh", 40, izhi, params={"a": 0.1, "d": 2.0},
    input_fn=lambda k, t, n: 2.0 * jax.random.normal(k, (n,)))

spec.add_synapse_population("ee", "exc", "exc", connect=FixedFanout(40),
                            weight=lambda r, s: 0.5 * r.random(s))
spec.add_synapse_population("ei", "exc", "inh", connect=FixedProbability(0.25),
                            weight=lambda r, s: 0.5 * r.random(s))
spec.add_synapse_population("ie", "inh", "exc", connect=FixedFanout(40),
                            weight=lambda r, s: -r.random(s),
                            psm=ExpDecay(tau_ms=3.0))

# Probes: device-resident recording of ANY declared state variable (the
# old record_raster flag is a special case: a "spikes" probe).
spec.probe("exc_raster", "exc", "spikes")
spec.probe("exc_v_mean", "exc", "V", reduce="mean")

# 3. Build: eager validation, seeded connectivity, representation choice ---
model = spec.build(dt=1.0, seed=0)
print("\n=== compiled model ===")
print(model)

print("\n=== representation choice (paper eq 1/2) ===")
for rep in model.memory_report():
    if rep.get("kind", "synapse_group") != "synapse_group":
        continue
    print(f"  {rep['name']}: {rep['representation']} "
          f"(sparse {rep['sparse_elements']} vs dense "
          f"{rep['dense_elements']} elements)")

# 4. Run (the step function is generated + jitted); probes come back in a
#    Recordings pytree keyed by probe name ---------------------------------
res = model.run(400)

print("\n=== results (400 ms) ===")
for pop, rate in res.rates_hz.items():
    print(f"  {pop}: {float(rate):.1f} Hz, finite={bool(res.finite)}")
vmean = np.asarray(res.recordings["exc_v_mean"])
print(f"  exc mean V over the last 5 samples: {vmean[-5:].round(1)}")

print("\n=== exc raster (first 40 neurons x 80 ms, probe 'exc_raster') ===")
raster = np.asarray(res.recordings["exc_raster"])[:80, :40]
for t in range(0, 80, 2):
    print("  " + "".join("|" if raster[t, i] else "." for i in range(40)))

# 5. Sweep gscale for one synapse group: ONE vmapped compile ----------------
grid = np.logspace(-0.5, 0.8, 8)
sweep = model.sweep_gscale("ee", grid, n_steps=400)
print("\n=== gscale sweep over 'ee' (single vmapped compile) ===")
print(" gscale | exc Hz | finite")
for g, r, f in zip(np.asarray(sweep.values), np.asarray(sweep.rates_hz["exc"]),
                   np.asarray(sweep.finite)):
    print(f" {g:6.2f} | {r:6.1f} | {bool(f)}")
