"""The paper's core experiment in miniature: find gScale(nConn) keeping the
Izhikevich network's firing rate constant, under the NaN guard, and fit the
paper's hyperbola  gScale = k1/(k2 + nConn) + k3   (Table 1 / Fig 2).

Each candidate grid is evaluated through CompiledModel.sweep_gscale — the
ModelSpec front-end's first-class vmapped sweep (one compile per network).

  PYTHONPATH=src python examples/conductance_scaling.py
"""

import numpy as np

from benchmarks.gscale_experiments import izhikevich_gscale_sweep
from repro.core.conductance import hyperbola

res = izhikevich_gscale_sweep(
    n_total=300, n_conns=(30, 60, 90, 150, 220, 300), n_steps=250)

print("=== gScale search (target rate "
      f"{res['target_rate']:.1f} Hz) ===")
print(f"{'nConn':>6} {'gScale':>9} {'rate Hz':>8}")
for n, g, r in zip(res["n_conns"], res["gscales"], res["rates"]):
    print(f"{n:6d} {g:9.3f} {r:8.1f}")

print("\n=== hyperbola fit gScale = k1/(k2+nConn) + k3 ===")
print(f"k1={res['k1']:.4g}  k2={res['k2']:.4g}  k3={res['k3']:.4g}  "
      f"MAPE={res['mape_pct']:.2f}% (paper reports 3.95% at full scale)")

n = np.asarray(res["n_conns"], float)
pred = hyperbola(n, res["k1"], res["k2"], res["k3"])
print("\nfit vs observed:")
for ni, p, o in zip(res["n_conns"], pred, res["gscales"]):
    bar = int(max(0.0, min(p, 40)))
    print(f"  nConn={ni:4d} fit={p:7.3f} obs={o:7.3f} " + "#" * bar)
