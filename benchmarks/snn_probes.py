"""Probe-overhead benchmark: step time with 0 / 1 / 4 declared probes.

Probes write device-resident ring buffers inside the simulation scan; the
design constraint is that recording stays **off the hot path when unused**
(0-probe step time is the gated metric — benchmarks/check_regression.py
compares it against the committed baseline) and costs roughly one masked
row-write per probe per step when used (the 1- and 4-probe rows are
reported for the trajectory).

Emits ``experiments/bench/BENCH_snn_probes.json`` and prints harness CSV
rows.

    PYTHONPATH=src python -m benchmarks.snn_probes

Env knobs (kept small in CI): SNN_PROBE_BENCH_N (neurons, default 500),
SNN_PROBE_BENCH_NCONN (fanout, default 64), SNN_PROBE_BENCH_STEPS
(default 200), SNN_PROBE_BENCH_REPS (default 3).
"""

from __future__ import annotations

import json
import os
import time
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[1] / "experiments" / "bench"
OUT_NAME = "BENCH_snn_probes.json"

PROBE_SETS = {
    0: [],
    1: [("v", "exc", "V", {"every": 1})],
    4: [("v", "exc", "V", {"every": 1}),
        ("spk", "exc", "spikes", {"every": 1}),
        ("u", "exc", "U", {"every": 4}),
        ("v_mean", "exc", "V", {"reduce": "mean"})],
}


def _build(n_total: int, n_conn: int, n_probes: int):
    from repro.core.models.izhikevich_net import IzhikevichNetConfig, spec

    cfg = IzhikevichNetConfig(n_total=n_total, n_conn=n_conn, seed=0)
    ms = spec(cfg)
    for name, target, var, kw in PROBE_SETS[n_probes]:
        ms.probe(name, target, var, **kw)
    return ms.build(dt=cfg.dt, seed=cfg.seed)


def _time_run(model, n_steps: int, reps: int) -> float:
    import jax

    state = model.init_state()
    model.run(n_steps, state=state)                 # warm the executable
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        res = model.run(n_steps, state=state)
        jax.block_until_ready(res.spike_counts)
        if res.recordings:
            jax.block_until_ready(res.recordings.data)
        best = min(best, time.perf_counter() - t0)
    return best


def main() -> None:
    import jax

    n_total = int(os.environ.get("SNN_PROBE_BENCH_N", 500))
    n_conn = int(os.environ.get("SNN_PROBE_BENCH_NCONN", 64))
    n_steps = int(os.environ.get("SNN_PROBE_BENCH_STEPS", 200))
    reps = int(os.environ.get("SNN_PROBE_BENCH_REPS", 3))
    n_conn = min(n_conn, n_total)

    rows = []
    base_us = None
    for n_probes in sorted(PROBE_SETS):
        model = _build(n_total, n_conn, n_probes)
        wall = _time_run(model, n_steps, reps)
        us_per_step = wall / n_steps * 1e6
        if n_probes == 0:
            base_us = us_per_step
        rows.append({
            "probes": n_probes, "n_steps": n_steps, "wall_s": wall,
            "us_per_step": us_per_step,
            "overhead_vs_unprobed": (us_per_step / base_us
                                     if base_us else 1.0),
        })
        print(f"probe_overhead={n_probes},{us_per_step:.1f},us_per_step "
              f"x{rows[-1]['overhead_vs_unprobed']:.2f}", flush=True)

    payload = {
        "backend": jax.default_backend(),
        "n_total": n_total,
        "n_conn": n_conn,
        "n_steps": n_steps,
        "probe_overhead": rows,
    }
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / OUT_NAME).write_text(json.dumps(payload, indent=1,
                                               default=float))
    print(f"wrote {RESULTS / OUT_NAME}", flush=True)


if __name__ == "__main__":
    main()
