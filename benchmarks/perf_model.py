"""Analytic cost models for the Pallas kernels, used to substitute the
measured cost of the XLA reference cores (attention / SSD) in the
hillclimbed cells:  corrected_cell = measured(no_core) + kernel_model(core).

Conventions: per-device numbers; batch shards over the batch axes, heads
shard over the model axis only when divisible (mirrors layers.shard's
divisibility rule); f32 accumulate, bf16 streams.
"""

from __future__ import annotations

import math
from typing import Dict

from repro.configs.base import ArchConfig, ShapeConfig

BYTES = 2          # bf16 streams
QB, KB = 512, 1024  # kernel default blocks (kernels/flash_attention.py)


def _shards(cfg: ArchConfig, mesh_devices: int, multi_pod: bool) -> Dict:
    model = 16
    batch_axes = mesh_devices // model
    head_shard = model if cfg.n_heads and cfg.n_heads % model == 0 else 1
    return {"batch": batch_axes, "head": head_shard}


def _vis(tq, tk, window, causal=True):
    causal_vis = 0.5 * (1 + 1 / tq) if causal and tq == tk else 1.0
    if window is not None:
        return min(causal_vis, min(window, tk) / tk)
    return causal_vis


def flash_attention_cell(cfg: ArchConfig, shape: ShapeConfig,
                         n_dev: int) -> Dict[str, float]:
    """Whole-cell flash attention kernel cost (all attention layers)."""
    from benchmarks.roofline import _attn_layers
    b, t = shape.global_batch, shape.seq_len
    sh = _shards(cfg, n_dev, n_dev > 256)
    div = sh["batch"] * sh["head"]
    hq, hkv, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
    train = shape.kind == "train"
    # matmul passes: fwd 2; train fwd(2) + flash-bwd(5, incl recompute)
    passes = 7 if train else 2
    byte_mult = 3 if train else 1   # bwd re-streams k,v + dq/dk/dv writes

    flops = byt = 0.0
    for grp in _attn_layers(cfg):
        vis = _vis(t, t, grp["window"])
        flops += grp["n"] * vis * 2.0 * b * hq * t * t * hd * passes
        nq = math.ceil(t / QB)
        kv_stream = nq * 2.0 * b * hkv * (vis * t) * hd * BYTES
        qo = 2.0 * b * hq * t * hd * BYTES + 4.0 * b * hq * t  # + lse f32
        byt += grp["n"] * (kv_stream + qo) * byte_mult
    if cfg.n_enc_layers:
        ta = cfg.enc_seq
        flops += cfg.n_enc_layers * 2.0 * b * hq * ta * ta * hd * passes
        byt += cfg.n_enc_layers * (
            math.ceil(ta / QB) * 2.0 * b * hkv * ta * hd * BYTES
            + 2.0 * b * hq * ta * hd * BYTES) * byte_mult
    return {"flops": flops / div, "bytes": byt / div}


def ssd_cell(cfg: ArchConfig, shape: ShapeConfig, n_dev: int,
             chunk: int = 256) -> Dict[str, float]:
    """Whole-cell SSD kernel cost (all mamba layers)."""
    if not cfg.ssm_state:
        return {"flops": 0.0, "bytes": 0.0}
    b, t = shape.global_batch, shape.seq_len
    sh = _shards(cfg, n_dev, n_dev > 256)
    di = cfg.ssm_expand * cfg.d_model
    h = di // cfg.ssm_head
    dh, ds = cfg.ssm_head, cfg.ssm_state
    head_shard = 16 if h % 16 == 0 else 1
    div = sh["batch"] * head_shard

    prog = cfg.program()
    n_mamba = sum(s.n for s in prog.segments if s.kind == "mamba") \
        * prog.repeats + sum(s.n for s in prog.tail if s.kind == "mamba")
    nc = max(1, t // chunk)
    q = min(chunk, t)
    per_layer_flops = b * nc * h * (2.0 * q * q * (ds + dh)
                                    + 4.0 * q * ds * dh)
    per_layer_bytes = (2.0 * b * t * h * dh + b * t * h
                       + 4.0 * b * t * ds) * 4.0
    passes = 3.0 if shape.kind == "train" else 1.0   # fwd + bwd(2x)
    return {"flops": n_mamba * per_layer_flops * passes / div,
            "bytes": n_mamba * per_layer_bytes * passes / div}


def kernelized_terms(no_core: Dict, cfg: ArchConfig, shape: ShapeConfig,
                     n_dev: int) -> Dict[str, float]:
    """measured(no_core) + analytic kernel cost -> roofline terms."""
    from benchmarks.roofline import HBM_BW, ICI_BW, PEAK_FLOPS
    fa = flash_attention_cell(cfg, shape, n_dev)
    sd = ssd_cell(cfg, shape, n_dev)
    flops = no_core["flops"] + fa["flops"] + sd["flops"]
    byt = no_core["bytes"] + fa["bytes"] + sd["bytes"]
    coll = no_core["collective_total"]
    return {
        "flops": flops, "bytes": byt, "collective": coll,
        "t_compute_s": flops / PEAK_FLOPS,
        "t_memory_s": byt / HBM_BW,
        "t_collective_s": coll / ICI_BW,
    }
