"""Bench regression gate: freshly emitted JSONs vs committed baselines.

CI runs the small-size SNN benchmarks (benchmarks/snn_scaling.py,
benchmarks/snn_serving.py), then this script compares the step-time /
throughput numbers against the baselines committed under
``benchmarks/baselines/`` and fails on *gross* regressions — shared-runner
timing is noisy, so tolerances are generous ratios.  Tolerances are
**per-metric**, read from the committed baseline file itself: a top-level
``"tolerances": {"<metric>": <max worse-ratio>}`` mapping (falling back to
--max-ratio when a metric is unlisted) — so the latency SLO gates can be
tighter than the throughput gates without a flag soup in CI.  The JSONs
are also uploaded as workflow artifacts so the trajectory stays
inspectable.

Gated metrics (matched row-by-row on their key fields):

  BENCH_snn_scaling.json  weak_scaling[].us_per_step     (lower is better)
                          construction_memory[].peak_bytes_per_device
                          (lower is better; deterministic analytic bytes,
                          so the tolerance is tight — the fused-local rows
                          are the O(nnz/device) construction-memory claim)
  BENCH_snn_serving.json  streams[].steps_per_sec        (higher is better)
                          streams[].p99_total_s          (lower is better;
                          the per-request latency SLO the gateway serves)
  BENCH_snn_probes.json   probe_overhead[].us_per_step   (lower is better;
                          the probes=0 row is the recording-off-the-hot-
                          path guarantee, probed rows bound the cost)
  BENCH_snn_health.json   monitor_overhead[].us_per_step (lower is better;
                          the monitor=0 row is the monitoring-is-free-
                          when-off guarantee)
  BENCH_gateway_soak.json summary[].p99_step_us          (lower is better)
                          summary[].p99_flat_ratio       (lower is better;
                          second-half vs first-half p99 per-step latency —
                          the "flat under sustained load" SLO)

One **cross-file** gate ties the two zero-cost guarantees together: the
fresh monitor=0 row of BENCH_snn_health.json is compared against the
*committed baseline's* probes=0 row of BENCH_snn_probes.json — both
measure the identical unobserved hot path (same model, sizes, steps), so
a monitor-off build drifting away from the 0-probe baseline is a real
regression even if its own baseline was regenerated alongside it.

  BENCH_snn_event.json    modes[].us_per_step            (lower is better;
                          dense vs event step time per firing rate)
                          speedups[].event_speedup       (higher is better;
                          the sparse-activity win the event path exists for)

Construction times and other fields are reported but never gate (first-call
jit noise dominates them at CI sizes).  A missing fresh file or baseline is
a warning, not a failure, so the gate cannot mask a bench crash silently —
CI runs the benches as separate steps that fail on their own.  A malformed
*fresh* JSON likewise warns and skips (the bench step that wrote it fails
on its own); a malformed **committed baseline** is a hard failure — it is
repo content, nothing else will catch it, and silently skipping it would
disarm every gate on that file.  The final summary lists **every** failing
metric (one bad gate never hides the rest).

    PYTHONPATH=src python -m benchmarks.check_regression \
        [--fresh experiments/bench] [--baseline benchmarks/baselines] \
        [--max-ratio 3.0]
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]

# (file, series key, payload-identity fields, row-identity fields, metric,
# direction).  Identity fields must pin the whole workload a metric was
# measured on — payload fields cover knobs recorded once at top level
# (network size, device count): a CI env-knob change without a regenerated
# baseline then degrades to the skip-with-warning path instead of silently
# comparing incomparable numbers.
GATES = [
    ("BENCH_snn_scaling.json", "weak_scaling",
     ("devices", "per_device_neurons"),
     ("devices", "n_total", "neurons_per_device"), "us_per_step", "lower"),
    ("BENCH_snn_scaling.json", "construction_memory",
     ("devices", "per_device_neurons"),
     ("path", "devices", "n_pre"), "peak_bytes_per_device", "lower"),
    ("BENCH_snn_serving.json", "streams",
     ("devices", "n_total"),
     ("streams", "chunk", "n_steps", "requests"), "steps_per_sec", "higher"),
    ("BENCH_snn_serving.json", "streams",
     ("devices", "n_total"),
     ("streams", "chunk", "n_steps", "requests"), "p99_total_s", "lower"),
    ("BENCH_snn_probes.json", "probe_overhead",
     ("n_total", "n_conn", "n_steps"),
     ("probes",), "us_per_step", "lower"),
    ("BENCH_snn_health.json", "monitor_overhead",
     ("n_total", "n_conn", "n_steps"),
     ("monitor",), "us_per_step", "lower"),
    ("BENCH_gateway_soak.json", "summary",
     ("devices", "n_total"),
     ("streams", "chunk", "n_steps"), "p99_step_us", "lower"),
    ("BENCH_gateway_soak.json", "summary",
     ("devices", "n_total"),
     ("streams", "chunk", "n_steps"), "p99_flat_ratio", "lower"),
    ("BENCH_snn_event.json", "modes",
     ("n_pre", "n_conn", "n_steps"),
     ("mode", "rate_pct"), "us_per_step", "lower"),
    ("BENCH_snn_event.json", "speedups",
     ("n_pre", "n_conn", "n_steps"),
     ("rate_pct",), "event_speedup", "higher"),
]


# Cross-file gates: (fresh file, series, row-match {field: value}) vs
# (baseline file, series, row-match), sharing payload-identity fields.
CROSS_GATES = [
    ("BENCH_snn_health.json", "monitor_overhead", {"monitor": 0},
     "BENCH_snn_probes.json", "probe_overhead", {"probes": 0},
     ("n_total", "n_conn", "n_steps"), "us_per_step", "lower"),
]


def _load(path: Path, bad_baselines: set | None = None):
    """Parse one bench JSON.  Fresh files (bad_baselines=None) warn-skip on
    malformed content — the bench step that wrote them fails CI on its own.
    Committed baselines record into `bad_baselines` instead: check() turns
    a non-empty set into a hard failure (nothing else guards repo content,
    and skipping would silently disarm every gate on the file)."""
    if not path.exists():
        return None
    try:
        return json.loads(path.read_text())
    except ValueError as e:
        if bad_baselines is not None:
            print(f"[check_regression] ERROR: malformed committed baseline "
                  f"{path}: {e} — fix or regenerate it")
            bad_baselines.add(str(path))
        else:
            print(f"[check_regression] WARN: malformed JSON in {path}: {e} "
                  "— skipping gates on this file")
        return None


def _index(rows, fields):
    return {tuple(r.get(f) for f in fields): r for r in rows}


def _compare(failures, tag, fields, key, metric, direction, got, want,
             tol) -> bool:
    """Compare one fresh/baseline metric pair; records failures, returns
    whether a comparison actually happened (want > 0)."""
    if want <= 0:
        return False
    ratio = got / want
    worse = ratio if direction == "lower" else 1.0 / max(ratio, 1e-12)
    ok = worse <= tol
    verdict = "ok" if ok else "REGRESSION"
    ident = f"{tag}{dict(zip(fields, key)) if fields else ''}"
    print(f"[check_regression] {ident} {metric}: "
          f"fresh={got:.3g} baseline={want:.3g} ({worse:.2f}x worse-ratio, "
          f"tol {tol}x) {verdict}")
    if not ok:
        failures.append((ident, metric, got, want, worse, tol))
    return True


def check(fresh_dir: Path, base_dir: Path, max_ratio: float) -> int:
    failures, checked = [], 0
    bad_baselines: set = set()
    for fname, series, pfields, fields, metric, direction in GATES:
        try:
            fresh = _load(fresh_dir / fname)
            base = _load(base_dir / fname, bad_baselines)
            if fresh is None:
                print(f"[check_regression] WARN: no fresh {fname} "
                      f"(bench not run?)")
                continue
            if base is None:
                print(f"[check_regression] WARN: no baseline {fname} "
                      f"(commit one under {base_dir})")
                continue
            mismatch = {f: (fresh.get(f), base.get(f)) for f in pfields
                        if fresh.get(f) != base.get(f)}
            if mismatch:
                print(f"[check_regression] WARN: {fname} workload differs "
                      f"from baseline {mismatch}; regenerate the baseline "
                      "— skipping this gate")
                continue
            # per-metric tolerance lives next to the numbers it bounds: the
            # committed baseline file (regenerating the baseline is already
            # the ritual for workload changes, so tolerance changes ride
            # along)
            tol = float(base.get("tolerances", {}).get(metric, max_ratio))
            base_rows = _index(base.get(series, []), fields)
            for row in fresh.get(series, []):
                key = tuple(row.get(f) for f in fields)
                ref = base_rows.get(key)
                if ref is None or metric not in ref or metric not in row:
                    continue
                checked += _compare(
                    failures, f"{fname} {series}", fields, key, metric,
                    direction, float(row[metric]), float(ref[metric]), tol)
        except Exception as e:      # one broken gate must not hide the rest
            print(f"[check_regression] WARN: gate {fname}/{series}/{metric} "
                  f"errored ({type(e).__name__}: {e}) — continuing")

    for (ffname, fseries, fmatch, bfname, bseries, bmatch, pfields,
         metric, direction) in CROSS_GATES:
        try:
            fresh = _load(fresh_dir / ffname)
            base = _load(base_dir / bfname, bad_baselines)
            if fresh is None or base is None:
                print(f"[check_regression] WARN: cross gate {ffname} vs "
                      f"{bfname} missing a side — skipping")
                continue
            mismatch = {f: (fresh.get(f), base.get(f)) for f in pfields
                        if fresh.get(f) != base.get(f)}
            if mismatch:
                print(f"[check_regression] WARN: cross gate {ffname} vs "
                      f"{bfname} workloads differ {mismatch} — skipping")
                continue
            tol = float(base.get("tolerances", {}).get(metric, max_ratio))
            frows = [r for r in fresh.get(fseries, [])
                     if all(r.get(k) == v for k, v in fmatch.items())]
            brows = [r for r in base.get(bseries, [])
                     if all(r.get(k) == v for k, v in bmatch.items())]
            if not frows or not brows:
                print(f"[check_regression] WARN: cross gate rows {fmatch} / "
                      f"{bmatch} not found — skipping")
                continue
            checked += _compare(
                failures, f"{ffname}:{fmatch} vs {bfname}:{bmatch} ",
                (), (), metric, direction,
                float(frows[0][metric]), float(brows[0][metric]), tol)
        except Exception as e:
            print(f"[check_regression] WARN: cross gate {ffname} vs "
                  f"{bfname} errored ({type(e).__name__}: {e}) — continuing")

    if not checked:
        print("[check_regression] WARN: nothing compared")
    if bad_baselines:
        print(f"[check_regression] FAILED: {len(bad_baselines)} malformed "
              f"committed baseline(s): {sorted(bad_baselines)}")
        return 1
    if failures:
        print(f"[check_regression] FAILED: {len(failures)} gross "
              f"regression(s) (over per-metric tolerance):")
        for ident, metric, got, want, worse, tol in failures:
            print(f"[check_regression]   {ident} {metric}: fresh={got:.3g} "
                  f"baseline={want:.3g} ({worse:.2f}x worse, tol {tol}x)")
        return 1
    print(f"[check_regression] passed: {checked} metric(s) within "
          "tolerance of baseline")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", type=Path,
                    default=REPO / "experiments" / "bench")
    ap.add_argument("--baseline", type=Path,
                    default=REPO / "benchmarks" / "baselines")
    ap.add_argument("--max-ratio", type=float, default=3.0,
                    help="fallback tolerance for metrics the baseline "
                         "file's 'tolerances' mapping does not list")
    args = ap.parse_args(argv)
    return check(args.fresh, args.baseline, args.max_ratio)


if __name__ == "__main__":
    sys.exit(main())
