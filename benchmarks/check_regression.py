"""Bench regression gate: freshly emitted JSONs vs committed baselines.

CI runs the small-size SNN benchmarks (benchmarks/snn_scaling.py,
benchmarks/snn_serving.py), then this script compares the step-time /
throughput numbers against the baselines committed under
``benchmarks/baselines/`` and fails on *gross* regressions — shared-runner
timing is noisy, so the default tolerance is a generous 3x ratio; the JSONs
are also uploaded as workflow artifacts so the trajectory stays inspectable.

Gated metrics (matched row-by-row on their key fields):

  BENCH_snn_scaling.json  weak_scaling[].us_per_step    (lower is better)
  BENCH_snn_serving.json  streams[].steps_per_sec       (higher is better)
  BENCH_snn_probes.json   probe_overhead[].us_per_step  (lower is better;
                          the probes=0 row is the recording-off-the-hot-
                          path guarantee, probed rows bound the cost)

Construction times and other fields are reported but never gate (first-call
jit noise dominates them at CI sizes).  A missing fresh file or baseline is
a warning, not a failure, so the gate cannot mask a bench crash silently —
CI runs the benches as separate steps that fail on their own.

    PYTHONPATH=src python -m benchmarks.check_regression \
        [--fresh experiments/bench] [--baseline benchmarks/baselines] \
        [--max-ratio 3.0]
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]

# (file, series key, payload-identity fields, row-identity fields, metric,
# direction).  Identity fields must pin the whole workload a metric was
# measured on — payload fields cover knobs recorded once at top level
# (network size, device count): a CI env-knob change without a regenerated
# baseline then degrades to the skip-with-warning path instead of silently
# comparing incomparable numbers.
GATES = [
    ("BENCH_snn_scaling.json", "weak_scaling",
     ("devices", "per_device_neurons"),
     ("devices", "n_total", "neurons_per_device"), "us_per_step", "lower"),
    ("BENCH_snn_serving.json", "streams",
     ("devices", "n_total"),
     ("streams", "chunk", "n_steps", "requests"), "steps_per_sec", "higher"),
    ("BENCH_snn_probes.json", "probe_overhead",
     ("n_total", "n_conn", "n_steps"),
     ("probes",), "us_per_step", "lower"),
]


def _load(path: Path):
    if not path.exists():
        return None
    return json.loads(path.read_text())


def _index(rows, fields):
    return {tuple(r.get(f) for f in fields): r for r in rows}


def check(fresh_dir: Path, base_dir: Path, max_ratio: float) -> int:
    failures, checked = [], 0
    for fname, series, pfields, fields, metric, direction in GATES:
        fresh = _load(fresh_dir / fname)
        base = _load(base_dir / fname)
        if fresh is None:
            print(f"[check_regression] WARN: no fresh {fname} "
                  f"(bench not run?)")
            continue
        if base is None:
            print(f"[check_regression] WARN: no baseline {fname} "
                  f"(commit one under {base_dir})")
            continue
        mismatch = {f: (fresh.get(f), base.get(f)) for f in pfields
                    if fresh.get(f) != base.get(f)}
        if mismatch:
            print(f"[check_regression] WARN: {fname} workload differs from "
                  f"baseline {mismatch}; regenerate the baseline — "
                  "skipping this gate")
            continue
        base_rows = _index(base.get(series, []), fields)
        for row in fresh.get(series, []):
            key = tuple(row.get(f) for f in fields)
            ref = base_rows.get(key)
            if ref is None or metric not in ref or metric not in row:
                continue
            got, want = float(row[metric]), float(ref[metric])
            if want <= 0:
                continue
            ratio = got / want
            worse = ratio if direction == "lower" else 1.0 / max(ratio, 1e-12)
            ok = worse <= max_ratio
            checked += 1
            tag = "ok" if ok else "REGRESSION"
            print(f"[check_regression] {fname} {series}"
                  f"{dict(zip(fields, key))} {metric}: fresh={got:.1f} "
                  f"baseline={want:.1f} ({worse:.2f}x worse-ratio) {tag}")
            if not ok:
                failures.append((fname, key, metric, got, want, worse))
    if not checked:
        print("[check_regression] WARN: nothing compared")
    if failures:
        print(f"[check_regression] FAILED: {len(failures)} gross "
              f"regression(s) (> {max_ratio}x)")
        return 1
    print(f"[check_regression] passed: {checked} metric(s) within "
          f"{max_ratio}x of baseline")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", type=Path,
                    default=REPO / "experiments" / "bench")
    ap.add_argument("--baseline", type=Path,
                    default=REPO / "benchmarks" / "baselines")
    ap.add_argument("--max-ratio", type=float, default=3.0,
                    help="fail when a metric is more than this factor "
                         "worse than baseline")
    args = ap.parse_args(argv)
    return check(args.fresh, args.baseline, args.max_ratio)


if __name__ == "__main__":
    sys.exit(main())
