"""Health-monitor overhead benchmark: step time with the monitor off / on.

The on-device activity monitor (repro.obs.health, compiled into the scan
via ``build(..., monitor=HealthConfig(...))``) must be **strictly free when
off** — a monitor-off build produces the same jaxpr as an unmonitored one
(tests/test_obs.py pins this down), so its step time is gated against the
committed baseline *and*, cross-file, against the 0-probe row of
BENCH_snn_probes.json (benchmarks/check_regression.py): the two rows
measure the identical unobserved hot path and must agree.  The monitor-on
row is reported for the trajectory (a handful of scalar adds per step).

Emits ``experiments/bench/BENCH_snn_health.json`` and prints harness CSV
rows.

    PYTHONPATH=src python -m benchmarks.snn_health

Env knobs (kept small in CI, matching snn_probes so the cross-file gate
compares like against like): SNN_HEALTH_BENCH_N (neurons, default 500),
SNN_HEALTH_BENCH_NCONN (fanout, default 64), SNN_HEALTH_BENCH_STEPS
(default 200), SNN_HEALTH_BENCH_REPS (default 3).
"""

from __future__ import annotations

import json
import os
import time
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[1] / "experiments" / "bench"
OUT_NAME = "BENCH_snn_health.json"


def _build(n_total: int, n_conn: int, monitored: bool):
    from repro.core.models.izhikevich_net import (IzhikevichNetConfig,
                                                  compile_model)
    from repro.obs.health import HealthConfig

    cfg = IzhikevichNetConfig(n_total=n_total, n_conn=n_conn, seed=0)
    return compile_model(cfg,
                         monitor=HealthConfig() if monitored else None)


def _time_run(model, n_steps: int, reps: int) -> float:
    import jax

    state = model.init_state()
    model.run(n_steps, state=state)                 # warm the executable
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        res = model.run(n_steps, state=state)
        jax.block_until_ready(res.spike_counts)
        if res.health is not None:
            jax.block_until_ready(jax.tree.leaves(res.health))
        best = min(best, time.perf_counter() - t0)
    return best


def main() -> None:
    import jax

    n_total = int(os.environ.get("SNN_HEALTH_BENCH_N", 500))
    n_conn = int(os.environ.get("SNN_HEALTH_BENCH_NCONN", 64))
    n_steps = int(os.environ.get("SNN_HEALTH_BENCH_STEPS", 200))
    reps = int(os.environ.get("SNN_HEALTH_BENCH_REPS", 3))
    n_conn = min(n_conn, n_total)

    rows = []
    base_us = None
    for monitored in (0, 1):
        model = _build(n_total, n_conn, bool(monitored))
        wall = _time_run(model, n_steps, reps)
        us_per_step = wall / n_steps * 1e6
        if not monitored:
            base_us = us_per_step
        rows.append({
            "monitor": monitored, "n_steps": n_steps, "wall_s": wall,
            "us_per_step": us_per_step,
            "overhead_vs_unmonitored": (us_per_step / base_us
                                        if base_us else 1.0),
        })
        print(f"monitor_overhead={monitored},{us_per_step:.1f},us_per_step "
              f"x{rows[-1]['overhead_vs_unmonitored']:.2f}", flush=True)

    payload = {
        "backend": jax.default_backend(),
        "n_total": n_total,
        "n_conn": n_conn,
        "n_steps": n_steps,
        "monitor_overhead": rows,
    }
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / OUT_NAME).write_text(json.dumps(payload, indent=1,
                                               default=float))
    print(f"wrote {RESULTS / OUT_NAME}", flush=True)


if __name__ == "__main__":
    main()
