"""Weak-scaling benchmarks for the sharded SNN engine + device construction.

Two series, both at constant work per device (weak scaling):

  * construction: host-side numpy initializer vs device-resident
    `device_init` resolve, build wall time vs network size;
  * simulation: ShardedEngine step time at D = 1, 2, 4, ... devices with
    neurons/device held constant.

Emits ``experiments/bench/BENCH_snn_scaling.json`` (the perf-trajectory
seed) and prints the harness CSV rows.

Run on CPU with fake devices (the CI job does this on every push):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m benchmarks.snn_scaling
"""

from __future__ import annotations

import json
import os
import sys
import time
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[1] / "experiments" / "bench"
OUT_NAME = "BENCH_snn_scaling.json"


def _bench_construction(per_dev: int, n_conn: int, sizes) -> list:
    import numpy as np
    import jax
    from repro.sparse import device_init as DI
    from repro.sparse import formats as F

    rows = []
    for n in sizes:
        rng = np.random.default_rng(0)
        t0 = time.perf_counter()
        F.FixedFanout(n_conn).resolve(rng, n, n, F.UniformWeight(0, 0.5))
        host_s = time.perf_counter() - t0

        key = jax.random.PRNGKey(0)
        # compiled-path timing: first call pays jit, second is steady state
        args = (F.FixedFanout(n_conn), key, n, n, F.UniformWeight(0, 0.5))
        jax.block_until_ready(DI.device_resolve(*args))
        t0 = time.perf_counter()
        jax.block_until_ready(DI.device_resolve(*args))
        dev_s = time.perf_counter() - t0
        rows.append({"n": n, "n_conn": n_conn, "host_s": host_s,
                     "device_s": dev_s,
                     "speedup": host_s / max(dev_s, 1e-9)})
        print(f"construct_n={n},{dev_s * 1e6:.1f},"
              f"host_us={host_s * 1e6:.1f} speedup={rows[-1]['speedup']:.1f}",
              flush=True)
    return rows


def _bench_construction_memory(per_dev: int, n_conn: int) -> list:
    """Peak construction bytes per device at fixed total size: the fused
    `device_init_local` path vs generate-then-partition.  The fused row
    must drop as devices double (O(nnz/device)); the partition row stays
    O(nnz).  k_local comes from a real fused build at each device count,
    the bytes from the analytic model `construction_peak_model` — the
    numbers are deterministic, so the regression gate can be tight."""
    import jax
    from repro.launch.mesh import make_snn_mesh
    from repro.sparse import device_init as DI
    from repro.sparse import formats as F

    n_dev = jax.device_count()
    n = per_dev * n_dev
    k = min(n_conn, n)
    rows = []
    d = 1
    while d <= n_dev:
        out = DI.device_init_local(F.FixedFanout(k), jax.random.PRNGKey(0),
                                   n, n, make_snn_mesh(d),
                                   weight=F.UniformWeight(0, 0.5))
        k_local = out[5]
        peak = DI.construction_peak_model(n, k, d, k_local)
        for path, nbytes in (
                ("fused_local", peak["fused_local_bytes"]),
                ("generate_partition", peak["generate_partition_bytes"])):
            rows.append({"path": path, "devices": d, "n_pre": n,
                         "k": k, "k_local": k_local,
                         "peak_bytes_per_device": int(nbytes)})
            print(f"construct_mem_{path}_d={d}_n={n},{nbytes},"
                  "peak_bytes_per_device", flush=True)
        d *= 2
    return rows


def _bench_weak_scaling_steps(per_dev: int, n_conn: int,
                              n_steps: int) -> list:
    import jax
    from repro.core.models.izhikevich_net import (IzhikevichNetConfig,
                                                  compile_model)
    from repro.launch.mesh import make_snn_mesh

    n_dev = jax.device_count()
    rows = []
    d = 1
    while d <= n_dev:
        n_total = per_dev * d
        cfg = IzhikevichNetConfig(n_total=n_total,
                                  n_conn=min(n_conn, n_total))
        model = compile_model(cfg, mesh=make_snn_mesh(d), init="device")
        state = model.init_state()
        jax.block_until_ready(model.run(n_steps, state=state).spike_counts)
        t0 = time.perf_counter()
        jax.block_until_ready(model.run(n_steps, state=state).spike_counts)
        per_step_us = (time.perf_counter() - t0) / n_steps * 1e6
        rows.append({"devices": d, "n_total": n_total,
                     "neurons_per_device": per_dev,
                     "us_per_step": per_step_us})
        print(f"weak_scaling_d={d}_n={n_total},{per_step_us:.1f},"
              f"us_per_step", flush=True)
        d *= 2
    return rows


def main() -> None:
    import jax

    per_dev = int(os.environ.get("SNN_BENCH_PER_DEV", 1024))
    n_conn = int(os.environ.get("SNN_BENCH_NCONN", 64))
    n_steps = int(os.environ.get("SNN_BENCH_STEPS", 50))
    sizes = [per_dev, 2 * per_dev, 4 * per_dev]

    payload = {
        "devices": jax.device_count(),
        "backend": jax.default_backend(),
        "per_device_neurons": per_dev,
        "construction": _bench_construction(per_dev, n_conn, sizes),
        "construction_memory": _bench_construction_memory(per_dev, n_conn),
        "weak_scaling": _bench_weak_scaling_steps(per_dev, n_conn,
                                                  n_steps),
    }
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / OUT_NAME).write_text(json.dumps(payload, indent=1,
                                               default=float))
    print(f"wrote {RESULTS / OUT_NAME}", flush=True)


if __name__ == "__main__":
    # must precede any jax import: device count locks at backend init
    if "--xla_force_host_platform_device_count" not in os.environ.get(
            "XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + " --xla_force_host_platform_device_count=8").strip()
    sys.exit(main())
