"""Conductance-scaling experiments (paper §5.1, Tables 1-2, Figs 2-3),
reduced to CPU-tractable sizes but methodologically identical:

  1. run the reference configuration, record its population rate;
  2. for each nConn, search gScale so the rate returns to the reference
     band, under the Fig-1 NaN guard (vmapped candidate sweep + refinement);
  3. fit gScale = k1/(k2+nConn)+k3 by the paper's linearized regression.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import conductance as C
from repro.core.models import izhikevich_net, mushroom_body

__all__ = ["izhikevich_gscale_sweep", "mushroom_gscale_sweep"]


def _rate_fn(model, names, n_steps, pop, state=None):
    """Candidate-vmapped (rates, finite) via CompiledModel.sweep_gscale —
    the first-class sweep replaces the hand-rolled jit(vmap(run))."""
    if state is None:
        state = model.init_state()

    def fn(grid):
        sw = model.sweep_gscale(names, grid, n_steps, state=state)
        return sw.rates_hz[pop], sw.finite

    return fn


def izhikevich_gscale_sweep(
    n_total: int = 400, n_conns: Tuple[int, ...] = (40, 60, 80, 120, 160,
                                                    240, 320, 400),
    n_steps: int = 350, representation: str = "auto", seed: int = 12,
    candidates: int = 20,
) -> Dict:
    """gScale(nConn) for the Izhikevich cortical net (reduced grid)."""
    # reference: the fully-connected-equivalent config at gScale = 1
    ref_cfg = izhikevich_net.IzhikevichNetConfig(
        n_total=n_total, n_conn=n_conns[-1], seed=seed,
        representation=representation)
    model = izhikevich_net.compile_model(ref_cfg)
    names = model.group_names
    rate_fn = _rate_fn(model, names, n_steps, "exc")
    r, f = rate_fn(jnp.ones((1,), jnp.float32))
    target = float(r[0])

    gscales, rates = [], []
    for n_conn in n_conns:
        cfg = dataclasses.replace(ref_cfg, n_conn=n_conn)
        model_i = izhikevich_net.compile_model(cfg)
        fn = _rate_fn(model_i, model_i.group_names, n_steps, "exc")
        # coarse log-grid sweep (one vmapped launch), then local refine
        grid = jnp.logspace(-1.0, 1.8, candidates)
        res = C.search_sweep(fn, grid, target)
        lo = max(res.gscale / 1.8, float(grid[0]))
        hi = min(res.gscale * 1.8, float(grid[-1]))
        fine = jnp.linspace(lo, hi, candidates)
        res = C.search_sweep(fn, fine, target)
        gscales.append(res.gscale)
        rates.append(res.rate_hz)

    k1, k2, k3, err = C.fit_hyperbola(np.asarray(n_conns, float),
                                      np.asarray(gscales, float))
    return {
        "n_conns": list(n_conns), "gscales": gscales, "rates": rates,
        "target_rate": target, "k1": k1, "k2": k2, "k3": k3,
        "mape_pct": err, "representation": representation,
    }


def mushroom_gscale_sweep(
    n_pns: Tuple[int, ...] = (8, 12, 20, 32),
    n_lhi: int = 5, n_kc: int = 100, n_dn: int = 10,
    n_steps: int = 700, seed: int = 9, candidates: int = 12,
) -> Dict:
    """gScale(nPN) for the mushroom-body PN->KC synapse (reduced)."""
    ref = mushroom_body.MushroomBodyConfig(
        n_pn=n_pns[-1], n_lhi=n_lhi, n_kc=n_kc, n_dn=n_dn, seed=seed)
    model = mushroom_body.compile_model(ref)
    fn = _rate_fn(model, ["PN_KC"], n_steps, "KC")
    r, _ = fn(jnp.ones((1,), jnp.float32))
    target = float(r[0])
    fn_lhi = _rate_fn(model, ["PN_LHI"], n_steps, "LHI")
    r_lhi, _ = fn_lhi(jnp.ones((1,), jnp.float32))
    target_lhi = float(r_lhi[0])

    gscales, rates = [], []
    gscales_lhi = []
    for n_pn in n_pns:
        cfg = dataclasses.replace(ref, n_pn=n_pn)
        model_i = mushroom_body.compile_model(cfg)
        fn_i = _rate_fn(model_i, ["PN_KC"], n_steps, "KC")
        grid = jnp.logspace(-0.7, 1.6, candidates)
        res = C.search_sweep(fn_i, grid, target)
        fine = jnp.linspace(max(res.gscale / 2, 1e-2), res.gscale * 2,
                            candidates)
        res = C.search_sweep(fn_i, fine, target)
        gscales.append(res.gscale)
        rates.append(res.rate_hz)
        # PN->LHI (the paper's second fitted synapse; its Table-2 fit is
        # the poor one, MAPE 71.4%)
        fn_l = _rate_fn(model_i, ["PN_LHI"], n_steps, "LHI")
        res_l = C.search_sweep(fn_l, grid, target_lhi)
        fine_l = jnp.linspace(max(res_l.gscale / 2, 1e-2),
                              res_l.gscale * 2, candidates)
        res_l = C.search_sweep(fn_l, fine_l, target_lhi)
        gscales_lhi.append(res_l.gscale)

    k1, k2, k3, err = C.fit_hyperbola(np.asarray(n_pns, float),
                                      np.asarray(gscales, float))
    kl1, kl2, kl3, errl = C.fit_hyperbola(np.asarray(n_pns, float),
                                          np.asarray(gscales_lhi, float))
    return {
        "n_pns": list(n_pns), "gscales": gscales, "rates": rates,
        "target_rate": target, "k1": k1, "k2": k2, "k3": k3,
        "mape_pct": err, "n_lhi": n_lhi,
        "gscales_lhi": gscales_lhi, "k1_lhi": kl1, "k2_lhi": kl2,
        "k3_lhi": kl3, "mape_lhi_pct": errl,
    }
