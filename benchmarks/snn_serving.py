"""Streams-vs-throughput benchmark for the SNN streaming server.

For a fixed network, sweep the number of device-resident stream slots
(1, 2, 4, ... up to SNN_SERVE_BENCH_STREAMS) and measure aggregate serving
throughput: all slots advance together in one compiled serve_chunk, so
throughput should grow near-linearly with streams until the hardware
saturates — the continuous-batching amortization the serving design is for.
Each row also records p50/p99 *per-request* total latency (submit to
finish), the SLO metric the gateway serves; check_regression.py gates it
with its own (tighter) tolerance from the committed baseline.

Emits ``experiments/bench/BENCH_snn_serving.json`` (gated against a
committed baseline by benchmarks/check_regression.py in CI) and prints the
harness CSV rows.

    PYTHONPATH=src python -m benchmarks.snn_serving

Env knobs (kept small in CI): SNN_SERVE_BENCH_STREAMS (max slots, default
8), SNN_SERVE_BENCH_STEPS (stimulus length, default 200), SNN_SERVE_BENCH_N
(neurons, default 500), SNN_SERVE_BENCH_CHUNK (default 50),
SNN_SERVE_BENCH_DEVICES (shard over N devices, default 0 = host build).
"""

from __future__ import annotations

import json
import os
import time
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[1] / "experiments" / "bench"
OUT_NAME = "BENCH_snn_serving.json"


def _percentile(xs, q: float) -> float:
    if not xs:
        return 0.0
    s = sorted(xs)
    return s[min(len(s) - 1, max(0, int(round(q * (len(s) - 1)))))]


def _bench_streams(model, stim_pop: str, max_streams: int, chunk: int,
                   n_steps: int) -> list:
    import numpy as np
    from repro.launch.snn_serve import SNNServer, StreamRequest

    n = model.network.populations[stim_pop].n

    def one_trial(s: int):
        srv = SNNServer(model, max_streams=s, chunk=chunk,
                        stim_pops=(stim_pop,))
        rng = np.random.default_rng(0)
        # 2x oversubscription so slot turnover (admit/evict) is measured too
        for i in range(2 * s):
            stim = {stim_pop: (3.0 * rng.normal(size=(n_steps, n)))
                    .astype(np.float32)}
            srv.submit(StreamRequest(rid=i, n_steps=n_steps, stim=stim,
                                     seed=i))
        # warm the compiled chunk program before timing
        srv.serve_step()
        pre = srv.total_slot_steps
        t0 = time.perf_counter()
        srv.run()
        wall = time.perf_counter() - t0
        served = srv.total_slot_steps - pre
        totals = [t.total_s for t in srv.sched.timings.values()
                  if t.finished_at is not None]
        return served, wall, srv.stats()["slot_utilization"], totals

    rows = []
    s = 1
    while s <= max_streams:
        # best of 2: shared-runner noise easily dwarfs the effect measured
        served, wall, util, totals = min(
            (one_trial(s) for _ in range(2)), key=lambda r: r[1] / r[0])
        steps_per_sec = served / max(wall, 1e-9)
        rows.append({
            "streams": s, "requests": 2 * s, "chunk": chunk,
            "n_steps": n_steps, "slot_steps": served, "wall_s": wall,
            "steps_per_sec": steps_per_sec,
            "utilization": util,
            # per-request total latency (submit -> finish): the serving
            # SLO, gated with its own tolerance in check_regression.py
            "p50_total_s": _percentile(totals, 0.50),
            "p99_total_s": _percentile(totals, 0.99),
        })
        print(f"serving_streams={s},{steps_per_sec:.1f},steps_per_sec "
              f"util={util:.2f} p99_total={rows[-1]['p99_total_s']:.3f}s",
              flush=True)
        s *= 2
    return rows


def main() -> None:
    import jax
    from repro.core.models.izhikevich_net import (IzhikevichNetConfig,
                                                  compile_model)

    max_streams = int(os.environ.get("SNN_SERVE_BENCH_STREAMS", 8))
    n_steps = int(os.environ.get("SNN_SERVE_BENCH_STEPS", 200))
    n_total = int(os.environ.get("SNN_SERVE_BENCH_N", 500))
    chunk = int(os.environ.get("SNN_SERVE_BENCH_CHUNK", 50))
    devices = int(os.environ.get("SNN_SERVE_BENCH_DEVICES", 0))

    mesh = None
    if devices:
        from repro.launch.mesh import make_snn_mesh
        mesh = make_snn_mesh(devices)
    cfg = IzhikevichNetConfig(n_total=n_total,
                              n_conn=min(64, n_total))
    model = compile_model(cfg, mesh=mesh)

    payload = {
        "devices": devices or 1,
        "backend": jax.default_backend(),
        "model": model.spec.name,
        "n_total": n_total,
        "streams": _bench_streams(model, "exc", max_streams, chunk,
                                  n_steps),
    }
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / OUT_NAME).write_text(json.dumps(payload, indent=1,
                                               default=float))
    print(f"wrote {RESULTS / OUT_NAME}", flush=True)


if __name__ == "__main__":
    main()
