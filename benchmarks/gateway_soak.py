"""Gateway soak: sustain thousands of deadline-bearing streams, assert SLOs.

The serving gateway's acceptance contract (ISSUE 6): drive >= 1000 streams
through a gateway with deadlines enabled and show (a) per-step p99 latency
stays *flat* across the run — no drift as slots churn, tables resize and
expired streams get evicted — and (b) every stream that was **not** evicted
is bit-exact against an offline ``model.run`` with the same seed and
stimulus, for host and sharded builds alike.

Traffic shape: requests arrive in bursts against a bounded admission queue
(so backpressure/rejection paths are exercised — rejected submits retry
after a tick), every ``evict_every``-th request carries a deliberately
impossible deadline (so queued *and* mid-flight eviction paths are
exercised), and everything else carries a generous-but-real deadline.

Emits ``experiments/bench/BENCH_gateway_soak.json``; CI gates
``p99_step_us`` and ``p99_flat_ratio`` against the committed baseline with
per-metric tolerances (benchmarks/check_regression.py) and the ``gateway``
job runs a sharded smoke asserting occupancy/rejection/eviction counters.

    PYTHONPATH=src python -m benchmarks.gateway_soak --streams 1000
    XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
        python -m benchmarks.gateway_soak --streams 300 --devices 8 \
        --require-rejections --require-evictions
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path
from typing import Dict, List

RESULTS = Path(__file__).resolve().parents[1] / "experiments" / "bench"
OUT_NAME = "BENCH_gateway_soak.json"


def _percentile(xs: List[float], q: float) -> float:
    if not xs:
        return 0.0
    s = sorted(xs)
    return s[min(len(s) - 1, max(0, int(round(q * (len(s) - 1)))))]


def run_soak(streams: int = 1000, devices: int = 0, n_total: int = 40,
             n_conn: int = 8, n_steps: int = 24, chunk: int = 8,
             buckets=(8, 16, 32), max_queue: int = 48, burst: int = 32,
             deadline_ms: float = 120_000.0, evict_every: int = 9,
             verify: bool = True, warm: bool = True,
             seed: int = 0) -> Dict:
    """Drive ``streams`` requests through one gateway; returns the metrics
    row (plus raw latency windows) the JSON and the assertions consume.

    Every request has a deadline: most get ``deadline_ms`` (generous —
    they must finish), every ``evict_every``-th gets ~0 (it must be
    evicted).  Rejected submits (queue full) are retried after serving a
    tick, so the full target count still flows *through* the gateway.
    """
    import jax
    import numpy as np
    from repro.core.models.izhikevich_net import (IzhikevichNetConfig,
                                                  compile_model)
    from repro.launch.gateway import Gateway, GatewayOverloaded

    mesh = None
    if devices:
        from repro.launch.mesh import make_snn_mesh
        mesh = make_snn_mesh(devices)
    model = compile_model(IzhikevichNetConfig(n_total=n_total,
                                              n_conn=min(n_conn, n_total)),
                          mesh=mesh)
    gw = Gateway(chunk=chunk, buckets=buckets, max_queue=max_queue,
                 warm=warm)
    gw.register("soak", model, stim_pops=("exc",))
    worker = gw.workers["soak"]
    n = model.network.populations["exc"].n
    rng = np.random.default_rng(seed)

    # one stimulus bank, fixed n_steps: the offline verification then
    # reuses a single compiled run executable across all streams
    rejected_submits = 0
    submitted = 0
    t0 = time.perf_counter()
    i = 0
    while i < streams:
        for _ in range(min(burst, streams - i)):
            stim = {"exc": (3.0 * rng.normal(size=(n_steps, n)))
                    .astype(np.float32)}
            dl = 0.01 if (i % evict_every == evict_every - 1) else deadline_ms
            while True:
                try:
                    gw.submit("soak", stim, n_steps, seed=10_000 + i,
                              deadline_ms=dl)
                    submitted += 1
                    break
                except GatewayOverloaded:
                    rejected_submits += 1
                    gw.tick()        # serve a chunk, then retry
            i += 1
        gw.tick()                    # interleave serving with arrivals
    gw.run_until_drained()
    wall_s = time.perf_counter() - t0

    done = gw.collect_finished()
    completed = [r for r in done if r.status == "done"]
    evicted = [r for r in done if r.evicted]
    metrics = gw.metrics()["models"]["soak"]

    # flatness: p99 per-step latency, first half of the run vs second half
    lat = worker.step_latency_us.samples()
    half = len(lat) // 2
    p99_a = _percentile(lat[:half], 0.99)
    p99_b = _percentile(lat[half:], 0.99)
    flat_ratio = (p99_b / p99_a) if p99_a > 0 else 1.0

    verified = 0
    if verify:
        for r in completed:
            res = model.run(r.n_steps, stim=r.stim,
                            state=model.init_state(
                                jax.random.PRNGKey(r.seed)))
            for k, v in res.spike_counts.items():
                got = r.spike_counts[k]
                if not np.array_equal(np.asarray(v), got):
                    raise AssertionError(
                        f"stream {r.rid} population {k!r}: served spike "
                        "counts diverged from the offline run — eviction/"
                        "resize perturbed a surviving stream")
            verified += 1

    row = {
        "streams": streams, "devices": devices or 1, "chunk": chunk,
        "n_steps": n_steps, "buckets": list(worker.buckets),
        "max_queue": max_queue, "wall_s": wall_s,
        "submitted": submitted, "completed": len(completed),
        "evicted": len(evicted), "rejected_submits": rejected_submits,
        "occupancy": metrics["occupancy"],
        "steps_per_sec": metrics["slot_steps"] / max(wall_s, 1e-9),
        "p50_step_us": _percentile(lat, 0.50),
        "p99_step_us": _percentile(lat, 0.99),
        "p99_flat_ratio": flat_ratio,
        "p50_queue_wait_s": metrics["queue_wait_s"]["p50"],
        "p99_queue_wait_s": metrics["queue_wait_s"]["p99"],
        "verified_streams": verified,
        "counters": metrics["counters"],
    }
    return row


def main(argv=None) -> int:
    import jax

    ap = argparse.ArgumentParser(description="gateway soak driver")
    ap.add_argument("--streams", type=int, default=1000)
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--n-total", type=int, default=40)
    ap.add_argument("--n-steps", type=int, default=24)
    ap.add_argument("--chunk", type=int, default=8)
    ap.add_argument("--buckets", default="8,16,32")
    ap.add_argument("--max-queue", type=int, default=48)
    ap.add_argument("--burst", type=int, default=32)
    ap.add_argument("--deadline-ms", type=float, default=120_000.0)
    ap.add_argument("--evict-every", type=int, default=9)
    ap.add_argument("--no-verify", action="store_true",
                    help="skip the per-stream offline bit-exactness check")
    ap.add_argument("--flat-tolerance", type=float, default=3.0,
                    help="fail when second-half p99 per-step latency is "
                         "more than this factor of the first half")
    ap.add_argument("--min-occupancy", type=float, default=0.3)
    ap.add_argument("--require-rejections", action="store_true",
                    help="fail unless backpressure rejected >= 1 submit")
    ap.add_argument("--require-evictions", action="store_true",
                    help="fail unless deadlines evicted >= 1 stream")
    args = ap.parse_args(argv)

    buckets = tuple(int(b) for b in args.buckets.split(","))
    row = run_soak(streams=args.streams, devices=args.devices,
                   n_total=args.n_total, n_steps=args.n_steps,
                   chunk=args.chunk, buckets=buckets,
                   max_queue=args.max_queue, burst=args.burst,
                   deadline_ms=args.deadline_ms,
                   evict_every=args.evict_every,
                   verify=not args.no_verify)

    payload = {
        "devices": args.devices or 1,
        "backend": jax.default_backend(),
        "model": f"izhikevich_{args.n_total}",
        "n_total": args.n_total,
        "summary": [row],
    }
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / OUT_NAME).write_text(json.dumps(payload, indent=1,
                                               default=float))
    print(f"[gateway_soak] {row['completed']} completed, "
          f"{row['evicted']} evicted, {row['rejected_submits']} rejected "
          f"submits in {row['wall_s']:.1f}s "
          f"({row['steps_per_sec']:.0f} steps/s, "
          f"occupancy {row['occupancy']:.2f})")
    print(f"[gateway_soak] per-step latency p50={row['p50_step_us']:.0f}us "
          f"p99={row['p99_step_us']:.0f}us "
          f"flat-ratio {row['p99_flat_ratio']:.2f} "
          f"(verified {row['verified_streams']} streams bit-exact)")
    print(f"wrote {RESULTS / OUT_NAME}", flush=True)

    failures = []
    if row["completed"] + row["evicted"] != args.streams:
        failures.append(
            f"lost streams: {row['completed']}+{row['evicted']} != "
            f"{args.streams}")
    if row["evicted"] < args.streams // args.evict_every:
        failures.append(
            f"expected >= {args.streams // args.evict_every} evictions "
            f"(every {args.evict_every}th request has a ~0 deadline), "
            f"got {row['evicted']}")
    if row["p99_flat_ratio"] > args.flat_tolerance:
        failures.append(
            f"per-step p99 latency not flat: second half is "
            f"{row['p99_flat_ratio']:.2f}x the first half "
            f"(tolerance {args.flat_tolerance}x)")
    if row["occupancy"] < args.min_occupancy:
        failures.append(f"slot occupancy {row['occupancy']:.2f} below "
                        f"{args.min_occupancy}")
    if args.require_rejections and row["rejected_submits"] == 0:
        failures.append("backpressure never rejected a submit "
                        "(queue bound too generous for this load)")
    if args.require_evictions and row["evicted"] == 0:
        failures.append("deadlines never evicted a stream")
    if failures:
        for f in failures:
            print(f"[gateway_soak] FAILED: {f}", file=sys.stderr)
        return 1
    print("[gateway_soak] all SLO assertions passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
