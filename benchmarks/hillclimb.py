import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Hillclimb measurement driver (§Perf).

For a cell, measures roofline terms for a sequence of named variants
(baseline, kernelized cores, remat policy, logits dtype, replicated serving
weights, ...), each a config tweak re-lowered through the same pipeline, and
writes experiments/perf/<cell>.json for the §Perf log.

  PYTHONPATH=src python -m benchmarks.hillclimb --cell mixtral_train
"""

import argparse
import dataclasses
import json
from pathlib import Path

from repro import flags
from repro.configs import get_config, get_shape
from repro.launch.dryrun import roofline_terms
from repro.launch.mesh import make_production_mesh

OUT = Path(__file__).resolve().parents[1] / "experiments" / "perf"


def measure(cfg, shape, mesh, no_core: bool = False) -> dict:
    if no_core:
        flags.ROOFLINE_NO_ATTN = True
        if cfg.family in ("ssm", "hybrid"):
            flags.ROOFLINE_NO_SSD = True
    try:
        t = roofline_terms(cfg, shape, mesh)
    finally:
        flags.ROOFLINE_NO_ATTN = False
        flags.ROOFLINE_NO_SSD = False
    return {k: t[k] for k in ("flops", "bytes", "transcendentals",
                              "collective_total")}


def run_mixtral_train() -> dict:
    cfg = get_config("mixtral-8x22b")
    shape = get_shape("train_4k")
    mesh = make_production_mesh()
    steps = {}
    steps["baseline_naive"] = measure(cfg, shape, mesh)
    steps["no_core"] = measure(cfg, shape, mesh, no_core=True)
    # iter 1: remat policy 'dots' — save matmul outputs, recompute the rest
    cfg1 = dataclasses.replace(cfg, remat_policy="dots")
    steps["remat_dots"] = measure(cfg1, shape, mesh)
    steps["remat_dots_no_core"] = measure(cfg1, shape, mesh, no_core=True)
    # iter 2: + bf16 CE logits
    cfg2 = dataclasses.replace(cfg1, logits_dtype="bfloat16")
    steps["remat_dots_bf16logits_no_core"] = measure(cfg2, shape, mesh,
                                                     no_core=True)
    return {"cell": "mixtral-8x22b x train_4k x pod16x16", "steps": steps,
            "n_devices": 256}


def run_qwen2_prefill() -> dict:
    cfg = get_config("qwen2-0.5b")
    shape = get_shape("prefill_32k")
    mesh = make_production_mesh()
    steps = {}
    steps["baseline_naive"] = measure(cfg, shape, mesh)
    steps["no_core"] = measure(cfg, shape, mesh, no_core=True)
    # iter 2: bf16 cache+logits head already; try logits bf16 anyway (head
    # matmul output): prefill emits [B, 1, V] so this is tiny — measured to
    # confirm the hypothesis that it does NOT matter here.
    cfg1 = dataclasses.replace(cfg, logits_dtype="bfloat16")
    steps["bf16_logits_no_core"] = measure(cfg1, shape, mesh, no_core=True)
    # iter 3: replicate weights for serving (0.5B bf16 = 1.25 GB/chip):
    # kills the per-layer FSDP all-gathers that dominate collectives
    cfg2 = dataclasses.replace(cfg, serve_replicate_weights=True)
    steps["replicated_no_core"] = measure(cfg2, shape, mesh, no_core=True)
    return {"cell": "qwen2-0.5b x prefill_32k x pod16x16", "steps": steps,
            "n_devices": 256}


def run_whisper_decode() -> dict:
    cfg = get_config("whisper-tiny")
    shape = get_shape("decode_32k")
    mesh = make_production_mesh()
    steps = {}
    steps["baseline"] = measure(cfg, shape, mesh)
    cfg1 = dataclasses.replace(cfg, serve_replicate_weights=True)
    steps["replicated_weights"] = measure(cfg1, shape, mesh)
    return {"cell": "whisper-tiny x decode_32k x pod16x16", "steps": steps,
            "n_devices": 256}


CELLS = {
    "mixtral_train": run_mixtral_train,
    "qwen2_prefill": run_qwen2_prefill,
    "whisper_decode": run_whisper_decode,
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, choices=sorted(CELLS))
    args = ap.parse_args()
    res = CELLS[args.cell]()
    OUT.mkdir(parents=True, exist_ok=True)
    path = OUT / f"{args.cell}.json"
    path.write_text(json.dumps(res, indent=1))
    print(json.dumps(res, indent=1))


if __name__ == "__main__":
    main()
