"""Event-driven propagation benchmark: dense vs event step time by rate.

Sweeps firing rate x propagation mode on one static synapse group and scans
`SynapseGroup.step` — the spike raster is precomputed Bernoulli at each
rate, so the activity level is exact and the two modes run the identical
workload.  The event path compacts the spiking pre rows before the ELL
pass (bit-exact, dense fallback on capacity overflow); its win is the
gated metric: at sparse activity (<= 5% firing — the regime GeNN's
event-driven kernels target) the event step must stay well ahead of the
dense step, and check_regression.py compares both the per-row step times
("modes") and the dense/event ratio ("speedups") against the committed
baseline.  High-rate rows are reported for the trajectory only — there the
crossover model itself says dense is the right choice.

Emits ``experiments/bench/BENCH_snn_event.json`` and prints harness CSV
rows.

    PYTHONPATH=src python -m benchmarks.snn_event

Env knobs (kept small in CI): SNN_EVENT_BENCH_N (pre/post neurons,
default 4096), SNN_EVENT_BENCH_NCONN (fanout, default 64),
SNN_EVENT_BENCH_STEPS (default 200), SNN_EVENT_BENCH_REPS (default 3),
SNN_EVENT_BENCH_RATES (percent list, default "1,5,10,25").
"""

from __future__ import annotations

import json
import os
import time
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[1] / "experiments" / "bench"
OUT_NAME = "BENCH_snn_event.json"

# speedup rows are gated only where the event path is supposed to win
GATED_RATE_PCT = 5.0


def _build_group(n_pre: int, n_conn: int, mode: str):
    import numpy as np

    from repro.core.snn.synapses import SynapseGroup
    from repro.sparse import formats as F

    rng = np.random.default_rng(0)
    post_ind, g, valid = F.FixedFanout(n_conn).resolve(
        rng, n_pre, n_pre, lambda r, s: r.random(s).astype(np.float32))
    return SynapseGroup(
        name=f"bench_{mode}", pre="pop", post="pop",
        ell=F.triple_to_ell(post_ind, g, valid, n_pre),
        propagation=mode)


def _time_mode(group, raster, n_steps: int, reps: int) -> float:
    import jax
    import jax.numpy as jnp

    state = group.init_state()
    gs = jnp.float32(1.0)

    @jax.jit
    def scan(st, spikes):
        def body(carry, spk):
            s, acc = carry
            s2, cur = group.step(s, spk, gs, 1.0)
            return (s2, acc + cur), None

        (s2, acc), _ = jax.lax.scan(body, (st, jnp.zeros(group.ell.n_post)),
                                    spikes)
        return acc

    jax.block_until_ready(scan(state, raster))       # warm the executable
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(scan(state, raster))
        best = min(best, time.perf_counter() - t0)
    return best


def main() -> None:
    import jax
    import jax.numpy as jnp
    import numpy as np

    n_pre = int(os.environ.get("SNN_EVENT_BENCH_N", 4096))
    n_conn = int(os.environ.get("SNN_EVENT_BENCH_NCONN", 64))
    n_steps = int(os.environ.get("SNN_EVENT_BENCH_STEPS", 200))
    reps = int(os.environ.get("SNN_EVENT_BENCH_REPS", 3))
    rates = [float(r) for r in os.environ.get(
        "SNN_EVENT_BENCH_RATES", "1,5,10,25").split(",")]
    n_conn = min(n_conn, n_pre)

    groups = {m: _build_group(n_pre, n_conn, m) for m in ("dense", "event")}
    cap = groups["event"].event_capacity
    print(f"event_capacity={cap} ({cap / n_pre:.1%} of {n_pre} rows)",
          flush=True)

    rng = np.random.default_rng(7)
    rows, speedups = [], []
    for rate in rates:
        raster = jnp.asarray(rng.random((n_steps, n_pre)) < rate / 100.0)
        us = {}
        for mode in ("dense", "event"):
            wall = _time_mode(groups[mode], raster, n_steps, reps)
            us[mode] = wall / n_steps * 1e6
            rows.append({"mode": mode, "rate_pct": rate,
                         "wall_s": wall, "us_per_step": us[mode]})
            print(f"mode={mode},rate={rate},{us[mode]:.1f},us_per_step",
                  flush=True)
        speedup = us["dense"] / us["event"]
        entry = {"rate_pct": rate, "dense_us_per_step": us["dense"],
                 "event_us_per_step": us["event"]}
        if rate <= GATED_RATE_PCT:
            entry["event_speedup"] = speedup
        else:
            entry["event_speedup_ungated"] = speedup
        speedups.append(entry)
        print(f"speedup,rate={rate},{speedup:.2f}x", flush=True)

    payload = {
        "backend": jax.default_backend(),
        "n_pre": n_pre,
        "n_conn": n_conn,
        "n_steps": n_steps,
        "event_capacity": cap,
        "modes": rows,
        "speedups": speedups,
    }
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / OUT_NAME).write_text(json.dumps(payload, indent=1,
                                               default=float))
    print(f"wrote {RESULTS / OUT_NAME}", flush=True)


if __name__ == "__main__":
    main()
