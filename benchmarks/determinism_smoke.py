"""Seeded-determinism smoke: same seed, different --devices, same spikes.

The whole sharded-SNN story rests on one invariant: a simulation is a pure
function of (spec, seed) — never of the device count.  This smoke runs the
same device-initialized model (heterogeneous dendritic delays + a
homogeneous-delay group, the states most likely to break the invariant)
under 1 and N host-platform devices in separate subprocesses (the XLA
device count locks at backend init, so one process cannot do both), and
fails if any spike count, raster bit or generated delay slot differs.

Emits ``experiments/bench/BENCH_determinism.json`` so the CI artifact
records the checked configuration next to the perf JSONs.

    PYTHONPATH=src python -m benchmarks.determinism_smoke [--devices 8]
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[1] / "experiments" / "bench"
OUT_NAME = "BENCH_determinism.json"
SRC = Path(__file__).resolve().parents[1] / "src"

_WORKER = r"""
import os, sys, json, hashlib
import numpy as np
import jax
from repro.core.snn.spec import ModelSpec
from repro.core.snn.synapses import ExpDecay
from repro.launch.mesh import make_snn_mesh
from repro.sparse.formats import FixedFanout, UniformIntDelay, UniformWeight

devices = int(sys.argv[1])
seed = int(sys.argv[2])
steps = int(sys.argv[3])

s = ModelSpec("determinism")
s.add_neuron_population(
    "a", 48, "izhikevich",
    input_fn=lambda k, t, n: 8.0 * jax.random.normal(k, (n,)))
s.add_neuron_population("b", 24, "izhikevich")
s.add_synapse_population("ab", "a", "b", connect=FixedFanout(6),
                         weight=UniformWeight(0, 9.0), psm=ExpDecay(4.0),
                         delay=UniformIntDelay(0, 3))
s.add_synapse_population("bb", "b", "b", connect=FixedFanout(4),
                         weight=UniformWeight(0, 0.3), delay_steps=2)
mesh = make_snn_mesh(devices) if devices > 1 else None
model = s.build(dt=1.0, seed=seed, init="device", mesh=mesh)
res = model.run(steps, record_raster=True)
out = {
    "devices": devices,
    "finite": bool(res.finite),
    "counts": {k: np.asarray(v).tolist() for k, v in res.spike_counts.items()},
    "raster_hash": {k: hashlib.sha256(
                        np.asarray(v, np.uint8).tobytes()).hexdigest()
                    for k, v in res.raster.items()},
    "delay_slots": np.asarray(
        model.network.synapses[0].ell.delay).tolist(),
}
print(json.dumps(out))
"""


def _run(devices: int, seed: int, steps: int) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "").replace(
        "--xla_force_host_platform_device_count=8", "").strip()
        + f" --xla_force_host_platform_device_count={devices}").strip()
    env["PYTHONPATH"] = str(SRC) + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c", _WORKER, str(devices), str(seed), str(steps)],
        capture_output=True, text=True, timeout=600, env=env)
    if out.returncode != 0:
        raise SystemExit(
            f"determinism worker (devices={devices}) failed:\n"
            + out.stderr[-3000:])
    return json.loads(out.stdout.strip().splitlines()[-1])


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--steps", type=int, default=40)
    args = ap.parse_args(argv)

    t0 = time.perf_counter()
    one = _run(1, args.seed, args.steps)
    many = _run(args.devices, args.seed, args.steps)
    checks = {
        "finite": one["finite"] and many["finite"],
        "spike_counts_equal": one["counts"] == many["counts"],
        "rasters_equal": one["raster_hash"] == many["raster_hash"],
        "delay_slots_equal": one["delay_slots"] == many["delay_slots"],
    }
    payload = {
        "seed": args.seed,
        "steps": args.steps,
        "devices_compared": [1, args.devices],
        "checks": checks,
        "wall_s": time.perf_counter() - t0,
    }
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / OUT_NAME).write_text(json.dumps(payload, indent=1))
    print(f"wrote {RESULTS / OUT_NAME}", flush=True)
    for name, ok in checks.items():
        print(f"determinism_{name}: {'OK' if ok else 'MISMATCH'}",
              flush=True)
    if not all(checks.values()):
        raise SystemExit(
            f"seeded-determinism smoke FAILED: {checks} — the same seed "
            f"produced different results on 1 vs {args.devices} devices")
    return 0


if __name__ == "__main__":
    sys.exit(main())
