"""Roofline analysis from dry-run artifacts (EXPERIMENTS.md §Roofline).

Three terms per (arch x shape x mesh), in seconds-per-step-per-chip:

  compute    = flops_per_device / PEAK_FLOPS
  memory     = hbm_bytes_per_device / HBM_BW
  collective = collective_operand_bytes_per_device / ICI_BW

Sources: the dry-run's depth-extrapolated cost_analysis (scan bodies counted
once by XLA, so flops/bytes/collectives are measured on unrolled depth-1/2
lowerings and extrapolated linearly — see launch/dryrun.py).  The roofline
lowerings use *naive* attention so every flop is visible to cost_analysis;
`attention_correction` swaps those terms for the flash kernel's
(block-skipped flops, VMEM-resident logits), per DESIGN.md §3.

MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) gives the useful-compute
ratio (remat/dispatch overhead shows up as ratio < 1).
"""

from __future__ import annotations

import dataclasses
import json
import math
from pathlib import Path
from typing import Dict, List, Optional

from repro.configs import ARCHS, SHAPES, get_config, get_shape
from repro.configs.base import ArchConfig, ShapeConfig

ART_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"

PEAK_FLOPS = 197e12          # bf16 / chip (v5e)
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link (brief's constant)


# ---------------------------------------------------------------------------
# analytic attention accounting
# ---------------------------------------------------------------------------

def _attn_layers(cfg: ArchConfig) -> List[Dict]:
    """(count, window) per attention layer class."""
    prog = cfg.program()
    out = []
    for rep in (prog.repeats,):
        for seg in prog.segments:
            if seg.kind in ("attn", "attn_local", "attn_global",
                            "shared_attn", "moe"):
                window = cfg.window
                if seg.kind == "attn_local":
                    window = cfg.local_window
                elif seg.kind == "attn_global":
                    window = None
                out.append({"n": seg.n * rep, "window": window})
    for seg in prog.tail:
        if seg.kind != "mamba":
            window = cfg.local_window if seg.kind == "attn_local" \
                else cfg.window
            out.append({"n": seg.n, "window": window})
    return out


def _visibility(tq: int, tk: int, window: Optional[int],
                causal: bool = True) -> float:
    """Average fraction of the Tq x Tk rectangle a flash kernel computes."""
    causal_vis = 0.5 * (1 + 1 / tq) if causal and tq == tk else 1.0
    if window is not None:
        return min(causal_vis, min(window, tk) / tk)
    return causal_vis


def attention_correction(cfg: ArchConfig, shape: ShapeConfig,
                         n_dev: int) -> Dict[str, float]:
    """Returns flops/bytes DELTAS to apply to the measured (naive) totals:
    corrected = measured - naive_delta + flash_delta."""
    if shape.kind in ("decode", "long") or cfg.n_heads == 0:
        return {"flops_delta": 0.0, "bytes_delta": 0.0}
    b = shape.global_batch
    tq = shape.seq_len if shape.kind != "train" else shape.seq_len
    if cfg.family == "vlm":
        tq = shape.seq_len  # img prefix + text fills the same budget
    tk = tq
    hd = cfg.head_dim
    hq = cfg.n_heads

    # matmul passes in the measured module: fwd QK+PV = 2; train adds
    # bwd(4) + remat fwd(2) = 8 total
    passes = 2 if shape.kind == "prefill" else 8
    # f32 logits materialization round-trips in the naive module
    byte_passes = 3 if shape.kind == "prefill" else 8

    naive_f = 0.0
    flash_f = 0.0
    logits_bytes = 0.0
    for grp in _attn_layers(cfg):
        full = 2.0 * b * hq * tq * tk * hd * passes * grp["n"]
        naive_f += full
        flash_f += full * _visibility(tq, tk, grp["window"])
        logits_bytes += (4.0 * b * hq * tq * tk * byte_passes * grp["n"]
                         * _visibility(tq, tk, grp["window"]) ** 0)
    # whisper encoder (non-causal, full): counted once (fwd[+bwd] handled
    # by passes above via the decoder count; encoder layers:
    if cfg.n_enc_layers:
        ta = cfg.enc_seq
        full = 2.0 * b * hq * ta * ta * hd * passes * cfg.n_enc_layers
        naive_f += full
        flash_f += full          # non-causal full attention
        logits_bytes += 4.0 * b * hq * ta * ta * byte_passes \
            * cfg.n_enc_layers
    return {
        "flops_delta": (naive_f - flash_f) / n_dev,
        "bytes_delta": logits_bytes / n_dev,
    }


# ---------------------------------------------------------------------------
# MODEL_FLOPS (6*N*D convention)
# ---------------------------------------------------------------------------

def _param_counts(cfg: ArchConfig) -> Dict[str, float]:
    """Analytic total + active params (embedding included once)."""
    d = cfg.d_model
    v = cfg.vocab
    emb = v * d * (1 if cfg.tie_embeddings else 2)
    per_attn = d * (cfg.n_heads + 2 * cfg.n_kv) * cfg.head_dim \
        + cfg.n_heads * cfg.head_dim * d if cfg.n_heads else 0
    per_mlp = d * cfg.d_ff * (3 if cfg.gated_mlp else 2)
    di = cfg.ssm_expand * d
    g = cfg.ssm_groups
    per_mamba = d * (2 * di + 2 * g * cfg.ssm_state
                     + (di // max(cfg.ssm_head, 1))) + di * d if \
        cfg.ssm_state else 0

    total = emb
    active = emb
    prog = cfg.program()
    for rep, segs in ((prog.repeats, prog.segments), (1, prog.tail)):
        for seg in segs:
            n = seg.n * rep
            if seg.kind == "mamba":
                total += n * per_mamba
                active += n * per_mamba
            elif seg.kind == "moe":
                moe_total = cfg.n_experts * 3 * d * cfg.d_ff
                moe_active = cfg.top_k * 3 * d * cfg.d_ff
                total += n * (per_attn + moe_total + d * cfg.n_experts)
                active += n * (per_attn + moe_active + d * cfg.n_experts)
            elif seg.kind == "shared_attn":
                total += (per_attn + per_mlp) * (1 if rep else 1)
                active += n * (per_attn + per_mlp)  # applied n*rep times
            else:
                total += n * (per_attn + per_mlp)
                active += n * (per_attn + per_mlp)
    if cfg.n_enc_layers:
        total += cfg.n_enc_layers * (per_attn + per_mlp)
        active += cfg.n_enc_layers * (per_attn + per_mlp)
    return {"total": float(total), "active": float(active)}


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    counts = _param_counts(cfg)
    n = counts["active"]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


# ---------------------------------------------------------------------------
# table builder
# ---------------------------------------------------------------------------

def load_cell(mesh_tag: str, arch: str, shape: str) -> Optional[dict]:
    p = ART_DIR / mesh_tag / f"{arch}__{shape}.json"
    if not p.exists():
        return None
    return json.loads(p.read_text())


def cell_terms(rec: dict) -> Optional[dict]:
    if rec.get("status") != "OK" or "roofline" not in rec \
            or "error" in rec.get("roofline", {}):
        return None
    cfg = get_config(rec["arch"])
    shape = get_shape(rec["shape"])
    n_dev = rec["n_devices"]
    roof = rec["roofline"]
    corr = attention_correction(cfg, shape, n_dev)
    flops = max(roof["flops"] - corr["flops_delta"], 0.0)
    hbm = max(roof["bytes"] - corr["bytes_delta"], 0.0)
    coll = roof["collective_total"]
    t_c = flops / PEAK_FLOPS
    t_m = hbm / HBM_BW
    t_n = coll / ICI_BW
    dom = max((t_c, "compute"), (t_m, "memory"), (t_n, "collective"))
    mf = model_flops(cfg, shape) / n_dev
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "flops_per_dev": flops, "hbm_bytes_per_dev": hbm,
        "coll_bytes_per_dev": coll,
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_n,
        "bottleneck": dom[1],
        "model_flops_per_dev": mf,
        "useful_ratio": mf / flops if flops else 0.0,
        "roofline_fraction": (max(t_c, t_m, t_n) and
                              t_c / max(t_c, t_m, t_n)),
        "step_time_bound_s": max(t_c, t_m, t_n),
    }


def build_table(mesh_tag: str = "pod16x16") -> List[dict]:
    rows = []
    for arch in ARCHS:
        for shape in SHAPES:
            rec = load_cell(mesh_tag, arch, shape.name)
            if rec is None:
                continue
            if rec["status"] == "SKIP":
                rows.append({"arch": arch, "shape": shape.name,
                             "mesh": mesh_tag, "skip": rec["reason"]})
                continue
            t = cell_terms(rec)
            if t:
                rows.append(t)
            else:
                rows.append({"arch": arch, "shape": shape.name,
                             "mesh": mesh_tag,
                             "skip": f"status={rec['status']}"})
    return rows


def format_table(rows: List[dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | "
           "bottleneck | MODEL/HLO | roofline frac |")
    sep = "|" + "---|" * 8
    lines = [hdr, sep]
    for r in rows:
        if "skip" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | SKIP | | | | | "
                         f"{r['skip'][:40]} |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3e} | "
            f"{r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} | "
            f"{r['bottleneck']} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.2f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    for tag in ("pod16x16", "pod2x16x16"):
        rows = build_table(tag)
        if rows:
            print(f"\n== {tag} ==")
            print(format_table(rows))
