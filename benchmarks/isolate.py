import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Core-isolation measurements for the hillclimbed cells (§Perf).

For a cell, lowers the depth-1/2 roofline variants twice more with the
attention (and, for SSM archs, SSD) core replaced by an identity-shaped
stand-in.  The difference  naive - no_core  is the measured share of the
core in every roofline term; the Pallas kernel's analytic cost is then
substituted by benchmarks/perf_model.py.

  PYTHONPATH=src python -m benchmarks.isolate --arch qwen2-0.5b \
      --shape prefill_32k [--multi-pod]

Writes experiments/dryrun/<mesh>/<arch>__<shape>.isolate.json.
"""

import argparse
import json
from pathlib import Path

from repro import flags
from repro.configs import get_config, get_shape
from repro.launch.dryrun import ART_DIR, _roofline_lowering, roofline_terms
from repro.launch.mesh import make_production_mesh


def isolate_cell(arch: str, shape_name: str, multi_pod: bool) -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    tag = "pod2x16x16" if multi_pod else "pod16x16"

    out = {"arch": arch, "shape": shape_name, "mesh": tag}
    # baseline (naive attention) terms — recomputed so both sides of the
    # subtraction share one code version
    out["naive"] = roofline_terms(cfg, shape, mesh)

    flags.ROOFLINE_NO_ATTN = True
    if cfg.family in ("ssm", "hybrid"):
        flags.ROOFLINE_NO_SSD = True
    try:
        out["no_core"] = roofline_terms(cfg, shape, mesh)
    finally:
        flags.ROOFLINE_NO_ATTN = False
        flags.ROOFLINE_NO_SSD = False

    core = {
        k: out["naive"][k] - out["no_core"][k]
        for k in ("flops", "bytes", "transcendentals")
    }
    core["collective_total"] = (out["naive"]["collective_total"]
                                - out["no_core"]["collective_total"])
    out["core"] = core

    path = ART_DIR / tag / f"{arch}__{shape_name}.isolate.json"
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(out, indent=1))
    print(f"[isolate] {arch} x {shape_name} x {tag}: "
          f"core flops {core['flops']:.3e}, bytes {core['bytes']:.3e}")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    isolate_cell(args.arch, args.shape, args.multi_pod)


if __name__ == "__main__":
    main()
