"""Benchmark harness: one function per paper table/figure, plus the roofline
reader.  Prints ``name,us_per_call,derived`` CSV rows (brief's format).

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run table1 eq12 ...
"""

from __future__ import annotations

import dataclasses
import json
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.bench_util import row, time_fn

RESULTS = Path(__file__).resolve().parents[1] / "experiments" / "bench"


def _save(name: str, payload: dict) -> None:
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / f"{name}.json").write_text(json.dumps(payload, indent=1,
                                                     default=float))


# ---------------------------------------------------------------------------
# Table 1: Izhikevich conductance-scaling regression
# ---------------------------------------------------------------------------

def bench_table1_izhikevich_gscale() -> None:
    from benchmarks.gscale_experiments import izhikevich_gscale_sweep
    t0 = time.perf_counter()
    res = izhikevich_gscale_sweep()
    us = (time.perf_counter() - t0) * 1e6
    _save("table1_izhikevich", res)
    row("table1_izhikevich_k1", us / len(res["n_conns"]),
        f"k1={res['k1']:.4g}")
    row("table1_izhikevich_k2", 0.0, f"k2={res['k2']:.4g}")
    row("table1_izhikevich_k3", 0.0, f"k3={res['k3']:.4g}")
    row("table1_izhikevich_mape", 0.0,
        f"mape_pct={res['mape_pct']:.2f} (paper: 3.95)")


# ---------------------------------------------------------------------------
# Table 2 / Fig 3: mushroom-body regression at two LHI counts
# ---------------------------------------------------------------------------

def bench_table2_mushroom_gscale() -> None:
    from benchmarks.gscale_experiments import mushroom_gscale_sweep
    for n_lhi in (5, 10):     # reduced stand-ins for the paper's 20/40
        t0 = time.perf_counter()
        res = mushroom_gscale_sweep(n_lhi=n_lhi)
        us = (time.perf_counter() - t0) * 1e6
        _save(f"table2_mushroom_lhi{n_lhi}", res)
        row(f"table2_pn_kc_lhi{n_lhi}_k1", us / len(res["n_pns"]),
            f"k1={res['k1']:.4g}")
        row(f"table2_pn_kc_lhi{n_lhi}_mape", 0.0,
            f"mape_pct={res['mape_pct']:.2f} (paper PN-KC: 16.1)")
        row(f"table2_pn_lhi_lhi{n_lhi}_k1", 0.0,
            f"k1={res['k1_lhi']:.4g}")
        row(f"table2_pn_lhi_lhi{n_lhi}_mape", 0.0,
            f"mape_pct={res['mape_lhi_pct']:.2f} (paper PN-LHI: 71.4)")


# ---------------------------------------------------------------------------
# Fig 2: representation (sparse vs dense) must not change the scaling
# ---------------------------------------------------------------------------

def bench_fig2_representation_agreement() -> None:
    from benchmarks.gscale_experiments import izhikevich_gscale_sweep
    res = {}
    for rep in ("sparse", "dense"):
        t0 = time.perf_counter()
        res[rep] = izhikevich_gscale_sweep(
            n_total=300, n_conns=(60, 150, 300), n_steps=200,
            representation=rep)
        us = (time.perf_counter() - t0) * 1e6
        row(f"fig2_gscale_{rep}", us / 4,
            "gscales=" + "/".join(f"{g:.3g}" for g in
                                  res[rep]["gscales"]))
    a = np.asarray(res["sparse"]["gscales"])
    b = np.asarray(res["dense"]["gscales"])
    mape = float(np.mean(np.abs(a - b) / np.maximum(np.abs(b), 1e-9))) * 100
    _save("fig2_agreement", {"sparse": res["sparse"], "dense": res["dense"],
                             "mape_pct": mape})
    row("fig2_sparse_vs_dense_mape", 0.0,
        f"mape_pct={mape:.2f} (paper: 3.95, 'negligible')")


# ---------------------------------------------------------------------------
# Eq (1)/(2): memory model
# ---------------------------------------------------------------------------

def bench_eq12_memory_model() -> None:
    from repro.sparse import formats as F
    rows = []
    for n_conn in range(100, 1001, 100):
        nnz = 1000 * n_conn
        s = F.sparse_memory_elements(nnz, 1000, 1000)
        d = F.dense_memory_elements(1000, 1000)
        rows.append((n_conn, s, d))
    _save("eq12_memory", {"rows": rows})
    crossover = next((n for n, s, d in rows if s >= d), None)
    row("eq12_memory_sparse_at_100", 0.0,
        f"sparse={rows[0][1]}el dense={rows[0][2]}el")
    row("eq12_memory_crossover_nconn", 0.0,
        f"crossover={crossover} (sparse wins below)")


# ---------------------------------------------------------------------------
# Sparse vs dense step timing (CPU proxy for the paper's GPU speedups)
# ---------------------------------------------------------------------------

def bench_sparse_vs_dense_step() -> None:
    from repro.core.models import izhikevich_net
    out = {}
    for n_total, n_conn in ((500, 50), (1000, 100)):
        for rep in ("sparse", "dense"):
            cfg = izhikevich_net.IzhikevichNetConfig(
                n_total=n_total, n_conn=n_conn, representation=rep)
            net, sim = izhikevich_net.build(cfg)
            st = sim.init_state()
            names = [g.name for g in net.synapses]
            run = jax.jit(lambda s: sim.run(
                s, 100, {n: jnp.float32(1.0) for n in names}).state)
            us = time_fn(run, st, warmup=1, iters=3) / 100
            out[f"{n_total}_{n_conn}_{rep}"] = us
            row(f"speed_step_n{n_total}_c{n_conn}_{rep}", us,
                f"density={n_conn/n_total:.2f}")
    for key in ("500_50", "1000_100"):
        sp = out[f"{key}_sparse"]
        dn = out[f"{key}_dense"]
        row(f"speed_ratio_{key}", 0.0, f"dense/sparse={dn/sp:.2f}x")
    _save("sparse_vs_dense_step", out)


# ---------------------------------------------------------------------------
# Kernel microbenchmarks (jnp semantics on CPU; Pallas targets TPU)
# ---------------------------------------------------------------------------

def bench_kernel_latencies() -> None:
    from repro.kernels import ref as R
    r = np.random.default_rng(0)
    n = 1 << 14
    v = jnp.asarray(r.uniform(-70, -50, n), jnp.float32)
    u = jnp.asarray(r.uniform(-15, -5, n), jnp.float32)
    isyn = jnp.asarray(r.standard_normal(n) * 3, jnp.float32)
    ab = jnp.full((n,), 0.02), jnp.full((n,), 0.2)
    cd = jnp.full((n,), -65.0), jnp.full((n,), 8.0)
    f = jax.jit(lambda *a: R.izhikevich_step_ref(*a, 1.0))
    us = time_fn(f, v, u, isyn, *ab, *cd)
    row("kernel_izhikevich_step_16k", us, f"neurons_per_us={n/us:.0f}")

    m = jnp.asarray(r.random(n), jnp.float32)
    f = jax.jit(lambda *a: R.hh_step_ref(*a, 0.1))
    us = time_fn(f, v, m, m, m, isyn)
    row("kernel_hh_step_16k", us, f"neurons_per_us={n/us:.0f}")

    npre, k, npost, b = 1024, 128, 1024, 8
    g = jnp.asarray(r.standard_normal((npre, k)), jnp.float32)
    idx = jnp.asarray(r.integers(0, npost, (npre, k)), jnp.int32)
    valid = jnp.ones((npre, k), bool)
    spk = jnp.asarray((r.random((b, npre)) < 0.1), jnp.float32)
    f = jax.jit(lambda *a: R.ell_spmv_ref(*a, npost))
    us = time_fn(f, g, idx, valid, spk)
    row("kernel_ell_spmv_1kx128x8", us,
        f"synapses_per_us={b*npre*k/us:.0f}")
    w = jnp.zeros((npre, npost), jnp.float32)
    fd = jax.jit(lambda s, w: s @ w)
    usd = time_fn(fd, spk, w)
    row("kernel_dense_spmv_1kx1k", usd, f"ell_speedup={usd/us:.2f}x")


# ---------------------------------------------------------------------------
# Occupancy table (paper §3 adapted to VMEM)
# ---------------------------------------------------------------------------

def bench_occupancy_blocksize() -> None:
    from repro.kernels.autotune import occupancy_report
    for line in occupancy_report().splitlines()[1:]:
        name, block, grid, occ = line.split(",")
        row(f"occupancy_{name}", 0.0,
            f"block={block} grid={grid} occ={occ}")


# ---------------------------------------------------------------------------
# LM-side: fan-in scaling probe (the paper's law on the LM stack)
# ---------------------------------------------------------------------------

def bench_lm_scaling_probe() -> None:
    from repro.core.scaling import probe_and_fit
    t0 = time.perf_counter()
    pol = probe_and_fit(jax.random.PRNGKey(0),
                        fanins=(64, 128, 256, 512, 1024, 2048))
    us = (time.perf_counter() - t0) * 1e6
    _save("lm_scaling_policy", {"k1": pol.k1, "k2": pol.k2, "k3": pol.k3})
    row("lm_scaling_fit", us / 6,
        f"k1={pol.k1:.4g} k2={pol.k2:.4g} k3={pol.k3:.4g}")
    # sanity: the fitted law should track 1/fan_in on variance
    s256, s1024 = pol.scale(256), pol.scale(1024)
    row("lm_scaling_ratio_256_1024", 0.0,
        f"scale_ratio={s256/s1024:.2f} (ideal 2.0)")


# ---------------------------------------------------------------------------
# Roofline table from dry-run artifacts
# ---------------------------------------------------------------------------

def bench_roofline() -> None:
    from benchmarks import roofline as RL
    for tag in ("pod16x16", "pod2x16x16"):
        rows_ = RL.build_table(tag)
        ok = [r for r in rows_ if "skip" not in r]
        if not ok:
            continue
        for r in ok:
            row(f"roofline_{tag}_{r['arch']}_{r['shape']}",
                r["step_time_bound_s"] * 1e6,
                f"bottleneck={r['bottleneck']} "
                f"frac={r['roofline_fraction']:.2f} "
                f"useful={r['useful_ratio']:.2f}")
        _save(f"roofline_{tag}", {"rows": ok})


BENCHES = {
    "table1": bench_table1_izhikevich_gscale,
    "table2": bench_table2_mushroom_gscale,
    "fig2": bench_fig2_representation_agreement,
    "eq12": bench_eq12_memory_model,
    "speed": bench_sparse_vs_dense_step,
    "kernels": bench_kernel_latencies,
    "occupancy": bench_occupancy_blocksize,
    "lm_scaling": bench_lm_scaling_probe,
    "roofline": bench_roofline,
}


def main() -> None:
    names = sys.argv[1:] or list(BENCHES)
    print("name,us_per_call,derived")
    for n in names:
        BENCHES[n]()


if __name__ == "__main__":
    main()
