"""Generate EXPERIMENTS.md from dry-run/bench artifacts.

  PYTHONPATH=src python -m benchmarks.report

Sections:
  §Paper-validation  — Tables 1/2, Fig 2, eq (1)/(2) reproduction results
  §Dry-run           — per-cell compile status, memory, collective schedule
  §Roofline          — three-term table per (arch x shape x mesh)
  §Perf              — hillclimb log (benchmarks/perf_log.md, hand-written
                       during the hypothesis->change->measure cycles)
"""

from __future__ import annotations

import json
from pathlib import Path

from benchmarks import roofline as RL

ROOT = Path(__file__).resolve().parents[1]
ART = ROOT / "experiments"


def _load(p: Path):
    try:
        return json.loads(p.read_text())
    except (FileNotFoundError, json.JSONDecodeError):
        return None


def paper_validation() -> str:
    out = ["## §Paper-validation (reproduction of the paper's own claims)",
           ""]
    t1 = _load(ART / "bench" / "table1_izhikevich.json")
    if t1:
        out += [
            "### Table 1 — Izhikevich net conductance-scaling fit",
            "",
            "Reduced grid (CPU container): "
            f"n_total=400, nConn in {t1['n_conns']}, "
            f"target rate {t1['target_rate']:.1f} Hz.",
            "",
            "| | k1 | k2 | k3 | MAPE % |",
            "|---|---|---|---|---|",
            f"| paper (1000 neurons) | 1.318e3 | 1.099e2 | -0.28 | 3.95 |",
            f"| this repro (reduced) | {t1['k1']:.4g} | {t1['k2']:.4g} | "
            f"{t1['k3']:.4g} | {t1['mape_pct']:.2f} |",
            "",
            "The law family (shifted hyperbola) fits with the paper's own "
            "residual level; constants differ because the network is "
            "reduced (constants are configuration-specific, as the paper "
            "itself shows between its two models).",
            "",
            "observed gScale per nConn: "
            + ", ".join(f"{n}->{g:.3g}" for n, g in
                        zip(t1["n_conns"], t1["gscales"])),
            "",
        ]
    for lhi in (5, 10):
        t2 = _load(ART / "bench" / f"table2_mushroom_lhi{lhi}.json")
        if t2:
            out += [
                f"### Table 2 / Fig 3 — mushroom body (LHI={lhi}, reduced "
                "stand-in for the paper's 20/40)",
                "",
                f"PN->KC fit: k1={t2['k1']:.4g} k2={t2['k2']:.4g} "
                f"k3={t2['k3']:.4g}, **MAPE {t2['mape_pct']:.2f}%** "
                "(paper PN-KC: 16.1%).",
                "",
            ]
            if "k1_lhi" in t2:
                out += [
                    f"PN->LHI fit: k1={t2['k1_lhi']:.4g} "
                    f"k2={t2['k2_lhi']:.4g} k3={t2['k3_lhi']:.4g}, "
                    f"**MAPE {t2['mape_lhi_pct']:.2f}%** (paper PN-LHI: "
                    "71.4%).  Our reduced PN->LHI fit is much better than "
                    "the paper's: their 71.4% MAPE is attributed (their "
                    "own discussion) to Poisson-input variability at "
                    "their scale; the reduced deterministic-seeded sweep "
                    "does not reproduce that variance.",
                    "",
                ]
    f2 = _load(ART / "bench" / "fig2_agreement.json")
    if f2:
        out += [
            "### Fig 2 — representation invariance (sparse vs dense)",
            "",
            f"gScale(nConn) searched independently under ELL-sparse and "
            f"dense synapse representations: MAPE between them "
            f"**{f2['mape_pct']:.2f}%** (paper: 3.95% 'negligible'). "
            "Identical seeds give bit-identical dynamics here because both "
            "paths share one simulator; the paper compared separate "
            "CPU/GPU builds.",
            "",
        ]
    eq = _load(ART / "bench" / "eq12_memory.json")
    if eq:
        r0 = eq["rows"][0]
        out += [
            "### Eq (1)/(2) — memory model",
            "",
            f"1000x1000 population, nConn=100: sparse {r0[1]:,} elements "
            f"vs dense {r0[2]:,}; crossover at nConn=500 "
            "(2*nNZ + nPre + 1 >= nPre*nPost).  The framework picks the "
            "representation per synapse group from exactly this model "
            "(`repro.sparse.formats.choose_representation`).",
            "",
        ]
    return "\n".join(out)


def dryrun_section() -> str:
    out = ["## §Dry-run (multi-pod compile proof)", "",
           "Every (arch x shape x mesh) cell lowered with production "
           "shardings and compiled (`.lower().compile()`); "
           "memory_analysis/cost_analysis/collective schedule recorded in "
           "`experiments/dryrun/`.  Fit proof: required bytes/device = "
           "temp + args − alias (serve caches are donated).  All 68 live "
           "cells ≤ 13.6 GB except mixtral-8x22b train_4k (16.5 GB) and "
           "prefill_32k (18.7 GB) on the single pod — both within the CPU "
           "backend's bf16→f32 buffer inflation of the 16 GB v5e budget, "
           "and both comfortably fit on the 2-pod mesh (12.6 / 9.8 GB).",
           ""]
    for tag, label in (("pod16x16", "single pod 16x16=256 chips"),
                       ("pod2x16x16", "multi-pod 2x16x16=512 chips")):
        d = ART / "dryrun" / tag
        if not d.exists():
            continue
        out += [f"### {label}", "",
                "| arch | shape | status | compile s | temp GB/dev | "
                "param GB/dev | collective ops (ag/ar/rs/a2a/cp) |",
                "|---|---|---|---|---|---|---|"]
        for f in sorted(d.glob("*.json")):
            if f.name.endswith(".isolate.json"):
                continue
            r = _load(f)
            if not r:
                continue
            if r["status"] == "SKIP":
                out.append(f"| {r['arch']} | {r['shape']} | SKIP | | | | "
                           f"{r['reason'][:48]} |")
                continue
            if r["status"] == "FAIL":
                out.append(f"| {r['arch']} | {r['shape']} | FAIL | | | | "
                           f"{r.get('error', '')[:48]} |")
                continue
            mem = r.get("memory_analysis", {})
            temp = mem.get("temp_size_in_bytes", 0) / 1e9
            pb = r.get("analytic_param_bytes_per_device", 0) / 1e9
            c = r.get("collectives", {}).get("counts", {})
            cs = "/".join(str(c.get(k, 0)) for k in
                          ("all-gather", "all-reduce", "reduce-scatter",
                           "all-to-all", "collective-permute"))
            out.append(
                f"| {r['arch']} | {r['shape']} | OK | "
                f"{r.get('compile_s', 0):.0f} | {temp:.1f} | {pb:.2f} | "
                f"{cs} |")
        out.append("")
    return "\n".join(out)


def roofline_section() -> str:
    out = ["## §Roofline", "",
           "Terms in seconds/step/chip: compute = flops/197e12, memory = "
           "HBM bytes/819e9, collective = operand bytes/50e9.  Flops/bytes "
           "are depth-extrapolated from unrolled depth-1/2 lowerings "
           "(XLA counts scan bodies once — launch/dryrun.py); attention "
           "measured on the fully-counted naive reference and corrected "
           "to flash-kernel terms (benchmarks/roofline.py).  Memory "
           "bytes reflect the *XLA reference implementation*; §Perf "
           "quantifies the Pallas-kernel substitution for the hillclimbed "
           "cells.", ""]
    for tag in ("pod16x16", "pod2x16x16"):
        rows = RL.build_table(tag)
        if not rows:
            continue
        out += [f"### {tag}", "", RL.format_table(rows), ""]
    out += [
        "`MODEL/HLO` = 6*N*D (6*N_active*D for MoE) / extrapolated HLO "
        "flops — the useful-compute ratio; values < 1 expose remat "
        "recompute, attention quadratic terms, MoE dispatch and dead "
        "padding.  `roofline frac` = compute term / max(term): 1.0 means "
        "compute-bound (the goal).",
        "",
    ]
    return "\n".join(out)


def perf_section() -> str:
    p = ROOT / "benchmarks" / "perf_log.md"
    if p.exists():
        return p.read_text()
    return "## §Perf\n\n(pending hillclimb runs)\n"


def main() -> None:
    doc = "\n".join([
        "# EXPERIMENTS",
        "",
        "Generated by `python -m benchmarks.report` from "
        "`experiments/` artifacts.  Regenerate after new dry-runs or "
        "benchmark runs.",
        "",
        paper_validation(),
        dryrun_section(),
        roofline_section(),
        perf_section(),
    ])
    (ROOT / "EXPERIMENTS.md").write_text(doc)
    print(f"wrote {ROOT / 'EXPERIMENTS.md'} ({len(doc)} chars)")


if __name__ == "__main__":
    main()
