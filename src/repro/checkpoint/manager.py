"""Step-atomic checkpointing with retention, async writes and manifests.

Layout per step:
  <dir>/step_<N>/
    manifest.json      -- tree structure + leaf metadata + status=COMPLETE
    shard_<p>.npz      -- this process's param/opt/data leaves

Atomicity: leaves are written first, the manifest last (write-to-temp +
rename); a step directory without a COMPLETE manifest is ignored by
`latest_step` and garbage-collected — a crash mid-write can never be
restored from.  Multi-host: each process writes only the leaves (shards) it
owns; on CPU tests there is one process.  `restore` reshards on load when
the device layout changed (elastic restart) because leaves are saved
unsharded per-process and re-placed via the current sharding rules.

An async writer thread overlaps serialization with training; `wait()` joins
it (call before exit or before deleting old steps).
"""

from __future__ import annotations

import json
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

__all__ = ["CheckpointManager"]


def _flatten(tree) -> Tuple[List[Tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out, treedef


class CheckpointManager:
    def __init__(self, directory: str | Path, max_to_keep: int = 3,
                 process_index: Optional[int] = None,
                 async_writes: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.max_to_keep = max_to_keep
        self.process_index = (jax.process_index() if process_index is None
                              else process_index)
        self.async_writes = async_writes
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Dict[str, Any],
             blocking: bool = False) -> None:
        """Snapshot now (device->host copy is synchronous; disk IO async)."""
        flat, _ = _flatten(tree)
        host_leaves = []
        for k, v in flat:
            if v is None:
                continue
            a = np.asarray(v)
            if a.dtype.name == "bfloat16":   # npz can't store ml_dtypes
                a = a.view(np.uint16)
            host_leaves.append((k, a))
        self.wait()

        def _write():
            try:
                self._write(step, host_leaves)
                self._gc()
            except BaseException as e:  # noqa: BLE001
                self._error = e

        if self.async_writes and not blocking:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def _write(self, step: int, host_leaves) -> None:
        d = self.dir / f"step_{step:09d}"
        d.mkdir(parents=True, exist_ok=True)
        shard = d / f"shard_{self.process_index}.npz"
        tmp = shard.with_suffix(".tmp.npz")
        np.savez(tmp, **{k: v for k, v in host_leaves})
        tmp.rename(shard)
        manifest = {
            "step": step,
            "status": "COMPLETE",
            "time": time.time(),
            "process_count": jax.process_count(),
            "keys": [k for k, _ in host_leaves],
        }
        mtmp = d / "manifest.tmp.json"
        mtmp.write_text(json.dumps(manifest))
        mtmp.rename(d / "manifest.json")

    # ------------------------------------------------------------------
    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError(f"async checkpoint write failed: {err!r}")

    # ------------------------------------------------------------------
    def steps(self) -> List[int]:
        out = []
        for d in sorted(self.dir.glob("step_*")):
            if (d / "manifest.json").exists():
                try:
                    m = json.loads((d / "manifest.json").read_text())
                    if m.get("status") == "COMPLETE":
                        out.append(int(m["step"]))
                except (json.JSONDecodeError, KeyError):
                    continue
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    # ------------------------------------------------------------------
    def restore(self, step: int, like: Dict[str, Any],
                shardings=None) -> Dict[str, Any]:
        """Restore into the structure of `like` (pytree of arrays or
        ShapeDtypeStructs); re-places onto current devices (resharding on
        elastic restarts handled by jax.device_put with new shardings)."""
        d = self.dir / f"step_{step:09d}"
        if not (d / "manifest.json").exists():
            raise FileNotFoundError(f"no COMPLETE checkpoint at {d}")
        data: Dict[str, np.ndarray] = {}
        for shard in sorted(d.glob("shard_*.npz")):
            with np.load(shard) as z:
                for k in z.files:
                    data[k] = z[k]
        flat, treedef = _flatten(like)
        leaves = []
        for key, ref in flat:
            if ref is None:
                leaves.append(None)
                continue
            if key not in data:
                raise KeyError(f"checkpoint missing leaf {key!r}")
            arr = data[key]
            if tuple(arr.shape) != tuple(ref.shape):
                raise ValueError(
                    f"leaf {key!r} shape {arr.shape} != {ref.shape}")
            ref_dtype = np.dtype(ref.dtype)
            if ref_dtype.name == "bfloat16" and arr.dtype == np.uint16:
                arr = arr.view(ref_dtype)   # undo the storage view
            leaves.append(arr.astype(ref_dtype))
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.device_put(tree, shardings)
        return tree

    # ------------------------------------------------------------------
    def _gc(self) -> None:
        steps = self.steps()
        # incomplete dirs: remove immediately
        for d in self.dir.glob("step_*"):
            if not (d / "manifest.json").exists():
                mtime = d.stat().st_mtime
                if time.time() - mtime > 60:
                    shutil.rmtree(d, ignore_errors=True)
        if self.max_to_keep and len(steps) > self.max_to_keep:
            for s in steps[: -self.max_to_keep]:
                shutil.rmtree(self.dir / f"step_{s:09d}",
                              ignore_errors=True)
