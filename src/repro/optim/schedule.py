"""LR schedules (warmup + cosine / linear / rsqrt)."""

from __future__ import annotations

import math

import jax.numpy as jnp

__all__ = ["warmup_cosine", "warmup_rsqrt", "constant"]


def constant(lr: float):
    return lambda step: jnp.float32(lr)


def warmup_cosine(peak: float, warmup: int, total: int, floor: float = 0.0):
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak * step / max(1, warmup)
        t = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = floor + 0.5 * (peak - floor) * (1.0 + jnp.cos(math.pi * t))
        return jnp.where(step < warmup, warm, cos)
    return fn


def warmup_rsqrt(peak: float, warmup: int):
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak * step / max(1, warmup)
        decay = peak * jnp.sqrt(warmup / jnp.maximum(step, warmup))
        return jnp.where(step < warmup, warm, decay)
    return fn
