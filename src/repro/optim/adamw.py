"""Sharded AdamW with bf16 params + fp32 master copies, global-norm clip.

Functional, optax-style: init(params) -> state; update(grads, state, params)
-> (new_params, new_state).  Optimizer state leaves mirror the parameter
tree, so the same PartitionSpecs (launch/sharding.py) shard them — FSDP
(ZeRO) for free under pjit.

Optional gradient compression (error-feedback int8) lives in
repro.optim.grad_compression and wraps the DP all-reduce in shard_map runs;
under plain pjit the reduction is XLA's.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "AdamWState", "init", "update", "global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: Callable[[jax.Array], jax.Array] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # keep a fp32 master copy when params are half precision
    master_fp32: bool = True


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any
    master: Any          # fp32 master params (None leaves if disabled)


def _lr_at(cfg: AdamWConfig, step) -> jax.Array:
    if callable(cfg.lr):
        return jnp.asarray(cfg.lr(step), jnp.float32)
    return jnp.asarray(cfg.lr, jnp.float32)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def init(cfg: AdamWConfig, params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    # copy=True: when params are already fp32, astype would alias the same
    # buffer and donation of (params, master) would double-donate.
    master = (jax.tree.map(
        lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params)
        if cfg.master_fp32 else None)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros), master=master)


def update(cfg: AdamWConfig, grads, state: AdamWState, params
           ) -> Tuple[Any, AdamWState, dict]:
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if cfg.grad_clip else jnp.float32(1.0)

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    lr = _lr_at(cfg, step)

    ref = state.master if cfg.master_fp32 else params

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m2 = b1 * m + (1.0 - b1) * g
        v2 = b2 * v + (1.0 - b2) * g * g
        mhat = m2 / bc1
        vhat = v2 / bc2
        p32 = p.astype(jnp.float32)
        p2 = p32 - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                         + cfg.weight_decay * p32)
        return m2, v2, p2

    out = jax.tree.map(upd, grads, state.mu, state.nu, ref)
    mu = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    nu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new32 = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))

    new_params = jax.tree.map(lambda p, n: n.astype(p.dtype), params, new32)
    new_state = AdamWState(step=step, mu=mu, nu=nu,
                           master=new32 if cfg.master_fp32 else None)
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
