"""Error-feedback int8 gradient compression for the data-parallel axis.

At multi-pod scale the DP all-reduce crosses the slow inter-pod links; 4x
compression (f32 grads -> int8 + per-block f32 scales) cuts that traffic
4x at the cost of quantization noise, which error feedback (carrying the
quantization residual into the next step) provably repairs for SGD-family
optimizers.

Usage (shard_map runs):  g8, scales = compress(g, err); g_sum =
psum(g8 as f32 * scales ... ) — here exposed as pure quantize/dequantize
with residual so it also slots under plain pjit (quantize -> psum ->
dequantize is what XLA sees; the collective then moves int8).
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["compress_leaf", "decompress_leaf", "init_error", "ef_compress",
           "ef_decompress_apply"]

_BLOCK = 2048


def init_error(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_leaf(g: jax.Array, err: jax.Array
                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """-> (q int8 [n], scales f32 [blocks], new_err f32)."""
    g = g.astype(jnp.float32) + err
    flat = g.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % _BLOCK
    fp = jnp.pad(flat, (0, pad)).reshape(-1, _BLOCK)
    scale = jnp.max(jnp.abs(fp), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(fp / scale), -127, 127).astype(jnp.int8)
    deq = (q.astype(jnp.float32) * scale).reshape(-1)[:n].reshape(g.shape)
    new_err = g - deq
    return q, scale[:, 0], new_err


def decompress_leaf(q: jax.Array, scales: jax.Array, shape) -> jax.Array:
    deq = q.astype(jnp.float32) * scales[:, None]
    n = 1
    for s in shape:
        n *= s
    return deq.reshape(-1)[:n].reshape(shape)


def ef_compress(grads, errors):
    """Tree version. -> (quantized tree {q, scales}, new error tree)."""
    qs, ss, es = {}, {}, {}
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree.leaves(errors)
    out_q, out_s, out_e = [], [], []
    for g, e in zip(flat_g, flat_e):
        q, s, ne = compress_leaf(g, e)
        out_q.append(q)
        out_s.append(s)
        out_e.append(ne)
    return (jax.tree_util.tree_unflatten(treedef, out_q),
            jax.tree_util.tree_unflatten(treedef, out_s),
            jax.tree_util.tree_unflatten(treedef, out_e))


def ef_decompress_apply(qtree, stree, like):
    flat_q = jax.tree.leaves(qtree)
    flat_s = jax.tree.leaves(stree)
    flat_l, treedef = jax.tree_util.tree_flatten(like)
    out = [decompress_leaf(q, s, l.shape)
           for q, s, l in zip(flat_q, flat_s, flat_l)]
    return jax.tree_util.tree_unflatten(treedef, out)
