"""repro: JAX/Pallas reproduction and production framework for GeNN (2014).

Layers:
  repro.core       -- the paper's contribution (SNN codegen, conductance scaling)
  repro.sparse     -- CSR/ELL synapse representations + memory model
  repro.kernels    -- Pallas TPU kernels (+ pure-jnp oracles)
  repro.models     -- LM architecture family (dense/GQA/MoE/SSM/hybrid/enc-dec/VLM)
  repro.configs    -- architecture configs (paper models + 10 assigned archs)
  repro.optim      -- sharded AdamW, schedules, gradient compression
  repro.data       -- deterministic resumable data pipeline
  repro.checkpoint -- step-atomic checkpoint manager
  repro.runtime    -- fault tolerance / elastic remesh / straggler mitigation
  repro.launch     -- mesh construction, sharding rules, dry-run, train, serve
"""

__version__ = "1.0.0"
