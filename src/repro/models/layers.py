"""Shared building blocks for the LM family (pure-functional, no flax).

Params are nested dicts of jnp arrays.  Activation sharding hints go through
`shard()`, which resolves logical axes ("batch", "seq", "model_d", "heads",
"ffn", "vocab", "experts") against the active mesh axes set by
repro.launch.sharding.activate() — identity when no mesh is active, so the
same model code runs in unit tests, dry-runs and real launches.
"""

from __future__ import annotations

import math
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# ---------------------------------------------------------------------------
# logical-axis resolution (set by repro.launch.sharding.activate()).
# Divisibility-aware: a logical axis is silently dropped for a tensor dim the
# mesh axis does not divide (e.g. 8 mixtral experts on a 16-way model axis,
# batch=1 long-context decode) — the same graceful degradation GSPMD applies,
# but decided here so constraints never force padded shardings.
# ---------------------------------------------------------------------------
_AXIS_ENV: dict = {
    "active": False, "batch": None, "model": None,
    "batch_size": 1, "model_size": 1,
}


def set_axis_env(batch_axes, model_axis, batch_size: int = 1,
                 model_size: int = 1) -> None:
    _AXIS_ENV.update(active=True, batch=batch_axes, model=model_axis,
                     batch_size=batch_size, model_size=model_size)


def clear_axis_env() -> None:
    _AXIS_ENV.update(active=False, batch=None, model=None,
                     batch_size=1, model_size=1)


_LOGICAL = {
    "batch": "batch", "heads": "model", "ffn": "model", "vocab": "model",
    "experts": "model", "kv_heads": "model", "model_d": None, "seq": None,
}


def shard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Apply a sharding constraint by logical axis names (None = replicated)."""
    if not _AXIS_ENV["active"]:
        return x
    spec = []
    for i, name in enumerate(logical):
        dim = x.shape[i] if i < x.ndim else 0
        if name is None:
            spec.append(None)
        elif name == "batch":
            if _AXIS_ENV["batch"] and dim % max(1, _AXIS_ENV["batch_size"]) == 0:
                spec.append(_AXIS_ENV["batch"])
            else:
                spec.append(None)
        elif _LOGICAL.get(name) == "model" and _AXIS_ENV["model"] \
                and dim % max(1, _AXIS_ENV["model_size"]) == 0:
            spec.append(_AXIS_ENV["model"])
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(x, P(*spec))


# ---------------------------------------------------------------------------
# initializers (fan-in scaling policy from the paper — core/scaling.py)
# ---------------------------------------------------------------------------

def dense_init(key, fan_in: int, fan_out: int, std: Optional[float] = None,
               dtype=jnp.float32) -> jax.Array:
    std = std if std is not None else 1.0 / math.sqrt(fan_in)
    return (std * jax.random.normal(key, (fan_in, fan_out))).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32) -> jax.Array:
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    y = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (y * (1.0 + gamma.astype(jnp.float32))).astype(dt)


def layernorm(x: jax.Array, gamma: jax.Array, beta: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * gamma.astype(jnp.float32)
            + beta.astype(jnp.float32)).astype(dt)


def norm_apply(kind: str, x, p):
    if kind == "rmsnorm":
        return rmsnorm(x, p["scale"])
    return layernorm(x, p["scale"], p["bias"])


def norm_init(kind: str, d: int, dtype=jnp.float32):
    if kind == "rmsnorm":
        return {"scale": jnp.zeros((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 10000.0) -> jax.Array:
    """x: [B, T, H, D]; positions: [B, T] or [T]."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # [D/2]
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B, T, D/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_init(key, d: int, f: int, gated: bool, dtype=jnp.float32,
             std_in: Optional[float] = None, std_out: Optional[float] = None):
    ks = jax.random.split(key, 3)
    p = {"w_out": dense_init(ks[2], f, d, std_out, dtype)}
    if gated:
        p["w_gate"] = dense_init(ks[0], d, f, std_in, dtype)
        p["w_up"] = dense_init(ks[1], d, f, std_in, dtype)
    else:
        p["w_in"] = dense_init(ks[0], d, f, std_in, dtype)
    return p


def mlp_apply(p, x: jax.Array, activation: str = "silu") -> jax.Array:
    act = {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
           "gelu_tanh": lambda v: jax.nn.gelu(v, approximate=True),
           "relu": jax.nn.relu}[activation]
    if "w_gate" in p:
        h = act(x @ p["w_gate"]) * (x @ p["w_up"])
    else:
        h = act(x @ p["w_in"])
    h = shard(h, "batch", None, "ffn")
    return h @ p["w_out"]


# ---------------------------------------------------------------------------
# misc
# ---------------------------------------------------------------------------

def cross_entropy(logits: jax.Array, labels: jax.Array,
                  ignore_id: int = -1,
                  true_vocab: Optional[int] = None) -> jax.Array:
    """Mean CE over non-ignored tokens; logits [.., V], labels [..].

    Partition-friendly: no take_along_axis (GSPMD implements gathers from a
    vocab-sharded operand with a full all-gather — an unsharded f32 logits
    copy per device).  The label term is an iota-mask reduce instead, and
    padded vocab entries (vocab rounded up for even model-axis sharding) are
    masked to -inf.  Every reduction partitions over the sharded vocab dim.
    """
    v = logits.shape[-1]
    x = logits.astype(jnp.float32)
    vidx = jax.lax.broadcasted_iota(jnp.int32, x.shape, x.ndim - 1)
    if true_vocab is not None and true_vocab < v:
        x = jnp.where(vidx < true_vocab, x, -jnp.inf)
    lmax = jax.lax.stop_gradient(jnp.max(x, axis=-1, keepdims=True))
    shifted = x - lmax
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + lmax[..., 0]
    label_hit = vidx == labels[..., None].clip(0)
    ll = jnp.sum(jnp.where(label_hit, x, 0.0), axis=-1)
    mask = (labels != ignore_id).astype(jnp.float32)
    nll = (lse - ll) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
