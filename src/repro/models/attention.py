"""Attention: GQA/MQA/MHA with RoPE, qk-norm, bias, sliding windows, caches.

The sliding-window path is the GeNN tie-in (DESIGN.md §4): the position ->
position attention pattern is a *synapse connectivity matrix*; a window makes
it banded-sparse, and we pick the representation (windowed kernel + ring
buffer cache vs dense cache) with the paper's eq(1)/(2) memory model
(`window_cache_elements` vs `dense_cache_elements`).

Two entry points:
  attention_forward : full-sequence (training / prefill), uses
                      kernels.ops.flash_attention (Pallas on TPU, ref on CPU)
  attention_decode  : one-token step against a KV cache (dense or ring)
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.models.layers import (apply_rope, dense_init, norm_apply,
                                 norm_init, rmsnorm, shard)

__all__ = [
    "AttnConfig", "attn_init", "attention_forward", "attention_decode",
    "init_cache", "window_cache_elements", "dense_cache_elements",
]


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    rope_theta: float = 10000.0
    qk_norm: bool = False
    qkv_bias: bool = False
    window: Optional[int] = None         # sliding window (None = full)
    causal: bool = True
    softcap: Optional[float] = None      # logit soft-capping (gemma-style)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv * self.head_dim


def attn_init(key: jax.Array, cfg: AttnConfig, dtype=jnp.float32,
              std: Optional[float] = None):
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    p = {
        "wq": dense_init(ks[0], d, cfg.q_dim, std, dtype),
        "wk": dense_init(ks[1], d, cfg.kv_dim, std, dtype),
        "wv": dense_init(ks[2], d, cfg.kv_dim, std, dtype),
        "wo": dense_init(ks[3], cfg.q_dim, d, std, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.q_dim,), dtype)
        p["bk"] = jnp.zeros((cfg.kv_dim,), dtype)
        p["bv"] = jnp.zeros((cfg.kv_dim,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = norm_init("rmsnorm", cfg.head_dim, dtype)
        p["k_norm"] = norm_init("rmsnorm", cfg.head_dim, dtype)
    return p


def _project_qkv(p, cfg: AttnConfig, x: jax.Array, positions: jax.Array):
    b, t, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, t, cfg.n_heads, cfg.head_dim)
    k = k.reshape(b, t, cfg.n_kv, cfg.head_dim)
    v = v.reshape(b, t, cfg.n_kv, cfg.head_dim)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"]["scale"])
        k = rmsnorm(k, p["k_norm"]["scale"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_forward(
    p, cfg: AttnConfig, x: jax.Array,
    positions: Optional[jax.Array] = None,
    window: Optional[jax.Array] = None,     # overrides cfg.window (traced ok)
    kv: Optional[tuple] = None,             # cross-attention source (k, v)
    return_kv: bool = False,
    prefix: Optional[int] = None,           # prefix-LM bidirectional span
):
    """x: [B, T, d] -> [B, T, d].  Full-sequence attention."""
    b, t, _ = x.shape
    if positions is None:
        positions = jnp.arange(t)
    if kv is None:
        q, k, v = _project_qkv(p, cfg, x, positions)
    else:
        q = (x @ p["wq"]).reshape(b, t, cfg.n_heads, cfg.head_dim)
        if cfg.qkv_bias:
            q = q + p["bq"].reshape(cfg.n_heads, cfg.head_dim)
        if cfg.qk_norm:
            q = rmsnorm(q, p["q_norm"]["scale"])
        k, v = kv

    q = shard(q, "batch", None, "heads", None)
    k = shard(k, "batch", None, "kv_heads", None)
    v = shard(v, "batch", None, "kv_heads", None)

    eff_window = window if window is not None else cfg.window
    out = kops.flash_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=cfg.causal, window=eff_window,
        scale=1.0 / math.sqrt(cfg.head_dim), softcap=cfg.softcap,
        prefix=prefix)
    out = out.transpose(0, 2, 1, 3).reshape(b, t, cfg.q_dim)
    out = shard(out, "batch", None, "heads")
    y = out @ p["wo"]
    if return_kv:
        return y, (k, v)
    return y


# ---------------------------------------------------------------------------
# KV caches.  Dense cache: [B, S, n_kv, D].  Ring cache (window layers):
# [B, W, n_kv, D] plus a position buffer [B?, W] (positions identical across
# batch; stored [W]).  Representation choice follows the paper's memory model.
# ---------------------------------------------------------------------------

def dense_cache_elements(seq: int, n_kv: int, head_dim: int) -> int:
    return 2 * seq * n_kv * head_dim


def window_cache_elements(window: int, n_kv: int, head_dim: int) -> int:
    return 2 * window * n_kv * head_dim + window  # + position ring


def init_cache(cfg: AttnConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16):
    """Choose ring vs dense per the paper's memory model."""
    use_ring = (cfg.window is not None and window_cache_elements(
        cfg.window, cfg.n_kv, cfg.head_dim) < dense_cache_elements(
        max_seq, cfg.n_kv, cfg.head_dim))
    s = cfg.window if use_ring else max_seq
    return {
        "k": jnp.zeros((batch, s, cfg.n_kv, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, s, cfg.n_kv, cfg.head_dim), dtype),
        "pos": jnp.full((s,), -1, jnp.int32),   # absolute positions
        "ring": jnp.asarray(use_ring),
    }


def fill_cache(cache, k: jax.Array, v: jax.Array, start: int = 0):
    """Prefill: write [B, T, kv, D] into the cache at [start, start+T)."""
    t = k.shape[1]
    s = cache["k"].shape[1]
    if t >= s:  # ring smaller than prefill: keep the last s positions
        ks, vs = k[:, -s:], v[:, -s:]
        pos = jnp.arange(t - s, t, dtype=jnp.int32) + start
        return {**cache, "k": ks.astype(cache["k"].dtype),
                "v": vs.astype(cache["v"].dtype), "pos": pos}
    kc = jax.lax.dynamic_update_slice(
        cache["k"], k.astype(cache["k"].dtype), (0, start, 0, 0))
    vc = jax.lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (0, start, 0, 0))
    pos = jax.lax.dynamic_update_slice(
        cache["pos"], jnp.arange(t, dtype=jnp.int32) + start, (start,))
    return {**cache, "k": kc, "v": vc, "pos": pos}


def attention_decode(
    p, cfg: AttnConfig, x: jax.Array, cache, index: jax.Array,
    cross: bool = False,
):
    """One-token step.  x: [B, 1, d]; index: absolute position (scalar).
    Returns (y [B,1,d], new_cache)."""
    b = x.shape[0]
    pos1 = jnp.full((b, 1), index, jnp.int32)

    q = (x @ p["wq"]).reshape(b, 1, cfg.n_heads, cfg.head_dim)
    if cfg.qkv_bias:
        q = q + p["bq"].reshape(cfg.n_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"]["scale"])

    if cross:
        # cross-attention: cache holds encoder KV; no insert, no rope.
        k, v, kpos = cache["k"], cache["v"], cache["pos"]
        new_cache = cache
    else:
        q = apply_rope(q, pos1, cfg.rope_theta)
        k1 = (x @ p["wk"]).reshape(b, 1, cfg.n_kv, cfg.head_dim)
        v1 = (x @ p["wv"]).reshape(b, 1, cfg.n_kv, cfg.head_dim)
        if cfg.qkv_bias:
            k1 = k1 + p["bk"].reshape(cfg.n_kv, cfg.head_dim)
            v1 = v1 + p["bv"].reshape(cfg.n_kv, cfg.head_dim)
        if cfg.qk_norm:
            k1 = rmsnorm(k1, p["k_norm"]["scale"])
        k1 = apply_rope(k1, pos1, cfg.rope_theta)
        s = cache["k"].shape[1]
        slot = jnp.where(cache["ring"], index % s, jnp.minimum(index, s - 1))
        kc = jax.lax.dynamic_update_slice(
            cache["k"], k1.astype(cache["k"].dtype), (0, slot, 0, 0))
        vc = jax.lax.dynamic_update_slice(
            cache["v"], v1.astype(cache["v"].dtype), (0, slot, 0, 0))
        kpos = jax.lax.dynamic_update_slice(
            cache["pos"], jnp.full((1,), index, jnp.int32), (slot,))
        new_cache = {**cache, "k": kc, "v": vc, "pos": kpos}
        k, v = kc, vc

    # masked attention of 1 query vs cache — grouped einsum, never
    # materializing the GQA-repeated cache (that repeat is O(S*H*D) HBM).
    rep = cfg.n_heads // cfg.n_kv
    qg = q.reshape(b, cfg.n_kv, rep, cfg.head_dim)
    logits = jnp.einsum("bgrd,bsgd->bgrs", qg, k,
                        preferred_element_type=jnp.float32)
    logits = logits / math.sqrt(cfg.head_dim)
    if cfg.softcap is not None:
        logits = cfg.softcap * jnp.tanh(logits / cfg.softcap)
    valid = kpos >= 0
    if not cross:
        valid = valid & (kpos <= index)
        if cfg.window is not None:
            valid = valid & (kpos > index - cfg.window)
    logits = jnp.where(valid[None, None, None, :], logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bgrs,bsgd->bgrd", w.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    y = out.reshape(b, 1, cfg.q_dim).astype(x.dtype) @ p["wo"]
    return y, new_cache
