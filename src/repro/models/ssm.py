"""Mamba2 (SSD — state-space duality) blocks.

`ssd_chunked` is the production O(T) algorithm: split the sequence into
chunks; inside a chunk the recurrence is computed in its "dual" quadratic
attention-like form (MXU-friendly matmuls), states are passed between chunks
by a tiny scan.  `repro.kernels.ref.ssd_scan_ref` (naive recurrence) is the
oracle; the Pallas kernel (kernels/ssd_scan.py) tiles the same chunked
algorithm for VMEM.

Block layout follows Mamba2: one input projection producing
[z | x | B | C | dt], causal depthwise conv on (x, B, C), SSD core, gated
RMSNorm, output projection.  Decode keeps (conv_state, ssd_state) — O(1)
per token, which is why the SSM/hybrid archs run the long_500k cell.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rmsnorm, shard

__all__ = ["SSMConfig", "ssm_init", "ssm_apply", "ssm_decode_step",
           "ssm_init_cache", "ssd_chunked"]


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_model: int
    d_state: int = 128          # N: SSM state size per head
    d_head: int = 64            # P: channels per head
    expand: int = 2
    n_groups: int = 1           # B/C groups (like KV heads)
    d_conv: int = 4
    dt_min: float = 0.001
    dt_max: float = 0.1

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.d_head


def ssm_init(key: jax.Array, cfg: SSMConfig, dtype=jnp.float32,
             std: Optional[float] = None):
    ks = jax.random.split(key, 5)
    d, di, g, n, hh = (cfg.d_model, cfg.d_inner, cfg.n_groups, cfg.d_state,
                       cfg.n_heads)
    d_in_proj = 2 * di + 2 * g * n + hh
    conv_dim = di + 2 * g * n
    # dt bias: softplus^-1 of U(dt_min, dt_max) samples
    u = jax.random.uniform(ks[2], (hh,), jnp.float32)
    dt0 = jnp.exp(u * (math.log(cfg.dt_max) - math.log(cfg.dt_min))
                  + math.log(cfg.dt_min))
    dt_bias = dt0 + jnp.log(-jnp.expm1(-dt0))
    a0 = jax.random.uniform(ks[3], (hh,), jnp.float32, 1.0, 16.0)
    return {
        "w_in": dense_init(ks[0], d, d_in_proj, std, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.d_conv, conv_dim))
                   * (1.0 / math.sqrt(cfg.d_conv))).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "dt_bias": dt_bias.astype(jnp.float32),
        "A_log": jnp.log(a0).astype(jnp.float32),
        "D": jnp.ones((hh,), jnp.float32),
        "norm_scale": jnp.zeros((di,), dtype),
        "w_out": dense_init(ks[4], di, d, std, dtype),
    }


# ---------------------------------------------------------------------------
# chunked SSD core
# ---------------------------------------------------------------------------

def ssd_chunked(x, dt, A, B, C, D=None, chunk: int = 256,
                initial_state=None, return_final_state: bool = False):
    """O(T) chunked SSD.  Shapes as ssd_scan_ref:
    x [b,t,h,dh], dt [b,t,h], A [h], B/C [b,t,g,ds] -> y [b,t,h,dh].
    """
    b, t, h, dh = x.shape
    g, ds = B.shape[2], B.shape[3]
    rep = h // g
    q = min(chunk, t)
    while t % q:
        q //= 2
    nc = t // q

    Bh = jnp.repeat(B, rep, axis=2)          # [b,t,h,ds]
    Ch = jnp.repeat(C, rep, axis=2)

    # per-step log decay  a_t = dt_t * A  (A < 0 via -exp(A_log) upstream)
    la = dt * A[None, None, :]               # [b,t,h] (negative)
    xc = x.reshape(b, nc, q, h, dh)
    dtc = dt.reshape(b, nc, q, h)
    lac = la.reshape(b, nc, q, h)
    Bc = Bh.reshape(b, nc, q, h, ds)
    Cc = Ch.reshape(b, nc, q, h, ds)

    cum = jnp.cumsum(lac, axis=2)            # within-chunk cumulative logs
    total = cum[:, :, -1]                    # [b,nc,h]

    # --- intra-chunk (dual/attention form): for i >= j
    #   att[i,j] = C_i . B_j * exp(cum_i - cum_j) * dt_j
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]    # [b,nc,q,q,h]
    mask = jnp.tril(jnp.ones((q, q), bool))
    dec = jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0)
    cb = jnp.einsum("bcihs,bcjhs->bcijh", Cc, Bc)
    att = cb * dec * dtc[:, :, None, :, :]
    y_intra = jnp.einsum("bcijh,bcjhd->bcihd", att, xc)

    # --- chunk states: S_c = sum_j exp(total - cum_j) * dt_j * B_j x_j^T
    w = jnp.exp(total[:, :, None, :] - cum) * dtc            # [b,nc,q,h]
    S = jnp.einsum("bcjh,bcjhs,bcjhd->bchsd", w, Bc, xc)     # [b,nc,h,ds,dh]

    # --- inter-chunk: scan states across chunks
    def scan_fn(s_prev, inp):
        s_c, tot_c = inp                      # [b,h,ds,dh], [b,h]
        s_new = s_prev * jnp.exp(tot_c)[:, :, None, None] + s_c
        return s_new, s_prev

    s0 = (initial_state if initial_state is not None
          else jnp.zeros((b, h, ds, dh), x.dtype))
    s_last, s_prevs = jax.lax.scan(
        scan_fn, s0, (jnp.moveaxis(S, 1, 0), jnp.moveaxis(total, 1, 0)))
    s_prevs = jnp.moveaxis(s_prevs, 0, 1)     # state entering each chunk

    # y_inter[i] = C_i . (exp(cum_i) * S_prev)
    y_inter = jnp.einsum("bcihs,bchsd->bcihd",
                         Cc * jnp.exp(cum)[..., None], s_prevs)

    y = (y_intra + y_inter).reshape(b, t, h, dh)
    if D is not None:
        y = y + x * D[None, None, :, None]
    if return_final_state:
        return y, s_last
    return y


# ---------------------------------------------------------------------------
# full block
# ---------------------------------------------------------------------------

def _split_proj(cfg: SSMConfig, zxbcdt):
    di, g, n, h = cfg.d_inner, cfg.n_groups, cfg.d_state, cfg.n_heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di: 2 * di + 2 * g * n]
    dt = zxbcdt[..., 2 * di + 2 * g * n:]
    return z, xbc, dt


def ssm_apply(p, cfg: SSMConfig, u: jax.Array,
              conv_state=None, ssd_state=None,
              return_state: bool = False):
    """u: [B, T, d_model] -> [B, T, d_model] (full-sequence)."""
    b, t, _ = u.shape
    di, g, n, h, dh = (cfg.d_inner, cfg.n_groups, cfg.d_state, cfg.n_heads,
                       cfg.d_head)
    zxbcdt = u @ p["w_in"]
    z, xbc, dt = _split_proj(cfg, zxbcdt)

    # causal depthwise conv over time (window d_conv)
    w = p["conv_w"]                            # [d_conv, conv_dim]
    pad = cfg.d_conv - 1
    xbc_pad = jnp.pad(xbc, ((0, 0), (pad, 0), (0, 0)))
    if conv_state is not None:
        xbc_pad = jax.lax.dynamic_update_slice(
            xbc_pad, conv_state.astype(xbc_pad.dtype), (0, 0, 0))
    xbc_conv = sum(
        xbc_pad[:, i: i + t] * w[i][None, None, :]
        for i in range(cfg.d_conv)) + p["conv_b"]
    xbc_conv = jax.nn.silu(xbc_conv)
    new_conv_state = xbc_pad[:, t: t + pad] if pad else None

    xs = xbc_conv[..., :di].reshape(b, t, h, dh)
    Bmat = xbc_conv[..., di: di + g * n].reshape(b, t, g, n)
    Cmat = xbc_conv[..., di + g * n:].reshape(b, t, g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    xs = shard(xs, "batch", None, "heads", None)
    from repro.kernels import ops as kops
    y = kops.ssd_scan(xs.astype(jnp.float32), dt, A,
                      Bmat.astype(jnp.float32), Cmat.astype(jnp.float32),
                      p["D"]) if not return_state else None
    if return_state:
        y, s_last = ssd_chunked(
            xs.astype(jnp.float32), dt, A, Bmat.astype(jnp.float32),
            Cmat.astype(jnp.float32), p["D"], initial_state=ssd_state,
            return_final_state=True)
    y = y.reshape(b, t, di).astype(u.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_scale"])
    out = y @ p["w_out"]
    if return_state:
        return out, (new_conv_state, s_last)
    return out


def ssm_init_cache(cfg: SSMConfig, batch: int, dtype=jnp.float32):
    conv_dim = cfg.d_inner + 2 * cfg.n_groups * cfg.d_state
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, conv_dim), dtype),
        "ssd": jnp.zeros((batch, cfg.n_heads, cfg.d_state, cfg.d_head),
                         jnp.float32),
    }


def ssm_decode_step(p, cfg: SSMConfig, u: jax.Array, cache):
    """u: [B, 1, d_model]; O(1) recurrent step."""
    b = u.shape[0]
    di, g, n, h, dh = (cfg.d_inner, cfg.n_groups, cfg.d_state, cfg.n_heads,
                       cfg.d_head)
    zxbcdt = u[:, 0] @ p["w_in"]
    z, xbc, dt = _split_proj(cfg, zxbcdt)

    hist = jnp.concatenate(
        [cache["conv"], xbc[:, None, :].astype(cache["conv"].dtype)], axis=1)
    w = p["conv_w"]
    xbc_conv = jnp.einsum("btc,tc->bc", hist.astype(jnp.float32),
                          w.astype(jnp.float32)) + p["conv_b"]
    xbc_conv = jax.nn.silu(xbc_conv)
    new_conv = hist[:, 1:]

    xs = xbc_conv[..., :di].reshape(b, h, dh)
    Bm = xbc_conv[..., di: di + g * n].reshape(b, g, n)
    Cm = xbc_conv[..., di + g * n:].reshape(b, g, n)
    rep = h // g
    Bm = jnp.repeat(Bm, rep, axis=1)          # [b,h,n]
    Cm = jnp.repeat(Cm, rep, axis=1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [b,h]
    A = -jnp.exp(p["A_log"])

    s = cache["ssd"]
    decay = jnp.exp(dt * A[None, :])[:, :, None, None]
    s_new = s * decay + (dt[:, :, None] * xs)[:, :, None, :] \
        * Bm[:, :, :, None]                    # [b,h,n,dh]
    y = jnp.einsum("bhsd,bhs->bhd", s_new, Cm) + xs * p["D"][None, :, None]
    y = y.reshape(b, di).astype(u.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_scale"])
    out = (y @ p["w_out"])[:, None, :]
    return out, {"conv": new_conv, "ssd": s_new}
