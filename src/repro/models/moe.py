"""Mixture-of-Experts FFN with top-k routing (group-wise capacity dispatch).

GeNN tie-in (DESIGN.md §4): the token->expert assignment is a sparse
connectivity matrix.  As with SNN spike propagation, TPUs want that sparse
scatter expressed as dense one-hot matmuls with a *bounded fan-out*; the
bound here is the expert capacity — the MoE analogue of ELL's fixed row
width.  Tokens over capacity are dropped (capacity-factor semantics) and the
auxiliary load-balancing loss keeps drops rare, playing the role of the
paper's "prescribed spiking range".

Dispatch is computed within fixed-size token groups (Mesh-TF/Switch style) so
the one-hot tensors are [G, group, E, cap] — G rides the data axis, keeping
per-device temporaries bounded regardless of global batch.  Expert weights
are sharded either over the expert axis (`expert_sharding="expert"`, e.g.
granite 32e on a 16-way model axis -> 2 experts/device) or tensor-parallel
inside each expert (`"ffn"`, e.g. mixtral 8e) — chosen per config.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, shard

__all__ = ["MoEConfig", "moe_init", "moe_apply"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff: int                 # per-expert hidden size
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    activation: str = "silu"
    aux_loss_weight: float = 0.01
    group_size: int = 1024
    expert_sharding: str = "expert"   # 'expert' | 'ffn'
    dispatch: str = "onehot"          # 'onehot' | 'gather'
    # 'onehot': Switch-style dispatch/combine einsums — O(n*e*cap*d) MXU
    #   flops, fully dense (the ELL lesson applied naively).
    # 'gather': invert the (token,slot)->(expert,pos) map once, then pure
    #   gathers — O(n*k*d) bytes, ~zero flops.  Beyond-paper optimization;
    #   see EXPERIMENTS.md §Perf (mixtral hillclimb).


def moe_init(key: jax.Array, cfg: MoEConfig, dtype=jnp.float32,
             std: Optional[float] = None):
    ks = jax.random.split(key, 4)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    std_in = std if std is not None else 1.0 / math.sqrt(d)
    std_out = std if std is not None else 1.0 / math.sqrt(f)
    return {
        "router": dense_init(ks[0], d, e, None, jnp.float32),
        "w_gate": (std_in * jax.random.normal(ks[1], (e, d, f))).astype(dtype),
        "w_up": (std_in * jax.random.normal(ks[2], (e, d, f))).astype(dtype),
        "w_out": (std_out * jax.random.normal(ks[3], (e, f, d))).astype(dtype),
    }


def _expert_shard(cfg: MoEConfig, x, *dims):
    """Apply expert/ffn sharding on an [.., e, .., f?] tensor by name."""
    names = []
    for dtag in dims:
        if dtag == "e":
            names.append("experts" if cfg.expert_sharding == "expert"
                         else None)
        elif dtag == "f":
            names.append("ffn" if cfg.expert_sharding == "ffn" else None)
        elif dtag == "b":
            names.append("batch")
        else:
            names.append(None)
    return shard(x, *names)


def moe_apply(p, cfg: MoEConfig, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: [B, T, d] -> (y [B, T, d], aux_loss scalar)."""
    b, t, d = x.shape
    n = b * t
    e, k = cfg.n_experts, cfg.top_k
    gs = min(cfg.group_size, n)
    while n % gs:
        gs //= 2
    g = n // gs
    cap = max(k, int(cfg.capacity_factor * gs * k / e))
    cap = min(cap, gs)

    xg = x.reshape(g, gs, d)
    xg = shard(xg, "batch", None, None)
    logits = xg.astype(jnp.float32) @ p["router"]            # [g, gs, e]
    probs = jax.nn.softmax(logits, axis=-1)

    gate_vals, expert_idx = jax.lax.top_k(probs, k)          # [g, gs, k]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # aux load-balance loss (Switch-style): e * sum_e f_e * P_e
    onehot_k = jax.nn.one_hot(expert_idx, e, dtype=jnp.float32)  # [g,gs,k,e]
    f_e = onehot_k.sum(axis=(0, 1, 2)) / (n * k)
    p_e = probs.mean(axis=(0, 1))
    aux = cfg.aux_loss_weight * e * jnp.sum(f_e * p_e)

    # position of each (token, slot) in its expert queue within the group;
    # slots of earlier tokens win (cumsum order: token-major, slot-minor)
    flat_choice = onehot_k.reshape(g, gs * k, e)
    pos_in_e = jnp.cumsum(flat_choice, axis=1) - flat_choice
    pos = (pos_in_e * flat_choice).sum(-1).reshape(g, gs, k)
    pos = pos.astype(jnp.int32)
    keep = pos < cap
    gate_vals = gate_vals * keep

    if cfg.dispatch == "gather":
        # invert (token,slot) -> (expert,pos): slot_token[g, e*cap] holds
        # the source token row (gs = padding -> zero row)
        flat_slot = jnp.where(keep, expert_idx * cap + pos, e * cap)
        slot_token = jnp.full((g, e * cap + 1), gs, jnp.int32)
        tok_ids = jnp.broadcast_to(jnp.arange(gs, dtype=jnp.int32)[None, :,
                                                                   None],
                                   (g, gs, k))
        slot_token = jax.vmap(
            lambda st, fs, ti: st.at[fs.reshape(-1)].set(ti.reshape(-1)))(
            slot_token, flat_slot, tok_ids)[:, : e * cap]
        xg_pad = jnp.concatenate(
            [xg, jnp.zeros((g, 1, d), xg.dtype)], axis=1)
        xe = jnp.take_along_axis(
            xg_pad, slot_token[:, :, None], axis=1)          # [g,e*cap,d]
        xe = xe.reshape(g, e, cap, d)
    else:
        pos_oh = jax.nn.one_hot(jnp.where(keep, pos, cap), cap + 1,
                                dtype=xg.dtype)[..., :cap]   # [g,gs,k,cap]
        oh = onehot_k.astype(xg.dtype)
        disp = jnp.einsum("gnke,gnkc->gnec", oh, pos_oh)
        comb = jnp.einsum("gnke,gnkc,gnk->gnec", oh, pos_oh,
                          gate_vals.astype(xg.dtype))
        xe = jnp.einsum("gnec,gnd->gecd", disp, xg)          # [g,e,cap,d]

    xe = _expert_shard(cfg, xe, "b", "e", None, None)
    act = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[cfg.activation]
    h = act(jnp.einsum("gecd,edf->gecf", xe, p["w_gate"]))
    h = h * jnp.einsum("gecd,edf->gecf", xe, p["w_up"])
    h = _expert_shard(cfg, h, "b", "e", None, "f")
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_out"])         # [g,e,cap,d]
    ye = _expert_shard(cfg, ye, "b", "e", None, None)

    if cfg.dispatch == "gather":
        # combine: gather each (token, slot)'s expert output, weight, sum
        ye_flat = ye.reshape(g, e * cap, d)
        picked = jnp.take_along_axis(
            ye_flat, jnp.where(keep, expert_idx * cap + pos,
                               0).reshape(g, gs * k)[:, :, None], axis=1)
        picked = picked.reshape(g, gs, k, d)
        y = jnp.sum(picked * (gate_vals * keep)[..., None].astype(
            picked.dtype), axis=2)
    else:
        y = jnp.einsum("gnec,gecd->gnd", comb, ye)
    return y.reshape(b, t, d), aux
