"""Unified model assembly for every assigned architecture family.

The layer stack follows the arch's `LayerProgram` (configs/base.py): an outer
scan over `repeats` groups, inner scans over each segment's stacked layers,
plus an optional tail.  This keeps HLO size O(#segment kinds) regardless of
depth, lets heterogeneous patterns (gemma3 5:1 local:global, zamba2 shared
attention) scan cleanly, and gives each segment its own cache pytree
(ring caches for windowed layers, dense for global — the paper's sparse-vs-
dense representation choice applied to the KV "synapse matrix").

Entry points (all pure):
  init_params(cfg, key)
  forward(params, cfg, tokens, extra)      -> logits           (train)
  loss_fn(params, cfg, batch)              -> (loss, metrics)
  prefill(params, cfg, tokens, extra)      -> (last_logits, caches)
  decode_step(params, cfg, caches, token, index) -> (logits, caches)
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, LayerProgram, Segment
from repro.models import attention as A
from repro.models import moe as M
from repro.models import ssm as S
from repro.models.layers import (cross_entropy, dense_init, embed_init,
                                 mlp_apply, mlp_init, norm_apply, norm_init,
                                 shard)

__all__ = ["init_params", "forward", "loss_fn", "prefill", "decode_step",
           "init_caches", "count_params", "model_flops_per_token"]

BIG_WINDOW = 1 << 30   # "global" encoded as a huge window (scan-uniform)

_DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
           "float16": jnp.float16}


def resolve_dtype(name: str):
    return _DTYPES[name]


def padded_vocab(v: int, multiple: int = 256) -> int:
    """Vocab rounded up so the model axis always divides it (Megatron-style
    padding); CE and sampling mask the pad entries to -inf."""
    return (v + multiple - 1) // multiple * multiple


# When True, layer scans are python-unrolled.  Used by the dry-run's
# depth-1/2 extrapolation lowerings: XLA's cost_analysis counts a while-loop
# body ONCE regardless of trip count, so roofline flops/bytes/collectives are
# measured on small unrolled depths and extrapolated linearly (see
# launch/dryrun.py).  Normal runs keep scan (compact HLO, fast compiles).
UNROLL_LAYERS = False


def maybe_scan(body, init, xs, out_axis0: bool = True):
    """lax.scan, or a python unroll of it when UNROLL_LAYERS is set."""
    if not UNROLL_LAYERS:
        return jax.lax.scan(body, init, xs)
    length = jax.tree.leaves(xs)[0].shape[0] if jax.tree.leaves(xs) else 0
    carry = init
    outs = []
    for i in range(length):
        x_i = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        outs.append(y)
    if outs and jax.tree.leaves(outs[0]):
        ys = jax.tree.map(lambda *a: jnp.stack(a), *outs)
    else:
        ys = None
    return carry, ys


# ---------------------------------------------------------------------------
# per-kind layer definitions
# ---------------------------------------------------------------------------

def _attn_cfg(cfg: ArchConfig, kind: str) -> A.AttnConfig:
    window = cfg.window
    if kind == "attn_local":
        window = cfg.local_window
    elif kind == "attn_global":
        window = None
    return A.AttnConfig(
        d_model=cfg.d_model, n_heads=cfg.n_heads, n_kv=cfg.n_kv,
        head_dim=cfg.head_dim, rope_theta=cfg.rope_theta,
        qk_norm=cfg.qk_norm, qkv_bias=cfg.qkv_bias, window=window,
        causal=True)


def _ssm_cfg(cfg: ArchConfig) -> S.SSMConfig:
    return S.SSMConfig(
        d_model=cfg.d_model, d_state=cfg.ssm_state, d_head=cfg.ssm_head,
        expand=cfg.ssm_expand, n_groups=cfg.ssm_groups)


def _moe_cfg(cfg: ArchConfig) -> M.MoEConfig:
    return M.MoEConfig(
        d_model=cfg.d_model, d_ff=cfg.d_ff, n_experts=cfg.n_experts,
        top_k=cfg.top_k, activation=cfg.activation,
        capacity_factor=getattr(cfg, "moe_capacity_factor", 1.25),
        dispatch=getattr(cfg, "moe_dispatch", "onehot"),
        group_size=cfg.moe_group_size, expert_sharding=cfg.expert_sharding)


def _layer_init(cfg: ArchConfig, kind: str, key, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    if kind == "mamba":
        return {"norm": norm_init(cfg.norm, d, dtype),
                "ssm": S.ssm_init(ks[0], _ssm_cfg(cfg), dtype)}
    p = {"ln1": norm_init(cfg.norm, d, dtype),
         "attn": A.attn_init(ks[0], _attn_cfg(cfg, kind), dtype),
         "ln2": norm_init(cfg.norm, d, dtype)}
    if kind == "moe":
        p["moe"] = M.moe_init(ks[1], _moe_cfg(cfg), dtype)
    else:
        p["mlp"] = mlp_init(ks[1], d, cfg.d_ff, cfg.gated_mlp, dtype)
    if cfg.family == "encdec" and kind == "attn" and not _is_enc(cfg, kind):
        p["ln_x"] = norm_init(cfg.norm, d, dtype)
        p["xattn"] = A.attn_init(ks[2], _attn_cfg(cfg, "attn"), dtype)
    return p


def _is_enc(cfg, kind):   # encoder segments are initialized separately
    return False


def _layer_apply(cfg: ArchConfig, kind: str, p, x, ctx) -> Tuple[Any, Any]:
    """Full-sequence layer.  Returns (x, (aux, kv_for_cache))."""
    aux = jnp.zeros((), jnp.float32)
    kv = None
    if kind == "mamba":
        if ctx.get("want_cache"):
            y, st = S.ssm_apply(p["ssm"], _ssm_cfg(cfg),
                                norm_apply(cfg.norm, x, p["norm"]),
                                return_state=True)
            kv = st
        else:
            y = S.ssm_apply(p["ssm"], _ssm_cfg(cfg),
                            norm_apply(cfg.norm, x, p["norm"]))
        return x + y, (aux, kv)

    acfg = _attn_cfg(cfg, kind)
    h = norm_apply(cfg.norm, x, p["ln1"])
    window = ctx.get("window_override")
    y, akv = A.attention_forward(
        p["attn"], acfg, h, positions=ctx.get("positions"),
        window=window, prefix=ctx.get("prefix"), return_kv=True)
    if ctx.get("want_cache"):
        kv = akv
    x = x + y
    if "xattn" in p:
        h = norm_apply(cfg.norm, x, p["ln_x"])
        y = A.attention_forward(
            p["xattn"], dataclasses.replace(acfg, causal=False), h,
            kv=ctx["enc_kv"])
        x = x + y
    h = norm_apply(cfg.norm, x, p["ln2"])
    if kind == "moe":
        y, aux = M.moe_apply(p["moe"], _moe_cfg(cfg), h)
    else:
        y = mlp_apply(p["mlp"], h, cfg.activation)
    return x + y, (aux, kv)


def _layer_decode(cfg: ArchConfig, kind: str, p, x, cache, ctx):
    """One-token layer step.  Returns (x, new_cache)."""
    if kind == "mamba":
        h = norm_apply(cfg.norm, x, p["norm"])
        y, new_cache = S.ssm_decode_step(p["ssm"], _ssm_cfg(cfg), h, cache)
        return x + y, new_cache

    acfg = _attn_cfg(cfg, kind)
    h = norm_apply(cfg.norm, x, p["ln1"])
    if "xattn" in p:
        self_cache, cross_cache = cache["self"], cache["cross"]
    else:
        self_cache = cache
    y, self_cache = A.attention_decode(p["attn"], acfg, h, self_cache,
                                       ctx["index"])
    x = x + y
    if "xattn" in p:
        h = norm_apply(cfg.norm, x, p["ln_x"])
        y, _ = A.attention_decode(p["xattn"], acfg, h, cross_cache,
                                  ctx["index"], cross=True)
        x = x + y
        new_cache = {"self": self_cache, "cross": cross_cache}
    else:
        new_cache = self_cache
    h = norm_apply(cfg.norm, x, p["ln2"])
    if kind == "moe":
        y, _ = M.moe_apply(p["moe"], _moe_cfg(cfg), h)
    else:
        y = mlp_apply(p["mlp"], h, cfg.activation)
    return x + y, new_cache


def _layer_cache_init(cfg: ArchConfig, kind: str, batch: int, max_seq: int,
                      dtype):
    if kind == "mamba":
        return S.ssm_init_cache(_ssm_cfg(cfg), batch)
    acfg = _attn_cfg(cfg, kind)
    c = A.init_cache(acfg, batch, max_seq, dtype)
    if cfg.family == "encdec":
        xc = A.init_cache(dataclasses.replace(acfg, window=None), batch,
                          cfg.enc_seq, dtype)
        return {"self": c, "cross": xc}
    return c


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------

def _stack_init(fn, key, n: int):
    return jax.vmap(lambda k: fn(k))(jax.random.split(key, n))


def init_params(cfg: ArchConfig, key: jax.Array) -> Dict[str, Any]:
    dtype = resolve_dtype(cfg.dtype)
    prog = cfg.program()
    keys = jax.random.split(key, 16)

    pv = padded_vocab(cfg.vocab)
    params: Dict[str, Any] = {
        "embed": embed_init(keys[0], pv, cfg.d_model, dtype),
        "final_norm": norm_init(cfg.norm, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[1], cfg.d_model, pv,
                                       None, dtype)

    def seg_init(seg: Segment, k):
        if seg.kind == "shared_attn":
            return _layer_init(cfg, "attn", k, dtype)   # single, unstacked
        return _stack_init(lambda kk: _layer_init(cfg, seg.kind, kk, dtype),
                           k, seg.n)

    segs = []
    for i, seg in enumerate(prog.segments):
        k = jax.random.fold_in(keys[2], i)
        if prog.repeats > 1 and seg.kind != "shared_attn":
            segs.append(_stack_init(lambda kk, s=seg: seg_init(s, kk), k,
                                    prog.repeats))
        else:
            segs.append(seg_init(seg, k))
    params["segments"] = segs
    params["tail"] = [seg_init(seg, jax.random.fold_in(keys[3], i))
                      for i, seg in enumerate(prog.tail)]

    if cfg.family == "encdec":
        enc_attn = dataclasses.replace(cfg, window=None)

        def enc_layer(k):
            ks = jax.random.split(k, 2)
            return {"ln1": norm_init(cfg.norm, cfg.d_model, dtype),
                    "attn": A.attn_init(ks[0], _attn_cfg(enc_attn, "attn"),
                                        dtype),
                    "ln2": norm_init(cfg.norm, cfg.d_model, dtype),
                    "mlp": mlp_init(ks[1], cfg.d_model, cfg.d_ff,
                                    cfg.gated_mlp, dtype)}

        params["enc"] = {
            "layers": _stack_init(enc_layer, keys[4], cfg.n_enc_layers),
            "norm": norm_init(cfg.norm, cfg.d_model, dtype),
            "pos_embed": (0.02 * jax.random.normal(
                keys[5], (cfg.enc_seq, cfg.d_model))).astype(dtype),
        }
    if cfg.family == "vlm":
        params["img_proj"] = dense_init(keys[6], cfg.img_embed_dim,
                                        cfg.d_model, None, dtype)
    return params


# ---------------------------------------------------------------------------
# stack execution
# ---------------------------------------------------------------------------

def _remat(cfg, body):
    """Apply the configured activation-checkpoint policy (§Perf lever)."""
    if not cfg.remat or getattr(cfg, "remat_policy", "full") == "none":
        return body
    if getattr(cfg, "remat_policy", "full") == "dots":
        return jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(body)


def _run_segment(cfg, seg: Segment, seg_params, x, ctx, shared_params=None):
    """Scan a stacked segment over the sequence activations."""
    if seg.kind == "shared_attn":
        x, (aux, kv) = _layer_apply(cfg, "attn", shared_params, x, ctx)
        return x, aux, kv

    def body(h, p_l):
        h2, (aux, kv) = _layer_apply(cfg, seg.kind, p_l, h, ctx)
        return h2, (aux, kv)

    body = _remat(cfg, body)
    x, (auxs, kvs) = maybe_scan(body, x, seg_params)
    return x, jnp.sum(auxs), kvs


def _apply_stack(params, cfg: ArchConfig, x, ctx):
    """Returns (x, aux_total, caches_struct or None)."""
    prog = cfg.program()
    want = ctx.get("want_cache", False)
    aux_total = jnp.zeros((), jnp.float32)
    caches = {"segments": [], "tail": []} if want else None

    if prog.repeats == 1:
        for seg, sp in zip(prog.segments, params["segments"]):
            shared = sp if seg.kind == "shared_attn" else None
            x, aux, kv = _run_segment(cfg, seg, sp, x, ctx, shared)
            aux_total += aux
            if want:
                caches["segments"].append(kv)
    else:
        shared_idx = {i for i, s in enumerate(prog.segments)
                      if s.kind == "shared_attn"}

        def group_body(h, rep_params):
            aux_g = jnp.zeros((), jnp.float32)
            kvs = []
            for i, seg in enumerate(prog.segments):
                sp = (params["segments"][i] if i in shared_idx
                      else rep_params[i])
                shared = sp if i in shared_idx else None
                h, aux, kv = _run_segment(cfg, seg, sp, h, ctx, shared)
                aux_g += aux
                kvs.append(kv)
            return h, (aux_g, kvs)

        rep_stack = [None if i in shared_idx else params["segments"][i]
                     for i in range(len(prog.segments))]
        x, (auxs, kvs) = maybe_scan(group_body, x, rep_stack)
        aux_total += jnp.sum(auxs)
        if want:
            caches["segments"] = kvs

    for seg, sp in zip(prog.tail, params["tail"]):
        x, aux, kv = _run_segment(cfg, seg, sp, x, ctx,
                                  sp if seg.kind == "shared_attn" else None)
        aux_total += aux
        if want:
            caches["tail"].append(kv)
    return x, aux_total, caches


# ---------------------------------------------------------------------------
# embeddings / heads
# ---------------------------------------------------------------------------

def _embed(params, cfg: ArchConfig, tokens, extra):
    x = params["embed"][tokens]
    if getattr(cfg, "embed_scale", False) or cfg.family == "vlm":
        x = x * math.sqrt(cfg.d_model)
    if cfg.family == "vlm":
        img = extra["img"].astype(x.dtype) @ params["img_proj"]
        x = jnp.concatenate([img, x], axis=1)
    return shard(x, "batch", None, None)


def _logits(params, cfg: ArchConfig, x, mask_pad: bool = False):
    x = norm_apply(cfg.norm, x, params["final_norm"])
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"])
    logits = x @ head
    if getattr(cfg, "logits_dtype", "float32") == "bfloat16":
        logits = logits.astype(jnp.bfloat16)
    logits = shard(logits, "batch", None, "vocab")
    if mask_pad and logits.shape[-1] != cfg.vocab:
        vidx = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                        logits.ndim - 1)
        logits = jnp.where(vidx < cfg.vocab, logits, -jnp.inf)
    return logits


def _encode(params, cfg: ArchConfig, audio):
    """Whisper encoder over stub frame embeddings [B, Ta, d]."""
    x = audio.astype(jnp.dtype(cfg.dtype)) + params["enc"]["pos_embed"]
    acfg = dataclasses.replace(_attn_cfg(cfg, "attn"), causal=False,
                               window=None)

    def body(h, p_l):
        a = A.attention_forward(p_l["attn"], acfg,
                                norm_apply(cfg.norm, h, p_l["ln1"]))
        h = h + a
        m = mlp_apply(p_l["mlp"], norm_apply(cfg.norm, h, p_l["ln2"]),
                      cfg.activation)
        return h + m, None

    body = _remat(cfg, body)
    x, _ = maybe_scan(body, x, params["enc"]["layers"])
    return norm_apply(cfg.norm, x, params["enc"]["norm"])


def _enc_kv(cfg, dec_params_xattn, enc_out):
    """Project encoder output to (k, v) for one decoder layer."""
    b, t, _ = enc_out.shape
    k = (enc_out @ dec_params_xattn["wk"]).reshape(b, t, cfg.n_kv,
                                                   cfg.head_dim)
    v = (enc_out @ dec_params_xattn["wv"]).reshape(b, t, cfg.n_kv,
                                                   cfg.head_dim)
    if cfg.qkv_bias:
        k = k + dec_params_xattn["bk"].reshape(cfg.n_kv, cfg.head_dim)
        v = v + dec_params_xattn["bv"].reshape(cfg.n_kv, cfg.head_dim)
    return k, v


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------

def forward(params, cfg: ArchConfig, tokens, extra=None):
    """Training/prefill logits over a full sequence."""
    extra = extra or {}
    x = _embed(params, cfg, tokens, extra)
    ctx = {"positions": jnp.arange(x.shape[1])}
    if cfg.family == "vlm":
        ctx["prefix"] = cfg.img_tokens
    if cfg.family == "encdec":
        ctx["enc_kv"] = None  # per-layer, see below

    if cfg.family == "encdec":
        enc_out = _encode(params, cfg, extra["audio"])
        # cross-attn kv differs per layer; fold enc_out through ctx and let
        # each layer project it (cheap: Ta x d @ d x kv_dim inside scan).
        ctx["enc_out"] = enc_out
        x, aux, _ = _apply_stack_encdec(params, cfg, x, ctx)
    else:
        x, aux, _ = _apply_stack(params, cfg, x, ctx)
    return _logits(params, cfg, x), aux


def _apply_stack_encdec(params, cfg, x, ctx):
    enc_out = ctx["enc_out"]
    want = ctx.get("want_cache", False)

    def body(h, p_l):
        ctx_l = dict(ctx)
        enc_kv = _enc_kv(cfg, p_l["xattn"], enc_out)
        ctx_l["enc_kv"] = enc_kv
        h2, (aux, kv) = _layer_apply(cfg, "attn", p_l, h, ctx_l)
        out_kv = (kv, enc_kv) if want else None
        return h2, (aux, out_kv)

    body = _remat(cfg, body)
    x, (auxs, kvs) = maybe_scan(body, x, params["segments"][0])
    caches = {"segments": [kvs], "tail": []} if want else None
    return x, jnp.sum(auxs), caches


def loss_fn(params, cfg: ArchConfig, batch):
    """batch: {'tokens': [B, T+1] int32, optional 'audio'/'img'}."""
    tokens = batch["tokens"]
    inp, labels = tokens[:, :-1], tokens[:, 1:]
    extra = {k: batch[k] for k in ("audio", "img") if k in batch}
    logits, aux = forward(params, cfg, inp, extra)
    if cfg.family == "vlm":   # image prefix positions produce no loss
        logits = logits[:, cfg.img_tokens:]
    ce = cross_entropy(logits, labels, true_vocab=cfg.vocab)
    return ce + aux, {"ce": ce, "aux": aux}


# -- serving ------------------------------------------------------------------

def init_caches(cfg: ArchConfig, batch: int, max_seq: int,
                dtype=jnp.bfloat16):
    """Cache pytree matching the layer program structure."""
    prog = cfg.program()

    def seg_cache(seg: Segment, stacked_reps: bool):
        # build [n, ...] stacks (and [R, n, ...] when grouped); the shared
        # attention block still gets one cache per application ([R, ...]).
        base = _layer_cache_init(cfg, seg.kind, batch, max_seq, dtype)
        c = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (seg.n,) + a.shape).copy()
            if seg.kind != "shared_attn" else a, base)
        if stacked_reps:
            c = jax.tree.map(
                lambda a: jnp.broadcast_to(
                    a, (prog.repeats,) + a.shape).copy(), c)
        return c

    grouped = prog.repeats > 1
    return {
        "segments": [seg_cache(s, grouped) for s in prog.segments],
        "tail": [seg_cache(s, False) for s in prog.tail],
        "index": jnp.zeros((), jnp.int32),
    }


def prefill(params, cfg: ArchConfig, tokens, extra=None,
            cache_dtype=jnp.bfloat16, max_seq: Optional[int] = None):
    """Run the full prompt, returning (last_token_logits, caches)."""
    extra = extra or {}
    b, t = tokens.shape
    total_t = t + (cfg.img_tokens if cfg.family == "vlm" else 0)
    max_seq = max(max_seq or total_t, total_t)
    x = _embed(params, cfg, tokens, extra)
    ctx = {"positions": jnp.arange(total_t), "want_cache": True}
    if cfg.family == "vlm":
        ctx["prefix"] = cfg.img_tokens
    if cfg.family == "encdec":
        ctx["enc_out"] = _encode(params, cfg, extra["audio"])
        x, _, kv_raw = _apply_stack_encdec(params, cfg, x, ctx)
    else:
        x, _, kv_raw = _apply_stack(params, cfg, x, ctx)

    caches = init_caches(cfg, b, max_seq, cache_dtype)
    caches = _write_prefill_caches(cfg, caches, kv_raw, total_t)
    caches["index"] = jnp.asarray(total_t, jnp.int32)
    logits = _logits(params, cfg, x[:, -1:, :], mask_pad=True)
    return logits[:, 0], caches


def _write_prefill_caches(cfg, caches, kv_raw, t):
    """Map per-layer (k, v) / ssm states from the forward scan into cache
    structures (ring truncation handled by fill_cache)."""
    prog = cfg.program()

    def write_one(cache_leaf_struct, kv, kind):
        if kind == "mamba":
            conv, ssd = kv
            return {"conv": conv.astype(cache_leaf_struct["conv"].dtype),
                    "ssd": ssd}
        if cfg.family == "encdec":
            (k, v), (kx, vx) = kv
            filled = A.fill_cache(cache_leaf_struct["self"], k, v, 0)
            cross = A.fill_cache(cache_leaf_struct["cross"], kx, vx, 0)
            return {"self": filled, "cross": cross}
        k, v = kv
        return A.fill_cache(cache_leaf_struct, k, v, 0)

    out_segments = []
    for i, seg in enumerate(prog.segments):
        kv = kv_raw["segments"][i]
        cache_seg = caches["segments"][i]
        if kv is None:
            out_segments.append(cache_seg)
            continue
        fn = functools.partial(write_one, kind=seg.kind)
        if seg.kind == "shared_attn":
            # unstacked params; caches stack only over repeats (if grouped)
            out_segments.append(jax.vmap(fn)(cache_seg, kv)
                                if prog.repeats > 1 else fn(cache_seg, kv))
        elif prog.repeats > 1:
            out_segments.append(jax.vmap(jax.vmap(fn))(cache_seg, kv))
        else:
            out_segments.append(jax.vmap(fn)(cache_seg, kv))
    out_tail = []
    for i, seg in enumerate(prog.tail):
        kv = kv_raw["tail"][i]
        fn = functools.partial(write_one, kind=seg.kind)
        out_tail.append(jax.vmap(fn)(caches["tail"][i], kv))
    return {"segments": out_segments, "tail": out_tail,
            "index": caches["index"]}


def decode_step(params, cfg: ArchConfig, caches, token, index=None):
    """token: [B] int32 -> (logits [B, V], new caches)."""
    index = caches["index"] if index is None else index
    prog = cfg.program()
    x = params["embed"][token][:, None, :]
    if getattr(cfg, "embed_scale", False) or cfg.family == "vlm":
        x = x * math.sqrt(cfg.d_model)
    x = shard(x, "batch", None, None)
    ctx = {"index": index}

    new_segments = []

    def run_seg_decode(seg, sp, cache_seg, h):
        def body(hh, inp):
            p_l, c_l = inp
            h2, c2 = _layer_decode(cfg, seg.kind, p_l, hh, c_l, ctx)
            return h2, c2
        h, new_c = maybe_scan(body, h, (sp, cache_seg))
        return h, new_c

    if prog.repeats == 1:
        for seg, sp, cs in zip(prog.segments, params["segments"],
                               caches["segments"]):
            if seg.kind == "shared_attn":
                x, new_c = _layer_decode(cfg, "attn", sp, x, cs, ctx)
            else:
                x, new_c = run_seg_decode(seg, sp, cs, x)
            new_segments.append(new_c)
    else:
        shared_idx = {i for i, s in enumerate(prog.segments)
                      if s.kind == "shared_attn"}

        def group_body(h, inp):
            rep_params, rep_caches = inp
            new_cs = []
            for i, seg in enumerate(prog.segments):
                if i in shared_idx:
                    h, c2 = _layer_decode(cfg, "attn",
                                          params["segments"][i], h,
                                          rep_caches[i], ctx)
                else:
                    h, c2 = run_seg_decode(seg, rep_params[i],
                                           rep_caches[i], h)
                new_cs.append(c2)
            return h, new_cs

        rep_stack = [None if i in shared_idx else params["segments"][i]
                     for i in range(len(prog.segments))]
        x, new_segments = maybe_scan(group_body, x,
                                     (rep_stack, caches["segments"]))

    new_tail = []
    for seg, sp, cs in zip(prog.tail, params["tail"], caches["tail"]):
        x, new_c = run_seg_decode(seg, sp, cs, x)
        new_tail.append(new_c)

    logits = _logits(params, cfg, x, mask_pad=True)[:, 0]
    new_caches = {"segments": new_segments, "tail": new_tail,
                  "index": index + 1}
    return logits, new_caches


# ---------------------------------------------------------------------------
# accounting
# ---------------------------------------------------------------------------

def count_params(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params)
               if hasattr(x, "size"))


def model_flops_per_token(cfg: ArchConfig, n_params: int,
                          n_active: Optional[int] = None) -> float:
    """6*N*D convention (N = active params for MoE)."""
    n = n_active if n_active is not None else n_params
    return 6.0 * n
