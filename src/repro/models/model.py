"""Model facade: bind an ArchConfig to pure step functions + input specs.

`input_specs(cfg, shape)` returns jax.ShapeDtypeStruct stand-ins for every
input of the step the shape cell lowers (train_step / prefill_step /
serve_step), so the multi-pod dry-run can `.lower().compile()` without
allocating anything.  Modality frontends are stubs per the brief: audio
enters as precomputed frame embeddings, images as patch embeddings.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import transformer as T

__all__ = ["Model", "build", "input_specs", "cache_specs"]


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig

    def init(self, key: jax.Array):
        return T.init_params(self.cfg, key)

    def loss(self, params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        return T.loss_fn(params, self.cfg, batch)

    def forward(self, params, tokens, extra=None):
        return T.forward(params, self.cfg, tokens, extra)

    def prefill(self, params, tokens, extra=None, max_seq=None):
        return T.prefill(params, self.cfg, tokens, extra, max_seq=max_seq)

    def decode_step(self, params, caches, token):
        return T.decode_step(params, self.cfg, caches, token)

    def init_caches(self, batch: int, max_seq: int, dtype=jnp.bfloat16):
        return T.init_caches(self.cfg, batch, max_seq, dtype)

    def count_params(self, params) -> int:
        return T.count_params(params)


def build(cfg: ArchConfig) -> Model:
    return Model(cfg)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStructs for the batch of the step this cell lowers."""
    b, t = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        batch: Dict[str, Any] = {"tokens": _sds((b, t + 1), jnp.int32)}
        if cfg.family == "encdec":
            batch["audio"] = _sds((b, cfg.enc_seq, cfg.d_model),
                                  jnp.float32)
        if cfg.family == "vlm":
            # image tokens take img_tokens of the sequence budget
            batch["tokens"] = _sds((b, t - cfg.img_tokens + 1), jnp.int32)
            batch["img"] = _sds((b, cfg.img_tokens, cfg.img_embed_dim),
                                jnp.float32)
        return {"batch": batch}
    if shape.kind == "prefill":
        out: Dict[str, Any] = {"tokens": _sds((b, t), jnp.int32)}
        if cfg.family == "encdec":
            out["audio"] = _sds((b, cfg.enc_seq, cfg.d_model), jnp.float32)
        if cfg.family == "vlm":
            out["tokens"] = _sds((b, t - cfg.img_tokens), jnp.int32)
            out["img"] = _sds((b, cfg.img_tokens, cfg.img_embed_dim),
                              jnp.float32)
        return out
    # decode / long: one new token against a seq_len cache
    return {
        "token": _sds((b,), jnp.int32),
        "caches": cache_specs(cfg, b, t),
    }


def cache_specs(cfg: ArchConfig, batch: int, max_seq: int,
                dtype=jnp.bfloat16):
    """ShapeDtypeStruct pytree mirroring init_caches (no allocation)."""
    caches = jax.eval_shape(
        lambda: T.init_caches(cfg, batch, max_seq, dtype))
    return caches


def param_specs(cfg: ArchConfig):
    return jax.eval_shape(
        lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
