"""Fault tolerance: heartbeats, failure detection, elastic remesh planning.

At 1000+ nodes, node loss is routine; the control plane here provides the
three pieces a JAX training job needs (the data plane — checkpoint/restart,
deterministic data resharding — lives in repro.checkpoint / repro.data):

  * HeartbeatMonitor   — per-host liveness with configurable timeout.
  * FailureDetector    — turns missed heartbeats / NaN watchdogs into
                         actionable FailureEvents.
  * ElasticPlanner     — given surviving hosts, picks the largest valid
                         (pod, data, model) mesh factorization <= survivors,
                         maps old shard coordinates to new ones, and emits a
                         RemeshPlan (which checkpoint to restore, which data
                         shards each host now owns).

Everything is pure-python and unit-testable on CPU; interfaces take host ids
and device counts, not concrete backends, so the same planner drives a real
multi-host restart (launcher re-execs with the planned topology).
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = ["HeartbeatMonitor", "FailureEvent", "FailureDetector",
           "RemeshPlan", "ElasticPlanner"]


class HeartbeatMonitor:
    """Tracks last-seen timestamps per host."""

    def __init__(self, hosts: Sequence[str], timeout_s: float = 60.0,
                 clock=time.monotonic):
        self.timeout_s = timeout_s
        self._clock = clock
        now = clock()
        self._last: Dict[str, float] = {h: now for h in hosts}

    def beat(self, host: str, at: Optional[float] = None) -> None:
        self._last[host] = self._clock() if at is None else at

    def dead(self, now: Optional[float] = None) -> List[str]:
        now = self._clock() if now is None else now
        return sorted(h for h, t in self._last.items()
                      if now - t > self.timeout_s)

    def alive(self, now: Optional[float] = None) -> List[str]:
        now = self._clock() if now is None else now
        return sorted(h for h, t in self._last.items()
                      if now - t <= self.timeout_s)


@dataclasses.dataclass(frozen=True)
class FailureEvent:
    kind: str          # 'host_lost' | 'nan' | 'straggler'
    host: Optional[str]
    step: int
    detail: str = ""


class FailureDetector:
    """Fuses liveness + numeric watchdogs into failure events."""

    def __init__(self, monitor: HeartbeatMonitor):
        self.monitor = monitor
        self._reported: set = set()

    def poll(self, step: int) -> List[FailureEvent]:
        events = []
        for h in self.monitor.dead():
            if h not in self._reported:
                self._reported.add(h)
                events.append(FailureEvent("host_lost", h, step,
                                           "heartbeat timeout"))
        return events

    def report_nan(self, step: int, what: str) -> FailureEvent:
        # NaN containment mirrors the paper's overflow guard (§2): the
        # training loop rolls back to the last checkpoint with a lowered
        # conductance/lr scale rather than propagating poison.
        return FailureEvent("nan", None, step, what)


@dataclasses.dataclass(frozen=True)
class RemeshPlan:
    mesh_shape: Tuple[int, ...]
    mesh_axes: Tuple[str, ...]
    hosts: Tuple[str, ...]            # surviving hosts, mesh order
    restore_step: Optional[int]
    data_shard_of_host: Dict[str, int]
    dropped_hosts: Tuple[str, ...]

    @property
    def n_devices(self) -> int:
        return math.prod(self.mesh_shape)


class ElasticPlanner:
    """Plans the post-failure topology.

    Constraints: model-parallel width is fixed (weights are laid out for
    it); the data(+pod) extent shrinks to the largest multiple the
    survivors support.  Batch is kept constant by raising per-shard batch
    (synchronous semantics preserved; throughput degrades gracefully).
    """

    def __init__(self, devices_per_host: int, model_parallel: int,
                 global_batch: int):
        self.devices_per_host = devices_per_host
        self.model_parallel = model_parallel
        self.global_batch = global_batch

    def plan(self, alive_hosts: Sequence[str], dead_hosts: Sequence[str],
             restore_step: Optional[int]) -> RemeshPlan:
        alive = sorted(alive_hosts)
        total_dev = len(alive) * self.devices_per_host
        mp = self.model_parallel
        if total_dev < mp:
            raise RuntimeError(
                f"survivors ({total_dev} devices) below model-parallel "
                f"width {mp}")
        data = total_dev // mp
        # keep data extent a divisor of the global batch so per-shard batch
        # stays integral
        while data > 1 and self.global_batch % data:
            data -= 1
        used_hosts = (data * mp + self.devices_per_host - 1) \
            // self.devices_per_host
        hosts = tuple(alive[:used_hosts])
        shards = {h: i % data for i, h in enumerate(hosts)}
        return RemeshPlan(
            mesh_shape=(data, mp), mesh_axes=("data", "model"),
            hosts=hosts, restore_step=restore_step,
            data_shard_of_host=shards, dropped_hosts=tuple(sorted(
                dead_hosts)))
