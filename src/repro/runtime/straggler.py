"""Straggler mitigation.

Synchronous SPMD steps move at the pace of the slowest host, so persistent
stragglers are a throughput failure even when nothing crashes.  Detection is
percentile-based over a sliding window of per-host step times; mitigation is
tiered:

  1. observe    — mark host; keep synchronous semantics.
  2. rebalance  — hand a fraction of the straggler's data shard to the
                  fastest hosts (deterministic: repro.data keys on global
                  row, so reassignment is a pure index remap).
  3. evict      — treat as failed; hand to ElasticPlanner.

The policy is deliberately deterministic and unit-testable: feed step-time
observations, read back directives.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict, deque
from typing import Deque, Dict, List, Optional

__all__ = ["StragglerPolicy", "Directive"]


@dataclasses.dataclass(frozen=True)
class Directive:
    host: str
    action: str          # 'observe' | 'rebalance' | 'evict'
    ratio: float = 0.0   # fraction of its shard to move (rebalance)
    detail: str = ""


class StragglerPolicy:
    def __init__(self, window: int = 20, slow_factor: float = 1.5,
                 evict_factor: float = 3.0, min_observations: int = 5):
        self.window = window
        self.slow_factor = slow_factor
        self.evict_factor = evict_factor
        self.min_observations = min_observations
        self._times: Dict[str, Deque[float]] = defaultdict(
            lambda: deque(maxlen=window))

    def observe(self, host: str, step_time_s: float) -> None:
        self._times[host].append(step_time_s)

    def _median_of_medians(self) -> Optional[float]:
        meds = []
        for q in self._times.values():
            if len(q) >= self.min_observations:
                s = sorted(q)
                meds.append(s[len(s) // 2])
        if not meds:
            return None
        meds.sort()
        return meds[len(meds) // 2]

    def directives(self) -> List[Directive]:
        base = self._median_of_medians()
        if base is None or base <= 0:
            return []
        out: List[Directive] = []
        for host, q in sorted(self._times.items()):
            if len(q) < self.min_observations:
                continue
            s = sorted(q)
            med = s[len(s) // 2]
            r = med / base
            if r >= self.evict_factor:
                out.append(Directive(host, "evict",
                                     detail=f"{r:.2f}x median"))
            elif r >= self.slow_factor:
                # shed work proportional to the slowdown
                ratio = min(0.5, 1.0 - 1.0 / r)
                out.append(Directive(host, "rebalance", ratio=ratio,
                                     detail=f"{r:.2f}x median"))
        return out
