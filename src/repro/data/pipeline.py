"""Deterministic, shard-aware, resumable data pipeline.

Production posture: every host draws only its shard of the global batch, any
(step, host) pair is reproducible from (seed, step) alone — no filesystem
state — so restarts and *elastic reshards* (a host taking over another's
shard after failure) are exact.  The synthetic token stream is a stand-in for
a tokenized corpus reader with identical interface; `state()`/`restore()`
carry the cursor through checkpoints.

Stream construction: per-(step, shard) counters feed threefry; documents are
Zipf-ish token draws with structure (BOS/EOS segmenting) so losses are not
degenerate-uniform.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["DataConfig", "TokenPipeline"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    bos_id: int = 1
    eos_id: int = 2
    mean_doc_len: int = 512
    zipf_a: float = 1.2


class TokenPipeline:
    """Iterator of {'tokens': [local_batch, seq_len+1]} batches."""

    def __init__(self, cfg: DataConfig, shard_index: int = 0,
                 num_shards: int = 1, start_step: int = 0):
        if cfg.global_batch % num_shards:
            raise ValueError(
                f"global_batch {cfg.global_batch} % shards {num_shards}")
        self.cfg = cfg
        self.shard_index = shard_index
        self.num_shards = num_shards
        self.step = start_step
        self._local = cfg.global_batch // num_shards
        # Zipf-ish unigram distribution over the vocab (stable across runs)
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        p = 1.0 / ranks ** cfg.zipf_a
        p[cfg.bos_id] = 0.0
        p[cfg.eos_id] = 0.0
        self._probs = (p / p.sum()).astype(np.float64)

    # -- resumability -------------------------------------------------------
    def state(self) -> Dict[str, int]:
        return {"step": self.step, "shard_index": self.shard_index,
                "num_shards": self.num_shards, "seed": self.cfg.seed}

    @classmethod
    def restore(cls, cfg: DataConfig, state: Dict[str, int],
                shard_index: Optional[int] = None,
                num_shards: Optional[int] = None) -> "TokenPipeline":
        """Re-create at a checkpointed cursor; shard layout may change
        (elastic rescale) because draws key on (seed, step, global row)."""
        return cls(cfg,
                   shard_index=(state["shard_index"] if shard_index is None
                                else shard_index),
                   num_shards=(state["num_shards"] if num_shards is None
                               else num_shards),
                   start_step=state["step"])

    # -- generation ---------------------------------------------------------
    def _row(self, step: int, global_row: int) -> np.ndarray:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, global_row]))
        out = np.empty(cfg.seq_len + 1, np.int64)
        i = 0
        while i < out.size:
            doc_len = max(8, int(rng.exponential(cfg.mean_doc_len)))
            n = min(doc_len, out.size - i)
            out[i] = cfg.bos_id
            if n > 1:
                body = rng.choice(cfg.vocab, size=n - 1, p=self._probs)
                # inject local structure: repeat previous token sometimes
                rep = rng.random(n - 1) < 0.15
                body[1:][rep[1:]] = body[:-1][rep[1:]]
                out[i + 1: i + n] = body
            i += n
            if i < out.size:
                out[i - 1] = cfg.eos_id
        return out.astype(np.int32)

    def next_batch(self) -> Dict[str, jnp.ndarray]:
        rows = []
        base = self.shard_index * self._local
        for r in range(self._local):
            rows.append(self._row(self.step, base + r))
        self.step += 1
        return {"tokens": jnp.asarray(np.stack(rows))}

    def __iter__(self) -> Iterator[Dict[str, jnp.ndarray]]:
        while True:
            yield self.next_batch()
