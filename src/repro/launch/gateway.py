"""SNN serving gateway: the async front door in front of CompiledModel.serve.

The streaming server (launch/snn_serve.py) is a tight loop over one model
with a fixed slot table: fine for a benchmark, not for traffic.  The paper's
premise is *sustained* throughput — keep the device saturated — and at the
orchestration layer that is won or lost in four places this module owns:

  1. **Admission control / backpressure.**  Each model has a bounded
     admission queue; a submit against a full queue raises
     :class:`GatewayOverloaded` carrying a ``retry_after_s`` estimate
     (HTTP front door: 429 + Retry-After) instead of growing an unbounded
     backlog that pushes every request past its deadline.
  2. **Deadlines.**  Requests carry ``deadline_ms``; at every chunk
     boundary the gateway sweeps queued *and* in-flight requests past
     their deadline and evicts them — a mid-flight eviction reclaims the
     slot immediately (the lane is masked until re-admission), and the
     client gets whatever chunks were already streamed.  Surviving
     streams are bit-exact vs. their offline run: eviction and slot
     re-packing only ever gather state along the stream axis
     (CompiledModel.select_streams), never touch it.
  3. **Elastic capacity.**  Slot tables come in a small set of
     pre-compiled ``max_streams`` buckets (e.g. 4/8/16).  The gateway
     grows to the smallest bucket covering current demand immediately and
     shrinks after ``shrink_patience`` consecutive underloaded chunks —
     resizes happen between chunks via a device-local gather, so there is
     no recompile stall (every bucket's serve program was warmed at
     registration) and no state copy through the host.
  4. **Multi-model slots.**  One gateway process serves any number of
     registered models (mushroom body + izhikevich, say), each with its
     own worker/slot table, advanced round-robin by ``tick()`` — the slot
     scheduler underneath is the same one driving the transformer server.

Observability: per-model p50/p99 queue wait, per-step serve latency and
end-to-end latency, slot occupancy, rejection/eviction/completion
counters — as a dict (:meth:`Gateway.metrics`) and a Prometheus-style
text snapshot (:meth:`Gateway.render_metrics`, the HTTP ``/metrics``
endpoint).  benchmarks/gateway_soak.py drives thousands of streams
through this and gates flat p99 per-step latency in CI.

Demo CLI (two models, mixed priorities, deadlines tight enough to evict):

  PYTHONPATH=src python -m repro.launch.gateway --requests 48 \
      --deadline-ms 2000 --buckets 4,8

Async HTTP front door: launch/gateway_http.py (stdlib asyncio only).
"""

from __future__ import annotations

import argparse
import collections
import dataclasses
import itertools
import threading
import time
from typing import Dict, List, Mapping, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.scheduling import SlotScheduler
from repro.launch.snn_serve import SNNServer, StreamRequest
from repro.obs import profile as obs_profile
# LatencyWindow moved to repro.obs.telemetry (PR 7); re-exported here for
# existing importers — the soak driver and dashboards see the same class.
from repro.obs.telemetry import LatencyWindow, PromText

__all__ = ["Gateway", "GatewayRequest", "GatewayOverloaded",
           "GatewayWorker", "LatencyWindow"]


class GatewayOverloaded(RuntimeError):
    """Raised by submit when a model's admission queue is full.

    ``retry_after_s`` estimates when capacity frees up: pending work in
    chunks times the recent chunk wall time (EMA).  Clients (and the HTTP
    layer's Retry-After header) should back off at least that long.
    """

    def __init__(self, model: str, queued: int, retry_after_s: float):
        super().__init__(
            f"admission queue full for model {model!r} ({queued} queued); "
            f"retry in {retry_after_s:.2f}s")
        self.model = model
        self.queued = queued
        self.retry_after_s = retry_after_s


@dataclasses.dataclass
class GatewayRequest(StreamRequest):
    """A StreamRequest with gateway semantics: priority class, deadline,
    and a lifecycle the client can wait on.

    status: queued -> active -> done | evicted.  An evicted request keeps
    every chunk streamed before its deadline (partial results); ``done``
    stays False.  ``wait`` blocks until the request leaves the gateway
    either way.
    """

    model: str = ""
    priority: int = 0                       # lower runs first
    deadline_ms: Optional[float] = None     # relative to submit
    deadline_at: Optional[float] = None     # absolute clock() time
    status: str = "queued"
    _done_evt: threading.Event = dataclasses.field(
        default_factory=threading.Event, repr=False)

    @property
    def evicted(self) -> bool:
        return self.status == "evicted"

    @property
    def steps_served(self) -> int:
        return sum(c.n_steps for c in self.chunks)

    def wait(self, timeout: Optional[float] = None) -> bool:
        """Block until the request completes or is evicted; True when it
        left the gateway within the timeout."""
        return self._done_evt.wait(timeout)

    def _finish(self, status: str) -> None:
        self.status = status
        self._done_evt.set()


class GatewayWorker(SNNServer):
    """One model's elastic slot table inside the gateway.

    Extends the streaming server with the gateway lifecycle: bounded
    admission, deadline sweeps at chunk boundaries, elastic bucket
    resizing (via CompiledModel.select_streams), and SLO accounting.
    Everything the plain server guarantees still holds — admitted lanes
    advance through the identical serve_chunk program, so a stream that is
    never evicted is bit-exact vs. its offline run regardless of how many
    neighbours got evicted or how often the table resized around it.
    """

    def __init__(self, name: str, model, buckets: Sequence[int] = (4, 8),
                 chunk: int = 50, stim_pops: Optional[Sequence[str]] = None,
                 gscales: Optional[Mapping[str, jax.Array]] = None,
                 record_raster: bool = False, max_queue: int = 64,
                 shrink_patience: int = 3, clock=time.monotonic,
                 warm: bool = True):
        buckets = tuple(sorted(set(int(b) for b in buckets)))
        if not buckets or buckets[0] <= 0:
            raise ValueError(f"buckets must be positive ints, got {buckets}")
        if max_queue <= 0:
            raise ValueError(f"max_queue must be positive, got {max_queue}")
        super().__init__(model, max_streams=buckets[0], chunk=chunk,
                         stim_pops=stim_pops, gscales=gscales,
                         record_raster=record_raster)
        self.name = name
        self.buckets = buckets
        self.max_queue = int(max_queue)
        self.shrink_patience = int(shrink_patience)
        self.clock = clock
        self.sched = SlotScheduler(buckets[0], clock=clock)
        self._shrink_ticks = 0
        # -- SLO accounting ------------------------------------------------
        self.counters = collections.Counter(
            submitted=0, rejected=0, completed=0,
            evicted_queued=0, evicted_active=0, grows=0, shrinks=0)
        self.queue_wait_s = LatencyWindow()
        self.step_latency_us = LatencyWindow()
        self.total_latency_s = LatencyWindow()
        self._ema_chunk_s: Optional[float] = None
        if warm:
            self.warm_buckets()

    # -- pre-compilation ---------------------------------------------------
    def warm_buckets(self) -> None:
        """Compile every bucket's serve program (and the inter-bucket
        resize gathers) up front, so elastic grow/shrink at traffic time
        is a cached-executable call, not a recompile stall."""
        states = {}
        for b in self.buckets:
            keys = jnp.stack([jax.random.PRNGKey(0)] * b)
            st = self.model.init_stream_state(keys)
            stim = {p: np.zeros((b, self.chunk, n), np.float32)
                    for p, n in self._pop_n.items()}
            st, *_ = self.model.serve_chunk(
                st, stim, np.zeros(b, np.int32), self.chunk,
                gscales=self.gscales, record_raster=self.record_raster)
            states[b] = st
        for b_from in self.buckets:            # resize gathers, both ways
            for b_to in self.buckets:
                if b_from == b_to:
                    continue
                keys = jnp.stack([jax.random.PRNGKey(0)] * b_to)
                idx = np.full(b_to, -1, np.int32)
                idx[: min(b_from, b_to)] = np.arange(min(b_from, b_to))
                self.model.select_streams(states[b_from], idx, keys)

    # -- admission control -------------------------------------------------
    def retry_after_s(self) -> float:
        """Backoff hint for rejected submits: pending chunks of work times
        the recent chunk wall time (coarse but monotone in backlog)."""
        ema = self._ema_chunk_s if self._ema_chunk_s else 0.05
        pending = len(self.sched.queue) + len(self.sched.active)
        chunks_ahead = 1 + pending / max(1, self.max_streams)
        return ema * chunks_ahead

    def submit(self, req: GatewayRequest) -> GatewayRequest:
        if len(self.sched.queue) >= self.max_queue:
            self.counters["rejected"] += 1
            raise GatewayOverloaded(self.name, len(self.sched.queue),
                                    self.retry_after_s())
        if req.deadline_ms is not None and req.deadline_at is None:
            req.deadline_at = self.clock() + req.deadline_ms / 1e3
        super().submit(req)             # validation + priority-FIFO enqueue
        self.counters["submitted"] += 1
        return req

    # -- chunk-boundary lifecycle -------------------------------------------
    def _sweep_deadlines(self, now: Optional[float] = None) -> List:
        """Evict every queued/in-flight request past its deadline; their
        slots are immediately reclaimable (lanes without an active request
        are masked to exact no-ops, so survivors never notice)."""
        if now is None:
            now = self.clock()
        evicted = []
        for req in self.sched.expired(now):
            was_active = any(r.rid == req.rid
                             for r in self.sched.active.values())
            if self.sched.evict(req.rid) is None:
                continue                 # raced with completion: no-op
            self.counters["evicted_active" if was_active
                          else "evicted_queued"] += 1
            req._finish("evicted")
            evicted.append(req)
        return evicted

    def _target_bucket(self) -> int:
        demand = len(self.sched.active) + len(self.sched.queue)
        for b in self.buckets:
            if b >= demand:
                return b
        return self.buckets[-1]

    def _autoscale(self) -> None:
        """Grow immediately under pressure; shrink only after
        ``shrink_patience`` consecutive underloaded chunk boundaries
        (hysteresis — admission bursts should not thrash the table)."""
        target = self._target_bucket()
        if target > self.max_streams:
            self._resize(target)
            self.counters["grows"] += 1
            self._shrink_ticks = 0
        elif target < self.max_streams:
            self._shrink_ticks += 1
            if self._shrink_ticks >= self.shrink_patience:
                self._resize(target)
                self.counters["shrinks"] += 1
                self._shrink_ticks = 0
        else:
            self._shrink_ticks = 0

    def _resize(self, new_size: int) -> None:
        """Move to another pre-compiled bucket between chunks: compact the
        active slots to the low end (scheduler ``move`` + one
        select_streams gather carrying their device state bitwise), then
        resize the slot table.  Never call mid-chunk."""
        actives = sorted(self.sched.active)
        idx = np.full(new_size, -1, np.int32)
        cursor = np.zeros(new_size, np.int64)
        for j, s in enumerate(actives):      # j <= s: destinations are free
            idx[j] = s
            cursor[j] = self._cursor[s]
            if j != s:
                self.sched.move(s, j)
        keys = jnp.stack([jax.random.PRNGKey(0)] * new_size)
        self.states = self.model.select_streams(self.states, idx, keys)
        self.sched.resize(new_size)
        self.max_streams = new_size
        self._cursor = cursor

    def serve_step(self) -> bool:
        """One gateway chunk: sweep deadlines, autoscale, admit, advance,
        account.  Returns True while work remains."""
        self._sweep_deadlines()
        self._autoscale()
        now = self.clock()
        for _, req in self._admit():
            req.status = "active"
            wait = self.sched.timings[req.rid].queue_wait_s
            if wait is not None:
                self.queue_wait_s.add(wait)
        if not self.sched.active:
            return self.sched.has_work()
        for req in self._advance_chunk():
            self.counters["completed"] += 1
            req._finish("done")
            total = self.sched.timings[req.rid].total_s
            if total is not None:
                self.total_latency_s.add(total)
        wall = self.last_chunk_wall_s
        self.step_latency_us.add(wall / self.chunk * 1e6)
        self._ema_chunk_s = (wall if self._ema_chunk_s is None
                             else 0.8 * self._ema_chunk_s + 0.2 * wall)
        return self.sched.has_work()

    # -- reporting ----------------------------------------------------------
    def metrics(self) -> Dict[str, object]:
        occupancy = (self.total_slot_steps / self.total_lane_steps
                     if self.total_lane_steps else 0.0)
        return {
            "model": self.name,
            "bucket": self.max_streams,
            "buckets": list(self.buckets),
            "active": len(self.sched.active),
            "queued": len(self.sched.queue),
            "max_queue": self.max_queue,
            "occupancy": occupancy,
            "chunks": self.total_chunks,
            "slot_steps": self.total_slot_steps,
            "counters": dict(self.counters),
            "queue_wait_s": self.queue_wait_s.summary(),
            "step_latency_us": self.step_latency_us.summary(),
            "total_latency_s": self.total_latency_s.summary(),
        }


class Gateway:
    """Multi-model serving gateway: one worker (elastic slot table) per
    registered model, advanced round-robin; a single front door for
    submits, deadline enforcement, backpressure, and SLO metrics.

    Thread-safe: ``submit``/``tick``/``metrics`` take the gateway lock, so
    an async front end (launch/gateway_http.py) can submit from its event
    loop while a pump thread ticks.  ``GatewayRequest.wait`` blocks
    without the lock.
    """

    def __init__(self, chunk: int = 50, buckets: Sequence[int] = (4, 8),
                 max_queue: int = 64, shrink_patience: int = 3,
                 clock=time.monotonic, warm: bool = True):
        self.chunk = chunk
        self.buckets = tuple(buckets)
        self.max_queue = max_queue
        self.shrink_patience = shrink_patience
        self.clock = clock
        self.warm = warm
        self.workers: Dict[str, GatewayWorker] = {}
        self._rid = itertools.count()
        self._lock = threading.RLock()
        self.started_at = clock()

    # -- registration -------------------------------------------------------
    def register(self, name: str, model, stim_pops=None, buckets=None,
                 chunk=None, max_queue=None, gscales=None,
                 record_raster: bool = False,
                 warm: Optional[bool] = None) -> GatewayWorker:
        """Attach a CompiledModel under ``name`` (per-model overrides fall
        back to the gateway defaults).  Warming compiles every bucket's
        serve program up front — pay it at registration, not mid-traffic."""
        with self._lock:
            if name in self.workers:
                raise ValueError(f"model {name!r} already registered")
            w = GatewayWorker(
                name, model,
                buckets=self.buckets if buckets is None else buckets,
                chunk=self.chunk if chunk is None else chunk,
                stim_pops=stim_pops, gscales=gscales,
                record_raster=record_raster,
                max_queue=self.max_queue if max_queue is None else max_queue,
                shrink_patience=self.shrink_patience, clock=self.clock,
                warm=self.warm if warm is None else warm)
            self.workers[name] = w
            return w

    # -- front door ---------------------------------------------------------
    def submit(self, model: str, stim: Dict[str, np.ndarray], n_steps: int,
               seed: int = 0, priority: int = 0,
               deadline_ms: Optional[float] = None) -> GatewayRequest:
        """Submit one stimulus stream; returns the live GatewayRequest
        (wait() on it, or poll .status).  Raises GatewayOverloaded when the
        model's admission queue is full and KeyError/ValueError for an
        unknown model or malformed stimulus."""
        with self._lock:
            if model not in self.workers:
                raise KeyError(
                    f"unknown model {model!r}; registered: "
                    f"{sorted(self.workers)}")
            req = GatewayRequest(rid=next(self._rid), n_steps=int(n_steps),
                                 stim=stim, seed=int(seed), model=model,
                                 priority=int(priority),
                                 deadline_ms=deadline_ms)
            return self.workers[model].submit(req)

    # -- serving loop --------------------------------------------------------
    def tick(self) -> bool:
        """Advance every model with work by one chunk (round-robin);
        returns True while any worker still has work."""
        with self._lock:
            busy = False
            for w in self.workers.values():
                if w.sched.has_work():
                    busy |= w.serve_step()
            return busy

    def has_work(self) -> bool:
        with self._lock:
            return any(w.sched.has_work() for w in self.workers.values())

    def run_until_drained(self) -> None:
        while self.tick():
            pass

    def collect_finished(self) -> List[GatewayRequest]:
        """Pop every done/evicted request across models (rid order),
        pruning per-request accounting (the bounded-memory contract of
        SNNServer.pop_finished, gateway-wide)."""
        with self._lock:
            out: List[GatewayRequest] = []
            for w in self.workers.values():
                done = [r for r in w.requests.values()
                        if r.done or getattr(r, "evicted", False)]
                for r in done:
                    del w.requests[r.rid]
                    w.sched.forget(r.rid)
                out.extend(done)
            return sorted(out, key=lambda r: r.rid)

    # -- observability -------------------------------------------------------
    def metrics(self) -> Dict[str, object]:
        """Structured metrics snapshot: per-model worker metrics plus
        gateway-wide totals (the JSON twin of /metrics)."""
        with self._lock:
            per_model = {n: w.metrics() for n, w in self.workers.items()}
            totals = collections.Counter()
            for m in per_model.values():
                totals.update(m["counters"])
            return {"uptime_s": self.clock() - self.started_at,
                    "models": per_model, "counters": dict(totals)}

    def render_metrics(self) -> str:
        """Prometheus-style text exposition (the /metrics endpoint):
        counters as ``gateway_<name>_total``, gauges plain, latency
        windows as quantile-labelled gauges in base units (seconds)."""
        m = self.metrics()
        out = PromText()
        out.sample("gateway_uptime_seconds", {}, m["uptime_s"], "{:.3f}")
        for name, wm in sorted(m["models"].items()):
            lab = {"model": name}
            for c, v in sorted(wm["counters"].items()):
                out.sample(f"gateway_{c}_total", lab, v)
            out.sample("gateway_slots", lab, wm["bucket"])
            out.sample("gateway_active_streams", lab, wm["active"])
            out.sample("gateway_queued_streams", lab, wm["queued"])
            out.sample("gateway_slot_occupancy", lab, wm["occupancy"],
                       "{:.4f}")
            out.sample("gateway_chunks_total", lab, wm["chunks"])
            for metric, unit in (("queue_wait_s", 1.0),
                                 ("total_latency_s", 1.0),
                                 ("step_latency_us", 1e-6)):
                base = metric.rsplit("_", 1)[0]
                out.quantiles(f"gateway_{base}_seconds", lab, wm[metric],
                              unit=unit)
        return out.render()


# ---------------------------------------------------------------------------
# demo CLI
# ---------------------------------------------------------------------------

def _demo_models(devices: int):
    from repro.core.models.izhikevich_net import (IzhikevichNetConfig,
                                                  compile_model)
    mesh = None
    if devices:
        from repro.launch.mesh import make_snn_mesh
        mesh = make_snn_mesh(devices)
    izh = compile_model(IzhikevichNetConfig(n_total=200, n_conn=30),
                        mesh=mesh)
    from repro.core.models.mushroom_body import (MushroomBodyConfig,
                                                 compile_model as compile_mb)
    mb = compile_mb(MushroomBodyConfig(n_pn=20, n_lhi=5, n_kc=100, n_dn=20),
                    mesh=mesh)
    return {"izhikevich": (izh, ("exc",), 3.0),
            "mushroom_body": (mb, ("KC",), 1.5)}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="multi-model SNN serving gateway demo")
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--steps", type=int, default=120,
                    help="stimulus length per request (dt steps)")
    ap.add_argument("--chunk", type=int, default=25)
    ap.add_argument("--buckets", default="4,8",
                    help="comma-separated max_streams buckets")
    ap.add_argument("--max-queue", type=int, default=32)
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="per-request deadline (0 = none); tight values "
                         "exercise mid-flight eviction")
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--http", default="",
                    help="host:port — serve the async HTTP front door "
                         "instead of the batch demo")
    ap.add_argument("--trace", default="", metavar="FILE",
                    help="write a Chrome trace_event JSON of build/serve "
                         "spans to FILE on exit (open in chrome://tracing "
                         "or Perfetto)")
    args = ap.parse_args(argv)

    buckets = tuple(int(b) for b in args.buckets.split(","))
    gw = Gateway(chunk=args.chunk, buckets=buckets,
                 max_queue=args.max_queue)
    models = _demo_models(args.devices)
    for name, (model, stim_pops, _) in models.items():
        gw.register(name, model, stim_pops=stim_pops)
        print(f"[gateway] registered {name}: buckets={buckets} "
              f"chunk={args.chunk} max_queue={args.max_queue}")

    if args.http:
        from repro.launch.gateway_http import serve_http
        host, _, port = args.http.rpartition(":")
        serve_http(gw, host or "127.0.0.1", int(port))
        return obs_profile.export_trace_cli(args.trace, "gateway")

    rng = np.random.default_rng(args.seed)
    names = sorted(models)
    reqs, rejected = [], 0
    for i in range(args.requests):
        name = names[i % len(names)]
        model, stim_pops, scale = models[name]
        pops = {p: model.network.populations[p].n for p in stim_pops}
        T = int(rng.integers(args.steps // 2, args.steps + 1))
        stim = {p: (scale * rng.normal(size=(T, n))).astype(np.float32)
                for p, n in pops.items()}
        try:
            reqs.append(gw.submit(name, stim, T, seed=1000 + i,
                                  priority=i % 3,
                                  deadline_ms=args.deadline_ms or None))
        except GatewayOverloaded as e:
            rejected += 1
            print(f"[gateway] request {i} rejected "
                  f"(retry in {e.retry_after_s:.2f}s)")
        if i % 8 == 7:          # burst pattern: let the queue drain a bit
            gw.tick()
    t0 = time.time()
    gw.run_until_drained()
    wall = time.time() - t0
    done = gw.collect_finished()
    completed = sum(1 for r in done if r.status == "done")
    evicted = sum(1 for r in done if r.evicted)
    print(f"[gateway] {completed} completed, {evicted} evicted, "
          f"{rejected} rejected in {wall:.2f}s")
    print(gw.render_metrics())
    return obs_profile.export_trace_cli(args.trace, "gateway")


if __name__ == "__main__":
    raise SystemExit(main())
