"""Streaming SNN serving: a continuous-batching server over CompiledModel.

The interactive-workload counterpart of launch/serve.py: instead of token
sequences and KV caches, the device-resident resource is *simulation state*.
An SNNServer owns one compiled spiking network (host Simulator or sharded
ShardedEngine build — same code path) whose state carries a leading
**stream axis** of `max_streams` preallocated slots: each slot is an
independent simulation with its own neuron/synapse/STDP state, dendritic-
delay rings (post-sharded `[max_delay+1, n_post_local]` on engine builds)
and PRNG key, all resident on device between requests.

Clients submit stimulus streams (per-population injected-current arrays,
one row per dt step).  The slot scheduler (launch/scheduling.py, shared
with the transformer server) admits queued streams into free slots; one
jitted `serve_step` — `model.serve_chunk(states, stim_chunk, steps_left)` —
then advances *all* active streams together, `chunk` dt steps per call,
vmapped over the stream axis.  Per-slot `steps_left` masking makes idle
slots exact no-ops, so a stream's spike output is bit-identical to an
offline `model.run(T, stim=..., state=init_state(PRNGKey(seed)))` with the
same seed and stimulus (tests/test_serving.py pins this down for host and
sharded builds).  Finished streams free their slot for queued requests —
continuous batching on the sweep's vmap axis.

Per chunk the server streams spike output back to the request: population
spike counts (and optionally full rasters).  Demo CLI:

  PYTHONPATH=src python -m repro.launch.snn_serve \
      --model mushroom_body --streams 8 --chunk 50

  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python -m repro.launch.snn_serve --model mushroom_body \
      --streams 4 --devices 8 --check
"""

from __future__ import annotations

import argparse
import dataclasses
import sys
import time
from typing import Dict, List, Mapping, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.scheduling import SlotScheduler
from repro.obs import profile as obs_profile

__all__ = ["SNNServer", "StreamRequest", "ChunkOutput"]


@dataclasses.dataclass
class ChunkOutput:
    """One chunk of spike output streamed back to a request."""

    start_step: int
    n_steps: int
    spike_counts: Dict[str, np.ndarray]          # pop -> [n] ints
    raster: Optional[Dict[str, np.ndarray]]      # pop -> [n_steps, n] bool
    # probe name -> [samples_this_chunk, ...] (already cropped per slot)
    recordings: Optional[Dict[str, np.ndarray]] = None
    # HealthReport.summary() dict for this slot over this chunk (monitored
    # builds only): per-pop spike totals / rate EMAs / silent / saturated
    # flags plus the NaN-guard verdict.  step indices are chunk-local.
    health: Optional[Dict[str, object]] = None


@dataclasses.dataclass
class StreamRequest:
    """One client stimulus stream.

    stim: population -> [T, n] injected currents (one row per dt step);
    populations outside the server's `stim_pops` are rejected, missing ones
    are driven with zeros.  `seed` keys the slot's private RNG: the served
    spike train is bit-identical to an offline run from
    init_state(PRNGKey(seed)) with the same stimulus.
    """

    rid: int
    n_steps: int
    stim: Dict[str, np.ndarray]
    seed: int = 0
    chunks: List[ChunkOutput] = dataclasses.field(default_factory=list)
    done: bool = False

    @property
    def spike_counts(self) -> Dict[str, np.ndarray]:
        """Total per-neuron spike counts streamed so far."""
        out: Dict[str, np.ndarray] = {}
        for c in self.chunks:
            for k, v in c.spike_counts.items():
                out[k] = out.get(k, 0) + v
        return out

    @property
    def raster(self) -> Dict[str, np.ndarray]:
        """[T, n] spike raster per population (record_raster servers)."""
        out: Dict[str, List[np.ndarray]] = {}
        for c in self.chunks:
            if c.raster is None:
                raise ValueError("server built with record_raster=False")
            for k, v in c.raster.items():
                out.setdefault(k, []).append(v)
        return {k: np.concatenate(v) for k, v in out.items()}

    @property
    def health(self) -> Optional[Dict[str, object]]:
        """Aggregated health over all streamed chunks (monitored servers):
        spike totals summed, NaN-guard verdicts OR'd (``first_bad_step``
        rebased to stream-global step index), rate EMAs / silent /
        saturated flags from the latest chunk (they reflect the most
        recent dynamics by construction).  None on unmonitored servers."""
        reports = [(c.start_step, c.health) for c in self.chunks
                   if c.health is not None]
        if not reports:
            return None
        last = reports[-1][1]
        pops: Dict[str, Dict[str, object]] = {}
        for p, cur in last["populations"].items():
            pops[p] = dict(cur)
            pops[p]["spikes"] = sum(int(h["populations"][p]["spikes"])
                                    for _, h in reports)
        first_bad = -1
        for start, h in reports:
            if int(h["first_bad_step"]) >= 0:
                first_bad = start + int(h["first_bad_step"])
                break
        return {
            "steps": sum(int(h["steps"]) for _, h in reports),
            "nonfinite": any(bool(h["nonfinite"]) for _, h in reports),
            "first_bad_step": first_bad,
            "populations": pops,
        }

    @property
    def recordings(self) -> Dict[str, np.ndarray]:
        """Stitched probe samples streamed so far: probe name ->
        [n_samples, ...] in chronological order — identical to the
        offline run's `Recordings` rows for the same seed and stimulus.
        (`window` probes stream every sample; window client-side.)"""
        out: Dict[str, List[np.ndarray]] = {}
        for c in self.chunks:
            for k, v in (c.recordings or {}).items():
                out.setdefault(k, []).append(v)
        return {k: np.concatenate(v) for k, v in out.items()}


class SNNServer:
    """Continuous-batching streaming server for one compiled SNN."""

    def __init__(self, model, max_streams: int = 4, chunk: int = 50,
                 stim_pops: Optional[Sequence[str]] = None,
                 gscales: Optional[Mapping[str, jax.Array]] = None,
                 record_raster: bool = False):
        if chunk <= 0:
            raise ValueError(f"chunk must be positive, got {chunk}")
        self.model = model
        self.chunk = int(chunk)
        self.max_streams = int(max_streams)
        pops = model.network.populations
        self.stim_pops = (tuple(stim_pops) if stim_pops is not None
                          else tuple(pops))
        unknown = set(self.stim_pops) - set(pops)
        if unknown:
            raise ValueError(
                f"unknown stim population(s) {sorted(unknown)}; declared "
                f"populations: {sorted(pops)}")
        self._pop_n = {p: pops[p].n for p in self.stim_pops}
        self.gscales = dict(gscales or {})
        self.record_raster = bool(record_raster)
        self.sched = SlotScheduler(max_streams)
        self.requests: Dict[int, StreamRequest] = {}   # rid -> request
        # device-resident batched state: slots start from placeholder keys
        # and are re-keyed at admission (slot seed = request seed)
        keys = jnp.stack([jax.random.PRNGKey(0)] * self.max_streams)
        self.states = model.init_stream_state(keys)
        self._cursor = np.zeros(self.max_streams, np.int64)  # steps served
        self._insert_jit = jax.jit(
            lambda states, fresh, slot: jax.tree.map(
                lambda b, f: jax.lax.dynamic_update_index_in_dim(
                    b, f.astype(b.dtype), slot, 0), states, fresh))
        # accounting
        self.total_chunks = 0
        self.total_slot_steps = 0      # steps actually served (masked out
        self.total_lane_steps = 0      # vs. lane capacity incl. idle slots)
        self.last_chunk_wall_s = 0.0   # wall time of the latest chunk

    # -- queue ------------------------------------------------------------
    def submit(self, req: StreamRequest) -> StreamRequest:
        unknown = set(req.stim) - set(self.stim_pops)
        if unknown:
            raise ValueError(
                f"request {req.rid}: stim population(s) {sorted(unknown)} "
                f"not served; server stim_pops={sorted(self.stim_pops)}")
        for p, arr in req.stim.items():
            want = (req.n_steps, self._pop_n[p])
            if tuple(np.shape(arr)) != want:
                raise ValueError(
                    f"request {req.rid}: stim[{p!r}] has shape "
                    f"{tuple(np.shape(arr))}, expected {want}")
        if req.rid in self.requests:
            raise ValueError(
                f"duplicate request rid {req.rid}; collect it with "
                "pop_finished() before recycling the id")
        # priority/deadline are optional request attributes (plain
        # StreamRequests carry neither): the gateway's GatewayRequest sets
        # both, and the scheduler orders/evicts accordingly
        self.sched.submit(req,          # also rejects rids still in timings
                          priority=getattr(req, "priority", 0),
                          deadline_at=getattr(req, "deadline_at", None))
        self.requests[req.rid] = req
        return req

    # -- internals --------------------------------------------------------
    def _admit(self) -> List:
        """Admit queued requests into free slots, initializing each slot's
        device-resident state from the request's seed; returns the new
        (slot, request) assignments (the gateway hooks these for queue-wait
        accounting)."""
        assigned = self.sched.admit()
        for slot, req in assigned:
            fresh = self.model.init_state(jax.random.PRNGKey(req.seed))
            self.states = self._insert_jit(self.states, fresh,
                                           jnp.int32(slot))
            self._cursor[slot] = 0
        return assigned

    def _assemble(self):
        """Stim chunk [S, chunk, n] per pop + per-slot steps_left."""
        S, C = self.max_streams, self.chunk
        steps_left = np.zeros(S, np.int32)
        stim = {p: np.zeros((S, C, n), np.float32)
                for p, n in self._pop_n.items()}
        for slot, req in self.sched.active.items():
            cur = int(self._cursor[slot])
            take = min(C, req.n_steps - cur)
            steps_left[slot] = take
            for p, arr in req.stim.items():
                stim[p][slot, :take] = arr[cur:cur + take]
        return stim, steps_left

    # -- main loop --------------------------------------------------------
    def serve_step(self) -> bool:
        """Admit, advance all active streams one chunk, stream outputs and
        evict finished streams; returns True while work remains."""
        self._admit()
        if not self.sched.active:
            return self.sched.has_work()
        self._advance_chunk()
        return self.sched.has_work()

    def _advance_chunk(self) -> List[StreamRequest]:
        """One compiled chunk over every active slot: assemble per-slot
        stimulus, run serve_chunk, stream outputs back to the requests,
        release finished slots.  Returns the requests that finished this
        chunk; ``last_chunk_wall_s`` holds the wall time of the whole
        advance (assembly + compute + host transfer) — the gateway's
        per-step latency sample."""
        t0 = time.perf_counter()
        stim, steps_left = self._assemble()
        out = self.model.serve_chunk(
            self.states, stim, steps_left, self.chunk,
            gscales=self.gscales, record_raster=self.record_raster)
        # monitored builds append a per-slot HealthReport (5-tuple)
        monitored = getattr(self.model, "monitor", None) is not None
        if monitored:
            self.states, counts, raster, rec, health = out
        else:
            (self.states, counts, raster, rec), health = out, None
        counts = {k: np.asarray(v) for k, v in counts.items()}
        if raster is not None:
            raster = {k: np.asarray(v) for k, v in raster.items()}
        rec_data = {k: np.asarray(v) for k, v in rec.data.items()}
        rec_counts = {k: np.asarray(v) for k, v in rec.counts.items()}
        self.total_chunks += 1
        self.total_slot_steps += int(steps_left.sum())
        self.total_lane_steps += self.max_streams * self.chunk
        finished: List[StreamRequest] = []
        for slot, req in list(self.sched.active.items()):
            took = int(steps_left[slot])
            start = int(self._cursor[slot])
            # copies, not views: a [slot] view would pin the whole [S, ...]
            # chunk array in memory for the request's lifetime
            req.chunks.append(ChunkOutput(
                start_step=start, n_steps=took,
                spike_counts={k: v[slot].copy() for k, v in counts.items()},
                raster=(None if raster is None
                        else {k: v[slot, :took].copy()
                              for k, v in raster.items()}),
                recordings={k: v[slot, : int(rec_counts[k][slot])].copy()
                            for k, v in rec_data.items()},
                health=(health.summary(slot) if health is not None
                        else None)))
            self._cursor[slot] = start + took
            if self._cursor[slot] >= req.n_steps:
                req.done = True
                self.sched.release(slot)
                finished.append(req)
        self.last_chunk_wall_s = time.perf_counter() - t0
        return finished

    def run(self) -> List[StreamRequest]:
        """Drain the queue; returns finished requests (rid order).  The
        server keeps finished requests (stimulus + streamed chunks)
        registered until pop_finished() collects them — a long-lived
        server must collect, or per-request memory grows without bound."""
        while self.serve_step():
            pass
        return sorted((r for r in self.requests.values() if r.done),
                      key=lambda r: r.rid)

    def pop_finished(self) -> List[StreamRequest]:
        """Collect finished requests (rid order), dropping them and their
        timing records from the server so memory stays bounded."""
        done = sorted((r for r in self.requests.values() if r.done),
                      key=lambda r: r.rid)
        for r in done:
            del self.requests[r.rid]
            self.sched.forget(r.rid)
        return done

    # -- reporting --------------------------------------------------------
    def stats(self) -> Dict[str, object]:
        util = (self.total_slot_steps / self.total_lane_steps
                if self.total_lane_steps else 0.0)
        return {
            "max_streams": self.max_streams,
            "chunk": self.chunk,
            "chunks": self.total_chunks,
            "slot_steps": self.total_slot_steps,
            "slot_utilization": util,
            "latency": self.sched.latency_summary(),
        }


# ---------------------------------------------------------------------------
# demo CLI
# ---------------------------------------------------------------------------

def _build_model(name: str, devices: int, full: bool, monitor=None):
    """(model, stim populations, stimulus current scale) for the demo."""
    mesh = None
    if devices:
        from repro.launch.mesh import make_snn_mesh
        mesh = make_snn_mesh(devices)
    if name == "mushroom_body":
        from repro.core.models.mushroom_body import (MushroomBodyConfig,
                                                     compile_model)
        # the KC membrane-voltage probe streams back per chunk alongside
        # spike counts — the serving demo of the probe API
        cfg = (MushroomBodyConfig(kc_probe_every=5) if full else
               MushroomBodyConfig(n_pn=20, n_lhi=5, n_kc=100, n_dn=20,
                                  kc_probe_every=5))
        return compile_model(cfg, mesh=mesh, monitor=monitor), ("KC",), 1.5
    if name == "izhikevich":
        from repro.core.models.izhikevich_net import (IzhikevichNetConfig,
                                                      compile_model)
        cfg = (IzhikevichNetConfig() if full else
               IzhikevichNetConfig(n_total=200, n_conn=30))
        return compile_model(cfg, mesh=mesh, monitor=monitor), ("exc",), 3.0
    raise SystemExit(f"unknown --model {name!r} "
                     "(expected mushroom_body or izhikevich)")


def _check_exact(model, req) -> List[str]:
    """Bit-exactness of one served request vs an offline ``model.run``;
    returns a list of failure descriptions (empty = exact)."""
    failures = []
    res = model.run(req.n_steps, stim=req.stim,
                    state=model.init_state(jax.random.PRNGKey(req.seed)))
    for k, v in res.spike_counts.items():
        if not np.array_equal(np.asarray(v), req.spike_counts[k]):
            failures.append(f"stream {req.rid}: population {k!r} spike "
                            "counts diverged from offline run")
    for k, v in req.recordings.items():
        off = np.asarray(res.recordings[k])
        off = off[: int(res.recordings.counts[k])]
        # continuous state (HH membrane V) tolerates FMA/fusion noise
        # between the batched serve program and the offline scan;
        # spike/event probes stay bit-exact (tests/test_probes.py)
        if off.shape != v.shape or not np.allclose(
                off, v, rtol=1e-5, atol=1e-4):
            failures.append(f"stream {req.rid}: probe {k!r} diverged "
                            "from offline run")
    return failures


def _run_gateway_demo(model, stim_pops, scale, args) -> int:
    """--deadline-ms path: drive the same demo through the serving gateway
    so deadline eviction + slot reclamation are exercised end-to-end.

    The deadline is applied to every *other* request — the evicted half
    demonstrates mid-flight reclamation while the unlimited half must
    still finish (and, under --check, stay bit-exact vs offline runs
    even though neighbouring slots were evicted under them).
    """
    from repro.launch.gateway import Gateway

    gw = Gateway(chunk=args.chunk, buckets=(args.streams,),
                 max_queue=max(2 * args.requests, 4))
    gw.register(args.model, model, stim_pops=stim_pops)
    pops = {p: model.network.populations[p].n for p in stim_pops}
    rng = np.random.default_rng(args.seed)
    for i in range(args.requests):
        T = int(rng.integers(args.steps // 2, args.steps + 1))
        stim = {p: (scale * rng.normal(size=(T, n))).astype(np.float32)
                for p, n in pops.items()}
        dl = args.deadline_ms if i % 2 == 1 else None
        gw.submit(args.model, stim, T, seed=1000 + i, deadline_ms=dl)

    t0 = time.time()
    gw.run_until_drained()
    wall = time.time() - t0
    done = gw.collect_finished()
    completed = [r for r in done if r.status == "done"]
    evicted = [r for r in done if r.evicted]
    m = gw.metrics()["models"][args.model]
    print(f"[snn_serve] gateway: {len(completed)} completed, "
          f"{len(evicted)} evicted (deadline {args.deadline_ms}ms on "
          f"every other request) in {wall:.2f}s")
    print(f"[snn_serve] gateway: occupancy {m['occupancy']:.2f} "
          f"p99 step {m['step_latency_us']['p99']:.0f}us "
          f"p99 queue wait {m['queue_wait_s']['p99'] * 1e3:.1f}ms")

    if len(completed) + len(evicted) != args.requests:
        print(f"[snn_serve] FAILED: lost streams "
              f"({len(completed)}+{len(evicted)} != {args.requests})",
              file=sys.stderr)
        return 1
    if args.check:
        failures = []
        for r in completed:
            failures += _check_exact(model, r)
        if failures:
            for f in failures:
                print(f"[snn_serve] exactness check FAILED: {f}",
                      file=sys.stderr)
            return 1
        print(f"[snn_serve] exactness check: all {len(completed)} "
              "non-evicted streams exact vs offline runs")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="streaming SNN serving demo (continuous batching)")
    ap.add_argument("--model", default="mushroom_body",
                    choices=["mushroom_body", "izhikevich"])
    ap.add_argument("--streams", type=int, default=8,
                    help="device-resident stream slots (vmap axis)")
    ap.add_argument("--chunk", type=int, default=50,
                    help="dt steps advanced per serve_step")
    ap.add_argument("--devices", type=int, default=0,
                    help="shard over N devices (0 = single-device build)")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--steps", type=int, default=200,
                    help="stimulus length per request (dt steps)")
    ap.add_argument("--full", action="store_true",
                    help="full-size model (default: reduced demo sizes)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--check", action="store_true",
                    help="verify served streams bit-exact vs offline runs; "
                         "exits non-zero on divergence")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="route the demo through the serving gateway with "
                         "this per-request deadline on every other request "
                         "(exercises deadline eviction end-to-end)")
    ap.add_argument("--health", action="store_true",
                    help="compile the on-device activity monitor into the "
                         "serve program (repro.obs.health) and print a "
                         "per-stream health line: spike totals, rate EMAs, "
                         "silent/saturated flags, NaN guard")
    ap.add_argument("--trace", default="", metavar="FILE",
                    help="write a Chrome trace_event JSON of build/serve "
                         "spans to FILE on exit (open in chrome://tracing "
                         "or Perfetto)")
    args = ap.parse_args(argv)

    monitor = None
    if args.health:
        from repro.obs.health import HealthConfig
        monitor = HealthConfig()
    model, stim_pops, scale = _build_model(args.model, args.devices,
                                           args.full, monitor=monitor)
    if args.deadline_ms is not None:
        code = _run_gateway_demo(model, stim_pops, scale, args)
        return code or obs_profile.export_trace_cli(args.trace, "snn_serve")
    pops = {p: model.network.populations[p].n for p in stim_pops}
    print(f"[snn_serve] {model!r}")
    print(f"[snn_serve] streams={args.streams} chunk={args.chunk} "
          f"devices={args.devices or 1} stim_pops={list(pops)}")

    srv = SNNServer(model, max_streams=args.streams, chunk=args.chunk,
                    stim_pops=stim_pops)
    rng = np.random.default_rng(args.seed)
    reqs = []
    for i in range(args.requests):
        # varied-length noisy current streams: each client gets its own
        # stimulus and its own RNG seed (slot state is re-keyed on admit)
        T = int(rng.integers(args.steps // 2, args.steps + 1))
        stim = {p: (scale * rng.normal(size=(T, n))).astype(np.float32)
                for p, n in pops.items()}
        reqs.append(srv.submit(StreamRequest(rid=i, n_steps=T, stim=stim,
                                             seed=1000 + i)))

    t0 = time.time()
    finished = srv.run()
    wall = time.time() - t0
    stats = srv.stats()
    total_steps = stats["slot_steps"]
    print(f"[snn_serve] {len(finished)}/{args.requests} streams, "
          f"{total_steps} stream-steps in {wall:.2f}s "
          f"({total_steps / max(wall, 1e-9):.0f} steps/s, "
          f"utilization {stats['slot_utilization']:.2f})")
    lat = stats["latency"]
    print(f"[snn_serve] latency: mean {lat.get('mean_total_s', 0):.3f}s "
          f"max {lat.get('max_total_s', 0):.3f}s "
          f"(queue wait {lat.get('mean_queue_wait_s', 0):.3f}s)")
    for r in finished[:4]:
        rates = {k: float(np.sum(v)) for k, v in r.spike_counts.items()}
        rec = r.recordings
        probes = {k: v.shape for k, v in rec.items()}
        print(f"  stream{r.rid}: T={r.n_steps} spikes={rates}"
              + (f" probes={probes}" if probes else ""))
    if args.health:
        for r in finished:
            h = r.health
            flags = [p for p, d in h["populations"].items() if d["silent"]]
            sat = [p for p, d in h["populations"].items() if d["saturated"]]
            ema = {p: round(d["rate_ema_hz"], 2)
                   for p, d in h["populations"].items()}
            print(f"  health stream{r.rid}: rate_ema_hz={ema} "
                  f"silent={flags or 'none'} saturated={sat or 'none'} "
                  f"nonfinite={h['nonfinite']}"
                  + (f" first_bad_step={h['first_bad_step']}"
                     if h["nonfinite"] else ""))

    if len(finished) != args.requests:
        print("[snn_serve] FAILED: not all streams finished",
              file=sys.stderr)
        return 1
    if args.check:
        failures = _check_exact(model, finished[0])
        if failures:
            for f in failures:
                print(f"[snn_serve] exactness check FAILED: {f}",
                      file=sys.stderr)
            return 1
        print("[snn_serve] exactness check: served stream 0 exact "
              "vs offline run (spike counts + probe recordings)")
    return obs_profile.export_trace_cli(args.trace, "snn_serve")


if __name__ == "__main__":
    sys.exit(main())
