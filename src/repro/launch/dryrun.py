import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the real step function (train_step with AdamW for
train shapes; prefill_step; serve_step = one decode against a full-length KV
cache), lowers it against ShapeDtypeStruct inputs with the production
shardings, compiles it, and records:

  * memory_analysis()       -- proves the cell fits (plus analytic bytes/dev)
  * cost_analysis()         -- HLO flops / bytes for the roofline
  * collective bytes        -- parsed from the post-SPMD HLO text, per
                               collective kind (all-gather, all-reduce,
                               reduce-scatter, all-to-all, collective-permute)

Artifacts land in experiments/dryrun/<mesh>/<arch>__<shape>.json; the
roofline benchmark and EXPERIMENTS.md tables read them.

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--skip-existing]
"""

import argparse
import json
import math
import re
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SHAPES, get_config, get_shape
from repro.launch import sharding as SH
from repro.launch.mesh import batch_axes, make_production_mesh
from repro.models import model as M
from repro.models import transformer as T
from repro.optim import adamw

ART_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8": 1, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|u64|s32|u32|s16|u16|s8|u8|"
                       r"pred|c64|c128)\[([0-9,]*)\]")


def _bytes_of_shape(txt: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(txt):
        dt, dims = m.groups()
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op in post-SPMD HLO."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.lstrip()
        # "%x = TYPE all-gather(...)" or fusion-less "x = ... all-gather("
        m = re.search(r"=\s+(.+?)\s+(all-gather|all-reduce|reduce-scatter|"
                      r"all-to-all|collective-permute)(-start)?\(", s)
        if not m:
            continue
        kind = m.group(2)
        # operand bytes: shapes inside the call parens are not printed, but
        # the RESULT type is; for all-gather result >= operand, for
        # reduce-scatter result <= operand.  Parse operand shapes from the
        # result-type prefix: for these ops HLO prints the full signature
        # in the type slot, e.g. "bf16[8,128]{1,0}" or a tuple.
        type_txt = m.group(1)
        nbytes = _bytes_of_shape(type_txt)
        if kind == "all-gather":
            # operand = result / group size; group size parsed from
            # replica_groups if present on the line
            g = _group_size(s)
            nbytes = nbytes // max(1, g)
        elif kind == "all-reduce":
            pass  # operand size == result size
        out[kind] += nbytes
        counts[kind] += 1
    return {"bytes": out, "counts": counts,
            "total_bytes": int(sum(out.values()))}


def _group_size(line: str) -> int:
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    return 1


def analytic_param_bytes_per_device(params_struct, specs, mesh) -> int:
    """Sum leaf bytes / shards — works even if memory_analysis() is bare."""
    total = 0
    for leaf, spec in zip(jax.tree.leaves(params_struct),
                          jax.tree.leaves(
                              specs, is_leaf=lambda x: isinstance(x, P))):
        shards = 1
        for entry in spec:
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            for a in axes:
                shards *= mesh.shape[a]
        total += leaf.size * leaf.dtype.itemsize // shards
    return int(total)


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

def make_train_step(cfg):
    """Train step with optional gradient accumulation (cfg.microbatches):
    big-model train cells don't fit their activations at the full global
    batch (see EXPERIMENTS.md §Dry-run memory column); accumulation bounds
    live activations to one microbatch at the cost of re-gathering FSDP
    weights per microbatch."""
    ocfg = adamw.AdamWConfig(lr=3e-4)
    n_mb_cfg = max(1, getattr(cfg, "microbatches", 1))

    def lf(p, b):
        loss, metrics = T.loss_fn(p, cfg, b)
        return loss, metrics

    def _effective_mb(global_batch: int) -> int:
        # keep each microbatch divisible by the batch-axis extent, or the
        # batch dim stops sharding and activations replicate (e.g. zamba2
        # at mb=16 on the 32-wide multi-pod batch axes)
        from repro.models.layers import _AXIS_ENV
        shards = max(1, _AXIS_ENV.get("batch_size", 1))
        mb = min(n_mb_cfg, global_batch)
        while mb > 1 and ((global_batch % mb)
                          or (global_batch // mb) % shards):
            mb -= 1
        return mb

    def train_step(params, opt_state, batch):
        n_mb = _effective_mb(
            jax.tree.leaves(batch)[0].shape[0])
        if n_mb == 1:
            (loss, metrics), grads = jax.value_and_grad(
                lf, has_aux=True)(params, batch)
        else:
            mbs = jax.tree.map(
                lambda x: x.reshape((n_mb, x.shape[0] // n_mb)
                                    + x.shape[1:]), batch)

            def body(carry, mb):
                gsum, lsum = carry
                (loss, metrics), g = jax.value_and_grad(
                    lf, has_aux=True)(params, mb)
                gsum = jax.tree.map(
                    lambda a, b_: a + b_.astype(jnp.float32), gsum, g)
                return (gsum, lsum + loss), metrics

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), ms = T.maybe_scan(body, (g0, jnp.float32(0.0)),
                                            mbs)
            grads = jax.tree.map(lambda g: g / n_mb, gsum)
            loss = lsum / n_mb
            metrics = jax.tree.map(lambda m: m[-1], ms)
        new_params, new_state, om = adamw.update(ocfg, grads, opt_state,
                                                 params)
        return new_params, new_state, {"loss": loss, **metrics, **om}

    return train_step


def make_prefill_step(cfg):
    def prefill_step(params, tokens, extra):
        return T.prefill(params, cfg, tokens, extra)
    return prefill_step


def make_serve_step(cfg):
    def serve_step(params, caches, token):
        return T.decode_step(params, cfg, caches, token)
    return serve_step


# ---------------------------------------------------------------------------
# roofline extrapolation lowerings
# ---------------------------------------------------------------------------
# cost_analysis counts while-loop bodies once, so scanned layer stacks and
# chunked attention under-report.  We re-lower at depth-groups g=1 and g=2
# with layer scans python-unrolled and naive (fully-counted) attention, then
# extrapolate linearly: term(G_full) = t1 + (t2 - t1) * (G_full - 1).
# The roofline builder swaps naive-attention terms for analytic flash terms.

def _roofline_lowering(cfg, shape, mesh, g: int) -> dict:
    import dataclasses as _dc

    from repro import flags
    from repro.configs.base import depth_scaled

    # microbatches=1 for the measurement: per-step totals are identical
    # (same global batch), and unrolling mb x layers would blow up compile;
    # the full-depth compile keeps accumulation for the memory proof.
    dcfg = _dc.replace(depth_scaled(cfg, g), microbatches=1)
    old_unroll = T.UNROLL_LAYERS
    T.UNROLL_LAYERS = True
    flags.ROOFLINE_NAIVE_ATTN = True
    try:
        with SH.activate(mesh):
            params_struct = jax.eval_shape(
                lambda: T.init_params(dcfg, jax.random.PRNGKey(0)))
            pshard = SH.spec_tree_to_shardings(
                SH.param_specs(params_struct, mesh), mesh)
            specs = M.input_specs(dcfg, shape)
            if shape.kind == "train":
                step = make_train_step(dcfg)
                opt_struct = jax.eval_shape(
                    lambda p: adamw.init(adamw.AdamWConfig(), p),
                    params_struct)
                oshard = adamw.AdamWState(
                    NamedSharding(mesh, P()),
                    SH.spec_tree_to_shardings(
                        SH.param_specs(opt_struct.mu, mesh), mesh),
                    SH.spec_tree_to_shardings(
                        SH.param_specs(opt_struct.nu, mesh), mesh),
                    SH.spec_tree_to_shardings(
                        SH.param_specs(opt_struct.master, mesh), mesh))
                bshard = SH.spec_tree_to_shardings(
                    SH.batch_specs(specs["batch"], mesh), mesh)
                lowered = jax.jit(
                    step, in_shardings=(pshard, oshard, bshard),
                    out_shardings=(pshard, oshard, None)).lower(
                    params_struct, opt_struct, specs["batch"])
            elif shape.kind == "prefill":
                step = make_prefill_step(dcfg)
                tok = specs["tokens"]
                extra = {k: v for k, v in specs.items() if k != "tokens"}
                if dcfg.serve_replicate_weights:
                    pshard = jax.tree.map(
                        lambda _: NamedSharding(mesh, P()), pshard)
                lowered = jax.jit(step, in_shardings=(
                    pshard,
                    SH.spec_tree_to_shardings(SH.batch_specs(tok, mesh),
                                              mesh),
                    SH.spec_tree_to_shardings(SH.batch_specs(extra, mesh),
                                              mesh))).lower(
                    params_struct, tok, extra)
            else:
                step = make_serve_step(dcfg)
                caches = specs["caches"]
                cshard = SH.cache_shardings(caches, mesh)
                if dcfg.serve_replicate_weights:
                    pshard = jax.tree.map(
                        lambda _: NamedSharding(mesh, P()), pshard)
                lowered = jax.jit(
                    step, in_shardings=(pshard, cshard,
                                        NamedSharding(mesh, P())),
                    out_shardings=(None, cshard)).lower(
                    params_struct, caches, specs["token"])
            compiled = lowered.compile()
        cost = compiled.cost_analysis() or {}
        coll = collective_bytes(compiled.as_text())
        return {
            "g": g,
            "flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "transcendentals": float(cost.get("transcendentals", 0.0)),
            "collectives": coll,
        }
    finally:
        T.UNROLL_LAYERS = old_unroll
        flags.ROOFLINE_NAIVE_ATTN = False


def roofline_terms(cfg, shape, mesh) -> dict:
    from repro.configs.base import full_groups
    g_full = full_groups(cfg)
    t1 = _roofline_lowering(cfg, shape, mesh, 1)
    t2 = _roofline_lowering(cfg, shape, mesh, min(2, g_full))
    span = max(1, t2["g"] - t1["g"])

    def extrap(a, b):
        return a + (b - a) / span * (g_full - t1["g"])

    coll1 = t1["collectives"]["bytes"]
    coll2 = t2["collectives"]["bytes"]
    return {
        "g_full": g_full,
        "depth1": t1, "depth2": t2,
        "flops": extrap(t1["flops"], t2["flops"]),
        "bytes": extrap(t1["bytes"], t2["bytes"]),
        "transcendentals": extrap(t1["transcendentals"],
                                  t2["transcendentals"]),
        "collective_bytes": {
            k: extrap(coll1[k], coll2[k]) for k in coll1},
        "collective_total": extrap(t1["collectives"]["total_bytes"],
                                   t2["collectives"]["total_bytes"]),
    }


# ---------------------------------------------------------------------------
# cell runner
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, multi_pod: bool,
             save: bool = True) -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    ok, why = cfg.applicable(shape)
    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
           "timestamp": time.time()}
    if not ok:
        rec.update(status="SKIP", reason=why)
        _save(rec, save)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        with SH.activate(mesh):
            params_struct = jax.eval_shape(
                lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
            pspecs = SH.param_specs(params_struct, mesh)
            pshard = SH.spec_tree_to_shardings(pspecs, mesh)

            specs = M.input_specs(cfg, shape)
            if shape.kind == "train":
                step = make_train_step(cfg)
                opt_struct = jax.eval_shape(
                    lambda p: adamw.init(adamw.AdamWConfig(), p),
                    params_struct)
                oshard = (
                    NamedSharding(mesh, P()),
                    SH.spec_tree_to_shardings(
                        SH.param_specs(opt_struct.mu, mesh), mesh),
                    SH.spec_tree_to_shardings(
                        SH.param_specs(opt_struct.nu, mesh), mesh),
                    SH.spec_tree_to_shardings(
                        SH.param_specs(opt_struct.master, mesh), mesh),
                )
                oshard = adamw.AdamWState(*oshard)
                bshard = SH.spec_tree_to_shardings(
                    SH.batch_specs(specs["batch"], mesh), mesh)
                jf = jax.jit(step,
                             in_shardings=(pshard, oshard, bshard),
                             out_shardings=(pshard, oshard, None))
                lowered = jf.lower(params_struct, opt_struct,
                                   specs["batch"])
            elif shape.kind == "prefill":
                step = make_prefill_step(cfg)
                tok = specs["tokens"]
                extra = {k: v for k, v in specs.items() if k != "tokens"}
                tshard = SH.spec_tree_to_shardings(
                    SH.batch_specs(tok, mesh), mesh)
                eshard = SH.spec_tree_to_shardings(
                    SH.batch_specs(extra, mesh), mesh)
                if cfg.serve_replicate_weights:
                    pshard = jax.tree.map(
                        lambda _: NamedSharding(mesh, P()), pshard)
                out_struct = jax.eval_shape(step, params_struct, tok,
                                            extra)
                cshard_out = SH.cache_shardings(out_struct[1], mesh)
                jf = jax.jit(step, in_shardings=(pshard, tshard, eshard),
                             out_shardings=(None, cshard_out))
                lowered = jf.lower(params_struct, tok, extra)
            else:  # decode / long
                step = make_serve_step(cfg)
                caches = specs["caches"]
                cshard = SH.cache_shardings(caches, mesh)
                tokshard = NamedSharding(mesh, P())
                if cfg.serve_replicate_weights:
                    # tiny models: TP all-reduce latency dwarfs the matmuls;
                    # replicate weights, keep only batch sharding (§Perf)
                    pshard = jax.tree.map(
                        lambda _: NamedSharding(mesh, P()), pshard)
                jf = jax.jit(step,
                             in_shardings=(pshard, cshard, tokshard),
                             out_shardings=(None, cshard),
                             donate_argnums=(1,))
                lowered = jf.lower(params_struct, caches, specs["token"])

            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)
        n_dev = mesh.devices.size

        mem_rec = {}
        for field in ("generated_code_size_in_bytes",
                      "argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "alias_size_in_bytes"):
            v = getattr(mem, field, None)
            if v is not None:
                mem_rec[field] = int(v)
        cost_rec = {}
        if cost:
            for k in ("flops", "bytes accessed", "transcendentals",
                      "optimal_seconds"):
                if k in cost:
                    cost_rec[k] = float(cost[k])
            for k, v in cost.items():
                if k.startswith("bytes accessed"):
                    cost_rec[k] = float(v)

        t1 = time.time()
        try:
            roof = roofline_terms(cfg, shape, mesh)
        except Exception as e:  # noqa: BLE001
            roof = {"error": f"{type(e).__name__}: {e}"}
        t_roof = time.time() - t1

        rec.update(
            status="OK",
            n_devices=n_dev,
            lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
            roofline_s=round(t_roof, 2),
            memory_analysis=mem_rec,
            cost_analysis=cost_rec,
            collectives=coll,
            roofline=roof,
            analytic_param_bytes_per_device=analytic_param_bytes_per_device(
                params_struct, pspecs, mesh),
            hlo_bytes=len(hlo),
        )
        print(f"[OK] {arch} x {shape_name} x {mesh_tag}: "
              f"lower {t_lower:.1f}s compile {t_compile:.1f}s "
              f"flops={cost_rec.get('flops', 0):.3e} "
              f"coll={coll['total_bytes']:.3e}B")
    except Exception as e:  # noqa: BLE001 - record and continue
        rec.update(status="FAIL", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        print(f"[FAIL] {arch} x {shape_name} x {mesh_tag}: {e}")
    _save(rec, save)
    return rec


def _save(rec: dict, save: bool) -> None:
    if not save:
        return
    d = ART_DIR / rec["mesh"]
    d.mkdir(parents=True, exist_ok=True)
    path = d / f"{rec['arch']}__{rec['shape']}.json"
    path.write_text(json.dumps(rec, indent=1))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args(argv)

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    cells = []
    archs = list(ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = ([s.name for s in SHAPES] if (args.all or not args.shape)
              else [args.shape])
    for mp in meshes:
        for a in archs:
            for s in shapes:
                cells.append((a, s, mp))

    failures = 0
    for a, s, mp in cells:
        tag = "pod2x16x16" if mp else "pod16x16"
        path = ART_DIR / tag / f"{a}__{s}.json"
        if args.skip_existing and path.exists():
            try:
                prev = json.loads(path.read_text())
                if prev.get("status") in ("OK", "SKIP"):
                    print(f"[skip-existing] {a} x {s} x {tag}")
                    continue
            except json.JSONDecodeError:
                pass
        rec = run_cell(a, s, mp)
        if rec["status"] == "FAIL":
            failures += 1
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
