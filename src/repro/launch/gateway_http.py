"""Async HTTP front door for the SNN serving gateway (stdlib asyncio only).

A deliberately small HTTP/1.1 JSON layer over launch/gateway.py — no web
framework (the container pins its dependency set), just asyncio streams and
a hand-rolled request parser.  The event loop never blocks on simulation:
a single pump thread drives ``Gateway.tick`` (the compiled chunk) through
``run_in_executor``, and request handlers wait on each request's completion
event in the executor too, so thousands of connections multiplex onto one
serving loop.

Routes:

  POST /v1/simulate
      {"model": "izhikevich", "n_steps": 100, "seed": 7, "priority": 0,
       "deadline_ms": 500, "stim": {"exc": [[...], ...]}}
      -> 200 {"status": "done", "steps_served": 100,
              "spike_counts": {"exc": [...]}, "queue_wait_s": ...}
      -> 200 {"status": "evicted", ...partial counts...}  (deadline hit;
         chunks streamed before eviction are returned, not discarded)
      -> 429 + Retry-After header when the admission queue is full
      -> 400 unknown model / malformed stimulus
  GET /metrics     Prometheus-style text (Gateway.render_metrics)
  GET /healthz     200 "ok"
  GET /v1/trace    Chrome trace_event JSON of the process's build/serve
                   spans so far (open in chrome://tracing / Perfetto) —
                   a debug endpoint, not a stable API

Start from the demo CLI (``python -m repro.launch.gateway --http
127.0.0.1:8080``) or embed via ``GatewayHTTP``/``serve_http``.
"""

from __future__ import annotations

import asyncio
import json
import threading
from typing import Dict, Optional, Tuple

import numpy as np

from repro.launch.gateway import Gateway, GatewayOverloaded
from repro.obs import trace as obs_trace

__all__ = ["GatewayHTTP", "serve_http"]

_MAX_BODY = 64 * 1024 * 1024        # 64 MiB: stim arrays are the payload


def _response(status: int, body: bytes, content_type: str,
              extra_headers: Tuple[Tuple[str, str], ...] = ()) -> bytes:
    reason = {200: "OK", 400: "Bad Request", 404: "Not Found",
              405: "Method Not Allowed", 413: "Payload Too Large",
              429: "Too Many Requests",
              500: "Internal Server Error"}.get(status, "OK")
    head = [f"HTTP/1.1 {status} {reason}",
            f"Content-Type: {content_type}",
            f"Content-Length: {len(body)}",
            "Connection: close"]
    head += [f"{k}: {v}" for k, v in extra_headers]
    return ("\r\n".join(head) + "\r\n\r\n").encode() + body


def _json_response(status: int, payload: Dict,
                   extra_headers: Tuple[Tuple[str, str], ...] = ()) -> bytes:
    return _response(status, json.dumps(payload).encode(),
                     "application/json", extra_headers)


class GatewayHTTP:
    """Owns the asyncio server plus the pump thread ticking the gateway.

    The pump is a plain daemon thread (not an asyncio task): `tick` holds
    the gateway lock for a whole compiled chunk, and a thread keeps that
    entirely off the event loop.  It idles at ``idle_sleep_s`` when no
    model has work, so an empty gateway costs ~nothing.
    """

    def __init__(self, gateway: Gateway, host: str = "127.0.0.1",
                 port: int = 0, idle_sleep_s: float = 0.005):
        self.gateway = gateway
        self.host = host
        self.port = port
        self.idle_sleep_s = idle_sleep_s
        self._server: Optional[asyncio.AbstractServer] = None
        self._stop = threading.Event()
        self._pump: Optional[threading.Thread] = None

    # -- lifecycle ----------------------------------------------------------
    async def start(self) -> Tuple[str, int]:
        self._server = await asyncio.start_server(
            self._handle, self.host, self.port)
        sock = self._server.sockets[0].getsockname()
        self.host, self.port = sock[0], sock[1]
        self._stop.clear()
        self._pump = threading.Thread(target=self._pump_loop,
                                      name="gateway-pump", daemon=True)
        self._pump.start()
        return self.host, self.port

    async def stop(self) -> None:
        self._stop.set()
        if self._pump is not None:
            self._pump.join(timeout=5)
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()

    def _pump_loop(self) -> None:
        import time
        while not self._stop.is_set():
            if not self.gateway.tick():
                time.sleep(self.idle_sleep_s)

    # -- request handling -----------------------------------------------------
    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        try:
            out = await self._dispatch(reader)
        except Exception as e:            # defensive: never kill the server
            out = _json_response(500, {"error": f"{type(e).__name__}: {e}"})
        try:
            writer.write(out)
            await writer.drain()
        finally:
            writer.close()

    async def _dispatch(self, reader: asyncio.StreamReader) -> bytes:
        request_line = (await reader.readline()).decode("latin1").strip()
        parts = request_line.split()
        if len(parts) < 2:
            return _json_response(400, {"error": "malformed request line"})
        method, path = parts[0].upper(), parts[1]
        headers: Dict[str, str] = {}
        while True:
            line = (await reader.readline()).decode("latin1").strip()
            if not line:
                break
            k, _, v = line.partition(":")
            headers[k.strip().lower()] = v.strip()

        if method == "GET" and path == "/healthz":
            return _response(200, b"ok\n", "text/plain")
        if method == "GET" and path == "/metrics":
            return _response(200, self.gateway.render_metrics().encode(),
                             "text/plain; version=0.0.4")
        if method == "GET" and path == "/v1/trace":
            return _response(200,
                             json.dumps(obs_trace.chrome_trace()).encode(),
                             "application/json")
        if path == "/v1/simulate":
            if method != "POST":
                return _json_response(405, {"error": "POST required"})
            length = int(headers.get("content-length", "0"))
            if length <= 0:
                return _json_response(400, {"error": "missing body"})
            if length > _MAX_BODY:
                return _json_response(413, {"error": "body too large"})
            body = await reader.readexactly(length)
            return await self._simulate(body)
        return _json_response(404, {"error": f"no route {path}"})

    async def _simulate(self, body: bytes) -> bytes:
        try:
            payload = json.loads(body)
            model = payload["model"]
            n_steps = int(payload["n_steps"])
            stim = {p: np.asarray(a, np.float32)
                    for p, a in payload.get("stim", {}).items()}
        except (json.JSONDecodeError, KeyError, TypeError, ValueError) as e:
            return _json_response(400, {"error": f"bad request: {e}"})
        loop = asyncio.get_running_loop()
        try:
            req = self.gateway.submit(
                model, stim, n_steps,
                seed=int(payload.get("seed", 0)),
                priority=int(payload.get("priority", 0)),
                deadline_ms=payload.get("deadline_ms"))
        except GatewayOverloaded as e:
            return _json_response(
                429, {"error": str(e), "retry_after_s": e.retry_after_s},
                extra_headers=(("Retry-After",
                                f"{max(1, int(e.retry_after_s + 0.5))}"),))
        except (KeyError, ValueError) as e:
            return _json_response(400, {"error": str(e)})
        # wait for completion/eviction off the event loop; the deadline
        # bounds eviction, so cap the wait well past it as a safety net
        timeout = None
        if payload.get("deadline_ms") is not None:
            timeout = payload["deadline_ms"] / 1e3 + 30.0
        finished = await loop.run_in_executor(None, req.wait, timeout)
        if not finished:
            return _json_response(500, {"error": "request stalled"})
        timing = self.gateway.workers[model].sched.timings.get(req.rid)
        out = {
            "rid": req.rid,
            "status": req.status,
            "n_steps": req.n_steps,
            "steps_served": req.steps_served,
            "spike_counts": {k: np.asarray(v).tolist()
                             for k, v in req.spike_counts.items()},
            "queue_wait_s": (timing.queue_wait_s
                             if timing is not None else None),
            "total_s": timing.total_s if timing is not None else None,
        }
        return _json_response(200, out)


def serve_http(gateway: Gateway, host: str = "127.0.0.1",
               port: int = 8080) -> None:
    """Blocking convenience runner (the CLI's --http mode)."""

    async def _main():
        srv = GatewayHTTP(gateway, host, port)
        h, p = await srv.start()
        print(f"[gateway] HTTP front door on http://{h}:{p} "
              f"(POST /v1/simulate, GET /metrics, GET /healthz)")
        try:
            await asyncio.Event().wait()     # until interrupted
        finally:
            await srv.stop()

    try:
        asyncio.run(_main())
    except KeyboardInterrupt:
        print("[gateway] shutting down")
