"""Serving driver: batched prefill + decode loop with continuous batching.

A minimal production-shaped server: requests (prompt token lists) enter a
queue; the slot scheduler (launch/scheduling.py, shared with the SNN stream
server) packs up to `max_batch` active sequences; prefill runs per
admission; decode steps run the whole active batch through one jitted
decode_step (KV caches preallocated to max_seq).  Finished sequences free
their slots for queued requests (continuous batching).  Greedy or
temperature sampling.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
      --requests 6 --max-new 16
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced as make_reduced
from repro.launch import sharding as SH
from repro.launch.mesh import make_local_mesh
from repro.launch.scheduling import SlotScheduler
from repro.models import transformer as T

__all__ = ["Server", "Request"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int = 16
    temperature: float = 0.0
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Server:
    def __init__(self, arch: str, use_reduced: bool = True,
                 max_batch: int = 4, max_seq: int = 512, seed: int = 0,
                 model_parallel: int = 1):
        self.cfg = make_reduced(get_config(arch)) if use_reduced \
            else get_config(arch)
        self.mesh = make_local_mesh(model_parallel)
        self.max_batch = max_batch
        self.max_seq = max_seq
        self._rng = np.random.default_rng(seed)
        with SH.activate(self.mesh):
            self.params = T.init_params(self.cfg, jax.random.PRNGKey(seed))
            self._decode = jax.jit(
                lambda p, c, t: T.decode_step(p, self.cfg, c, t))
        self.sched = SlotScheduler(max_batch)
        self.finished: List[Request] = []
        self.caches = None
        self.slot_len: Dict[int, int] = {}

    # -- queue --------------------------------------------------------------
    @property
    def queue(self) -> List[Request]:
        return self.sched.queue

    @property
    def active(self) -> Dict[int, Request]:
        return self.sched.active

    def submit(self, req: Request) -> None:
        self.sched.submit(req)

    # -- internals ------------------------------------------------------------
    def _extra(self, b):
        extra = {}
        if self.cfg.family == "encdec":
            extra["audio"] = jnp.zeros((b, self.cfg.enc_seq,
                                        self.cfg.d_model), jnp.float32)
        if self.cfg.family == "vlm":
            extra["img"] = jnp.zeros((b, self.cfg.img_tokens,
                                      self.cfg.img_embed_dim), jnp.float32)
        return extra

    def _admit(self) -> None:
        """Prefill queued requests into free slots (one batch per admit)."""
        assigned = self.sched.admit()
        if not assigned:
            return
        slots = [s for s, _ in assigned]
        reqs = [r for _, r in assigned]
        take = len(reqs)
        maxlen = max(len(r.prompt) for r in reqs)
        toks = np.zeros((take, maxlen), np.int32)
        for i, r in enumerate(reqs):
            toks[i, maxlen - len(r.prompt):] = r.prompt  # left-pad
        with SH.activate(self.mesh):
            logits, caches = T.prefill(
                self.params, self.cfg, jnp.asarray(toks),
                self._extra(take), max_seq=self.max_seq)
        # merge these caches into the big batch (simple path: if no active
        # batch yet, adopt; otherwise run sequences independently per admit)
        if self.caches is None and take == self.max_batch:
            self.caches = caches
        for i, (r, s) in enumerate(zip(reqs, slots)):
            self.slot_len[s] = maxlen
            tok = self._sample(np.asarray(logits[i]), r)
            r.out.append(int(tok))
        # dedicated per-admit caches (slot-batched serving): store
        self._admit_caches = caches
        self._admit_slots = slots

    def _sample(self, logits: np.ndarray, req: Request) -> int:
        if req.temperature <= 0:
            return int(np.argmax(logits))
        z = logits / req.temperature
        z = z - z.max()
        p = np.exp(z)
        p /= p.sum()
        return int(self._rng.choice(len(p), p=p))

    # -- main loop ------------------------------------------------------------
    def step(self) -> bool:
        """One decode step over the admitted batch; returns True if work
        remains."""
        if not self.active:
            self._admit()
            if not self.active:
                return False
        reqs = [self.active[s] for s in sorted(self.active)]
        last = jnp.asarray([r.out[-1] if r.out else r.prompt[-1]
                            for r in reqs], jnp.int32)
        with SH.activate(self.mesh):
            logits, self._admit_caches = self._decode(
                self.params, self._admit_caches, last)
        logits_np = np.asarray(logits)
        for i, (s, r) in enumerate(sorted(self.active.items())):
            tok = self._sample(logits_np[i], r)
            r.out.append(tok)
            if len(r.out) >= r.max_new:
                r.done = True
        for s in [s for s, r in self.active.items() if r.done]:
            self.finished.append(self.sched.release(s))
        if not self.active:
            self._admit_caches = None
            return bool(self.queue)
        return True

    def run(self) -> List[Request]:
        while self.step():
            pass
        return list(self.finished)

    def pop_finished(self) -> List[Request]:
        """Collect finished requests, pruning their accounting records so
        a long-lived server stays bounded (and their rids reusable)."""
        done, self.finished = self.finished, []
        for r in done:
            self.sched.forget(r.rid)
        return done


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    srv = Server(args.arch, use_reduced=not args.full,
                 max_batch=args.max_batch)
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        prompt = rng.integers(3, srv.cfg.vocab,
                              size=rng.integers(4, 12)).tolist()
        r = Request(rid=i, prompt=prompt, max_new=args.max_new,
                    temperature=args.temperature)
        reqs.append(r)
        srv.submit(r)
    t0 = time.time()
    srv.run()
    dt = time.time() - t0
    total_tokens = sum(len(r.out) for r in reqs)
    print(f"[serve] {args.requests} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens/dt:.1f} tok/s)")
    print(f"[serve] latency: {srv.sched.latency_summary()}")
    for r in reqs[:4]:
        print(f"  req{r.rid}: prompt[:6]={r.prompt[:6]} -> out[:8]={r.out[:8]}")


if __name__ == "__main__":
    main()
