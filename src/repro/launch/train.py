"""Training driver: mesh + sharded train loop + checkpoint/restart + fault
hooks.  Runs real (small) jobs on CPU and is the same code path the dry-run
lowers for the production meshes.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
      --reduced --steps 100 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt

NaN containment follows the paper's Fig-1 guard: a non-finite loss triggers
rollback to the last checkpoint with the LR (the "conductance") halved —
the same bisection-on-overflow logic, applied to training.
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from pathlib import Path
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config, reduced as make_reduced
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.launch import sharding as SH
from repro.launch.mesh import make_local_mesh
from repro.models import transformer as T
from repro.optim import adamw, schedule
from repro.runtime.fault_tolerance import FailureDetector, HeartbeatMonitor
from repro.runtime.straggler import StragglerPolicy


def make_train_step(cfg, ocfg):
    def train_step(params, opt_state, batch):
        def lf(p):
            return T.loss_fn(p, cfg, batch)
        (loss, metrics), grads = jax.value_and_grad(
            lf, has_aux=True)(params)
        new_params, new_opt, om = adamw.update(ocfg, grads, opt_state,
                                               params)
        return new_params, new_opt, {"loss": loss, **metrics, **om}
    return train_step


def run(arch: str, steps: int = 50, batch: int = 8, seq: int = 256,
        use_reduced: bool = True, ckpt_dir: Optional[str] = None,
        ckpt_every: int = 25, lr: float = 3e-3, seed: int = 0,
        model_parallel: int = 1, log_every: int = 10,
        lr_floor_scale: float = 0.125):
    cfg = get_config(arch)
    if use_reduced:
        cfg = make_reduced(cfg)
    mesh = make_local_mesh(model_parallel)

    sched = schedule.warmup_cosine(lr, warmup=min(20, steps // 5 + 1),
                                   total=steps)
    ocfg = adamw.AdamWConfig(lr=sched, grad_clip=1.0)

    dcfg = DataConfig(vocab=cfg.vocab, seq_len=seq, global_batch=batch,
                      seed=seed)
    pipe = TokenPipeline(dcfg)

    mgr = CheckpointManager(ckpt_dir, max_to_keep=2) if ckpt_dir else None

    with SH.activate(mesh):
        params = T.init_params(cfg, jax.random.PRNGKey(seed))
        opt_state = adamw.init(ocfg, params)
        pshard = SH.spec_tree_to_shardings(
            SH.param_specs(params, mesh), mesh)
        params = jax.device_put(params, pshard)

        step_fn = jax.jit(make_train_step(cfg, ocfg),
                          donate_argnums=(0, 1))

        # restart?
        start = 0
        if mgr and mgr.latest_step() is not None:
            start = mgr.latest_step()
            snap = mgr.restore(start, {"params": params, "opt": opt_state})
            params, opt_state = snap["params"], snap["opt"]
            pipe = TokenPipeline.restore(dcfg, {"step": start,
                                                "shard_index": 0,
                                                "num_shards": 1,
                                                "seed": seed})
            print(f"[train] restored step {start}")

        monitor = HeartbeatMonitor([f"host{jax.process_index()}"])
        detector = FailureDetector(monitor)
        straggler = StragglerPolicy()

        losses = []
        lr_scale = 1.0
        i = start
        while i < steps:
            batch_data = pipe.next_batch()
            if cfg.family == "encdec":
                b = batch_data["tokens"].shape[0]
                batch_data["audio"] = 0.1 * jax.random.normal(
                    jax.random.fold_in(jax.random.PRNGKey(seed), i),
                    (b, cfg.enc_seq, cfg.d_model))
            if cfg.family == "vlm":
                b = batch_data["tokens"].shape[0]
                batch_data["img"] = 0.1 * jax.random.normal(
                    jax.random.fold_in(jax.random.PRNGKey(seed), i),
                    (b, cfg.img_tokens, cfg.img_embed_dim))
            t0 = time.time()
            params, opt_state, metrics = step_fn(params, opt_state,
                                                 batch_data)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            monitor.beat(f"host{jax.process_index()}")
            straggler.observe(f"host{jax.process_index()}", dt)

            if not np.isfinite(loss):
                # paper Fig-1 guard: overflow -> roll back, halve the scale
                if mgr is None or mgr.latest_step() is None:
                    raise FloatingPointError(
                        f"non-finite loss at step {i} and no checkpoint")
                lr_scale = max(lr_scale * 0.5, lr_floor_scale)
                back = mgr.latest_step()
                print(f"[train] NaN at step {i}; rollback to {back}, "
                      f"lr_scale={lr_scale}")
                ocfg = dataclasses.replace(
                    ocfg, lr=lambda s: sched(s) * lr_scale)
                step_fn = jax.jit(make_train_step(cfg, ocfg),
                                  donate_argnums=(0, 1))
                snap = mgr.restore(back, {"params": params,
                                          "opt": opt_state})
                params, opt_state = snap["params"], snap["opt"]
                pipe = TokenPipeline.restore(
                    dcfg, {"step": back, "shard_index": 0,
                           "num_shards": 1, "seed": seed})
                i = back
                continue

            losses.append(loss)
            i += 1
            if i % log_every == 0 or i == steps:
                print(f"[train] step {i:5d} loss {loss:.4f} "
                      f"({dt*1e3:.0f} ms/step)")
            if mgr and (i % ckpt_every == 0 or i == steps):
                mgr.save(i, {"params": params, "opt": opt_state})
        if mgr:
            mgr.wait()
    return losses


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--full", action="store_true",
                    help="use the full config (default: reduced)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args(argv)
    losses = run(args.arch, steps=args.steps, batch=args.batch,
                 seq=args.seq, use_reduced=not args.full,
                 ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                 lr=args.lr, seed=args.seed,
                 model_parallel=args.model_parallel)
    print(f"[train] first loss {losses[0]:.4f} -> last {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
