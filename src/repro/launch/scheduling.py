"""Slot scheduling for continuous-batching servers.

Both serving front-ends — the transformer token server (launch/serve.py) and
the spiking-network stream server (launch/snn_serve.py) — share the same
shape: a fixed table of device-resident slots (KV-cache rows there, stream
lanes on the SNN vmap axis here), a FIFO queue of pending requests, and a
loop that admits queued requests into free slots, advances every occupied
slot in one compiled step, and evicts finished requests so their slots are
immediately reusable.  This module is that shared core, plus the
per-request latency accounting both servers report.

Requests are arbitrary objects with an integer ``rid`` attribute; the
scheduler never inspects anything else.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

__all__ = ["RequestTiming", "SlotScheduler"]


@dataclasses.dataclass
class RequestTiming:
    """Wall-clock milestones of one request through the slot table."""

    submitted_at: float
    admitted_at: Optional[float] = None
    finished_at: Optional[float] = None

    @property
    def queue_wait_s(self) -> Optional[float]:
        if self.admitted_at is None:
            return None
        return self.admitted_at - self.submitted_at

    @property
    def service_s(self) -> Optional[float]:
        if self.admitted_at is None or self.finished_at is None:
            return None
        return self.finished_at - self.admitted_at

    @property
    def total_s(self) -> Optional[float]:
        if self.finished_at is None:
            return None
        return self.finished_at - self.submitted_at


class SlotScheduler:
    """FIFO queue + fixed slot table (continuous batching).

    Slots are integers in [0, max_slots); a slot is either free or bound to
    exactly one in-flight request.  ``admit`` moves queued requests into
    free slots (FIFO), ``release`` frees a slot when its request finishes —
    the next ``admit`` refills it, so a long-running request never blocks
    the batch (the continuous-batching property both servers rely on).
    """

    def __init__(self, max_slots: int):
        if max_slots <= 0:
            raise ValueError(f"max_slots must be positive, got {max_slots}")
        self.max_slots = int(max_slots)
        self.queue: List[object] = []
        self.active: Dict[int, object] = {}      # slot -> request
        self.timings: Dict[int, RequestTiming] = {}   # rid -> timing

    # -- queue ------------------------------------------------------------
    def submit(self, req) -> None:
        """Enqueue a request (stamped for latency accounting)."""
        if req.rid in self.timings:
            raise ValueError(
                f"duplicate request rid {req.rid}: timing/accounting is "
                "keyed by rid; use forget() after collecting a finished "
                "request to recycle its id")
        self.timings[req.rid] = RequestTiming(submitted_at=time.monotonic())
        self.queue.append(req)

    @property
    def free_slots(self) -> List[int]:
        return [s for s in range(self.max_slots) if s not in self.active]

    def has_work(self) -> bool:
        return bool(self.queue) or bool(self.active)

    # -- slot transitions -------------------------------------------------
    def admit(self) -> List[Tuple[int, object]]:
        """Bind queued requests to free slots (FIFO); returns the new
        (slot, request) assignments so the caller can initialize the
        device-resident state those slots hold."""
        assigned: List[Tuple[int, object]] = []
        free = self.free_slots
        now = time.monotonic()
        while free and self.queue:
            slot = free.pop(0)
            req = self.queue.pop(0)
            self.active[slot] = req
            self.timings[req.rid].admitted_at = now
            assigned.append((slot, req))
        return assigned

    def release(self, slot: int):
        """Free a slot whose request finished; returns the request."""
        req = self.active.pop(slot)
        self.timings[req.rid].finished_at = time.monotonic()
        return req

    def forget(self, rid: int) -> None:
        """Drop a finished request's timing record (long-lived servers
        prune per-request accounting after collecting results; without
        this the timings dict grows one entry per request forever)."""
        t = self.timings.get(rid)
        if t is not None and t.finished_at is not None:
            del self.timings[rid]

    # -- reporting --------------------------------------------------------
    def latency_summary(self) -> Dict[str, float]:
        """Mean/max total latency and queue wait over finished requests."""
        done = [t for t in self.timings.values()
                if t.finished_at is not None]
        if not done:
            return {"finished": 0}
        totals = [t.total_s for t in done]
        waits = [t.queue_wait_s for t in done]
        return {
            "finished": len(done),
            "mean_total_s": sum(totals) / len(done),
            "max_total_s": max(totals),
            "mean_queue_wait_s": sum(waits) / len(done),
        }
