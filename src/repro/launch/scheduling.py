"""Slot scheduling for continuous-batching servers.

Both serving front-ends — the transformer token server (launch/serve.py) and
the spiking-network stream server (launch/snn_serve.py) — share the same
shape: a fixed table of device-resident slots (KV-cache rows there, stream
lanes on the SNN vmap axis here), a FIFO queue of pending requests, and a
loop that admits queued requests into free slots, advances every occupied
slot in one compiled step, and evicts finished requests so their slots are
immediately reusable.  This module is that shared core, plus the
per-request latency accounting both servers report.

The serving gateway (launch/gateway.py) layers admission control on top and
needs three more primitives, all here rather than forked: priority-aware
FIFO (``submit(req, priority=...)`` — lower value runs first, FIFO within a
priority class), mid-flight eviction (``evict(rid)`` — deadline-expired
requests leave the queue or give their slot back without counting as
completions), and slot re-packing (``move``/``resize`` — the elastic-
capacity resize compacts active slots before shrinking the table).

Requests are arbitrary objects with an integer ``rid`` attribute; the
scheduler never inspects anything else.  Time comes from an injectable
``clock`` (default ``time.monotonic``) so deadline logic is testable.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

__all__ = ["RequestTiming", "SlotScheduler"]


@dataclasses.dataclass
class RequestTiming:
    """Wall-clock milestones of one request through the slot table.

    ``deadline_at``/``evicted_at`` are the gateway's SLO fields: a request
    past ``deadline_at`` is evicted at the next chunk boundary, stamping
    ``evicted_at`` (and ``finished_at``, so pruning via ``forget`` still
    works) — evicted requests are excluded from completion-latency
    percentiles and counted separately.
    """

    submitted_at: float
    admitted_at: Optional[float] = None
    finished_at: Optional[float] = None
    deadline_at: Optional[float] = None    # absolute; None = no deadline
    evicted_at: Optional[float] = None

    @property
    def queue_wait_s(self) -> Optional[float]:
        if self.admitted_at is None:
            return None
        return self.admitted_at - self.submitted_at

    @property
    def service_s(self) -> Optional[float]:
        if self.admitted_at is None or self.finished_at is None:
            return None
        return self.finished_at - self.admitted_at

    @property
    def total_s(self) -> Optional[float]:
        if self.finished_at is None:
            return None
        return self.finished_at - self.submitted_at

    @property
    def evicted(self) -> bool:
        return self.evicted_at is not None

    def deadline_exceeded(self, now: float) -> bool:
        """True when the request has a deadline and ``now`` is past it."""
        return self.deadline_at is not None and now > self.deadline_at


class SlotScheduler:
    """Priority FIFO queue + fixed slot table (continuous batching).

    Slots are integers in [0, max_slots); a slot is either free or bound to
    exactly one in-flight request.  ``admit`` moves queued requests into
    free slots (priority order, FIFO within a priority), ``release`` frees
    a slot when its request finishes — the next ``admit`` refills it, so a
    long-running request never blocks the batch (the continuous-batching
    property both servers rely on).  ``evict`` removes a request that will
    *not* finish (deadline expiry, load shedding) whether it is still
    queued or already holds a slot; evicting something already gone is a
    no-op, so callers can be sloppy about races between completion and
    deadline checks.
    """

    def __init__(self, max_slots: int,
                 clock: Callable[[], float] = time.monotonic):
        if max_slots <= 0:
            raise ValueError(f"max_slots must be positive, got {max_slots}")
        self.max_slots = int(max_slots)
        self.clock = clock
        self.queue: List[object] = []
        self.active: Dict[int, object] = {}      # slot -> request
        self.timings: Dict[int, RequestTiming] = {}   # rid -> timing
        self._priority: Dict[int, int] = {}      # rid -> submit priority
        self.evicted_total = 0

    # -- queue ------------------------------------------------------------
    def submit(self, req, priority: int = 0,
               deadline_at: Optional[float] = None) -> None:
        """Enqueue a request (stamped for latency accounting).

        ``priority``: lower runs first; equal priorities stay FIFO (stable
        insertion, so the default 0 everywhere degrades to plain FIFO).
        ``deadline_at``: absolute clock() time after which the request is
        eligible for eviction (the *caller* checks and calls evict —
        typically at chunk boundaries, where slots can actually be
        reclaimed).
        """
        if req.rid in self.timings:
            raise ValueError(
                f"duplicate request rid {req.rid}: timing/accounting is "
                "keyed by rid; use forget() after collecting a finished "
                "request to recycle its id")
        self.timings[req.rid] = RequestTiming(submitted_at=self.clock(),
                                              deadline_at=deadline_at)
        self._priority[req.rid] = int(priority)
        i = len(self.queue)
        while i > 0 and self._priority[self.queue[i - 1].rid] > priority:
            i -= 1
        self.queue.insert(i, req)

    @property
    def free_slots(self) -> List[int]:
        return [s for s in range(self.max_slots) if s not in self.active]

    def has_work(self) -> bool:
        return bool(self.queue) or bool(self.active)

    # -- slot transitions -------------------------------------------------
    def admit(self) -> List[Tuple[int, object]]:
        """Bind queued requests to free slots (priority FIFO); returns the
        new (slot, request) assignments so the caller can initialize the
        device-resident state those slots hold."""
        assigned: List[Tuple[int, object]] = []
        free = self.free_slots
        now = self.clock()
        while free and self.queue:
            slot = free.pop(0)
            req = self.queue.pop(0)
            self.active[slot] = req
            self.timings[req.rid].admitted_at = now
            assigned.append((slot, req))
        return assigned

    def release(self, slot: int):
        """Free a slot whose request finished; returns the request."""
        req = self.active.pop(slot)
        self.timings[req.rid].finished_at = self.clock()
        return req

    def evict(self, rid: int):
        """Remove a request that will not finish (deadline expiry, load
        shedding): a queued request leaves the queue, an in-flight request
        gives its slot back, an unknown/already-finished rid is a **no-op**
        (double-finish safe — deadline sweeps race with completions).
        Returns the request if one was actually evicted, else None; stamps
        ``evicted_at`` and ``finished_at`` so latency accounting and
        ``forget`` pruning keep working."""
        t = self.timings.get(rid)
        if t is None or t.finished_at is not None:
            return None
        for i, req in enumerate(self.queue):
            if req.rid == rid:
                del self.queue[i]
                now = self.clock()
                t.evicted_at = t.finished_at = now
                self.evicted_total += 1
                return req
        for slot, req in self.active.items():
            if req.rid == rid:
                del self.active[slot]
                now = self.clock()
                t.evicted_at = t.finished_at = now
                self.evicted_total += 1
                return req
        return None

    def expired(self, now: Optional[float] = None) -> List[object]:
        """Queued or in-flight requests whose deadline has passed (the
        chunk-boundary sweep calls this, then evicts each one)."""
        if now is None:
            now = self.clock()
        out = [r for r in self.queue
               if self.timings[r.rid].deadline_exceeded(now)]
        out += [r for _, r in sorted(self.active.items())
                if self.timings[r.rid].deadline_exceeded(now)]
        return out

    # -- slot re-packing (elastic capacity) --------------------------------
    def move(self, src: int, dst: int) -> None:
        """Re-bind the request in slot ``src`` to free slot ``dst`` (the
        elastic resize compacts active slots to the low end before
        shrinking the table; the caller must move the device-resident
        state the same way — CompiledModel.select_streams)."""
        if dst in self.active:
            raise ValueError(f"destination slot {dst} is occupied")
        self.active[dst] = self.active.pop(src)

    def resize(self, new_max: int) -> None:
        """Change the slot-table capacity between chunks.  Growing is
        always safe; shrinking requires every active slot to already be
        below the new capacity (compact with move() first)."""
        if new_max <= 0:
            raise ValueError(f"max_slots must be positive, got {new_max}")
        stranded = [s for s in self.active if s >= new_max]
        if stranded:
            raise ValueError(
                f"cannot shrink to {new_max} slots: active slot(s) "
                f"{sorted(stranded)} would be stranded; move() them first")
        self.max_slots = int(new_max)

    def forget(self, rid: int) -> None:
        """Drop a finished request's timing record (long-lived servers
        prune per-request accounting after collecting results; without
        this the timings dict grows one entry per request forever)."""
        t = self.timings.get(rid)
        if t is not None and t.finished_at is not None:
            del self.timings[rid]
            self._priority.pop(rid, None)

    # -- reporting --------------------------------------------------------
    def latency_summary(self) -> Dict[str, float]:
        """Mean/max total latency and queue wait over *completed* requests
        (evicted ones are not completions: their latency measures the
        deadline, not the service — they are counted, not averaged)."""
        done = [t for t in self.timings.values()
                if t.finished_at is not None and not t.evicted]
        evicted = sum(1 for t in self.timings.values() if t.evicted)
        if not done:
            return {"finished": 0, "evicted": evicted}
        totals = [t.total_s for t in done]
        waits = [t.queue_wait_s for t in done if t.queue_wait_s is not None]
        return {
            "finished": len(done),
            "evicted": evicted,
            "mean_total_s": sum(totals) / len(done),
            "max_total_s": max(totals),
            "mean_queue_wait_s": (sum(waits) / len(waits)) if waits else 0.0,
        }
