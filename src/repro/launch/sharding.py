"""Sharding rules: param/optimizer/batch/cache PartitionSpecs per mesh.

Strategy (DESIGN.md §3): tensor parallelism over "model", FSDP (ZeRO-3-style
parameter + optimizer sharding) over the batch axes ("data" or
("pod","data")).  Rules are *candidate* axes per trailing dim of each leaf;
allocation is greedy with divisibility + no-axis-reuse checks, so one rule
set serves every architecture (e.g. granite's 32 experts take the model axis,
mixtral's 8 leave it to the per-expert ffn dim automatically).

`activate(mesh)` binds the logical-axis env used by in-model
with_sharding_constraint calls (repro.models.layers.shard).
"""

from __future__ import annotations

import contextlib
import math
import re
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import batch_axes
from repro.models.layers import clear_axis_env, set_axis_env

__all__ = ["activate", "param_specs", "param_shardings", "batch_specs",
           "cache_shardings", "spec_tree_to_shardings",
           "neuron_pad", "pad_neuron_axis", "snn_shardings"]


@contextlib.contextmanager
def activate(mesh):
    """Bind logical axes for in-model sharding constraints."""
    ba = batch_axes(mesh)
    bs = math.prod(mesh.shape[a] for a in ba) if ba else 1
    ms = mesh.shape.get("model", 1)
    set_axis_env(ba, "model", bs, ms)
    try:
        with mesh:
            yield mesh
    finally:
        clear_axis_env()


# --------------------------------------------------------------------------
# rule table: path-regex -> candidate axes for the trailing dims.
# "fsdp" = the batch axes tuple; "model" = the model axis; None = replicated.
# Leading (stack) dims not covered by a rule are never sharded.
# --------------------------------------------------------------------------
_RULES: List[Tuple[str, List[Optional[str]]]] = [
    # order matters: first match wins; rules align to TRAILING dims so layer
    # stacks ([R, n, ...]) never shard their stack dims.
    (r"moe/(w_gate|w_up)$",       ["model", "fsdp", "model"]),  # [E, d, f]
    (r"moe/w_out$",               ["model", "model", "fsdp"]),  # [E, f, d]
    (r"moe/router$",              ["fsdp", None]),              # [d, E]
    (r"embed$",                   ["model", "fsdp"]),     # [V, d]
    (r"lm_head$",                 ["fsdp", "model"]),     # [d, V]
    (r"img_proj$",                [None, "fsdp"]),        # [1152, d]
    (r"pos_embed$",               [None, "fsdp"]),        # [Ta, d]
    (r"(wq|wk|wv)$",              ["fsdp", "model"]),     # [d, H*hd]
    (r"wo$",                      ["model", "fsdp"]),     # [H*hd, d]
    (r"(bq|bk|bv)$",              ["model"]),             # [H*hd]
    (r"ssm/w_in$",                ["fsdp", "model"]),
    (r"ssm/w_out$",               ["model", "fsdp"]),
    (r"(w_gate|w_up|w_in)$",      ["fsdp", "model"]),     # dense MLP [d, f]
    (r"w_out$",                   ["model", "fsdp"]),     # dense MLP [f, d]
    (r"conv_w$",                  [None, "model"]),       # [4, conv_dim]
    (r"conv_b$",                  ["model"]),
    (r"(dt_bias|A_log|D)$",       ["model"]),
    (r"norm_scale$",              ["model"]),             # [d_inner]
    (r"(scale|bias)$",            [None]),                # norms
]


def _leaf_path(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _alloc(shape: Tuple[int, ...], cands: List[Optional[str]],
           mesh) -> P:
    """Greedy allocation of candidate axes to the trailing dims of shape."""
    ba = batch_axes(mesh)
    bsz = math.prod(mesh.shape[a] for a in ba) if ba else 1
    msz = mesh.shape.get("model", 1)
    ndim = len(shape)
    k = len(cands)
    cands = list(cands)
    if k > ndim:
        cands = cands[k - ndim:]
        k = ndim
    spec: List[Any] = [None] * ndim
    used = set()
    for j, cand in enumerate(cands):
        dim = ndim - k + j
        size = shape[dim]
        if cand == "fsdp":
            if ba and "fsdp" not in used and size % bsz == 0:
                spec[dim] = ba if len(ba) > 1 else ba[0]
                used.add("fsdp")
        elif cand == "model":
            if "model" in mesh.axis_names and "model" not in used \
                    and size % msz == 0:
                spec[dim] = "model"
                used.add("model")
    return P(*spec)


def param_specs(params, mesh):
    """PartitionSpec pytree for a parameter tree."""

    def spec_of(path, leaf):
        p = _leaf_path(path)
        if not hasattr(leaf, "shape") or leaf.ndim == 0:
            return P()
        for pat, cands in _RULES:
            if re.search(pat, p):
                return _alloc(leaf.shape, cands, mesh)
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(spec_of, params)


def spec_tree_to_shardings(specs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def param_shardings(params, mesh):
    return spec_tree_to_shardings(param_specs(params, mesh), mesh)


def batch_specs(batch, mesh):
    """Shard the leading (batch) dim of every batch leaf on the batch axes."""
    ba = batch_axes(mesh)
    bsz = math.prod(mesh.shape[a] for a in ba) if ba else 1

    def spec_of(leaf):
        if not hasattr(leaf, "shape") or leaf.ndim == 0:
            return P()
        if leaf.shape[0] % bsz == 0:
            return P(ba if len(ba) > 1 else ba[0],
                     *([None] * (leaf.ndim - 1)))
        return P(*([None] * leaf.ndim))

    return jax.tree.map(spec_of, batch)


# --------------------------------------------------------------------------
# SNN neuron-axis partitioning (the sharded engine, repro.core.snn.engine):
# every population is split along its neuron dimension over the mesh's
# neuron axis; these helpers own the pad-to-divisible layout so the engine
# and tests agree on it.
# --------------------------------------------------------------------------

def neuron_pad(n: int, n_shards: int) -> int:
    """Smallest multiple of n_shards >= n (per-population padded size)."""
    return -(-n // n_shards) * n_shards


def pad_neuron_axis(x, n_pad: int, axis: int = 0):
    """Pad a per-neuron array to the sharded size, edge-replicating so the
    padded lanes carry benign (bounded-dynamics) values."""
    n = x.shape[axis]
    if n == n_pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, n_pad - n)
    return jnp.pad(x, widths, mode="edge")


def snn_shardings(mesh, axis: str):
    """The placements SNN engine state uses: per-neuron arrays split on
    `axis`, replicated scalars/full-pre vectors, [D, n_pre, K] per-shard
    connectivity blocks split on their leading device dim,
    [max_delay+1, n_post] dendritic-delay rings split on their post
    (trailing) dim — each device holds only its own post shard's ring —
    and [capacity, n] probe recording buffers, which shard their sample
    rows along the neuron axis the same way (reduced probes are scalar
    per sample and live replicated)."""
    return {
        "neuron": NamedSharding(mesh, P(axis)),
        "replicated": NamedSharding(mesh, P()),
        "block": NamedSharding(mesh, P(axis, None, None)),
        "ring": NamedSharding(mesh, P(None, axis)),
        "probe": NamedSharding(mesh, P(None, axis)),
    }


def cache_shardings(caches, mesh):
    """KV caches: batch dim on batch axes when divisible, else shard the
    sequence dim (long-context batch=1 decode); kv feature dims on model
    when divisible.  SSM states: batch then heads."""
    ba = batch_axes(mesh)
    bsz = math.prod(mesh.shape[a] for a in ba) if ba else 1
    msz = mesh.shape.get("model", 1)
    ba_spec = ba if len(ba) > 1 else (ba[0] if ba else None)

    def spec_of(path, leaf):
        if not hasattr(leaf, "shape") or leaf.ndim == 0:
            return NamedSharding(mesh, P())
        p = _leaf_path(path)
        shape = leaf.shape
        name = p.rsplit("/", 1)[-1]
        nd = leaf.ndim
        spec: List[Any] = [None] * nd
        if name in ("k", "v") and nd >= 4:
            # [..., B, S, kv, hd] with possible leading stack dims
            b_dim, s_dim, kv_dim = nd - 4, nd - 3, nd - 2
            if shape[b_dim] % bsz == 0 and ba:
                spec[b_dim] = ba_spec
            elif shape[s_dim] % bsz == 0 and ba:
                spec[s_dim] = ba_spec
            if shape[kv_dim] % msz == 0:
                spec[kv_dim] = "model"
            elif spec[s_dim] is None and shape[s_dim] % msz == 0:
                # kv heads don't divide the model axis (most GQA archs):
                # shard the sequence dim instead — attention against the
                # cache becomes a partial-softmax contraction + reduce
                # (flash-decoding), which GSPMD emits automatically, and
                # the cache memory actually scales with the mesh.
                spec[s_dim] = "model"
        elif name == "ssd" and nd >= 4:
            b_dim, h_dim = nd - 4, nd - 3
            if shape[b_dim] % bsz == 0 and ba:
                spec[b_dim] = ba_spec
            if shape[h_dim] % msz == 0:
                spec[h_dim] = "model"
        elif name == "conv" and nd >= 3:
            b_dim, c_dim = nd - 3, nd - 1
            if shape[b_dim] % bsz == 0 and ba:
                spec[b_dim] = ba_spec
            if shape[c_dim] % msz == 0:
                spec[c_dim] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(spec_of, caches)
