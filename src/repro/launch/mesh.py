"""Production mesh construction.

Single pod: 16x16 = 256 chips, axes ("data", "model").
Multi-pod:  2x16x16 = 512 chips, axes ("pod", "data", "model") — "pod" is an
outer data/FSDP axis crossing the inter-pod (DCN/ICI) links.

Functions, not module constants: importing this module must never touch JAX
device state (device count is locked at first backend init; the dry-run sets
XLA_FLAGS before importing anything).
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax

__all__ = ["make_mesh", "make_production_mesh", "make_local_mesh",
           "make_snn_mesh", "snn_axis", "batch_axes", "MeshPlan",
           "init_distributed"]

#: mesh axis the SNN engine partitions neuron populations over
SNN_AXIS = "neuron"

# process-wide: jax.distributed.initialize may run exactly once
_DISTRIBUTED = {"initialized": False}


def init_distributed(coordinator_address: Optional[str] = None,
                     num_processes: Optional[int] = None,
                     process_id: Optional[int] = None,
                     **kwargs) -> Tuple[int, int]:
    """Wire this process into a multi-host JAX runtime and return
    (process_index, process_count).

    Call once per process before building any mesh; afterwards
    `jax.devices()` spans every host, so `make_snn_mesh()` returns a
    mesh crossing hosts and `ModelSpec.build(init="device", mesh=...)`
    constructs each host's connectivity shards locally
    (`device_init_local`) — no host ever materializes the full ELL.

    With no arguments the coordinator/rank come from the environment
    (JAX_COORDINATOR_ADDRESS etc. / the cluster plugin); pass
    `coordinator_address="host:port"`, `num_processes`, `process_id`
    explicitly for bare multi-process launches.  Idempotent: a second
    call (or an already-initialized runtime) is a no-op."""
    if not _DISTRIBUTED["initialized"]:
        try:
            # the CPU backend needs an explicit cross-process collectives
            # implementation; must be set before the backend initializes,
            # which is exactly when this function runs.  No-op on GPU/TPU.
            jax.config.update("jax_cpu_collectives_implementation", "gloo")
        except (AttributeError, ValueError):
            pass  # older jax: CPU multi-process simply unsupported
        try:
            jax.distributed.initialize(
                coordinator_address=coordinator_address,
                num_processes=num_processes,
                process_id=process_id, **kwargs)
        except RuntimeError as e:
            # tolerate double-init (ours or a framework's): the runtime
            # is already up, which is all this function guarantees
            if "already" not in str(e).lower():
                raise
        _DISTRIBUTED["initialized"] = True
    return jax.process_index(), jax.process_count()


def _axis_type_kwargs(n: int) -> dict:
    """`axis_types` compatibility shim: jax.sharding.AxisType only exists in
    newer jax releases (and older jax.make_mesh rejects the kwarg).  Auto is
    the default there anyway, so omitting it preserves semantics."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n}


def make_mesh(shape, axes):
    kwargs = _axis_type_kwargs(len(axes))
    try:
        return jax.make_mesh(shape, axes, **kwargs)
    except TypeError:
        # jax new enough to have AxisType but make_mesh not accepting the
        # kwarg (or vice-versa mid-release): fall back to defaults.
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh(model_parallel: int = 1):
    """Mesh over whatever devices exist (tests / CPU runs)."""
    n = len(jax.devices())
    mp = max(1, min(model_parallel, n))
    return make_mesh((n // mp, mp), ("data", "model"))


def make_snn_mesh(n_devices: Optional[int] = None):
    """1-D mesh for the sharded SNN engine: populations are partitioned
    along the neuron axis (`SNN_AXIS`) over `n_devices` (default: all)."""
    n = len(jax.devices()) if n_devices is None else int(n_devices)
    return make_mesh((n,), (SNN_AXIS,))


def snn_axis(mesh) -> str:
    """The neuron-partition axis of a mesh: `SNN_AXIS` when present, else a
    single-axis mesh's only axis (so plain 1-D meshes work unrenamed)."""
    if SNN_AXIS in mesh.axis_names:
        return SNN_AXIS
    if len(mesh.axis_names) == 1:
        return mesh.axis_names[0]
    raise ValueError(
        f"mesh axes {mesh.axis_names} have no {SNN_AXIS!r} axis; build the "
        "mesh with make_snn_mesh or name one axis 'neuron'")


def batch_axes(mesh) -> Tuple[str, ...]:
    """Axes the global batch (and FSDP shards) ride on."""
    return tuple(a for a in mesh.axis_names if a != "model")


class MeshPlan:
    """Mesh + axis bookkeeping passed through launch entry points."""

    def __init__(self, mesh):
        self.mesh = mesh
        self.batch = batch_axes(mesh)
        self.model = "model" if "model" in mesh.axis_names else None

    @property
    def n_devices(self) -> int:
        return self.mesh.devices.size

    def __repr__(self) -> str:
        axes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        return f"MeshPlan({axes})"
