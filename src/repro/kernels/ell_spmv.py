"""Pallas TPU kernel: batched ELL sparse synaptic accumulation.

This is the paper's GPU hot loop (sparse spike propagation) re-thought for
TPU.  GeNN's CUDA kernel assigns one thread per (spike, synapse) and uses
atomics into shared memory.  TPUs have neither per-lane scatter nor atomics;
the idiomatic move is to turn the scatter into a *one-hot matmul* that runs on
the MXU:

    out[b, j] = sum_{i,k} spikes[b, i] * g[i, k] * [post_ind[i, k] == j]

For a (pre-block x post-block) tile we build the one-hot matrix
O[(i,k), j_local] in VMEM from the index tile and contract the spike tile
against it.  The batch dimension B (the conductance-scaling sweep uses it for
gScale candidates; the simulator for independent networks) makes the
contraction a real matmul instead of a matvec.

Grid layout: (post_blocks, pre_blocks) — pre is the minor (fastest) axis so
each output tile stays resident in VMEM while all pre-blocks accumulate into
it (revisiting pattern, init at pre_block==0).

Block sizes come from repro.kernels.autotune (occupancy model, paper §3).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.autotune import V5E, TPULimits, choose_block_spmv

__all__ = ["ell_spmv_pallas", "ell_spmv_delay_pallas", "default_blocks"]


def _kernel(spk_ref, g_ref, idx_ref, out_ref, *, bn: int):
    pb = pl.program_id(1)           # pre-block index (minor, accumulating)
    jb = pl.program_id(0)           # post-block index

    @pl.when(pb == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    spk = spk_ref[...]              # [B, BP]
    g = g_ref[...]                  # [BP, K]
    idx = idx_ref[...]              # [BP, K] global post indices (int32)

    bp, k = g.shape
    m = bp * k
    local = idx - jb * bn           # position inside this post tile
    flat = local.reshape(m)
    cols = jax.lax.broadcasted_iota(jnp.int32, (m, bn), 1)
    onehot = (flat[:, None] == cols).astype(g.dtype) * g.reshape(m)[:, None]

    # expand spikes along the K slots: S[b, (i,k)] = spk[b, i]
    s = jnp.broadcast_to(spk[:, :, None], (spk.shape[0], bp, k)).reshape(
        spk.shape[0], m)
    out_ref[...] += jax.lax.dot_general(
        s, onehot, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def _delay_kernel(spk_ref, g_ref, idx_ref, dly_ref, out_ref, *, bn: int,
                  n_slots: int):
    """Fused delay-scatter variant: the one-hot column index is the combined
    (delay_slot, local_post) coordinate, so one MXU contraction lands every
    synapse's contribution in its own dendritic-ring slot."""
    pb = pl.program_id(1)
    jb = pl.program_id(0)

    @pl.when(pb == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    spk = spk_ref[...]              # [B, BP]
    g = g_ref[...]                  # [BP, K]
    idx = idx_ref[...]              # [BP, K] global post indices (int32)
    dly = dly_ref[...]              # [BP, K] delay slots (int32)

    bp, k = g.shape
    m = bp * k
    local = idx - jb * bn
    # slots whose post lands outside this tile must NOT fold into a
    # neighboring delay band of the combined index: mask them to -1 (the
    # plain kernel gets this for free because its out-of-range locals miss
    # every one-hot column)
    inb = (local >= 0) & (local < bn)
    comb = jnp.where(inb, dly * bn + local, -1).reshape(m)
    cols = jax.lax.broadcasted_iota(jnp.int32, (m, n_slots * bn), 1)
    onehot = (comb[:, None] == cols).astype(g.dtype) * g.reshape(m)[:, None]

    s = jnp.broadcast_to(spk[:, :, None], (spk.shape[0], bp, k)).reshape(
        spk.shape[0], m)
    acc = jax.lax.dot_general(
        s, onehot, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    out_ref[...] += acc.reshape(spk.shape[0], n_slots, bn)


def default_blocks(n_pre: int, k: int, n_post: int, b: int,
                   lim: TPULimits = V5E) -> tuple[int, int]:
    """(pre_block, post_block) from the occupancy-based block-size
    determination (paper §3, repro.kernels.autotune.choose_block_spmv)."""
    cfg = choose_block_spmv(n_pre, k, n_post, b, lim=lim)
    return cfg["bp"], cfg["bn"]


def feasible_k_chunk(n_pre: int, k: int, n_post: int, b: int,
                     lim: TPULimits = V5E, n_slots: int = 1) -> tuple[int, dict]:
    """Largest K-chunk whose chosen tiling fits VMEM (the kernel loads
    full-K row tiles, so very wide rows must be split and the partial
    currents summed).  Returns (k_chunk, block config for that chunk)."""
    kc = k
    while True:
        cfg = choose_block_spmv(n_pre, kc, n_post, b, lim=lim,
                                n_slots=n_slots)
        if cfg["feasible"] or kc == 1:
            return kc, cfg
        kc = (kc + 1) // 2


@functools.partial(
    jax.jit,
    static_argnames=("n_post", "pre_block", "post_block", "interpret"))
def ell_spmv_pallas(
    g: jax.Array, post_ind: jax.Array, valid: jax.Array, spikes: jax.Array,
    *, n_post: int, pre_block: int | None = None,
    post_block: int | None = None, interpret: bool = False,
) -> jax.Array:
    """Batched ELL spmv on TPU.  g/post_ind/valid: [n_pre, K];
    spikes: [B, n_pre] -> [B, n_post].

    When no (bp, bn) tiling of the full K width fits VMEM (K beyond a few
    thousand slots), the rows are split into feasible K-chunks, each
    launched separately, and the partial currents summed."""
    n_pre, k = g.shape
    b = spikes.shape[0]

    if pre_block is None and post_block is None:
        kc, cfg = feasible_k_chunk(n_pre, k, n_post, b)
        if kc < k:
            out = jnp.zeros((b, n_post), jnp.float32)
            for lo in range(0, k, kc):
                out = out + ell_spmv_pallas(
                    g[:, lo:lo + kc], post_ind[:, lo:lo + kc],
                    valid[:, lo:lo + kc], spikes, n_post=n_post,
                    pre_block=cfg["bp"], post_block=cfg["bn"],
                    interpret=interpret)
            return out
        pre_block, post_block = cfg["bp"], cfg["bn"]
    elif pre_block is None or post_block is None:
        dbp, dbn = default_blocks(n_pre, k, n_post, b)
        pre_block = pre_block or dbp
        post_block = post_block or dbn

    gm = jnp.where(valid, g, 0.0).astype(jnp.float32)

    # pad to block multiples (padded g rows are zero => no contribution;
    # padded post columns are sliced off)
    pp = math.ceil(n_pre / pre_block) * pre_block
    pj = math.ceil(n_post / post_block) * post_block
    if pp != n_pre:
        pad = pp - n_pre
        gm = jnp.pad(gm, ((0, pad), (0, 0)))
        post_ind = jnp.pad(post_ind, ((0, pad), (0, 0)))
        spikes = jnp.pad(spikes, ((0, 0), (0, pad)))

    grid = (pj // post_block, pp // pre_block)
    out = pl.pallas_call(
        functools.partial(_kernel, bn=post_block),
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, pre_block), lambda jb, pb: (0, pb)),
            pl.BlockSpec((pre_block, k), lambda jb, pb: (pb, 0)),
            pl.BlockSpec((pre_block, k), lambda jb, pb: (pb, 0)),
        ],
        out_specs=pl.BlockSpec((b, post_block), lambda jb, pb: (0, jb)),
        out_shape=jax.ShapeDtypeStruct((b, pj), jnp.float32),
        interpret=interpret,
    )(spikes.astype(jnp.float32), gm, post_ind.astype(jnp.int32))
    return out[:, :n_post]


@functools.partial(
    jax.jit,
    static_argnames=("n_post", "n_slots", "pre_block", "post_block",
                     "interpret"))
def ell_spmv_delay_pallas(
    g: jax.Array, post_ind: jax.Array, valid: jax.Array, delay: jax.Array,
    spikes: jax.Array, *, n_post: int, n_slots: int,
    pre_block: int | None = None, post_block: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Fused delay-scatter ELL spmv on TPU: one pass over the slots lands
    each synapse's contribution at its (delay_slot, post) ring coordinate.

    g/post_ind/valid/delay: [n_pre, K]; spikes: [B, n_pre]
    -> [B, n_slots, n_post].  Semantics: repro.kernels.ref.ell_spmv_delay_ref.
    Replaces n_slots masked single-delay passes with one kernel launch."""
    n_pre, k = g.shape
    b = spikes.shape[0]

    if pre_block is None and post_block is None:
        kc, cfg = feasible_k_chunk(n_pre, k, n_post, b, n_slots=n_slots)
        if kc < k:
            out = jnp.zeros((b, n_slots, n_post), jnp.float32)
            for lo in range(0, k, kc):
                out = out + ell_spmv_delay_pallas(
                    g[:, lo:lo + kc], post_ind[:, lo:lo + kc],
                    valid[:, lo:lo + kc], delay[:, lo:lo + kc], spikes,
                    n_post=n_post, n_slots=n_slots,
                    pre_block=cfg["bp"], post_block=cfg["bn"],
                    interpret=interpret)
            return out
        pre_block, post_block = cfg["bp"], cfg["bn"]
    elif pre_block is None or post_block is None:
        cfg = choose_block_spmv(n_pre, k, n_post, b, n_slots=n_slots)
        pre_block = pre_block or cfg["bp"]
        post_block = post_block or cfg["bn"]

    gm = jnp.where(valid, g, 0.0).astype(jnp.float32)
    dly = jnp.where(valid, delay, 0).astype(jnp.int32)

    pp = math.ceil(n_pre / pre_block) * pre_block
    pj = math.ceil(n_post / post_block) * post_block
    if pp != n_pre:
        pad = pp - n_pre
        gm = jnp.pad(gm, ((0, pad), (0, 0)))
        post_ind = jnp.pad(post_ind, ((0, pad), (0, 0)))
        dly = jnp.pad(dly, ((0, pad), (0, 0)))
        spikes = jnp.pad(spikes, ((0, 0), (0, pad)))

    grid = (pj // post_block, pp // pre_block)
    out = pl.pallas_call(
        functools.partial(_delay_kernel, bn=post_block, n_slots=n_slots),
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, pre_block), lambda jb, pb: (0, pb)),
            pl.BlockSpec((pre_block, k), lambda jb, pb: (pb, 0)),
            pl.BlockSpec((pre_block, k), lambda jb, pb: (pb, 0)),
            pl.BlockSpec((pre_block, k), lambda jb, pb: (pb, 0)),
        ],
        out_specs=pl.BlockSpec((b, n_slots, post_block),
                               lambda jb, pb: (0, 0, jb)),
        out_shape=jax.ShapeDtypeStruct((b, n_slots, pj), jnp.float32),
        interpret=interpret,
    )(spikes.astype(jnp.float32), gm, post_ind.astype(jnp.int32), dly)
    return out[:, :, :n_post]
