"""Occupancy-based block-size determination, adapted from paper §3 to TPU.

The paper picks CUDA block sizes by computing *occupancy* — resident warps per
SM limited by four bottlenecks (threads, blocks/SM, shared memory, registers)
— and choosing the smallest block size that still hides memory latency.

TPU has no warps/SMs, but the same shape of reasoning applies to Pallas tiles:

  bottleneck (CUDA)            ->  bottleneck (TPU / Pallas)
  threads per block            ->  lane/sublane alignment (last dim % 128,
                                   second-minor % 8 for f32, % 16 bf16)
  shared memory per SM         ->  VMEM working set per grid step (incl. the
                                   x2 for Mosaic's automatic double-buffering)
  registers                    ->  VREGs; proxied by the per-block footprint
  blocks per SM / grid width   ->  grid steps per TensorCore: enough grid
                                   parallelism to hide HBM->VMEM latency

`occupancy()` scores a candidate tile; `choose_block*` enumerate aligned
candidates and pick the max-occupancy one (ties -> larger tile, fewer grid
steps).  The same calculator drives every kernel in this package and is
exported as a benchmark table (bench_occupancy_blocksize).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Sequence, Tuple

from repro.obs import trace as _trace

__all__ = [
    "TPULimits", "V5E", "occupancy", "choose_block_elementwise",
    "choose_block_matmul", "choose_block_spmv", "spmv_block_bytes",
    "choose_propagation", "occupancy_report",
]


@dataclasses.dataclass(frozen=True)
class TPULimits:
    """Per-core resource limits (v5e defaults)."""

    vmem_bytes: int = 16 * 1024 * 1024     # usable VMEM per core
    lane: int = 128                        # vector lane count
    sublane_f32: int = 8                   # sublanes per vreg (f32)
    mxu: int = 128                         # MXU systolic dim
    min_grid_per_core: int = 2             # grid steps to overlap DMA/compute
    double_buffer: int = 2                 # Mosaic pipelines in/out buffers


V5E = TPULimits()


def _align_penalty(shape: Tuple[int, ...], dtype_bytes: int,
                   lim: TPULimits) -> float:
    """1.0 when hardware-aligned, <1 when padding would waste lanes."""
    if not shape:
        return 1.0
    last = shape[-1]
    sub = shape[-2] if len(shape) >= 2 else 1
    lane_eff = min(1.0, last / math.ceil(last / lim.lane) / lim.lane)
    sublane_quota = lim.sublane_f32 * (4 // max(1, dtype_bytes))
    sub_eff = min(1.0, sub / math.ceil(sub / sublane_quota) / sublane_quota)
    return lane_eff * sub_eff


def occupancy(
    block_bytes: int, grid_steps: int, shapes: Sequence[Tuple[int, ...]],
    dtype_bytes: int = 4, lim: TPULimits = V5E,
) -> float:
    """Occupancy in [0, 1]: how well this tiling hides memory latency.

    block_bytes: total VMEM working set of ONE grid step (all operands+outputs)
    grid_steps:  number of grid steps the kernel launches on this core
    shapes:      per-operand block shapes (for alignment scoring)
    """
    need = block_bytes * lim.double_buffer
    if need > lim.vmem_bytes:
        return 0.0
    # VMEM term: fraction of VMEM left as headroom counts *against* nothing,
    # but being able to hold >=2 in-flight buffers is required (double_buffer)
    # and >=2 grid steps are needed so DMA for step i+1 overlaps compute of i.
    grid_term = min(1.0, grid_steps / lim.min_grid_per_core)
    align_term = 1.0
    for s in shapes:
        align_term = min(align_term, _align_penalty(s, dtype_bytes, lim))
    # Prefer tiles that use a healthy fraction of VMEM (big tiles amortize
    # control overhead) without exceeding it — mirrors "enough resident
    # warps" without "register spill".
    util = need / lim.vmem_bytes
    util_term = min(1.0, 0.25 + util)  # soft ramp; full credit at 75%+ usage
    return grid_term * align_term * util_term


def _pow2s(lo: int, hi: int):
    v = lo
    while v <= hi:
        yield v
        v *= 2


def choose_block_elementwise(
    n: int, arrays: int, dtype_bytes: int = 4, lim: TPULimits = V5E,
) -> Tuple[int, int]:
    """Tile a length-n elementwise op reshaped to (rows, 128).

    Returns (block_rows, grid_steps). `arrays` counts ins+outs resident."""
    rows = math.ceil(n / lim.lane)
    best = (lim.sublane_f32, 1, -1.0)
    for br in _pow2s(lim.sublane_f32, max(lim.sublane_f32, 1 << 14)):
        if br > rows and br != lim.sublane_f32:
            break
        grid = math.ceil(rows / br)
        bytes_ = br * lim.lane * dtype_bytes * arrays
        occ = occupancy(bytes_, grid, [(br, lim.lane)], dtype_bytes, lim)
        score = (occ, br)  # ties -> bigger block
        if score > (best[2], best[0]):
            best = (br, grid, occ)
    return best[0], best[1]


def choose_block_matmul(
    m: int, n: int, k: int, dtype_bytes: int = 4, lim: TPULimits = V5E,
    candidates: Sequence[int] = (128, 256, 512, 1024, 2048),
) -> Dict[str, int]:
    """Pick (bm, bn, bk) for a tiled matmul C[m,n] += A[m,k] B[k,n]."""
    best = None
    for bm in candidates:
        if bm > max(m, lim.mxu):
            continue
        for bn in candidates:
            if bn > max(n, lim.mxu):
                continue
            for bk in candidates:
                if bk > max(k, lim.mxu):
                    continue
                blk = (bm * bk + bk * bn + bm * bn) * dtype_bytes
                grid = (math.ceil(m / bm) * math.ceil(n / bn)
                        * math.ceil(k / bk))
                occ = occupancy(blk, grid, [(bm, bk), (bk, bn), (bm, bn)],
                                dtype_bytes, lim)
                # secondary objective: arithmetic intensity ~ 1/(1/bm+1/bn)
                ai = 1.0 / (1.0 / bm + 1.0 / bn)
                key = (occ, ai)
                if best is None or key > best[0]:
                    best = (key, {"bm": bm, "bn": bn, "bk": bk,
                                  "occupancy": occ, "grid": grid})
    assert best is not None
    return best[1]


def spmv_block_bytes(bp: int, bn: int, k: int, b: int,
                     dtype_bytes: int = 4, n_slots: int = 1) -> int:
    """VMEM working set of one ELL-spmv grid step (repro.kernels.ell_spmv):
    spike tile [B, BP], g + idx tiles [BP, K], output tile [B, BN], plus the
    in-kernel one-hot materialization [BP*K, BN] and the K-expanded spike
    tile [B, BP*K] — the one-hot temporary is the VMEM driver.

    n_slots > 1 describes the fused-delay variant: a third [BP, K] row tile
    (the delay slots) and a (delay, post)-combined one-hot/output whose post
    extent is n_slots * BN."""
    m = bp * k
    row_tiles = 2 if n_slots == 1 else 3
    return (b * bp + row_tiles * bp * k + b * bn * n_slots
            + m * bn * n_slots + b * m) * dtype_bytes


def choose_block_spmv(
    n_pre: int, k: int, n_post: int, b: int, dtype_bytes: int = 4,
    lim: TPULimits = V5E, tag: str = "", n_slots: int = 1,
) -> Dict[str, int]:
    """Pick (bp, bn) tiles for the ELL one-hot-matmul spmv via the
    occupancy model (paper §3: smallest block that still hides latency;
    ties prefer larger tiles / fewer grid steps).

    The kernel loads full-K row tiles, so for very wide rows (K beyond a
    few thousand slots) *no* (bp, bn) fits VMEM: the result then carries
    ``feasible: False`` and the minimum (8, 128) tiling — callers
    (repro.kernels.ell_spmv) split K into feasible chunks and sum.

    Every decision is recorded as a ``choose_block_spmv`` trace instant
    (repro.obs.trace) carrying the problem shape, chosen tile, occupancy
    and VMEM footprint; ``tag`` attributes it (e.g. a synapse group name).

    n_slots > 1 sizes the fused-delay variant (repro.kernels.ell_spmv.
    ell_spmv_delay_pallas): output and one-hot tiles grow by the number of
    dendritic-delay ring slots.
    """
    bn_candidates = [bn for bn in (128, 256, 512, 1024)
                     if bn <= max(128, math.ceil(n_post / lim.lane)
                                  * lim.lane)]
    best = None
    for bn in bn_candidates:
        bp = lim.sublane_f32
        while bp <= max(lim.sublane_f32, 1 << 14):
            if bp > n_pre and bp != lim.sublane_f32:
                break
            grid = math.ceil(n_post / bn) * math.ceil(n_pre / bp)
            blk = spmv_block_bytes(bp, bn, k, b, dtype_bytes, n_slots)
            occ = occupancy(blk, grid,
                            [(bp, k), (b, bp), (b, bn * n_slots),
                             (bp * k, bn * n_slots)],
                            dtype_bytes, lim)
            key = (occ, bp * bn)           # ties -> bigger tile
            if best is None or key > best[0]:
                best = (key, {"bp": bp, "bn": bn, "occupancy": occ,
                              "grid": grid,
                              "block_bytes": blk, "feasible": occ > 0.0})
            bp *= 2
    if best is None or best[0][0] <= 0.0:
        blk = spmv_block_bytes(lim.sublane_f32, lim.lane, k, b, dtype_bytes,
                               n_slots)
        cfg = {"bp": lim.sublane_f32, "bn": lim.lane, "occupancy": 0.0,
               "grid": (math.ceil(n_post / lim.lane)
                        * math.ceil(n_pre / lim.sublane_f32)),
               "block_bytes": blk,
               "feasible": blk * lim.double_buffer <= lim.vmem_bytes}
    else:
        cfg = best[1]
    _trace.instant("choose_block_spmv", tag=tag, n_pre=n_pre, k=k,
                   n_post=n_post, b=b, n_slots=n_slots, **cfg)
    return cfg


def choose_propagation(
    n_pre: int, k: int, n_post: int, b: int = 1, activity: float = 0.1,
    capacity: int | None = None, n_slots: int = 1, dtype_bytes: int = 4,
    lim: TPULimits = V5E, tag: str = "",
) -> Dict[str, object]:
    """Occupancy/activity-model crossover: dense full-matrix spmv vs
    event-driven row gathering for one synapse group (paper's sparse
    synapse-connection representation; cf. GeNN's sparse spike delivery).

    Dense traverses all n_pre*K ELL slots every step.  Event-driven compacts
    the spiking pre-neuron index list into a fixed-capacity buffer (overflow
    falls back to dense at runtime) and gathers only those rows, paying an
    O(n_pre) compaction sweep per step.  ``activity`` is the modelled mean
    firing fraction per step; the capacity gets ~2.5x headroom over it so
    typical fluctuations stay on the fast path, rounded up to the sublane
    quantum and clamped to n_pre.

    Picks "event" only when (a) the modelled event slot traffic is at most
    half the dense traffic — the compaction/gather overhead needs a clear
    win — (b) the matrix is big enough (>= 32768 slots) to amortize the
    fixed per-step compaction cost, and (c) the compacted problem still has
    a feasible spmv tiling.  Returns mode, capacity, both block configs and
    the modelled slot counts; records a ``choose_propagation`` trace
    instant.
    """
    if capacity is None:
        q = lim.sublane_f32
        cap = math.ceil(n_pre * activity * 2.5 / q) * q
        cap = int(min(n_pre, max(q, cap)))
    else:
        cap = int(min(n_pre, max(1, capacity)))
    dense_slots = n_pre * k
    event_slots = cap * k + n_pre      # gathered rows + compaction sweep
    dense_cfg = choose_block_spmv(n_pre, k, n_post, b, dtype_bytes, lim,
                                  tag=f"{tag}:dense", n_slots=n_slots)
    event_cfg = choose_block_spmv(cap, k, n_post, b, dtype_bytes, lim,
                                  tag=f"{tag}:event", n_slots=n_slots)
    worthwhile = (dense_slots >= 32768
                  and 2 * event_slots <= dense_slots
                  and event_cfg["feasible"])
    mode = "event" if worthwhile else "dense"
    cfg = {"mode": mode, "capacity": cap, "activity": activity,
           "dense_slots": dense_slots, "event_slots": event_slots,
           "dense_occupancy": dense_cfg["occupancy"],
           "event_occupancy": event_cfg["occupancy"]}
    _trace.instant("choose_propagation", tag=tag, n_pre=n_pre, k=k,
                   n_post=n_post, b=b, n_slots=n_slots, **cfg)
    return cfg


def occupancy_report(lim: TPULimits = V5E) -> str:
    """The paper-style block-size table (benchmarked in bench_occupancy)."""
    lines = ["workload,block,grid,occupancy"]
    for n in (1 << 12, 1 << 16, 1 << 20):
        br, grid = choose_block_elementwise(n, arrays=6, lim=lim)
        occ = occupancy(br * lim.lane * 4 * 6, grid, [(br, lim.lane)], 4, lim)
        lines.append(f"elementwise_n={n},({br}x128),{grid},{occ:.3f}")
    for m, n, k in ((512, 512, 512), (4096, 4096, 4096), (8192, 1024, 8192)):
        cfg = choose_block_matmul(m, n, k, 2, lim)
        lines.append(
            f"matmul_{m}x{n}x{k},({cfg['bm']}x{cfg['bn']}x{cfg['bk']}),"
            f"{cfg['grid']},{cfg['occupancy']:.3f}")
    return "\n".join(lines)
