"""Pallas TPU kernel: fused Izhikevich neuron update.

GeNN's generated neuron kernels are elementwise state updates with one thread
per neuron.  The TPU version reshapes the population to (rows, 128) lanes and
fuses the two V half-steps, the U update, spike detection and reset into one
VPU pass — one HBM round-trip for the whole update instead of one per
statement.  Block rows come from the occupancy model.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.autotune import choose_block_elementwise

__all__ = ["izhikevich_step_pallas"]

_LANE = 128


def _kernel(v_ref, u_ref, isyn_ref, a_ref, b_ref, c_ref, d_ref,
            vout_ref, uout_ref, spk_ref, *, dt: float):
    v = v_ref[...]
    u = u_ref[...]
    isyn = isyn_ref[...]
    a, b, c, d = a_ref[...], b_ref[...], c_ref[...], d_ref[...]

    v = v + 0.5 * dt * (0.04 * v * v + 5.0 * v + 140.0 - u + isyn)
    v = v + 0.5 * dt * (0.04 * v * v + 5.0 * v + 140.0 - u + isyn)
    u = u + dt * a * (b * v - u)
    v = jnp.minimum(v, 30.0)
    spiked = v >= 29.99
    vout_ref[...] = jnp.where(spiked, c, v)
    uout_ref[...] = jnp.where(spiked, u + d, u)
    spk_ref[...] = spiked


def _to_2d(x: jax.Array, rows: int) -> jax.Array:
    n = x.shape[0]
    pad = rows * _LANE - n
    return jnp.pad(x, (0, pad)).reshape(rows, _LANE)


@functools.partial(jax.jit, static_argnames=("dt", "block_rows", "interpret"))
def izhikevich_step_pallas(
    v, u, isyn, a, b, c, d, *, dt: float, block_rows: int | None = None,
    interpret: bool = False,
):
    """All inputs [n] f32 (params may be per-neuron arrays).
    Returns (v', u', spiked) with shapes [n], [n], [n](bool)."""
    n = v.shape[0]
    rows = math.ceil(n / _LANE)
    if block_rows is None:
        block_rows, _ = choose_block_elementwise(n, arrays=10)
    block_rows = min(block_rows, rows)
    grid_rows = math.ceil(rows / block_rows) * block_rows

    args = [_to_2d(jnp.broadcast_to(jnp.asarray(x, jnp.float32), (n,)),
                   grid_rows)
            for x in (v, u, isyn, a, b, c, d)]

    spec = pl.BlockSpec((block_rows, _LANE), lambda i: (i, 0))
    vout, uout, spk = pl.pallas_call(
        functools.partial(_kernel, dt=dt),
        grid=(grid_rows // block_rows,),
        in_specs=[spec] * 7,
        out_specs=[spec] * 3,
        out_shape=[
            jax.ShapeDtypeStruct((grid_rows, _LANE), jnp.float32),
            jax.ShapeDtypeStruct((grid_rows, _LANE), jnp.float32),
            jax.ShapeDtypeStruct((grid_rows, _LANE), jnp.bool_),
        ],
        interpret=interpret,
    )(*args)
    return (vout.reshape(-1)[:n], uout.reshape(-1)[:n],
            spk.reshape(-1)[:n])
