"""Pallas TPU kernel: fused Traub-Miles Hodgkin-Huxley update.

The HH update is ~40 flops + 6 transcendentals per neuron per step on 5
state/input arrays — arithmetic-intensity-rich for an elementwise op, so the
win is fusing everything (V, gating rates, 3 gate updates, clips) into a
single VMEM-resident pass.  Same (rows x 128) layout as izhikevich_step.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.autotune import choose_block_elementwise

__all__ = ["hh_step_pallas"]

_LANE = 128


def _vtrap(x):
    return jnp.where(jnp.abs(x) > 1e-4,
                     x / (jnp.exp(x) - 1.0), 1.0 - x / 2.0)


def _kernel(v_ref, m_ref, h_ref, n_ref, isyn_ref,
            vo_ref, mo_ref, ho_ref, no_ref, *, dt, substeps, gNa, ENa, gK,
            EK, gl, El, C):
    v = v_ref[...]
    m = m_ref[...]
    h = h_ref[...]
    n = n_ref[...]
    isyn = isyn_ref[...]
    hdt = dt / substeps

    def body(_, carry):
        v, m, h, n = carry
        imem = -(m * m * m * h * gNa * (v - ENa)
                 + n * n * n * n * gK * (v - EK) + gl * (v - El) - isyn)
        v = v + hdt * imem / C
        a_m = 1.28 * _vtrap((-52.0 - v) / 4.0)
        b_m = 1.4 * _vtrap((v + 25.0) / 5.0)
        a_h = 0.128 * jnp.exp((-48.0 - v) / 18.0)
        b_h = 4.0 / (jnp.exp((-25.0 - v) / 5.0) + 1.0)
        a_n = 0.16 * _vtrap((-50.0 - v) / 5.0)
        b_n = 0.5 * jnp.exp((-55.0 - v) / 40.0)
        m = jnp.clip(m + hdt * (a_m * (1.0 - m) - b_m * m), 0.0, 1.0)
        h = jnp.clip(h + hdt * (a_h * (1.0 - h) - b_h * h), 0.0, 1.0)
        n = jnp.clip(n + hdt * (a_n * (1.0 - n) - b_n * n), 0.0, 1.0)
        return v, m, h, n

    v, m, h, n = jax.lax.fori_loop(0, substeps, body, (v, m, h, n))
    vo_ref[...] = v
    mo_ref[...] = m
    ho_ref[...] = h
    no_ref[...] = n


def _to_2d(x, rows):
    n = x.shape[0]
    return jnp.pad(x, (0, rows * _LANE - n)).reshape(rows, _LANE)


@functools.partial(jax.jit, static_argnames=(
    "dt", "substeps", "gNa", "ENa", "gK", "EK", "gl", "El", "C",
    "block_rows", "interpret"))
def hh_step_pallas(
    v, m, h, n, isyn, *, dt: float, substeps: int = 5, gNa=7.15, ENa=50.0,
    gK=1.43, EK=-95.0, gl=0.02672, El=-63.563, C=0.143,
    block_rows: int | None = None, interpret: bool = False,
):
    nn = v.shape[0]
    rows = math.ceil(nn / _LANE)
    if block_rows is None:
        block_rows, _ = choose_block_elementwise(nn, arrays=9)
    block_rows = min(block_rows, rows)
    grid_rows = math.ceil(rows / block_rows) * block_rows

    # pad V with a safe resting value so rate denominators stay finite
    pad = grid_rows * _LANE - nn
    vp = jnp.pad(jnp.asarray(v, jnp.float32), (0, pad),
                 constant_values=-60.0).reshape(grid_rows, _LANE)
    args = [vp] + [
        _to_2d(jnp.asarray(x, jnp.float32), grid_rows)
        for x in (m, h, n, isyn)]

    spec = pl.BlockSpec((block_rows, _LANE), lambda i: (i, 0))
    shp = jax.ShapeDtypeStruct((grid_rows, _LANE), jnp.float32)
    vo, mo, ho, no = pl.pallas_call(
        functools.partial(_kernel, dt=dt, substeps=substeps, gNa=gNa,
                          ENa=ENa, gK=gK, EK=EK, gl=gl, El=El, C=C),
        grid=(grid_rows // block_rows,),
        in_specs=[spec] * 5,
        out_specs=[spec] * 4,
        out_shape=[shp] * 4,
        interpret=interpret,
    )(*args)
    return (vo.reshape(-1)[:nn], mo.reshape(-1)[:nn],
            ho.reshape(-1)[:nn], no.reshape(-1)[:nn])
