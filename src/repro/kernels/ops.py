"""Jit'd dispatching wrappers around the Pallas kernels.

Dispatch policy (see DESIGN.md §3):
  REPRO_USE_PALLAS=1          -> compiled Pallas kernels (real TPU)
  REPRO_USE_PALLAS=interpret  -> Pallas interpret mode (CPU validation)
  unset/0                     -> pure-jnp reference (CPU dry-runs, rooflines)

The public functions keep one signature regardless of backend so the rest of
the system never branches.
"""

from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref

__all__ = [
    "backend", "use_pallas", "ell_spmv", "ell_spmv_batched",
    "izhikevich_step", "hh_step", "flash_attention", "ssd_scan",
]


def backend() -> str:
    v = os.environ.get("REPRO_USE_PALLAS", "0").lower()
    if v in ("1", "true", "tpu"):
        return "pallas"
    if v == "interpret":
        return "interpret"
    return "ref"


def use_pallas() -> bool:
    return backend() != "ref"


# -- sparse synaptic accumulation -------------------------------------------

def ell_spmv_batched(ell, spikes: jax.Array) -> jax.Array:
    """spikes [B, n_pre] -> currents [B, n_post]."""
    be = backend()
    if be == "ref":
        return _ref.ell_spmv_ref(ell.g, ell.post_ind, ell.valid, spikes,
                                 ell.n_post)
    from repro.kernels.ell_spmv import ell_spmv_pallas
    return ell_spmv_pallas(ell.g, ell.post_ind, ell.valid, spikes,
                           n_post=ell.n_post,
                           interpret=(be == "interpret"))


def ell_spmv(ell, spikes: jax.Array) -> jax.Array:
    """spikes [n_pre] -> currents [n_post]."""
    return ell_spmv_batched(ell, spikes[None, :])[0]


# -- fused neuron updates -----------------------------------------------------

def izhikevich_step(v, u, isyn, a, b, c, d, dt: float):
    be = backend()
    if be == "ref":
        return _ref.izhikevich_step_ref(v, u, isyn, a, b, c, d, dt)
    from repro.kernels.izhikevich_step import izhikevich_step_pallas
    n = v.shape[0]
    bcast = lambda x: jnp.broadcast_to(jnp.asarray(x, jnp.float32), (n,))
    return izhikevich_step_pallas(
        v, u, isyn, bcast(a), bcast(b), bcast(c), bcast(d), dt=dt,
        interpret=(be == "interpret"))


def hh_step(v, m, h, n, isyn, dt: float, **params):
    be = backend()
    if be == "ref":
        return _ref.hh_step_ref(v, m, h, n, isyn, dt, **params)
    from repro.kernels.hh_step import hh_step_pallas
    return hh_step_pallas(v, m, h, n, isyn, dt=dt,
                          interpret=(be == "interpret"), **params)


# -- LM kernels ---------------------------------------------------------------

def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    scale: Optional[float] = None, q_offset: int = 0,
                    softcap: Optional[float] = None,
                    prefix: Optional[int] = None):
    from repro import flags
    be = backend()
    if flags.ROOFLINE_NO_ATTN:
        # identity-shaped stand-in: costs of projections remain, core gone
        rep = q.shape[1] // k.shape[1]
        return q * (scale or 1.0) + jnp.repeat(v, rep, axis=1).mean(
            axis=2, keepdims=True)
    if flags.ROOFLINE_NAIVE_ATTN:
        return _ref.flash_attention_ref(
            q, k, v, causal=causal, window=window, scale=scale,
            q_offset=q_offset, softcap=softcap, prefix=prefix)
    if isinstance(window, jax.core.Tracer):
        # traced window (not produced by the built-in archs): masked XLA path
        return _ref.flash_attention_xla(
            q, k, v, causal=causal, window=window, scale=scale,
            q_offset=q_offset, softcap=softcap, prefix=prefix)
    if be == "ref":
        if q.shape[2] * k.shape[2] <= 1024 * 1024:
            return _ref.flash_attention_ref(
                q, k, v, causal=causal, window=window, scale=scale,
                q_offset=q_offset, softcap=softcap, prefix=prefix)
        from repro.kernels.flash_xla import flash_attention_xla
        return flash_attention_xla(q, k, v, causal, window, scale,
                                   q_offset, softcap, prefix)
    if prefix is not None:
        # prefix-LM masking not in the Pallas kernel (VLM prefill only)
        return _ref.flash_attention_xla(
            q, k, v, causal=causal, window=window, scale=scale,
            q_offset=q_offset, softcap=softcap, prefix=prefix)
    from repro.kernels.flash_attention import flash_attention_pallas
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, scale=scale,
        q_offset=q_offset, softcap=softcap,
        interpret=(be == "interpret"))


def ssd_scan(x, dt, A, B, C, D=None):
    from repro import flags
    if flags.ROOFLINE_NO_SSD:
        return x * dt[..., None] + C.mean(axis=(2, 3))[..., None, None]
    be = backend()
    if be == "ref":
        from repro.models.ssm import ssd_chunked  # chunked jnp (production)
        return ssd_chunked(x, dt, A, B, C, D)
    from repro.kernels.ssd_scan import ssd_scan_pallas
    return ssd_scan_pallas(x, dt, A, B, C, D,
                           interpret=(be == "interpret"))
