"""Jit'd dispatching wrappers around the Pallas kernels.

Dispatch policy (see DESIGN.md §3):
  REPRO_USE_PALLAS=1          -> compiled Pallas kernels (real TPU)
  REPRO_USE_PALLAS=interpret  -> Pallas interpret mode (CPU validation)
  unset/0                     -> pure-jnp reference (CPU dry-runs, rooflines)

The public functions keep one signature regardless of backend so the rest of
the system never branches.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro import flags as _flags
from repro.kernels import ref as _ref

__all__ = [
    "backend", "use_pallas", "ell_spmv", "ell_spmv_batched",
    "ell_spmv_delay", "ell_spmv_delay_batched", "ell_spmv_event",
    "ell_spmv_event_delay", "izhikevich_step", "hh_step",
    "flash_attention", "ssd_scan",
]


def backend() -> str:
    """Kernel backend: 'ref' | 'pallas' | 'interpret'.  The env parse lives
    in repro.flags.pallas_mode (one site; misspellings raise)."""
    mode = _flags.pallas_mode()
    if mode is _flags.PallasMode.ON:
        return "pallas"
    if mode is _flags.PallasMode.INTERPRET:
        return "interpret"
    return "ref"


def use_pallas() -> bool:
    return backend() != "ref"


# -- sparse synaptic accumulation -------------------------------------------

def ell_spmv_batched(ell, spikes: jax.Array) -> jax.Array:
    """spikes [B, n_pre] -> currents [B, n_post]."""
    be = backend()
    if be == "ref":
        return _ref.ell_spmv_ref(ell.g, ell.post_ind, ell.valid, spikes,
                                 ell.n_post)
    from repro.kernels.ell_spmv import ell_spmv_pallas
    return ell_spmv_pallas(ell.g, ell.post_ind, ell.valid, spikes,
                           n_post=ell.n_post,
                           interpret=(be == "interpret"))


def ell_spmv(ell, spikes: jax.Array) -> jax.Array:
    """spikes [n_pre] -> currents [n_post]."""
    return ell_spmv_batched(ell, spikes[None, :])[0]


def ell_spmv_delay_batched(ell, spikes: jax.Array, n_slots: int) -> jax.Array:
    """Fused delay-scatter: spikes [B, n_pre] -> ring contributions
    [B, n_slots, n_post] (slot d = contributions arriving d steps from now,
    before cursor rotation).  Requires ell.delay."""
    be = backend()
    if be == "ref":
        return _ref.ell_spmv_delay_ref(ell.g, ell.post_ind, ell.valid,
                                       ell.delay, spikes, ell.n_post, n_slots)
    from repro.kernels.ell_spmv import ell_spmv_delay_pallas
    return ell_spmv_delay_pallas(ell.g, ell.post_ind, ell.valid, ell.delay,
                                 spikes, n_post=ell.n_post, n_slots=n_slots,
                                 interpret=(be == "interpret"))


def ell_spmv_delay(ell, spikes: jax.Array, n_slots: int) -> jax.Array:
    """spikes [n_pre] -> ring contributions [n_slots, n_post]."""
    return ell_spmv_delay_batched(ell, spikes[None, :], n_slots)[0]


# -- event-driven propagation -------------------------------------------------

def _compact_rows(ell, spikes: jax.Array, capacity: int):
    """Compact the spiking pre-neuron rows of an ELL matrix.

    Returns (ell_c, spk_c, count): a capacity-row ELL holding the spiking
    rows in ascending pre order (dead tail rows invalidated), the matching
    spike values, and the true spike count.  Ascending order + exact-zero
    contributions from dropped rows keep the per-post accumulation sequence
    identical to the dense pass, so the result is bit-exact."""
    n_pre = ell.n_pre
    hits = spikes != 0
    count = jnp.sum(hits.astype(jnp.int32))
    (idx,) = jnp.nonzero(hits, size=capacity, fill_value=n_pre)
    safe = jnp.minimum(idx, n_pre - 1)
    live = idx < n_pre
    ell_c = type(ell)(
        g=ell.g[safe], post_ind=ell.post_ind[safe],
        valid=ell.valid[safe] & live[:, None], n_post=ell.n_post,
        delay=None if ell.delay is None else ell.delay[safe])
    spk = jnp.asarray(spikes, jnp.float32)
    spk_c = jnp.where(live, spk[safe], 0.0)
    return ell_c, spk_c, count


def ell_spmv_event(ell, spikes: jax.Array, capacity: int) -> jax.Array:
    """Event-driven spmv: gather only the spiking rows (fixed capacity);
    more than `capacity` simultaneous spikes falls back to the dense pass.
    spikes [n_pre] -> currents [n_post], bit-exact vs ell_spmv."""
    ell_c, spk_c, count = _compact_rows(ell, spikes, capacity)
    spk = jnp.asarray(spikes, jnp.float32)
    return jax.lax.cond(
        count <= capacity,
        lambda: ell_spmv(ell_c, spk_c),
        lambda: ell_spmv(ell, spk))


def ell_spmv_event_delay(ell, spikes: jax.Array, n_slots: int,
                         capacity: int) -> jax.Array:
    """Event-driven fused delay-scatter: spikes [n_pre] ->
    [n_slots, n_post], bit-exact vs ell_spmv_delay; overflow falls back to
    the dense fused pass."""
    ell_c, spk_c, count = _compact_rows(ell, spikes, capacity)
    spk = jnp.asarray(spikes, jnp.float32)
    return jax.lax.cond(
        count <= capacity,
        lambda: ell_spmv_delay(ell_c, spk_c, n_slots),
        lambda: ell_spmv_delay(ell, spk, n_slots))


# -- fused neuron updates -----------------------------------------------------

def izhikevich_step(v, u, isyn, a, b, c, d, dt: float):
    be = backend()
    if be == "ref":
        return _ref.izhikevich_step_ref(v, u, isyn, a, b, c, d, dt)
    from repro.kernels.izhikevich_step import izhikevich_step_pallas
    n = v.shape[0]
    bcast = lambda x: jnp.broadcast_to(jnp.asarray(x, jnp.float32), (n,))
    return izhikevich_step_pallas(
        v, u, isyn, bcast(a), bcast(b), bcast(c), bcast(d), dt=dt,
        interpret=(be == "interpret"))


def hh_step(v, m, h, n, isyn, dt: float, **params):
    be = backend()
    if be == "ref":
        return _ref.hh_step_ref(v, m, h, n, isyn, dt, **params)
    from repro.kernels.hh_step import hh_step_pallas
    return hh_step_pallas(v, m, h, n, isyn, dt=dt,
                          interpret=(be == "interpret"), **params)


# -- LM kernels ---------------------------------------------------------------

def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    scale: Optional[float] = None, q_offset: int = 0,
                    softcap: Optional[float] = None,
                    prefix: Optional[int] = None):
    from repro import flags
    be = backend()
    if flags.ROOFLINE_NO_ATTN:
        # identity-shaped stand-in: costs of projections remain, core gone
        rep = q.shape[1] // k.shape[1]
        return q * (scale or 1.0) + jnp.repeat(v, rep, axis=1).mean(
            axis=2, keepdims=True)
    if flags.ROOFLINE_NAIVE_ATTN:
        return _ref.flash_attention_ref(
            q, k, v, causal=causal, window=window, scale=scale,
            q_offset=q_offset, softcap=softcap, prefix=prefix)
    if isinstance(window, jax.core.Tracer):
        # traced window (not produced by the built-in archs): masked XLA path
        return _ref.flash_attention_xla(
            q, k, v, causal=causal, window=window, scale=scale,
            q_offset=q_offset, softcap=softcap, prefix=prefix)
    if be == "ref":
        if q.shape[2] * k.shape[2] <= 1024 * 1024:
            return _ref.flash_attention_ref(
                q, k, v, causal=causal, window=window, scale=scale,
                q_offset=q_offset, softcap=softcap, prefix=prefix)
        from repro.kernels.flash_xla import flash_attention_xla
        return flash_attention_xla(q, k, v, causal, window, scale,
                                   q_offset, softcap, prefix)
    if prefix is not None:
        # prefix-LM masking not in the Pallas kernel (VLM prefill only)
        return _ref.flash_attention_xla(
            q, k, v, causal=causal, window=window, scale=scale,
            q_offset=q_offset, softcap=softcap, prefix=prefix)
    from repro.kernels.flash_attention import flash_attention_pallas
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, scale=scale,
        q_offset=q_offset, softcap=softcap,
        interpret=(be == "interpret"))


def ssd_scan(x, dt, A, B, C, D=None):
    from repro import flags
    if flags.ROOFLINE_NO_SSD:
        return x * dt[..., None] + C.mean(axis=(2, 3))[..., None, None]
    be = backend()
    if be == "ref":
        from repro.models.ssm import ssd_chunked  # chunked jnp (production)
        return ssd_chunked(x, dt, A, B, C, D)
    from repro.kernels.ssd_scan import ssd_scan_pallas
    return ssd_scan_pallas(x, dt, A, B, C, D,
                           interpret=(be == "interpret"))
