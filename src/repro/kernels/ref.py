"""Pure-jnp oracles for every Pallas kernel.

These are the semantics; kernels must match them to float tolerance.  They are
also the implementations used for CPU dry-runs/rooflines (the CPU backend
cannot compile Mosaic TPU custom-calls) — see DESIGN.md §3.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "ell_spmv_ref", "ell_spmv_delay_ref", "izhikevich_step_ref",
    "hh_step_ref", "flash_attention_ref", "ssd_scan_ref",
]


def ell_spmv_ref(g: jax.Array, post_ind: jax.Array, valid: jax.Array,
                 spikes: jax.Array, n_post: int) -> jax.Array:
    """Batched ELL scatter-accumulate.

    g, post_ind, valid: [n_pre, K];  spikes: [B, n_pre]  ->  [B, n_post]
    out[b, j] = sum_{i,k} spikes[b,i] * g[i,k] * valid[i,k] * (post_ind[i,k]==j)
    """
    gm = jnp.where(valid, g, 0.0)
    contrib = spikes[:, :, None] * gm[None, :, :]          # [B, n_pre, K]
    flat_idx = post_ind.reshape(-1)                        # [n_pre*K]
    flat = contrib.reshape(contrib.shape[0], -1)           # [B, n_pre*K]
    out = jnp.zeros((spikes.shape[0], n_post), flat.dtype)
    return out.at[:, flat_idx].add(flat)


def ell_spmv_delay_ref(g: jax.Array, post_ind: jax.Array, valid: jax.Array,
                       delay: jax.Array, spikes: jax.Array, n_post: int,
                       n_slots: int) -> jax.Array:
    """Fused delay-scatter: one pass over the ELL slots lands every synapse's
    contribution at its own (delay_slot, post) coordinate.

    g, post_ind, valid, delay: [n_pre, K];  spikes: [B, n_pre]
    ->  [B, n_slots, n_post]
    out[b, d, j] = sum_{i,k} spikes[b,i] * g[i,k] * valid[i,k]
                             * (delay[i,k]==d) * (post_ind[i,k]==j)

    Per (d, j) the contributing slots are visited in the same row-major
    (i, k) order as a masked single-delay ell_spmv_ref pass, so replacing
    the max_delay+1 masked passes with one fused scatter is bit-exact.
    """
    gm = jnp.where(valid, g, 0.0)
    contrib = spikes[:, :, None] * gm[None, :, :]          # [B, n_pre, K]
    dflat = jnp.where(valid, delay, 0).reshape(-1)         # [n_pre*K]
    pflat = post_ind.reshape(-1)
    flat = contrib.reshape(contrib.shape[0], -1)
    out = jnp.zeros((spikes.shape[0], n_slots, n_post), flat.dtype)
    return out.at[:, dflat, pflat].add(flat)


def izhikevich_step_ref(v, u, isyn, a, b, c, d, dt):
    """Fused Izhikevich update (two half-steps on V), matching
    repro.core.snn.neurons.IZHIKEVICH semantics."""
    v1 = v + 0.5 * dt * (0.04 * v * v + 5.0 * v + 140.0 - u + isyn)
    v2 = v1 + 0.5 * dt * (0.04 * v1 * v1 + 5.0 * v1 + 140.0 - u + isyn)
    u2 = u + dt * a * (b * v2 - u)
    v2 = jnp.minimum(v2, 30.0)
    spiked = v2 >= 29.99
    v_out = jnp.where(spiked, c, v2)
    u_out = jnp.where(spiked, u2 + d, u2)
    return v_out, u_out, spiked


def _vtrap(x):
    """x / (exp(x) - 1), guarded at the pole (Taylor: 1 - x/2)."""
    return jnp.where(jnp.abs(x) > 1e-4,
                     x / (jnp.exp(x) - 1.0), 1.0 - x / 2.0)


def hh_step_ref(v, m, h, n, isyn, dt, substeps=5, gNa=7.15, ENa=50.0,
                gK=1.43, EK=-95.0, gl=0.02672, El=-63.563, C=0.143):
    """Fused Traub-Miles HH update, matching make_traubmiles(substeps)."""
    hdt = dt / substeps
    for _ in range(substeps):
        imem = -(m * m * m * h * gNa * (v - ENa) + n ** 4 * gK * (v - EK)
                 + gl * (v - El) - isyn)
        v = v + hdt * imem / C
        a_m = 1.28 * _vtrap((-52.0 - v) / 4.0)
        b_m = 1.4 * _vtrap((v + 25.0) / 5.0)
        a_h = 0.128 * jnp.exp((-48.0 - v) / 18.0)
        b_h = 4.0 / (jnp.exp((-25.0 - v) / 5.0) + 1.0)
        a_n = 0.16 * _vtrap((-50.0 - v) / 5.0)
        b_n = 0.5 * jnp.exp((-55.0 - v) / 40.0)
        m = jnp.clip(m + hdt * (a_m * (1.0 - m) - b_m * m), 0.0, 1.0)
        h = jnp.clip(h + hdt * (a_h * (1.0 - h) - b_h * h), 0.0, 1.0)
        n = jnp.clip(n + hdt * (a_n * (1.0 - n) - b_n * n), 0.0, 1.0)
    return v, m, h, n


def flash_attention_ref(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool = True, window: Optional[int] = None,
    scale: Optional[float] = None, q_offset: int = 0,
    softcap: Optional[float] = None, prefix: Optional[int] = None,
) -> jax.Array:
    """Plain softmax attention.

    q: [B, Hq, Tq, D]; k, v: [B, Hkv, Tk, D] with Hq % Hkv == 0 (GQA).
    window: if set, query position p attends keys in (p-window, p].
    q_offset: absolute position of q[0] (for decode: q_offset = Tk - Tq).
    """
    b, hq, tq, d = q.shape
    hkv = k.shape[1]
    rep = hq // hkv
    kk = jnp.repeat(k, rep, axis=1)
    vv = jnp.repeat(v, rep, axis=1)
    s = scale if scale is not None else 1.0 / jnp.sqrt(d).astype(q.dtype)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, kk).astype(jnp.float32) * s
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    qpos = jnp.arange(tq) + q_offset
    kpos = jnp.arange(k.shape[2])
    mask = jnp.ones((tq, k.shape[2]), bool)
    if causal:
        cmask = kpos[None, :] <= qpos[:, None]
        if prefix is not None:   # prefix-LM: bidirectional inside prefix
            cmask |= (kpos[None, :] < prefix) & (qpos[:, None] < prefix)
        mask &= cmask
    if window is not None:
        mask &= kpos[None, :] > (qpos[:, None] - window)
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows -> 0
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(vv.dtype), vv)


def flash_attention_xla(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool = True, window=None, scale: Optional[float] = None,
    q_offset: int = 0, softcap: Optional[float] = None,
    prefix: Optional[int] = None, q_chunk: int = 512, k_chunk: int = 1024,
) -> jax.Array:
    """Flash-style attention in plain XLA: online softmax over k-chunks inside
    a scan, q-chunks via lax.map.  Same semantics as flash_attention_ref but
    with O(q_chunk * k_chunk) temporaries — this is the production path for
    long sequences on backends without the Pallas kernel, and what the
    dry-run/roofline lowers.  Accepts a traced `window`."""
    b, hq, tq, d = q.shape
    hkv = k.shape[1]
    rep = hq // hkv
    tk = k.shape[2]
    s = scale if scale is not None else 1.0 / math.sqrt(d)

    qc = min(q_chunk, tq)
    while tq % qc:
        qc //= 2
    kc = min(k_chunk, tk)
    while tk % kc:
        kc //= 2
    nq, nk = tq // qc, tk // kc

    # [b, hkv, rep, t, d] grouped views; fold q-chunks into the batch of map
    qg = q.reshape(b, hkv, rep, nq, qc, d)
    qg = jnp.moveaxis(qg, 3, 0)                      # [nq, b, hkv, rep, qc, d]
    kg = k.reshape(b, hkv, nk, kc, d)
    vg = v.reshape(b, hkv, nk, kc, d)
    kpos_all = jnp.arange(tk).reshape(nk, kc)

    def do_q_chunk(args):
        qi, qblk = args                               # [], [b,hkv,rep,qc,d]
        qpos = qi * qc + jnp.arange(qc) + q_offset

        def body(carry, inp):
            m, l, acc = carry
            kblk, vblk, kpos = inp                    # [b,hkv,kc,d] x2, [kc]
            logits = jnp.einsum(
                "bgrqd,bgkd->bgrqk", qblk, kblk,
                preferred_element_type=jnp.float32) * s
            if softcap is not None:
                logits = softcap * jnp.tanh(logits / softcap)
            mask = jnp.ones((qc, kc), bool)
            if causal:
                cm = kpos[None, :] <= qpos[:, None]
                if prefix is not None:
                    cm |= (kpos[None, :] < prefix) & (qpos[:, None] < prefix)
                mask &= cm
            if window is not None:
                mask &= kpos[None, :] > (qpos[:, None] - window)
            logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
            m_new = jnp.maximum(m, logits.max(-1))
            m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
            p = jnp.exp(logits - m_safe[..., None])
            p = jnp.where(mask[None, None, None], p, 0.0)
            alpha = jnp.where(jnp.isneginf(m), 0.0, jnp.exp(m - m_safe))
            l_new = l * alpha + p.sum(-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bgrqk,bgkd->bgrqd", p.astype(vblk.dtype), vblk,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hkv, rep, qc), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, hkv, rep, qc), jnp.float32)
        a0 = jnp.zeros((b, hkv, rep, qc, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            body, (m0, l0, a0),
            (jnp.moveaxis(kg, 2, 0), jnp.moveaxis(vg, 2, 0), kpos_all))
        out = acc / jnp.maximum(l, 1e-37)[..., None]
        return out                                    # [b,hkv,rep,qc,d]

    outs = jax.lax.map(do_q_chunk, (jnp.arange(nq), qg))
    out = jnp.moveaxis(outs, 0, 3)                    # [b,hkv,rep,nq,qc,d]
    return out.reshape(b, hq, tq, d).astype(q.dtype)


def ssd_scan_ref(x, dt, A, B, C, D=None):
    """Mamba2 SSD reference: naive sequential state-space recurrence.

    x:  [b, t, h, dh]   inputs (already gated/projected)
    dt: [b, t, h]       softplus'd step sizes (>0)
    A:  [h]             negative decay rates (A < 0)
    B:  [b, t, g, ds]   input projections (g state groups, broadcast to h)
    C:  [b, t, g, ds]   output projections
    D:  [h] or None     skip connection
    Returns y: [b, t, h, dh].
    State: s[h, dh, ds];   s' = exp(dt*A) * s + dt * x ⊗ B;   y = s · C
    """
    b, t, h, dh = x.shape
    g = B.shape[2]
    ds = B.shape[3]
    rep = h // g
    Bh = jnp.repeat(B, rep, axis=2)  # [b, t, h, ds]
    Ch = jnp.repeat(C, rep, axis=2)

    def step(s, inp):
        xt, dtt, bt, ct = inp  # [b,h,dh], [b,h], [b,h,ds], [b,h,ds]
        decay = jnp.exp(dtt * A[None, :])[:, :, None, None]   # [b,h,1,1]
        ds_new = s * decay + (dtt[:, :, None] * xt)[..., None] * bt[:, :, None, :]
        y = jnp.einsum("bhds,bhs->bhd", ds_new, ct)
        return ds_new, y

    s0 = jnp.zeros((b, h, dh, ds), x.dtype)
    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(Bh, 1, 0), jnp.moveaxis(Ch, 1, 0))
    _, ys = jax.lax.scan(step, s0, xs)
    y = jnp.moveaxis(ys, 0, 1)  # [b, t, h, dh]
    if D is not None:
        y = y + x * D[None, None, :, None]
    return y
