"""Pallas TPU kernel: flash attention (forward) with causal/window/prefix
masks, GQA, and logit soft-capping.

Tiling: grid (B*Hq, nq, nk) with the k-block axis minor, so each q-tile's
(m, l, acc) online-softmax state lives in VMEM scratch across the k sweep
(init at ki==0, emit at ki==nk-1).  K/V tiles are indexed through the GQA
head map (q head -> kv head) inside the BlockSpec index_map, so grouped
heads never materialize repeated KV.

Block sizes default from the occupancy model (paper §3): the (qb x kb)
logits tile is the VMEM driver; qb/kb multiples of the 128-lane MXU dims.

The backward pass on TPU would follow kernels/flash_xla.py's recompute
schedule; training on this CPU container uses that XLA path, so only the
forward kernel is provided here (validated in interpret mode against
ref.flash_attention_ref).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.autotune import V5E, TPULimits

__all__ = ["flash_attention_pallas", "default_blocks"]

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale, causal, window, prefix, softcap, q_offset, qb, kb, nk,
            tk_real):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)            # [qb, d]
    k = k_ref[0].astype(jnp.float32)            # [kb, d]
    v = v_ref[0].astype(jnp.float32)            # [kb, d]

    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale     # [qb, kb]
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)

    qpos = qi * qb + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 0) \
        + q_offset
    kpos = ki * kb + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 1)
    mask = kpos < tk_real          # padded key positions contribute nothing
    if causal:
        cm = kpos <= qpos
        if prefix is not None:
            cm = jnp.logical_or(cm, jnp.logical_and(kpos < prefix,
                                                    qpos < prefix))
        mask = jnp.logical_and(mask, cm)
    if window is not None:
        mask = jnp.logical_and(mask, kpos > qpos - window)
    logits = jnp.where(mask, logits, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_cur = jnp.max(logits, axis=1)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    l_new = l_prev * alpha + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _emit():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-37)[:, None]
                    ).astype(o_ref.dtype)


def default_blocks(tq: int, tk: int, d: int,
                   lim: TPULimits = V5E) -> tuple[int, int]:
    """qb/kb from the occupancy model: working set = q + k + v + logits +
    acc tiles (x2 double-buffered) under the VMEM budget, dims 128-aligned."""
    qb = min(512, max(128, tq))
    kb = min(1024, max(128, tk))
    while (qb * d + 2 * kb * d + qb * kb + qb * d) * 4 * lim.double_buffer \
            > lim.vmem_bytes and kb > 128:
        kb //= 2
    while (qb * d + 2 * kb * d + qb * kb + qb * d) * 4 * lim.double_buffer \
            > lim.vmem_bytes and qb > 128:
        qb //= 2
    return qb, kb


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "scale", "q_offset", "softcap", "prefix",
    "q_block", "k_block", "interpret"))
def flash_attention_pallas(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool = True, window: Optional[int] = None,
    scale: Optional[float] = None, q_offset: int = 0,
    softcap: Optional[float] = None, prefix: Optional[int] = None,
    q_block: Optional[int] = None, k_block: Optional[int] = None,
    interpret: bool = False,
) -> jax.Array:
    """q [B,Hq,Tq,D]; k,v [B,Hkv,Tk,D] -> [B,Hq,Tq,D]."""
    b, hq, tq, d = q.shape
    hkv, tk = k.shape[1], k.shape[2]
    rep = hq // hkv
    s = scale if scale is not None else 1.0 / math.sqrt(d)

    qb, kb = default_blocks(tq, tk, d)
    qb = q_block or min(qb, tq)
    kb = k_block or min(kb, tk)
    # pad sequence dims to block multiples
    pq = math.ceil(tq / qb) * qb
    pk = math.ceil(tk / kb) * kb
    q3 = q.reshape(b * hq, tq, d)
    k3 = k.reshape(b * hkv, tk, d)
    v3 = v.reshape(b * hkv, tk, d)
    if pq != tq:
        q3 = jnp.pad(q3, ((0, 0), (0, pq - tq), (0, 0)))
    if pk != tk:
        k3 = jnp.pad(k3, ((0, 0), (0, pk - tk), (0, 0)))
        v3 = jnp.pad(v3, ((0, 0), (0, pk - tk), (0, 0)))
        # padded keys are masked: their kpos > every real qpos under causal;
        # for non-causal we mask via window=None ... guard with explicit
        # validity below by folding into the causal/window mask using kpos.
    nq, nk = pq // qb, pk // kb

    kernel = functools.partial(
        _kernel, scale=s, causal=causal, window=window, prefix=prefix,
        softcap=softcap, q_offset=q_offset, qb=qb, kb=kb, nk=nk,
        tk_real=tk)

    out = pl.pallas_call(
        kernel,
        grid=(b * hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, qb, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, kb, d),
                         lambda bh, qi, ki, rep=rep: (bh // rep, ki, 0)),
            pl.BlockSpec((1, kb, d),
                         lambda bh, qi, ki, rep=rep: (bh // rep, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, qb, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, pq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qb,), jnp.float32),
            pltpu.VMEM((qb,), jnp.float32),
            pltpu.VMEM((qb, d), jnp.float32),
        ],
        interpret=interpret,
    )(q3, k3, v3)
    return out[:, :tq].reshape(b, hq, tq, d)