"""Pallas TPU kernels for the compute hot-spots GeNN optimizes.

Each kernel module provides `<name>_pallas(...)` built from pl.pallas_call with
explicit BlockSpec VMEM tiling. `ref.py` holds pure-jnp oracles, `ops.py` the
jit'd dispatching wrappers (pallas on TPU / interpret for validation / jnp ref
for dry-runs on CPU). `autotune.py` is the occupancy-based block-size model
(the paper's Section 3 adapted to VMEM)."""
