"""Pallas TPU kernel: Mamba2 SSD chunked scan.

Same algorithm as models.ssm.ssd_chunked, tiled for VMEM: grid
(batch, head_blocks, chunks) with the chunk axis minor so the inter-chunk
SSM state [hb, ds, dh] persists in VMEM scratch across the sequential chunk
sweep (the recurrence), while the intra-chunk work is the quadratic "dual
form" — two MXU matmuls per chunk — exactly the paper-style reformulation of
a sparse/sequential computation into dense blocked compute.

Supports n_groups == 1 (all built-in SSM archs); head blocks must divide
n_heads.  Validated in interpret mode against kernels.ref.ssd_scan_ref.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssd_scan_pallas"]


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref, state_scr,
            *, q: int, hb: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)        # [q, hb, dh]
    dt = dt_ref[0].astype(jnp.float32)      # [q, hb]
    A = a_ref[...].astype(jnp.float32)      # [hb]
    Bm = b_ref[0, :, 0].astype(jnp.float32)  # [q, ds]
    Cm = c_ref[0, :, 0].astype(jnp.float32)  # [q, ds]
    D = d_ref[...].astype(jnp.float32)      # [hb]

    la = dt * A[None, :]                    # [q, hb] (negative)
    cum = jnp.cumsum(la, axis=0)            # [q, hb]

    # intra-chunk dual form
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [q, q]
    diff = cum[:, None, :] - cum[None, :, :]            # [q(i), q(j), hb]
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    tril = (jj <= ii)[:, :, None]
    att = jnp.where(tril, cb[:, :, None] * jnp.exp(diff), 0.0)
    att = att * dt[None, :, :]                          # weight by dt_j
    y_intra = jnp.einsum("ijh,jhd->ihd", att, x,
                         preferred_element_type=jnp.float32)

    # inter-chunk from carried state
    state = state_scr[...]                              # [hb, ds, dh]
    y_inter = jnp.einsum("is,hsd->ihd", Cm, state,
                         preferred_element_type=jnp.float32)
    y_inter = y_inter * jnp.exp(cum)[:, :, None]

    y = y_intra + y_inter + x * D[None, :, None]
    y_ref[0] = y.astype(y_ref.dtype)

    # state update
    total = cum[-1]                                     # [hb]
    w = jnp.exp(total[None, :] - cum) * dt              # [q, hb]
    s_c = jnp.einsum("js,jhd,jh->hsd", Bm, x, w,
                     preferred_element_type=jnp.float32)
    state_scr[...] = state * jnp.exp(total)[:, None, None] + s_c


@functools.partial(jax.jit, static_argnames=("chunk", "head_block",
                                             "interpret"))
def ssd_scan_pallas(x, dt, A, B, C, D=None, *, chunk: int = 256,
                    head_block: int | None = None,
                    interpret: bool = False):
    """Shapes as ssd_scan_ref: x [b,t,h,dh], dt [b,t,h], A [h],
    B/C [b,t,1,ds] -> y [b,t,h,dh].  n_groups must be 1."""
    b, t, h, dh = x.shape
    g, ds = B.shape[2], B.shape[3]
    if g != 1:
        raise NotImplementedError("ssd_scan_pallas supports n_groups == 1")
    q = min(chunk, t)
    while t % q:
        q //= 2
    nc = t // q
    hb = head_block or min(8, h)
    while h % hb:
        hb //= 2
    nh = h // hb

    if D is None:
        D = jnp.zeros((h,), jnp.float32)

    out = pl.pallas_call(
        functools.partial(_kernel, q=q, hb=hb),
        grid=(b, nh, nc),
        in_specs=[
            pl.BlockSpec((1, q, hb, dh), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, q, hb), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((hb,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, q, 1, ds), lambda bi, hi, ci: (bi, ci, 0, 0)),
            pl.BlockSpec((1, q, 1, ds), lambda bi, hi, ci: (bi, ci, 0, 0)),
            pl.BlockSpec((hb,), lambda bi, hi, ci: (hi,)),
        ],
        out_specs=pl.BlockSpec((1, q, hb, dh),
                               lambda bi, hi, ci: (bi, ci, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, t, h, dh), x.dtype),
        scratch_shapes=[pltpu.VMEM((hb, ds, dh), jnp.float32)],
        interpret=interpret,
    )(x, dt.astype(jnp.float32), jnp.asarray(A, jnp.float32),
      B.astype(jnp.float32), C.astype(jnp.float32),
      jnp.asarray(D, jnp.float32))
    return out
