"""Flash attention in plain XLA with a flash-style custom VJP.

Forward: online-softmax over k-chunks (lax.scan) inside a lax.map over
q-chunks — O(qc * kc) temporaries.  Backward: recomputes per-block
probabilities from saved (q, k, v, o, lse) instead of storing scan residuals
(plain autodiff through the chunked forward saves every block's probability
tensor — tens of GB per layer at 4k+ context, defeating the point of
chunking).  This mirrors exactly what the Pallas/TPU flash kernel does in its
backward, so dry-run memory numbers are representative of the real kernel.

Semantics identical to kernels.ref.flash_attention_ref (GQA, causal, window,
softcap, prefix, q_offset).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["flash_attention_xla"]


def _mask(qpos, kpos, causal, window, prefix):
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        cm = kpos[None, :] <= qpos[:, None]
        if prefix is not None:
            cm |= (kpos[None, :] < prefix) & (qpos[:, None] < prefix)
        m &= cm
    if window is not None:
        m &= kpos[None, :] > (qpos[:, None] - window)
    return m


def _chunks(t, pref, maximum):
    c = min(pref, t, maximum)
    while t % c:
        c //= 2
    return c


@functools.partial(
    jax.custom_vjp,
    nondiff_argnums=(3, 4, 5, 6, 7, 8, 9, 10))
def flash_attention_xla(q, k, v, causal=True, window=None, scale=None,
                        q_offset=0, softcap=None, prefix=None,
                        q_chunk=512, k_chunk=1024):
    out, _ = _fwd_impl(q, k, v, causal, window, scale, q_offset, softcap,
                       prefix, q_chunk, k_chunk)
    return out


def _fwd_impl(q, k, v, causal, window, scale, q_offset, softcap, prefix,
              q_chunk, k_chunk):
    b, hq, tq, d = q.shape
    hkv = k.shape[1]
    rep = hq // hkv
    tk = k.shape[2]
    s = scale if scale is not None else 1.0 / math.sqrt(d)
    qc = _chunks(tq, q_chunk, tq)
    kc = _chunks(tk, k_chunk, tk)
    nq, nk = tq // qc, tk // kc

    qg = jnp.moveaxis(q.reshape(b, hkv, rep, nq, qc, d), 3, 0)
    kg = k.reshape(b, hkv, nk, kc, d)
    vg = v.reshape(b, hkv, nk, kc, d)
    kpos_all = jnp.arange(tk).reshape(nk, kc)

    def do_q(args):
        qi, qblk = args
        qpos = qi * qc + jnp.arange(qc) + q_offset

        def body(carry, inp):
            m, l, acc = carry
            kblk, vblk, kpos = inp
            logits = jnp.einsum("bgrqd,bgkd->bgrqk", qblk, kblk,
                                preferred_element_type=jnp.float32) * s
            if softcap is not None:
                logits = softcap * jnp.tanh(logits / softcap)
            msk = _mask(qpos, kpos, causal, window, prefix)
            logits = jnp.where(msk[None, None, None], logits, -jnp.inf)
            m_new = jnp.maximum(m, logits.max(-1))
            m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
            p = jnp.where(msk[None, None, None],
                          jnp.exp(logits - m_safe[..., None]), 0.0)
            alpha = jnp.where(jnp.isneginf(m), 0.0, jnp.exp(m - m_safe))
            l_new = l * alpha + p.sum(-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bgrqk,bgkd->bgrqd", p.astype(vblk.dtype), vblk,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hkv, rep, qc), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, hkv, rep, qc), jnp.float32)
        a0 = jnp.zeros((b, hkv, rep, qc, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            body, (m0, l0, a0),
            (jnp.moveaxis(kg, 2, 0), jnp.moveaxis(vg, 2, 0), kpos_all))
        out = acc / jnp.maximum(l, 1e-37)[..., None]
        lse = jnp.where(jnp.isneginf(m), -jnp.inf,
                        m + jnp.log(jnp.maximum(l, 1e-37)))
        return out, lse

    outs, lses = jax.lax.map(do_q, (jnp.arange(nq), qg))
    out = jnp.moveaxis(outs, 0, 3).reshape(b, hq, tq, d).astype(q.dtype)
    lse = jnp.moveaxis(lses, 0, 3).reshape(b, hq, tq)   # [b,hq,tq] f32
    return out, lse


def _fwd(q, k, v, causal, window, scale, q_offset, softcap, prefix,
         q_chunk, k_chunk):
    out, lse = _fwd_impl(q, k, v, causal, window, scale, q_offset, softcap,
                         prefix, q_chunk, k_chunk)
    return out, (q, k, v, out, lse)


def _bwd(causal, window, scale, q_offset, softcap, prefix, q_chunk, k_chunk,
         res, g):
    q, k, v, out, lse = res
    b, hq, tq, d = q.shape
    hkv = k.shape[1]
    rep = hq // hkv
    tk = k.shape[2]
    s = scale if scale is not None else 1.0 / math.sqrt(d)
    qc = _chunks(tq, q_chunk, tq)
    kc = _chunks(tk, k_chunk, tk)
    nq, nk = tq // qc, tk // kc

    gf = g.astype(jnp.float32)
    # delta[b,h,q] = rowsum(dO * O)
    delta = jnp.sum(gf * out.astype(jnp.float32), axis=-1)

    qg = jnp.moveaxis(q.reshape(b, hkv, rep, nq, qc, d), 3, 0)
    gg = jnp.moveaxis(gf.reshape(b, hkv, rep, nq, qc, d), 3, 0)
    lseg = jnp.moveaxis(lse.reshape(b, hkv, rep, nq, qc), 3, 0)
    dg = jnp.moveaxis(delta.reshape(b, hkv, rep, nq, qc), 3, 0)
    kg = k.reshape(b, hkv, nk, kc, d)
    vg = v.reshape(b, hkv, nk, kc, d)
    kpos_all = jnp.arange(tk).reshape(nk, kc)

    def do_q(carry, args):
        dk_tot, dv_tot = carry
        qi, qblk, gblk, lseblk, dblk = args
        qpos = qi * qc + jnp.arange(qc) + q_offset

        def body(dq_acc, inp):
            kblk, vblk, kpos = inp
            raw = jnp.einsum("bgrqd,bgkd->bgrqk", qblk, kblk,
                             preferred_element_type=jnp.float32) * s
            if softcap is not None:
                capped = softcap * jnp.tanh(raw / softcap)
            else:
                capped = raw
            msk = _mask(qpos, kpos, causal, window, prefix)
            lse_safe = jnp.where(jnp.isneginf(lseblk), 0.0, lseblk)
            p = jnp.where(msk[None, None, None],
                          jnp.exp(capped - lse_safe[..., None]), 0.0)
            dv_blk = jnp.einsum("bgrqk,bgrqd->bgkd", p, gblk,
                                preferred_element_type=jnp.float32)
            dp = jnp.einsum("bgrqd,bgkd->bgrqk", gblk, vblk,
                            preferred_element_type=jnp.float32)
            ds = p * (dp - dblk[..., None])
            if softcap is not None:
                # d(capped)/d(raw) = sech^2 = 1 - tanh^2
                th = jnp.tanh(raw / softcap)
                ds = ds * (1.0 - th * th)
            ds = ds * s
            dq_blk = jnp.einsum("bgrqk,bgkd->bgrqd", ds, kblk,
                                preferred_element_type=jnp.float32)
            dk_blk = jnp.einsum("bgrqk,bgrqd->bgkd", ds, qblk,
                                preferred_element_type=jnp.float32)
            return dq_acc + dq_blk, (dk_blk, dv_blk)

        dq0 = jnp.zeros((b, hkv, rep, qc, d), jnp.float32)
        dq_blk, (dk_blks, dv_blks) = jax.lax.scan(
            body, dq0,
            (jnp.moveaxis(kg, 2, 0), jnp.moveaxis(vg, 2, 0), kpos_all))
        # [nk, b, g, kc, d] -> [b, g, tk, d], accumulated across q-chunks
        dk_tot = dk_tot + jnp.moveaxis(dk_blks, 0, 2).reshape(
            b, hkv, tk, d)
        dv_tot = dv_tot + jnp.moveaxis(dv_blks, 0, 2).reshape(
            b, hkv, tk, d)
        return (dk_tot, dv_tot), dq_blk

    dk0 = jnp.zeros((b, hkv, tk, d), jnp.float32)
    dv0 = jnp.zeros((b, hkv, tk, d), jnp.float32)
    (dk, dv), dqs = jax.lax.scan(
        do_q, (dk0, dv0), (jnp.arange(nq), qg, gg, lseg, dg))
    # dq: [nq, b, g, r, qc, d] -> [b, hq, tq, d]
    dq = jnp.moveaxis(dqs, 0, 3).reshape(b, hq, tq, d).astype(q.dtype)
    return dq, dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention_xla.defvjp(_fwd, _bwd)
