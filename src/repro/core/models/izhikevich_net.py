"""The Izhikevich (2003) cortical network, as used in the paper §5.1.

1000 spiking cortical neurons (4:1 excitatory:inhibitory), each pre neuron
connected to `n_conn` random post neurons (the paper sweeps n_conn from 100
to 1000 in steps of 50).  Weights: excitatory 0.5*U(0,1), inhibitory
-1.0*U(0,1); thalamic input 5*N(0,1) (exc) / 2*N(0,1) (inh) per ms, as in
Izhikevich's original script.  dt = 0.5 ms with 2 substeps on V (the GeNN
default for this model).

The reference configuration (n_conn = n_total, gscale = 1) defines the target
spiking rate the conductance-scaling study maintains.

Expressed through the declarative ModelSpec front-end: each presynaptic
group draws `n_conn` targets over the *whole* population (a multi-post
synapse population split per post group at build time), exactly the seed
construction, so the same seed reproduces the same graph bit-for-bit.
"""

from __future__ import annotations

import dataclasses

import jax

from repro.core.snn import neurons as N
from repro.core.snn.network import Network
from repro.core.snn.simulator import Simulator
from repro.core.snn.spec import CompiledModel, ModelSpec
from repro.sparse.formats import FixedFanout, UniformWeight

__all__ = ["IzhikevichNetConfig", "spec", "compile_model", "build"]


@dataclasses.dataclass(frozen=True)
class IzhikevichNetConfig:
    n_total: int = 1000
    exc_frac: float = 0.8
    n_conn: int = 1000
    representation: str = "auto"   # 'auto' | 'sparse' | 'dense'
    dt: float = 1.0                # 1 ms, two half-steps on V (as Izhikevich)
    seed: int = 1234
    input_scale: float = 1.0
    # declare an excitatory membrane-voltage probe sampled every
    # `probe_v_every` steps (0 = none) — see docs/API.md "Probes"
    probe_v_every: int = 0


def spec(cfg: IzhikevichNetConfig) -> ModelSpec:
    """Declarative description of the cortical net."""
    n_exc = int(round(cfg.n_total * cfg.exc_frac))
    n_inh = cfg.n_total - n_exc
    key = jax.random.PRNGKey(cfg.seed)

    pkey, _ = jax.random.split(key)
    params = N.izhikevich_population_params(pkey, n_exc, n_inh)
    exc_params = {k: v[:n_exc] for k, v in params.items()}
    inh_params = {k: v[n_exc:] for k, v in params.items()}

    s_in = cfg.input_scale

    def thalamic_exc(k, t, n):
        return 5.0 * s_in * jax.random.normal(k, (n,))

    def thalamic_inh(k, t, n):
        return 2.0 * s_in * jax.random.normal(k, (n,))

    ms = ModelSpec(name=f"izhikevich_{cfg.n_total}_{cfg.n_conn}")
    ms.add_neuron_population("exc", n_exc, N.IZHIKEVICH, exc_params,
                             thalamic_exc)
    ms.add_neuron_population("inh", n_inh, N.IZHIKEVICH, inh_params,
                             thalamic_inh)

    # fixed-fanout random connectivity, n_conn targets per pre neuron over
    # the WHOLE population (multi-post: split into exc/inh groups at build).
    # Dual-backend weight snippets: bit-identical to the historical
    # 0.5*r.random / -1.0*r.random lambdas on the host path, and resolvable
    # on device (spec.build(init="device")).
    ms.add_synapse_population(
        "exc", "exc", ["exc", "inh"], connect=FixedFanout(cfg.n_conn),
        weight=UniformWeight(0.0, 0.5),
        representation=cfg.representation)
    ms.add_synapse_population(
        "inh", "inh", ["exc", "inh"], connect=FixedFanout(cfg.n_conn),
        weight=UniformWeight(0.0, -1.0),
        representation=cfg.representation)
    if cfg.probe_v_every:
        ms.probe("exc_v", "exc", "V", every=cfg.probe_v_every)
    return ms


def compile_model(cfg: IzhikevichNetConfig, mesh=None,
                  init: str = "host", monitor=None) -> CompiledModel:
    return spec(cfg).build(dt=cfg.dt, seed=cfg.seed, mesh=mesh, init=init,
                           monitor=monitor)


def build(cfg: IzhikevichNetConfig) -> tuple[Network, Simulator]:
    """Legacy entry point: (Network, Simulator) from the compiled spec."""
    model = compile_model(cfg)
    return model.network, model.simulator


def gscale_keys(net: Network) -> list[str]:
    """Synapse-group names the conductance search scales together."""
    return [g.name for g in net.synapses]
