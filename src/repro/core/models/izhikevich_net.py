"""The Izhikevich (2003) cortical network, as used in the paper §5.1.

1000 spiking cortical neurons (4:1 excitatory:inhibitory), each pre neuron
connected to `n_conn` random post neurons (the paper sweeps n_conn from 100
to 1000 in steps of 50).  Weights: excitatory 0.5*U(0,1), inhibitory
-1.0*U(0,1); thalamic input 5*N(0,1) (exc) / 2*N(0,1) (inh) per ms, as in
Izhikevich's original script.  dt = 0.5 ms with 2 substeps on V (the GeNN
default for this model).

The reference configuration (n_conn = n_total, gscale = 1) defines the target
spiking rate the conductance-scaling study maintains.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.snn import neurons as N
from repro.core.snn.network import Network
from repro.core.snn.simulator import Simulator
from repro.core.snn.synapses import make_group

__all__ = ["IzhikevichNetConfig", "build"]


@dataclasses.dataclass(frozen=True)
class IzhikevichNetConfig:
    n_total: int = 1000
    exc_frac: float = 0.8
    n_conn: int = 1000
    representation: str = "auto"   # 'auto' | 'sparse' | 'dense'
    dt: float = 1.0                # 1 ms, two half-steps on V (as Izhikevich)
    seed: int = 1234
    input_scale: float = 1.0


def build(cfg: IzhikevichNetConfig) -> tuple[Network, Simulator]:
    n_exc = int(round(cfg.n_total * cfg.exc_frac))
    n_inh = cfg.n_total - n_exc
    rng = np.random.default_rng(cfg.seed)
    key = jax.random.PRNGKey(cfg.seed)

    net = Network(name=f"izhikevich_{cfg.n_total}_{cfg.n_conn}")

    pkey, _ = jax.random.split(key)
    params = N.izhikevich_population_params(pkey, n_exc, n_inh)
    exc_params = {k: v[:n_exc] for k, v in params.items()}
    inh_params = {k: v[n_exc:] for k, v in params.items()}

    s_in = cfg.input_scale

    def thalamic_exc(k, t, n):
        return 5.0 * s_in * jax.random.normal(k, (n,))

    def thalamic_inh(k, t, n):
        return 2.0 * s_in * jax.random.normal(k, (n,))

    net.add_population("exc", N.IZHIKEVICH, n_exc, exc_params, thalamic_exc)
    net.add_population("inh", N.IZHIKEVICH, n_inh, inh_params, thalamic_inh)

    # fixed-fanout random connectivity, n_conn targets per pre neuron,
    # targets drawn over the WHOLE population then split by post group
    def split_targets(weight_fn, sign):
        """Build exc->exc/inh or inh->exc/inh groups from one draw."""
        groups = []
        for pre, n_pre in (("exc", n_exc), ("inh", n_inh)):
            if sign > 0 and pre != "exc":
                continue
            if sign < 0 and pre != "inh":
                continue
            from repro.sparse.formats import (ELLSynapses,
                                              fixed_fanout_connectivity)
            post_all, g_all = fixed_fanout_connectivity(
                rng, n_pre, cfg.n_total, cfg.n_conn, weight_fn)
            for post, lo, hi in (("exc", 0, n_exc),
                                 ("inh", n_exc, cfg.n_total)):
                mask = (post_all >= lo) & (post_all < hi)
                idx = np.where(mask, post_all - lo, 0).astype(np.int32)
                gg = np.where(mask, g_all, 0.0).astype(np.float32)
                ell = ELLSynapses(
                    g=jnp.asarray(gg), post_ind=jnp.asarray(idx),
                    valid=jnp.asarray(mask), n_post=hi - lo)
                from repro.core.snn.synapses import SynapseGroup
                groups.append(SynapseGroup(
                    name=f"{pre}_{post}", pre=pre, post=post, ell=ell,
                    representation=cfg.representation, dynamics="pulse",
                    sign=1.0))
        return groups

    exc_w = lambda r, shape: 0.5 * r.random(shape)
    inh_w = lambda r, shape: -1.0 * r.random(shape)
    for grp in split_targets(exc_w, +1):
        net.add_synapse(grp)
    for grp in split_targets(inh_w, -1):
        net.add_synapse(grp)

    sim = Simulator(net, dt=cfg.dt, seed=cfg.seed)
    return net, sim


def gscale_keys(net: Network) -> list[str]:
    """Synapse-group names the conductance search scales together."""
    return [g.name for g in net.synapses]
