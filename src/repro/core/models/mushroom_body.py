"""The insect olfactory system / mushroom-body model (paper §5.1, ref [10]).

Populations (as in Nowotny et al. 2005 and the GeNN MBody example):
  PN   projection neurons       — Poisson inputs (odor-driven rates)
  LHI  lateral horn interneurons— HH, driven by PNs, inhibit KCs (feedforward
                                  gain control)
  KC   Kenyon cells (1000)      — HH, sparse PN input
  DN   detection neurons (100)  — HH, driven by KCs, mutual inhibition

The paper varies the PN population (and therefore the PN->KC / PN->LHI
fan-in) and fits gScale(nConn) for those two synapse groups, with 20 and 40
LHIs for verification.  Connectivities follow the GeNN example: PN->KC sparse
(prob 0.5 -> fixed fanout here), PN->LHI all-to-all-ish dense, LHI->KC dense
inhibitory, KC->DN all-to-all plastic (static here), DN->DN inhibitory.

Expressed through the declarative ModelSpec front-end; every synapse group
is an ExpCond postsynaptic model (generated code), connectivity comes from
FixedFanout initializers resolved in declaration order, reproducing the seed
construction bit-for-bit.

Baseline conductances: the synaptic current is applied with the post
membrane potential held over one dt (explicit coupling), so a group is
numerically stable only while (dt / C_m) * inSyn_total stays well below 2
(C_m = 0.143 nF, dt = 0.1 ms => inSyn bound ~2.9 uS).  The per-group
conductances below keep the summed baseline drive inside that bound with
headroom (peak inSyn ~ n_pre * g * rate * tau); over-scaling PN->KC by the
paper's large gScale values pushes KC->DN drive across the bound, which is
exactly the float-overflow phenomenon the NaN guard must catch.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.snn import neurons as N
from repro.core.snn.network import Network
from repro.core.snn.simulator import Simulator
from repro.core.snn.spec import CompiledModel, ModelSpec
from repro.core.snn.synapses import ExpCond
from repro.sparse.formats import FixedFanout

__all__ = ["MushroomBodyConfig", "spec", "compile_model", "build"]


@dataclasses.dataclass(frozen=True)
class MushroomBodyConfig:
    n_pn: int = 100
    n_lhi: int = 20
    n_kc: int = 1000
    n_dn: int = 100
    pn_kc_fanout_frac: float = 0.5     # fraction of KCs each PN contacts
    pn_rate_hz: float = 50.0           # odor-on Poisson rate
    dt: float = 0.1
    seed: int = 7
    representation: str = "auto"
    # Baseline conductances (uS) — GeNN MBody-like magnitudes, calibrated to
    # the explicit-coupling stability bound (module docstring): at the
    # reference sizes the summed per-neuron inSyn stays well under ~2.9 uS at
    # gScale=1 (earlier values g_kc_dn=0.05 / g_dn_dn=0.1 accumulated past it
    # on the DN population, blowing up the *baseline*), while gScale ~50 on
    # PN->KC makes coincident PN arrivals (0.02*50 = 1 uS each) cross the
    # bound on KCs and trip the NaN guard — the paper's overflow phenomenon.
    # (Calibrated at the reduced benchmark sizes used by tests/examples;
    # larger populations need gScale rescaling — the paper's whole point.)
    g_pn_kc: float = 0.015
    g_pn_lhi: float = 0.0025
    g_lhi_kc: float = 0.40
    g_kc_dn: float = 0.02
    g_dn_dn: float = 0.01
    # Observation / intervention (the runtime API the gscale calibration
    # loop uses): a KC membrane-voltage probe sampled every `kc_probe_every`
    # steps (0 = no probe), and the KC->DN ("KC->EN" in the MBody papers)
    # incoming-weight normalization as a declared custom update — per-DN
    # total conductance rescaled to its expected build value, runnable on
    # demand (model.custom_update("normalize_kc_dn", state)) without
    # rebuilding.  Normalization makes KC_DN's g state-resident (mutable),
    # which routes it through the sparse/ELL path; both default off so the
    # seed dynamics of existing configs stay bit-identical.
    kc_probe_every: int = 0
    kc_dn_normalize: bool = False


def spec(cfg: MushroomBodyConfig) -> ModelSpec:
    """Declarative description of the mushroom-body net."""
    ms = ModelSpec(name=f"mbody_pn{cfg.n_pn}_lhi{cfg.n_lhi}")

    ms.add_neuron_population("PN", cfg.n_pn, N.POISSON,
                             {"rate_hz": cfg.pn_rate_hz})
    ms.add_neuron_population("LHI", cfg.n_lhi, N.TRAUBMILES_HH)
    ms.add_neuron_population("KC", cfg.n_kc, N.TRAUBMILES_HH)
    ms.add_neuron_population("DN", cfg.n_dn, N.TRAUBMILES_HH)

    n_kc_per_pn = max(1, int(round(cfg.pn_kc_fanout_frac * cfg.n_kc)))
    ms.add_synapse_population(
        "PN_KC", "PN", "KC", connect=FixedFanout(n_kc_per_pn),
        weight=cfg.g_pn_kc, representation=cfg.representation,
        psm=ExpCond(tau_ms=2.0, e_rev=0.0))

    ms.add_synapse_population(
        "PN_LHI", "PN", "LHI", connect=FixedFanout(cfg.n_lhi),
        weight=cfg.g_pn_lhi, representation="dense",
        psm=ExpCond(tau_ms=1.0, e_rev=0.0))

    ms.add_synapse_population(
        "LHI_KC", "LHI", "KC", connect=FixedFanout(cfg.n_kc),
        weight=cfg.g_lhi_kc, representation="dense",
        psm=ExpCond(tau_ms=3.0, e_rev=-92.0))

    ms.add_synapse_population(
        "KC_DN", "KC", "DN", connect=FixedFanout(cfg.n_dn),
        weight=lambda r, s: (cfg.g_kc_dn * r.random(s)).astype(np.float32),
        representation=cfg.representation,
        psm=ExpCond(tau_ms=5.0, e_rev=0.0))

    ms.add_synapse_population(
        "DN_DN", "DN", "DN", connect=FixedFanout(cfg.n_dn),
        weight=cfg.g_dn_dn, representation="dense",
        psm=ExpCond(tau_ms=10.0, e_rev=-92.0))

    if cfg.kc_probe_every:
        ms.probe("kc_v", "KC", "V", every=cfg.kc_probe_every)
    if cfg.kc_dn_normalize:
        # hold each DN's total incoming conductance at its expected build
        # value (n_kc synapses, weights ~ U(0, g_kc_dn) -> mean g_kc_dn/2)
        ms.add_custom_update(
            "normalize_kc_dn", "KC_DN",
            update_code="g = g * g_total / maximum(w_sum, eps)",
            params={"g_total": cfg.n_kc * cfg.g_kc_dn / 2.0, "eps": 1e-9},
            reduce={"w_sum": ("sum", "g", "post")})
    return ms


def compile_model(cfg: MushroomBodyConfig, mesh=None,
                  init: str = "host", monitor=None) -> CompiledModel:
    return spec(cfg).build(dt=cfg.dt, seed=cfg.seed, mesh=mesh, init=init,
                           monitor=monitor)


def build(cfg: MushroomBodyConfig) -> tuple[Network, Simulator]:
    """Legacy entry point: (Network, Simulator) from the compiled spec."""
    model = compile_model(cfg)
    return model.network, model.simulator
