"""The insect olfactory system / mushroom-body model (paper §5.1, ref [10]).

Populations (as in Nowotny et al. 2005 and the GeNN MBody example):
  PN   projection neurons       — Poisson inputs (odor-driven rates)
  LHI  lateral horn interneurons— HH, driven by PNs, inhibit KCs (feedforward
                                  gain control)
  KC   Kenyon cells (1000)      — HH, sparse PN input
  DN   detection neurons (100)  — HH, driven by KCs, mutual inhibition

The paper varies the PN population (and therefore the PN->KC / PN->LHI
fan-in) and fits gScale(nConn) for those two synapse groups, with 20 and 40
LHIs for verification.  Connectivities follow the GeNN example: PN->KC sparse
(prob 0.5 -> fixed fanout here), PN->LHI all-to-all-ish dense, LHI->KC dense
inhibitory, KC->DN all-to-all plastic (static here), DN->DN inhibitory.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.snn import neurons as N
from repro.core.snn.network import Network
from repro.core.snn.simulator import Simulator
from repro.core.snn.synapses import SynapseGroup, make_group

__all__ = ["MushroomBodyConfig", "build"]


@dataclasses.dataclass(frozen=True)
class MushroomBodyConfig:
    n_pn: int = 100
    n_lhi: int = 20
    n_kc: int = 1000
    n_dn: int = 100
    pn_kc_fanout_frac: float = 0.5     # fraction of KCs each PN contacts
    pn_rate_hz: float = 50.0           # odor-on Poisson rate
    dt: float = 0.1
    seed: int = 7
    representation: str = "auto"
    # baseline conductances (uS) — GeNN MBody-like magnitudes
    g_pn_kc: float = 0.01
    g_pn_lhi: float = 0.0025
    g_lhi_kc: float = 0.15
    g_kc_dn: float = 0.05
    g_dn_dn: float = 0.1


def build(cfg: MushroomBodyConfig) -> tuple[Network, Simulator]:
    rng = np.random.default_rng(cfg.seed)
    net = Network(name=f"mbody_pn{cfg.n_pn}_lhi{cfg.n_lhi}")

    net.add_population("PN", N.POISSON, cfg.n_pn,
                       {"rate_hz": cfg.pn_rate_hz})
    net.add_population("LHI", N.TRAUBMILES_HH, cfg.n_lhi)
    net.add_population("KC", N.TRAUBMILES_HH, cfg.n_kc)
    net.add_population("DN", N.TRAUBMILES_HH, cfg.n_dn)

    const = lambda g: (lambda r, shape: np.full(shape, g, np.float32))

    n_kc_per_pn = max(1, int(round(cfg.pn_kc_fanout_frac * cfg.n_kc)))
    net.add_synapse(make_group(
        rng, "PN_KC", "PN", "KC", cfg.n_pn, cfg.n_kc, n_kc_per_pn,
        weight_fn=const(cfg.g_pn_kc), representation=cfg.representation,
        dynamics="exp_decay", tau_ms=2.0, e_rev=0.0, sign=1.0))

    net.add_synapse(make_group(
        rng, "PN_LHI", "PN", "LHI", cfg.n_pn, cfg.n_lhi, cfg.n_lhi,
        weight_fn=const(cfg.g_pn_lhi), representation="dense",
        dynamics="exp_decay", tau_ms=1.0, e_rev=0.0, sign=1.0))

    net.add_synapse(make_group(
        rng, "LHI_KC", "LHI", "KC", cfg.n_lhi, cfg.n_kc, cfg.n_kc,
        weight_fn=const(cfg.g_lhi_kc), representation="dense",
        dynamics="exp_decay", tau_ms=3.0, e_rev=-92.0, sign=1.0))

    net.add_synapse(make_group(
        rng, "KC_DN", "KC", "DN", cfg.n_kc, cfg.n_dn, cfg.n_dn,
        weight_fn=lambda r, s: (cfg.g_kc_dn * r.random(s)).astype(
            np.float32),
        representation=cfg.representation,
        dynamics="exp_decay", tau_ms=5.0, e_rev=0.0, sign=1.0))

    net.add_synapse(make_group(
        rng, "DN_DN", "DN", "DN", cfg.n_dn, cfg.n_dn, cfg.n_dn,
        weight_fn=const(cfg.g_dn_dn), representation="dense",
        dynamics="exp_decay", tau_ms=10.0, e_rev=-92.0, sign=1.0))

    sim = Simulator(net, dt=cfg.dt, seed=cfg.seed)
    return net, sim
