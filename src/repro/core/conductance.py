"""Synaptic-conductance scaling (the paper's central contribution, §2/§5.1).

Given a network whose fan-in (`nConn`) differs from the reference
configuration, find the conductance multiplier `gScale` that restores the
reference spiking behaviour, subject to the two constraints of the paper's
Fig. 1 pseudocode:

  (a) the population mean spiking rate stays inside a prescribed band, and
  (b) no float32 overflow / NaN anywhere in the chained state
      (NaNs propagate through the connectivity — the paper's "contagious"
      failure — so a single isfinite flag per run suffices).

Two search strategies are provided:

  * `search_bisect` — the paper's iterative halving: treat NaN as "scale too
    high", halve the interval on the rate otherwise.  Runs O(log) sims.
  * `search_sweep`  — vmap a whole candidate grid through ONE compiled
    simulator (the grid rides the batch axis of the TPU spmv kernel) and pick
    the in-band candidate closest to the target.  This is the TPU-native
    reformulation: one launch instead of a host-driven loop.

`fit_hyperbola` reproduces the paper's regression
    gScale = k1/(k2 + nConn) + k3
via the exact linearization the paper uses ("linear regression"):
    (g - k3)(n + k2) = k1   =>   g*n = -k2*g + k3*n + (k1 + k2*k3)
optionally refined by a 1-D search over k2 with exact linear solves for
(k1, k3) — the model is linear in (k1, k3) for fixed k2.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "RateResult", "search_bisect", "search_sweep",
    "fit_hyperbola", "hyperbola", "mape",
]

# run_fn(gscale: scalar) -> (rate_hz: scalar, finite: bool scalar)
RunFn = Callable[[jax.Array], Tuple[jax.Array, jax.Array]]


@dataclasses.dataclass
class RateResult:
    gscale: float
    rate_hz: float
    finite: bool
    iters: int


def search_bisect(
    run_fn: RunFn, lo: float, hi: float,
    target_band: Tuple[float, float], max_iters: int = 24,
) -> RateResult:
    """Paper Fig-1: guarded bisection on the (monotone) rate-vs-gscale curve.

    NaN/overflow counts as rate-too-high (constraint (b) dominates (a)).
    """
    target_lo, target_hi = target_band
    mid_rate, mid_finite = 0.0, True
    lo, hi = float(lo), float(hi)
    it = 0
    gs = 0.5 * (lo + hi)
    for it in range(1, max_iters + 1):
        gs = 0.5 * (lo + hi)
        rate, finite = run_fn(jnp.float32(gs))
        mid_rate = float(rate)
        mid_finite = bool(finite)
        too_high = (not mid_finite) or (mid_rate > target_hi)
        too_low = mid_finite and (mid_rate < target_lo)
        if too_high:
            hi = gs
        elif too_low:
            lo = gs
        else:  # in band
            break
        if hi - lo < 1e-6 * max(1.0, abs(hi)):
            break
    return RateResult(gscale=gs, rate_hz=mid_rate, finite=mid_finite,
                      iters=it)


def search_sweep(
    run_fn_batched: Callable[[jax.Array], Tuple[jax.Array, jax.Array]],
    candidates: jax.Array, target_rate: float,
) -> RateResult:
    """Evaluate all candidates in one vmapped run; pick the finite candidate
    with rate closest to target.  `run_fn_batched(gscales[B]) ->
    (rates[B], finite[B])`."""
    rates, finite = run_fn_batched(jnp.asarray(candidates, jnp.float32))
    rates = jnp.asarray(rates)
    penalty = jnp.where(finite, 0.0, jnp.inf)
    score = jnp.abs(rates - target_rate) + penalty
    i = int(jnp.argmin(score))
    return RateResult(gscale=float(candidates[i]), rate_hz=float(rates[i]),
                      finite=bool(finite[i]), iters=len(candidates))


# ---------------------------------------------------------------------------
# Regression (paper Tables 1 & 2)
# ---------------------------------------------------------------------------

def hyperbola(n: np.ndarray, k1: float, k2: float, k3: float) -> np.ndarray:
    return k1 / (k2 + np.asarray(n, np.float64)) + k3


def mape(pred: np.ndarray, obs: np.ndarray) -> float:
    obs = np.asarray(obs, np.float64)
    pred = np.asarray(pred, np.float64)
    return float(np.mean(np.abs(pred - obs) / np.abs(obs))) * 100.0


def _solve_k1k3(n: np.ndarray, g: np.ndarray, k2: float):
    """Exact least-squares (k1, k3) for fixed k2 (model linear in both)."""
    x = 1.0 / (k2 + n)
    A = np.stack([x, np.ones_like(x)], axis=1)
    coef, *_ = np.linalg.lstsq(A, g, rcond=None)
    k1, k3 = float(coef[0]), float(coef[1])
    sse = float(np.sum((A @ coef - g) ** 2))
    return k1, k3, sse


def fit_hyperbola(
    nconn: np.ndarray, gscale: np.ndarray, refine: bool = True,
) -> Tuple[float, float, float, float]:
    """Fit gScale = k1/(k2+nConn) + k3.  Returns (k1, k2, k3, mape_pct)."""
    n = np.asarray(nconn, np.float64)
    g = np.asarray(gscale, np.float64)

    # paper's linearization: g*n = -k2*g + k3*n + (k1 + k2*k3)
    X = np.stack([g, n, np.ones_like(n)], axis=1)
    y = g * n
    coef, *_ = np.linalg.lstsq(X, y, rcond=None)
    a, b, c = coef
    k2 = float(-a)
    k3 = float(b)
    k1 = float(c - k2 * k3)

    if refine:
        # 1-D refinement over k2 (golden-section on SSE, bracketed around the
        # linearized estimate; guards the pole k2 = -min(n)).
        lo = k2 - 10.0 * (abs(k2) + 1.0)
        hi = k2 + 10.0 * (abs(k2) + 1.0)
        pole = -np.min(n)
        grid = np.linspace(lo, hi, 2001)
        grid = grid[np.abs(grid - pole) > 1e-6]
        best = (np.inf, k1, k2, k3)
        for k2c in grid:
            k1c, k3c, sse = _solve_k1k3(n, g, k2c)
            if sse < best[0]:
                best = (sse, k1c, k2c, k3c)
        _, k1, k2, k3 = best

    err = mape(hyperbola(n, k1, k2, k3), g)
    return k1, k2, k3, err
