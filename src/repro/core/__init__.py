"""The paper's primary contribution: GeNN-style code generation for SNNs,
synaptic conductance scaling, and the LM adaptation of the scaling law."""
