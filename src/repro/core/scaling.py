"""Fan-in-indexed weight scaling for the LM stack — the paper's conductance
scaling transplanted to deep networks (DESIGN.md §4).

Correspondence: a linear layer's fan-in plays nConn; the activation RMS after
the layer plays the post-synaptic spiking rate; float overflow/NaN during a
probe forward/backward plays the paper's overflow guard.  The same guarded
search (probe → band check → bisect) and the same hyperbola regression
  scale(fan_in) = k1/(k2 + fan_in) + k3
are reused verbatim from repro.core.conductance.

For Gaussian activations theory says scale ≈ 1/sqrt(fan_in); the probe-based
search *discovers* the right curve rather than assuming it, exactly as the
paper refuses to assume a law and fits simulations instead.  `fit_scaling_law`
fits the hyperbola to sqrt-scales so both regimes (sparse spike-like inputs
-> 1/n, dense Gaussian -> 1/sqrt(n)) are representable; the fitted law is then
queried at each layer's fan-in at init time.

`ScalingPolicy` is what model configs carry; `probe_and_fit` is run once per
family (or the closed-form default used) and cached.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.conductance import fit_hyperbola, hyperbola

__all__ = ["ScalingPolicy", "probe_scale_for_fanin", "probe_and_fit",
           "DEFAULT_POLICY"]


@dataclasses.dataclass(frozen=True)
class ScalingPolicy:
    """init std = scale(fan_in) * base;  residual branches additionally
    multiplied by residual_alpha / sqrt(2 * n_layers) (muP-style depth term).
    """

    k1: float
    k2: float
    k3: float
    base: float = 1.0
    residual_alpha: float = 1.0
    squared: bool = True   # law fitted on scale^2 (variance) vs fan_in

    def scale(self, fan_in: int) -> float:
        v = hyperbola(np.asarray([fan_in], np.float64), self.k1, self.k2,
                      self.k3)[0]
        v = max(float(v), 1e-12)
        return self.base * (math.sqrt(v) if self.squared else v)

    def init_std(self, fan_in: int) -> float:
        return self.scale(fan_in)

    def residual_std(self, fan_in: int, n_layers: int) -> float:
        return self.scale(fan_in) * self.residual_alpha / math.sqrt(
            max(1, 2 * n_layers))


# The closed-form limit of the probe for dense Gaussian activations:
# variance law 1/fan_in is the hyperbola with k2=k3=0, k1=1.
DEFAULT_POLICY = ScalingPolicy(k1=1.0, k2=0.0, k3=0.0)


def probe_scale_for_fanin(
    key: jax.Array, fan_in: int, fan_out: int = 256,
    target_rms: float = 1.0, band: float = 0.05, batch: int = 512,
    max_iters: int = 40,
) -> float:
    """Guarded bisection (paper Fig-1) on one linear layer's output RMS."""
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (batch, fan_in), jnp.float32)
    w0 = jax.random.normal(kw, (fan_in, fan_out), jnp.float32)

    @jax.jit
    def rms_of(scale):
        y = x @ (scale * w0)
        r = jnp.sqrt(jnp.mean(y * y))
        return r, jnp.isfinite(r)

    lo, hi = 0.0, 16.0
    s = 1.0
    for _ in range(max_iters):
        s = 0.5 * (lo + hi)
        r, finite = rms_of(jnp.float32(s))
        r = float(r)
        if not bool(finite) or r > target_rms * (1 + band):
            hi = s
        elif r < target_rms * (1 - band):
            lo = s
        else:
            break
    return s


def probe_and_fit(
    key: jax.Array, fanins: Sequence[int] = (64, 128, 256, 512, 1024,
                                             2048, 4096, 8192),
    **probe_kw,
) -> ScalingPolicy:
    """Probe a fan-in sweep and fit the paper's hyperbola on variance."""
    scales = []
    for i, f in enumerate(fanins):
        scales.append(probe_scale_for_fanin(
            jax.random.fold_in(key, i), int(f), **probe_kw))
    var = np.asarray(scales, np.float64) ** 2
    k1, k2, k3, err = fit_hyperbola(np.asarray(fanins, np.float64), var)
    return ScalingPolicy(k1=k1, k2=k2, k3=k3)
