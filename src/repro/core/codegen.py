"""GeNN-style code generation, adapted to JAX.

GeNN's defining feature is that users describe neuron models as *code snippets*
(update equations, a threshold condition, a reset block) plus parameter lists,
and the framework generates specialized CUDA kernels for exactly that network.

Here the same user-facing workflow is kept: models are declared as equation
strings (`sim_code`, `threshold_code`, `reset_code`).  "Code generation" is the
pipeline

    equation strings --ast-validate/rewrite--> python code objects
                     --trace under jax.jit--> XLA HLO specialized to the model

i.e. XLA replaces nvcc as the backend compiler, and the tracer replaces GeNN's
C++ string emission.  The compiled artifact is specialized to the exact model,
population sizes and dtypes, exactly as GeNN's generated kernels are.

Security note: equation strings are compiled only after a strict AST whitelist
pass (arithmetic, comparisons, boolean ops rewritten to jnp.logical_*,
ternaries rewritten to jnp.where, calls restricted to a math whitelist, no
attributes/subscripts/imports), and executed with empty builtins.
"""

from __future__ import annotations

import ast
import dataclasses
from typing import Any, Callable, Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "NeuronModel",
    "PostsynapticModel",
    "WeightUpdateModel",
    "CodegenError",
    "compile_sim",
    "compile_postsynaptic",
    "compile_weight_update",
    "compile_custom_update",
    "compile_expr",
    "assigned_names",
    "generated_source",
]


class CodegenError(ValueError):
    """Raised when a model code snippet fails validation."""


# Functions user code may call; resolved against jnp at execution time.
_FUNC_WHITELIST: Dict[str, Callable[..., Any]] = {
    "exp": jnp.exp,
    "expm1": jnp.expm1,
    "log": jnp.log,
    "log1p": jnp.log1p,
    "sqrt": jnp.sqrt,
    "tanh": jnp.tanh,
    "sin": jnp.sin,
    "cos": jnp.cos,
    "abs": jnp.abs,
    "minimum": jnp.minimum,
    "maximum": jnp.maximum,
    "clip": jnp.clip,
    "where": jnp.where,
    "power": jnp.power,
    "floor": jnp.floor,
    "sign": jnp.sign,
    "isfinite": jnp.isfinite,
}

_ALLOWED_NODES = (
    ast.Module, ast.Expression, ast.Expr, ast.Assign, ast.AugAssign,
    ast.Name, ast.Load,
    ast.Store, ast.BinOp, ast.UnaryOp, ast.BoolOp, ast.Compare, ast.Call,
    ast.Constant, ast.IfExp, ast.Add, ast.Sub, ast.Mult, ast.Div, ast.Pow,
    ast.Mod, ast.USub, ast.UAdd, ast.Not, ast.And, ast.Or, ast.Lt, ast.Gt,
    ast.LtE, ast.GtE, ast.Eq, ast.NotEq, ast.keyword, ast.Tuple,
)


@dataclasses.dataclass(frozen=True)
class NeuronModel:
    """A GeNN-style declarative neuron model.

    state:          state variable name -> default initial value
    params:         parameter name -> default value (scalars; instances may
                    override with per-neuron arrays)
    sim_code:       statements advancing the state by one step ``dt``.
                    May reference state vars, params, and the externals
                    ``Isyn`` (summed synaptic input), ``dt``, ``t`` and
                    ``rand`` (per-neuron U(0,1) draw, fresh each step).
    threshold_code: boolean expression; True => the neuron emits a spike.
    reset_code:     statements applied (masked) to neurons that spiked.
    """

    name: str
    state: Mapping[str, float]
    params: Mapping[str, float]
    sim_code: str
    threshold_code: str = ""
    reset_code: str = ""

    def __post_init__(self) -> None:
        _check_reserved(self.name, _EXTERNALS,
                        state=self.state, params=self.params)

    @property
    def needs_rand(self) -> bool:
        return any(
            "rand" in _names(code)
            for code in (self.sim_code, self.threshold_code, self.reset_code)
            if code
        )


def _check_reserved(model_name: str, reserved, **groups) -> None:
    """Eager name validation: a state/param var shadowing a reserved
    external (or another var group) would silently replace the real value
    in the generated environment instead of erroring."""
    seen: Dict[str, str] = {}
    for gname, keys in groups.items():
        for k in keys:
            if k in reserved:
                raise CodegenError(
                    f"{model_name}: {gname} name {k!r} collides with the "
                    f"reserved names {sorted(reserved)}")
            if k in seen:
                raise CodegenError(
                    f"{model_name}: name {k!r} declared in both "
                    f"{seen[k]} and {gname}")
            seen[k] = gname


def _names(code: str) -> set:
    try:
        tree = ast.parse(code or "0", mode="exec")
    except SyntaxError:
        return set()
    return {n.id for n in ast.walk(tree) if isinstance(n, ast.Name)}


class _Rewriter(ast.NodeTransformer):
    """Rewrite python boolean semantics into array semantics."""

    def visit_BoolOp(self, node: ast.BoolOp) -> ast.AST:
        self.generic_visit(node)
        fn = "logical_and" if isinstance(node.op, ast.And) else "logical_or"
        out = node.values[0]
        for v in node.values[1:]:
            out = ast.Call(
                func=ast.Name(id=f"__{fn}", ctx=ast.Load()), args=[out, v],
                keywords=[])
        return out

    def visit_UnaryOp(self, node: ast.UnaryOp) -> ast.AST:
        self.generic_visit(node)
        if isinstance(node.op, ast.Not):
            return ast.Call(
                func=ast.Name(id="__logical_not", ctx=ast.Load()),
                args=[node.operand], keywords=[])
        return node

    def visit_IfExp(self, node: ast.IfExp) -> ast.AST:
        self.generic_visit(node)
        return ast.Call(
            func=ast.Name(id="__where", ctx=ast.Load()),
            args=[node.test, node.body, node.orelse], keywords=[])


_REWRITE_FUNCS = {
    "__logical_and": jnp.logical_and,
    "__logical_or": jnp.logical_or,
    "__logical_not": jnp.logical_not,
    "__where": jnp.where,
}


def _validate(tree: ast.AST, allowed_names: set, what: str) -> None:
    for node in ast.walk(tree):
        if not isinstance(node, _ALLOWED_NODES):
            raise CodegenError(
                f"{what}: disallowed syntax {type(node).__name__!r}")
        if isinstance(node, ast.Call):
            if not isinstance(node.func, ast.Name):
                raise CodegenError(f"{what}: only plain function calls allowed")
            if node.func.id not in _FUNC_WHITELIST:
                raise CodegenError(
                    f"{what}: call to non-whitelisted function "
                    f"{node.func.id!r}")
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
            if (node.id not in allowed_names
                    and node.id not in _FUNC_WHITELIST
                    and not node.id.startswith("__")):
                raise CodegenError(f"{what}: unknown name {node.id!r}")


def _compile_block(code: str, allowed_names: set, what: str):
    tree = ast.parse(code, mode="exec")
    _validate(tree, allowed_names, what)
    tree = _Rewriter().visit(tree)
    ast.fix_missing_locations(tree)
    return compile(tree, filename=f"<genn:{what}>", mode="exec")


def compile_expr(code: str, allowed_names: set, what: str = "expr"):
    """Compile a single boolean/scalar expression to a code object."""
    tree = ast.parse(code, mode="eval")
    _validate(tree, allowed_names, what)
    tree = _Rewriter().visit(tree)
    ast.fix_missing_locations(tree)
    return compile(tree, filename=f"<genn:{what}>", mode="eval")


def _assigned_names(code: str) -> set:
    out = set()
    tree = ast.parse(code or "", mode="exec")
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    out.add(t.id)
        elif isinstance(node, ast.AugAssign) and isinstance(node.target,
                                                            ast.Name):
            out.add(node.target.id)
    return out


_EXTERNALS = ("Isyn", "dt", "t", "rand")


def compile_sim(model: NeuronModel) -> Callable[..., Tuple[Dict[str, jax.Array], jax.Array]]:
    """Generate the per-step update function for a neuron model.

    Returns ``update(state, params, externals) -> (new_state, spiked)`` where
    - state:     dict of per-neuron arrays, keys == model.state
    - params:    dict of scalars or per-neuron arrays, keys == model.params
    - externals: dict with any of Isyn/dt/t/rand
    - spiked:    bool array (all-False when the model has no threshold).

    The returned function is pure and trace-safe; wrap in jax.jit at the
    call site (the Simulator does).
    """
    state_keys = tuple(model.state)
    param_keys = tuple(model.params)
    allowed = set(state_keys) | set(param_keys) | set(_EXTERNALS)

    sim_assigned = _assigned_names(model.sim_code)
    reset_assigned = _assigned_names(model.reset_code)
    for n in (sim_assigned | reset_assigned) - set(state_keys):
        # Temporaries are fine in sim_code; reset may only touch state.
        if n in reset_assigned and n not in state_keys:
            raise CodegenError(
                f"reset_code assigns non-state variable {n!r}")
    allowed |= sim_assigned  # temporaries become readable after assignment

    sim_code = _compile_block(model.sim_code, allowed, f"{model.name}.sim")
    thr_code = (compile_expr(model.threshold_code, allowed,
                             f"{model.name}.threshold")
                if model.threshold_code else None)
    reset_code = (_compile_block(model.reset_code, allowed,
                                 f"{model.name}.reset")
                  if model.reset_code else None)

    def update(state: Dict[str, jax.Array],
               params: Mapping[str, Any],
               externals: Mapping[str, Any]) -> Tuple[Dict[str, jax.Array], jax.Array]:
        n = None
        for v in state.values():
            n = v.shape
            break
        env = _env_base()
        env.update({k: params[k] for k in param_keys})
        env.update({k: externals[k] for k in _EXTERNALS if k in externals})
        env.update({k: state[k] for k in state_keys})

        exec(sim_code, env)  # noqa: S102 - validated, builtins-stripped

        if thr_code is not None:
            spiked = jnp.asarray(eval(thr_code, env), bool)  # noqa: S307
        else:
            shape = n if n is not None else ()
            spiked = jnp.zeros(shape, bool)

        if reset_code is not None:
            pre_reset = {k: env[k] for k in state_keys}
            exec(reset_code, env)  # noqa: S102
            for k in state_keys:
                env[k] = jnp.where(spiked, env[k], pre_reset[k])

        new_state = {k: jnp.asarray(env[k]) for k in state_keys}
        return new_state, spiked

    update.__name__ = f"update_{model.name}"
    return update


# ---------------------------------------------------------------------------
# Synapse-side models.  GeNN splits synapse behaviour into a *weight update*
# model (what a spike event does, plus optional learning) and a *postsynaptic*
# model (how arriving input decays and is applied to the neuron).  Both are
# declared as code snippets and compiled through the same AST-whitelist
# pipeline as NeuronModel.
# ---------------------------------------------------------------------------


def _env_base() -> Dict[str, Any]:
    env: Dict[str, Any] = {"__builtins__": {}}
    env.update(_FUNC_WHITELIST)
    env.update(_REWRITE_FUNCS)
    return env


@dataclasses.dataclass(frozen=True)
class PostsynapticModel:
    """A GeNN-style postsynaptic model: per-post-neuron input dynamics.

    state:      per-post-neuron state var -> initial value
    params:     parameter name -> default value
    decay_code: statements advancing the state by one step.  May reference
                state vars, params, ``dt``, ``t`` and ``inj`` (this step's
                arriving spikes weighted by the synapse matrix, summed per
                post neuron, already scaled by sign*gscale).
    apply_code: expression for the current injected into the post neuron.
                May reference state vars, params, ``inj``, ``dt``, ``t`` and
                ``V`` (the post population's membrane potential) — the
                reversal-potential hook for conductance-based synapses.
    """

    name: str
    state: Mapping[str, float] = dataclasses.field(default_factory=dict)
    params: Mapping[str, float] = dataclasses.field(default_factory=dict)
    decay_code: str = ""
    apply_code: str = "inj"

    def __post_init__(self) -> None:
        _check_reserved(self.name, _PSM_EXTERNALS,
                        state=self.state, params=self.params)

    @property
    def needs_v(self) -> bool:
        return "V" in _names(self.apply_code) | _names(self.decay_code)


_PSM_EXTERNALS = ("inj", "dt", "t", "V")


def compile_postsynaptic(model: PostsynapticModel) -> Callable[..., Tuple[Dict[str, jax.Array], jax.Array]]:
    """Generate the per-step input-dynamics function for a synapse group.

    Returns ``step(state, params, externals) -> (new_state, current)`` where
    externals provides any of ``inj``/``dt``/``t``/``V``.  Pure/trace-safe.
    """
    state_keys = tuple(model.state)
    param_keys = tuple(model.params)
    allowed = set(state_keys) | set(param_keys) | set(_PSM_EXTERNALS)
    allowed |= _assigned_names(model.decay_code)

    decay = (_compile_block(model.decay_code, allowed, f"{model.name}.decay")
             if model.decay_code else None)
    apply_ = compile_expr(model.apply_code, allowed, f"{model.name}.apply")

    def step(state: Dict[str, jax.Array], params: Mapping[str, Any],
             externals: Mapping[str, Any]) -> Tuple[Dict[str, jax.Array], jax.Array]:
        env = _env_base()
        env.update({k: params[k] for k in param_keys})
        env.update({k: externals[k] for k in _PSM_EXTERNALS
                    if k in externals})
        env.update({k: state[k] for k in state_keys})
        if decay is not None:
            exec(decay, env)  # noqa: S102 - validated, builtins-stripped
        current = jnp.asarray(eval(apply_, env))  # noqa: S307
        return {k: jnp.asarray(env[k]) for k in state_keys}, current

    step.__name__ = f"psm_{model.name}"
    return step


@dataclasses.dataclass(frozen=True)
class WeightUpdateModel:
    """A GeNN-style weight-update model: spike events + optional learning.

    spike_code: per-synapse *expression* for the contribution a presynaptic
                spike adds to the post neuron's input (GeNN's addToInSyn).
                May reference ``g``, syn_state vars, params and ``delay``
                (the per-synapse dendritic delay in dt steps, as float32;
                the scalar delay_steps on homogeneous groups, 0.0 on
                delay-free ones) — e.g. a distance-dependent attenuation
                ``g * exp(-delay / lam)``.
    syn_state:  extra per-synapse variables (same shape as ``g``).
    pre_state / post_state:
                per-pre- / per-post-neuron trace variables -> initial value.
    pre_code / post_code:
                statements advancing the traces each step.  May reference the
                trace vars, params, ``dt``, ``t`` and ``pre_spike`` /
                ``post_spike`` (0/1 float arrays over the population).
    learn_code: statements updating per-synapse variables (``g`` and
                syn_state) each step.  Pre-side names (pre traces,
                ``pre_spike``) broadcast as [n_pre, 1]; post-side names are
                gathered to synapse shape [n_pre, max_conn].  May also read
                ``delay`` (per-synapse dendritic delay, float32).
    """

    name: str
    params: Mapping[str, float] = dataclasses.field(default_factory=dict)
    syn_state: Mapping[str, float] = dataclasses.field(default_factory=dict)
    pre_state: Mapping[str, float] = dataclasses.field(default_factory=dict)
    post_state: Mapping[str, float] = dataclasses.field(default_factory=dict)
    spike_code: str = "g"
    pre_code: str = ""
    post_code: str = ""
    learn_code: str = ""

    def __post_init__(self) -> None:
        _check_reserved(self.name,
                        {"g", "pre_spike", "post_spike", "delay"}
                        | set(_WU_EXTERNALS),
                        params=self.params, syn_state=self.syn_state,
                        pre_state=self.pre_state, post_state=self.post_state)

    @property
    def has_learning(self) -> bool:
        return bool(self.learn_code or self.pre_code or self.post_code)

    @property
    def is_static_pulse(self) -> bool:
        """True when propagation can use the stored matrix unmodified."""
        return (self.spike_code.strip() == "g" and not self.has_learning
                and not self.syn_state)


_WU_EXTERNALS = ("dt", "t")
# per-synapse-shaped externals visible to spike_code / learn_code only (the
# pre/post trace snippets are population-shaped and must not see them)
_WU_SYN_EXTERNALS = ("dt", "t", "delay")


@dataclasses.dataclass(frozen=True)
class CompiledWeightUpdate:
    """Executable pieces of a WeightUpdateModel (see compile_weight_update)."""

    effective_weight: Callable[..., jax.Array]
    pre_step: Optional[Callable[..., Dict[str, jax.Array]]] = None
    post_step: Optional[Callable[..., Dict[str, jax.Array]]] = None
    learn: Optional[Callable[..., Tuple[jax.Array, Dict[str, jax.Array]]]] = None


def compile_weight_update(model: WeightUpdateModel) -> "CompiledWeightUpdate":
    """Generate the executable pieces of a weight-update model.

    - effective_weight(g, syn_state, params): eval of spike_code, per-synapse
    - pre_step(pre_state, params, externals{pre_spike,dt,t}) -> new state
    - post_step(post_state, params, externals{post_spike,dt,t}) -> new state
    - learn(g, syn_state, traces, params, externals) -> (new_g, new_syn_state)
      where ``traces`` maps every pre/post trace var (and pre_spike /
      post_spike) to an array already broadcast/gathered to synapse shape.
    """
    param_keys = tuple(model.params)
    syn_keys = tuple(model.syn_state)
    pre_keys = tuple(model.pre_state)
    post_keys = tuple(model.post_state)

    w_allowed = ({"g"} | set(syn_keys) | set(param_keys)
                 | set(_WU_SYN_EXTERNALS))
    w_code = compile_expr(model.spike_code, w_allowed,
                          f"{model.name}.spike")

    def effective_weight(g, syn_state, params, externals=None):
        env = _env_base()
        env.update({k: params[k] for k in param_keys})
        env.update({k: (externals or {})[k] for k in _WU_SYN_EXTERNALS
                    if k in (externals or {})})
        env["g"] = g
        env.update({k: syn_state[k] for k in syn_keys})
        return jnp.asarray(eval(w_code, env))  # noqa: S307

    def _trace_step(code_str, keys, spike_name, what):
        allowed = (set(keys) | set(param_keys) | {spike_name}
                   | set(_WU_EXTERNALS))
        allowed |= _assigned_names(code_str)
        code = _compile_block(code_str, allowed, what)

        def step(state, params, externals):
            env = _env_base()
            env.update({k: params[k] for k in param_keys})
            env.update({k: externals[k] for k in (spike_name,) + _WU_EXTERNALS
                        if k in externals})
            env.update({k: state[k] for k in keys})
            exec(code, env)  # noqa: S102
            return {k: jnp.asarray(env[k]) for k in keys}

        return step

    pre_step = (_trace_step(model.pre_code, pre_keys, "pre_spike",
                            f"{model.name}.pre")
                if model.pre_code else None)
    post_step = (_trace_step(model.post_code, post_keys, "post_spike",
                             f"{model.name}.post")
                 if model.post_code else None)

    learn = None
    if model.learn_code:
        allowed = ({"g", "pre_spike", "post_spike"} | set(syn_keys)
                   | set(pre_keys) | set(post_keys) | set(param_keys)
                   | set(_WU_SYN_EXTERNALS))
        allowed |= _assigned_names(model.learn_code)
        l_code = _compile_block(model.learn_code, allowed,
                                f"{model.name}.learn")

        def learn(g, syn_state, traces, params, externals):
            env = _env_base()
            env.update({k: params[k] for k in param_keys})
            env.update({k: externals[k] for k in _WU_SYN_EXTERNALS
                        if k in externals})
            env.update(traces)
            env["g"] = g
            env.update({k: syn_state[k] for k in syn_keys})
            exec(l_code, env)  # noqa: S102
            return (jnp.asarray(env["g"]),
                    {k: jnp.asarray(env[k]) for k in syn_keys})

    return CompiledWeightUpdate(effective_weight=effective_weight,
                                pre_step=pre_step, post_step=post_step,
                                learn=learn)


def assigned_names(code: str) -> set:
    """Public view of the assignment-target scan (custom-update validation
    uses it to determine which state variables an update writes)."""
    return _assigned_names(code)


# ---------------------------------------------------------------------------
# Custom updates (GeNN 4's CustomUpdate): on-demand / scheduled snippets
# that rewrite model state outside the per-step dynamics — weight
# normalization, homeostatic scaling, state resets.  Same AST whitelist and
# boolean/ternary rewriting as every other snippet; reduction results enter
# the environment as plain names (computed by the runtime, cross-device via
# psum/pmax on sharded builds).
# ---------------------------------------------------------------------------

_CU_EXTERNALS = ("dt", "t")


def compile_custom_update(name: str, update_code: str, var_keys, param_keys,
                          reduce_keys):
    """Generate the executable body of a custom update.

    Returns ``apply(vars, params, reductions, externals) -> new_vars`` where
    - vars:       dict of the target's writable state arrays (all returned,
                  assigned or not; temporaries are allowed and discarded)
    - params:     update parameters
    - reductions: reduction name -> precomputed array/scalar
    - externals:  any of dt / t
    """
    var_keys = tuple(var_keys)
    param_keys = tuple(param_keys)
    reduce_keys = tuple(reduce_keys)
    allowed = (set(var_keys) | set(param_keys) | set(reduce_keys)
               | set(_CU_EXTERNALS))
    allowed |= _assigned_names(update_code)
    code = _compile_block(update_code, allowed, f"{name}.update")

    def apply(vars: Mapping[str, Any], params: Mapping[str, Any],
              reductions: Mapping[str, Any],
              externals: Mapping[str, Any]) -> Dict[str, jax.Array]:
        env = _env_base()
        env.update({k: params[k] for k in param_keys})
        env.update({k: externals[k] for k in _CU_EXTERNALS
                    if k in externals})
        env.update({k: reductions[k] for k in reduce_keys})
        env.update({k: vars[k] for k in var_keys})
        exec(code, env)  # noqa: S102 - validated, builtins-stripped
        return {k: jnp.asarray(env[k]) for k in var_keys}

    apply.__name__ = f"custom_update_{name}"
    return apply


def generated_source(model: NeuronModel) -> str:
    """Human-readable view of what was generated (for docs/debugging)."""
    lines = [
        f"# generated update for neuron model {model.name!r}",
        f"def update_{model.name}(state, params, externals):",
    ]
    for k in model.state:
        lines.append(f"    {k} = state[{k!r}]")
    for k in model.params:
        lines.append(f"    {k} = params[{k!r}]")
    lines.append("    Isyn, dt, t, rand = externals[...]  # as referenced")
    for ln in model.sim_code.strip().splitlines():
        lines.append(f"    {ln.strip()}")
    if model.threshold_code:
        lines.append(f"    spiked = ({model.threshold_code})")
    if model.reset_code:
        lines.append("    # applied where spiked:")
        for ln in model.reset_code.strip().splitlines():
            lines.append(f"    {ln.strip()}")
    lines.append(
        f"    return {{{', '.join(repr(k) + ': ' + k for k in model.state)}}}, spiked")
    return "\n".join(lines)
