"""uint32 spike bitmasks (GeNN's 32x packing) for exchange and storage.

A bool spike vector costs one byte per neuron on the wire; packing 32
neurons per uint32 word cuts the sharded engine's per-step all-gather
payload 8x (bool byte -> bit) and shrinks device-resident `spikes`-probe
ring buffers by the same factor.  Packing is exact — bools round-trip
bit-for-bit — so it never perturbs the bit-exactness contract.

Word w holds neurons [32w, 32w+32); neuron n is bit (n % 32) of word
n // 32 (LSB-first).  Trailing bits of the last word are zero.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["words_for", "pack_spikes", "unpack_spikes", "pack_rows",
           "unpack_rows", "unpack_segments"]

_BITS = 32


def words_for(n: int) -> int:
    """uint32 words needed for n spike bits (>= 1)."""
    return max(1, -(-int(n) // _BITS))


def pack_spikes(bits: jax.Array) -> jax.Array:
    """bool[n] -> uint32[words_for(n)] (LSB-first within each word)."""
    n = bits.shape[-1]
    w = words_for(n)
    b = jnp.asarray(bits, jnp.uint32)
    b = jnp.pad(b, [(0, 0)] * (b.ndim - 1) + [(0, w * _BITS - n)])
    b = b.reshape(b.shape[:-1] + (w, _BITS))
    shifts = jnp.arange(_BITS, dtype=jnp.uint32)
    # bits are disjoint within a word, so the sum is exact (< 2**32)
    return jnp.sum(b << shifts, axis=-1).astype(jnp.uint32)


def unpack_spikes(words: jax.Array, n: int) -> jax.Array:
    """uint32[W] -> bool[n] (inverse of pack_spikes)."""
    shifts = jnp.arange(_BITS, dtype=jnp.uint32)
    bits = (words[..., :, None] >> shifts) & jnp.uint32(1)
    flat = bits.reshape(bits.shape[:-2] + (-1,))
    return flat[..., :n].astype(bool)


def pack_rows(bits: jax.Array) -> jax.Array:
    """bool[..., n] -> uint32[..., words_for(n)] (rows packed independently)."""
    return pack_spikes(bits)


def unpack_rows(words: jax.Array, n: int) -> jax.Array:
    """uint32[..., W] -> bool[..., n]."""
    return unpack_spikes(words, n)


def unpack_segments(words: jax.Array, n_per_seg: int) -> jax.Array:
    """uint32[D, W] (one packed segment per device) -> bool[D * n_per_seg].

    Each row packs n_per_seg bits; rows are unpacked independently and
    concatenated, matching an all-gather of per-device bool shards."""
    return unpack_spikes(words, n_per_seg).reshape(-1)
