"""Probes: first-class device-resident recording of simulation state.

A probe declares that one state variable (any neuron / postsynaptic /
weight-update state var, the plastic conductance matrix, or spike events)
is sampled into a device-resident strided ring buffer while the simulation
scans — GeNN's spike/variable recording, generalized:

    spec.probe("kc_v", "KC", "V", every=5)            # strided
    spec.probe("kc_last", "KC", "V", window=100)      # last 100 samples
    spec.probe("kc_peak", "KC", "V", reduce="max")    # scalar per sample
    spec.probe("raster", "KC", "spikes")              # the old record_raster

`run` / `sweep_gscale` / `serve_chunk` all return a unified `Recordings`
pytree keyed by probe name (replacing the ad-hoc ``record_raster`` flag,
which survives as a deprecation shim).  Sampling happens *after* each step
(so a spike probe with ``every=1`` reproduces the legacy raster bit for
bit) and is scheduled on the simulation's global step counter
(``round(t/dt)``), so a served stream's samples line up with the offline
oracle across chunk boundaries.

Buffer contract: a probe's buffer holds ``capacity`` sample rows
(``window`` when set, else ``ceil(n_steps/every)``); samples are written
round-robin and `finalize` returns them in chronological order with the
number of valid rows (`Recordings.counts`).  Unfilled tail rows are zeros.

Sharding: per-neuron-shaped probes store shard-local rows that are gathered
on exit (the buffer shards along the neuron axis like the dendritic ring);
*reduced* probes gather the full vector first and apply the identical
reduction, so reduced samples are bit-exact against the host build.
Synapse-matrix reductions combine per-device partials with psum/pmax —
exact for max/min, correct to float rounding for sum/mean.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.snn.errors import SpecError

__all__ = ["ProbeSpec", "ResolvedProbe", "Recordings", "REDUCE_OPS",
           "resolve_probes", "validate_probe_scalars", "capacity",
           "probe_base", "write_sample", "finalize", "vector_reduce",
           "masked_reduce", "is_packed"]

REDUCE_OPS = ("sum", "mean", "max", "min")

# variable kinds a probe can target; "matrix" kinds are per-synapse shaped
# and must declare a reduction (there is no canonical cross-device layout
# for raw [n_pre, max_conn] blocks)
_MATRIX_KINDS = ("g", "syn")


@dataclasses.dataclass(frozen=True)
class ProbeSpec:
    """A probe as declared on the ModelSpec (unresolved)."""

    name: str
    target: str
    var: str
    every: int = 1
    window: Optional[int] = None
    reduce: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class ResolvedProbe:
    """A probe bound to a built Network.

    kind:    "population" | "group"
    varkind: "neuron" | "spikes" | "psm" | "wu_pre" | "wu_post" | "g" | "syn"
    n:       full sample length for vector-shaped probes (None for matrix)
    denom:   mean denominator (population size / valid synapse count)
    """

    name: str
    kind: str
    target: str
    var: str
    varkind: str
    every: int
    window: Optional[int]
    reduce: Optional[str]
    n: Optional[int]
    denom: float

    @property
    def dtype(self):
        if self.reduce is None and self.varkind == "spikes":
            return jnp.bool_
        return jnp.float32

    def sample_shape(self) -> Tuple[int, ...]:
        """Full (unsharded) shape of one sample row."""
        return () if self.reduce is not None else (self.n,)

    def elements_per_sample(self) -> int:
        return 1 if self.reduce is not None else int(self.n)


def _group_vars(group) -> Dict[str, str]:
    """var name -> varkind for everything probe-able on a synapse group."""
    out = {k: "psm" for k in group.psm.state}
    out.update({k: "wu_pre" for k in group.wum.pre_state})
    out.update({k: "wu_post" for k in group.wum.post_state})
    out.update({k: "syn" for k in group.wum.syn_state})
    out["g"] = "g"
    return out


def validate_probe_scalars(name: str, every, window, reduce) -> None:
    """Shared name/every/window/reduce validation — the single source of
    truth for both the eager ModelSpec.probe check and resolve_probes
    (direct Simulator/engine construction), so the rules cannot drift."""
    if not name or not isinstance(name, str):
        raise SpecError(
            f"probe name must be a non-empty string, got {name!r}")
    where = f"probe {name!r}"
    if not isinstance(every, int) or isinstance(every, bool) or every <= 0:
        raise SpecError(f"{where}: every must be a positive int, got "
                        f"{every!r}")
    if window is not None and (not isinstance(window, int)
                               or isinstance(window, bool) or window <= 0):
        raise SpecError(f"{where}: window must be a positive int or "
                        f"None, got {window!r}")
    if reduce is not None and reduce not in REDUCE_OPS:
        raise SpecError(f"{where}: unknown reduce {reduce!r}; valid "
                        f"reductions: {list(REDUCE_OPS)}")


def resolve_probes(specs, net) -> Tuple[ResolvedProbe, ...]:
    """Validate probe declarations against a built Network (SpecError)."""
    groups = {g.name: g for g in net.synapses}
    seen = set()
    out = []
    for p in specs:
        validate_probe_scalars(p.name, p.every, p.window, p.reduce)
        if p.name in seen:
            raise SpecError(f"duplicate probe name {p.name!r}")
        seen.add(p.name)
        where = f"probe {p.name!r}"
        if p.target in net.populations:
            pop = net.populations[p.target]
            valid = sorted(pop.model.state) + ["spikes"]
            if p.var == "spikes":
                varkind = "spikes"
            elif p.var in pop.model.state:
                varkind = "neuron"
            else:
                raise SpecError(
                    f"{where}: population {p.target!r} (model "
                    f"{pop.model.name!r}) has no state variable {p.var!r}; "
                    f"valid variables: {valid}")
            out.append(ResolvedProbe(
                name=p.name, kind="population", target=p.target, var=p.var,
                varkind=varkind, every=p.every, window=p.window,
                reduce=p.reduce, n=pop.n, denom=float(pop.n)))
            continue
        if p.target in groups:
            g = groups[p.target]
            gvars = _group_vars(g)
            if p.var not in gvars:
                raise SpecError(
                    f"{where}: synapse group {p.target!r} has no state "
                    f"variable {p.var!r}; valid variables: "
                    f"{sorted(gvars)}")
            varkind = gvars[p.var]
            if varkind == "g" and not g.plastic:
                raise SpecError(
                    f"{where}: 'g' on synapse group {p.target!r} is "
                    "constant (no learn_code and no custom update writes "
                    "it); probe a plastic group or declare a custom "
                    "update first")
            if varkind in _MATRIX_KINDS:
                if p.reduce is None:
                    raise SpecError(
                        f"{where}: {p.var!r} is per-synapse shaped "
                        f"[n_pre, max_conn]; synapse-matrix probes must "
                        f"declare reduce= one of {list(REDUCE_OPS)}")
                n = None
                denom = float(
                    jax.device_get(g.ell.valid).sum())
            else:
                n = (g.ell.n_pre if varkind == "wu_pre" else g.ell.n_post)
                denom = float(n)
            out.append(ResolvedProbe(
                name=p.name, kind="group", target=p.target, var=p.var,
                varkind=varkind, every=p.every, window=p.window,
                reduce=p.reduce, n=n, denom=denom))
            continue
        raise SpecError(
            f"{where}: unknown target {p.target!r}; valid targets: "
            f"populations {sorted(net.populations)}, synapse groups "
            f"{sorted(groups)}")
    return tuple(out)


def is_packed(probe: ResolvedProbe) -> bool:
    """True when the probe's ring rows are stored as uint32 spike bitmasks
    (unreduced `spikes` probes — GeNN's recording-bitmask layout).  Packing
    is storage-only: rows are unpacked back to bool at finalize, so
    `Recordings` keeps the documented bool[cap, n] shape."""
    return probe.reduce is None and probe.varkind == "spikes"


# ---------------------------------------------------------------------------
# scheduling / buffer arithmetic (shared by Simulator and ShardedEngine)
# ---------------------------------------------------------------------------

def capacity(probe: ResolvedProbe, n_steps: int, serving: bool = False) -> int:
    """Static buffer row count for an up-to-n_steps scan.  The serving
    path streams every sample per chunk, so `window` does not cap it
    (clients window the stitched stream)."""
    cap = int(math.ceil(n_steps / probe.every))
    if probe.window is not None and not serving:
        cap = probe.window
    return max(cap, 1)


def probe_base(probe: ResolvedProbe, start):
    """Samples already taken before this scan (global schedule: a sample
    fires after a step when round(t/dt) % every == 0)."""
    return start // probe.every


def sample_slot(probe: ResolvedProbe, start, base, i, cap: int):
    """(active, slot) for scan step i (0-based within this scan)."""
    elapsed = start + i + 1
    active = (elapsed % probe.every) == 0
    idx = elapsed // probe.every - 1 - base
    return active, idx % cap


def write_sample(buf, slot, active, val):
    """Masked ring write: one row read + one row write per step."""
    prev = buf[slot]
    return buf.at[slot].set(jnp.where(active, val, prev))


def finalize(buf, start, n_eff, probe: ResolvedProbe, cap: int,
             use_window: bool = True):
    """(chronological buffer, valid row count) after a scan of n_eff steps
    (n_eff may be traced — the serving path clamps per slot).  The serving
    path passes use_window=False: chunk buffers are plain strided runs."""
    base = probe_base(probe, start)
    total = (start + n_eff) // probe.every - base
    count = jnp.minimum(total, cap).astype(jnp.int32)
    if probe.window is None or not use_window:
        return buf, count
    shift = jnp.where(total >= cap, total % cap, 0)
    idx = (jnp.arange(cap) + shift) % cap
    return jnp.take(buf, idx, axis=0), count


# ---------------------------------------------------------------------------
# reductions
# ---------------------------------------------------------------------------

def vector_reduce(val, op: str, denom: float):
    """Reduce a full-size vector sample to a scalar (identical op on host
    and sharded paths — the engine gathers the full vector first, so the
    result is bit-exact across device counts)."""
    val = jnp.asarray(val, jnp.float32)
    if op == "sum":
        return jnp.sum(val)
    if op == "mean":
        return jnp.sum(val) / jnp.float32(denom)
    if op == "max":
        return jnp.max(val)
    return jnp.min(val)


def reduce_neutral(op: str):
    return {"sum": 0.0, "mean": 0.0, "max": -jnp.inf, "min": jnp.inf}[op]


def masked_reduce(val, mask, op: str, denom: float):
    """Reduce a masked synapse matrix to a scalar (invalid slots neutral)."""
    val = jnp.where(mask, jnp.asarray(val, jnp.float32),
                    reduce_neutral(op))
    if op == "sum":
        return jnp.sum(val)
    if op == "mean":
        return jnp.sum(val) / jnp.float32(denom)
    if op == "max":
        return jnp.max(val)
    return jnp.min(val)


def host_sample(probe: ResolvedProbe, groups, state, spikes):
    """Extract one (possibly reduced) sample from a post-step SimState on
    the single-device path."""
    if probe.varkind == "neuron":
        val = state.neurons[probe.target][probe.var]
    elif probe.varkind == "spikes":
        val = spikes[probe.target]
    elif probe.varkind == "psm":
        val = state.syn[probe.target].psm[probe.var]
    elif probe.varkind == "wu_pre":
        val = state.syn[probe.target].wu_pre[probe.var]
    elif probe.varkind == "wu_post":
        val = state.syn[probe.target].wu_post[probe.var]
    elif probe.varkind == "g":
        val = state.syn[probe.target].g
    else:  # syn
        val = state.syn[probe.target].syn[probe.var]
    if probe.reduce is None:
        return val
    if probe.varkind in _MATRIX_KINDS:
        return masked_reduce(val, groups[probe.target].ell.valid,
                             probe.reduce, probe.denom)
    return vector_reduce(val, probe.reduce, probe.denom)


# ---------------------------------------------------------------------------
# the unified result container
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Recordings:
    """Probe outputs, keyed by probe name.

    data[name]:   [capacity, ...sample shape] (chronological; a leading
                  candidate/stream axis on sweep/serving paths)
    counts[name]: int32 valid-row count (same leading axes)
    """

    data: Dict[str, jax.Array]
    counts: Dict[str, jax.Array]

    def __getitem__(self, name):
        return self.data[name]

    def __contains__(self, name):
        return name in self.data

    def __bool__(self):
        return bool(self.data)

    def keys(self):
        return self.data.keys()

    def items(self):
        return self.data.items()

    def count(self, name):
        return self.counts[name]

    def tree_flatten(self):
        return ((self.data, self.counts), ())

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)
