"""ModelSpec: the declarative front-end for spiking networks.

This mirrors GeNN's ModelSpec workflow (addNeuronPopulation /
addSynapsePopulation -> generate -> run): the whole network — neuron models,
synapse models, connectivity — is declared as *data and code snippets*, then
`build` validates the spec eagerly, resolves seeded connectivity
initializers, runs the paper's representation choice (eqs. (1)/(2)) and
generates the specialized simulator.

    spec = ModelSpec("demo")
    spec.add_neuron_population("exc", 160, "izhikevich",
                               input_fn=thalamic)
    spec.add_synapse_population("ee", "exc", "exc",
                                connect=FixedFanout(40),
                                weight=lambda r, s: 0.5 * r.random(s),
                                psm=ExpDecay(5.0))
    model = spec.build(dt=1.0, seed=0)
    res = model.run(400)
    sweep = model.sweep_gscale("ee", jnp.logspace(-1, 1, 16), n_steps=400)

`post` may be a list of population names: one connectivity draw is made over
the concatenated target space and split per post population (a presynaptic
axon targeting the union — the paper's cortical-net construction).

Errors are raised at declaration/build time with the offending names spelled
out (SpecError), not at first jit trace.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.codegen import (NeuronModel, PostsynapticModel,
                                WeightUpdateModel, assigned_names)
from repro.core.snn import bitmask as BM
from repro.core.snn import custom_updates as CU
from repro.core.snn import probes as PR
from repro.core.snn.errors import SpecError
from repro.core.snn.network import InputFn, Network
from repro.core.snn.probes import ProbeSpec, Recordings
from repro.core.snn.custom_updates import CustomUpdateSpec
from repro.core.snn.simulator import RunResult, SimState, Simulator
from repro.core.snn.synapses import PROPAGATIONS, Pulse, SynapseGroup
from repro.kernels import autotune as AT
from repro.obs import trace
from repro.sparse import formats as F

__all__ = ["ModelSpec", "CompiledModel", "SweepResult", "SpecError",
           "Recordings", "MAX_DELAY_STEPS"]

# weight initialization: scalar, or (rng, shape) -> array
WeightInit = Union[None, float, int, Callable[..., np.ndarray]]

# delay initialization: steps (int), or a per-synapse DelaySnippet
DelayInit = Union[None, int, F.DelaySnippet]

_REPRESENTATIONS = ("auto", "sparse", "dense")

# Dendritic ring capacity: every delayed group carries a
# [max_delay+1, n_post] ring resident on device for the whole simulation, so
# an unbounded delay would silently allocate an arbitrarily large ring.
# Delays above this bound are almost certainly a unit error (steps vs ms).
MAX_DELAY_STEPS = 1024


@dataclasses.dataclass
class NeuronPopSpec:
    name: str
    n: int
    model: NeuronModel
    params: Dict[str, object]
    input_fn: Optional[InputFn]
    edge_spikes: Optional[bool]


@dataclasses.dataclass
class SynapsePopSpec:
    name: str
    pre: str
    post: Tuple[str, ...]
    connect: F.ConnectivityInit
    weight: WeightInit
    wum: Optional[WeightUpdateModel]
    psm: PostsynapticModel
    delay_steps: int
    delay: Optional[F.DelaySnippet]
    delay_ms: Optional[float]
    sign: float
    representation: str
    propagation: str = "auto"

    def group_names(self) -> List[str]:
        if len(self.post) == 1:
            return [self.name]
        return [f"{self.name}_{p}" for p in self.post]


def _as_weight_fn(weight: WeightInit):
    """Normalize the weight initializer to the (rng, shape) protocol.
    Scalars consume no rng draws (matching the historical const() helpers)."""
    if weight is None or callable(weight):
        return weight
    w = float(weight)
    return lambda rng, shape: np.full(shape, w, np.float32)


class ModelSpec:
    """Declarative network description; `build` compiles it."""

    def __init__(self, name: str = "model"):
        self.name = name
        self.populations: Dict[str, NeuronPopSpec] = {}
        self.synapses: List[SynapsePopSpec] = []
        self.probes: List[ProbeSpec] = []
        self.custom_updates: List[CustomUpdateSpec] = []

    def _declared_targets(self) -> Tuple[set, set]:
        """(population names, concrete synapse group names) declared so
        far — the namespace probes and custom updates address."""
        groups = {n for s in self.synapses for n in s.group_names()}
        return set(self.populations), groups

    # -- declaration ------------------------------------------------------
    def add_neuron_population(
        self, name: str, n: int, model: Union[NeuronModel, str],
        params: Optional[Mapping[str, object]] = None,
        input_fn: Optional[InputFn] = None,
        edge_spikes: Optional[bool] = None,
    ) -> NeuronPopSpec:
        if not name or not isinstance(name, str):
            raise SpecError(f"population name must be a non-empty string, "
                            f"got {name!r}")
        if name in self.populations:
            raise SpecError(f"duplicate population name {name!r}")
        if not isinstance(n, int) or n <= 0:
            raise SpecError(f"population {name!r}: n must be a positive "
                            f"int, got {n!r}")
        if isinstance(model, str):
            from repro.core.snn import neurons as _neurons
            try:
                model = _neurons.get_model(model)
            except KeyError as e:
                raise SpecError(f"population {name!r}: {e.args[0]}") from None
        if not isinstance(model, NeuronModel):
            raise SpecError(f"population {name!r}: model must be a "
                            f"NeuronModel or registry name, got "
                            f"{type(model).__name__}")
        merged = dict(model.params)
        for k, v in (params or {}).items():
            if k not in model.params:
                raise SpecError(
                    f"population {name!r}: unknown parameter {k!r} for "
                    f"neuron model {model.name!r}; valid parameters: "
                    f"{sorted(model.params)}")
            shape = np.shape(v)
            if shape and shape[0] != n:
                raise SpecError(
                    f"population {name!r}: per-neuron parameter {k!r} has "
                    f"leading dimension {shape[0]} != population size {n}")
            merged[k] = v
        pop = NeuronPopSpec(name=name, n=n, model=model, params=merged,
                            input_fn=input_fn, edge_spikes=edge_spikes)
        self.populations[name] = pop
        return pop

    def add_synapse_population(
        self, name: str, pre: str, post: Union[str, Sequence[str]],
        connect: F.ConnectivityInit,
        weight: WeightInit = None,
        wum: Optional[WeightUpdateModel] = None,
        psm: Optional[PostsynapticModel] = None,
        delay_steps: int = 0,
        delay: DelayInit = None,
        delay_ms: Optional[float] = None,
        sign: float = 1.0,
        representation: str = "auto",
        propagation: str = "auto",
    ) -> SynapsePopSpec:
        """Declare a synapse population.

        Delays (dendritic: the weighted current is buffered on the post
        side) come in three declaration forms, at most one of which may be
        used per population:

        - ``delay_steps=k``: every synapse delays by k dt steps
          (homogeneous fast path — one ring slot written per step);
        - ``delay=ConstantDelay(k) | UniformIntDelay(lo, hi) | int``: a
          per-synapse delay slot resolved like a weight initializer
          (heterogeneous path; an int means ConstantDelay);
        - ``delay_ms=x``: homogeneous delay declared in milliseconds,
          converted at build time — x must be an integer multiple of dt.

        ``propagation`` selects how spikes traverse the group each step:
        ``"dense"`` always runs the full ELL pass; ``"event"`` compacts
        the spiking pre rows first (bit-exact, with a dense fallback when
        more rows spike than the compaction capacity); ``"auto"``
        (default) picks per group from the occupancy/activity crossover
        model (`repro.kernels.autotune.choose_propagation`).  The choice
        is surfaced per group in `CompiledModel.memory_report` — see
        docs/API.md "Propagation modes".
        """
        if not name or not isinstance(name, str):
            raise SpecError(f"synapse population name must be a non-empty "
                            f"string, got {name!r}")
        post_t = (post,) if isinstance(post, str) else tuple(post)
        if not post_t:
            raise SpecError(f"synapse population {name!r}: empty post list")
        if len(set(post_t)) != len(post_t):
            raise SpecError(
                f"synapse population {name!r}: duplicate post population "
                f"in {list(post_t)}")
        # declared names and expanded group names share one namespace:
        # gscales/sweep address either, so a collision in either direction
        # would make scaling silently partial
        taken = {s.name for s in self.synapses}
        taken |= {n for s in self.synapses for n in s.group_names()}
        if isinstance(delay, int) and not isinstance(delay, bool):
            try:
                delay = F.ConstantDelay(delay)
            except ValueError as e:
                raise SpecError(
                    f"synapse population {name!r}: {e}") from None
        spec = SynapsePopSpec(
            name=name, pre=pre, post=post_t, connect=connect, weight=weight,
            wum=wum, psm=psm if psm is not None else Pulse(),
            delay_steps=delay_steps, delay=delay, delay_ms=delay_ms,
            sign=sign, representation=representation,
            propagation=propagation)
        new_names = spec.group_names()
        for gname in [name] + new_names:
            if gname in taken or new_names.count(gname) > 1:
                raise SpecError(f"duplicate synapse group name {gname!r}")
        for popname, what in [(pre, "pre")] + [(p, "post") for p in post_t]:
            if popname not in self.populations:
                raise SpecError(
                    f"synapse population {name!r}: unknown {what} "
                    f"population {popname!r}; declared populations: "
                    f"{sorted(self.populations)}")
        if not isinstance(spec.connect, F.ConnectivityInit):
            raise SpecError(
                f"synapse population {name!r}: connect must be a "
                f"ConnectivityInit (FixedFanout / FixedProbability / "
                f"OneToOne / DenseInit), got {type(connect).__name__}")
        if not isinstance(spec.psm, PostsynapticModel):
            raise SpecError(
                f"synapse population {name!r}: psm must be a "
                f"PostsynapticModel, got {type(spec.psm).__name__}")
        if wum is not None and not isinstance(wum, WeightUpdateModel):
            raise SpecError(
                f"synapse population {name!r}: wum must be a "
                f"WeightUpdateModel, got {type(wum).__name__}")
        if representation not in _REPRESENTATIONS:
            raise SpecError(
                f"synapse population {name!r}: representation "
                f"{representation!r} not in {_REPRESENTATIONS}")
        if propagation not in PROPAGATIONS:
            raise SpecError(
                f"synapse population {name!r}: propagation "
                f"{propagation!r} not in {PROPAGATIONS}")
        if propagation == "event" and representation == "dense":
            raise SpecError(
                f"synapse population {name!r}: propagation='event' is "
                "incompatible with representation='dense' (event-driven "
                "compaction gathers ELL rows; the dense mirror has none); "
                "use representation 'sparse' or 'auto'")
        if (representation == "dense" and wum is not None
                and not wum.is_static_pulse):
            raise SpecError(
                f"synapse population {name!r}: representation='dense' is "
                f"incompatible with weight-update model {wum.name!r} "
                "(dynamic weights propagate via the ELL path); use "
                "'sparse' or 'auto'")
        if not isinstance(delay_steps, int) or delay_steps < 0:
            raise SpecError(
                f"synapse population {name!r}: delay_steps must be a "
                f"non-negative int, got {delay_steps!r}")
        declared = [d for d, used in [
            ("delay_steps", delay_steps != 0), ("delay", delay is not None),
            ("delay_ms", delay_ms is not None)] if used]
        if len(declared) > 1:
            raise SpecError(
                f"synapse population {name!r}: {' and '.join(declared)} are "
                "mutually exclusive; declare the delay exactly one way")
        if delay_steps > MAX_DELAY_STEPS:
            raise SpecError(
                f"synapse population {name!r}: delay_steps={delay_steps} "
                f"exceeds the dendritic ring capacity "
                f"MAX_DELAY_STEPS={MAX_DELAY_STEPS} (the ring holds "
                "max_delay+1 per-post-neuron slots on device; delays this "
                "large are almost certainly a steps-vs-ms unit error)")
        if delay is not None:
            if not isinstance(delay, F.DelaySnippet):
                raise SpecError(
                    f"synapse population {name!r}: delay must be an int or "
                    f"a DelaySnippet (ConstantDelay / UniformIntDelay), "
                    f"got {type(delay).__name__}")
            if delay.max_steps > MAX_DELAY_STEPS:
                raise SpecError(
                    f"synapse population {name!r}: "
                    f"{type(delay).__name__} max delay {delay.max_steps} "
                    f"exceeds the dendritic ring capacity "
                    f"MAX_DELAY_STEPS={MAX_DELAY_STEPS}")
            if representation == "dense":
                raise SpecError(
                    f"synapse population {name!r}: representation='dense' "
                    "is incompatible with per-synapse delays (the dense "
                    "mirror has no delay slot); use 'sparse' or 'auto'")
        if delay_ms is not None:
            if not isinstance(delay_ms, (int, float)) or delay_ms < 0:
                raise SpecError(
                    f"synapse population {name!r}: delay_ms must be a "
                    f"non-negative number, got {delay_ms!r}")
        if spec.psm.needs_v:
            for p in post_t:
                if "V" not in self.populations[p].model.state:
                    raise SpecError(
                        f"synapse population {name!r}: postsynaptic model "
                        f"{spec.psm.name!r} references V but post "
                        f"population {p!r} (model "
                        f"{self.populations[p].model.name!r}) has no "
                        "membrane state 'V'")
        self.synapses.append(spec)
        return spec

    # -- observation / intervention ---------------------------------------
    def probe(self, name: str, target: str, var: str, every: int = 1,
              window: Optional[int] = None,
              reduce: Optional[str] = None) -> ProbeSpec:
        """Declare a recording probe on a population or synapse group.

        target: a population name or a concrete synapse group name
                (declare it first);
        var:    any state variable of the target — a neuron state var or
                ``"spikes"`` for populations; a postsynaptic /
                weight-update trace var, ``"g"`` (plastic groups) or a
                per-synapse var for groups;
        every:  sample every k-th dt step (after the step);
        window: keep only the last `window` samples (device-resident ring);
        reduce: "sum" | "mean" | "max" | "min" — reduce each sample over
                the neuron axis (mandatory for per-synapse-shaped vars).

        `run`/`sweep_gscale`/`serve_chunk` return the samples in a
        `Recordings` pytree keyed by probe name.
        """
        PR.validate_probe_scalars(name, every, window, reduce)
        if any(p.name == name for p in self.probes):
            raise SpecError(f"duplicate probe name {name!r}")
        pops, groups = self._declared_targets()
        if target not in pops and target not in groups:
            multi = {s.name for s in self.synapses
                     if len(s.post) > 1 and s.name == target}
            hint = (f"; {target!r} is a multi-post synapse population — "
                    f"probe one of its concrete groups "
                    f"{[n for s in self.synapses if s.name == target for n in s.group_names()]}"
                    if multi else "")
            raise SpecError(
                f"probe {name!r}: unknown target {target!r}; declared "
                f"populations: {sorted(pops)}, synapse groups: "
                f"{sorted(groups)}{hint}")
        p = ProbeSpec(name=name, target=target, var=var, every=every,
                      window=window, reduce=reduce)
        self.probes.append(p)
        return p

    def add_custom_update(self, name: str, group: str, update_code: str,
                          params: Optional[Mapping[str, float]] = None,
                          reduce: Optional[Mapping[str, tuple]] = None,
                          every: Optional[int] = None) -> CustomUpdateSpec:
        """Declare a codegen'd custom update on a population or synapse
        group (GeNN 4's CustomUpdate).

        group:       target population or concrete synapse group name;
        update_code: statements rewriting the target's state vars (``g`` /
                     per-synapse vars for groups; model state vars for
                     populations), AST-validated like every other snippet;
        params:      update parameters (populations also read their model
                     params);
        reduce:      reductions computed before the code runs —
                     ``{"w_sum": ("sum", "g", "post")}`` for groups
                     (axis "pre" | "post" | "all"),
                     ``{"v_max": ("max", "V")}`` for populations;
        every:       run every n steps inside the scan; None = on demand
                     only (``CompiledModel.custom_update(name, state)``).
        """
        CU.validate_update_scalars(name, every)
        if any(cu.name == name for cu in self.custom_updates):
            raise SpecError(f"duplicate custom update name {name!r}")
        pops, groups = self._declared_targets()
        if group not in pops and group not in groups:
            raise SpecError(
                f"custom update {name!r}: unknown target {group!r}; "
                f"declared populations: {sorted(pops)}, synapse groups: "
                f"{sorted(groups)}")
        cu = CustomUpdateSpec(name=name, target=group,
                              update_code=update_code,
                              params=dict(params or {}),
                              reduce=dict(reduce or {}), every=every)
        self.custom_updates.append(cu)
        return cu

    def _mutable_groups(self) -> set:
        """Synapse groups whose g a declared custom update writes (their
        conductances must be state-resident)."""
        _, groups = self._declared_targets()
        out = set()
        for cu in self.custom_updates:
            if cu.target in groups:
                try:
                    writes = assigned_names(cu.update_code)
                except SyntaxError:
                    writes = set()
                if "g" in writes:
                    out.add(cu.target)
        return out

    # -- pre-flight capacity planning --------------------------------------
    def _plan_groups(self, dt: float):
        """Static per-group geometry the planner sizes from: no arrays are
        allocated and nothing is resolved — connectivity widths come from
        the same bounds `device_init` uses for its slot padding."""
        from repro.sparse import device_init as DI
        mutable = self._mutable_groups()
        groups = []
        for sp in self.synapses:
            n_pre = self.populations[sp.pre].n
            sizes = [self.populations[p].n for p in sp.post]
            n_post_total = int(sum(sizes))
            c = sp.connect
            if isinstance(c, F.FixedFanout):
                k = int(c.n_conn)
            elif isinstance(c, F.FixedProbability):
                k = DI._binomial_slots(n_post_total, c.p)
            elif isinstance(c, F.OneToOne):
                k = 1
            else:                       # DenseInit / unknown: worst case
                k = n_post_total
            if sp.delay is not None:
                ring_slots = sp.delay.max_steps + 1
            elif sp.delay_ms is not None:
                ring_slots = int(round(sp.delay_ms / dt)) + 1
            elif sp.delay_steps > 0:
                ring_slots = sp.delay_steps + 1
            else:
                ring_slots = 0
            wum = sp.wum
            plastic = ((wum is not None and not wum.is_static_pulse)
                       or any(g in mutable for g in sp.group_names()))
            for pname, n_p, gname in zip(sp.post, sizes,
                                         sp.group_names()):
                groups.append({
                    "name": gname, "pre": sp.pre, "post": pname,
                    "n_pre": n_pre, "n_post": n_p,
                    "n_post_total": n_post_total, "k": k,
                    "has_delay": sp.delay is not None,
                    "ring_slots": ring_slots, "plastic": plastic,
                    "n_pre_state": len(wum.pre_state) if wum else 0,
                    "n_post_state": len(wum.post_state) if wum else 0,
                    "n_syn_state": len(wum.syn_state) if wum else 0,
                    "n_psm_state": len(sp.psm.state)})
        return groups

    def _plan_at(self, D: int, dt: float, n_steps: Optional[int],
                 max_streams: int):
        """Per-device byte breakdown at device count D (planner core)."""
        from repro.sparse import device_init as DI
        components = []

        def shard(n):
            return -(-int(n) // D)

        constr_fused = constr_part = 0
        steady = 0
        for gi in self._plan_groups(dt):
            K = gi["k"]
            # the post-partitioned slot width concentrates each row's K
            # slots onto D shards: binomial mean + 6 sigma, the same
            # bound device_init uses for its own slot padding
            q = min(1.0, shard(gi["n_post"]) / max(gi["n_post_total"], 1))
            k_local = int(min(K, np.ceil(
                K * q + 6.0 * np.sqrt(max(K * q * (1.0 - q), 0.0)) + 1)))
            k_local = max(k_local, 1)
            slot_b = F.ell_slot_bytes(gi["has_delay"])
            block_b = gi["n_pre"] * k_local * slot_b
            dyn_b = (gi["n_pre"] * k_local * 4
                     * ((1 if gi["plastic"] else 0) + gi["n_syn_state"])
                     + shard(gi["n_post"]) * 4
                     * (gi["n_psm_state"] + gi["n_post_state"])
                     + shard(gi["n_pre"]) * 4 * gi["n_pre_state"]
                     + gi["ring_slots"] * shard(gi["n_post"]) * 4)
            peak = DI.construction_peak_model(
                gi["n_pre"], K, D, k_local, has_delay=gi["has_delay"])
            constr_fused += peak["fused_local_bytes"]
            constr_part += peak["generate_partition_bytes"]
            steady += block_b + dyn_b * max_streams
            components.append({
                "name": gi["name"], "kind": "synapse_group",
                "bytes_per_device": block_b + dyn_b * max_streams,
                "construction_fused_bytes": peak["fused_local_bytes"],
                "construction_partition_bytes":
                    peak["generate_partition_bytes"],
                "k": K, "k_local": k_local})
        for name, pop in self.populations.items():
            nb = (len(pop.model.state) + 2) * shard(pop.n) * 4 \
                * max_streams
            steady += nb
            components.append({"name": name, "kind": "population",
                               "bytes_per_device": nb})
        if n_steps is not None:
            # probe rings (packed spikes rows at their true uint32 size)
            pops, groups = self._declared_targets()
            for p in self.probes:
                cap = int(np.ceil(n_steps / p.every))
                if p.window is not None:
                    cap = min(cap, p.window)
                if p.reduce is not None:
                    bps = 4
                elif p.target in pops and p.var == "spikes":
                    bps = BM.words_for(shard(
                        self.populations[p.target].n)) * 4
                else:
                    width = (self.populations[p.target].n
                             if p.target in pops else max(
                                 (gi["n_post"]
                                  for gi in self._plan_groups(dt)
                                  if gi["name"] == p.target), default=1))
                    bps = shard(width) * 4
                nb = cap * bps * max_streams
                steady += nb
                components.append({"name": p.name, "kind": "probe",
                                   "bytes_per_device": nb,
                                   "is_packed": (p.reduce is None
                                                 and p.var == "spikes")})
        return {"steady_state_bytes": int(steady),
                "construction_fused_bytes": int(constr_fused),
                "construction_partition_bytes": int(constr_part),
                "peak_bytes": int(max(steady + constr_fused, steady)),
                "components": components}

    def plan(self, mesh_shape: int = 1, host_gib: float = 16.0,
             dt: float = 0.5, n_steps: Optional[int] = None,
             max_streams: int = 1) -> dict:
        """Pre-flight capacity planner: per-device *construction* and
        steady-state bytes at `mesh_shape` devices against a `host_gib`
        budget per device, without building anything.

        Returns a dict with ``devices``, ``budget_bytes_per_device``,
        ``per_device`` (``construction_fused_bytes`` for the
        `device_init_local` path, ``construction_partition_bytes`` for
        generate-then-partition, ``steady_state_bytes``, ``peak_bytes``),
        a per-component breakdown, ``fits``, ``first_overflow`` (the
        first component that pushes the running total past the budget),
        and — when the spec does not fit — ``min_devices`` and a
        human-readable ``needs`` ("this spec needs N hosts", one device
        per host).  Construction sizing assumes the fused
        `init="device"` + mesh path; the generate-then-partition column
        shows what the same build would peak at without it."""
        if not isinstance(mesh_shape, int) or mesh_shape <= 0:
            raise SpecError(f"plan: mesh_shape must be a positive int, "
                            f"got {mesh_shape!r}")
        budget = int(host_gib * (1 << 30))
        res = self._plan_at(mesh_shape, dt, n_steps, max_streams)
        first_overflow = None
        running = 0
        for comp in res["components"]:
            running += (comp["bytes_per_device"]
                        + comp.get("construction_fused_bytes", 0))
            if first_overflow is None and running > budget:
                first_overflow = comp["name"]
        fits = res["peak_bytes"] <= budget
        out = {"devices": mesh_shape,
               "budget_bytes_per_device": budget,
               "per_device": {
                   "construction_fused_bytes":
                       res["construction_fused_bytes"],
                   "construction_partition_bytes":
                       res["construction_partition_bytes"],
                   "steady_state_bytes": res["steady_state_bytes"],
                   "peak_bytes": res["peak_bytes"]},
               "components": res["components"],
               "fits": fits,
               "first_overflow": first_overflow}
        if not fits:
            D = mesh_shape
            while D < (1 << 24):
                D *= 2
                if self._plan_at(D, dt, n_steps,
                                 max_streams)["peak_bytes"] <= budget:
                    break
            out["min_devices"] = D
            out["needs"] = (f"this spec needs {D} hosts "
                            f"({host_gib} GiB each); first component over "
                            f"budget: {first_overflow}")
        else:
            out["min_devices"] = mesh_shape
            out["needs"] = "fits"
        return out

    # -- build ------------------------------------------------------------
    def build(self, dt: float = 0.5, seed: int = 0, mesh=None,
              init: str = "host", monitor=None) -> "CompiledModel":
        """Validate, resolve connectivity (seeded) and generate the
        simulator.

        init="host" (default): initializers are resolved in declaration
        order from a single np rng seeded with `seed` — same spec + seed
        reproduces the same graph bit-for-bit (the reference oracle).

        init="device": connectivity is generated on-accelerator by
        `repro.sparse.device_init` — jit-compiled, O(nnz) memory,
        counter-based (per-row key-split) so the graph is seed-deterministic
        and independent of device count.  Weights must be dual-backend
        snippets (UniformWeight / NormalWeight / ConstantWeight) or scalars;
        per-synapse delays are DelaySnippets (dual-backend already) and
        generate on device through the same per-row key schedule.

        mesh: a 1-D jax.sharding mesh (see launch.mesh.make_snn_mesh) —
        populations are partitioned along the neuron axis and `run` /
        `step` / `sweep_gscale` execute on the ShardedEngine; mesh=None
        keeps the single-device Simulator path.

        monitor: a repro.obs.health.HealthConfig — compiles per-population
        spike/rate accumulators, silent/saturation detectors and a NaN/Inf
        guard into the step scan; `run`/`serve_chunk` then return a
        HealthReport.  None (default) or enabled=False builds the exact
        unmonitored program (same jaxpr).
        """
        with trace.span("build", model=self.name, init=init,
                        sharded=mesh is not None):
            return self._build(dt=dt, seed=seed, mesh=mesh, init=init,
                               monitor=monitor)

    def _build(self, dt: float, seed: int, mesh, init: str,
               monitor) -> "CompiledModel":
        with trace.span("validate", populations=len(self.populations),
                        synapses=len(self.synapses)):
            if init not in ("host", "device"):
                raise SpecError(
                    f"init must be 'host' or 'device', got {init!r}")
            if not self.populations:
                raise SpecError(
                    f"model {self.name!r} declares no populations")
            if monitor is not None:
                try:
                    monitor.validate(self.populations)
                except ValueError as e:
                    raise SpecError(f"monitor: {e}") from None
        rng = np.random.default_rng(seed)
        base_key = jax.random.PRNGKey(seed) if init == "device" else None
        mutable = self._mutable_groups()
        net = Network(name=self.name)
        for pop in self.populations.values():
            net.add_population(pop.name, pop.model, pop.n,
                               params=pop.params, input_fn=pop.input_fn,
                               edge_spikes=pop.edge_spikes)

        # init="device" + mesh: per-group fused-construction plans (the
        # engine generates each device's rows locally instead of
        # re-partitioning the full ELL — bit-exact, O(nnz/device) peak)
        local_plans: Dict[str, object] = {}
        for sidx, sp in enumerate(self.synapses):
            n_pre = self.populations[sp.pre].n
            sizes = [self.populations[p].n for p in sp.post]
            n_post_total = int(sum(sizes))
            where = (f"synapse population {sp.name!r} "
                     f"({sp.pre} -> {'+'.join(sp.post)})")

            # delay_ms -> steps, now that dt is known (dt-consistency: a
            # delay that is not an integer number of simulation steps
            # cannot be represented by the ring and would silently round)
            delay_steps = sp.delay_steps
            if sp.delay_ms is not None:
                steps_f = sp.delay_ms / dt
                steps = int(round(steps_f))
                if abs(steps_f - steps) > 1e-6:
                    raise SpecError(
                        f"{where}: delay_ms={sp.delay_ms} is not an "
                        f"integer multiple of dt={dt} "
                        f"({steps_f:.6g} steps); dendritic delays are "
                        "ring-buffered in whole dt steps")
                if steps > MAX_DELAY_STEPS:
                    raise SpecError(
                        f"{where}: delay_ms={sp.delay_ms} is {steps} steps "
                        f"at dt={dt}, exceeding the dendritic ring "
                        f"capacity MAX_DELAY_STEPS={MAX_DELAY_STEPS}")
                delay_steps = steps

            if init == "device":
                from repro.sparse import device_init as DI
                try:
                    with trace.span("device_init", group=sp.name,
                                    rows=n_pre, n_post=n_post_total):
                        post_ind, g, valid = DI.device_resolve(
                            sp.connect, jax.random.fold_in(base_key, sidx),
                            n_pre, n_post_total, sp.weight)
                        dd = (None if sp.delay is None
                              else DI.device_delays(
                                  jax.random.fold_in(base_key, sidx), n_pre,
                                  post_ind.shape[1], sp.delay))
                except (ValueError, TypeError, NotImplementedError) as e:
                    # TypeError here is our own declaration check (numpy
                    # weight callables can't be traced), not a user bug
                    raise SpecError(f"{where}: {e}") from None
            else:
                try:
                    with trace.span("host_init", group=sp.name,
                                    rows=n_pre, n_post=n_post_total):
                        post_ind, g, valid = sp.connect.resolve(
                            rng, n_pre, n_post_total,
                            _as_weight_fn(sp.weight))
                except ValueError as e:
                    raise SpecError(f"{where}: {e}") from None
                # delays draw from the same rng *after* connectivity and
                # weights, so delay-free specs reproduce their pre-delay
                # graphs bit for bit
                dd = (None if sp.delay is None
                      else sp.delay(rng, post_ind.shape))

            xp = jnp if init == "device" else np
            # zero delay draws in invalid slots (the ELLSynapses contract:
            # invalid slots -> 0), so a ring bound inferred from the slot
            # array never sizes off invalid-slot noise
            if dd is not None:
                dd = xp.where(valid, dd, 0).astype(xp.int32)
            lo = 0
            for pname, n_p, gname in zip(sp.post, sizes, sp.group_names()):
                hi = lo + n_p
                if len(sp.post) == 1:
                    idx, gg, vv, dv = post_ind, g, valid, dd
                else:
                    mask = (post_ind >= lo) & (post_ind < hi) & valid
                    idx = xp.where(mask, post_ind - lo, 0).astype(xp.int32)
                    gg = xp.where(mask, g, 0.0).astype(xp.float32)
                    vv = mask
                    dv = (None if dd is None
                          else xp.where(mask, dd, 0).astype(xp.int32))
                try:
                    # SynapseGroup owns the representation conflict rules
                    # (incl. dense vs a custom update writing g)
                    group = SynapseGroup(
                        name=gname, pre=sp.pre, post=pname,
                        ell=F.triple_to_ell(idx, gg, vv, n_p, delay=dv),
                        representation=sp.representation,
                        propagation=sp.propagation,
                        wum=sp.wum, psm=sp.psm,
                        delay_steps=delay_steps,
                        max_delay=(None if sp.delay is None
                                   else sp.delay.max_steps),
                        sign=sp.sign,
                        mutable_g=gname in mutable)
                except ValueError as e:
                    raise SpecError(f"{where}: {e}") from None
                net.add_synapse(group)
                if init == "device" and mesh is not None:
                    from repro.sparse import device_init as DI
                    local_plans[gname] = DI.LocalInitPlan(
                        connect=sp.connect,
                        key=jax.random.fold_in(base_key, sidx),
                        n_pre=n_pre, n_post_total=n_post_total,
                        weight=sp.weight, delay=sp.delay,
                        post_window=((lo, hi) if len(sp.post) > 1
                                     else None))
                lo = hi

        # resolve the observation/intervention surface against the built
        # network (deep validation: vars, reductions, writability)
        with trace.span("validate", probes=len(self.probes),
                        custom_updates=len(self.custom_updates)):
            probes = PR.resolve_probes(self.probes, net)
            custom = CU.resolve_custom_updates(self.custom_updates, net)

        # audit the tile the ELL-spmv kernel would pick for every group
        # (choose_block_spmv records an instant trace event per decision:
        # chosen tile, occupancy estimate, VMEM footprint — auditable even
        # for groups the representation choice routed to the dense path)
        for g in net.synapses:
            AT.choose_block_spmv(g.ell.n_pre, g.ell.max_conn, g.ell.n_post,
                                 b=1, tag=f"{g.name}:{g.representation}")

        engine = None
        if mesh is not None:
            from repro.core.snn.engine import ShardedEngine
            with trace.span("shard", devices=len(mesh.devices.flat)):
                engine = ShardedEngine(net, mesh, dt=dt, seed=seed,
                                       probes=probes, custom_updates=custom,
                                       monitor=monitor,
                                       local_init=local_plans or None)
        with trace.span("codegen", populations=len(net.populations)):
            sim = Simulator(net, dt=dt, seed=seed, probes=probes,
                            custom_updates=custom, monitor=monitor)
        return CompiledModel(spec=self, network=net, simulator=sim,
                             engine=engine)


@dataclasses.dataclass
class SweepResult:
    """One vmapped gscale sweep: per-candidate statistics."""

    values: jax.Array                      # [n_candidates]
    rates_hz: Dict[str, jax.Array]         # pop -> [n_candidates]
    finite: jax.Array                      # [n_candidates] bool
    spike_counts: Dict[str, jax.Array]     # pop -> [n_candidates, n]
    recordings: object = None              # Recordings, leading cand. axis


class CompiledModel:
    """A built network: validated spec + generated simulator.

    Wraps the lower-level Simulator with a cached-jit `run`, a `step`, and
    the first-class `sweep_gscale` (one compile, vmapped over candidates)
    that the conductance-scaling study drives.  When built with a mesh,
    `run`/`step`/`sweep_gscale` execute on the multi-device ShardedEngine
    instead (same results, neuron axis partitioned over devices).
    """

    def __init__(self, spec: ModelSpec, network: Network,
                 simulator: Simulator, engine=None):
        self.spec = spec
        self.network = network
        self.simulator = simulator
        self.engine = engine
        self._run_cache: Dict[tuple, Callable] = {}
        self._sweep_cache: Dict[tuple, Callable] = {}

    @property
    def group_names(self) -> List[str]:
        return [g.name for g in self.network.synapses]

    def _expand_group(self, name: str) -> List[str]:
        """Resolve a synapse name to concrete group names.  A multi-post
        synapse population ('exc' -> ['exc', 'inh']) is one declarative
        object but several groups; its declared name addresses all of them."""
        if name in set(self.group_names):
            return [name]
        for sp in self.spec.synapses:
            if sp.name == name:
                return sp.group_names()
        raise SpecError(
            f"unknown synapse group {name!r}; valid names: "
            f"{sorted(set(self.group_names) | {s.name for s in self.spec.synapses})}")

    @property
    def dt(self) -> float:
        return self.simulator.dt

    @property
    def monitor(self):
        """The HealthConfig this model was built with (None when
        unmonitored) — monitored models return a HealthReport as an extra
        trailing element from serve_chunk and in RunResult.health."""
        return self.simulator.monitor

    def init_state(self, key: Optional[jax.Array] = None) -> SimState:
        if self.engine is not None:
            return self.engine.init_state(key)
        return self.simulator.init_state(key)

    def step(self, state: SimState,
             gscales: Optional[Mapping[str, jax.Array]] = None,
             stim: Optional[Mapping[str, jax.Array]] = None):
        stim = self._norm_stim(stim)
        if self.engine is not None:
            return self.engine.step(state, self._norm_gscales(gscales),
                                    stim=stim)
        return self.simulator.step(state, self._norm_gscales(gscales),
                                   stim=stim)

    def _norm_stim(self, stim) -> Dict[str, jax.Array]:
        out = {k: jnp.asarray(v, jnp.float32)
               for k, v in (stim or {}).items()}
        unknown = set(out) - set(self.network.populations)
        if unknown:
            raise SpecError(
                f"unknown stim population(s) {sorted(unknown)}; declared "
                f"populations: {sorted(self.network.populations)}")
        return out

    def _norm_gscales(self, gscales) -> Dict[str, jax.Array]:
        out: Dict[str, jax.Array] = {}
        for k, v in (gscales or {}).items():
            for g in self._expand_group(k):
                if g in out:
                    raise SpecError(
                        f"gscales address synapse group {g!r} twice "
                        f"(overlapping keys in {sorted(gscales)})")
                out[g] = jnp.asarray(v, jnp.float32)
        self.simulator._validate_gscales(out)
        return out

    def _warn_record_raster(self) -> None:
        # the shim's migration target is a probe named after the variable;
        # a user probe already named "spikes" would leave two writers
        # racing for the same Recordings key (last one wins, silently) —
        # refuse loudly instead of warning
        clash = [p.name for p in self.simulator.probes
                 if p.name == "spikes"]
        if clash:
            raise SpecError(
                "record_raster=True collides with the declared probe named "
                "'spikes': the deprecation shim and the probe would both "
                "write the 'spikes' recordings key (last writer wins). "
                "Drop record_raster=True (the probe already records the "
                "raster) or rename the probe.")
        warnings.warn(
            "record_raster is deprecated: declare a probe instead "
            "(spec.probe(name, population, 'spikes') reproduces the "
            "raster bit for bit via run(...).recordings) — see the "
            "migration table in docs/API.md",
            DeprecationWarning, stacklevel=3)

    def run(self, n_steps: int,
            gscales: Optional[Mapping[str, jax.Array]] = None,
            state: Optional[SimState] = None,
            record_raster: bool = False,
            stim: Optional[Mapping[str, jax.Array]] = None) -> RunResult:
        """Run n_steps from `state` (default: fresh init), jit-compiled.
        The compiled executable is cached per (n_steps, gscale keys, stim
        keys, record_raster); gscale/stim *values* are traced, so sweeping
        values reuses one executable.  stim: population -> [n_steps, n]
        external currents injected one row per step — the offline oracle a
        served stream is bit-exact against.  Declared probes come back in
        `RunResult.recordings`."""
        if record_raster:
            self._warn_record_raster()
        gscales = self._norm_gscales(gscales)
        stim = self._norm_stim(stim)
        if self.engine is not None:
            return self.engine.run(n_steps, gscales, state, record_raster,
                                   stim=stim)
        if state is None:
            state = self.init_state()
        keys = tuple(sorted(gscales))
        stim_keys = tuple(sorted(stim))
        cache_key = (n_steps, keys, record_raster, stim_keys)
        compiled = cache_key not in self._run_cache
        if compiled:
            sim = self.simulator

            @jax.jit
            def _run(st, vals, stim_v):
                return sim.run(st, n_steps, dict(zip(keys, vals)),
                               record_raster=record_raster, stim=stim_v)

            self._run_cache[cache_key] = _run
        vals = tuple(gscales[k] for k in keys)
        with trace.span("run", model=self.spec.name, n_steps=n_steps,
                        sharded=False, compile=compiled):
            return self._run_cache[cache_key](state, vals, stim)

    def sweep_gscale(self, group: Union[str, Sequence[str]],
                     values, n_steps: int,
                     state: Optional[SimState] = None) -> SweepResult:
        """Sweep a gscale multiplier over `values` for one synapse group (or
        several scaled together): a single vmapped compile, the batch
        dimension the paper's candidate search wants."""
        requested = [group] if isinstance(group, str) else list(group)
        names = [g for r in requested for g in self._expand_group(r)]
        if self.engine is not None:
            vals, rates, finite, counts, rec = self.engine.sweep_gscale(
                names, values, n_steps, state)
            return SweepResult(values=vals, rates_hz=rates, finite=finite,
                               spike_counts=counts, recordings=rec)
        if state is None:
            state = self.init_state()
        values = jnp.atleast_1d(jnp.asarray(values, jnp.float32))
        cache_key = (tuple(names), n_steps)
        if cache_key not in self._sweep_cache:
            sim = self.simulator

            @jax.jit
            def _sweep(st, vals):
                def one(gval):
                    res = sim.run(st, n_steps, {n: gval for n in names})
                    return (res.rates_hz, res.finite, res.spike_counts,
                            res.recordings)
                return jax.vmap(one)(vals)

            self._sweep_cache[cache_key] = _sweep
        rates, finite, counts, rec = self._sweep_cache[cache_key](state,
                                                                  values)
        return SweepResult(values=values, rates_hz=rates, finite=finite,
                           spike_counts=counts, recordings=rec)

    # -- streaming / serving ----------------------------------------------
    def init_stream_state(self, keys) -> SimState:
        """Batched device-resident state: one independent simulation per
        stream slot (leading stream axis on every leaf).  keys: stacked
        per-slot PRNG keys [max_streams, ...]; slot s starts bit-identical
        to init_state(keys[s])."""
        backend = self.engine if self.engine is not None else self.simulator
        return backend.init_stream_state(jnp.asarray(keys))

    def select_streams(self, state: SimState, idx, keys) -> SimState:
        """Re-pack the stream axis of a batched serving state between
        chunks: new slot j continues old slot ``idx[j]`` **bit-for-bit**
        when ``idx[j] >= 0``, else fresh-inits from ``keys[j]``; the length
        of ``idx`` sets the new slot count.  This is the gateway's slot-
        reclamation + elastic-resize primitive (grow/shrink between
        pre-compiled max_streams buckets, compact after evictions) — one
        call, both backends, surviving streams untouched."""
        backend = self.engine if self.engine is not None else self.simulator
        return backend.select_streams(state, idx, keys)

    def serve_chunk(self, state: SimState, stim, steps_left, n_steps: int,
                    gscales: Optional[Mapping[str, jax.Array]] = None,
                    record_raster: bool = False):
        """Advance every stream slot by up to n_steps (one serving chunk),
        jit-compiled and cached per (n_steps, gscale keys, stim pops,
        record_raster).  Returns (state, counts, raster, recordings) —
        plus a per-slot HealthReport as a 5th element when built with
        `monitor=` — see Simulator.serve_chunk for the masking contract;
        SNNServer (repro.launch.snn_serve) drives this."""
        if record_raster:
            self._warn_record_raster()
        gscales = self._norm_gscales(gscales)
        stim = self._norm_stim(stim)
        steps_left = jnp.asarray(steps_left, jnp.int32)
        if self.engine is not None:
            return self.engine.serve_chunk(state, stim, steps_left, n_steps,
                                           gscales, record_raster)
        keys = tuple(sorted(gscales))
        stim_keys = tuple(sorted(stim))
        cache_key = ("serve", n_steps, keys, stim_keys, record_raster)
        compiled = cache_key not in self._run_cache
        if compiled:
            sim = self.simulator

            @jax.jit
            def _serve(st, stim_v, left, vals):
                return sim.serve_chunk(st, stim_v, left, n_steps,
                                       dict(zip(keys, vals)),
                                       record_raster=record_raster)

            self._run_cache[cache_key] = _serve
        vals = tuple(gscales[k] for k in keys)
        n_streams = int(jax.tree.leaves(state)[0].shape[0])
        with trace.span("serve_chunk", model=self.spec.name,
                        n_steps=n_steps, streams=n_streams, sharded=False,
                        compile=compiled):
            return self._run_cache[cache_key](state, stim, steps_left, vals)

    def serve(self, max_streams: int = 4, chunk: int = 50, **kwargs):
        """A streaming SNNServer over this model: `max_streams` device-
        resident slots on the stream (vmap) axis, advanced `chunk` steps
        per serve_step call.  See repro.launch.snn_serve."""
        from repro.launch.snn_serve import SNNServer
        return SNNServer(self, max_streams=max_streams, chunk=chunk,
                         **kwargs)

    # -- custom updates ----------------------------------------------------
    @property
    def probes(self) -> Tuple:
        """Resolved probes (declaration order)."""
        return self.simulator.probes

    @property
    def custom_update_names(self) -> List[str]:
        return sorted(self.simulator.custom_updates)

    def custom_update(self, name: str,
                      state: Optional[SimState] = None) -> SimState:
        """Run one declared custom update on demand against `state`
        (jit-compiled, cached per update name).  Scheduled (`every=n`)
        updates also fire automatically inside run/sweep/serve scans;
        this entry point is the in-loop intervention hook — e.g. weight
        normalization between sweep rounds without rebuilding."""
        if name not in self.simulator.custom_updates:
            raise SpecError(
                f"unknown custom update {name!r}; declared updates: "
                f"{sorted(self.simulator.custom_updates)}")
        if state is None:
            state = self.init_state()
        if self.engine is not None:
            return self.engine.custom_update(state, name)
        cache_key = ("custom_update", name)
        if cache_key not in self._run_cache:
            sim = self.simulator
            self._run_cache[cache_key] = jax.jit(
                lambda st: sim.custom_update(st, name))
        return self._run_cache[cache_key](state)

    def memory_report(self, n_steps: Optional[int] = None,
                      max_streams: int = 1) -> List[dict]:
        """Live-usage memory accounting: the paper's eq-(1)/(2) elements
        per synapse group *plus* everything the runtime actually holds —
        per-group dynamic state including the dendritic-delay ring,
        per-population neuron state, probe buffers (pass `n_steps` to size
        strided buffers), and the per-stream serving multiplier
        (`max_streams` slots each carry a full copy of the dynamic
        state)."""
        out = [dict(rep) for rep in self.network.memory_report()]
        stream_state = 0
        for rep in out:
            rep["kind"] = "synapse_group"
            stream_state += rep["state_elements"]
        for name, pop in self.network.populations.items():
            n_state = (len(pop.model.state) + 1
                       + (1 if pop.edge_spikes else 0)) * pop.n
            stream_state += n_state
            out.append({"name": name, "kind": "population",
                        "n": pop.n, "state_elements": n_state})
        for p in self.simulator.probes:
            # bytes reflect the *stored* ring: unreduced spikes probes are
            # bit-packed to uint32 [cap, words] (PR 8), 32x smaller than
            # their logical bool [cap, n] samples — the capacity planner
            # sizes off these numbers, so overestimating here would
            # overprovision hosts
            packed = PR.is_packed(p)
            if packed:
                bps = BM.words_for(p.n) * 4
            elif p.reduce is not None:
                bps = 4
            else:
                bps = int(p.n) * 4
            entry = {"name": p.name, "kind": "probe", "target": p.target,
                     "var": p.var, "every": p.every,
                     "elements_per_sample": p.elements_per_sample(),
                     "is_packed": packed,
                     "bytes_per_sample": bps}
            # when n_steps is known, size exactly what _probe_init
            # allocates (window caps the strided capacity); bare window
            # probes report the window itself
            cap = None
            if n_steps is not None:
                cap = PR.capacity(p, n_steps)
            elif p.window is not None:
                cap = p.window
            if cap is not None:
                entry["buffer_elements"] = cap * p.elements_per_sample()
                entry["buffer_bytes"] = cap * bps
            out.append(entry)
        for name, cu in sorted(self.simulator.custom_updates.items()):
            out.append({"name": name, "kind": "custom_update",
                        "target": cu.target, "every": cu.every,
                        "n_reductions": len(cu.reduce)})
        out.append({"name": "streams", "kind": "serving",
                    "max_streams": max_streams,
                    "state_elements_per_stream": stream_state,
                    "stream_state_elements": stream_state * max_streams})
        return out

    def __repr__(self) -> str:
        pops = {p.name: p.n for p in self.spec.populations.values()}
        return (f"CompiledModel({self.spec.name!r}, populations={pops}, "
                f"synapse_groups={self.group_names}, dt={self.dt})")
