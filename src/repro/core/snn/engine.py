"""ShardedEngine: multi-device SNN simulation over a jax.sharding mesh.

Populations are partitioned along the neuron axis: device d owns neuron
block d of *every* population and, for every synapse group, the slots whose
POST neuron lives in that block (`partition_ell_by_post`).  One step runs
entirely under `shard_map`:

  1. spike exchange: each device all-gathers the previous step's spikes
     (one small bool vector per pre population — the only per-step
     communication, following the distributed-construction literature);
  2. synaptic propagation: each device scatter-accumulates currents into
     its own post shard using its connectivity block (the compiled
     weight-update / postsynaptic snippets are reused unchanged via the
     `ell=`/`dense=` overrides of SynapseGroup.step); dendritic delays land
     those currents in the group's post-sharded delay ring — each device
     holds [max_delay+1, n_post_local], with per-synapse delay slots
     partitioned alongside the weights, so no delay state is replicated;
  3. neuron updates: the codegen'd model equations advance the local shard.

The engine is *bit-exact* against the single-device Simulator for the same
seed: the PRNG key schedule is replicated, `input_fn`/`rand` draws are
full-size and sliced per shard (the key must consume the same stream at any
device count), `stim` arrays are zero-padded and sharded along the mesh,
and the post-sharded connectivity preserves per-post-neuron scatter order.
STDP pre-trace vectors (`wu_pre`) shard along the PRE axis; the full trace
vector is all-gathered per step only when learn code reads it, so no
per-neuron or per-synapse plastic state is replicated.  Population sizes
are padded to a multiple of the device count; padded lanes carry
edge-replicated parameters, never spike, and are excluded from the finite
reduction and all outputs.

The whole n-step scan lives inside one shard_map call, so a run compiles to
a single program with one all-gather per (population, step).  `sweep_gscale`
vmaps the scan over candidates *inside* shard_map, composing the paper's
conductance sweep with neuron-axis parallelism.

Serving (`init_stream_state` / `serve_chunk`) reuses the same vmap-inside-
shard_map composition with a *stream* axis instead of the candidate axis:
`max_streams` independent simulations stay resident on device (each slot
its own neuron/synapse/delay state + PRNG key, every leaf gaining a leading
stream dim), and one compiled chunk program advances all slots together
under per-slot `steps_left` masking.  External stimuli enter zero-padded
and sharded along the neuron axis, so a served stream is bit-exact against
the offline `run(..., stim=...)`.
"""

from __future__ import annotations

from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import codegen
from repro.core.snn import bitmask as BM
from repro.core.snn import custom_updates as CU
from repro.core.snn import probes as PR
from repro.core.snn.network import Network
from repro.core.snn.probes import Recordings
from repro.core.snn.simulator import (RunResult, SimState,
                                      _select_streams)
from repro.core.snn.synapses import LocalConnectivity, SynapseState
from repro.launch.mesh import snn_axis
from repro.launch.sharding import neuron_pad, pad_neuron_axis, snn_shardings
from repro.obs import health as HE
from repro.obs import trace
from repro.sparse import formats as F
from repro.sparse.device_init import device_init_local, partition_ell_by_post

__all__ = ["ShardedEngine"]


class ShardedEngine:
    """Runs a built Network partitioned over a 1-D device mesh."""

    def __init__(self, net: Network, mesh, dt: float = 0.5, seed: int = 0,
                 probes=(), custom_updates=(), monitor=None,
                 local_init=None):
        """local_init: optional {group name -> LocalInitPlan} — groups with
        a plan build their post-sharded connectivity blocks with
        `device_init_local` (each device generates only the rows it owns,
        O(nnz/device) peak construction memory) instead of materializing
        the full ELL and calling `partition_ell_by_post`.  Bit-exact
        either way; `ModelSpec.build(init="device", mesh=...)` wires this
        automatically."""
        self.net = net
        self.mesh = mesh
        self.axis = snn_axis(mesh)
        self.n_shards = int(mesh.shape[self.axis])
        self.dt = float(dt)
        self.seed = seed
        # --- opt-in health monitor (same gating as the host Simulator:
        # None / enabled=False never touches the compiled program) ---
        if monitor is not None and monitor.enabled:
            monitor.validate(net.populations)
            self.monitor = monitor
        else:
            self.monitor = None
        self._pop_sizes = {name: pop.n
                           for name, pop in net.populations.items()}
        self._updates = {
            name: codegen.compile_sim(pop.model)
            for name, pop in net.populations.items()
        }
        self._group_names = {g.name for g in net.synapses}
        self._groups = {g.name: g for g in net.synapses}
        self.probes = tuple(probes)
        self.custom_updates = {cu.name: cu for cu in custom_updates}
        self._scheduled = [cu for cu in custom_updates
                           if cu.every is not None]
        D = self.n_shards
        self._npad = {name: neuron_pad(pop.n, D)
                      for name, pop in net.populations.items()}
        self._shard = {name: self._npad[name] // D for name in self._npad}

        self._sh = snn_shardings(mesh, self.axis)
        sh_block = self._sh["block"]
        sh_neuron = self._sh["neuron"]

        # --- partition connectivity: post-shard every group ---------------
        # blocks[gname]: {"g","post","valid"} each [D, n_pre, K_local], or
        # {"dense"}: [D, n_pre, shard] column blocks of the dense mirror.
        self._blocks: Dict[str, Dict[str, jax.Array]] = {}
        self._block_specs: Dict[str, Dict[str, P]] = {}
        self._k_local: Dict[str, int] = {}
        for g in net.synapses:
            n_post_pad = self._npad[g.post]
            if g.representation == "dense" and not g.plastic:
                w = jnp.pad(g.dense,
                            ((0, 0), (0, n_post_pad - g.ell.n_post)))
                blk = w.reshape(g.ell.n_pre, D, n_post_pad // D)
                blk = jnp.moveaxis(blk, 1, 0)
                self._blocks[g.name] = {
                    "dense": jax.device_put(blk, sh_block)}
                self._block_specs[g.name] = {"dense": P(self.axis, None,
                                                        None)}
            else:
                plan = (local_init or {}).get(g.name)
                if plan is not None:
                    # fused local construction: each device generates only
                    # its own rows inside shard_map and exchanges finished
                    # post-sharded slots — the full ELL is never
                    # materialized on any single device
                    with trace.span("device_init_local", group=g.name,
                                    rows=g.ell.n_pre, devices=D):
                        (gg, post, valid, delay, shard_size,
                         k_loc) = device_init_local(
                             plan.connect, plan.key, plan.n_pre,
                             plan.n_post_total, self.mesh,
                             weight=plan.weight, delay=plan.delay,
                             axis=self.axis,
                             post_window=plan.post_window)
                else:
                    with trace.span("partition_ell_by_post", group=g.name,
                                    rows=g.ell.n_pre, k=g.ell.max_conn,
                                    devices=D):
                        (gg, post, valid, delay, shard_size,
                         k_loc) = partition_ell_by_post(g.ell, D)
                assert shard_size == self._shard[g.post]
                self._k_local[g.name] = k_loc
                self._blocks[g.name] = {
                    "g": jax.device_put(gg, sh_block),
                    "post": jax.device_put(post, sh_block),
                    "valid": jax.device_put(valid, sh_block),
                }
                if delay is not None:
                    # per-synapse dendritic delays ride in the same
                    # post-sharded layout as the weights they gate
                    self._blocks[g.name]["delay"] = jax.device_put(
                        delay, sh_block)
                self._block_specs[g.name] = {
                    k: P(self.axis, None, None)
                    for k in self._blocks[g.name]}

        # --- per-neuron parameter arrays (scalars stay baked) -------------
        self._pn_params: Dict[str, Dict[str, jax.Array]] = {}
        self._pn_specs: Dict[str, Dict[str, P]] = {}
        self._scalar_params: Dict[str, Dict[str, object]] = {}
        for name, pop in net.populations.items():
            pn, sc = {}, {}
            for k, v in pop.params.items():
                arr = jnp.asarray(v)
                if arr.ndim and arr.shape[0] == pop.n:
                    pn[k] = jax.device_put(
                        pad_neuron_axis(arr, self._npad[name]), sh_neuron)
                else:
                    sc[k] = v
            self._pn_params[name] = pn
            self._pn_specs[name] = {k: P(self.axis) for k in pn}
            self._scalar_params[name] = sc

        self._state_specs = self._make_state_specs()
        self._run_cache: Dict[tuple, Callable] = {}
        self._sweep_cache: Dict[tuple, Callable] = {}
        self._step_cache: Dict[tuple, Callable] = {}
        self._serve_cache: Dict[tuple, Callable] = {}
        self._custom_cache: Dict[str, Callable] = {}

    # ------------------------------------------------------------------
    # state layout
    # ------------------------------------------------------------------
    def _make_state_specs(self) -> SimState:
        net, ax = self.net, self.axis
        neurons = {name: {k: P(ax) for k in pop.model.state}
                   for name, pop in net.populations.items()}
        spikes = {name: P(ax) for name in net.populations}
        prev = {name: P(ax) for name, pop in net.populations.items()
                if pop.edge_spikes}
        syn = {}
        for g in net.synapses:
            # spec twin of each SynapseState: same pytree nodes, P leaves.
            # The dendritic ring is post-sized, so it shards on the neuron
            # axis like every other post-side buffer, and the wu_pre STDP
            # traces shard along the PRE axis — no per-neuron or
            # per-synapse plastic state is replicated across devices.
            syn[g.name] = SynapseState(
                psm={k: P(ax) for k in g.psm.state},
                wu_pre={k: P(ax) for k in g.wum.pre_state},
                wu_post={k: P(ax) for k in g.wum.post_state},
                g=P(ax, None, None) if g.plastic else None,
                syn={k: P(ax, None, None) for k in g.wum.syn_state},
                dendritic=P(None, ax) if g.needs_ring else None,
                cursor=P() if g.needs_ring else None)
        return SimState(neurons=neurons, spikes=spikes, prev_above=prev,
                        syn=syn, t=P(), key=P(), finite=P())

    def init_state(self, key: Optional[jax.Array] = None) -> SimState:
        """Initial sharded state, bit-equivalent to Simulator.init_state on
        the real lanes (padding lanes replicate the init constants)."""
        if key is None:
            key = jax.random.PRNGKey(self.seed)
        net, D = self.net, self.n_shards
        shn = self._sh["neuron"]
        shr = self._sh["replicated"]
        shb = self._sh["block"]
        put = jax.device_put
        neurons, spikes, prev = {}, {}, {}
        for name, pop in net.populations.items():
            npad = self._npad[name]
            neurons[name] = {
                k: put(jnp.full((npad,), v, jnp.float32), shn)
                for k, v in pop.model.state.items()}
            spikes[name] = put(jnp.zeros((npad,), bool), shn)
            if pop.edge_spikes:
                prev[name] = put(jnp.zeros((npad,), bool), shn)
        syn = {}
        for g in net.synapses:
            n_pre = g.ell.n_pre
            npost_pad = self._npad[g.post]
            psm = {k: put(jnp.full((npost_pad,), v, jnp.float32), shn)
                   for k, v in g.psm.state.items()}
            # pre traces shard along the pre-population neuron axis
            # (padded lanes carry the init constant and never spike)
            wu_pre = {k: put(jnp.full((self._npad[g.pre],), v,
                                      jnp.float32), shn)
                      for k, v in g.wum.pre_state.items()}
            wu_post = {k: put(jnp.full((npost_pad,), v, jnp.float32), shn)
                       for k, v in g.wum.post_state.items()}
            gv = (put(self._blocks[g.name]["g"], shb) if g.plastic
                  else None)
            syn_vars = {
                k: put(jnp.full((D, n_pre, self._k_local[g.name]), v,
                                jnp.float32), shb)
                for k, v in g.wum.syn_state.items()}
            if g.needs_ring:
                # dendritic ring sharded along the post axis: each device
                # holds [ring_slots, n_post_local], never a replicated
                # pre-sized buffer
                buf = put(jnp.zeros((g.ring_slots, npost_pad),
                                    jnp.float32), self._sh["ring"])
                cur = put(jnp.zeros((), jnp.int32), shr)
            else:
                buf, cur = None, None
            syn[g.name] = SynapseState(psm=psm, wu_pre=wu_pre,
                                       wu_post=wu_post, g=gv, syn=syn_vars,
                                       dendritic=buf, cursor=cur)
        return SimState(
            neurons=neurons, spikes=spikes, prev_above=prev, syn=syn,
            t=put(jnp.zeros((), jnp.float32), shr), key=put(key, shr),
            finite=put(jnp.ones((), bool), shr))

    # ------------------------------------------------------------------
    # local (per-device) computation
    # ------------------------------------------------------------------
    def _squeeze_blocks(self, tree):
        """[1, n_pre, K] local views -> [n_pre, K]."""
        return jax.tree.map(lambda x: x[0] if x.ndim == 3 else x, tree)

    def _squeeze_syn(self, syn):
        out = {}
        for name, s in syn.items():
            out[name] = s.__class__(
                psm=s.psm, wu_pre=s.wu_pre, wu_post=s.wu_post,
                g=None if s.g is None else s.g[0],
                syn={k: v[0] for k, v in s.syn.items()},
                dendritic=s.dendritic, cursor=s.cursor)
        return out

    def _unsqueeze_syn(self, syn):
        out = {}
        for name, s in syn.items():
            out[name] = s.__class__(
                psm=s.psm, wu_pre=s.wu_pre, wu_post=s.wu_post,
                g=None if s.g is None else s.g[None],
                syn={k: v[None] for k, v in s.syn.items()},
                dendritic=s.dendritic, cursor=s.cursor)
        return out

    def _local_step(self, state: SimState, blocks, pn_params,
                    gscales: Mapping[str, jax.Array],
                    stim: Optional[Mapping[str, jax.Array]] = None):
        """One dt step on this device's shard; mirrors Simulator.step
        line for line (key schedule, group order, update order).
        stim: population -> [S] local shard of zero-padded external
        currents (sharded along the neuron axis by _pad_stim)."""
        stim = stim or {}
        net, dt, ax = self.net, self.dt, self.axis
        d = jax.lax.axis_index(ax)
        key, *subkeys = jax.random.split(state.key,
                                         1 + 2 * len(net.populations))
        subkeys = iter(subkeys)

        # 0. spike exchange, bit-packed (GeNN's 32x spike bitmask): each
        # device packs its bool shard into uint32 words, all-gathers the
        # words — 8x less wire traffic than gathering bool bytes — and
        # unpacks device-locally.  Round-trip is exact, so the gathered
        # vector is bitwise the old one.
        full_spikes = {}
        D = self.n_shards
        for name in sorted({g.pre for g in net.synapses}):
            seg = self._shard[name]
            words = BM.pack_spikes(state.spikes[name])
            fw = jax.lax.all_gather(words, ax, tiled=True)
            full = BM.unpack_segments(fw.reshape(D, BM.words_for(seg)), seg)
            full_spikes[name] = full[: net.populations[name].n]

        # 1. synaptic propagation into the local post shard --------------
        isyn = {name: jnp.zeros((self._shard[name],), jnp.float32)
                for name in net.populations}
        new_syn = dict(state.syn)
        for g in net.synapses:
            gs = jnp.asarray(gscales.get(g.name, 1.0), jnp.float32)
            blk = blocks[g.name]
            if "dense" in blk:
                ell_l, dense_l = None, blk["dense"]
                # a local ELL stand-in keeps post-side shapes consistent
                ell_l = F.ELLSynapses(
                    g=jnp.zeros((g.ell.n_pre, 1), jnp.float32),
                    post_ind=jnp.zeros((g.ell.n_pre, 1), jnp.int32),
                    valid=jnp.zeros((g.ell.n_pre, 1), bool),
                    n_post=self._shard[g.post])
            else:
                ell_l = F.ELLSynapses(g=blk["g"], post_ind=blk["post"],
                                      valid=blk["valid"],
                                      n_post=self._shard[g.post],
                                      delay=blk.get("delay"))
                dense_l = None
            v_post = state.neurons[g.post].get("V")
            new_pre_local = None
            pre_arg = None
            if g.wum.pre_state:
                # wu_pre shards along the PRE axis: advance the local
                # trace segment (the elementwise pre_step commutes with
                # slicing; padded lanes never spike) and gather the full
                # vector only when learn code actually reads it — a
                # per-step transient, never replicated persistent state
                new_pre_local = state.syn[g.name].wu_pre
                if g._wu.pre_step is not None:
                    new_pre_local = g._wu.pre_step(
                        state.syn[g.name].wu_pre, g.wum.params,
                        {"dt": dt, "t": state.t,
                         "delay": jnp.float32(g.delay_steps),
                         "pre_spike":
                             state.spikes[g.pre].astype(jnp.float32)})
                pre_arg = {}
                if g._wu.learn is not None:
                    pre_arg = {
                        k: jax.lax.all_gather(
                            v, ax, tiled=True)[: g.ell.n_pre]
                        for k, v in new_pre_local.items()}
            s_new, cur = g.step(
                state.syn[g.name], full_spikes[g.pre], gs, dt,
                v_post=v_post, post_spikes=state.spikes[g.post], t=state.t,
                conn=LocalConnectivity(ell=ell_l, dense=dense_l),
                pre_traces=pre_arg)
            if new_pre_local is not None:
                s_new = s_new.__class__(
                    psm=s_new.psm, wu_pre=new_pre_local,
                    wu_post=s_new.wu_post, g=s_new.g, syn=s_new.syn,
                    dendritic=s_new.dendritic, cursor=s_new.cursor)
            new_syn[g.name] = s_new
            isyn[g.post] = isyn[g.post] + cur

        # 2+3. neuron updates on the local shard -------------------------
        new_neurons, new_spikes = {}, {}
        new_prev = dict(state.prev_above)
        finite = state.finite
        for name, pop in net.populations.items():
            k_in, k_rand = next(subkeys), next(subkeys)
            S = self._shard[name]
            lane = d * S + jnp.arange(S)
            lane_valid = lane < pop.n
            cur = isyn[name]
            if pop.input_fn is not None:
                # full-size draw + slice: bit-identical to the unsharded
                # path (the key consumes the same stream regardless of D)
                full = pop.input_fn(k_in, state.t, pop.n)
                full = jnp.pad(full, (0, self._npad[name] - pop.n))
                cur = cur + jax.lax.dynamic_slice(full, (d * S,), (S,))
            if name in stim:
                # stim arrives zero-padded and sharded along the neuron
                # axis (see _pad_stim): the local segment adds directly —
                # bit-identical to the old replicated draw + slice, with
                # 1/D the per-device footprint
                cur = cur + jnp.asarray(stim[name], jnp.float32)
            params = dict(self._scalar_params[name])
            params.update(pn_params[name])
            ext = {"Isyn": cur, "dt": jnp.float32(dt), "t": state.t}
            if pop.model.needs_rand:
                full = jax.random.uniform(k_rand, (pop.n,))
                full = jnp.pad(full, (0, self._npad[name] - pop.n))
                ext["rand"] = jax.lax.dynamic_slice(full, (d * S,), (S,))
            ns, above = self._updates[name](state.neurons[name], params,
                                           ext)
            if pop.edge_spikes:
                spk = above & ~state.prev_above[name]
                new_prev[name] = above
            else:
                spk = above
            new_neurons[name] = ns
            new_spikes[name] = spk & lane_valid
            for arr in ns.values():
                finite = finite & jnp.all(
                    jnp.isfinite(jnp.where(lane_valid, arr, 0.0)))

        new_state = SimState(
            neurons=new_neurons, spikes=new_spikes, prev_above=new_prev,
            syn=new_syn, t=state.t + dt, key=key, finite=finite)
        new_state = self._run_scheduled_local(new_state, blocks, pn_params)
        return new_state, new_spikes

    def _combine_finite(self, finite):
        return jax.lax.pmin(finite.astype(jnp.int32), self.axis) == 1

    # ------------------------------------------------------------------
    # health monitor plumbing (mirrors Simulator._health_* with psum'd
    # partial sums and lane/slot-masked guards; integer psum keeps the
    # per-step counts — and hence every downstream float op — bitwise
    # identical to the host path)
    # ------------------------------------------------------------------
    def _health_counts_local(self, spikes) -> Dict[str, jax.Array]:
        """Full-population scalar int32 spike count for one step (local
        spikes are already lane_valid-masked, so padded lanes add 0)."""
        return {p: jax.lax.psum(jnp.sum(spikes[p].astype(jnp.int32)),
                                self.axis)
                for p in self._pop_sizes}

    def _health_ok_local(self, state: SimState, blocks) -> jax.Array:
        """This device's shard of the NaN guard: V on valid lanes, plastic
        g on valid ELL slots.  Per-device verdicts are merged at scan exit
        (HE.combine_across_devices), preserving the host's first-bad-step."""
        ok = jnp.ones((), bool)
        d = jax.lax.axis_index(self.axis)
        for name, pop in self.net.populations.items():
            v = state.neurons[name].get("V")
            if v is not None:
                S = self._shard[name]
                lane_valid = d * S + jnp.arange(S) < pop.n
                ok = ok & jnp.all(jnp.isfinite(
                    jnp.where(lane_valid, v, 0.0)))
        for g in self.net.synapses:
            st = state.syn[g.name]
            if st.g is not None:
                ok = ok & jnp.all(jnp.isfinite(
                    jnp.where(blocks[g.name]["valid"], st.g, 0.0)))
        return ok

    # ------------------------------------------------------------------
    # custom updates on the local shard (mirrors Simulator._apply_custom;
    # cross-device reductions via psum/pmax/pmin, per-post reductions are
    # device-local because each device owns its post shard)
    # ------------------------------------------------------------------
    def _run_scheduled_local(self, state: SimState, blocks,
                             pn_params) -> SimState:
        if not self._scheduled:
            return state
        elapsed = jnp.int32(jnp.round(state.t / jnp.float32(self.dt)))
        for cu in self._scheduled:
            trig = (elapsed % cu.every) == 0
            state = self._apply_custom_local(state, cu, trig, blocks,
                                             pn_params)
        return state

    def _group_reduce_local(self, op, val, blk, axis, denom_all: float,
                            n_post_local: int):
        """One declared group reduction on this device's connectivity
        block.  'post' needs no communication (the device owns every
        synapse targeting its post shard); 'pre'/'all' combine per-device
        partials with psum/pmax/pmin."""
        ax = self.axis
        valid = blk["valid"]
        neutral = PR.reduce_neutral(op)
        masked = jnp.where(valid, jnp.asarray(val, jnp.float32), neutral)
        if axis == "post":
            per_post = CU._scatter_post(val, blk["post"], valid,
                                        n_post_local, op)
            return CU.gather_post(per_post, blk["post"])
        if axis == "pre":
            if op in ("sum", "mean"):
                rs = jax.lax.psum(jnp.sum(
                    jnp.where(valid, jnp.asarray(val, jnp.float32), 0.0),
                    axis=1), ax)
                if op == "sum":
                    return rs[:, None]
                cnt = jax.lax.psum(
                    jnp.sum(valid.astype(jnp.float32), axis=1), ax)
                return jnp.where(cnt > 0, rs / jnp.maximum(cnt, 1.0),
                                 0.0)[:, None]
            part = (jnp.max(masked, axis=1) if op == "max"
                    else jnp.min(masked, axis=1))
            comb = jax.lax.pmax if op == "max" else jax.lax.pmin
            return comb(part, ax)[:, None]
        # axis == "all": scalar over the whole matrix
        if op in ("sum", "mean"):
            tot = jax.lax.psum(jnp.sum(
                jnp.where(valid, jnp.asarray(val, jnp.float32), 0.0)), ax)
            return tot / jnp.float32(denom_all) if op == "mean" else tot
        part = jnp.max(masked) if op == "max" else jnp.min(masked)
        comb = jax.lax.pmax if op == "max" else jax.lax.pmin
        return comb(part, ax)

    def _pop_reduce_local(self, op, val, lane_valid, denom: float):
        """Population-axis reduction over the local shard, combined
        across devices (padded lanes neutral-masked)."""
        ax = self.axis
        neutral = PR.reduce_neutral(op)
        masked = jnp.where(lane_valid, jnp.asarray(val, jnp.float32),
                           neutral)
        if op in ("sum", "mean"):
            tot = jax.lax.psum(jnp.sum(
                jnp.where(lane_valid, jnp.asarray(val, jnp.float32),
                          0.0)), ax)
            return tot / jnp.float32(denom) if op == "mean" else tot
        part = jnp.max(masked) if op == "max" else jnp.min(masked)
        comb = jax.lax.pmax if op == "max" else jax.lax.pmin
        return comb(part, ax)

    def _apply_custom_local(self, state: SimState, cu, trig, blocks,
                            pn_params) -> SimState:
        ext = {"dt": jnp.float32(self.dt), "t": state.t}
        if cu.kind == "group":
            grp = self._groups[cu.target]
            blk = blocks[cu.target]
            st = state.syn[cu.target]
            g_arr = st.g if st.g is not None else blk["g"]
            cu_vars = {"g": g_arr, **st.syn}
            red = {
                rname: self._group_reduce_local(
                    op, cu_vars[var], blk, axis, cu.denom_all,
                    self._shard[grp.post])
                for rname, (op, var, axis) in cu.reduce.items()}
            new = cu.fn(cu_vars, cu.params, red, ext)
            valid = blk["valid"]

            def sel(name, old):
                if name not in cu.writes:
                    return old
                return jnp.where(trig, jnp.where(valid, new[name], old),
                                 old)

            # NaN guard: the update's writes must trip `finite` exactly
            # like an over-scaled conductance would (local check; the
            # run/step wrappers pmin-combine across devices)
            ok = jnp.ones((), bool)
            for name in cu.writes:
                ok = ok & jnp.all(jnp.isfinite(
                    jnp.where(valid, new[name], 0.0)))
            finite = state.finite & jnp.where(trig, ok, True)
            new_syn = dict(state.syn)
            new_syn[cu.target] = SynapseState(
                psm=st.psm, wu_pre=st.wu_pre, wu_post=st.wu_post,
                g=(sel("g", g_arr) if st.g is not None else None),
                syn={k: sel(k, v) for k, v in st.syn.items()},
                dendritic=st.dendritic, cursor=st.cursor)
            return SimState(neurons=state.neurons, spikes=state.spikes,
                            prev_above=state.prev_above, syn=new_syn,
                            t=state.t, key=state.key, finite=finite)
        # population target
        pop = self.net.populations[cu.target]
        d = jax.lax.axis_index(self.axis)
        S = self._shard[cu.target]
        lane_valid = d * S + jnp.arange(S) < pop.n
        cu_vars = dict(state.neurons[cu.target])
        red = {rname: self._pop_reduce_local(op, cu_vars[var], lane_valid,
                                             cu.denom_all)
               for rname, (op, var, _axis) in cu.reduce.items()}
        # cu.params carries the resolve-time merge (update params + full
        # pop params); re-overlay the population params with their local
        # shard / baked-scalar forms
        params = dict(cu.params)
        params.update(self._scalar_params[cu.target])
        params.update(pn_params[cu.target])
        new = cu.fn(cu_vars, params, red, ext)
        ok = jnp.ones((), bool)
        for name in cu.writes:
            ok = ok & jnp.all(jnp.isfinite(
                jnp.where(lane_valid, new[name], 0.0)))
        finite = state.finite & jnp.where(trig, ok, True)
        new_neurons = dict(state.neurons)
        new_neurons[cu.target] = {
            k: (jnp.where(trig, new[k], v) if k in cu.writes else v)
            for k, v in state.neurons[cu.target].items()}
        return SimState(neurons=new_neurons, spikes=state.spikes,
                        prev_above=state.prev_above, syn=state.syn,
                        t=state.t, key=state.key, finite=finite)

    # ------------------------------------------------------------------
    # probes on the local shard.  Per-neuron-shaped probes store local
    # rows (the buffer shards along the neuron axis, gathered on exit);
    # reduced per-neuron probes all-gather the full vector and apply the
    # identical reduction (bit-exact vs the host build); synapse-matrix
    # reductions combine per-device partials with psum/pmax/pmin.
    # ------------------------------------------------------------------
    def _probe_sharded(self, p) -> bool:
        """True when the probe's buffer rows shard along the neuron axis
        (wu_pre buffers shard along the PRE population's axis)."""
        return p.reduce is None

    def _probe_local_shape(self, p, cap: int):
        if p.reduce is not None:
            return (cap,)
        if p.varkind == "wu_pre":
            return (cap, self._shard[self._groups[p.target].pre])
        if PR.is_packed(p):
            # spike rows live as uint32 bitmasks (32x smaller rings);
            # unpacked shard-locally at finalize, before the exit gather
            return (cap, BM.words_for(self._shard[p.target]))
        if p.kind == "population":
            return (cap, self._shard[p.target])
        return (cap, self._shard[self._groups[p.target].post])

    def _probe_init_local(self, n_steps: int, serving: bool = False):
        bufs, caps = {}, {}
        for p in self.probes:
            cap = PR.capacity(p, n_steps, serving=serving)
            caps[p.name] = cap
            bufs[p.name] = jnp.zeros(self._probe_local_shape(p, cap),
                                     jnp.uint32 if PR.is_packed(p)
                                     else p.dtype)
        return bufs, caps

    def _probe_local_value(self, p, state, spikes, blocks):
        ax = self.axis
        if p.varkind == "wu_pre":
            val = state.syn[p.target].wu_pre[p.var]   # local pre shard
            if p.reduce is None:
                return val                            # sharded buffer rows
            full = jax.lax.all_gather(val, ax, tiled=True)[: p.n]
            return PR.vector_reduce(full, p.reduce, p.denom)
        if p.varkind in ("g", "syn"):
            blk = blocks[p.target]
            st = state.syn[p.target]
            val = st.g if p.varkind == "g" else st.syn[p.var]
            op = p.reduce
            masked = jnp.where(blk["valid"], jnp.asarray(val, jnp.float32),
                               PR.reduce_neutral(op))
            if op in ("sum", "mean"):
                tot = jax.lax.psum(jnp.sum(
                    jnp.where(blk["valid"],
                              jnp.asarray(val, jnp.float32), 0.0)), ax)
                return tot / jnp.float32(p.denom) if op == "mean" else tot
            part = jnp.max(masked) if op == "max" else jnp.min(masked)
            comb = jax.lax.pmax if op == "max" else jax.lax.pmin
            return comb(part, ax)
        if p.varkind == "neuron":
            val = state.neurons[p.target][p.var]
        elif p.varkind == "spikes":
            val = spikes[p.target]
        elif p.varkind == "psm":
            val = state.syn[p.target].psm[p.var]
        else:  # wu_post
            val = state.syn[p.target].wu_post[p.var]
        if p.reduce is None:
            return val                              # local shard rows
        full = jax.lax.all_gather(val, ax, tiled=True)[: p.n]
        return PR.vector_reduce(full, p.reduce, p.denom)

    def _probe_write_local(self, bufs, caps, start, i, state, spikes,
                           blocks, gate=None):
        out = dict(bufs)
        for p in self.probes:
            base = PR.probe_base(p, start)
            active, slot = PR.sample_slot(p, start, base, i, caps[p.name])
            if gate is not None:
                active = active & gate
            val = self._probe_local_value(p, state, spikes, blocks)
            if PR.is_packed(p):
                val = BM.pack_spikes(val)
            out[p.name] = PR.write_sample(bufs[p.name], slot, active, val)
        return out

    def _probe_finalize_local(self, bufs, caps, start, n_eff,
                              serving: bool = False):
        data, counts = {}, {}
        for p in self.probes:
            d, counts[p.name] = PR.finalize(
                bufs[p.name], start, n_eff, p, caps[p.name],
                use_window=not serving)
            if PR.is_packed(p):
                # unpack to the local shard width while still inside
                # shard_map, so the exit gather/crop contract is unchanged
                d = BM.unpack_rows(d, self._shard[p.target])
            data[p.name] = d
        return data, counts

    def _probe_out_specs(self, lead=()):
        """(data specs, count specs) keyed by probe name; `lead` prefixes
        extra unsharded axes (sweep candidates / serving streams)."""
        data, counts = {}, {}
        for p in self.probes:
            if self._probe_sharded(p):
                data[p.name] = P(*lead, None, self.axis)
            elif p.reduce is None:
                data[p.name] = P(*lead, None, None)
            else:
                data[p.name] = P(*lead, None)
            counts[p.name] = P(*lead)
        return data, counts

    def _crop_probe_data(self, data):
        """Gathered neuron-sharded buffers carry padded lanes; crop them."""
        return {p.name: (data[p.name][..., : p.n]
                         if self._probe_sharded(p) else data[p.name])
                for p in self.probes}

    def _step_count(self, state: SimState) -> jax.Array:
        return jnp.int32(jnp.round(state.t / jnp.float32(self.dt)))

    # ------------------------------------------------------------------
    # compiled entry points (cached like CompiledModel)
    # ------------------------------------------------------------------
    def _validate_gscales(self, gscales) -> None:
        if not gscales:
            return
        unknown = set(gscales) - self._group_names
        if unknown:
            raise ValueError(
                f"unknown gscale key(s) {sorted(unknown)}; valid synapse "
                f"group names: {sorted(self._group_names)}")

    def _validate_stim(self, stim) -> None:
        if not stim:
            return
        unknown = set(stim) - set(self.net.populations)
        if unknown:
            raise ValueError(
                f"unknown stim population(s) {sorted(unknown)}; declared "
                f"populations: {sorted(self.net.populations)}")

    def _pad_stim(self, stim) -> Dict[str, jax.Array]:
        """Zero-pad each stim array's neuron axis (the last) to the padded
        population size so it enters shard_map sharded along the mesh
        instead of replicated.  Padded lanes add 0 into padded shard lanes
        (masked out of every output), so this is bit-identical to the old
        full-size replicated array + per-device dynamic_slice at 1/D the
        per-device footprint."""
        out = {}
        for k, v in stim.items():
            arr = jnp.asarray(v, jnp.float32)
            pad = self._npad[k] - self.net.populations[k].n
            if pad:
                cfg = [(0, 0)] * (arr.ndim - 1) + [(0, pad)]
                arr = jnp.pad(arr, cfg)
            out[k] = arr
        return out

    def _in_specs(self):
        return (self._state_specs, self._block_specs, self._pn_specs)

    def _shard_map(self, fn, in_specs, out_specs):
        return jax.jit(shard_map(fn, mesh=self.mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=False))

    def _make_run(self, n_steps: int, keys: Tuple[str, ...],
                  record_raster: bool, stim_keys: Tuple[str, ...] = ()):
        mon = self.monitor

        def local_fn(state, blocks, pn_params, vals, stim):
            blocks = {k: self._squeeze_blocks(v) for k, v in blocks.items()}
            state = state.__class__(
                neurons=state.neurons, spikes=state.spikes,
                prev_above=state.prev_above,
                syn=self._squeeze_syn(state.syn), t=state.t, key=state.key,
                finite=state.finite)
            gs = dict(zip(keys, vals))
            start = self._step_count(state)
            bufs0, caps = self._probe_init_local(n_steps)

            def body(carry, xs):
                i, stim_t = xs
                if mon is not None:
                    st, counts, bufs, hstate = carry
                else:
                    st, counts, bufs = carry
                st2, spk = self._local_step(st, blocks, pn_params, gs,
                                            stim=stim_t)
                counts = {k: counts[k] + spk[k] for k in counts}
                bufs = self._probe_write_local(bufs, caps, start, i, st2,
                                               spk, blocks)
                out = spk if record_raster else None
                if mon is not None:
                    hstate = HE.accumulate(
                        mon, hstate, self._health_counts_local(spk),
                        self._health_ok_local(st2, blocks), self.dt,
                        self._pop_sizes)
                    return (st2, counts, bufs, hstate), out
                return (st2, counts, bufs), out

            counts0 = {name: jnp.zeros((self._shard[name],), jnp.int32)
                       for name in self.net.populations}
            xs = (jnp.arange(n_steps, dtype=jnp.int32),
                  stim if stim_keys else None)
            carry0 = (state, counts0, bufs0)
            if mon is not None:
                carry0 = carry0 + (HE.init_state(self._pop_sizes),)
            carry_out, raster = jax.lax.scan(body, carry0, xs,
                                             length=n_steps)
            st2, counts, bufs = carry_out[:3]
            pdata, pcounts = self._probe_finalize_local(bufs, caps, start,
                                                        n_steps)
            st2 = st2.__class__(
                neurons=st2.neurons, spikes=st2.spikes,
                prev_above=st2.prev_above,
                syn=self._unsqueeze_syn(st2.syn), t=st2.t, key=st2.key,
                finite=self._combine_finite(st2.finite))
            if mon is not None:
                hstate = HE.combine_across_devices(carry_out[3], self.axis)
                health = HE.finalize(mon, hstate, self.dt, self._pop_sizes)
                return st2, counts, raster, pdata, pcounts, health
            return st2, counts, raster, pdata, pcounts

        ax = self.axis
        counts_specs = {name: P(ax) for name in self.net.populations}
        raster_specs = ({name: P(None, ax) for name in self.net.populations}
                        if record_raster else None)
        pdata_specs, pcount_specs = self._probe_out_specs()
        out_specs = (self._state_specs, counts_specs, raster_specs,
                     pdata_specs, pcount_specs)
        if mon is not None:
            out_specs = out_specs + (
                HE.report_specs(self._pop_sizes, lambda: P()),)
        return self._shard_map(
            local_fn,
            in_specs=(*self._in_specs(), tuple(P() for _ in keys),
                      {k: P(None, ax) for k in stim_keys}),
            out_specs=out_specs)

    def run(self, n_steps: int,
            gscales: Optional[Mapping[str, jax.Array]] = None,
            state: Optional[SimState] = None,
            record_raster: bool = False,
            stim: Optional[Mapping[str, jax.Array]] = None) -> RunResult:
        """Scan n_steps under shard_map; spike statistics match the
        single-device Simulator bit for bit.  stim: population ->
        [n_steps, n] external currents (full-size; sliced per shard)."""
        gscales = dict(gscales or {})
        self._validate_gscales(gscales)
        self._validate_stim(stim)
        stim = self._pad_stim(stim or {})
        if state is None:
            state = self.init_state()
        keys = tuple(sorted(gscales))
        stim_keys = tuple(sorted(stim))
        cache_key = (n_steps, keys, record_raster, stim_keys)
        compiled = cache_key not in self._run_cache
        if compiled:
            self._run_cache[cache_key] = self._make_run(n_steps, keys,
                                                        record_raster,
                                                        stim_keys)
        vals = tuple(jnp.asarray(gscales[k], jnp.float32) for k in keys)
        with trace.span("run", model=self.net.name, n_steps=n_steps,
                        sharded=True, compile=compiled):
            out = self._run_cache[cache_key](
                state, self._blocks, self._pn_params, vals, stim)
        st2, counts, raster, pdata, pcounts = out[:5]
        health = out[5] if self.monitor is not None else None
        pops = self.net.populations
        counts = {k: v[: pops[k].n] for k, v in counts.items()}
        t_sec = n_steps * self.dt * 1e-3
        rates = {k: jnp.mean(v) / t_sec for k, v in counts.items()}
        if record_raster:
            raster = {k: v[:, : pops[k].n] for k, v in raster.items()}
        rec = Recordings(data=self._crop_probe_data(pdata), counts=pcounts)
        return RunResult(state=st2, spike_counts=counts, rates_hz=rates,
                         finite=st2.finite,
                         raster=raster if record_raster else None,
                         recordings=rec, health=health)

    def _make_step(self, keys: Tuple[str, ...],
                   stim_keys: Tuple[str, ...] = ()):
        def local_fn(state, blocks, pn_params, vals, stim):
            blocks = {k: self._squeeze_blocks(v) for k, v in blocks.items()}
            state = state.__class__(
                neurons=state.neurons, spikes=state.spikes,
                prev_above=state.prev_above,
                syn=self._squeeze_syn(state.syn), t=state.t, key=state.key,
                finite=state.finite)
            st2, spk = self._local_step(state, blocks, pn_params,
                                        dict(zip(keys, vals)), stim=stim)
            st2 = st2.__class__(
                neurons=st2.neurons, spikes=st2.spikes,
                prev_above=st2.prev_above,
                syn=self._unsqueeze_syn(st2.syn), t=st2.t, key=st2.key,
                finite=st2.finite)
            return st2, spk

        ax = self.axis
        return self._shard_map(
            local_fn,
            in_specs=(*self._in_specs(), tuple(P() for _ in keys),
                      {k: P(ax) for k in stim_keys}),
            out_specs=(self._state_specs,
                       {name: P(ax) for name in self.net.populations}))

    def step(self, state: SimState,
             gscales: Optional[Mapping[str, jax.Array]] = None,
             stim: Optional[Mapping[str, jax.Array]] = None):
        """One dt step (sharded); returns (new_state, spikes dict [n]).
        stim: population -> [n] external currents (full-size)."""
        gscales = dict(gscales or {})
        self._validate_gscales(gscales)
        self._validate_stim(stim)
        stim = self._pad_stim(stim or {})
        keys = tuple(sorted(gscales))
        stim_keys = tuple(sorted(stim))
        cache_key = (keys, stim_keys)
        if cache_key not in self._step_cache:
            self._step_cache[cache_key] = self._make_step(keys, stim_keys)
        vals = tuple(jnp.asarray(gscales[k], jnp.float32) for k in keys)
        st2, spk = self._step_cache[cache_key](state, self._blocks,
                                               self._pn_params, vals, stim)
        return st2, {k: v[: self.net.populations[k].n]
                     for k, v in spk.items()}

    def _make_sweep(self, n_steps: int, names: Tuple[str, ...]):
        def local_fn(state, blocks, pn_params, vals):
            blocks = {k: self._squeeze_blocks(v) for k, v in blocks.items()}
            state = state.__class__(
                neurons=state.neurons, spikes=state.spikes,
                prev_above=state.prev_above,
                syn=self._squeeze_syn(state.syn), t=state.t, key=state.key,
                finite=state.finite)

            start = self._step_count(state)

            def one(v):
                gs = {n: v for n in names}
                bufs0, caps = self._probe_init_local(n_steps)

                def body(carry, i):
                    st, counts, bufs = carry
                    st2, spk = self._local_step(st, blocks, pn_params, gs)
                    counts = {k: counts[k] + spk[k] for k in counts}
                    bufs = self._probe_write_local(bufs, caps, start, i,
                                                   st2, spk, blocks)
                    return (st2, counts, bufs), None

                counts0 = {name: jnp.zeros((self._shard[name],), jnp.int32)
                           for name in self.net.populations}
                (st2, counts, bufs), _ = jax.lax.scan(
                    body, (state, counts0, bufs0),
                    jnp.arange(n_steps, dtype=jnp.int32), length=n_steps)
                pdata, pcounts = self._probe_finalize_local(
                    bufs, caps, start, n_steps)
                return counts, st2.finite, pdata, pcounts

            counts, finite, pdata, pcounts = jax.vmap(one)(vals)
            return counts, self._combine_finite(finite), pdata, pcounts

        ax = self.axis
        pdata_specs, pcount_specs = self._probe_out_specs(lead=(None,))
        return self._shard_map(
            local_fn,
            in_specs=(*self._in_specs(), P()),
            out_specs=({name: P(None, ax)
                        for name in self.net.populations}, P(),
                       pdata_specs, pcount_specs))

    def sweep_gscale(self, names: Sequence[str], values, n_steps: int,
                     state: Optional[SimState] = None):
        """Vmapped gscale sweep inside shard_map: candidates on the batch
        dimension, neurons on the mesh.  Returns (values, rates, finite,
        counts, recordings) matching CompiledModel.sweep_gscale
        semantics (recordings leaves carry a leading candidate axis)."""
        names = tuple(names)
        self._validate_gscales({n: 1.0 for n in names})
        if state is None:
            state = self.init_state()
        values = jnp.atleast_1d(jnp.asarray(values, jnp.float32))
        cache_key = (tuple(names), n_steps)
        if cache_key not in self._sweep_cache:
            self._sweep_cache[cache_key] = self._make_sweep(n_steps, names)
        counts, finite, pdata, pcounts = self._sweep_cache[cache_key](
            state, self._blocks, self._pn_params, values)
        pops = self.net.populations
        counts = {k: v[:, : pops[k].n] for k, v in counts.items()}
        t_sec = n_steps * self.dt * 1e-3
        rates = {k: jnp.mean(v, axis=1) / t_sec for k, v in counts.items()}
        rec = Recordings(data=self._crop_probe_data(pdata), counts=pcounts)
        return values, rates, finite, counts, rec

    # ------------------------------------------------------------------
    # streaming / serving: a leading stream axis over independent sims
    # ------------------------------------------------------------------
    def _stream_state_specs(self):
        """Spec twin of a stream-batched SimState: every leaf gains a
        leading (unsharded) stream dim in front of its single-sim spec."""
        return jax.tree.map(lambda spec: P(None, *tuple(spec)),
                            self._state_specs)

    def init_stream_state(self, keys: jax.Array) -> SimState:
        """Batched sharded initial state: one independent simulation per
        slot, every leaf broadcast along a leading stream axis (neuron
        shards stay on their devices; per-slot PRNG keys replicated).  Slot
        s starts bit-identical to init_state(keys[s])."""
        keys = jnp.asarray(keys)
        S = int(keys.shape[0])
        base = self.init_state()
        mesh = self.mesh

        def bcast(x, spec):
            sh = NamedSharding(mesh, P(None, *tuple(spec)))
            return jax.device_put(
                jnp.broadcast_to(x[None], (S,) + x.shape), sh)

        st = jax.tree.map(bcast, base, self._state_specs)
        return SimState(
            neurons=st.neurons, spikes=st.spikes, prev_above=st.prev_above,
            syn=st.syn, t=st.t,
            key=jax.device_put(keys, self._sh["replicated"]),
            finite=st.finite)

    def select_streams(self, state: SimState, idx, keys) -> SimState:
        """Re-pack the stream axis between chunks (slot reclamation and
        elastic resize) — semantics match Simulator.select_streams: new
        slot j continues old slot ``idx[j]`` bit-for-bit, ``idx[j] < 0``
        fresh-inits from ``keys[j]``.  The stream axis is the *unsharded*
        leading axis (P(None, ...)), so the gather is device-local: neuron
        shards never move, and surviving slots stay bitwise identical on
        every device."""
        fresh = self.init_stream_state(jnp.asarray(keys))
        return _select_streams(state, fresh, jnp.asarray(idx, jnp.int32))

    def _make_serve(self, n_steps: int, keys: Tuple[str, ...],
                    stim_keys: Tuple[str, ...], record_raster: bool):
        mon = self.monitor

        def local_fn(state, blocks, pn_params, vals, stim, steps_left):
            blocks = {k: self._squeeze_blocks(v) for k, v in blocks.items()}
            gs = dict(zip(keys, vals))

            def one_stream(st, st_stim, left):
                st = st.__class__(
                    neurons=st.neurons, spikes=st.spikes,
                    prev_above=st.prev_above,
                    syn=self._squeeze_syn(st.syn), t=st.t, key=st.key,
                    finite=st.finite)
                start = self._step_count(st)
                bufs0, caps = self._probe_init_local(n_steps, serving=True)

                def body(carry, xs):
                    t_idx, stim_t = xs
                    if mon is not None:
                        st, counts, bufs, hstate = carry
                    else:
                        st, counts, bufs = carry
                    st2, spk = self._local_step(st, blocks, pn_params, gs,
                                                stim=stim_t)
                    act = t_idx < left
                    st2 = jax.tree.map(lambda a, b: jnp.where(act, a, b),
                                       st2, st)
                    spk = {k: v & act for k, v in spk.items()}
                    counts = {k: counts[k] + spk[k] for k in counts}
                    bufs = self._probe_write_local(bufs, caps, start,
                                                   t_idx, st2, spk,
                                                   blocks, gate=act)
                    out = spk if record_raster else None
                    if mon is not None:
                        hstate = HE.accumulate(
                            mon, hstate, self._health_counts_local(spk),
                            self._health_ok_local(st2, blocks), self.dt,
                            self._pop_sizes, gate=act)
                        return (st2, counts, bufs, hstate), out
                    return (st2, counts, bufs), out

                counts0 = {name: jnp.zeros((self._shard[name],), jnp.int32)
                           for name in self.net.populations}
                xs = (jnp.arange(n_steps, dtype=jnp.int32),
                      st_stim if stim_keys else None)
                carry0 = (st, counts0, bufs0)
                if mon is not None:
                    carry0 = carry0 + (HE.init_state(self._pop_sizes),)
                carry_out, raster = jax.lax.scan(body, carry0, xs,
                                                 length=n_steps)
                st2, counts, bufs = carry_out[:3]
                pdata, pcounts = self._probe_finalize_local(
                    bufs, caps, start, jnp.minimum(left, n_steps),
                    serving=True)
                st2 = st2.__class__(
                    neurons=st2.neurons, spikes=st2.spikes,
                    prev_above=st2.prev_above,
                    syn=self._unsqueeze_syn(st2.syn), t=st2.t, key=st2.key,
                    finite=st2.finite)
                if mon is not None:
                    return st2, counts, raster, pdata, pcounts, carry_out[3]
                return st2, counts, raster, pdata, pcounts

            out = jax.vmap(one_stream)(state, stim, steps_left)
            st2, counts, raster, pdata, pcounts = out[:5]
            st2 = st2.__class__(
                neurons=st2.neurons, spikes=st2.spikes,
                prev_above=st2.prev_above, syn=st2.syn, t=st2.t,
                key=st2.key, finite=self._combine_finite(st2.finite))
            if mon is not None:
                # per-device NaN-guard verdicts merge on the batched
                # leaves (same pattern as the finite flag above); every
                # other health leaf is already replicated
                hstate = HE.combine_across_devices(out[5], self.axis)
                health = HE.finalize(mon, hstate, self.dt, self._pop_sizes)
                return st2, counts, raster, pdata, pcounts, health
            return st2, counts, raster, pdata, pcounts

        ax = self.axis
        stream_specs = self._stream_state_specs()
        counts_specs = {name: P(None, ax) for name in self.net.populations}
        raster_specs = ({name: P(None, None, ax)
                         for name in self.net.populations}
                        if record_raster else None)
        pdata_specs, pcount_specs = self._probe_out_specs(lead=(None,))
        out_specs = (stream_specs, counts_specs, raster_specs,
                     pdata_specs, pcount_specs)
        if mon is not None:
            out_specs = out_specs + (
                HE.report_specs(self._pop_sizes, lambda: P(None)),)
        return self._shard_map(
            local_fn,
            in_specs=(stream_specs, self._block_specs, self._pn_specs,
                      tuple(P() for _ in keys),
                      {k: P(None, None, ax) for k in stim_keys},
                      P()),
            out_specs=out_specs)

    def serve_chunk(self, state: SimState, stim: Mapping[str, jax.Array],
                    steps_left: jax.Array, n_steps: int,
                    gscales: Optional[Mapping[str, jax.Array]] = None,
                    record_raster: bool = False):
        """Advance every stream slot by up to n_steps under shard_map:
        streams on the vmap axis, neurons on the mesh.  Semantics match
        Simulator.serve_chunk (per-slot steps_left masking, masked lanes
        exact no-ops); outputs are cropped to real neurons.  Returns
        (state, counts, raster, recordings) with a leading stream axis on
        every recordings leaf — plus a per-slot HealthReport when the
        engine was built with a monitor."""
        gscales = dict(gscales or {})
        self._validate_gscales(gscales)
        self._validate_stim(stim)
        stim = self._pad_stim(stim)
        steps_left = jnp.asarray(steps_left, jnp.int32)
        keys = tuple(sorted(gscales))
        stim_keys = tuple(sorted(stim))
        cache_key = (n_steps, keys, stim_keys, record_raster)
        compiled = cache_key not in self._serve_cache
        if compiled:
            self._serve_cache[cache_key] = self._make_serve(
                n_steps, keys, stim_keys, record_raster)
        vals = tuple(jnp.asarray(gscales[k], jnp.float32) for k in keys)
        n_streams = int(jax.tree.leaves(state)[0].shape[0])
        with trace.span("serve_chunk", model=self.net.name,
                        n_steps=n_steps, streams=n_streams, sharded=True,
                        compile=compiled):
            out = self._serve_cache[cache_key](
                state, self._blocks, self._pn_params, vals, stim,
                steps_left)
        st2, counts, raster, pdata, pcounts = out[:5]
        pops = self.net.populations
        counts = {k: v[:, : pops[k].n] for k, v in counts.items()}
        if record_raster:
            raster = {k: v[:, :, : pops[k].n] for k, v in raster.items()}
        rec = Recordings(data=self._crop_probe_data(pdata), counts=pcounts)
        base = (st2, counts, (raster if record_raster else None), rec)
        if self.monitor is not None:
            return base + (out[5],)
        return base

    # ------------------------------------------------------------------
    # on-demand custom updates (one shard_map'd program per update name)
    # ------------------------------------------------------------------
    def custom_update(self, state: SimState, name: str) -> SimState:
        """Run one declared custom update on demand against a sharded
        state; reductions execute inside shard_map (psum/pmax across the
        mesh, per-post reductions device-local)."""
        if name not in self.custom_updates:
            raise ValueError(
                f"unknown custom update {name!r}; declared updates: "
                f"{sorted(self.custom_updates)}")
        if name not in self._custom_cache:
            cu = self.custom_updates[name]

            def local_fn(state, blocks, pn_params):
                blocks = {k: self._squeeze_blocks(v)
                          for k, v in blocks.items()}
                st = state.__class__(
                    neurons=state.neurons, spikes=state.spikes,
                    prev_above=state.prev_above,
                    syn=self._squeeze_syn(state.syn), t=state.t,
                    key=state.key, finite=state.finite)
                st2 = self._apply_custom_local(st, cu, jnp.bool_(True),
                                               blocks, pn_params)
                return st2.__class__(
                    neurons=st2.neurons, spikes=st2.spikes,
                    prev_above=st2.prev_above,
                    syn=self._unsqueeze_syn(st2.syn), t=st2.t, key=st2.key,
                    finite=self._combine_finite(st2.finite))

            self._custom_cache[name] = self._shard_map(
                local_fn, in_specs=self._in_specs(),
                out_specs=self._state_specs)
        return self._custom_cache[name](state, self._blocks,
                                        self._pn_params)

    def memory_report(self) -> List[dict]:
        """Per-group sharded footprint next to the paper's eq-(1)/(2)
        elements: what one device actually holds (connectivity block,
        dendritic-ring shard, dynamic state)."""
        D = self.n_shards
        out = []
        for g in self.net.synapses:
            rep = g.memory_report()
            blk = self._blocks[g.name]
            if "dense" in blk:
                local = int(blk["dense"].shape[1] * blk["dense"].shape[2])
            else:
                local = int(blk["g"].shape[1] * blk["g"].shape[2])
            rep["local_elements_per_device"] = local
            rep["ring_elements_per_device"] = (
                g.ring_slots * (self._npad[g.post] // D)
                if g.needs_ring else 0)
            rep["n_shards"] = D
            out.append(rep)
        return out
