"""Synapse groups: connectivity + representation + generated dynamics.

A SynapseGroup connects a pre to a post population.  Representation is chosen
per the paper's memory model (eqs. (1)/(2)) unless forced.  Dynamics are no
longer hardcoded branches: every group carries a GeNN-style

  - WeightUpdateModel  (what a presynaptic spike contributes, plus optional
                        trace-based learning updating ``g`` online), and
  - PostsynapticModel  (how arriving input decays and is applied to the post
                        neuron, with an optional reversal-potential term),

both declared as code snippets and compiled through the same AST-whitelist ->
jit pipeline as neuron models (repro.core.codegen).  The built-ins `Pulse`,
`ExpDecay`, `ExpCond` reproduce the historical 'pulse'/'exp_decay' branches;
`Alpha` and `STDP` are only expressible through the generated path.

`gscale` is the paper's synaptic-conductance scaling factor — the quantity
the whole scalability study is about.  It multiplies the stored conductances
at propagation time so a single network build can be swept over gscale.

Dendritic delays (GeNN's per-synapse delay model): every group may carry an
integer delay per synapse (``ELLSynapses.delay``) or a homogeneous
``delay_steps``; both land weighted currents in a post-side ring
``[max_delay+1, n_post]`` (``SynapseState.dendritic``) read at the cursor —
post-sized state that shards along the post axis, replacing the old
replicated pre-side spike ring.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.codegen import (CompiledWeightUpdate, PostsynapticModel,
                                WeightUpdateModel, compile_postsynaptic,
                                compile_weight_update)
from repro.core.snn.errors import SpecError
from repro.sparse import formats as F
from repro.sparse import ops as sparse_ops
from repro.kernels import autotune as AT
from repro.kernels import ops as kops

__all__ = [
    "SynapseGroup", "SynapseState", "LocalConnectivity", "make_group",
    "Pulse", "ExpDecay", "ExpCond", "Alpha",
    "StaticPulse", "STDP", "PROPAGATIONS",
]

PROPAGATIONS = ("auto", "dense", "event")


# ---------------------------------------------------------------------------
# Built-in postsynaptic models.  Pulse/ExpDecay/ExpCond reproduce the
# pre-redesign hardcoded branches bit-for-bit (same operations in the same
# order, dt and tau entering as python floats).
# ---------------------------------------------------------------------------

def Pulse() -> PostsynapticModel:
    """Instantaneous current injection (the Izhikevich cortical net)."""
    return PostsynapticModel(name="pulse")


def ExpDecay(tau_ms: float) -> PostsynapticModel:
    """Exponentially decaying current, time constant tau_ms."""
    return PostsynapticModel(
        name="exp_decay",
        state={"in_syn": 0.0},
        params={"tau": float(tau_ms)},
        decay_code="in_syn = in_syn * exp(-dt / tau) + inj",
        apply_code="in_syn",
    )


def ExpCond(tau_ms: float, e_rev: float) -> PostsynapticModel:
    """Exponentially decaying conductance with reversal potential e_rev."""
    return PostsynapticModel(
        name="exp_cond",
        state={"in_syn": 0.0},
        params={"tau": float(tau_ms), "e_rev": float(e_rev)},
        decay_code="in_syn = in_syn * exp(-dt / tau) + inj",
        apply_code="in_syn * (e_rev - V)",
    )


def Alpha(tau_ms: float) -> PostsynapticModel:
    """Alpha-function synapse x(t) ~ (t/tau) exp(-t/tau) — a two-stage
    exponential cascade the old 'pulse'/'exp_decay' API could not express."""
    return PostsynapticModel(
        name="alpha",
        state={"x": 0.0, "y": 0.0},
        params={"tau": float(tau_ms)},
        decay_code=(
            "x = (x + (dt / tau) * y) * exp(-dt / tau)\n"
            "y = y * exp(-dt / tau) + inj"
        ),
        apply_code="x",
    )


# ---------------------------------------------------------------------------
# Built-in weight-update models.
# ---------------------------------------------------------------------------

def StaticPulse() -> WeightUpdateModel:
    """A spike contributes the stored conductance g; no learning."""
    return WeightUpdateModel(name="static_pulse")


def STDP(lr: float = 0.005, tau_pre: float = 20.0, tau_post: float = 20.0,
         g_min: float = 0.0, g_max: float = 1.0) -> WeightUpdateModel:
    """Trace-based pair STDP updating ``g`` online from pre/post spike
    coincidence — potentiation when pre precedes post, depression when post
    precedes pre.  Not expressible in the pre-redesign API."""
    return WeightUpdateModel(
        name="stdp",
        params={"lr": float(lr), "tau_pre": float(tau_pre),
                "tau_post": float(tau_post), "g_min": float(g_min),
                "g_max": float(g_max)},
        pre_state={"x_pre": 0.0},
        post_state={"x_post": 0.0},
        pre_code="x_pre = x_pre * exp(-dt / tau_pre) + pre_spike",
        post_code="x_post = x_post * exp(-dt / tau_post) + post_spike",
        learn_code=("g = clip(g + lr * x_pre * post_spike"
                    " - lr * x_post * pre_spike, g_min, g_max)"),
    )


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SynapseState:
    """Per-group dynamic state (pytree).

    ``dendritic`` is the post-side dendritic-delay ring
    [max_delay+1, n_post]: arriving weighted currents are scatter-added
    ``delay`` slots ahead of the cursor and delivered when the cursor
    reaches them.  It replaces the old pre-side spike ring
    ([delay+1, n_pre]) — post-sized state shards along the post/neuron
    axis, so no per-group buffer is replicated across devices.
    """

    psm: Dict[str, jax.Array]          # postsynaptic model state   [n_post]
    wu_pre: Dict[str, jax.Array]       # presynaptic trace vars     [n_pre]
    wu_post: Dict[str, jax.Array]      # postsynaptic trace vars    [n_post]
    g: Optional[jax.Array]             # dynamic weights (plastic groups)
    syn: Dict[str, jax.Array]          # extra per-synapse vars [n_pre, K]
    dendritic: Optional[jax.Array]     # delay ring [max_delay+1, n_post]
    cursor: Optional[jax.Array]        # ring cursor, int32 scalar

    @property
    def in_syn(self) -> Optional[jax.Array]:
        """Legacy accessor for the ExpDecay/ExpCond conductance state."""
        return self.psm.get("in_syn")

    def tree_flatten(self):
        return (self.psm, self.wu_pre, self.wu_post, self.g, self.syn,
                self.dendritic, self.cursor), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


@dataclasses.dataclass(frozen=True)
class LocalConnectivity:
    """A step-time connectivity override: the sharded engine passes each
    device's post-shard of the group's connectivity through
    ``SynapseGroup.step(conn=...)`` while reusing the group's compiled
    dynamics unchanged.  Replaces the deprecated ``ell=``/``dense=`` kwarg
    pair (one declared object instead of two loose knobs)."""

    ell: F.ELLSynapses
    dense: Optional[jax.Array] = None


@dataclasses.dataclass
class SynapseGroup:
    name: str
    pre: str
    post: str
    ell: F.ELLSynapses                      # canonical storage
    dense: Optional[jax.Array] = None       # dense mirror when chosen/forced
    representation: str = "auto"            # 'auto' | 'sparse' | 'dense'
    propagation: str = "auto"               # 'auto' | 'dense' | 'event'
    wum: Optional[WeightUpdateModel] = None  # default StaticPulse()
    psm: Optional[PostsynapticModel] = None  # default from legacy `dynamics`
    delay_steps: int = 0                    # homogeneous dendritic delay
    max_delay: Optional[int] = None         # static ring bound for ell.delay
    sign: float = 1.0                       # +1 excitatory / -1 inhibitory
    # a custom update writes g: conductances become state-resident even
    # without a learning rule (ModelSpec.build sets this)
    mutable_g: bool = False
    # legacy shorthand (pre-ModelSpec API); translated to a PostsynapticModel
    # in __post_init__ and kept for introspection.
    dynamics: Optional[str] = None          # 'pulse' | 'exp_decay'
    tau_ms: float = 5.0                     # for exp_decay
    e_rev: Optional[float] = None           # reversal potential (cond-based)

    def __post_init__(self) -> None:
        if self.psm is None:
            dyn = self.dynamics or "pulse"
            if dyn == "pulse":
                self.psm = Pulse()
            elif dyn == "exp_decay":
                self.psm = (ExpDecay(self.tau_ms) if self.e_rev is None
                            else ExpCond(self.tau_ms, self.e_rev))
            else:
                raise ValueError(
                    f"{self.name}: unknown dynamics {dyn!r} "
                    "(expected 'pulse' or 'exp_decay', or pass psm=)")
        self.dynamics = self.psm.name
        if self.wum is None:
            self.wum = StaticPulse()

        # --- dendritic delays ------------------------------------------
        # delay_steps=k (homogeneous) and ell.delay (per-synapse slot) both
        # lower onto the same post-side dendritic ring; the homogeneous case
        # keeps the single full-matrix spmv per step (one ring slot written).
        if self.propagation not in PROPAGATIONS:
            raise ValueError(
                f"synapse group {self.name!r}: propagation "
                f"{self.propagation!r} not in {PROPAGATIONS}")
        if self.propagation == "event":
            if self.representation == "dense":
                raise ValueError(
                    f"synapse group {self.name!r}: propagation='event' is "
                    "incompatible with representation='dense' (event-driven "
                    "delivery gathers the spiking pre-neurons' ELL rows); "
                    "use representation 'sparse' or 'auto'")
            self.representation = "sparse"

        if not isinstance(self.delay_steps, int) or self.delay_steps < 0:
            raise ValueError(
                f"{self.name}: delay_steps must be a non-negative int, got "
                f"{self.delay_steps!r}")
        if self.ell.delay is not None:
            if self.delay_steps:
                raise ValueError(
                    f"{self.name}: delay_steps={self.delay_steps} and a "
                    "per-synapse delay slot are mutually exclusive; declare "
                    "one of them")
            if tuple(self.ell.delay.shape) != tuple(self.ell.g.shape):
                raise ValueError(
                    f"{self.name}: delay slot shape "
                    f"{tuple(self.ell.delay.shape)} != synapse shape "
                    f"{tuple(self.ell.g.shape)}")
            if self.representation == "dense":
                raise ValueError(
                    f"synapse group {self.name!r}: representation='dense' "
                    "is incompatible with per-synapse delays (the dense "
                    "mirror has no delay slot; currents route through the "
                    "ELL path); use 'sparse' or 'auto'")
            self.representation = "sparse"
            dvals = np.asarray(jax.device_get(self.ell.delay))
            dmax = int(dvals.max()) if dvals.size else 0
            if dvals.size and int(dvals.min()) < 0:
                raise ValueError(
                    f"{self.name}: negative per-synapse delay "
                    f"{int(dvals.min())}")
            if self.max_delay is None:
                self.max_delay = dmax
            elif dmax > self.max_delay:
                raise ValueError(
                    f"{self.name}: per-synapse delay {dmax} exceeds the "
                    f"declared ring bound max_delay={self.max_delay}")
        else:
            self.max_delay = self.delay_steps

        # Any non-default weight-update model — or a custom update writing
        # g — propagates through the ELL effective-weight path (plastic /
        # mutable g lives in state; custom spike_code rewrites weights per
        # step), so a dense mirror would go stale or sit unused: an
        # explicit 'dense' request is a conflict, and 'auto' resolves to
        # sparse.
        if not self.wum.is_static_pulse or self.mutable_g:
            if self.representation == "dense":
                what = ("a custom update writing g" if self.mutable_g
                        and self.wum.is_static_pulse
                        else f"weight-update model {self.wum.name!r}")
                raise ValueError(
                    f"synapse group {self.name!r}: representation='dense' "
                    f"is incompatible with {what} (dynamic weights "
                    "propagate via the ELL path); use 'sparse' or 'auto'")
            self.representation = "sparse"
        elif self.representation == "auto":
            nnz = self.ell.n_pre * self.ell.max_conn
            self.representation = F.choose_representation(
                self.ell.n_pre, self.ell.n_post, nnz)
        if self.representation == "dense" and self.dense is None:
            self.dense = F.ell_to_dense(self.ell)

        # --- propagation mode (declared -> effective) -------------------
        # 'auto' asks the occupancy/activity crossover model whether event-
        # driven row gathering beats the full-matrix pass for this group's
        # shape; an explicit 'event' keeps the modelled capacity but skips
        # the verdict.  Both paths are bit-exact, so the choice is purely a
        # performance decision.
        self.propagation_declared = self.propagation
        if self.representation == "dense" or self.propagation == "dense":
            self.propagation_mode = "dense"
            self.event_capacity = None
        else:
            cfg = AT.choose_propagation(
                self.ell.n_pre, self.ell.max_conn, self.ell.n_post,
                n_slots=(self.ring_slots if self.ell.delay is not None
                         else 1),
                tag=self.name)
            if self.propagation == "event":
                self.propagation_mode = "event"
            else:
                self.propagation_mode = cfg["mode"]
            self.event_capacity = (int(cfg["capacity"])
                                   if self.propagation_mode == "event"
                                   else None)

        # --- code generation: compile the synapse models once per group ---
        self._psm_step = compile_postsynaptic(self.psm)
        self._wu: CompiledWeightUpdate = compile_weight_update(self.wum)

    @property
    def plastic(self) -> bool:
        """True when g is state-resident: a learn_code rewrites it during
        simulation, or a custom update may rewrite it on demand."""
        return bool(self.wum.learn_code) or self.mutable_g

    @property
    def needs_ring(self) -> bool:
        """True when this group carries a dendritic-delay ring (homogeneous
        delay_steps > 0 or a per-synapse delay slot, even an all-zero one)."""
        return self.max_delay > 0 or self.ell.delay is not None

    @property
    def ring_slots(self) -> int:
        return self.max_delay + 1

    # -- state ------------------------------------------------------------
    def init_state(self) -> SynapseState:
        n_pre, n_post = self.ell.n_pre, self.ell.n_post
        psm = {k: jnp.full((n_post,), v, jnp.float32)
               for k, v in self.psm.state.items()}
        wu_pre = {k: jnp.full((n_pre,), v, jnp.float32)
                  for k, v in self.wum.pre_state.items()}
        wu_post = {k: jnp.full((n_post,), v, jnp.float32)
                   for k, v in self.wum.post_state.items()}
        syn = {k: jnp.full((n_pre, self.ell.max_conn), v, jnp.float32)
               for k, v in self.wum.syn_state.items()}
        g = jnp.asarray(self.ell.g) if self.plastic else None
        if self.needs_ring:
            buf = jnp.zeros((self.ring_slots, n_post), jnp.float32)
            cur = jnp.zeros((), jnp.int32)
        else:
            buf, cur = None, None
        return SynapseState(psm=psm, wu_pre=wu_pre, wu_post=wu_post, g=g,
                            syn=syn, dendritic=buf, cursor=cur)

    # -- propagation -------------------------------------------------------
    def _effective_ell(self, g: Optional[jax.Array],
                       syn: Dict[str, jax.Array],
                       externals: Dict[str, jax.Array],
                       ell: F.ELLSynapses) -> F.ELLSynapses:
        """The ELL matrix to propagate this step: the stored one for static
        groups, or one carrying this step's effective weights (computed
        ONCE per step — the old masked-pass delay loop recomputed them per
        delay value)."""
        if self.wum.is_static_pulse and g is None:
            return ell
        g_cur = ell.g if g is None else g
        w_eff = self._wu.effective_weight(g_cur, syn, self.wum.params,
                                          externals)
        w_eff = jnp.where(ell.valid, w_eff, 0.0)
        return F.ELLSynapses(g=w_eff, post_ind=ell.post_ind, valid=ell.valid,
                             n_post=ell.n_post, delay=ell.delay)

    def _spmv(self, ell: F.ELLSynapses, spk: jax.Array) -> jax.Array:
        """One full accumulation via the group's effective propagation mode
        (dense full-matrix pass vs event-driven row gathering)."""
        if self.propagation_mode == "event":
            return kops.ell_spmv_event(ell, spk, self.event_capacity)
        return kops.ell_spmv(ell, spk)

    def _raw_current(self, spikes: jax.Array, gscale: jax.Array,
                     g: Optional[jax.Array], syn: Dict[str, jax.Array],
                     externals: Dict[str, jax.Array],
                     ell: F.ELLSynapses,
                     dense: Optional[jax.Array]) -> jax.Array:
        """sum_i spike_i * w_eff_ij * gscale for this step's arriving
        spikes.  `ell`/`dense` are the resolved (possibly shard-local)
        connectivity."""
        spk = jnp.asarray(spikes, jnp.float32)
        if (self.wum.is_static_pulse and g is None
                and self.representation == "dense"):
            out = sparse_ops.accumulate_dense(dense, spk)
        else:
            out = self._spmv(self._effective_ell(g, syn, externals, ell), spk)
        return self.sign * gscale * out

    def _delay_contrib(self, spikes: jax.Array, gscale: jax.Array,
                       g: Optional[jax.Array], syn: Dict[str, jax.Array],
                       externals: Dict[str, jax.Array],
                       ell: F.ELLSynapses) -> jax.Array:
        """Fused heterogeneous-delay accumulation: one pass over the ELL
        slots returns [ring_slots, n_post] — slot d holds the currents due
        d steps from now.  Replaces the max_delay+1 masked spmv passes;
        per (slot, post) the accumulation order is unchanged, so the ring
        contents stay bit-exact."""
        spk = jnp.asarray(spikes, jnp.float32)
        eff = self._effective_ell(g, syn, externals, ell)
        if self.propagation_mode == "event":
            out = kops.ell_spmv_event_delay(eff, spk, self.ring_slots,
                                            self.event_capacity)
        else:
            out = kops.ell_spmv_delay(eff, spk, self.ring_slots)
        return self.sign * gscale * out

    def _resolve_conn(self, conn: Optional[LocalConnectivity],
                      ell: Optional[F.ELLSynapses],
                      dense: Optional[jax.Array]) -> LocalConnectivity:
        """Fold the step-time overrides into one LocalConnectivity.  The
        loose ``ell=``/``dense=`` kwargs are deprecated in favor of
        ``conn=``; passing both is a conflict."""
        if ell is not None or dense is not None:
            if conn is not None:
                raise SpecError(
                    f"synapse group {self.name!r}: conn= and the deprecated "
                    "ell=/dense= overrides were both passed to step() and "
                    "conflict; pass only conn=LocalConnectivity(...)")
            warnings.warn(
                "SynapseGroup.step(ell=..., dense=...) is deprecated; pass "
                "conn=LocalConnectivity(ell=..., dense=...) instead "
                "(docs/API.md 'Propagation modes' has the migration table)",
                DeprecationWarning, stacklevel=3)
            return LocalConnectivity(
                ell=ell if ell is not None else self.ell,
                dense=dense if dense is not None else self.dense)
        if conn is None:
            return LocalConnectivity(ell=self.ell, dense=self.dense)
        return conn

    def step(
        self, state: SynapseState, spikes: jax.Array, gscale: jax.Array,
        dt: float, v_post: Optional[jax.Array] = None,
        post_spikes: Optional[jax.Array] = None,
        t: Optional[jax.Array] = None,
        conn: Optional[LocalConnectivity] = None,
        ell: Optional[F.ELLSynapses] = None,
        dense: Optional[jax.Array] = None,
        pre_traces: Optional[Dict[str, jax.Array]] = None,
    ) -> tuple[SynapseState, jax.Array]:
        """Advance one step; returns (new_state, current into post neurons).

        `conn` overrides the stored connectivity (sharded engine path); all
        shapes on the post side then follow the override.  The loose
        ``ell=``/``dense=`` kwargs are a deprecated spelling of the same
        override (DeprecationWarning; conflicting with conn= raises
        SpecError).

        `pre_traces`: when not None, the caller owns the pre-trace state —
        the internal pre_step is skipped (state.wu_pre passes through
        untouched; the sharded engine advances its own pre-sharded copy) and
        learn reads these full-size [n_pre] trace vectors instead.  The
        host path always passes None.

        Dendritic delays: each synapse's weighted contribution is scatter-
        added into the post-side ring ``delay`` slots ahead of the cursor
        and delivered when the cursor reaches it.  The homogeneous
        ``delay_steps=k`` case writes one ring slot with the same single
        full-matrix accumulation as the delay-free path; heterogeneous
        per-synapse delays run ONE fused delay-scatter pass that lands every
        synapse's contribution at its (delay_slot, post) ring coordinate
        (kernels.ops.ell_spmv_delay — bit-exact vs the old max_delay+1
        masked passes, one kernel launch instead of S).
        Weights (and gscale) are applied at *spike* time, GeNN's dendritic-
        delay semantics — for plastic groups this reads g as of emission,
        not delivery (the migration note in docs/API.md spells this out).
        """
        conn = self._resolve_conn(conn, ell, dense)
        lell = conn.ell
        # dt/t are always present in the snippet environments: any model
        # code referencing them must work even when a legacy caller omits t
        wu_ext = {"dt": dt, "t": t if t is not None else jnp.float32(0.0)}
        # the per-synapse delay slot is readable from spike_code/learn_code;
        # homogeneous groups see their scalar delay_steps (keeping
        # ConstantDelay(k) == delay_steps=k for delay-reading snippets) and
        # delay-free groups see 0.0, so snippets stay portable
        wu_ext["delay"] = (lell.delay.astype(jnp.float32)
                           if lell.delay is not None
                           else jnp.float32(self.delay_steps))

        if not self.needs_ring:
            inj = self._raw_current(spikes, gscale, state.g, state.syn,
                                    wu_ext, lell, conn.dense)
            new_buf, new_cur = state.dendritic, state.cursor
        else:
            S = self.ring_slots
            cur = state.cursor
            ring = state.dendritic
            if lell.delay is None:
                # homogeneous: one full accumulation, one slot written
                contrib = self._raw_current(spikes, gscale, state.g,
                                            state.syn, wu_ext, lell,
                                            conn.dense)
                ring = ring.at[(cur + self.delay_steps) % S].add(contrib)
            else:
                # fused delay scatter: contrib_all[d] is what the old d-th
                # masked pass produced; rolling by the cursor aligns slot d
                # with ring row (cur+d) % S, one add per slot as before
                contrib_all = self._delay_contrib(spikes, gscale, state.g,
                                                  state.syn, wu_ext, lell)
                ring = ring + jnp.roll(contrib_all, cur, axis=0)
            inj = ring[cur]
            new_buf = ring.at[cur].set(0.0)
            new_cur = (cur + 1) % S

        # -- learning (generated weight-update code) -----------------------
        # pre traces and learning fire at spike (emission) time — the
        # dendritic delay buffers the *current*, not the spike event
        pre_spk = jnp.asarray(spikes, jnp.float32)
        post_spk = (jnp.asarray(post_spikes, jnp.float32)
                    if post_spikes is not None
                    else jnp.zeros((lell.n_post,), jnp.float32))
        new_pre = state.wu_pre
        if pre_traces is None and self._wu.pre_step is not None:
            new_pre = self._wu.pre_step(
                state.wu_pre, self.wum.params,
                {**wu_ext, "pre_spike": pre_spk})
        new_post = state.wu_post
        if self._wu.post_step is not None:
            new_post = self._wu.post_step(
                state.wu_post, self.wum.params,
                {**wu_ext, "post_spike": post_spk})
        new_g, new_syn = state.g, state.syn
        if self._wu.learn is not None:
            gather = lell.post_ind
            traces = {"pre_spike": pre_spk[:, None],
                      "post_spike": post_spk[gather]}
            pre_read = new_pre if pre_traces is None else pre_traces
            traces.update({k: v[:, None] for k, v in pre_read.items()})
            traces.update({k: v[gather] for k, v in new_post.items()})
            g_learn, new_syn = self._wu.learn(
                state.g, state.syn, traces, self.wum.params, wu_ext)
            new_g = jnp.where(lell.valid, g_learn, state.g)

        # -- postsynaptic dynamics (generated decay/apply code) ------------
        psm_ext = {"inj": inj, "dt": wu_ext["dt"], "t": wu_ext["t"]}
        if self.psm.needs_v:
            if v_post is None:
                raise ValueError(
                    f"synapse group {self.name!r}: postsynaptic model "
                    f"{self.psm.name!r} references V but the post population "
                    "has no membrane state 'V'")
            psm_ext["V"] = v_post
        new_psm, current = self._psm_step(state.psm, self.psm.params, psm_ext)

        new_state = SynapseState(psm=new_psm, wu_pre=new_pre,
                                 wu_post=new_post, g=new_g, syn=new_syn,
                                 dendritic=new_buf, cursor=new_cur)
        return new_state, current

    # -- memory accounting (paper eqs 1/2) ----------------------------------
    def state_elements(self) -> int:
        """Per-simulation dynamic state this group carries (one stream
        slot's worth): postsynaptic/trace/synapse vars, state-resident g,
        and the dendritic-delay ring + cursor.  Serving multiplies this by
        max_streams (each slot is an independent simulation)."""
        n_pre, n_post = self.ell.n_pre, self.ell.n_post
        nnz = n_pre * self.ell.max_conn
        total = (len(self.psm.state) * n_post
                 + len(self.wum.pre_state) * n_pre
                 + len(self.wum.post_state) * n_post
                 + len(self.wum.syn_state) * nnz)
        if self.plastic:
            total += nnz
        if self.needs_ring:
            total += self.ring_slots * n_post + 1
        return total

    def memory_report(self) -> dict:
        nnz = self.ell.n_pre * self.ell.max_conn
        return {
            "name": self.name,
            "representation": self.representation,
            "propagation": self.propagation_declared,
            "propagation_mode": self.propagation_mode,
            "event_capacity": self.event_capacity,
            "sparse_elements": F.sparse_memory_elements(
                nnz, self.ell.n_pre, self.ell.n_post),
            "dense_elements": F.dense_memory_elements(
                self.ell.n_pre, self.ell.n_post),
            "max_delay": self.max_delay,
            "dendritic_ring_elements": (
                self.ring_slots * self.ell.n_post if self.needs_ring else 0),
            "state_elements": self.state_elements(),
        }


def make_group(
    rng: np.random.Generator, name: str, pre: str, post: str,
    n_pre: int, n_post: int, n_conn: int, weight_fn=None,
    representation: str = "auto", connect: Optional[F.ConnectivityInit] = None,
    **kw,
) -> SynapseGroup:
    """Legacy front-end: build a group from a connectivity initializer
    (default: the paper's fixed-fanout construction).  Thin shim over the
    ModelSpec path — prefer repro.core.snn.spec for new code."""
    if connect is None:
        connect = F.FixedFanout(n_conn)
    post_ind, g, valid = connect.resolve(rng, n_pre, n_post, weight_fn)
    ell = F.triple_to_ell(post_ind, g, valid, n_post)
    return SynapseGroup(name=name, pre=pre, post=post, ell=ell,
                        representation=representation, **kw)
