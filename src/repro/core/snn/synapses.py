"""Synapse groups: connectivity + representation + post-synaptic dynamics.

A SynapseGroup connects a pre to a post population.  Representation is chosen
per the paper's memory model (eqs. (1)/(2)) unless forced; dynamics are either
instantaneous current pulses (the Izhikevich cortical net) or exponentially
decaying conductances (the mushroom-body net), optionally with a fixed
axonal delay implemented as a spike ring-buffer.

`gscale` is the paper's synaptic-conductance scaling factor — the quantity
the whole scalability study is about.  It multiplies the stored conductances
at propagation time so a single network build can be swept over gscale.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.sparse import formats as F
from repro.sparse import ops as sparse_ops
from repro.kernels import ops as kops

__all__ = ["SynapseGroup", "SynapseState"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SynapseState:
    """Per-group dynamic state (pytree)."""

    in_syn: Optional[jax.Array]        # decaying conductance input [n_post]
    spike_buffer: Optional[jax.Array]  # delay ring [delay+1, n_pre]
    cursor: Optional[jax.Array]        # ring cursor, int32 scalar

    def tree_flatten(self):
        return (self.in_syn, self.spike_buffer, self.cursor), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


@dataclasses.dataclass
class SynapseGroup:
    name: str
    pre: str
    post: str
    ell: F.ELLSynapses                      # canonical storage
    dense: Optional[jax.Array] = None       # dense mirror when chosen/forced
    representation: str = "auto"            # 'auto' | 'sparse' | 'dense'
    dynamics: str = "pulse"                 # 'pulse' | 'exp_decay'
    tau_ms: float = 5.0                     # for exp_decay
    e_rev: Optional[float] = None           # reversal potential (cond-based)
    delay_steps: int = 0
    sign: float = 1.0                       # +1 excitatory / -1 inhibitory

    def __post_init__(self) -> None:
        if self.representation == "auto":
            nnz = self.ell.n_pre * self.ell.max_conn
            self.representation = F.choose_representation(
                self.ell.n_pre, self.ell.n_post, nnz)
        if self.representation == "dense" and self.dense is None:
            self.dense = F.ell_to_dense(self.ell)

    # -- state ------------------------------------------------------------
    def init_state(self) -> SynapseState:
        in_syn = (jnp.zeros((self.ell.n_post,), jnp.float32)
                  if self.dynamics == "exp_decay" else None)
        if self.delay_steps > 0:
            buf = jnp.zeros((self.delay_steps + 1, self.ell.n_pre),
                            jnp.float32)
            cur = jnp.zeros((), jnp.int32)
        else:
            buf, cur = None, None
        return SynapseState(in_syn=in_syn, spike_buffer=buf, cursor=cur)

    # -- propagation -------------------------------------------------------
    def _raw_current(self, spikes: jax.Array, gscale: jax.Array) -> jax.Array:
        """sum_i spike_i * g_ij * gscale for this step's arriving spikes."""
        spk = jnp.asarray(spikes, jnp.float32)
        if self.representation == "dense":
            out = sparse_ops.accumulate_dense(self.dense, spk)
        else:
            out = kops.ell_spmv(self.ell, spk)
        return self.sign * gscale * out

    def step(
        self, state: SynapseState, spikes: jax.Array, gscale: jax.Array,
        dt: float, v_post: Optional[jax.Array] = None,
    ) -> tuple[SynapseState, jax.Array]:
        """Advance one step; returns (new_state, current into post neurons)."""
        if self.delay_steps > 0:
            buf = state.spike_buffer.at[state.cursor].set(
                jnp.asarray(spikes, jnp.float32))
            read = (state.cursor + 1) % (self.delay_steps + 1)
            arriving = buf[read]
            new_buf, new_cur = buf, read
        else:
            arriving = spikes
            new_buf, new_cur = state.spike_buffer, state.cursor

        inj = self._raw_current(arriving, gscale)

        if self.dynamics == "exp_decay":
            decay = jnp.exp(-dt / self.tau_ms).astype(jnp.float32)
            in_syn = state.in_syn * decay + inj
            if self.e_rev is not None and v_post is not None:
                current = in_syn * (self.e_rev - v_post)
            else:
                current = in_syn
            new_state = SynapseState(in_syn=in_syn, spike_buffer=new_buf,
                                     cursor=new_cur)
            return new_state, current

        new_state = SynapseState(in_syn=state.in_syn, spike_buffer=new_buf,
                                 cursor=new_cur)
        return new_state, inj

    # -- memory accounting (paper eqs 1/2) ----------------------------------
    def memory_report(self) -> dict:
        nnz = self.ell.n_pre * self.ell.max_conn
        return {
            "name": self.name,
            "representation": self.representation,
            "sparse_elements": F.sparse_memory_elements(
                nnz, self.ell.n_pre, self.ell.n_post),
            "dense_elements": F.dense_memory_elements(
                self.ell.n_pre, self.ell.n_post),
        }


def make_group(
    rng: np.random.Generator, name: str, pre: str, post: str,
    n_pre: int, n_post: int, n_conn: int, weight_fn=None,
    representation: str = "auto", **kw,
) -> SynapseGroup:
    """Build a fixed-fanout group (the paper's construction)."""
    post_ind, g = F.fixed_fanout_connectivity(
        rng, n_pre, n_post, n_conn, weight_fn)
    ell = F.ELLSynapses(
        g=jnp.asarray(g), post_ind=jnp.asarray(post_ind),
        valid=jnp.ones_like(jnp.asarray(post_ind), bool), n_post=n_post)
    return SynapseGroup(name=name, pre=pre, post=post, ell=ell,
                        representation=representation, **kw)
