"""Built-in neuron models, declared in the GeNN equation DSL.

These mirror the models GeNN ships and the two networks the paper benchmarks:
Izhikevich (2003) simple neurons for the cortical net, Traub-Miles
Hodgkin-Huxley neurons + Poisson inputs for the insect olfaction / mushroom
body net.  All are plain `NeuronModel` declarations — users define their own
the same way (that is the point of the code-generation approach).

Units follow GeNN: time in ms, voltages in mV, conductances in uS, currents
in nA, capacitance in nF.

Every state variable declared here (e.g. Izhikevich's ``V``/``U``, the HH
gates ``m``/``h``/``n``, Poisson's ``timeToSpike``) is recordable with a
probe — ``spec.probe(name, population, var, ...)`` — as are spike events
via the reserved variable name ``"spikes"``; population custom updates may
rewrite them (``spec.add_custom_update``).  See docs/API.md "Probes".
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.codegen import NeuronModel

__all__ = [
    "IZHIKEVICH", "TRAUBMILES_HH", "POISSON", "LIF", "RULKOV_MAP",
    "izhikevich_population_params", "get_model",
]

# ---------------------------------------------------------------------------
# Izhikevich (2003) "simple model of spiking neurons".
# Two coupled ODEs, Euler-integrated with two half-steps on V for stability —
# exactly the update GeNN generates for its Izhikevich model.
# ---------------------------------------------------------------------------
IZHIKEVICH = NeuronModel(
    name="izhikevich",
    state={"V": -65.0, "U": -13.0},
    params={"a": 0.02, "b": 0.2, "c": -65.0, "d": 8.0},
    sim_code="""
V = V + 0.5*dt*(0.04*V*V + 5.0*V + 140.0 - U + Isyn)
V = V + 0.5*dt*(0.04*V*V + 5.0*V + 140.0 - U + Isyn)
U = U + dt*a*(b*V - U)
V = minimum(V, 30.0)
""",
    threshold_code="V >= 29.99",
    reset_code="""
V = c
U = U + d
""",
)


def izhikevich_population_params(key: jax.Array, n_exc: int, n_inh: int):
    """Per-neuron parameter arrays for the Izhikevich (2003) cortical net.

    Excitatory: (a,b) = (0.02, 0.2), (c,d) = (-65+15 r^2, 8-6 r^2)
    Inhibitory: (a,b) = (0.02+0.08 r, 0.25-0.05 r), (c,d) = (-65, 2)
    """
    ke, ki = jax.random.split(key)
    re = jax.random.uniform(ke, (n_exc,))
    ri = jax.random.uniform(ki, (n_inh,))
    a = jnp.concatenate([jnp.full((n_exc,), 0.02), 0.02 + 0.08 * ri])
    b = jnp.concatenate([jnp.full((n_exc,), 0.2), 0.25 - 0.05 * ri])
    c = jnp.concatenate([-65.0 + 15.0 * re**2, jnp.full((n_inh,), -65.0)])
    d = jnp.concatenate([8.0 - 6.0 * re**2, jnp.full((n_inh,), 2.0)])
    return {"a": a, "b": b, "c": c, "d": d}


# ---------------------------------------------------------------------------
# Traub-Miles Hodgkin-Huxley (the HH variant GeNN uses for KC/LHI/DN in the
# mushroom-body model).  The update code is *generated*: the singular rate
# functions x/(exp(x)-1) are emitted in guarded form (Taylor fallback at the
# pole — the paper's float-overflow concern, §2), and the integration is
# unrolled into `substeps` Euler substeps per dt, exactly how GeNN emits an
# inner loop in its generated CUDA for stiff models.
# ---------------------------------------------------------------------------

_HH_SUBSTEP = """
Imem = -(m*m*m*h*gNa*(V-ENa) + n*n*n*n*gK*(V-EK) + gl*(V-El) - Isyn)
V = V + {h_dt}*Imem/C
xm = (-52.0 - V)/4.0
a_m = 1.28*where(abs(xm) > 1e-4, xm/(exp(xm) - 1.0), 1.0 - xm/2.0)
xb = (V + 25.0)/5.0
b_m = 1.4*where(abs(xb) > 1e-4, xb/(exp(xb) - 1.0), 1.0 - xb/2.0)
a_h = 0.128*exp((-48.0 - V)/18.0)
b_h = 4.0/(exp((-25.0 - V)/5.0) + 1.0)
xn = (-50.0 - V)/5.0
a_n = 0.16*where(abs(xn) > 1e-4, xn/(exp(xn) - 1.0), 1.0 - xn/2.0)
b_n = 0.5*exp((-55.0 - V)/40.0)
m = clip(m + {h_dt}*(a_m*(1.0 - m) - b_m*m), 0.0, 1.0)
h = clip(h + {h_dt}*(a_h*(1.0 - h) - b_h*h), 0.0, 1.0)
n = clip(n + {h_dt}*(a_n*(1.0 - n) - b_n*n), 0.0, 1.0)
"""


def make_traubmiles(substeps: int = 5) -> NeuronModel:
    """Generate a Traub-Miles HH model with `substeps` Euler substeps/dt."""
    body = "".join(
        _HH_SUBSTEP.format(h_dt=f"(dt/{float(substeps)})")
        for _ in range(substeps))
    return NeuronModel(
        name=f"traubmiles_hh_x{substeps}",
        state={"V": -60.0, "m": 0.0529, "h": 0.3177, "n": 0.3177},
        params={
            "gNa": 7.15, "ENa": 50.0, "gK": 1.43, "EK": -95.0,
            "gl": 0.02672, "El": -63.563, "C": 0.143,
        },
        sim_code=body,
        # Spike = upward crossing of 0 mV.  V stays super-threshold for
        # several steps, so populations using this model default to
        # edge_spikes=True (rising-edge detection) in Network.add_population.
        threshold_code="V >= 0.0",
        reset_code="",
    )


TRAUBMILES_HH = make_traubmiles(5)

# ---------------------------------------------------------------------------
# Poisson input neurons (the PN population of the mushroom-body model).
# rate_hz may be a per-neuron array; dt is in ms.
# ---------------------------------------------------------------------------
POISSON = NeuronModel(
    name="poisson",
    state={"timeToSpike": 0.0},
    params={"rate_hz": 20.0},
    sim_code="timeToSpike = rand",
    threshold_code="timeToSpike < rate_hz * dt * 0.001",
    reset_code="",
)

# ---------------------------------------------------------------------------
# Leaky integrate-and-fire, the minimal sanity model.
# ---------------------------------------------------------------------------
LIF = NeuronModel(
    name="lif",
    state={"V": -70.0},
    params={"tau": 20.0, "Vrest": -70.0, "Vreset": -70.0,
            "Vthresh": -50.0, "R": 1.0},
    sim_code="V = V + dt*((Vrest - V) + R*Isyn)/tau",
    threshold_code="V >= Vthresh",
    reset_code="V = Vreset",
)

# ---------------------------------------------------------------------------
# Rulkov map neuron (GeNN's MAPNEURON) — included to show a non-ODE model in
# the same DSL (map-based models are GeNN's historical default).
# ---------------------------------------------------------------------------
RULKOV_MAP = NeuronModel(
    name="rulkov_map",
    state={"V": -60.0, "preV": -60.0},
    params={"Vspike": 60.0, "alpha": 3.0, "y": -2.468, "beta": 0.0165},
    sim_code="""
tmp = where(V <= 0.0, alpha*V/(1.0 - V) + y + beta*Isyn,
            where((V < Vspike) * (preV <= 0.0), Vspike + y, -2.468))
preV = V
V = tmp
""",
    threshold_code="V >= Vspike",
    reset_code="",
)

_REGISTRY = {
    m.name: m for m in (IZHIKEVICH, TRAUBMILES_HH, POISSON, LIF, RULKOV_MAP)
}
_REGISTRY["traubmiles_hh"] = TRAUBMILES_HH


def get_model(name: str) -> NeuronModel:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown neuron model {name!r}; have {sorted(_REGISTRY)}")
