"""Custom updates: codegen'd on-demand / scheduled state rewrites.

GeNN 4's CustomUpdate, adapted: a snippet of update code targeting one
neuron population or synapse group, compiled through the same AST
whitelist as every other model snippet (`repro.core.codegen`), runnable
*on demand* (`CompiledModel.custom_update(name, state)`) or *scheduled*
every ``n`` steps inside the simulation scan — weight normalization,
homeostatic scaling, state resets, all without rebuilding the model:

    spec.add_custom_update(
        "normalize", "KC_DN",
        update_code="g = g * g_target / maximum(w_sum, 1e-9)",
        params={"g_target": 1.0},
        reduce={"w_sum": ("sum", "g", "post")})

Reductions are declared as data and computed *before* the update code runs,
from the pre-update state:

- synapse-group targets take ``(op, var, axis)`` with axis ``"post"``
  (per-post-neuron, gathered back to synapse shape — the normalization
  axis), ``"pre"`` (per-row, broadcast back), or ``"all"`` (scalar);
- population targets take ``(op, var)`` — a scalar over the neuron axis.

``op`` is one of sum / mean / max / min.  On sharded builds, "post"
reductions are device-local (each device owns its post shard — no
communication), while "pre"/"all"/population reductions combine per-device
partials with ``psum``/``pmax``/``pmin`` inside ``shard_map``.

A custom update that *writes* ``g`` makes the target group's conductances
state-resident (``mutable_g``), which forces the sparse/ELL propagation
path exactly like a learning rule does.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional, Tuple

import jax.numpy as jnp

from repro.core import codegen
from repro.core.snn.errors import SpecError
from repro.core.snn.probes import REDUCE_OPS, reduce_neutral

__all__ = ["CustomUpdateSpec", "ResolvedCustomUpdate",
           "resolve_custom_updates", "group_reduce_host", "pop_reduce",
           "gather_post", "GROUP_AXES"]

GROUP_AXES = ("pre", "post", "all")


@dataclasses.dataclass(frozen=True)
class CustomUpdateSpec:
    """A custom update as declared on the ModelSpec (unresolved)."""

    name: str
    target: str
    update_code: str
    params: Mapping[str, float]
    reduce: Mapping[str, tuple]
    every: Optional[int]


@dataclasses.dataclass(frozen=True)
class ResolvedCustomUpdate:
    """A custom update bound to a built Network.

    kind:   "population" | "group"
    writes: target state vars the update code assigns
    reduce: reduction name -> (op, var, axis); axis is "pop" for
            population targets
    fn:     compiled apply(vars, params, reductions, externals)
    """

    name: str
    kind: str
    target: str
    update_code: str
    params: Dict[str, object]
    reduce: Dict[str, Tuple[str, str, str]]
    every: Optional[int]
    writes: frozenset
    denom_all: float
    fn: object


def validate_update_scalars(name: str, every) -> None:
    """Shared name/every validation — single source of truth for the
    eager ModelSpec.add_custom_update check and resolve_custom_updates."""
    if not name or not isinstance(name, str):
        raise SpecError(f"custom update name must be a non-empty "
                        f"string, got {name!r}")
    if every is not None and (not isinstance(every, int)
                              or isinstance(every, bool) or every <= 0):
        raise SpecError(
            f"custom update {name!r}: every must be a positive int or "
            f"None (on-demand), got {every!r}")


def written_targets(spec: CustomUpdateSpec) -> frozenset:
    """Names the update code assigns (superset: includes temporaries)."""
    try:
        return frozenset(codegen.assigned_names(spec.update_code))
    except SyntaxError:
        return frozenset()


def resolve_custom_updates(specs, net) -> Tuple[ResolvedCustomUpdate, ...]:
    """Validate custom-update declarations against a built Network."""
    groups = {g.name: g for g in net.synapses}
    seen = set()
    out = []
    for cu in specs:
        validate_update_scalars(cu.name, cu.every)
        if cu.name in seen:
            raise SpecError(f"duplicate custom update name {cu.name!r}")
        seen.add(cu.name)
        where = f"custom update {cu.name!r}"
        if cu.target in net.populations:
            kind = "population"
            pop = net.populations[cu.target]
            var_keys = tuple(pop.model.state)
            param_keys = dict(pop.params)
            denom_all = float(pop.n)
        elif cu.target in groups:
            kind = "group"
            grp = groups[cu.target]
            var_keys = ("g",) + tuple(grp.wum.syn_state)
            param_keys = {}
            denom_all = float(jnp.asarray(grp.ell.valid).sum())
        else:
            raise SpecError(
                f"{where}: unknown target {cu.target!r}; valid targets: "
                f"populations {sorted(net.populations)}, synapse groups "
                f"{sorted(groups)}")
        for k in list(cu.params) + list(dict(cu.reduce or {})):
            if k in ("dt", "t"):
                raise SpecError(
                    f"{where}: name {k!r} is reserved (the dt/t externals "
                    "are always visible to update code)")
        for k in cu.params:
            if k in var_keys or k in param_keys:
                raise SpecError(
                    f"{where}: parameter {k!r} shadows a state variable or "
                    f"model parameter of target {cu.target!r}")
        merged_params = {**param_keys, **dict(cu.params)}

        reduce_norm: Dict[str, Tuple[str, str, str]] = {}
        for rname, rspec in dict(cu.reduce or {}).items():
            if rname in var_keys or rname in merged_params:
                raise SpecError(
                    f"{where}: reduction name {rname!r} shadows a state "
                    f"variable or parameter of target {cu.target!r}")
            rspec = tuple(rspec) if isinstance(rspec, (tuple, list)) else (rspec,)
            if kind == "population":
                if len(rspec) != 2:
                    raise SpecError(
                        f"{where}: population reductions are declared as "
                        f"(op, var); got {rspec!r}")
                op, var = rspec
                axis = "pop"
            else:
                if len(rspec) != 3:
                    raise SpecError(
                        f"{where}: synapse-group reductions are declared "
                        f"as (op, var, axis) with axis in {GROUP_AXES}; "
                        f"got {rspec!r}")
                op, var, axis = rspec
                if axis not in GROUP_AXES:
                    raise SpecError(
                        f"{where}: unknown reduction axis {axis!r}; valid "
                        f"axes: {list(GROUP_AXES)}")
            if op not in REDUCE_OPS:
                raise SpecError(
                    f"{where}: unknown reduction op {op!r}; valid ops: "
                    f"{list(REDUCE_OPS)}")
            if var not in var_keys:
                raise SpecError(
                    f"{where}: reduction {rname!r} reads unknown state "
                    f"variable {var!r} of target {cu.target!r}; valid "
                    f"variables: {sorted(var_keys)}")
            reduce_norm[rname] = (op, var, axis)

        try:
            fn = codegen.compile_custom_update(
                cu.name, cu.update_code, var_keys, tuple(merged_params),
                tuple(reduce_norm))
        except (codegen.CodegenError, SyntaxError) as e:
            raise SpecError(f"{where}: {e}") from None
        writes = written_targets(cu) & set(var_keys)
        if not writes:
            raise SpecError(
                f"{where}: update_code assigns none of target "
                f"{cu.target!r}'s state variables {sorted(var_keys)} — the "
                "update would be a no-op")
        if kind == "group" and "g" in writes and not groups[cu.target].plastic:
            raise SpecError(
                f"{where}: writes 'g' of synapse group {cu.target!r} but "
                "the group's conductances are not state-resident; build "
                "through ModelSpec (which marks the group mutable) or use "
                "a plastic weight-update model")
        out.append(ResolvedCustomUpdate(
            name=cu.name, kind=kind, target=cu.target,
            update_code=cu.update_code, params=merged_params,
            reduce=reduce_norm, every=cu.every, writes=writes,
            denom_all=denom_all, fn=fn))
    return tuple(out)


# ---------------------------------------------------------------------------
# reduction execution (host path; the sharded engine has local variants)
# ---------------------------------------------------------------------------

def _scatter_post(val, post_ind, valid, n_post: int, op: str):
    """Per-post-neuron reduction of a [n_pre, K] per-synapse array."""
    masked = jnp.where(valid, jnp.asarray(val, jnp.float32),
                       reduce_neutral(op))
    flat_i = post_ind.reshape(-1)
    flat_v = masked.reshape(-1)
    if op in ("sum", "mean"):
        tot = jnp.zeros((n_post,), jnp.float32).at[flat_i].add(flat_v)
        if op == "sum":
            return tot
        deg = jnp.zeros((n_post,), jnp.float32).at[flat_i].add(
            valid.reshape(-1).astype(jnp.float32))
        return jnp.where(deg > 0, tot / jnp.maximum(deg, 1.0), 0.0)
    if op == "max":
        return jnp.full((n_post,), -jnp.inf, jnp.float32).at[flat_i].max(
            flat_v)
    return jnp.full((n_post,), jnp.inf, jnp.float32).at[flat_i].min(flat_v)


def gather_post(per_post, post_ind):
    """Broadcast a per-post-neuron reduction back to synapse shape."""
    return per_post[post_ind]


def _row_reduce(val, valid, op: str):
    """Per-pre-row reduction of a [n_pre, K] per-synapse array."""
    masked = jnp.where(valid, jnp.asarray(val, jnp.float32),
                       reduce_neutral(op))
    if op == "sum":
        return jnp.sum(masked, axis=1)
    if op == "mean":
        cnt = jnp.sum(valid.astype(jnp.float32), axis=1)
        return jnp.where(cnt > 0, jnp.sum(masked, axis=1)
                         / jnp.maximum(cnt, 1.0), 0.0)
    if op == "max":
        return jnp.max(masked, axis=1)
    return jnp.min(masked, axis=1)


def group_reduce_host(op: str, val, ell, axis: str, denom_all: float):
    """One declared reduction on the host path, already broadcast to the
    shape the update environment expects (synapse shape for 'post',
    [n_pre, 1] for 'pre', scalar for 'all')."""
    if axis == "post":
        per_post = _scatter_post(val, ell.post_ind, ell.valid, ell.n_post,
                                 op)
        return gather_post(per_post, ell.post_ind)
    if axis == "pre":
        return _row_reduce(val, ell.valid, op)[:, None]
    masked = jnp.where(ell.valid, jnp.asarray(val, jnp.float32),
                       reduce_neutral(op))
    if op == "sum":
        return jnp.sum(masked)
    if op == "mean":
        return jnp.sum(masked) / jnp.float32(denom_all)
    if op == "max":
        return jnp.max(masked)
    return jnp.min(masked)


def pop_reduce(op: str, val, denom: float):
    """Population-axis reduction to a scalar (full-size val)."""
    val = jnp.asarray(val, jnp.float32)
    if op == "sum":
        return jnp.sum(val)
    if op == "mean":
        return jnp.sum(val) / jnp.float32(denom)
    if op == "max":
        return jnp.max(val)
    return jnp.min(val)
