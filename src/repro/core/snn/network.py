"""Network description: populations of neurons + synapse groups.

This is the built IR consumed by the Simulator.  The user-facing
declarative front-end is ModelSpec (repro.core.snn.spec), which validates a
spec, resolves connectivity initializers and produces a Network + Simulator;
`add_population` / `add_synapse` remain as the legacy/low-level path
(docs/API.md has the migration table).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Mapping, Optional

import jax

from repro.core.codegen import NeuronModel
from repro.core.snn.synapses import SynapseGroup

__all__ = ["Population", "Network"]

# external input: (key, t, n) -> current [n]
InputFn = Callable[[jax.Array, jax.Array, int], jax.Array]


@dataclasses.dataclass
class Population:
    name: str
    model: NeuronModel
    n: int
    params: Mapping[str, object]            # scalar or per-neuron arrays
    input_fn: Optional[InputFn] = None      # external current source
    # emit spikes only on upward threshold crossings (needed for models
    # without a reset, e.g. HH, where V stays > 0 for several steps)
    edge_spikes: bool = False


@dataclasses.dataclass
class Network:
    name: str = "net"
    populations: Dict[str, Population] = dataclasses.field(
        default_factory=dict)
    synapses: List[SynapseGroup] = dataclasses.field(default_factory=list)

    def add_population(
        self, name: str, model: NeuronModel, n: int,
        params: Optional[Mapping[str, object]] = None,
        input_fn: Optional[InputFn] = None,
        edge_spikes: Optional[bool] = None,
    ) -> Population:
        if name in self.populations:
            raise ValueError(f"duplicate population {name!r}")
        if edge_spikes is None:
            edge_spikes = bool(model.threshold_code) and not model.reset_code
        merged = dict(model.params)
        merged.update(params or {})
        pop = Population(name=name, model=model, n=n, params=merged,
                         input_fn=input_fn, edge_spikes=edge_spikes)
        self.populations[name] = pop
        return pop

    def add_synapse(self, group: SynapseGroup) -> SynapseGroup:
        # the Simulator keys per-group state by name; a collision would make
        # two groups silently share (and clobber) one state slot
        if any(g.name == group.name for g in self.synapses):
            raise ValueError(f"duplicate synapse group name {group.name!r}")
        if group.pre not in self.populations:
            raise ValueError(f"unknown pre population {group.pre!r}")
        if group.post not in self.populations:
            raise ValueError(f"unknown post population {group.post!r}")
        if group.ell.n_pre != self.populations[group.pre].n:
            raise ValueError(
                f"{group.name}: n_pre {group.ell.n_pre} != population "
                f"{self.populations[group.pre].n}")
        if group.ell.n_post != self.populations[group.post].n:
            raise ValueError(
                f"{group.name}: n_post {group.ell.n_post} != population "
                f"{self.populations[group.post].n}")
        self.synapses.append(group)
        return group

    def memory_report(self) -> List[dict]:
        return [g.memory_report() for g in self.synapses]
