"""The simulator: generates and runs the per-step update for a Network.

This is the JAX analogue of GeNN's generated simulation loop:

  for each step:
    1. synaptic propagation: last step's spikes -> post-synaptic currents
       (sparse ELL / dense matmul per the representation choice)
    2. neuron updates: the codegen'd model equations advance every population
    3. spike extraction (threshold / reset, or rising-edge detection)

`build_step` returns a pure function suitable for jax.jit / lax.scan / vmap;
`run` scans it.  gScale factors enter as *traced arguments* so a single
compiled simulator serves the whole conductance-scaling sweep (vmap over
candidates — the batch dimension the TPU spmv kernel wants).

External stimuli (`stim`): `step`/`run` accept per-population injected
currents — the serving path's per-request drive.  A stimulus is added to
Isyn *after* the population's input_fn, consuming no PRNG draws, so a run
with stim is bit-identical to the same run with that current folded into an
input_fn, and the serving engine's per-slot replay of a stimulus is
bit-identical to the offline run (the exactness contract
tests/test_serving.py pins down).

Synaptic delays are *dendritic* (GeNN's per-synapse delay model): each
group's weighted currents land in a post-side ring
`[max_delay+1, n_post]` (`SynapseState.dendritic`) `delay` slots ahead of
the cursor — see repro.core.snn.synapses.  The homogeneous `delay_steps=k`
shorthand lowers onto the same ring.

Streaming/serving (`init_stream_state` / `serve_chunk`): state gains a
leading *stream* axis (vmap) — `max_streams` independent simulations
resident on device, each slot carrying its own neuron/synapse/delay state
and PRNG key.  `serve_chunk` advances every slot up to `n_steps` with
per-slot `steps_left` masking: slot lanes past their remaining stimulus are
select-restored, so idle/finished slots are exact no-ops (state, key stream
and finite flag untouched).

NaN containment (paper §2): every step folds an `isfinite` reduction over
membrane state into a carried `finite` flag; overflow from an over-scaled
conductance is detected without host round-trips.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import codegen
from repro.core.snn import bitmask as BM
from repro.core.snn import custom_updates as CU
from repro.core.snn import probes as PR
from repro.core.snn.network import Network
from repro.core.snn.probes import Recordings
from repro.core.snn.synapses import SynapseState
from repro.obs import health as HE

__all__ = ["Simulator", "SimState", "RunResult"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SimState:
    neurons: Dict[str, Dict[str, jax.Array]]
    spikes: Dict[str, jax.Array]        # last step's spikes (bool)
    prev_above: Dict[str, jax.Array]    # for edge-spike populations
    syn: Dict[str, object]              # SynapseState per group name
    t: jax.Array                        # ms
    key: jax.Array
    finite: jax.Array                   # bool: no NaN/Inf so far

    def tree_flatten(self):
        return ((self.neurons, self.spikes, self.prev_above, self.syn,
                 self.t, self.key, self.finite), ())

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class RunResult:
    state: SimState
    spike_counts: Dict[str, jax.Array]   # per-neuron spike totals
    rates_hz: Dict[str, jax.Array]       # population mean rate
    finite: jax.Array
    raster: object = None                # legacy [steps, n] bool per pop
    recordings: object = None            # Recordings keyed by probe name
    health: object = None                # HealthReport when built monitored

    def tree_flatten(self):
        return ((self.state, self.spike_counts, self.rates_hz, self.finite,
                 self.raster, self.recordings, self.health), ())

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


@jax.jit
def _select_streams(state, fresh, idx):
    """Gather/mix the stream axis: leaf-wise ``new[j] = old[idx[j]]`` when
    ``idx[j] >= 0`` else ``fresh[j]``.  jitted once and cached by shape —
    one executable per (old_size, new_size) bucket pair, shared by the host
    Simulator and the ShardedEngine (whose stream axis is unsharded, so a
    leading-axis gather never crosses devices)."""
    old_size = jax.tree.leaves(state)[0].shape[0]
    take = jnp.clip(idx, 0, old_size - 1)
    keep = idx >= 0

    def mix(old, fr):
        g = jnp.take(old, take, axis=0)
        m = keep.reshape((-1,) + (1,) * (g.ndim - 1))
        return jnp.where(m, g, fr.astype(g.dtype))

    return jax.tree.map(mix, state, fresh)


class Simulator:
    def __init__(self, net: Network, dt: float = 0.5, seed: int = 0,
                 probes=(), custom_updates=(), monitor=None):
        self.net = net
        self.dt = float(dt)
        self.seed = seed
        # --- opt-in health monitor (None / enabled=False -> identical
        # program: the scan body and carry never mention health) ---
        if monitor is not None and monitor.enabled:
            monitor.validate(net.populations)
            self.monitor = monitor
        else:
            self.monitor = None
        self._pop_sizes = {name: pop.n
                           for name, pop in net.populations.items()}
        # --- code generation: one update fn per population model ---
        self._updates = {
            name: codegen.compile_sim(pop.model)
            for name, pop in net.populations.items()
        }
        self._incoming = {
            name: [g for g in net.synapses if g.post == name]
            for name in net.populations
        }
        self._group_names = {g.name for g in net.synapses}
        self._groups = {g.name: g for g in net.synapses}
        self._run_jit_cache: Dict[tuple, object] = {}
        # --- probes + custom updates (ModelSpec passes these resolved) ---
        self.probes = tuple(probes)
        self.custom_updates = {cu.name: cu for cu in custom_updates}
        self._scheduled = [cu for cu in custom_updates
                           if cu.every is not None]

    def _validate_gscales(
            self, gscales: Optional[Mapping[str, jax.Array]]) -> None:
        """Reject gscale keys that match no synapse group (silent-typo
        hazard: a misspelled key used to be ignored via .get(name, 1.0))."""
        if not gscales:
            return
        unknown = set(gscales) - self._group_names
        if unknown:
            raise ValueError(
                f"unknown gscale key(s) {sorted(unknown)}; valid synapse "
                f"group names: {sorted(self._group_names)}")

    def _validate_stim(self, stim: Optional[Mapping[str, jax.Array]]) -> None:
        """Stim keys must name populations (same silent-typo hazard as
        gscales: a misspelled key would be an ignored no-op drive)."""
        if not stim:
            return
        unknown = set(stim) - set(self.net.populations)
        if unknown:
            raise ValueError(
                f"unknown stim population(s) {sorted(unknown)}; declared "
                f"populations: {sorted(self.net.populations)}")

    # ------------------------------------------------------------------
    def init_state(self, key: Optional[jax.Array] = None) -> SimState:
        if key is None:
            key = jax.random.PRNGKey(self.seed)
        neurons, spikes, prev_above = {}, {}, {}
        for name, pop in self.net.populations.items():
            neurons[name] = {
                k: jnp.full((pop.n,), v, jnp.float32)
                for k, v in pop.model.state.items()
            }
            spikes[name] = jnp.zeros((pop.n,), bool)
            if pop.edge_spikes:
                prev_above[name] = jnp.zeros((pop.n,), bool)
        syn = {g.name: g.init_state() for g in self.net.synapses}
        return SimState(neurons=neurons, spikes=spikes,
                        prev_above=prev_above, syn=syn,
                        t=jnp.zeros((), jnp.float32), key=key,
                        finite=jnp.ones((), bool))

    # ------------------------------------------------------------------
    def step(
        self, state: SimState,
        gscales: Optional[Mapping[str, jax.Array]] = None,
        stim: Optional[Mapping[str, jax.Array]] = None,
    ) -> Tuple[SimState, Dict[str, jax.Array]]:
        """One dt step. gscales: synapse-group name -> scalar multiplier;
        stim: population name -> [n] external current injected this step."""
        net, dt = self.net, self.dt
        self._validate_gscales(gscales)
        self._validate_stim(stim)
        gscales = gscales or {}
        stim = stim or {}
        key, *subkeys = jax.random.split(state.key,
                                         1 + 2 * len(net.populations))
        subkeys = iter(subkeys)

        # 1. synaptic propagation (last step's spikes) ------------------
        isyn = {name: jnp.zeros((pop.n,), jnp.float32)
                for name, pop in net.populations.items()}
        new_syn = dict(state.syn)
        for g in net.synapses:
            gs = jnp.asarray(gscales.get(g.name, 1.0), jnp.float32)
            v_post = state.neurons[g.post].get("V")
            s_new, cur = g.step(state.syn[g.name], state.spikes[g.pre], gs,
                                dt, v_post=v_post,
                                post_spikes=state.spikes[g.post], t=state.t)
            new_syn[g.name] = s_new
            isyn[g.post] = isyn[g.post] + cur

        # 2+3. neuron updates via generated code ------------------------
        new_neurons, new_spikes, new_prev = {}, {}, dict(state.prev_above)
        finite = state.finite
        for name, pop in net.populations.items():
            k_in, k_rand = next(subkeys), next(subkeys)
            cur = isyn[name]
            if pop.input_fn is not None:
                cur = cur + pop.input_fn(k_in, state.t, pop.n)
            if name in stim:
                cur = cur + jnp.asarray(stim[name], jnp.float32)
            ext = {"Isyn": cur, "dt": jnp.float32(dt), "t": state.t}
            if pop.model.needs_rand:
                ext["rand"] = jax.random.uniform(k_rand, (pop.n,))
            ns, above = self._updates[name](state.neurons[name], pop.params,
                                            ext)
            if pop.edge_spikes:
                spk = above & ~state.prev_above[name]
                new_prev[name] = above
            else:
                spk = above
            new_neurons[name] = ns
            new_spikes[name] = spk
            for arr in ns.values():
                finite = finite & jnp.all(jnp.isfinite(arr))

        new_state = SimState(
            neurons=new_neurons, spikes=new_spikes, prev_above=new_prev,
            syn=new_syn, t=state.t + dt, key=key, finite=finite)
        new_state = self._run_scheduled_updates(new_state)
        return new_state, new_spikes

    # ------------------------------------------------------------------
    # custom updates (on-demand + scheduled)
    # ------------------------------------------------------------------
    def _run_scheduled_updates(self, state: SimState) -> SimState:
        """Apply every `every=n` custom update whose step count is due.
        The trigger is the global step counter round(t/dt), so scheduling
        is consistent across run/step/serving (a served stream fires at
        the same absolute steps as the offline oracle)."""
        if not self._scheduled:
            return state
        elapsed = jnp.int32(jnp.round(state.t / jnp.float32(self.dt)))
        for cu in self._scheduled:
            trig = (elapsed % cu.every) == 0
            state = self._apply_custom(state, cu, trig)
        return state

    def _apply_custom(self, state: SimState, cu, trig) -> SimState:
        """Apply one custom update, masked by the (scalar bool) trigger.
        Written arrays are folded into the carried NaN-guard flag (an
        update that divides by a zero reduction must trip `finite` just
        like an over-scaled conductance does)."""
        ext = {"dt": jnp.float32(self.dt), "t": state.t}
        if cu.kind == "group":
            grp = self._groups[cu.target]
            st = state.syn[cu.target]
            g_arr = st.g if st.g is not None else jnp.asarray(grp.ell.g)
            cu_vars = {"g": g_arr, **st.syn}
            red = {
                rname: CU.group_reduce_host(op, cu_vars[var], grp.ell,
                                            axis, cu.denom_all)
                for rname, (op, var, axis) in cu.reduce.items()}
            new = cu.fn(cu_vars, cu.params, red, ext)
            valid = grp.ell.valid

            def sel(name, old):
                if name not in cu.writes:
                    return old
                return jnp.where(trig, jnp.where(valid, new[name], old),
                                 old)

            ok = jnp.ones((), bool)
            for name in cu.writes:
                ok = ok & jnp.all(jnp.isfinite(
                    jnp.where(valid, new[name], 0.0)))
            finite = state.finite & jnp.where(trig, ok, True)
            new_syn = dict(state.syn)
            new_syn[cu.target] = SynapseState(
                psm=st.psm, wu_pre=st.wu_pre, wu_post=st.wu_post,
                g=(sel("g", g_arr) if st.g is not None else None),
                syn={k: sel(k, v) for k, v in st.syn.items()},
                dendritic=st.dendritic, cursor=st.cursor)
            return SimState(neurons=state.neurons, spikes=state.spikes,
                            prev_above=state.prev_above, syn=new_syn,
                            t=state.t, key=state.key, finite=finite)
        # population target
        cu_vars = dict(state.neurons[cu.target])
        red = {rname: CU.pop_reduce(op, cu_vars[var], cu.denom_all)
               for rname, (op, var, _axis) in cu.reduce.items()}
        new = cu.fn(cu_vars, cu.params, red, ext)
        ok = jnp.ones((), bool)
        for name in cu.writes:
            ok = ok & jnp.all(jnp.isfinite(new[name]))
        finite = state.finite & jnp.where(trig, ok, True)
        new_neurons = dict(state.neurons)
        new_neurons[cu.target] = {
            k: (jnp.where(trig, new[k], v) if k in cu.writes else v)
            for k, v in state.neurons[cu.target].items()}
        return SimState(neurons=new_neurons, spikes=state.spikes,
                        prev_above=state.prev_above, syn=state.syn,
                        t=state.t, key=state.key, finite=finite)

    def custom_update(self, state: SimState, name: str) -> SimState:
        """Run one declared custom update on demand (any `every`)."""
        if name not in self.custom_updates:
            raise ValueError(
                f"unknown custom update {name!r}; declared updates: "
                f"{sorted(self.custom_updates)}")
        return self._apply_custom(state, self.custom_updates[name],
                                  jnp.bool_(True))

    # ------------------------------------------------------------------
    # probe plumbing (shared by run and serve_chunk)
    # ------------------------------------------------------------------
    def _probe_init(self, n_steps: int, serving: bool = False):
        """Preallocated device-resident ring buffers, one per probe.
        Unreduced spike probes store uint32 bitmask rows (32x smaller);
        finalize unpacks them back to the documented bool layout."""
        bufs, caps = {}, {}
        for p in self.probes:
            cap = PR.capacity(p, n_steps, serving=serving)
            caps[p.name] = cap
            if PR.is_packed(p):
                bufs[p.name] = jnp.zeros((cap, BM.words_for(p.n)),
                                         jnp.uint32)
            else:
                bufs[p.name] = jnp.zeros((cap,) + p.sample_shape(), p.dtype)
        return bufs, caps

    def _probe_write(self, bufs, caps, start, i, state, spikes, gate=None):
        """One post-step sampling pass (strided ring write per probe)."""
        out = dict(bufs)
        for p in self.probes:
            base = PR.probe_base(p, start)
            active, slot = PR.sample_slot(p, start, base, i, caps[p.name])
            if gate is not None:
                active = active & gate
            val = PR.host_sample(p, self._groups, state, spikes)
            if PR.is_packed(p):
                val = BM.pack_spikes(val)
            out[p.name] = PR.write_sample(bufs[p.name], slot, active, val)
        return out

    def _probe_finalize(self, bufs, caps, start, n_eff,
                        serving: bool = False) -> Recordings:
        data, counts = {}, {}
        for p in self.probes:
            d, counts[p.name] = PR.finalize(
                bufs[p.name], start, n_eff, p, caps[p.name],
                use_window=not serving)
            data[p.name] = BM.unpack_rows(d, p.n) if PR.is_packed(p) else d
        return Recordings(data=data, counts=counts)

    # ------------------------------------------------------------------
    # health monitor plumbing (repro.obs.health; engine mirrors these with
    # psum'd partial sums and lane-masked guards for bitwise parity)
    # ------------------------------------------------------------------
    def _health_counts(self, spikes) -> Dict[str, jax.Array]:
        """Per-population scalar int32 spike count for one step."""
        return {p: jnp.sum(spikes[p].astype(jnp.int32))
                for p in self._pop_sizes}

    def _health_ok(self, state: SimState) -> jax.Array:
        """Scalar bool: V (where the model has one) and plastic g all
        finite.  Invalid ELL slots are masked out — their g values are
        never read by the dynamics, so they must not trip the guard."""
        ok = jnp.ones((), bool)
        for name in self.net.populations:
            v = state.neurons[name].get("V")
            if v is not None:
                ok = ok & jnp.all(jnp.isfinite(v))
        for g in self.net.synapses:
            st = state.syn[g.name]
            if st.g is not None:
                ok = ok & jnp.all(jnp.isfinite(
                    jnp.where(g.ell.valid, st.g, 0.0)))
        return ok

    def _step_count(self, state: SimState) -> jax.Array:
        """Global step counter: probes and scheduled custom updates key
        their schedule off it so serving chunks line up with offline runs."""
        return jnp.int32(jnp.round(state.t / jnp.float32(self.dt)))

    # ------------------------------------------------------------------
    def run(
        self, state: SimState, n_steps: int,
        gscales: Optional[Mapping[str, jax.Array]] = None,
        record_raster: bool = False,
        stim: Optional[Mapping[str, jax.Array]] = None,
    ) -> RunResult:
        """Scan n_steps; returns spike statistics, probe recordings (and
        legacy rasters).  stim: population name -> [n_steps, n] external
        currents, one row injected per step (the serving path's offline
        oracle)."""
        self._validate_gscales(gscales)
        self._validate_stim(stim)
        stim = {k: jnp.asarray(v, jnp.float32) for k, v in (stim or {}).items()}
        start = self._step_count(state)
        bufs0, caps = self._probe_init(n_steps)
        mon = self.monitor

        def body(carry, xs):
            i, stim_t = xs
            if mon is not None:
                st, counts, bufs, hstate = carry
            else:
                st, counts, bufs = carry
            st2, spk = self.step(st, gscales, stim=stim_t)
            counts = {k: counts[k] + spk[k] for k in counts}
            bufs = self._probe_write(bufs, caps, start, i, st2, spk)
            out = spk if record_raster else None
            if mon is not None:
                hstate = HE.accumulate(mon, hstate, self._health_counts(spk),
                                       self._health_ok(st2), self.dt,
                                       self._pop_sizes)
                return (st2, counts, bufs, hstate), out
            return (st2, counts, bufs), out

        counts0 = {name: jnp.zeros((pop.n,), jnp.int32)
                   for name, pop in self.net.populations.items()}
        xs = (jnp.arange(n_steps, dtype=jnp.int32),
              stim if stim else None)
        carry0 = (state, counts0, bufs0)
        if mon is not None:
            carry0 = carry0 + (HE.init_state(self._pop_sizes),)
        carry_out, raster = jax.lax.scan(body, carry0, xs, length=n_steps)
        if mon is not None:
            state2, counts, bufs, hstate = carry_out
            health = HE.finalize(mon, hstate, self.dt, self._pop_sizes)
        else:
            state2, counts, bufs = carry_out
            health = None
        rec = self._probe_finalize(bufs, caps, start, n_steps)

        t_sec = n_steps * self.dt * 1e-3
        rates = {k: jnp.mean(v) / t_sec for k, v in counts.items()}
        return RunResult(state=state2, spike_counts=counts, rates_hz=rates,
                         finite=state2.finite,
                         raster=raster if record_raster else None,
                         recordings=rec, health=health)

    # jit-compiled convenience wrapper (step count static) --------------
    def run_jit(self, n_steps: int, record_raster: bool = False):
        """Cached per (n_steps, record_raster), mirroring CompiledModel's
        executable cache: repeated calls with the same step count reuse one
        compiled program instead of re-jitting (gscale *values* are traced,
        so sweeping values also reuses it)."""
        cache_key = (int(n_steps), bool(record_raster))
        if cache_key not in self._run_jit_cache:

            @jax.jit
            def _run(state, gscales):
                return self.run(state, n_steps, gscales,
                                record_raster=record_raster)

            self._run_jit_cache[cache_key] = _run
        return self._run_jit_cache[cache_key]

    # ------------------------------------------------------------------
    # streaming / serving: a leading stream axis over independent sims
    # ------------------------------------------------------------------
    def init_stream_state(self, keys: jax.Array) -> SimState:
        """Batched initial state: one independent simulation per slot.
        keys: [max_streams, ...] stacked PRNG keys (one per slot); every
        other leaf is the single-sim init broadcast along the stream axis,
        so slot s starts bit-identical to init_state(keys[s])."""
        return jax.vmap(self.init_state)(jnp.asarray(keys))

    def select_streams(self, state: SimState, idx, keys) -> SimState:
        """Re-pack the stream axis between chunks (slot reclamation and
        elastic resize).  New slot j continues old slot ``idx[j]``
        **bit-for-bit** when ``idx[j] >= 0``, else starts fresh from
        ``keys[j]``; ``len(idx)`` sets the new stream-axis size, so the
        same call grows, shrinks, compacts, or re-keys the slot table.
        Surviving slots are pure gathers — no arithmetic touches their
        state, which is what keeps mid-flight eviction/resize invisible to
        the streams that stay (tests/test_gateway.py pins this down)."""
        fresh = self.init_stream_state(jnp.asarray(keys))
        return _select_streams(state, fresh, jnp.asarray(idx, jnp.int32))

    def serve_chunk(
        self, state: SimState, stim: Mapping[str, jax.Array],
        steps_left: jax.Array, n_steps: int,
        gscales: Optional[Mapping[str, jax.Array]] = None,
        record_raster: bool = False,
    ):
        """Advance every stream slot by up to `n_steps` (one serving chunk).

        state: SimState with a leading stream axis (init_stream_state);
        stim: population -> [max_streams, n_steps, n] injected currents;
        steps_left: [max_streams] int32 — slot s advances
        min(steps_left[s], n_steps) steps; lanes at or past their budget are
        select-restored so idle/finished slots are exact no-ops.

        Returns (new_state, counts, raster, recordings): counts maps
        population -> [max_streams, n] spikes within the chunk (masked
        steps contribute zero); raster maps population ->
        [max_streams, n_steps, n] when record_raster (masked steps
        all-False), else None; recordings is a Recordings whose leaves
        carry a leading stream axis (per-slot sample counts in
        `.counts` — masked lanes take no samples).  Probe sampling keys
        off each slot's global step counter, so stitched chunks are
        bit-identical to the offline run's recordings.
        """
        self._validate_gscales(gscales)
        self._validate_stim(stim)
        stim = {k: jnp.asarray(v, jnp.float32) for k, v in stim.items()}
        steps_left = jnp.asarray(steps_left, jnp.int32)

        mon = self.monitor

        def one_stream(st, st_stim, left):
            start = self._step_count(st)
            bufs0, caps = self._probe_init(n_steps, serving=True)

            def body(carry, xs):
                t_idx, stim_t = xs
                if mon is not None:
                    st, counts, bufs, hstate = carry
                else:
                    st, counts, bufs = carry
                st2, spk = self.step(st, gscales, stim=stim_t)
                act = t_idx < left
                st2 = jax.tree.map(lambda a, b: jnp.where(act, a, b),
                                   st2, st)
                spk = {k: v & act for k, v in spk.items()}
                counts = {k: counts[k] + spk[k] for k in counts}
                bufs = self._probe_write(bufs, caps, start, t_idx, st2,
                                         spk, gate=act)
                out = spk if record_raster else None
                if mon is not None:
                    hstate = HE.accumulate(
                        mon, hstate, self._health_counts(spk),
                        self._health_ok(st2), self.dt, self._pop_sizes,
                        gate=act)
                    return (st2, counts, bufs, hstate), out
                return (st2, counts, bufs), out

            counts0 = {name: jnp.zeros((pop.n,), jnp.int32)
                       for name, pop in self.net.populations.items()}
            xs = (jnp.arange(n_steps, dtype=jnp.int32),
                  st_stim if st_stim else None)
            carry0 = (st, counts0, bufs0)
            if mon is not None:
                carry0 = carry0 + (HE.init_state(self._pop_sizes),)
            carry_out, raster = jax.lax.scan(body, carry0, xs,
                                             length=n_steps)
            st2, counts, bufs = carry_out[:3]
            rec = self._probe_finalize(bufs, caps, start,
                                       jnp.minimum(left, n_steps),
                                       serving=True)
            if mon is not None:
                health = HE.finalize(mon, carry_out[3], self.dt,
                                     self._pop_sizes)
                return st2, counts, raster, rec, health
            return st2, counts, raster, rec

        return jax.vmap(one_stream)(state, stim, steps_left)
