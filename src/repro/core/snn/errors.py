"""Named declaration/build-time errors for the SNN front-end.

`SpecError` historically lived in `repro.core.snn.spec` (which re-exports it
for compatibility); it sits in its own leaf module so the probe and
custom-update machinery (imported *by* spec) can raise it without an import
cycle.
"""

from __future__ import annotations

__all__ = ["SpecError"]


class SpecError(ValueError):
    """A ModelSpec declaration or build-time validation failure."""
