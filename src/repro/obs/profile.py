"""Wall-clock phase timing + optional jax.profiler capture hooks.

:class:`PhaseTimer` is the CLI-facing layer over :mod:`repro.obs.trace`:
phases are recorded both as trace spans (so they land in the exported
Chrome trace) and as a simple (name, seconds) table the CLIs print.

:func:`jax_profiler_trace` wraps ``jax.profiler.trace`` when available
(XLA-level timelines, TensorBoard-loadable) and degrades to a no-op with a
warning otherwise, so ``--jax-profile DIR`` never breaks a build without
profiler support.
"""
from __future__ import annotations

import sys
import time
from contextlib import contextmanager
from typing import List, Optional, Tuple

from repro.obs import trace as _trace

__all__ = ["PhaseTimer", "jax_profiler_trace", "write_trace",
           "export_trace_cli"]


class PhaseTimer:
    """Accumulates named wall-clock phases; each phase is also a span."""

    def __init__(self, collector: Optional[_trace.TraceCollector] = None):
        self._collector = collector or _trace.get_collector()
        self.phases: List[Tuple[str, float]] = []

    @contextmanager
    def phase(self, name: str, **args):
        t0 = time.perf_counter()
        with self._collector.span(name, **args):
            yield
        self.phases.append((name, time.perf_counter() - t0))

    def total(self) -> float:
        return sum(s for _, s in self.phases)

    def render(self) -> str:
        if not self.phases:
            return "(no phases recorded)"
        width = max(len(n) for n, _ in self.phases)
        lines = [f"  {n:<{width}}  {s * 1e3:10.2f} ms" for n, s in self.phases]
        lines.append(f"  {'total':<{width}}  {self.total() * 1e3:10.2f} ms")
        return "\n".join(lines)


@contextmanager
def jax_profiler_trace(logdir: str):
    """jax.profiler.trace(logdir) when supported, else a warning no-op."""
    try:
        import jax.profiler as _prof
        ctx = _prof.trace(logdir)
    except Exception as e:  # profiler unavailable in this build
        print(f"[obs] jax profiler unavailable ({e}); continuing without",
              file=sys.stderr)
        yield
        return
    with ctx:
        yield


def write_trace(path: str,
                collector: Optional[_trace.TraceCollector] = None) -> int:
    """Export the Chrome trace to ``path``; returns the event count.

    Raises OSError when the file cannot be written — callers (the CLIs)
    turn that into a non-zero exit instead of a teardown-swallowed error.
    """
    c = collector or _trace.get_collector()
    return c.export(path)


def export_trace_cli(path: str, tag: str,
                     collector: Optional[_trace.TraceCollector] = None
                     ) -> int:
    """Shared ``--trace FILE`` tail for the CLIs: export and report.

    Returns a process exit code — 0 on success (or empty ``path``), 1 with
    a clear stderr message when the trace file cannot be written.  The run
    itself already happened; only the export failed.
    """
    if not path:
        return 0
    try:
        n = write_trace(path, collector)
    except OSError as e:
        print(f"[{tag}] error: cannot write trace file {path!r}: {e}",
              file=sys.stderr)
        return 1
    print(f"[{tag}] wrote {n} trace events to {path} "
          "(open in chrome://tracing or Perfetto)")
    return 0
