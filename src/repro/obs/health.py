"""On-device activity health monitoring compiled into the step scan.

The paper's headline tuning concern is scaling synaptic conductances "to
ensure sufficient spiking": a silent or saturated population is the failure
mode.  :class:`HealthConfig` (passed as ``build(..., monitor=...)``)
compiles a small accumulator *into* the simulation scan:

- per-population spike counts and an exponential-moving-average firing
  rate (Hz, time constant ``ema_tau_ms``);
- silent / saturated detectors: final EMA below/above a per-population
  ``bands_hz`` entry (or ``default_band_hz``);
- a NaN/Inf guard on membrane potential ``V`` and plastic conductance
  ``g`` recording the *first* bad step.

The result is a :class:`HealthReport` pytree returned from ``run`` /
``serve_chunk``.  Monitoring is strictly zero-cost when disabled: the
scan body and carry are built under a Python-level conditional, so a
monitor-off build produces the *same jaxpr* as an unmonitored one (the
same gating discipline as the 0-probe path).

Bitwise host/sharded parity: per-step counts are integer sums (the sharded
engine ``psum``'s per-device partial int32 sums — integer addition is
exact), and every subsequent float op uses Python-precomputed constants
(``alpha``, ``1/(n·dt)``) with an identical instruction sequence on host
and devices, so the sharded report equals the host report bit-for-bit.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["HealthConfig", "HealthState", "HealthReport", "NO_BAD_STEP"]

# Sentinel for "no non-finite value seen yet"; pmin-reducible across
# devices, mapped to -1 in the finalized report.
NO_BAD_STEP = jnp.iinfo(jnp.int32).max


@dataclasses.dataclass(frozen=True)
class HealthConfig:
    """Knobs for the compiled-in activity monitor.

    enabled: master switch — ``HealthConfig(enabled=False)`` builds the
        exact unmonitored program (same jaxpr as ``monitor=None``).
    ema_tau_ms: time constant of the firing-rate EMA.  The per-step
        update is ``ema += alpha * (rate - ema)`` with
        ``alpha = 1 - exp(-dt/tau)``.
    bands_hz: population name -> (lo_hz, hi_hz) healthy firing band;
        populations not listed fall back to ``default_band_hz``.
    default_band_hz: band for unlisted populations; ``None`` disables
        silent/saturated detection for them.
    nan_guard: fold an ``isfinite`` check on every population's ``V``
        (when the model has one) and every plastic group's ``g`` into the
        report, recording the first offending step.
    """
    enabled: bool = True
    ema_tau_ms: float = 20.0
    bands_hz: Mapping[str, Tuple[float, float]] = dataclasses.field(
        default_factory=dict)
    default_band_hz: Optional[Tuple[float, float]] = (1.0, 200.0)
    nan_guard: bool = True

    def validate(self, pop_names) -> None:
        """Raise ValueError on unknown populations / malformed bands."""
        if self.ema_tau_ms <= 0:
            raise ValueError(
                f"ema_tau_ms must be > 0, got {self.ema_tau_ms}")
        unknown = set(self.bands_hz) - set(pop_names)
        if unknown:
            raise ValueError(
                f"unknown band population(s) {sorted(unknown)}; declared "
                f"populations: {sorted(pop_names)}")
        for name, band in list(self.bands_hz.items()) + (
                [("<default>", self.default_band_hz)]
                if self.default_band_hz is not None else []):
            lo, hi = band
            if not (lo <= hi):
                raise ValueError(
                    f"band for {name!r} has lo > hi: ({lo}, {hi})")

    def band(self, pop: str) -> Optional[Tuple[float, float]]:
        return self.bands_hz.get(pop, self.default_band_hz)

    def alpha(self, dt_ms: float) -> float:
        return float(1.0 - math.exp(-float(dt_ms) / self.ema_tau_ms))


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class HealthState:
    """Scan-carried accumulator (all scalars; dicts keyed by population)."""
    spike_total: Dict[str, jax.Array]   # int32
    rate_ema_hz: Dict[str, jax.Array]   # float32
    steps: jax.Array                    # int32 (active steps accumulated)
    nonfinite: jax.Array                # bool
    first_bad_step: jax.Array           # int32, NO_BAD_STEP sentinel

    def tree_flatten(self):
        return ((self.spike_total, self.rate_ema_hz, self.steps,
                 self.nonfinite, self.first_bad_step), ())

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class HealthReport:
    """Finalized monitor output; under serving each leaf gains a leading
    stream axis (per-slot reports)."""
    spike_total: Dict[str, jax.Array]    # int32: population spike total
    rate_ema_hz: Dict[str, jax.Array]    # float32: final EMA rate
    mean_rate_hz: Dict[str, jax.Array]   # float32: total/(n*steps*dt)
    silent: Dict[str, jax.Array]         # bool: EMA below band lo
    saturated: Dict[str, jax.Array]      # bool: EMA above band hi
    steps: jax.Array                     # int32
    nonfinite: jax.Array                 # bool
    first_bad_step: jax.Array            # int32, -1 when never tripped

    def tree_flatten(self):
        return ((self.spike_total, self.rate_ema_hz, self.mean_rate_hz,
                 self.silent, self.saturated, self.steps, self.nonfinite,
                 self.first_bad_step), ())

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    def summary(self, slot: Optional[int] = None) -> dict:
        """Host-side plain-python view (optionally one serving slot)."""
        import numpy as np

        def sel(x):
            a = np.asarray(x)
            return a[slot] if slot is not None else a

        pops = {}
        for p in sorted(self.spike_total):
            pops[p] = {
                "spikes": int(sel(self.spike_total[p])),
                "rate_ema_hz": float(sel(self.rate_ema_hz[p])),
                "mean_rate_hz": float(sel(self.mean_rate_hz[p])),
                "silent": bool(sel(self.silent[p])),
                "saturated": bool(sel(self.saturated[p])),
            }
        return {
            "steps": int(sel(self.steps)),
            "nonfinite": bool(sel(self.nonfinite)),
            "first_bad_step": int(sel(self.first_bad_step)),
            "populations": pops,
        }


# ---------------------------------------------------------------------------
# scan plumbing (shared by the host Simulator and the ShardedEngine)
# ---------------------------------------------------------------------------

def init_state(pop_sizes: Mapping[str, int]) -> HealthState:
    return HealthState(
        spike_total={p: jnp.zeros((), jnp.int32) for p in pop_sizes},
        rate_ema_hz={p: jnp.zeros((), jnp.float32) for p in pop_sizes},
        steps=jnp.zeros((), jnp.int32),
        nonfinite=jnp.zeros((), bool),
        first_bad_step=jnp.full((), NO_BAD_STEP, jnp.int32),
    )


def accumulate(cfg: HealthConfig, hs: HealthState,
               counts: Mapping[str, jax.Array], ok: jax.Array,
               dt_ms: float, pop_sizes: Mapping[str, int],
               gate: Optional[jax.Array] = None) -> HealthState:
    """One post-step update.

    counts: population -> scalar int32 spike count for this step (already
    summed over the *full* population — the engine psums partial sums
    before calling).  ok: scalar bool, True when V/g are all finite this
    step.  gate: optional scalar bool (serving's per-slot active mask) —
    when False the state passes through untouched.
    """
    alpha = jnp.float32(cfg.alpha(dt_ms))
    new_total, new_ema = {}, {}
    for p, n in pop_sizes.items():
        c = counts[p]
        new_total[p] = hs.spike_total[p] + c
        # rate in Hz: count / (n * dt_s); 1/(n*dt_s) precomputed in python
        inv = jnp.float32(1.0 / (n * dt_ms * 1e-3))
        rate = c.astype(jnp.float32) * inv
        new_ema[p] = hs.rate_ema_hz[p] + alpha * (rate - hs.rate_ema_hz[p])
    if cfg.nan_guard:
        bad = ~ok
        first = jnp.where(bad & (hs.first_bad_step == NO_BAD_STEP),
                          hs.steps, hs.first_bad_step)
        nonfinite = hs.nonfinite | bad
    else:
        first = hs.first_bad_step
        nonfinite = hs.nonfinite
    new = HealthState(spike_total=new_total, rate_ema_hz=new_ema,
                      steps=hs.steps + 1, nonfinite=nonfinite,
                      first_bad_step=first)
    if gate is None:
        return new
    return jax.tree.map(lambda a, b: jnp.where(gate, a, b), new, hs)


def report_specs(pop_sizes: Mapping[str, int], make_leaf) -> HealthReport:
    """Spec twin of a HealthReport (e.g. shard_map out_specs): every leaf
    is ``make_leaf()`` — all health leaves are replicated scalars (or
    stream-leading vectors under serving)."""
    def d():
        return {p: make_leaf() for p in pop_sizes}
    return HealthReport(spike_total=d(), rate_ema_hz=d(), mean_rate_hz=d(),
                        silent=d(), saturated=d(), steps=make_leaf(),
                        nonfinite=make_leaf(), first_bad_step=make_leaf())


def combine_across_devices(hs: HealthState, axis: str) -> HealthState:
    """Merge per-device NaN-guard verdicts at scan exit (inside shard_map).

    Spike totals, EMAs and step counts are already replicated (they are
    built from psum'd counts); only the guard fields differ per device:
    ``nonfinite`` ORs (pmax) and ``first_bad_step`` takes the earliest
    step (pmin over the NO_BAD_STEP-sentineled int32).
    """
    nonfinite = jax.lax.pmax(hs.nonfinite.astype(jnp.int32), axis) == 1
    first = jax.lax.pmin(hs.first_bad_step, axis)
    return HealthState(spike_total=hs.spike_total,
                       rate_ema_hz=hs.rate_ema_hz, steps=hs.steps,
                       nonfinite=nonfinite, first_bad_step=first)


def finalize(cfg: HealthConfig, hs: HealthState, dt_ms: float,
             pop_sizes: Mapping[str, int]) -> HealthReport:
    """HealthState -> HealthReport (elementwise; vmap-safe for serving)."""
    steps_f = jnp.maximum(hs.steps.astype(jnp.float32), 1.0)
    mean, silent, saturated = {}, {}, {}
    for p, n in pop_sizes.items():
        inv = jnp.float32(1.0 / (n * float(dt_ms) * 1e-3))
        mean[p] = hs.spike_total[p].astype(jnp.float32) * inv / steps_f
        band = cfg.band(p)
        if band is None:
            silent[p] = jnp.zeros_like(hs.nonfinite)
            saturated[p] = jnp.zeros_like(hs.nonfinite)
        else:
            lo, hi = band
            silent[p] = hs.rate_ema_hz[p] < jnp.float32(lo)
            saturated[p] = hs.rate_ema_hz[p] > jnp.float32(hi)
    first = jnp.where(hs.first_bad_step == NO_BAD_STEP,
                      jnp.int32(-1), hs.first_bad_step)
    return HealthReport(spike_total=hs.spike_total,
                        rate_ema_hz=hs.rate_ema_hz, mean_rate_hz=mean,
                        silent=silent, saturated=saturated, steps=hs.steps,
                        nonfinite=hs.nonfinite, first_bad_step=first)
