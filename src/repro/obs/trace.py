"""Structured tracing: lightweight spans with Chrome trace_event export.

A ``TraceCollector`` is a thread-safe, bounded in-process ring of trace
events.  Code instruments itself with::

    from repro.obs import trace

    with trace.span("device_init", group="PN_KC", rows=4096):
        ...                      # timed region -> "X" (complete) event

    trace.instant("choose_block_spmv", bp=8, bn=128)   # point event

Events accumulate in a module-level default collector and can be exported
as Chrome ``trace_event`` JSON (loadable in chrome://tracing or Perfetto)
via :func:`export` / :func:`chrome_trace`.  The collector is bounded: once
``cap`` events are held the oldest are dropped and ``dropped`` counts them,
so long-running servers never grow without bound.

Timestamps are microseconds relative to the collector's epoch
(``time.perf_counter_ns`` at construction), which is what the Chrome trace
viewer expects (``ts``/``dur`` in µs).  Nesting is implicit: the viewer
reconstructs the span tree from ts/dur containment per ``tid``.
"""
from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from contextlib import contextmanager
from typing import Any, Dict, Iterator, List, Optional

__all__ = [
    "TraceCollector",
    "span",
    "instant",
    "events",
    "clear",
    "chrome_trace",
    "export",
    "get_collector",
    "set_enabled",
]

_DEFAULT_CAP = 65536


def _jsonable(v: Any) -> Any:
    """Coerce an arg value to something json.dumps accepts."""
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    try:  # numpy / jax scalars
        return v.item()
    except (AttributeError, ValueError, TypeError):
        return str(v)


class TraceCollector:
    """Thread-safe bounded collector of Chrome trace_event records."""

    def __init__(self, cap: int = _DEFAULT_CAP, enabled: bool = True):
        if cap < 1:
            raise ValueError(f"cap must be >= 1, got {cap}")
        self._lock = threading.Lock()
        self._events: deque = deque(maxlen=cap)
        self._epoch_ns = time.perf_counter_ns()
        self.enabled = enabled
        self.dropped = 0

    # -- recording -------------------------------------------------------
    def _now_us(self) -> float:
        return (time.perf_counter_ns() - self._epoch_ns) / 1e3

    def _append(self, ev: Dict[str, Any]) -> None:
        with self._lock:
            if len(self._events) == self._events.maxlen:
                self.dropped += 1
            self._events.append(ev)

    @contextmanager
    def span(self, name: str, **args: Any) -> Iterator[None]:
        """Record a complete ("X") event covering the with-block."""
        if not self.enabled:
            yield
            return
        t0 = self._now_us()
        try:
            yield
        finally:
            self._append({
                "name": name,
                "ph": "X",
                "ts": t0,
                "dur": self._now_us() - t0,
                "pid": os.getpid(),
                "tid": threading.get_ident(),
                "args": {k: _jsonable(v) for k, v in args.items()},
            })

    def instant(self, name: str, **args: Any) -> None:
        """Record an instant ("i") event at the current time."""
        if not self.enabled:
            return
        self._append({
            "name": name,
            "ph": "i",
            "s": "t",
            "ts": self._now_us(),
            "pid": os.getpid(),
            "tid": threading.get_ident(),
            "args": {k: _jsonable(v) for k, v in args.items()},
        })

    # -- export ----------------------------------------------------------
    def events(self) -> List[Dict[str, Any]]:
        with self._lock:
            return list(self._events)

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self.dropped = 0

    def chrome_trace(self) -> Dict[str, Any]:
        """The full Chrome trace_event JSON document (as a dict)."""
        return {
            "traceEvents": self.events(),
            "displayTimeUnit": "ms",
            "otherData": {"dropped_events": self.dropped},
        }

    def export(self, path: str) -> int:
        """Write the Chrome trace JSON to ``path``; returns event count.

        Raises OSError if the file cannot be written.
        """
        doc = self.chrome_trace()
        with open(path, "w") as fh:
            json.dump(doc, fh)
        return len(doc["traceEvents"])


# -- module-level default collector --------------------------------------
_default = TraceCollector()


def get_collector() -> TraceCollector:
    return _default


def set_enabled(enabled: bool) -> None:
    _default.enabled = enabled


def span(name: str, **args: Any):
    return _default.span(name, **args)


def instant(name: str, **args: Any) -> None:
    _default.instant(name, **args)


def events() -> List[Dict[str, Any]]:
    return _default.events()


def clear() -> None:
    _default.clear()


def chrome_trace() -> Dict[str, Any]:
    return _default.chrome_trace()


def export(path: str) -> int:
    return _default.export(path)


def validate_chrome_trace(doc: Any) -> Optional[str]:
    """Check a dict against the Chrome trace_event schema we emit.

    Returns None when valid, else a string describing the first problem.
    Used by tests and the ``/v1/trace`` endpoint's self-check.
    """
    if not isinstance(doc, dict):
        return "document is not an object"
    evs = doc.get("traceEvents")
    if not isinstance(evs, list):
        return "traceEvents missing or not a list"
    for i, ev in enumerate(evs):
        if not isinstance(ev, dict):
            return f"event {i} not an object"
        for field in ("name", "ph", "ts", "pid", "tid"):
            if field not in ev:
                return f"event {i} missing {field!r}"
        if not isinstance(ev["name"], str):
            return f"event {i} name not a string"
        if ev["ph"] not in ("X", "i", "B", "E", "M"):
            return f"event {i} has unknown phase {ev['ph']!r}"
        if ev["ph"] == "X" and (not isinstance(ev.get("dur"), (int, float))
                                or ev["dur"] < 0):
            return f"event {i} 'X' without non-negative dur"
        try:
            json.dumps(ev.get("args", {}))
        except (TypeError, ValueError):
            return f"event {i} args not JSON-serializable"
    return None
