"""Unified metrics: counters/gauges/histograms + Prometheus text rendering.

This is the one canonical home for metrics plumbing across the stack.
``launch/gateway.py`` renders its ``/metrics`` endpoint through the
primitives here (it previously carried a private copy of ``LatencyWindow``
and hand-rolled the exposition text); anything else that wants metrics —
benches, the serve CLI, future calibration loops — registers them on a
:class:`MetricsRegistry`.

Rendering follows the Prometheus text exposition format, version 0.0.4:
``name{label="value",...} value`` lines, one sample per line, trailing
newline.  :class:`PromText` is the low-level line builder used both by the
registry and by the gateway (whose metric names/labels are frozen for
dashboard compatibility).
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Any, Dict, Iterable, List, Mapping, Optional, Tuple

__all__ = [
    "LatencyWindow",
    "PromText",
    "format_labels",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
]


class LatencyWindow:
    """Sliding window of latency samples with percentile summaries.

    Keeps the most recent ``cap`` samples (bounded memory) plus a lifetime
    count.  Percentiles use nearest-rank on the sorted window.
    """

    def __init__(self, cap: int = 4096):
        self._samples: deque = deque(maxlen=cap)
        self.count = 0  # lifetime, not windowed

    def add(self, v: float) -> None:
        self._samples.append(float(v))
        self.count += 1

    def samples(self) -> List[float]:
        return list(self._samples)

    def percentile(self, q: float) -> float:
        """q in [0, 1]; 0.0 if no samples."""
        s = sorted(self._samples)
        if not s:
            return 0.0
        i = min(len(s) - 1, max(0, int(round(q * (len(s) - 1)))))
        return s[i]

    def summary(self) -> Dict[str, float]:
        s = self.samples()
        return {
            "count": self.count,
            "p50": self.percentile(0.50),
            "p99": self.percentile(0.99),
            "mean": (sum(s) / len(s)) if s else 0.0,
            "max": max(s) if s else 0.0,
        }


def format_labels(labels: Mapping[str, Any]) -> str:
    """Render a label set as ``{k="v",...}`` (empty string when no labels)."""
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in labels.items())
    return "{" + inner + "}"


class PromText:
    """Prometheus text-exposition line builder.

    The formatting knobs exist so callers with frozen output contracts
    (the gateway's PR 6 metric text is bit-compatible by test) can
    reproduce their exact historical formatting through one renderer.
    """

    CONTENT_TYPE = "text/plain; version=0.0.4"

    def __init__(self) -> None:
        self.lines: List[str] = []

    def sample(self, name: str, labels: Mapping[str, Any], value: Any,
               fmt: str = "{}") -> None:
        self.lines.append(f"{name}{format_labels(labels)} " + fmt.format(value))

    def quantiles(self, name: str, labels: Mapping[str, Any],
                  summary: Mapping[str, float], unit: float = 1.0,
                  quantiles: Iterable[str] = ("50", "99"),
                  fmt: str = "{:.6f}") -> None:
        """Emit ``name{...,quantile="q"}`` lines plus ``name_count``.

        ``summary`` is a :meth:`LatencyWindow.summary` dict; ``unit``
        rescales samples (e.g. 1e-6 for µs windows rendered as seconds).
        """
        for q in quantiles:
            lab = dict(labels)
            lab["quantile"] = q
            self.sample(name, lab, summary[f"p{q}"] * unit, fmt)
        self.sample(name + "_count", labels, summary["count"])

    def render(self) -> str:
        return "\n".join(self.lines) + "\n"


class Counter:
    """Monotonically increasing counter, optionally labelled."""

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._lock = threading.Lock()
        self._values: Dict[Tuple[Tuple[str, str], ...], float] = {}

    def inc(self, amount: float = 1, **labels: Any) -> None:
        if amount < 0:
            raise ValueError("counters can only increase")
        key = tuple(sorted((k, str(v)) for k, v in labels.items()))
        with self._lock:
            self._values[key] = self._values.get(key, 0) + amount

    def value(self, **labels: Any) -> float:
        key = tuple(sorted((k, str(v)) for k, v in labels.items()))
        with self._lock:
            return self._values.get(key, 0)

    def collect(self, out: PromText) -> None:
        with self._lock:
            items = sorted(self._values.items())
        for key, v in items:
            out.sample(self.name, dict(key), v, "{:g}")


class Gauge:
    """A value that can go up and down."""

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._lock = threading.Lock()
        self._values: Dict[Tuple[Tuple[str, str], ...], float] = {}

    def set(self, value: float, **labels: Any) -> None:
        key = tuple(sorted((k, str(v)) for k, v in labels.items()))
        with self._lock:
            self._values[key] = float(value)

    def value(self, **labels: Any) -> float:
        key = tuple(sorted((k, str(v)) for k, v in labels.items()))
        with self._lock:
            return self._values.get(key, 0.0)

    def collect(self, out: PromText) -> None:
        with self._lock:
            items = sorted(self._values.items())
        for key, v in items:
            out.sample(self.name, dict(key), v, "{:g}")


class Histogram:
    """Fixed-bucket histogram rendered as cumulative ``_bucket`` lines."""

    DEFAULT_BUCKETS = (0.001, 0.005, 0.01, 0.05, 0.1, 0.5, 1.0, 5.0)

    def __init__(self, name: str, help: str = "",
                 buckets: Optional[Iterable[float]] = None):
        self.name = name
        self.help = help
        self.buckets = tuple(sorted(buckets if buckets is not None
                                    else self.DEFAULT_BUCKETS))
        self._lock = threading.Lock()
        # per label-set: (bucket counts, sum, count)
        self._series: Dict[Tuple[Tuple[str, str], ...],
                           Tuple[List[int], float, int]] = {}

    def observe(self, value: float, **labels: Any) -> None:
        key = tuple(sorted((k, str(v)) for k, v in labels.items()))
        with self._lock:
            counts, total, n = self._series.get(
                key, ([0] * len(self.buckets), 0.0, 0))
            for i, le in enumerate(self.buckets):
                if value <= le:
                    counts[i] += 1
            self._series[key] = (counts, total + value, n + 1)

    def collect(self, out: PromText) -> None:
        with self._lock:
            items = sorted((k, (list(c), s, n))
                           for k, (c, s, n) in self._series.items())
        for key, (counts, total, n) in items:
            base = dict(key)
            for le, c in zip(self.buckets, counts):
                lab = dict(base)
                lab["le"] = f"{le:g}"
                out.sample(self.name + "_bucket", lab, c)
            lab = dict(base)
            lab["le"] = "+Inf"
            out.sample(self.name + "_bucket", lab, n)
            out.sample(self.name + "_sum", base, total, "{:.6f}")
            out.sample(self.name + "_count", base, n)


class MetricsRegistry:
    """Registry of named metrics with one canonical text renderer."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._metrics: Dict[str, Any] = {}

    def _register(self, cls, name: str, help: str, **kw):
        with self._lock:
            existing = self._metrics.get(name)
            if existing is not None:
                if not isinstance(existing, cls):
                    raise ValueError(
                        f"metric {name!r} already registered as "
                        f"{type(existing).__name__}")
                return existing
            m = cls(name, help, **kw)
            self._metrics[name] = m
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._register(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._register(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Optional[Iterable[float]] = None) -> Histogram:
        return self._register(Histogram, name, help, buckets=buckets)

    def render(self) -> str:
        out = PromText()
        with self._lock:
            metrics = [self._metrics[k] for k in sorted(self._metrics)]
        for m in metrics:
            m.collect(out)
        return out.render()


# Default process-wide registry (mirrors trace's default collector).
default_registry = MetricsRegistry()
