"""Observability subsystem: tracing, metrics, health monitors, profiling.

- :mod:`repro.obs.trace` — structured spans + Chrome trace_event export
- :mod:`repro.obs.telemetry` — counters/gauges/histograms + Prometheus text
- :mod:`repro.obs.health` — on-device activity monitor (``build(monitor=)``)
- :mod:`repro.obs.profile` — wall-clock phases + jax.profiler hooks
"""
from repro.obs import trace  # noqa: F401
from repro.obs.health import HealthConfig, HealthReport  # noqa: F401
from repro.obs.telemetry import LatencyWindow, MetricsRegistry  # noqa: F401

__all__ = ["trace", "HealthConfig", "HealthReport", "LatencyWindow",
           "MetricsRegistry"]
