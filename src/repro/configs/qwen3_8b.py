"""qwen3-8b [dense]: 36L, d=4096, 32H GQA kv=8, head_dim=128, ff=12288,
vocab=151936.  RMSNorm, SwiGLU, qk-norm.  [hf:Qwen/Qwen3-8B]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-8b", family="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv=8, head_dim=128,
    d_ff=12288, vocab=151936,
    qk_norm=True, rope_theta=1000000.0,
    microbatches=8,
)
