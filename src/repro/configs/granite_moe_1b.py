"""granite-moe-1b-a400m [moe]: 24L, d=1024, 16H GQA kv=8, 32 experts
top-8 with per-expert ff=512, vocab=49155.  Experts shard over the model
axis (32 % 16 == 0).  [hf:ibm-granite/granite-3.0-1b-a400m-base]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv=8, head_dim=64,
    d_ff=512, vocab=49155,
    n_experts=32, top_k=8, expert_sharding="expert",
    tie_embeddings=True,
)
