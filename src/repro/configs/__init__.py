"""Config registry: the paper's SNN configs + the 10 assigned architectures."""
from repro.configs.base import (ArchConfig, LayerProgram, Segment,
                                ShapeConfig, SHAPES, reduced)

from repro.configs.zamba2_7b import CONFIG as zamba2_7b
from repro.configs.whisper_tiny import CONFIG as whisper_tiny
from repro.configs.starcoder2_15b import CONFIG as starcoder2_15b
from repro.configs.qwen3_8b import CONFIG as qwen3_8b
from repro.configs.gemma3_12b import CONFIG as gemma3_12b
from repro.configs.qwen2_0_5b import CONFIG as qwen2_0_5b
from repro.configs.mamba2_2_7b import CONFIG as mamba2_2_7b
from repro.configs.granite_moe_1b import CONFIG as granite_moe_1b
from repro.configs.mixtral_8x22b import CONFIG as mixtral_8x22b
from repro.configs.paligemma_3b import CONFIG as paligemma_3b

ARCHS = {c.name: c for c in [
    zamba2_7b, whisper_tiny, starcoder2_15b, qwen3_8b, gemma3_12b,
    qwen2_0_5b, mamba2_2_7b, granite_moe_1b, mixtral_8x22b, paligemma_3b,
]}


def get_config(name: str) -> ArchConfig:
    try:
        return ARCHS[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")


def get_shape(name: str) -> ShapeConfig:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(f"unknown shape {name!r}")
