"""mixtral-8x22b [moe]: 56L, d=6144, 48H GQA kv=8, 8 experts top-2 with
per-expert ff=16384, vocab=32768, sliding-window attention.  8 experts
don't divide the 16-way model axis -> tensor-parallel inside experts
(expert_sharding='ffn').  [arXiv:2401.04088]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv=8, head_dim=128,
    d_ff=16384, vocab=32768,
    n_experts=8, top_k=2, expert_sharding="ffn",
    window=4096, rope_theta=1000000.0,
    microbatches=16,
)
