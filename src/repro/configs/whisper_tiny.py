"""whisper-tiny [audio]: 4L enc + 4L dec, d=384, 6H, ff=1536, vocab=51865.
Conv audio frontend is a STUB: input_specs() feeds precomputed frame
embeddings [B, 1500, 384].  LayerNorm + GELU, non-gated MLP.
[arXiv:2212.04356]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny", family="encdec",
    n_layers=4, n_enc_layers=4, enc_seq=1500,
    d_model=384, n_heads=6, n_kv=6, head_dim=64,
    d_ff=1536, vocab=51865,
    norm="layernorm", activation="gelu", gated_mlp=False,
    notes="enc-dec; conv frontend stubbed to frame embeddings",
)
