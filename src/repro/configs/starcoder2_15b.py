"""starcoder2-15b [dense]: 40L, d=6144, 48H GQA kv=4, ff=24576,
vocab=49152.  LayerNorm, non-gated GELU MLP, attention+MLP bias, RoPE.
[arXiv:2402.19173]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-15b", family="dense",
    n_layers=40, d_model=6144, n_heads=48, n_kv=4, head_dim=128,
    d_ff=24576, vocab=49152,
    norm="layernorm", activation="gelu", gated_mlp=False, qkv_bias=True,
    rope_theta=100000.0,
    microbatches=16,
)
