"""Architecture + shape configuration schema.

One `ArchConfig` describes any member of the assigned pool (dense / MoE /
SSM / hybrid / enc-dec / VLM).  `LayerProgram` describes the layer stacking
pattern (uniform, local:global interleave, shared-attention hybrid, ...) in a
scan-friendly grouped form: `repeats x segments + tail`, where each segment
is a (kind, count) pair whose params are stacked [repeats, count, ...].

Shapes: every arch is paired with the four assigned shape cells; `applicable`
encodes the briefed skips (encoder-only decode, full-attention long_500k).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

__all__ = ["ArchConfig", "ShapeConfig", "Segment", "LayerProgram", "SHAPES",
           "reduced"]


@dataclasses.dataclass(frozen=True)
class Segment:
    kind: str   # 'attn' | 'attn_local' | 'attn_global' | 'moe' | 'mamba'
    #           | 'shared_attn'
    n: int


@dataclasses.dataclass(frozen=True)
class LayerProgram:
    repeats: int
    segments: Tuple[Segment, ...]
    tail: Tuple[Segment, ...] = ()

    @property
    def total_layers(self) -> int:
        per = sum(s.n for s in self.segments)
        return self.repeats * per + sum(s.n for s in self.tail)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    norm: str = "rmsnorm"
    activation: str = "silu"
    gated_mlp: bool = True
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    embed_scale: bool = False      # multiply embeddings by sqrt(d) (gemma)
    # attention pattern
    window: Optional[int] = None   # uniform sliding window (mixtral SWA)
    local_global: int = 0          # gemma3: N local layers per 1 global
    local_window: int = 1024
    # moe
    n_experts: int = 0
    top_k: int = 0
    moe_group_size: int = 1024
    moe_capacity_factor: float = 1.25
    moe_dispatch: str = "onehot"   # 'onehot' | 'gather' (see models/moe.py)
    expert_sharding: str = "expert"
    # ssm (mamba2 / zamba2)
    ssm_state: int = 0
    ssm_head: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    # hybrid (zamba2): one shared attention block every `attn_every` blocks
    attn_every: int = 0
    # enc-dec (whisper)
    n_enc_layers: int = 0
    enc_seq: int = 1500            # audio frames after conv stub
    # vlm (paligemma)
    img_tokens: int = 0
    img_embed_dim: int = 0
    # numerics / compile
    microbatches: int = 1          # gradient-accumulation steps (train)
    dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "full"     # 'full' | 'dots' | 'none'
    logits_dtype: str = "float32"  # CE logits compute dtype ('bfloat16' cuts
    #                                head/CE HBM traffic ~2x; see §Perf)
    serve_replicate_weights: bool = False  # decode cells: skip TP, replicate
    notes: str = ""

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads > 0:
            object.__setattr__(self, "head_dim",
                               self.d_model // max(1, self.n_heads))

    # ---- layer program ----------------------------------------------------
    def program(self) -> LayerProgram:
        if self.family == "ssm":
            return LayerProgram(1, (Segment("mamba", self.n_layers),))
        if self.family == "hybrid":
            k = self.attn_every
            groups, rem = divmod(self.n_layers, k + 1)
            segs = (Segment("mamba", k), Segment("shared_attn", 1))
            tail = (Segment("mamba", rem),) if rem else ()
            return LayerProgram(groups, segs, tail)
        if self.local_global > 0:
            lg = self.local_global
            groups, rem = divmod(self.n_layers, lg + 1)
            segs = (Segment("attn_local", lg), Segment("attn_global", 1))
            tail = (Segment("attn_local", rem),) if rem else ()
            return LayerProgram(groups, segs, tail)
        kind = "moe" if self.family == "moe" else "attn"
        return LayerProgram(1, (Segment(kind, self.n_layers),))

    # ---- shape-cell applicability (DESIGN.md §4 skips) ---------------------
    def applicable(self, shape: "ShapeConfig") -> Tuple[bool, str]:
        if shape.kind in ("decode", "long") and self.family == "encdec" \
                and self.n_layers == 0:
            return False, "encoder-only arch has no decode step"
        if shape.kind == "long":
            sub_quadratic = (
                self.family in ("ssm", "hybrid")
                or self.window is not None
                or self.local_global > 0)
            if not sub_quadratic:
                return False, ("pure full-attention arch: 500k decode "
                               "exceeds design assumptions (DESIGN.md §4)")
        return True, ""


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # 'train' | 'prefill' | 'decode' | 'long'


SHAPES: List[ShapeConfig] = [
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "long"),
]


def full_groups(cfg: ArchConfig) -> int:
    """Depth-extrapolation unit count of the full config (see dryrun)."""
    prog = cfg.program()
    if prog.repeats > 1:
        return prog.repeats
    return cfg.n_layers


def depth_scaled(cfg: ArchConfig, g: int) -> ArchConfig:
    """Same arch with g depth-groups (for roofline extrapolation):
    cost(g) is linear in g; full cost = cost at full_groups(cfg)."""
    prog = cfg.program()
    kw = {}
    if prog.repeats > 1:
        per = sum(s.n for s in prog.segments)
        tail = sum(s.n for s in prog.tail)
        kw["n_layers"] = per * g + tail
    else:
        kw["n_layers"] = g
    if cfg.n_enc_layers:
        kw["n_enc_layers"] = g
    return dataclasses.replace(cfg, **kw)


def reduced(cfg: ArchConfig, **overrides) -> ArchConfig:
    """Tiny same-family config for CPU smoke tests."""
    base = dict(
        n_layers=min(cfg.n_layers, 4) if cfg.family != "hybrid" else 7,
        d_model=128,
        n_heads=min(cfg.n_heads, 4) if cfg.n_heads else 0,
        n_kv=min(cfg.n_kv, 2) if cfg.n_kv else 0,
        head_dim=32 if cfg.n_heads else 0,
        d_ff=256 if cfg.d_ff else 0,
        vocab=512,
        n_experts=min(cfg.n_experts, 4),
        top_k=min(cfg.top_k, 2),
        moe_group_size=64,
        moe_capacity_factor=8.0,   # dropless: smoke tests are deterministic

        ssm_state=min(cfg.ssm_state, 16),
        ssm_head=16 if cfg.ssm_state else 64,
        local_window=32 if cfg.local_global else cfg.local_window,
        window=min(cfg.window, 32) if cfg.window else None,
        attn_every=2 if cfg.family == "hybrid" else 0,
        n_enc_layers=min(cfg.n_enc_layers, 2),
        enc_seq=16 if cfg.n_enc_layers else cfg.enc_seq,
        img_tokens=8 if cfg.img_tokens else 0,
        img_embed_dim=64 if cfg.img_embed_dim else 0,
        dtype="float32",
        remat=False,
        local_global=cfg.local_global and 2,
    )
    base.update(overrides)
    return dataclasses.replace(cfg, **base)
