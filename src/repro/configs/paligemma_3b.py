"""paligemma-3b [vlm]: 18L gemma backbone, d=2048, 8H MQA kv=1,
head_dim=256, ff=16384, vocab=257216.  SigLIP vision tower is a STUB:
input_specs() feeds precomputed patch embeddings [B, 256, 1152]; a learned
projection maps them into the prefix.  Prefix-LM masking (image prefix
bidirectional, text causal).  [arXiv:2407.07726]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv=1, head_dim=256,
    d_ff=16384, vocab=257216,
    activation="gelu_tanh", tie_embeddings=True,
    img_tokens=256, img_embed_dim=1152,
    microbatches=4,
)
