"""gemma3-12b [dense]: 48L, d=3840, 16H GQA kv=8, head_dim=256, ff=15360,
vocab=262144.  5:1 local:global attention (1024-token local window), GeGLU,
tied embeddings, 128k context.  [hf:google/gemma-3-*]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-12b", family="dense",
    n_layers=48, d_model=3840, n_heads=16, n_kv=8, head_dim=256,
    d_ff=15360, vocab=262144,
    activation="gelu_tanh", tie_embeddings=True, embed_scale=True,
    local_global=5, local_window=1024, rope_theta=1000000.0,
    microbatches=8,
)
