"""zamba2-7b [hybrid]: Mamba2 backbone + one SHARED attention block applied
every 6th layer slot (weights reused, Zamba-style).  81 layer slots =
13 x (5 mamba + 1 shared-attn) + 3 mamba tail.  [arXiv:2411.15242]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv=32, head_dim=112,
    d_ff=14336, vocab=32000,
    ssm_state=64, ssm_head=64, ssm_expand=2, attn_every=5,
    notes="shared transformer block (Zamba2); ssm_state=64",
    microbatches=16,
)
