"""Sparse synapse representations (paper Section 3).

The paper's Compressed Row Storage (CRS) is kept verbatim as a container and
as the memory model used to *choose* a representation (eqs. (1)/(2)).  For TPU
compute we add an ELLPACK layout (fixed number of slots per row): the paper's
benchmark networks have a constant nConn per pre-synaptic neuron, so ELL is
exact there, and its rectangular shape is what VMEM tiling and the MXU
one-hot-matmul scatter want.  CSR row-gather (one CUDA thread per row/spike)
has no efficient TPU analogue — see DESIGN.md §2.

All containers are registered pytrees so they flow through jit/scan/vmap.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "CSRSynapses", "ELLSynapses",
    "sparse_memory_elements", "dense_memory_elements", "memory_bytes",
    "ell_slot_bytes", "ell_memory_bytes",
    "choose_representation",
    "dense_to_csr", "dense_to_ell", "csr_to_dense", "ell_to_dense",
    "fixed_fanout_connectivity",
    "ConnectivityInit", "FixedFanout", "FixedProbability", "OneToOne",
    "DenseInit", "triple_to_ell",
    "WeightSnippet", "ConstantWeight", "UniformWeight", "NormalWeight",
    "DelaySnippet", "ConstantDelay", "UniformIntDelay",
]


# The affine weight combines (`mean + std * draw`) must round the same way
# in *every* compilation context: generation runs eagerly in ModelSpec
# builds but inside one big jit/shard_map in device_init_local, and XLA's
# CPU backend FMA-contracts mul+add when it compiles them together — a one-
# ulp drift that breaks the fused path's bit-exactness contract.  Jitting
# the draw as its own unit pins the contraction decision: eager callers and
# enclosing jits both see the identical compiled expression.

@functools.partial(jax.jit, static_argnames=("shape", "lo", "hi"))
def _uniform_affine_draw(key, shape, lo, hi):
    return lo + (hi - lo) * jax.random.uniform(key, shape, jnp.float32)


@functools.partial(jax.jit, static_argnames=("shape", "mean", "std"))
def _normal_affine_draw(key, shape, mean, std):
    return mean + std * jax.random.normal(key, shape, jnp.float32)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class CSRSynapses:
    """Compressed Row Storage exactly as described in the paper §3.

    g:        non-zero conductances, traversed along pre-neuron rows  [nNZ]
    post_ind: post-synaptic neuron index per non-zero                 [nNZ]
    row_start:index into post_ind where each pre-neuron's row begins  [nPre+1]
    row_of_nz:pre-neuron index per non-zero (derived, static; lets the
              TPU path avoid a serial row walk)                       [nNZ]
    """

    g: jax.Array
    post_ind: jax.Array
    row_start: jax.Array
    row_of_nz: jax.Array
    n_post: int

    @property
    def n_pre(self) -> int:
        return self.row_start.shape[0] - 1

    @property
    def nnz(self) -> int:
        return self.g.shape[0]

    def tree_flatten(self):
        return (self.g, self.post_ind, self.row_start, self.row_of_nz), (
            self.n_post,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, n_post=aux[0])


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ELLSynapses:
    """ELLPACK: fixed max_conn slots per pre-neuron row.

    g:        conductances                      [nPre, max_conn]
    post_ind: post indices (invalid slots -> 0) [nPre, max_conn]
    valid:    slot mask                         [nPre, max_conn]
    delay:    per-synapse dendritic delay in dt steps (int32, invalid
              slots -> 0), or None for delay-free / homogeneous groups
              (GeNN's dendritic-delay model)  [nPre, max_conn]
    """

    g: jax.Array
    post_ind: jax.Array
    valid: jax.Array
    n_post: int
    delay: Optional[jax.Array] = None

    @property
    def n_pre(self) -> int:
        return self.g.shape[0]

    @property
    def max_conn(self) -> int:
        return self.g.shape[1]

    def tree_flatten(self):
        return (self.g, self.post_ind, self.valid, self.delay), (self.n_post,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        g, post_ind, valid, delay = children
        return cls(g=g, post_ind=post_ind, valid=valid, n_post=aux[0],
                   delay=delay)


# ---------------------------------------------------------------------------
# Memory model — paper eqs. (1) and (2), in array *elements*.
# CRS stores two nNZ-sized arrays (g, post_ind) plus the row-start array of
# pre-population size (+1 sentinel, which the paper drops; we keep their
# expression and note the off-by-one is immaterial at scale).
# ---------------------------------------------------------------------------

def sparse_memory_elements(n_nz: int, n_pre: int, n_post: int) -> int:
    """Paper eq. (1): 2*nNZ + row-start array (pre-population sized)."""
    del n_post
    return 2 * n_nz + (n_pre + 1)


def dense_memory_elements(n_pre: int, n_post: int) -> int:
    """Paper eq. (2): nPreSynN * nPostSynN."""
    return n_pre * n_post


def memory_bytes(elements: int, dtype=jnp.float32) -> int:
    return int(elements) * jnp.dtype(dtype).itemsize


def ell_slot_bytes(has_delay: bool = False) -> int:
    """Bytes one ELL slot occupies across its parallel arrays: g (float32)
    + post_ind (int32) + valid (bool), plus the int32 dendritic-delay slot
    when the group declares per-synapse delays."""
    return 4 + 4 + 1 + (4 if has_delay else 0)


def ell_memory_bytes(n_pre: int, max_conn: int,
                     has_delay: bool = False) -> int:
    """Resident bytes of an [n_pre, max_conn] ELL (all parallel arrays)."""
    return int(n_pre) * int(max_conn) * ell_slot_bytes(has_delay)


def choose_representation(n_pre: int, n_post: int, n_nz: int) -> str:
    """Pick 'sparse' or 'dense' from the paper's memory model."""
    sparse_cost = sparse_memory_elements(n_nz, n_pre, n_post)
    dense_cost = dense_memory_elements(n_pre, n_post)
    return "sparse" if sparse_cost < dense_cost else "dense"


# ---------------------------------------------------------------------------
# Builders / converters (host-side numpy; called at model-build time, the
# resulting containers are device arrays).
# ---------------------------------------------------------------------------

def dense_to_csr(w: np.ndarray) -> CSRSynapses:
    w = np.asarray(w)
    n_pre, n_post = w.shape
    rows, cols = np.nonzero(w)
    order = np.lexsort((cols, rows))
    rows, cols = rows[order], cols[order]
    g = w[rows, cols].astype(np.float32)
    row_start = np.zeros(n_pre + 1, np.int32)
    np.add.at(row_start, rows + 1, 1)
    row_start = np.cumsum(row_start).astype(np.int32)
    return CSRSynapses(
        g=jnp.asarray(g), post_ind=jnp.asarray(cols.astype(np.int32)),
        row_start=jnp.asarray(row_start),
        row_of_nz=jnp.asarray(rows.astype(np.int32)), n_post=n_post)


def dense_to_ell(w: np.ndarray, max_conn: int | None = None) -> ELLSynapses:
    w = np.asarray(w)
    n_pre, n_post = w.shape
    counts = (w != 0).sum(axis=1)
    k = int(counts.max()) if max_conn is None else int(max_conn)
    k = max(k, 1)
    g = np.zeros((n_pre, k), np.float32)
    idx = np.zeros((n_pre, k), np.int32)
    valid = np.zeros((n_pre, k), bool)
    for i in range(n_pre):
        cols = np.nonzero(w[i])[0][:k]
        g[i, : len(cols)] = w[i, cols]
        idx[i, : len(cols)] = cols
        valid[i, : len(cols)] = True
    return ELLSynapses(g=jnp.asarray(g), post_ind=jnp.asarray(idx),
                       valid=jnp.asarray(valid), n_post=n_post)


def csr_to_dense(s: CSRSynapses) -> jax.Array:
    w = jnp.zeros((s.n_pre, s.n_post), s.g.dtype)
    return w.at[s.row_of_nz, s.post_ind].add(s.g)


def ell_to_dense(s: ELLSynapses) -> jax.Array:
    w = jnp.zeros((s.n_pre, s.n_post), s.g.dtype)
    rows = jnp.arange(s.n_pre)[:, None] * jnp.ones_like(s.post_ind)
    vals = jnp.where(s.valid, s.g, 0.0)
    return w.at[rows.reshape(-1), s.post_ind.reshape(-1)].add(
        vals.reshape(-1))


# ---------------------------------------------------------------------------
# Connectivity initializers as data (GeNN's InitSparseConnectivitySnippet).
# A ConnectivityInit is a declarative, seedable description of the synapse
# graph; `resolve` materializes it as an ELL triple at model-build time.
# All randomness comes from the passed rng, so the same seed reproduces the
# same graph.  weight_fn has the repo-wide signature (rng, shape) -> array.
# ---------------------------------------------------------------------------

_Triple = Tuple[np.ndarray, np.ndarray, np.ndarray]  # post_ind, g, valid


# ---------------------------------------------------------------------------
# Backend-dual weight initializers (GeNN's InitVarSnippet).  Each one is
# callable with the repo-wide numpy protocol (rng, shape) -> array, so it
# drops into every existing host-side path unchanged, and additionally
# carries a `device(key, shape)` jax path so the same declaration can be
# resolved on-accelerator by repro.sparse.device_init.  Raw lambdas remain
# valid for host-only builds; device builds require one of these (or a
# scalar), because a numpy closure cannot be traced under jit.
# ---------------------------------------------------------------------------

class WeightSnippet:
    """Base class for dual-backend (numpy + jax) weight initializers."""

    def __call__(self, rng: np.random.Generator, shape) -> np.ndarray:
        raise NotImplementedError

    def device(self, key: jax.Array, shape) -> jax.Array:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class ConstantWeight(WeightSnippet):
    value: float = 1.0

    def __call__(self, rng, shape) -> np.ndarray:
        return np.full(shape, self.value, np.float32)

    def device(self, key, shape) -> jax.Array:
        return jnp.full(shape, self.value, jnp.float32)


@dataclasses.dataclass(frozen=True)
class UniformWeight(WeightSnippet):
    """U(lo, hi) scaled draws.  `lo + (hi - lo) * u` with u = rng.random —
    for lo = 0 this is bit-identical to the historical `hi * rng.random`
    lambdas (including negative hi for inhibitory weights)."""

    lo: float = 0.0
    hi: float = 1.0

    def __call__(self, rng, shape) -> np.ndarray:
        return (self.lo + (self.hi - self.lo) * rng.random(shape)).astype(
            np.float32)

    def device(self, key, shape) -> jax.Array:
        return _uniform_affine_draw(key, tuple(shape), self.lo, self.hi)


@dataclasses.dataclass(frozen=True)
class NormalWeight(WeightSnippet):
    mean: float = 0.0
    std: float = 1.0

    def __call__(self, rng, shape) -> np.ndarray:
        return (self.mean + self.std * rng.standard_normal(shape)).astype(
            np.float32)

    def device(self, key, shape) -> jax.Array:
        return _normal_affine_draw(key, tuple(shape), self.mean, self.std)


# ---------------------------------------------------------------------------
# Backend-dual per-synapse delay initializers (GeNN's dendritic-delay model:
# each synapse carries an integer delay in dt steps; the spike's weighted
# current lands in the post neuron's dendritic ring `delay` slots ahead).
# Same dual protocol as WeightSnippet: host `__call__(rng, shape)` and jax
# `device(key, shape)`, so one declaration resolves on either backend.
# `max_steps` is the *static* ring-sizing bound — known at declaration time
# so graphs never need a device round-trip to size their delay state.
# ---------------------------------------------------------------------------

class DelaySnippet:
    """Base class for dual-backend per-synapse delay initializers (steps)."""

    @property
    def max_steps(self) -> int:
        """Largest delay this snippet can emit (sizes the dendritic ring)."""
        raise NotImplementedError

    def __call__(self, rng: np.random.Generator, shape) -> np.ndarray:
        raise NotImplementedError

    def device(self, key: jax.Array, shape) -> jax.Array:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class ConstantDelay(DelaySnippet):
    """Every synapse delays its current by the same number of dt steps.

    Semantically identical to the homogeneous ``delay_steps`` shorthand, but
    materialized as a per-synapse slot — the bit-exactness bridge between the
    homogeneous fast path and heterogeneous delay initializers.
    """

    steps: int = 0

    def __post_init__(self) -> None:
        if not isinstance(self.steps, int) or self.steps < 0:
            raise ValueError(
                f"ConstantDelay steps must be a non-negative int, got "
                f"{self.steps!r}")

    @property
    def max_steps(self) -> int:
        return self.steps

    def __call__(self, rng, shape) -> np.ndarray:
        return np.full(shape, self.steps, np.int32)

    def device(self, key, shape) -> jax.Array:
        return jnp.full(shape, self.steps, jnp.int32)


@dataclasses.dataclass(frozen=True)
class UniformIntDelay(DelaySnippet):
    """Per-synapse delay drawn uniformly from {lo, ..., hi} (inclusive)."""

    lo: int = 0
    hi: int = 0

    def __post_init__(self) -> None:
        if (not isinstance(self.lo, int) or not isinstance(self.hi, int)
                or self.lo < 0 or self.hi < self.lo):
            raise ValueError(
                f"UniformIntDelay requires 0 <= lo <= hi (ints), got "
                f"lo={self.lo!r} hi={self.hi!r}")

    @property
    def max_steps(self) -> int:
        return self.hi

    def __call__(self, rng, shape) -> np.ndarray:
        return rng.integers(self.lo, self.hi + 1, size=shape).astype(np.int32)

    def device(self, key, shape) -> jax.Array:
        return jax.random.randint(key, shape, self.lo, self.hi + 1,
                                  jnp.int32)


def _weights(rng: np.random.Generator, shape, weight_fn) -> np.ndarray:
    if weight_fn is None:
        return np.ones(shape, np.float32)
    return np.asarray(weight_fn(rng, shape)).astype(np.float32)


@dataclasses.dataclass(frozen=True)
class ConnectivityInit:
    """Base class; subclasses fill a [n_pre, K] ELL triple."""

    def resolve(self, rng: np.random.Generator, n_pre: int, n_post: int,
                weight_fn=None) -> _Triple:
        raise NotImplementedError

    def describe(self) -> str:
        return type(self).__name__


@dataclasses.dataclass(frozen=True)
class FixedFanout(ConnectivityInit):
    """Exactly n_conn random targets per pre neuron (paper's construction)."""

    n_conn: int

    def resolve(self, rng, n_pre, n_post, weight_fn=None) -> _Triple:
        post, g = fixed_fanout_connectivity(rng, n_pre, n_post, self.n_conn,
                                            weight_fn)
        return post, g, np.ones_like(post, bool)

    def describe(self) -> str:
        return f"FixedFanout(n_conn={self.n_conn})"


@dataclasses.dataclass(frozen=True)
class FixedProbability(ConnectivityInit):
    """Each (pre, post) pair connected independently with probability p."""

    p: float

    def resolve(self, rng, n_pre, n_post, weight_fn=None) -> _Triple:
        if not 0.0 <= self.p <= 1.0:
            raise ValueError(f"FixedProbability p={self.p} outside [0, 1]")
        # O(nnz + n_post) memory — never a dense n_pre*n_post mask, which
        # would OOM at the scalability-study sizes (Generator.choice with
        # size << n_post also keeps the per-row draw cheap): per-row degree
        # is Binomial(n_post, p) and membership uniform without
        # replacement — exactly the per-pair Bernoulli model, marginalized.
        counts = rng.binomial(n_post, self.p, size=n_pre)
        k = max(int(counts.max(initial=0)), 1)
        post = np.zeros((n_pre, k), np.int32)
        valid = np.arange(k)[None, :] < counts[:, None]
        for i in range(n_pre):
            cols = np.sort(rng.choice(n_post, size=counts[i],
                                      replace=False))
            post[i, : counts[i]] = cols
        g = np.where(valid, _weights(rng, (n_pre, k), weight_fn), 0.0)
        return post, g.astype(np.float32), valid

    def describe(self) -> str:
        return f"FixedProbability(p={self.p})"


@dataclasses.dataclass(frozen=True)
class OneToOne(ConnectivityInit):
    """Neuron i connects to neuron i; requires equal population sizes."""

    def resolve(self, rng, n_pre, n_post, weight_fn=None) -> _Triple:
        if n_pre != n_post:
            raise ValueError(
                f"OneToOne requires n_pre == n_post, got {n_pre} != {n_post}")
        post = np.arange(n_pre, dtype=np.int32)[:, None]
        g = _weights(rng, (n_pre, 1), weight_fn)
        return post, g, np.ones_like(post, bool)


@dataclasses.dataclass(frozen=True)
class DenseInit(ConnectivityInit):
    """All-to-all connectivity (the dense matrix, in ELL form)."""

    def resolve(self, rng, n_pre, n_post, weight_fn=None) -> _Triple:
        post = np.broadcast_to(np.arange(n_post, dtype=np.int32),
                               (n_pre, n_post)).copy()
        g = _weights(rng, (n_pre, n_post), weight_fn)
        return post, g, np.ones_like(post, bool)


def triple_to_ell(post_ind: np.ndarray, g: np.ndarray, valid: np.ndarray,
                  n_post: int, delay: Optional[np.ndarray] = None,
                  ) -> ELLSynapses:
    """Device-side ELL container from a resolved connectivity triple
    (plus an optional per-synapse dendritic-delay slot)."""
    return ELLSynapses(
        g=jnp.asarray(g, jnp.float32),
        post_ind=jnp.asarray(post_ind, jnp.int32),
        valid=jnp.asarray(valid, bool), n_post=n_post,
        delay=None if delay is None else jnp.asarray(delay, jnp.int32))


def fixed_fanout_connectivity(
    rng: np.random.Generator, n_pre: int, n_post: int, n_conn: int,
    weight_fn=None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Random connectivity with exactly n_conn targets per pre neuron
    (sampled without replacement) — the paper's construction for both
    benchmark networks.  Returns (post_ind[n_pre, n_conn], g[n_pre, n_conn]).
    """
    if n_conn > n_post:
        raise ValueError(f"n_conn={n_conn} > n_post={n_post}")
    post = np.empty((n_pre, n_conn), np.int32)
    for i in range(n_pre):
        post[i] = rng.choice(n_post, size=n_conn, replace=False)
    if weight_fn is None:
        g = np.ones((n_pre, n_conn), np.float32)
    else:
        g = weight_fn(rng, (n_pre, n_conn)).astype(np.float32)
    return post, g
