"""Synaptic propagation ops over the sparse/dense representations.

`accumulate_*` computes the post-synaptic current vector
    I_post[j] = sum_i spike[i] * g[i, j]
for one step, which is the inner loop the paper's GPU kernels optimize.

The jnp implementations here are the *reference semantics*; the Pallas TPU
kernel lives in repro.kernels.ell_spmv and is validated against these.
`accumulate_auto` picks sparse vs dense per the paper's memory model.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sparse.formats import (
    CSRSynapses, ELLSynapses, choose_representation,
)

__all__ = [
    "accumulate_dense", "accumulate_csr", "accumulate_ell",
    "accumulate_ell_compacted", "accumulate_auto",
]


def accumulate_dense(w: jax.Array, spikes: jax.Array) -> jax.Array:
    """I = spikes @ W with W: [n_pre, n_post]."""
    return jnp.asarray(spikes, w.dtype) @ w


def accumulate_csr(s: CSRSynapses, spikes: jax.Array) -> jax.Array:
    """Scatter-add over non-zeros; row_of_nz avoids a serial row walk."""
    contrib = s.g * jnp.asarray(spikes, s.g.dtype)[s.row_of_nz]
    return jnp.zeros((s.n_post,), s.g.dtype).at[s.post_ind].add(contrib)


def accumulate_ell(s: ELLSynapses, spikes: jax.Array) -> jax.Array:
    contrib = s.g * jnp.where(s.valid, 1.0, 0.0)
    contrib = contrib * jnp.asarray(spikes, s.g.dtype)[:, None]
    return jnp.zeros((s.n_post,), s.g.dtype).at[
        s.post_ind.reshape(-1)].add(contrib.reshape(-1))


def accumulate_ell_compacted(
    s: ELLSynapses, spikes: jax.Array, max_active: int,
) -> jax.Array:
    """Spike-list path: TPU-idiomatic stream compaction via top_k.

    GeNN compacts spikes into a list with warp ballots + atomics; the TPU
    equivalent bounds the active set at `max_active` and gathers only those
    rows.  Exact when #spikes <= max_active (overflow drops the smallest
    indices — callers size max_active from the target rate band).
    """
    spk = jnp.asarray(spikes, jnp.float32)
    vals, rows = jax.lax.top_k(spk, max_active)  # active pre-neurons
    g = s.g[rows] * jnp.where(s.valid[rows], 1.0, 0.0) * vals[:, None]
    idx = s.post_ind[rows]
    return jnp.zeros((s.n_post,), s.g.dtype).at[idx.reshape(-1)].add(
        g.reshape(-1))


def accumulate_auto(rep_sparse: ELLSynapses, w_dense: jax.Array | None,
                    spikes: jax.Array) -> jax.Array:
    """Representation choice from the paper's eq (1)/(2) memory model."""
    n_pre, n_post = rep_sparse.n_pre, rep_sparse.n_post
    nnz = int(rep_sparse.max_conn) * n_pre
    if w_dense is not None and choose_representation(
            n_pre, n_post, nnz) == "dense":
        return accumulate_dense(w_dense, spikes)
    return accumulate_ell(rep_sparse, spikes)
