"""Device-resident connectivity construction (the build-time hot path).

The host-side initializers in `repro.sparse.formats` materialize the synapse
graph with a python loop over pre-neuron rows — at the paper's scalability-
study sizes the *construction*, not the step loop, becomes the ceiling
(minutes of host time and host RAM for graphs whose simulation step is
milliseconds).  Following "Runtime Construction of Large-Scale Spiking
Neuronal Network Models on GPU Devices" (Golosio et al., 2023), this module
generates connectivity *on device, in parallel*, emitting `ELLSynapses`
directly in O(nnz) memory.

Design rules:

* **Counter-based randomness.**  Every row draws from
  ``fold_in(base_key, global_row_index)`` — a pure function of (seed, row).
  The graph is therefore bit-deterministic for a fixed seed and *identical*
  regardless of device count or row chunking: generating rows [0, n) in one
  call equals concatenating any partition of the rows (`rows=` argument).
* **O(nnz) memory.**  Fixed-fanout sampling without replacement uses a
  dedup-redraw loop over the K slots (exactly the "collect first K distinct
  values of an iid stream" construction of a uniform K-subset), never a
  dense [n_pre, n_post] mask.  Only when K > n_post/2 — where O(n_post) per
  row *is* O(K) — does it switch to a per-row top-k permutation.
* **Same declarations.**  The dispatcher `device_resolve` consumes the very
  same `ConnectivityInit` dataclasses the host path uses; weights come from
  the dual-backend `WeightSnippet`s (scalars and None also work).

`partition_ell_by_post` repacks a built ELL into post-sharded per-device
blocks for the sharded engine (`repro.core.snn.engine`): slot (i, k) goes to
the shard owning post neuron post_ind[i, k], compacted to K_local slots with
the original slot order preserved (so scatter-accumulation order — and hence
bit-exact currents — is preserved per post neuron).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import snn_axis
from repro.obs import trace
from repro.sparse import formats as F

__all__ = [
    "device_resolve", "device_fixed_fanout", "device_fixed_probability",
    "device_one_to_one", "device_dense", "partition_ell_by_post",
    "as_device_weight", "as_device_delay", "device_delays",
    "device_init_local", "LocalInitPlan", "construction_peak_model",
]

_JTriple = Tuple[jax.Array, jax.Array, jax.Array]  # post_ind, g, valid

_MAX_REDRAW_ROUNDS = 64  # residual-duplicate probability < 2**-64 per slot


# ---------------------------------------------------------------------------
# weights
# ---------------------------------------------------------------------------

def as_device_weight(weight) -> F.WeightSnippet:
    """Normalize a ModelSpec weight declaration to a device-capable snippet.

    None -> ConstantWeight(1); scalars -> ConstantWeight(x); WeightSnippet
    passes through.  Raw numpy callables cannot be traced under jit — raise
    with the fix spelled out.
    """
    if weight is None:
        return F.ConstantWeight(1.0)
    if isinstance(weight, F.WeightSnippet):
        return weight
    if isinstance(weight, (int, float)):
        return F.ConstantWeight(float(weight))
    raise TypeError(
        f"device-side construction needs a dual-backend weight initializer "
        f"(ConstantWeight / UniformWeight / NormalWeight, or a scalar), got "
        f"{weight!r}; host-only numpy callables cannot run under jit — "
        "declare the weight as a WeightSnippet or build with init='host'")


def as_device_delay(delay) -> F.DelaySnippet:
    """Normalize a delay declaration to a device-capable snippet.

    Ints -> ConstantDelay(x); DelaySnippet passes through.  Raw numpy
    callables cannot be traced under jit — raise with the fix spelled out.
    """
    if isinstance(delay, F.DelaySnippet):
        return delay
    if isinstance(delay, int) and not isinstance(delay, bool):
        return F.ConstantDelay(delay)
    raise TypeError(
        f"device-side construction needs a dual-backend delay initializer "
        f"(ConstantDelay / UniformIntDelay, or an int), got {delay!r}; "
        "host-only numpy callables cannot run under jit — declare the delay "
        "as a DelaySnippet or build with init='host'")


def _row_keys(key: jax.Array, rows: jax.Array) -> jax.Array:
    return jax.vmap(lambda r: jax.random.fold_in(key, r))(rows)


def _row_weights(weight: F.WeightSnippet, key: jax.Array, rows: jax.Array,
                 k: int) -> jax.Array:
    """Per-row keyed weight draws: w[r] depends only on (seed, global row)."""
    wkey = jax.random.fold_in(key, 0x5EED)
    return jax.vmap(lambda rk: weight.device(rk, (k,)))(_row_keys(wkey, rows))


def device_delays(key: jax.Array, n_pre: int, k: int, delay,
                  rows: Optional[jax.Array] = None) -> jax.Array:
    """[len(rows), k] int32 per-synapse dendritic delays, generated on
    device with the same counter-based key schedule as connectivity and
    weights: row r draws from fold_in(fold_in(key, 0xDE1A), r), a pure
    function of (seed, global row) — so the delay matrix is seed-
    deterministic and independent of device count or row chunking."""
    snip = as_device_delay(delay)
    rows = _rows_or_default(rows, n_pre)
    dkey = jax.random.fold_in(key, 0xDE1A)
    return jax.vmap(lambda rk: snip.device(rk, (k,)))(
        _row_keys(dkey, rows)).astype(jnp.int32)


# ---------------------------------------------------------------------------
# distinct sampling: k targets per row, uniform without replacement
# ---------------------------------------------------------------------------

def _distinct_topk(rk: jax.Array, n_post: int, k: int) -> jax.Array:
    """Uniform k-subset via the k smallest of n_post iid uniforms.
    O(n_post) per row — used only when k > n_post/2, where that *is* O(k)."""
    u = jax.random.uniform(rk, (n_post,))
    _, idx = jax.lax.top_k(-u, k)
    return jnp.sort(idx.astype(jnp.int32))


def _distinct_redraw(rk: jax.Array, n_post: int, k: int) -> jax.Array:
    """Uniform k-subset in O(k) memory: draw k iid values, redraw duplicate
    slots with fresh counters until all distinct.  Keeping first occurrences
    and redrawing the rest is exactly "first k distinct values of an iid
    uniform stream" — i.e. sequential sampling without replacement."""

    def dup_mask(sorted_vals):
        return jnp.concatenate([jnp.zeros((1,), bool),
                                sorted_vals[1:] == sorted_vals[:-1]])

    def cond(carry):
        i, _, has_dup = carry
        return has_dup & (i < _MAX_REDRAW_ROUNDS)

    def body(carry):
        i, vals, _ = carry
        fresh = jax.random.randint(jax.random.fold_in(rk, i), (k,), 0,
                                   n_post, jnp.int32)
        vals = jnp.sort(jnp.where(dup_mask(vals), fresh, vals))
        return i + 1, vals, dup_mask(vals).any()

    v0 = jnp.sort(jax.random.randint(jax.random.fold_in(rk, 0), (k,), 0,
                                     n_post, jnp.int32))
    _, vals, _ = jax.lax.while_loop(cond, body, (1, v0, dup_mask(v0).any()))
    return vals


@functools.partial(jax.jit, static_argnames=("n_post", "k"))
def _sample_distinct_rows(key: jax.Array, rows: jax.Array, n_post: int,
                          k: int) -> jax.Array:
    """[len(rows), k] int32, each row a uniform k-subset of [0, n_post),
    sorted ascending, keyed by the *global* row index."""
    if k > n_post:
        raise ValueError(f"k={k} > n_post={n_post}")
    if k == n_post:
        return jnp.broadcast_to(jnp.arange(n_post, dtype=jnp.int32),
                                (rows.shape[0], n_post))
    rks = _row_keys(key, rows)
    one = _distinct_topk if k > n_post // 2 else _distinct_redraw
    return jax.vmap(lambda rk: one(rk, n_post, k))(rks)


# ---------------------------------------------------------------------------
# initializer kernels
# ---------------------------------------------------------------------------

def _rows_or_default(rows, n_pre: int) -> jax.Array:
    if rows is None:
        return jnp.arange(n_pre, dtype=jnp.int32)
    return jnp.asarray(rows, jnp.int32)


def device_fixed_fanout(key: jax.Array, n_pre: int, n_post: int,
                        n_conn: int, weight=None,
                        rows: Optional[jax.Array] = None) -> _JTriple:
    """Exactly n_conn distinct random targets per pre row, on device."""
    rows = _rows_or_default(rows, n_pre)
    post = _sample_distinct_rows(jax.random.fold_in(key, 0xC0), rows,
                                 n_post, n_conn)
    g = _row_weights(as_device_weight(weight), key, rows, n_conn)
    return post, g.astype(jnp.float32), jnp.ones_like(post, bool)


def _binomial_slots(n_post: int, p: float) -> int:
    """Static slot count covering Binomial(n_post, p) row degrees: mean plus
    six standard deviations (residual clamp probability < 1e-9 per row)."""
    mean = n_post * p
    std = math.sqrt(max(n_post * p * (1.0 - p), 0.0))
    return int(min(n_post, max(1, math.ceil(mean + 6.0 * std + 1.0))))


@functools.partial(jax.jit, static_argnames=("n_post", "k"))
def _fixed_probability_rows(
    key: jax.Array, rows: jax.Array, n_post: int, p: float, k: int,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """(post [R, k], counts [R], overflow [R] bool): per-row
    Binomial(n_post, p) degrees, then a uniform degree-subset of targets (a
    k-subset randomly permuted, first `count` slots valid) — the per-pair
    Bernoulli model, marginalized.  A raw degree draw above the static slot
    padding `k` is clamped, and the row is flagged in `overflow` so callers
    can surface the clamp instead of silently dropping synapses."""
    ckey = jax.random.fold_in(key, 0xDE)

    def one(rk):
        raw = jax.random.binomial(jax.random.fold_in(rk, 1), n_post,
                                  p).astype(jnp.int32)
        cnt = jnp.clip(raw, 0, k)
        vals = (_distinct_topk if k > n_post // 2 else _distinct_redraw)(
            jax.random.fold_in(rk, 2), n_post, k)
        perm = jnp.argsort(
            jax.random.uniform(jax.random.fold_in(rk, 3), (k,)))
        return vals[perm], cnt, raw > k

    return jax.vmap(one)(_row_keys(ckey, rows))


def _report_overflow(n_rows, *, n_pre: int, n_post: int, p: float,
                     k: int) -> None:
    """Surface clamped FixedProbability rows through the trace timeline.

    Under jit/shard_map `n_rows` is a tracer — the count cannot be read at
    trace time, so reporting is skipped here and done by the caller that owns
    the host sync (`device_init_local` reports from its count pass)."""
    if isinstance(n_rows, jax.core.Tracer):
        return
    n = int(jax.device_get(n_rows))
    if n > 0:
        trace.instant("device_init.overflow", kind="fixed_probability",
                      rows_clamped=n, rows=n_pre, n_post=n_post, p=float(p),
                      max_k=k)


def device_fixed_probability(key: jax.Array, n_pre: int, n_post: int,
                             p: float, weight=None,
                             rows: Optional[jax.Array] = None) -> _JTriple:
    """Each (pre, post) pair connected independently with probability p."""
    if not 0.0 <= p <= 1.0:
        raise ValueError(f"FixedProbability p={p} outside [0, 1]")
    rows = _rows_or_default(rows, n_pre)
    k = _binomial_slots(n_post, p)
    post, counts, over = _fixed_probability_rows(key, rows, n_post, p, k)
    _report_overflow(jnp.sum(over.astype(jnp.int32)), n_pre=n_pre,
                     n_post=n_post, p=p, k=k)
    valid = jnp.arange(k, dtype=jnp.int32)[None, :] < counts[:, None]
    g = _row_weights(as_device_weight(weight), key, rows, k)
    g = jnp.where(valid, g, 0.0).astype(jnp.float32)
    return jnp.where(valid, post, 0).astype(jnp.int32), g, valid


def device_one_to_one(key: jax.Array, n_pre: int, n_post: int, weight=None,
                      rows: Optional[jax.Array] = None) -> _JTriple:
    if n_pre != n_post:
        raise ValueError(
            f"OneToOne requires n_pre == n_post, got {n_pre} != {n_post}")
    rows = _rows_or_default(rows, n_pre)
    post = rows[:, None]
    g = _row_weights(as_device_weight(weight), key, rows, 1)
    return post, g.astype(jnp.float32), jnp.ones_like(post, bool)


def device_dense(key: jax.Array, n_pre: int, n_post: int, weight=None,
                 rows: Optional[jax.Array] = None) -> _JTriple:
    rows = _rows_or_default(rows, n_pre)
    post = jnp.broadcast_to(jnp.arange(n_post, dtype=jnp.int32),
                            (rows.shape[0], n_post))
    g = _row_weights(as_device_weight(weight), key, rows, n_post)
    return post, g.astype(jnp.float32), jnp.ones_like(post, bool)


def device_resolve(connect: F.ConnectivityInit, key: jax.Array, n_pre: int,
                   n_post: int, weight=None,
                   rows: Optional[jax.Array] = None) -> _JTriple:
    """Dispatch a ConnectivityInit declaration to its device kernel."""
    if isinstance(connect, F.FixedFanout):
        return device_fixed_fanout(key, n_pre, n_post, connect.n_conn,
                                   weight, rows)
    if isinstance(connect, F.FixedProbability):
        return device_fixed_probability(key, n_pre, n_post, connect.p,
                                        weight, rows)
    if isinstance(connect, F.OneToOne):
        return device_one_to_one(key, n_pre, n_post, weight, rows)
    if isinstance(connect, F.DenseInit):
        return device_dense(key, n_pre, n_post, weight, rows)
    raise NotImplementedError(
        f"no device-side kernel for {connect.describe()}; build with "
        "init='host' or add a kernel to repro.sparse.device_init")


# ---------------------------------------------------------------------------
# post-sharding: repack a built ELL into per-device blocks
# ---------------------------------------------------------------------------

def _shard_counts(post_ind: jax.Array, valid: jax.Array, n_shards: int,
                  shard_size: int) -> jax.Array:
    """[rows, n_shards] int32 slot counts per (pre row, post shard).

    Computed from the sorted shard ids via searchsorted boundaries:
    O(rows * D log K), never an [rows, K, D] one-hot temporary (which would
    be O(nnz * D) — the very blowup this module exists to avoid).  Every op
    is per-row independent, so counts over any row chunk equal the matching
    rows of the full-matrix call — the property `device_init_local` leans on.
    """
    shard = jnp.where(valid, post_ind // shard_size, n_shards)
    shard_s = jnp.sort(shard, axis=1)
    bounds = jnp.arange(n_shards + 1, dtype=shard_s.dtype)
    edges = jax.vmap(
        lambda row: jnp.searchsorted(row, bounds, side="left"))(shard_s)
    return jnp.diff(edges, axis=1)


def _partition_rows(
    g: jax.Array, post_ind: jax.Array, valid: jax.Array,
    delay: Optional[jax.Array], n_shards: int, shard_size: int, k_local: int,
) -> Tuple[jax.Array, jax.Array, jax.Array, Optional[jax.Array]]:
    """Repack ELL rows into [n_shards, rows, k_local] post-shard blocks.

    Slot (i, k) goes to the shard owning post neuron post_ind[i, k],
    compacted left and re-indexed to shard-local post ids; the within-row
    slot order is preserved (stable argsort), so per-post-neuron scatter
    accumulation order — and hence bit-exact currents — matches the input
    slot order.  All ops are per-row independent: partitioning a chunk of
    rows bit-matches the corresponding rows of a full-matrix partition.
    """
    n_rows, k = g.shape
    shard = jnp.where(valid, post_ind // shard_size, n_shards)
    order = jnp.argsort(shard, axis=1)            # stable in jax
    shard_s = jnp.take_along_axis(shard, order, axis=1)
    post_s = jnp.take_along_axis(post_ind, order, axis=1)
    g_s = jnp.take_along_axis(jnp.where(valid, g, 0.0), order, axis=1)
    delay_s = (None if delay is None else jnp.take_along_axis(
        jnp.where(valid, delay, 0), order, axis=1))
    bounds = jnp.arange(n_shards + 1, dtype=shard_s.dtype)
    edges = jax.vmap(
        lambda row: jnp.searchsorted(row, bounds, side="left"))(shard_s)
    counts = jnp.diff(edges, axis=1)              # [n_rows, n_shards]
    start = jnp.concatenate(
        [jnp.zeros((n_rows, 1), counts.dtype),
         jnp.cumsum(counts, axis=1)[:, :-1]], axis=1)   # exclusive prefix
    d_idx = shard_s                                # [n_rows, k]
    slot = jnp.arange(k, dtype=jnp.int32)[None, :] - jnp.take_along_axis(
        start, jnp.clip(d_idx, 0, n_shards - 1), axis=1)
    row = jnp.broadcast_to(jnp.arange(n_rows)[:, None], (n_rows, k))
    shape = (n_shards, n_rows, k_local)
    # invalid slots carry d_idx == n_shards -> dropped by the OOB mode
    g_out = jnp.zeros(shape, jnp.float32).at[d_idx, row, slot].set(
        g_s, mode="drop")
    post_out = jnp.zeros(shape, jnp.int32).at[d_idx, row, slot].set(
        (post_s - d_idx * shard_size).astype(jnp.int32), mode="drop")
    valid_out = jnp.zeros(shape, bool).at[d_idx, row, slot].set(
        shard_s < n_shards, mode="drop")
    delay_out = (None if delay_s is None
                 else jnp.zeros(shape, jnp.int32).at[d_idx, row, slot].set(
                     delay_s.astype(jnp.int32), mode="drop"))
    return g_out, post_out, valid_out, delay_out


def partition_ell_by_post(
    ell: F.ELLSynapses, n_shards: int,
) -> Tuple[jax.Array, jax.Array, jax.Array, Optional[jax.Array], int, int]:
    """Split an ELL column-wise into `n_shards` post-neuron shards.

    Returns (g, post_local, valid, delay_local, shard_size, k_local) with
    the array outputs shaped [n_shards, n_pre, k_local]: shard d holds, for
    every pre row, the slots whose post neuron lives in
    [d*shard_size, (d+1)*shard_size), compacted left and re-indexed to
    shard-local post ids.  The within-row slot order is preserved (stable
    sort), so per-post-neuron scatter accumulation order — and hence
    bit-exact currents — matches the global ELL.  The per-synapse dendritic
    delay slot (when present) rides along through the identical permutation;
    delay_local is None for delay-free ELLs.  Total memory across shards
    ~= nnz (k_local ~= K / n_shards).

    This materializes the *full* ELL first — every device pays O(nnz).  For
    builds where that does not fit, `device_init_local` fuses generation and
    partitioning per device at O(nnz / n_devices) peak, bit-exactly.
    """
    n_post = ell.n_post
    shard_size = -(-n_post // n_shards)  # ceil
    counts = _shard_counts(ell.post_ind, ell.valid, n_shards, shard_size)
    k_local = max(1, int(counts.max()))           # build-time host sync
    g_out, post_out, valid_out, delay_out = _partition_rows(
        ell.g, ell.post_ind, ell.valid, ell.delay, n_shards, shard_size,
        k_local)
    return g_out, post_out, valid_out, delay_out, shard_size, k_local


# ---------------------------------------------------------------------------
# fused local construction: generate only the rows you own, partition in
# place, exchange slots — peak memory O(nnz / device)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LocalInitPlan:
    """Everything `device_init_local` needs to rebuild one synapse group's
    post-sharded blocks without the full ELL: the declaration, its key, and
    the generation-space geometry.  `n_post_total` is the *generation* post
    space (the concatenated post-population window); `post_window` restricts
    to one concrete group's [lo, hi) slice of it (None = the whole space)."""
    connect: F.ConnectivityInit
    key: jax.Array
    n_pre: int
    n_post_total: int
    weight: object = None
    delay: object = None
    post_window: Optional[Tuple[int, int]] = None


def _fp_row_overflow(key: jax.Array, rows: jax.Array, n_post: int,
                     p: float) -> jax.Array:
    """[rows] int32 flags: FixedProbability rows whose raw Binomial degree
    draw exceeds the static ELL slot padding (mirrors the key schedule of
    `_fixed_probability_rows` without materializing targets)."""
    k = _binomial_slots(n_post, p)
    ckey = jax.random.fold_in(key, 0xDE)

    def one(rk):
        raw = jax.random.binomial(jax.random.fold_in(rk, 1), n_post,
                                  p).astype(jnp.int32)
        return (raw > k).astype(jnp.int32)

    return jax.vmap(one)(_row_keys(ckey, rows))


def device_init_local(
    connect: F.ConnectivityInit, key: jax.Array, n_pre: int, n_post: int,
    mesh, weight=None, delay=None, axis: Optional[str] = None,
    post_window: Optional[Tuple[int, int]] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array, Optional[jax.Array], int, int]:
    """Fused `device_resolve` + `partition_ell_by_post` under `shard_map`.

    Each device generates *only* its own ceil(n_pre / D) pre rows (via the
    counter-based `rows=` argument, so the draws bit-match a global
    generation), partitions them into post-shard blocks locally, and trades
    slots with an `all_to_all` — no device ever materializes the full ELL,
    so peak construction memory is O(nnz / device) instead of O(nnz).

    Returns (g, post_local, valid, delay_local, shard_size, k_local) with
    arrays shaped [n_shards, n_pre, k_local] exactly like
    `partition_ell_by_post` (sharded along axis 0 over the mesh) and
    bit-identical to the generate-then-partition path at any device count.

    `n_post` is the total generation post space; `post_window=(lo, hi)`
    restricts the output to one post-population window of it (matching the
    multi-post-population split in `ModelSpec._build`).
    """
    axis = snn_axis(mesh) if axis is None else axis
    D = int(mesh.shape[axis])
    if post_window is None:
        lo, hi = 0, int(n_post)
    else:
        lo, hi = int(post_window[0]), int(post_window[1])
    n_local_post = hi - lo
    shard_size = -(-n_local_post // D)   # == engine's per-device post shard
    R = -(-n_pre // D)                   # padded pre rows per device
    has_delay = delay is not None
    is_fp = isinstance(connect, F.FixedProbability)

    def _generate(k):
        """This device's row chunk, masked to the post window.  Rows past
        n_pre (pre-axis padding) are generated then invalidated — their
        draws never reach the output, so padding cannot break exactness."""
        d = jax.lax.axis_index(axis)
        rows = d * R + jnp.arange(R, dtype=jnp.int32)
        post, g, valid = device_resolve(connect, k, n_pre, n_post, weight,
                                        rows=rows)
        valid = valid & (rows < n_pre)[:, None]
        dd = None
        if has_delay:
            dd = device_delays(k, n_pre, post.shape[1], delay, rows=rows)
            dd = jnp.where(valid, dd, 0).astype(jnp.int32)
        if post_window is not None:
            mask = (post >= lo) & (post < hi) & valid
            post = jnp.where(mask, post - lo, 0).astype(jnp.int32)
            g = jnp.where(mask, g, 0.0).astype(jnp.float32)
            dd = None if dd is None else jnp.where(mask, dd, 0)
            valid = mask
        return rows, post, g, valid, dd

    def count_fn(k):
        rows, post, _, valid, _ = _generate(k)
        counts = _shard_counts(post, valid, D, shard_size)
        # reduce across the axis so the outputs are replicated: in a
        # multi-host mesh each process can only read its own shards, but
        # every process needs the same k_local to build the same program
        kmax = jax.lax.pmax(jnp.max(counts).astype(jnp.int32), axis)
        if is_fp:
            over = _fp_row_overflow(k, rows, n_post, connect.p)
            osum = jax.lax.psum(
                jnp.sum(jnp.where(rows < n_pre, over, 0)), axis)
        else:
            osum = jnp.zeros((), jnp.int32)
        return kmax.reshape(1), osum.reshape(1)

    counted = jax.jit(shard_map(
        count_fn, mesh=mesh, in_specs=(P(),),
        out_specs=(P(), P()), check_rep=False))(key)
    k_local = max(1, int(jax.device_get(counted[0])[0]))
    if is_fp:
        overflow = int(jax.device_get(counted[1])[0])
        if overflow > 0:
            trace.instant("device_init.overflow", kind="fixed_probability",
                          rows_clamped=overflow, rows=n_pre, n_post=n_post,
                          p=float(connect.p),
                          max_k=_binomial_slots(n_post, connect.p))

    def fill_fn(k):
        _, post, g, valid, dd = _generate(k)
        parts = _partition_rows(g, post, valid, dd, D, shard_size, k_local)
        out = []
        for arr in parts:
            if arr is None:
                continue
            # [D, R, kl] where [s] = slots for shard s from this device's
            # rows; all_to_all makes [s] = device s's rows for *this* shard,
            # so the reshape recovers global row order for the local block
            blk = jax.lax.all_to_all(arr, axis, split_axis=0, concat_axis=0)
            out.append(blk.reshape(D * R, k_local)[None])
        return tuple(out)

    n_out = 4 if has_delay else 3
    outs = jax.jit(shard_map(
        fill_fn, mesh=mesh, in_specs=(P(),),
        out_specs=tuple(P(axis, None, None) for _ in range(n_out)),
        check_rep=False))(key)
    g_out = outs[0][:, :n_pre]
    post_out = outs[1][:, :n_pre]
    valid_out = outs[2][:, :n_pre]
    delay_out = outs[3][:, :n_pre] if has_delay else None
    return g_out, post_out, valid_out, delay_out, shard_size, k_local


def construction_peak_model(n_pre: int, k: int, n_devices: int, k_local: int,
                            has_delay: bool = False) -> dict:
    """Analytic peak construction bytes per device for one synapse group:
    generate-then-partition (every device materializes the full [n_pre, k]
    ELL plus sort temporaries plus the full [D, n_pre, k_local] block stack)
    vs. the fused local path (only ceil(n_pre / D) rows resident, plus the
    partitioned blocks, their all_to_all receive buffer, and the final
    block).  Used by `ModelSpec.plan` and the scaling bench — the fused
    number is the O(nnz / device) claim, stated in bytes."""
    slot_b = F.ell_slot_bytes(has_delay)
    # argsort order (i4) + sorted shard ids (i4) + sorted copies of each slot
    # array: the transient working set of `_partition_rows` per source slot
    tmp_b = 8 + slot_b
    rows_local = -(-n_pre // n_devices)
    block_b = n_devices * k_local * slot_b       # [D, ., k_local] per row
    gen = n_pre * (k * (slot_b + tmp_b) + block_b)
    fused = rows_local * (k * (slot_b + tmp_b) + 3 * block_b)
    return {"generate_partition_bytes": int(gen),
            "fused_local_bytes": int(fused)}
