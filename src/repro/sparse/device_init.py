"""Device-resident connectivity construction (the build-time hot path).

The host-side initializers in `repro.sparse.formats` materialize the synapse
graph with a python loop over pre-neuron rows — at the paper's scalability-
study sizes the *construction*, not the step loop, becomes the ceiling
(minutes of host time and host RAM for graphs whose simulation step is
milliseconds).  Following "Runtime Construction of Large-Scale Spiking
Neuronal Network Models on GPU Devices" (Golosio et al., 2023), this module
generates connectivity *on device, in parallel*, emitting `ELLSynapses`
directly in O(nnz) memory.

Design rules:

* **Counter-based randomness.**  Every row draws from
  ``fold_in(base_key, global_row_index)`` — a pure function of (seed, row).
  The graph is therefore bit-deterministic for a fixed seed and *identical*
  regardless of device count or row chunking: generating rows [0, n) in one
  call equals concatenating any partition of the rows (`rows=` argument).
* **O(nnz) memory.**  Fixed-fanout sampling without replacement uses a
  dedup-redraw loop over the K slots (exactly the "collect first K distinct
  values of an iid stream" construction of a uniform K-subset), never a
  dense [n_pre, n_post] mask.  Only when K > n_post/2 — where O(n_post) per
  row *is* O(K) — does it switch to a per-row top-k permutation.
* **Same declarations.**  The dispatcher `device_resolve` consumes the very
  same `ConnectivityInit` dataclasses the host path uses; weights come from
  the dual-backend `WeightSnippet`s (scalars and None also work).

`partition_ell_by_post` repacks a built ELL into post-sharded per-device
blocks for the sharded engine (`repro.core.snn.engine`): slot (i, k) goes to
the shard owning post neuron post_ind[i, k], compacted to K_local slots with
the original slot order preserved (so scatter-accumulation order — and hence
bit-exact currents — is preserved per post neuron).
"""

from __future__ import annotations

import functools
import math
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.sparse import formats as F

__all__ = [
    "device_resolve", "device_fixed_fanout", "device_fixed_probability",
    "device_one_to_one", "device_dense", "partition_ell_by_post",
    "as_device_weight", "as_device_delay", "device_delays",
]

_JTriple = Tuple[jax.Array, jax.Array, jax.Array]  # post_ind, g, valid

_MAX_REDRAW_ROUNDS = 64  # residual-duplicate probability < 2**-64 per slot


# ---------------------------------------------------------------------------
# weights
# ---------------------------------------------------------------------------

def as_device_weight(weight) -> F.WeightSnippet:
    """Normalize a ModelSpec weight declaration to a device-capable snippet.

    None -> ConstantWeight(1); scalars -> ConstantWeight(x); WeightSnippet
    passes through.  Raw numpy callables cannot be traced under jit — raise
    with the fix spelled out.
    """
    if weight is None:
        return F.ConstantWeight(1.0)
    if isinstance(weight, F.WeightSnippet):
        return weight
    if isinstance(weight, (int, float)):
        return F.ConstantWeight(float(weight))
    raise TypeError(
        f"device-side construction needs a dual-backend weight initializer "
        f"(ConstantWeight / UniformWeight / NormalWeight, or a scalar), got "
        f"{weight!r}; host-only numpy callables cannot run under jit — "
        "declare the weight as a WeightSnippet or build with init='host'")


def as_device_delay(delay) -> F.DelaySnippet:
    """Normalize a delay declaration to a device-capable snippet.

    Ints -> ConstantDelay(x); DelaySnippet passes through.  Raw numpy
    callables cannot be traced under jit — raise with the fix spelled out.
    """
    if isinstance(delay, F.DelaySnippet):
        return delay
    if isinstance(delay, int) and not isinstance(delay, bool):
        return F.ConstantDelay(delay)
    raise TypeError(
        f"device-side construction needs a dual-backend delay initializer "
        f"(ConstantDelay / UniformIntDelay, or an int), got {delay!r}; "
        "host-only numpy callables cannot run under jit — declare the delay "
        "as a DelaySnippet or build with init='host'")


def _row_keys(key: jax.Array, rows: jax.Array) -> jax.Array:
    return jax.vmap(lambda r: jax.random.fold_in(key, r))(rows)


def _row_weights(weight: F.WeightSnippet, key: jax.Array, rows: jax.Array,
                 k: int) -> jax.Array:
    """Per-row keyed weight draws: w[r] depends only on (seed, global row)."""
    wkey = jax.random.fold_in(key, 0x5EED)
    return jax.vmap(lambda rk: weight.device(rk, (k,)))(_row_keys(wkey, rows))


def device_delays(key: jax.Array, n_pre: int, k: int, delay,
                  rows: Optional[jax.Array] = None) -> jax.Array:
    """[len(rows), k] int32 per-synapse dendritic delays, generated on
    device with the same counter-based key schedule as connectivity and
    weights: row r draws from fold_in(fold_in(key, 0xDE1A), r), a pure
    function of (seed, global row) — so the delay matrix is seed-
    deterministic and independent of device count or row chunking."""
    snip = as_device_delay(delay)
    rows = _rows_or_default(rows, n_pre)
    dkey = jax.random.fold_in(key, 0xDE1A)
    return jax.vmap(lambda rk: snip.device(rk, (k,)))(
        _row_keys(dkey, rows)).astype(jnp.int32)


# ---------------------------------------------------------------------------
# distinct sampling: k targets per row, uniform without replacement
# ---------------------------------------------------------------------------

def _distinct_topk(rk: jax.Array, n_post: int, k: int) -> jax.Array:
    """Uniform k-subset via the k smallest of n_post iid uniforms.
    O(n_post) per row — used only when k > n_post/2, where that *is* O(k)."""
    u = jax.random.uniform(rk, (n_post,))
    _, idx = jax.lax.top_k(-u, k)
    return jnp.sort(idx.astype(jnp.int32))


def _distinct_redraw(rk: jax.Array, n_post: int, k: int) -> jax.Array:
    """Uniform k-subset in O(k) memory: draw k iid values, redraw duplicate
    slots with fresh counters until all distinct.  Keeping first occurrences
    and redrawing the rest is exactly "first k distinct values of an iid
    uniform stream" — i.e. sequential sampling without replacement."""

    def dup_mask(sorted_vals):
        return jnp.concatenate([jnp.zeros((1,), bool),
                                sorted_vals[1:] == sorted_vals[:-1]])

    def cond(carry):
        i, _, has_dup = carry
        return has_dup & (i < _MAX_REDRAW_ROUNDS)

    def body(carry):
        i, vals, _ = carry
        fresh = jax.random.randint(jax.random.fold_in(rk, i), (k,), 0,
                                   n_post, jnp.int32)
        vals = jnp.sort(jnp.where(dup_mask(vals), fresh, vals))
        return i + 1, vals, dup_mask(vals).any()

    v0 = jnp.sort(jax.random.randint(jax.random.fold_in(rk, 0), (k,), 0,
                                     n_post, jnp.int32))
    _, vals, _ = jax.lax.while_loop(cond, body, (1, v0, dup_mask(v0).any()))
    return vals


@functools.partial(jax.jit, static_argnames=("n_post", "k"))
def _sample_distinct_rows(key: jax.Array, rows: jax.Array, n_post: int,
                          k: int) -> jax.Array:
    """[len(rows), k] int32, each row a uniform k-subset of [0, n_post),
    sorted ascending, keyed by the *global* row index."""
    if k > n_post:
        raise ValueError(f"k={k} > n_post={n_post}")
    if k == n_post:
        return jnp.broadcast_to(jnp.arange(n_post, dtype=jnp.int32),
                                (rows.shape[0], n_post))
    rks = _row_keys(key, rows)
    one = _distinct_topk if k > n_post // 2 else _distinct_redraw
    return jax.vmap(lambda rk: one(rk, n_post, k))(rks)


# ---------------------------------------------------------------------------
# initializer kernels
# ---------------------------------------------------------------------------

def _rows_or_default(rows, n_pre: int) -> jax.Array:
    if rows is None:
        return jnp.arange(n_pre, dtype=jnp.int32)
    return jnp.asarray(rows, jnp.int32)


def device_fixed_fanout(key: jax.Array, n_pre: int, n_post: int,
                        n_conn: int, weight=None,
                        rows: Optional[jax.Array] = None) -> _JTriple:
    """Exactly n_conn distinct random targets per pre row, on device."""
    rows = _rows_or_default(rows, n_pre)
    post = _sample_distinct_rows(jax.random.fold_in(key, 0xC0), rows,
                                 n_post, n_conn)
    g = _row_weights(as_device_weight(weight), key, rows, n_conn)
    return post, g.astype(jnp.float32), jnp.ones_like(post, bool)


def _binomial_slots(n_post: int, p: float) -> int:
    """Static slot count covering Binomial(n_post, p) row degrees: mean plus
    six standard deviations (residual clamp probability < 1e-9 per row)."""
    mean = n_post * p
    std = math.sqrt(max(n_post * p * (1.0 - p), 0.0))
    return int(min(n_post, max(1, math.ceil(mean + 6.0 * std + 1.0))))


@functools.partial(jax.jit, static_argnames=("n_post", "k"))
def _fixed_probability_rows(key: jax.Array, rows: jax.Array, n_post: int,
                            p: float, k: int) -> Tuple[jax.Array, jax.Array]:
    """(post [R, k], counts [R]): per-row Binomial(n_post, p) degrees, then a
    uniform degree-subset of targets (a k-subset randomly permuted, first
    `count` slots valid) — the per-pair Bernoulli model, marginalized."""
    ckey = jax.random.fold_in(key, 0xDE)

    def one(rk):
        cnt = jax.random.binomial(jax.random.fold_in(rk, 1), n_post,
                                  p).astype(jnp.int32)
        cnt = jnp.clip(cnt, 0, k)
        vals = (_distinct_topk if k > n_post // 2 else _distinct_redraw)(
            jax.random.fold_in(rk, 2), n_post, k)
        perm = jnp.argsort(
            jax.random.uniform(jax.random.fold_in(rk, 3), (k,)))
        return vals[perm], cnt

    return jax.vmap(one)(_row_keys(ckey, rows))


def device_fixed_probability(key: jax.Array, n_pre: int, n_post: int,
                             p: float, weight=None,
                             rows: Optional[jax.Array] = None) -> _JTriple:
    """Each (pre, post) pair connected independently with probability p."""
    if not 0.0 <= p <= 1.0:
        raise ValueError(f"FixedProbability p={p} outside [0, 1]")
    rows = _rows_or_default(rows, n_pre)
    k = _binomial_slots(n_post, p)
    post, counts = _fixed_probability_rows(key, rows, n_post, p, k)
    valid = jnp.arange(k, dtype=jnp.int32)[None, :] < counts[:, None]
    g = _row_weights(as_device_weight(weight), key, rows, k)
    g = jnp.where(valid, g, 0.0).astype(jnp.float32)
    return jnp.where(valid, post, 0).astype(jnp.int32), g, valid


def device_one_to_one(key: jax.Array, n_pre: int, n_post: int, weight=None,
                      rows: Optional[jax.Array] = None) -> _JTriple:
    if n_pre != n_post:
        raise ValueError(
            f"OneToOne requires n_pre == n_post, got {n_pre} != {n_post}")
    rows = _rows_or_default(rows, n_pre)
    post = rows[:, None]
    g = _row_weights(as_device_weight(weight), key, rows, 1)
    return post, g.astype(jnp.float32), jnp.ones_like(post, bool)


def device_dense(key: jax.Array, n_pre: int, n_post: int, weight=None,
                 rows: Optional[jax.Array] = None) -> _JTriple:
    rows = _rows_or_default(rows, n_pre)
    post = jnp.broadcast_to(jnp.arange(n_post, dtype=jnp.int32),
                            (rows.shape[0], n_post))
    g = _row_weights(as_device_weight(weight), key, rows, n_post)
    return post, g.astype(jnp.float32), jnp.ones_like(post, bool)


def device_resolve(connect: F.ConnectivityInit, key: jax.Array, n_pre: int,
                   n_post: int, weight=None,
                   rows: Optional[jax.Array] = None) -> _JTriple:
    """Dispatch a ConnectivityInit declaration to its device kernel."""
    if isinstance(connect, F.FixedFanout):
        return device_fixed_fanout(key, n_pre, n_post, connect.n_conn,
                                   weight, rows)
    if isinstance(connect, F.FixedProbability):
        return device_fixed_probability(key, n_pre, n_post, connect.p,
                                        weight, rows)
    if isinstance(connect, F.OneToOne):
        return device_one_to_one(key, n_pre, n_post, weight, rows)
    if isinstance(connect, F.DenseInit):
        return device_dense(key, n_pre, n_post, weight, rows)
    raise NotImplementedError(
        f"no device-side kernel for {connect.describe()}; build with "
        "init='host' or add a kernel to repro.sparse.device_init")


# ---------------------------------------------------------------------------
# post-sharding: repack a global ELL into per-device blocks
# ---------------------------------------------------------------------------

def partition_ell_by_post(
    ell: F.ELLSynapses, n_shards: int,
) -> Tuple[jax.Array, jax.Array, jax.Array, Optional[jax.Array], int, int]:
    """Split an ELL column-wise into `n_shards` post-neuron shards.

    Returns (g, post_local, valid, delay_local, shard_size, k_local) with
    the array outputs shaped [n_shards, n_pre, k_local]: shard d holds, for
    every pre row, the slots whose post neuron lives in
    [d*shard_size, (d+1)*shard_size), compacted left and re-indexed to
    shard-local post ids.  The within-row slot order is preserved (stable
    sort), so per-post-neuron scatter accumulation order — and hence
    bit-exact currents — matches the global ELL.  The per-synapse dendritic
    delay slot (when present) rides along through the identical permutation;
    delay_local is None for delay-free ELLs.  Total memory across shards
    ~= nnz (k_local ~= K / n_shards).
    """
    n_pre, k = ell.g.shape
    n_post = ell.n_post
    shard_size = -(-n_post // n_shards)  # ceil
    shard = jnp.where(ell.valid, ell.post_ind // shard_size, n_shards)
    order = jnp.argsort(shard, axis=1)            # stable in jax
    shard_s = jnp.take_along_axis(shard, order, axis=1)
    post_s = jnp.take_along_axis(ell.post_ind, order, axis=1)
    g_s = jnp.take_along_axis(jnp.where(ell.valid, ell.g, 0.0), order,
                              axis=1)
    delay_s = (None if ell.delay is None else jnp.take_along_axis(
        jnp.where(ell.valid, ell.delay, 0), order, axis=1))
    # per-row per-shard slot counts from the sorted shard ids via
    # searchsorted boundaries: O(n_pre * D log K), never an [n_pre, K, D]
    # one-hot temporary (which would be O(nnz * D) — the very blowup this
    # module exists to avoid)
    bounds = jnp.arange(n_shards + 1, dtype=shard_s.dtype)
    edges = jax.vmap(
        lambda row: jnp.searchsorted(row, bounds, side="left"))(shard_s)
    counts = jnp.diff(edges, axis=1)              # [n_pre, n_shards]
    k_local = max(1, int(counts.max()))           # build-time host sync
    start = jnp.concatenate(
        [jnp.zeros((n_pre, 1), counts.dtype),
         jnp.cumsum(counts, axis=1)[:, :-1]], axis=1)   # exclusive prefix
    d_idx = shard_s                                # [n_pre, k]
    slot = jnp.arange(k, dtype=jnp.int32)[None, :] - jnp.take_along_axis(
        start, jnp.clip(d_idx, 0, n_shards - 1), axis=1)
    row = jnp.broadcast_to(jnp.arange(n_pre)[:, None], (n_pre, k))
    shape = (n_shards, n_pre, k_local)
    # invalid slots carry d_idx == n_shards -> dropped by the OOB mode
    g_out = jnp.zeros(shape, jnp.float32).at[d_idx, row, slot].set(
        g_s, mode="drop")
    post_out = jnp.zeros(shape, jnp.int32).at[d_idx, row, slot].set(
        (post_s - d_idx * shard_size).astype(jnp.int32), mode="drop")
    valid_out = jnp.zeros(shape, bool).at[d_idx, row, slot].set(
        shard_s < n_shards, mode="drop")
    delay_out = (None if delay_s is None
                 else jnp.zeros(shape, jnp.int32).at[d_idx, row, slot].set(
                     delay_s.astype(jnp.int32), mode="drop"))
    return g_out, post_out, valid_out, delay_out, shard_size, k_local
