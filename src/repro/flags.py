"""Process-wide mode flags (set by the dry-run's roofline lowerings).

ROOFLINE_NAIVE_ATTN: force the un-chunked attention reference so every
attention flop/byte appears in XLA's cost_analysis (the chunked/flash paths
hide work inside while-loops, which cost_analysis counts once).  The roofline
builder then swaps the naive attention terms for analytic flash-kernel terms
(benchmarks/roofline.py) — see DESIGN.md §3.

pallas_mode(): the single parse site for the REPRO_USE_PALLAS environment
variable (kernel backend selection).  Every kernel dispatcher
(repro.kernels.ops.backend) routes through it, so the accepted spellings
cannot drift per module, and a misspelled value raises instead of silently
falling back to the reference path.
"""

from __future__ import annotations

import enum
import os


class PallasMode(str, enum.Enum):
    """Kernel backend selection (REPRO_USE_PALLAS)."""

    OFF = "off"              # pure-jnp reference (CPU dry-runs, rooflines)
    ON = "on"                # compiled Pallas kernels (real TPU)
    INTERPRET = "interpret"  # Pallas interpret mode (CPU validation)


_OFF_SPELLINGS = ("", "0", "false", "off", "no", "none")
_ON_SPELLINGS = ("1", "true", "on", "tpu", "pallas")


def pallas_mode(value: str | None = None) -> PallasMode:
    """Parse REPRO_USE_PALLAS (or an explicit `value`) into a PallasMode.

    Unset / "0" / "off"  -> OFF;  "1" / "true" / "tpu" -> ON;
    "interpret" -> INTERPRET.  Anything else raises ValueError: a typo like
    "interperet" would otherwise silently disable the Pallas kernels and
    every downstream benchmark would quietly measure the reference path.
    """
    if value is None:
        value = os.environ.get("REPRO_USE_PALLAS", "")
    v = value.strip().lower()
    if v in _OFF_SPELLINGS:
        return PallasMode.OFF
    if v in _ON_SPELLINGS:
        return PallasMode.ON
    if v == "interpret":
        return PallasMode.INTERPRET
    raise ValueError(
        f"REPRO_USE_PALLAS={value!r} is not a recognized mode; use one of "
        f"{_OFF_SPELLINGS[1:]} (off), {_ON_SPELLINGS} (on), or 'interpret'")


ROOFLINE_NAIVE_ATTN = False

# Replace the attention / SSD cores with identity passthroughs.  Used by the
# perf analysis to ISOLATE each core's measured share of a cell's roofline
# terms: core_cost = cell(naive) - cell(no_core); the Pallas kernel's
# analytic cost is then substituted (EXPERIMENTS.md §Perf).
ROOFLINE_NO_ATTN = False
ROOFLINE_NO_SSD = False
