"""Process-wide mode flags (set by the dry-run's roofline lowerings).

ROOFLINE_NAIVE_ATTN: force the un-chunked attention reference so every
attention flop/byte appears in XLA's cost_analysis (the chunked/flash paths
hide work inside while-loops, which cost_analysis counts once).  The roofline
builder then swaps the naive attention terms for analytic flash-kernel terms
(benchmarks/roofline.py) — see DESIGN.md §3.
"""

ROOFLINE_NAIVE_ATTN = False

# Replace the attention / SSD cores with identity passthroughs.  Used by the
# perf analysis to ISOLATE each core's measured share of a cell's roofline
# terms: core_cost = cell(naive) - cell(no_core); the Pallas kernel's
# analytic cost is then substituted (EXPERIMENTS.md §Perf).
ROOFLINE_NO_ATTN = False
ROOFLINE_NO_SSD = False
