"""ModelSpec v2 API: spec validation, connectivity initializers, generated
synapse models (equivalence with the seed's hardcoded dynamics), learning,
and the first-class gscale sweep."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.snn import neurons as N
from repro.core.snn.simulator import Simulator
from repro.core.snn.spec import ModelSpec, SpecError
from repro.core.snn.synapses import (Alpha, ExpCond, ExpDecay, Pulse, STDP,
                                     SynapseGroup, make_group)
from repro.kernels import ops as kops
from repro.sparse import formats as F


def _two_pop_spec(n=8):
    spec = ModelSpec("t")
    spec.add_neuron_population("a", n, "lif", params={"Vthresh": -100.0})
    spec.add_neuron_population("b", n, "lif")
    return spec


# -- spec validation ---------------------------------------------------------

def test_duplicate_population_rejected():
    spec = _two_pop_spec()
    with pytest.raises(SpecError, match="duplicate population name 'a'"):
        spec.add_neuron_population("a", 4, "lif")


def test_unknown_neuron_model_name():
    spec = ModelSpec("t")
    with pytest.raises(SpecError, match="unknown neuron model 'nope'"):
        spec.add_neuron_population("a", 4, "nope")


def test_unknown_neuron_param_named():
    spec = ModelSpec("t")
    with pytest.raises(SpecError, match="unknown parameter 'zz'.*lif"):
        spec.add_neuron_population("a", 4, "lif", params={"zz": 1.0})


def test_per_neuron_param_shape_checked():
    spec = ModelSpec("t")
    with pytest.raises(SpecError, match="leading dimension 3 != population "
                                        "size 4"):
        spec.add_neuron_population("a", 4, "lif",
                                   params={"tau": np.ones(3)})


def test_unknown_pre_post_population_named():
    spec = _two_pop_spec()
    with pytest.raises(SpecError, match="unknown post population 'c'"):
        spec.add_synapse_population("ab", "a", "c",
                                    connect=F.FixedFanout(2))
    with pytest.raises(SpecError, match="unknown pre population 'z'"):
        spec.add_synapse_population("ab", "z", "b",
                                    connect=F.FixedFanout(2))


def test_duplicate_post_and_group_names_rejected():
    # two groups with one name would silently share a Simulator state slot
    spec = _two_pop_spec()
    with pytest.raises(SpecError, match="duplicate post population"):
        spec.add_synapse_population("s", "a", ["b", "b"],
                                    connect=F.FixedFanout(2))
    spec.add_synapse_population("s", "a", "b", connect=F.FixedFanout(2))
    with pytest.raises(SpecError, match="duplicate synapse group name 's'"):
        spec.add_synapse_population("s", "a", "b", connect=F.FixedFanout(2))
    # a multi-post declared name colliding with an existing single-post
    # name (and vice versa) would make gscale addressing silently partial
    with pytest.raises(SpecError, match="duplicate synapse group name 's'"):
        spec.add_synapse_population("s", "a", ["a", "b"],
                                    connect=F.FixedFanout(2))
    # the legacy Network path guards the same invariant
    from repro.core.snn.network import Network
    net = Network()
    net.add_population("a", N.LIF, 4)
    net.add_synapse(make_group(np.random.default_rng(0), "g", "a", "a",
                               4, 4, 2))
    with pytest.raises(ValueError, match="duplicate synapse group name"):
        net.add_synapse(make_group(np.random.default_rng(1), "g", "a", "a",
                                   4, 4, 2))


def test_bad_representation_rejected():
    spec = _two_pop_spec()
    with pytest.raises(SpecError, match="representation 'ragged'"):
        spec.add_synapse_population("ab", "a", "b",
                                    connect=F.FixedFanout(2),
                                    representation="ragged")
    # explicit dense conflicts with dynamic weights (ELL-only path)
    with pytest.raises(SpecError, match="'dense' is incompatible.*stdp"):
        spec.add_synapse_population("ab2", "a", "b",
                                    connect=F.FixedFanout(2),
                                    wum=STDP(), representation="dense")


def test_conductance_model_requires_membrane_state():
    spec = ModelSpec("t")
    spec.add_neuron_population("pn", 4, "poisson")
    spec.add_neuron_population("x", 4, "poisson")
    # poisson neurons have no V; ExpCond applies in_syn * (e_rev - V)
    with pytest.raises(SpecError, match="references V.*'x'.*no.*membrane"):
        spec.add_synapse_population("px", "pn", "x",
                                    connect=F.FixedFanout(2),
                                    psm=ExpCond(2.0, 0.0))


def test_one_to_one_size_mismatch_reported_with_group_name():
    spec = ModelSpec("t")
    spec.add_neuron_population("a", 4, "lif")
    spec.add_neuron_population("b", 6, "lif")
    spec.add_synapse_population("ab", "a", "b", connect=F.OneToOne())
    with pytest.raises(SpecError, match="'ab'.*n_pre == n_post"):
        spec.build(dt=1.0, seed=0)


def test_unknown_gscale_key_raises_with_valid_names():
    model = _two_pop_spec().build(dt=1.0, seed=0)
    spec = _two_pop_spec()
    spec.add_synapse_population("ab", "a", "b", connect=F.FixedFanout(2))
    model = spec.build(dt=1.0, seed=0)
    with pytest.raises(ValueError, match=r"typo.*valid.*\['ab'\]"):
        model.run(5, gscales={"typo": 2.0})
    with pytest.raises((SpecError, ValueError), match="nope"):
        model.sweep_gscale("nope", [1.0], n_steps=5)
    # the Simulator path (legacy API) validates too
    with pytest.raises(ValueError, match="unknown gscale key"):
        model.simulator.run(model.init_state(), 5, {"tpyo": 1.0})
    with pytest.raises(ValueError, match="unknown gscale key"):
        model.simulator.step(model.init_state(), {"tpyo": 1.0})


# -- connectivity initializers ----------------------------------------------

@pytest.mark.parametrize("init", [
    F.FixedFanout(5), F.FixedProbability(0.3), F.OneToOne(), F.DenseInit(),
])
def test_initializers_deterministic(init):
    wf = lambda r, s: r.random(s).astype(np.float32)
    a = init.resolve(np.random.default_rng(42), 20, 20, wf)
    b = init.resolve(np.random.default_rng(42), 20, 20, wf)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    c = init.resolve(np.random.default_rng(43), 20, 20, wf)
    # different seed gives different weights (and generally different graph)
    assert any(not np.array_equal(x, y) for x, y in zip(a, c))


def test_fixed_fanout_degree():
    post, g, valid = F.FixedFanout(7).resolve(
        np.random.default_rng(0), 30, 50, None)
    assert post.shape == (30, 7) and valid.all()
    # without replacement: no duplicate targets within a row
    for row in post:
        assert len(set(row.tolist())) == 7


def test_fixed_probability_degree_statistics():
    n_pre, n_post, p = 200, 100, 0.2
    post, g, valid = F.FixedProbability(p).resolve(
        np.random.default_rng(1), n_pre, n_post, None)
    degrees = valid.sum(axis=1)
    # mean degree ~ Binomial(n_post, p): 20 +- ~4/sqrt(200) ~= 0.3
    assert abs(degrees.mean() - p * n_post) < 1.5
    assert degrees.std() > 1.0  # genuinely random, not fixed-fanout
    # valid slots are left-packed with ascending unique column indices
    row = post[0][valid[0]]
    assert (np.diff(row) > 0).all()
    assert not valid[0][int(degrees[0]):].any()


def test_one_to_one_and_dense():
    post, g, valid = F.OneToOne().resolve(np.random.default_rng(0), 9, 9,
                                          None)
    np.testing.assert_array_equal(post.ravel(), np.arange(9))
    post, g, valid = F.DenseInit().resolve(np.random.default_rng(0), 4, 6,
                                           None)
    assert post.shape == (4, 6) and valid.all()
    np.testing.assert_array_equal(post[2], np.arange(6))


def test_spec_build_same_seed_same_graph():
    def build():
        spec = _two_pop_spec()
        spec.add_synapse_population(
            "ab", "a", "b", connect=F.FixedProbability(0.4),
            weight=lambda r, s: r.random(s))
        return spec.build(dt=1.0, seed=11)

    g1 = build().network.synapses[0].ell
    g2 = build().network.synapses[0].ell
    np.testing.assert_array_equal(np.asarray(g1.g), np.asarray(g2.g))
    np.testing.assert_array_equal(np.asarray(g1.post_ind),
                                  np.asarray(g2.post_ind))


def test_make_group_shim_matches_initializer_path():
    """The legacy make_group must be a thin shim over FixedFanout."""
    wf = lambda r, s: r.random(s).astype(np.float32)
    grp = make_group(np.random.default_rng(3), "g", "a", "b", 10, 12, 4,
                     weight_fn=wf)
    post, g, valid = F.FixedFanout(4).resolve(
        np.random.default_rng(3), 10, 12, wf)
    np.testing.assert_array_equal(np.asarray(grp.ell.post_ind), post)
    np.testing.assert_array_equal(np.asarray(grp.ell.g), g)


# -- generated synapse dynamics vs the seed's hardcoded branches ------------

def _group(psm, n_pre=6, n_post=5, sign=1.0):
    rng = np.random.default_rng(7)
    post, g, valid = F.FixedFanout(3).resolve(
        rng, n_pre, n_post, lambda r, s: r.random(s).astype(np.float32))
    ell = F.triple_to_ell(post, g, valid, n_post)
    return SynapseGroup(name="g", pre="a", post="b", ell=ell,
                        representation="sparse", psm=psm, sign=sign)


def test_pulse_matches_seed_semantics():
    grp = _group(Pulse(), sign=-1.0)
    st = grp.init_state()
    rng = np.random.default_rng(0)
    for _ in range(5):
        spk = jnp.asarray(rng.random(6) < 0.4, jnp.float32)
        gs = jnp.float32(1.7)
        st, cur = grp.step(st, spk, gs, dt=1.0)
        expect = -1.0 * gs * kops.ell_spmv(grp.ell, spk)
        np.testing.assert_array_equal(np.asarray(cur), np.asarray(expect))


def test_exp_decay_matches_seed_semantics():
    """Generated ExpDecay reproduces `in_syn*exp(-dt/tau) + inj` exactly."""
    tau, dt = 4.0, 0.5
    grp = _group(ExpDecay(tau))
    st = grp.init_state()
    rng = np.random.default_rng(1)
    ref = jnp.zeros(5)
    for _ in range(20):
        spk = jnp.asarray(rng.random(6) < 0.5, jnp.float32)
        st, cur = grp.step(st, spk, jnp.float32(1.0), dt=dt)
        inj = kops.ell_spmv(grp.ell, spk)
        ref = ref * jnp.exp(-dt / tau).astype(jnp.float32) + inj
        np.testing.assert_array_equal(np.asarray(cur), np.asarray(ref))


def test_exp_cond_matches_seed_semantics():
    """Generated ExpCond reproduces `in_syn * (e_rev - v_post)` exactly."""
    tau, dt, e_rev = 3.0, 0.1, -92.0
    grp = _group(ExpCond(tau, e_rev))
    st = grp.init_state()
    rng = np.random.default_rng(2)
    ref = jnp.zeros(5)
    for _ in range(20):
        spk = jnp.asarray(rng.random(6) < 0.5, jnp.float32)
        v = jnp.asarray(rng.normal(-60, 5, 5), jnp.float32)
        st, cur = grp.step(st, spk, jnp.float32(2.0), dt=dt, v_post=v)
        inj = 2.0 * kops.ell_spmv(grp.ell, spk)
        ref = ref * jnp.exp(-dt / tau).astype(jnp.float32) + inj
        np.testing.assert_array_equal(np.asarray(cur),
                                      np.asarray(ref * (e_rev - v)))


def test_exp_cond_without_v_raises_named_error():
    grp = _group(ExpCond(3.0, 0.0))
    st = grp.init_state()
    with pytest.raises(ValueError, match="'g'.*references V"):
        grp.step(st, jnp.zeros(6), jnp.float32(1.0), dt=0.1)


def test_alpha_synapse_new_expressiveness():
    """Alpha kernel: response to a single spike rises then falls (peak near
    tau), unlike Pulse (instant) or ExpDecay (monotone decay)."""
    tau, dt = 2.0, 0.1
    grp = _group(Alpha(tau))
    st = grp.init_state()
    spk1 = jnp.zeros(6).at[0].set(1.0)
    st, cur = grp.step(st, spk1, jnp.float32(1.0), dt=dt)
    trace = []
    for _ in range(100):
        st, cur = grp.step(st, jnp.zeros(6), jnp.float32(1.0), dt=dt)
        trace.append(float(jnp.max(cur)))
    peak = int(np.argmax(trace))
    assert trace[-1] < trace[peak]          # decays after the peak
    assert 5 <= peak <= 40                  # rises first (~tau/dt = 20)


def test_reserved_names_rejected_eagerly():
    """A state/param var shadowing a reserved external would silently
    replace the real value in the generated env — must error at declare."""
    from repro.core.codegen import (CodegenError, NeuronModel,
                                    PostsynapticModel, WeightUpdateModel)
    with pytest.raises(CodegenError, match="'inj' collides"):
        PostsynapticModel(name="m", state={"inj": 0.0})
    with pytest.raises(CodegenError, match="'V' collides"):
        PostsynapticModel(name="m", params={"V": 1.0})
    with pytest.raises(CodegenError, match="'g' collides"):
        WeightUpdateModel(name="m", syn_state={"g": 0.0})
    with pytest.raises(CodegenError, match="'dt' collides"):
        WeightUpdateModel(name="m", params={"dt": 1.0})
    with pytest.raises(CodegenError, match="'Isyn' collides"):
        NeuronModel(name="m", state={"Isyn": 0.0}, params={}, sim_code="")
    with pytest.raises(CodegenError, match="both state and params"):
        NeuronModel(name="m", state={"V": 0.0}, params={"V": 1.0},
                    sim_code="")
    with pytest.raises(CodegenError, match="both pre_state and post_state"):
        WeightUpdateModel(name="m", pre_state={"x": 0.0},
                          post_state={"x": 0.0})


def test_spike_code_may_reference_dt_without_t():
    """dt/t are always present in snippet envs, even for legacy callers
    that never pass t."""
    from repro.core.codegen import WeightUpdateModel
    wum = WeightUpdateModel(name="scaled", spike_code="g * dt")
    rng = np.random.default_rng(0)
    post, g, valid = F.FixedFanout(2).resolve(rng, 4, 4, None)
    grp = SynapseGroup(name="g", pre="a", post="b",
                       ell=F.triple_to_ell(post, g, valid, 4),
                       representation="sparse", wum=wum)
    st = grp.init_state()
    spk = jnp.ones(4)
    st, cur = grp.step(st, spk, jnp.float32(1.0), dt=0.5)   # no t kwarg
    np.testing.assert_allclose(np.asarray(cur),
                               np.asarray(0.5 * kops.ell_spmv(grp.ell, spk)))


def test_overlapping_gscale_keys_rejected():
    spec = ModelSpec("t")
    spec.add_neuron_population("src", 6, "lif")
    spec.add_neuron_population("e", 4, "lif")
    spec.add_neuron_population("i", 2, "lif")
    spec.add_synapse_population("out", "src", ["e", "i"],
                                connect=F.FixedFanout(3))
    model = spec.build(dt=1.0, seed=0)
    # 'out' expands to out_e+out_i; also naming out_i directly is ambiguous
    with pytest.raises(SpecError, match="'out_i' twice"):
        model.run(5, gscales={"out": 1.0, "out_i": 2.0})


# -- learning (weight-update models) ----------------------------------------

def _stdp_group():
    ell = F.triple_to_ell(np.zeros((1, 1), np.int32),
                          np.full((1, 1), 0.5, np.float32),
                          np.ones((1, 1), bool), 1)
    return SynapseGroup(name="s", pre="a", post="b", ell=ell,
                        representation="sparse",
                        wum=STDP(lr=0.1, tau_pre=10.0, tau_post=10.0,
                                 g_max=1.0))


def test_stdp_pre_before_post_potentiates():
    grp = _stdp_group()
    st = grp.init_state()
    one, zero = jnp.ones(1), jnp.zeros(1)
    st, _ = grp.step(st, one, jnp.float32(1.0), dt=1.0, post_spikes=zero)
    st, _ = grp.step(st, zero, jnp.float32(1.0), dt=1.0, post_spikes=one)
    assert float(st.g[0, 0]) > 0.5


def test_stdp_post_before_pre_depresses():
    grp = _stdp_group()
    st = grp.init_state()
    one, zero = jnp.ones(1), jnp.zeros(1)
    st, _ = grp.step(st, zero, jnp.float32(1.0), dt=1.0, post_spikes=one)
    st, _ = grp.step(st, one, jnp.float32(1.0), dt=1.0, post_spikes=zero)
    assert float(st.g[0, 0]) < 0.5


def test_stdp_runs_inside_simulator_and_stays_bounded():
    spec = ModelSpec("t")
    # both populations spike every step; the slow post trace then outweighs
    # the fast pre trace, so net depression must drive g down (and g_min
    # must clip it at 0)
    spec.add_neuron_population("a", 4, "lif", params={"Vthresh": -100.0})
    spec.add_neuron_population("b", 4, "lif", params={"Vthresh": -100.0})
    spec.add_synapse_population("ab", "a", "b", connect=F.OneToOne(),
                                weight=0.2,
                                wum=STDP(lr=0.01, tau_pre=5.0,
                                         tau_post=50.0, g_max=0.4))
    model = spec.build(dt=1.0, seed=0)
    st = model.init_state()
    step = jax.jit(model.step)
    for _ in range(60):
        st, _ = step(st)
    g = np.asarray(st.syn["ab"].g)
    assert (g >= 0.0).all() and (g <= 0.4).all()
    assert (g < 0.19).all()                 # learning actually moved g down


# -- build/run front-end -----------------------------------------------------

def test_sweep_gscale_matches_individual_runs():
    spec = _two_pop_spec()
    spec.add_synapse_population("ab", "a", "b", connect=F.FixedFanout(3),
                                weight=0.3, psm=ExpDecay(4.0))
    model = spec.build(dt=1.0, seed=5)
    st = model.init_state()
    values = [0.5, 1.0, 4.0]
    sweep = model.sweep_gscale("ab", values, n_steps=40, state=st)
    assert sweep.finite.shape == (3,)
    for i, v in enumerate(values):
        res = model.run(40, gscales={"ab": v}, state=st)
        np.testing.assert_allclose(float(sweep.rates_hz["b"][i]),
                                   float(res.rates_hz["b"]), rtol=1e-6)


def test_multi_post_split_draw():
    """post=[...] makes one draw over the concatenated target space."""
    spec = ModelSpec("t")
    spec.add_neuron_population("src", 10, "lif")
    spec.add_neuron_population("e", 6, "lif")
    spec.add_neuron_population("i", 4, "lif")
    spec.add_synapse_population("out", "src", ["e", "i"],
                                connect=F.FixedFanout(5))
    model = spec.build(dt=1.0, seed=0)
    assert model.group_names == ["out_e", "out_i"]
    ge = model.network.synapses[0]
    gi = model.network.synapses[1]
    # the split covers the draw exactly: per pre neuron, valid slots in the
    # two groups partition the n_conn targets
    total = (np.asarray(ge.ell.valid).sum(axis=1)
             + np.asarray(gi.ell.valid).sum(axis=1))
    np.testing.assert_array_equal(total, np.full(10, 5))
    # scaling the declared name scales both split groups, through run AND
    # manual stepping
    res = model.run(10, gscales={"out": 2.0})
    assert bool(res.finite)
    st, _ = model.step(model.init_state(), gscales={"out": 2.0})


def test_compiled_model_run_caches_executable():
    spec = _two_pop_spec()
    spec.add_synapse_population("ab", "a", "b", connect=F.FixedFanout(2))
    model = spec.build(dt=1.0, seed=0)
    model.run(10, gscales={"ab": 1.0})
    model.run(10, gscales={"ab": 2.0})
    assert len(model._run_cache) == 1       # same executable, traced gscale


def test_network_shim_still_works_with_simulator():
    """The legacy Network/make_group path stays functional."""
    from repro.core.snn.network import Network
    net = Network()
    net.add_population("a", N.LIF, 4, {"Vthresh": -100.0})
    net.add_population("b", N.LIF, 4)
    net.add_synapse(make_group(np.random.default_rng(0), "ab", "a", "b",
                               4, 4, 2, dynamics="exp_decay", tau_ms=3.0))
    sim = Simulator(net, dt=1.0)
    res = sim.run(sim.init_state(), 20)
    assert bool(res.finite)
    assert float(res.rates_hz["a"]) > 0.0


def test_simulator_run_jit_cached_per_n_steps():
    """run_jit mirrors the CompiledModel cache: one compiled callable per
    (n_steps, record_raster), not one per call."""
    spec = _two_pop_spec()
    spec.add_synapse_population("ab", "a", "b", connect=F.FixedFanout(2))
    sim = spec.build(dt=1.0, seed=0).simulator
    f1 = sim.run_jit(10)
    f2 = sim.run_jit(10)
    assert f1 is f2
    assert sim.run_jit(20) is not f1
    assert sim.run_jit(10, record_raster=True) is not f1
    assert len(sim._run_jit_cache) == 3
    res = f1(sim.init_state(), {})
    assert bool(res.finite)
