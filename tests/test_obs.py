"""Observability subsystem: tracing, telemetry, health monitors (PR 7).

Covers the acceptance criteria of the telemetry issue:

- span nesting / thread-safety / bounded-cap drop accounting, and Chrome
  trace_event schema validity of the exported JSON;
- the gateway's /metrics text staying byte-compatible with the PR 6
  renderer after its migration onto repro.obs.telemetry;
- the on-device health monitor against a pure-numpy oracle (exact spike
  counts, EMA fold, silent/saturated band flags, NaN guard tripping on an
  induced conductance blow-up);
- monitor-off builds producing the *same jaxpr* as unmonitored builds
  (strictly zero-cost when disabled);
- host vs sharded (up to 8 forced host devices in CI) HealthReport
  bitwise agreement for both ``run`` and ``serve_chunk``, with the
  under-scaled PN->KC configuration flagged silent;
- the ``--trace`` CLI flag (success and unwritable-path exit codes) and
  the HTTP ``/v1/trace`` debug endpoint.
"""

import asyncio
import json
import threading

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.models.izhikevich_net import (IzhikevichNetConfig,
                                              compile_model as compile_izh)
from repro.core.models.mushroom_body import (MushroomBodyConfig,
                                             compile_model as compile_mb)
from repro.core.snn.spec import SpecError
from repro.launch.mesh import make_snn_mesh
from repro.obs import trace as obs_trace
from repro.obs.health import HealthConfig
from repro.obs.telemetry import (Counter, LatencyWindow, MetricsRegistry,
                                 PromText, format_labels)
from repro.obs.trace import TraceCollector, validate_chrome_trace


def _n_dev() -> int:
    """Devices for in-process sharded tests, capped at 8 (same rationale
    as tests/test_engine_sharded.py)."""
    return min(jax.device_count(), 8)


# ---------------------------------------------------------------------------
# trace: spans, thread-safety, Chrome export
# ---------------------------------------------------------------------------

def test_span_nesting_and_chrome_export(tmp_path):
    c = TraceCollector()
    with c.span("outer", model="m"):
        with c.span("inner", k=1):
            pass
        c.instant("tick", j=2)
    evs = c.events()
    assert [e["name"] for e in evs] == ["inner", "tick", "outer"]
    inner, tick, outer = evs
    # nesting is ts/dur containment per tid (how the viewer reconstructs)
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-6
    assert outer["ts"] <= tick["ts"] <= outer["ts"] + outer["dur"]
    assert inner["tid"] == outer["tid"]
    assert outer["args"] == {"model": "m"}

    path = tmp_path / "trace.json"
    assert c.export(str(path)) == 3
    doc = json.loads(path.read_text())
    assert validate_chrome_trace(doc) is None
    assert doc["displayTimeUnit"] == "ms"
    assert doc["otherData"]["dropped_events"] == 0


def test_span_records_even_when_body_raises():
    c = TraceCollector()
    with pytest.raises(RuntimeError):
        with c.span("failing"):
            raise RuntimeError("boom")
    assert [e["name"] for e in c.events()] == ["failing"]


def test_collector_thread_safety_and_bounded_cap():
    cap, threads, per_thread = 512, 8, 200
    c = TraceCollector(cap=cap)

    def work(i):
        for j in range(per_thread):
            with c.span(f"t{i}", j=j):
                pass

    ts = [threading.Thread(target=work, args=(i,)) for i in range(threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    evs = c.events()
    assert len(evs) == cap
    assert c.dropped == threads * per_thread - cap
    assert validate_chrome_trace(c.chrome_trace()) is None
    # every retained event is fully formed (no torn writes)
    assert all(e["ph"] == "X" and e["dur"] >= 0 for e in evs)


def test_collector_disabled_records_nothing():
    c = TraceCollector(enabled=False)
    with c.span("x"):
        c.instant("y")
    assert c.events() == []


def test_validate_chrome_trace_rejects_malformed():
    assert validate_chrome_trace([]) == "document is not an object"
    assert "traceEvents" in validate_chrome_trace({})
    assert "missing 'ts'" in validate_chrome_trace(
        {"traceEvents": [{"name": "a", "ph": "X", "pid": 1, "tid": 1}]})
    assert "unknown phase" in validate_chrome_trace(
        {"traceEvents": [{"name": "a", "ph": "Z", "ts": 0,
                          "pid": 1, "tid": 1}]})
    assert "non-negative dur" in validate_chrome_trace(
        {"traceEvents": [{"name": "a", "ph": "X", "ts": 0,
                          "pid": 1, "tid": 1, "dur": -1}]})


# ---------------------------------------------------------------------------
# telemetry: windows, registry, renderer
# ---------------------------------------------------------------------------

def test_latency_window_percentiles_and_lifetime_count():
    w = LatencyWindow(cap=10)
    for v in range(100):
        w.add(float(v))
    assert w.count == 100                    # lifetime
    assert w.samples() == [float(v) for v in range(90, 100)]  # windowed
    assert w.percentile(0.0) == 90.0
    assert w.percentile(1.0) == 99.0
    s = w.summary()
    assert s["count"] == 100 and s["max"] == 99.0
    assert s["p50"] == pytest.approx(94.0, abs=1.0)


def test_gateway_reexports_telemetry_latency_window():
    from repro.launch.gateway import LatencyWindow as GatewayLW
    assert GatewayLW is LatencyWindow


def test_metrics_registry_render_and_type_conflicts():
    reg = MetricsRegistry()
    c = reg.counter("requests_total")
    c.inc(model="a")
    c.inc(2, model="a")
    reg.gauge("slots").set(8, model="a")
    h = reg.histogram("lat_s", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    with pytest.raises(ValueError):
        reg.gauge("requests_total")          # registered as a Counter
    assert reg.counter("requests_total") is c  # same-type re-registration
    txt = reg.render()
    assert txt.endswith("\n")
    assert 'requests_total{model="a"} 3' in txt
    assert 'slots{model="a"} 8' in txt
    assert 'lat_s_bucket{le="0.1"} 1' in txt
    assert 'lat_s_bucket{le="+Inf"} 2' in txt
    assert "lat_s_count 2" in txt
    assert format_labels({}) == ""


def test_prom_text_quantiles_formatting():
    out = PromText()
    out.quantiles("g_seconds", {"model": "m"},
                  {"p50": 1.5, "p99": 2.0, "count": 7}, unit=1e-3)
    assert out.render() == (
        'g_seconds{model="m",quantile="50"} 0.001500\n'
        'g_seconds{model="m",quantile="99"} 0.002000\n'
        'g_seconds_count{model="m"} 7\n')


# ---------------------------------------------------------------------------
# shared models (module-scoped: builds are the expensive part)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def izh_mon():
    """Small monitored izhikevich host build + its config."""
    cfg = IzhikevichNetConfig(n_total=60, n_conn=10, seed=2)
    return compile_izh(cfg, monitor=HealthConfig()), cfg


@pytest.fixture(scope="module")
def mb_silent_pair():
    """Host + sharded monitored mushroom-body builds with PN->KC
    deliberately under-scaled (the paper's 'insufficient spiking' failure
    mode: KCs never fire).  The default collector is cleared first so the
    trace-content test can assert exactly what these builds emitted."""
    obs_trace.clear()
    cfg = MushroomBodyConfig(n_pn=16, n_lhi=4, n_kc=64, n_dn=12,
                             g_pn_kc=1e-6, seed=5)
    mon = HealthConfig(ema_tau_ms=5.0)
    host = compile_mb(cfg, monitor=mon)
    eng = compile_mb(cfg, mesh=make_snn_mesh(_n_dev()), monitor=mon)
    return host, eng, cfg


def _report_leaves_equal(a, b) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(la, lb))


# ---------------------------------------------------------------------------
# health monitor: numpy oracle, NaN guard, zero-cost-off, host/sharded parity
# ---------------------------------------------------------------------------

def test_health_report_matches_numpy_oracle(izh_mon):
    model, cfg = izh_mon
    mon = model.monitor
    T = 40
    with pytest.deprecated_call():           # legacy raster IS the oracle
        res = model.run(T, record_raster=True)
    rep = res.health
    assert rep is not None

    alpha = np.float32(mon.alpha(cfg.dt))
    for pop in ("exc", "inh"):
        n = model.network.populations[pop].n
        raster = np.asarray(res.raster[pop])         # [T, n] bool
        per_step = raster.sum(axis=1).astype(np.int64)
        assert int(np.asarray(rep.spike_total[pop])) == int(per_step.sum())

        inv = np.float32(1.0 / (n * cfg.dt * 1e-3))
        ema = np.float32(0.0)
        for c in per_step:
            rate = np.float32(c) * inv
            ema = ema + alpha * (rate - ema)
        np.testing.assert_allclose(np.asarray(rep.rate_ema_hz[pop]),
                                   ema, rtol=1e-5, atol=1e-6)
        mean = per_step.sum() * float(inv) / T
        np.testing.assert_allclose(np.asarray(rep.mean_rate_hz[pop]),
                                   mean, rtol=1e-5, atol=1e-6)
        lo, hi = mon.band(pop)
        assert bool(np.asarray(rep.silent[pop])) == (float(ema) < lo)
        assert bool(np.asarray(rep.saturated[pop])) == (float(ema) > hi)
    assert int(np.asarray(rep.steps)) == T
    assert not bool(np.asarray(rep.nonfinite))
    assert int(np.asarray(rep.first_bad_step)) == -1


def test_unmonitored_run_has_no_health(izh_mon):
    _, cfg = izh_mon
    plain = compile_izh(cfg)
    assert plain.monitor is None
    assert plain.run(5).health is None


def test_nan_guard_trips_on_conductance_blowup():
    # over-scaling PN->KC past the explicit-coupling stability bound is the
    # paper's float-overflow phenomenon (mushroom_body module docstring)
    cfg = MushroomBodyConfig(n_pn=16, n_lhi=4, n_kc=64, n_dn=12, seed=5)
    model = compile_mb(cfg, monitor=HealthConfig())
    T = 300
    res = model.run(T, gscales={"PN_KC": jnp.float32(500.0)})
    rep = res.health
    assert bool(np.asarray(rep.nonfinite))
    assert not bool(np.asarray(res.finite))
    assert 0 <= int(np.asarray(rep.first_bad_step)) < T


def test_monitor_off_build_has_identical_jaxpr(izh_mon):
    _, cfg = izh_mon
    off = compile_izh(cfg, monitor=HealthConfig(enabled=False))
    plain = compile_izh(cfg)
    assert off.monitor is None

    def jaxpr_of(model):
        st = model.init_state(jax.random.PRNGKey(0))
        return str(jax.make_jaxpr(
            lambda s: model.simulator.run(s, 7))(st))

    assert jaxpr_of(off) == jaxpr_of(plain)


def test_monitor_validation_errors(izh_mon):
    _, cfg = izh_mon
    with pytest.raises(SpecError, match="monitor"):
        compile_izh(cfg, monitor=HealthConfig(
            bands_hz={"nope": (1.0, 2.0)}))
    with pytest.raises(ValueError, match="ema_tau_ms"):
        HealthConfig(ema_tau_ms=0.0).validate(["exc"])
    with pytest.raises(ValueError, match="lo > hi"):
        HealthConfig(bands_hz={"exc": (5.0, 1.0)}).validate(["exc"])


def test_host_vs_sharded_health_bitwise_run(mb_silent_pair):
    host, eng, _ = mb_silent_pair
    T = 60
    rh, re = host.run(T), eng.run(T)
    assert rh.health is not None and re.health is not None
    assert _report_leaves_equal(rh.health, re.health)
    # the under-scaled PN->KC configuration is flagged: KCs silent, PNs not
    assert bool(np.asarray(rh.health.silent["KC"]))
    assert not bool(np.asarray(rh.health.silent["PN"]))
    assert not bool(np.asarray(rh.health.nonfinite))


def test_host_vs_sharded_health_bitwise_serve(mb_silent_pair):
    host, eng, _ = mb_silent_pair
    S, C = 2, 12
    steps_left = np.array([12, 5], np.int32)
    n_pn = host.network.populations["PN"].n
    rng = np.random.default_rng(0)
    stim = {"PN": rng.normal(size=(S, C, n_pn)).astype(np.float32)}
    outs = []
    for model in (host, eng):
        keys = jnp.stack([jax.random.PRNGKey(i) for i in range(S)])
        st = model.init_stream_state(keys)
        out = model.serve_chunk(st, stim, steps_left, C)
        assert len(out) == 5                 # monitored -> health appended
        outs.append(out[4])
    assert _report_leaves_equal(*outs)
    for slot in range(S):
        s = outs[0].summary(slot)
        assert s["steps"] == int(steps_left[slot])
        assert s["populations"]["KC"]["silent"]


def test_trace_contains_build_autotune_and_serve_spans(mb_silent_pair,
                                                       tmp_path):
    host, eng, _ = mb_silent_pair
    # the fixture cleared the collector before building; the sharded run
    # and serve tests above dispatched through the traced entry points
    host.run(3)
    eng.run(3)
    eng.run(3)                               # cache hit -> compile=False
    names = {e["name"] for e in obs_trace.events()}
    assert {"build", "validate", "codegen", "shard", "run"} <= names
    assert "choose_block_spmv" in names      # autotune decision audit
    run_spans = [e for e in obs_trace.events() if e["name"] == "run"]
    assert any(e["args"].get("sharded") for e in run_spans)
    assert any(e["args"].get("compile") is False for e in run_spans)

    path = tmp_path / "acceptance_trace.json"
    obs_trace.export(str(path))
    doc = json.loads(path.read_text())
    assert validate_chrome_trace(doc) is None
    tuned = [e for e in doc["traceEvents"]
             if e["name"] == "choose_block_spmv"]
    assert tuned and all({"bp", "bn", "occupancy"} <= set(e["args"])
                         for e in tuned)


# ---------------------------------------------------------------------------
# serving integration: SNNServer health, gateway /metrics + /v1/trace, CLI
# ---------------------------------------------------------------------------

def test_snn_server_streams_health(izh_mon):
    from repro.launch.snn_serve import SNNServer, StreamRequest
    model, _ = izh_mon
    n = model.network.populations["exc"].n
    srv = SNNServer(model, max_streams=2, chunk=8, stim_pops=("exc",))
    rng = np.random.default_rng(1)
    for i, T in enumerate((20, 11)):
        stim = {"exc": (3.0 * rng.normal(size=(T, n))).astype(np.float32)}
        srv.submit(StreamRequest(rid=i, n_steps=T, stim=stim, seed=i))
    finished = srv.run()
    assert len(finished) == 2
    for r in finished:
        assert all(c.health is not None for c in r.chunks)
        h = r.health
        assert h["steps"] == r.n_steps
        assert not h["nonfinite"] and h["first_bad_step"] == -1
        # chunk summaries aggregate: spike totals sum to the stream total
        assert h["populations"]["exc"]["spikes"] == int(
            np.sum(r.spike_counts["exc"]))


def test_gateway_metrics_text_bit_compatible_with_pr6(izh_mon):
    from repro.launch.gateway import Gateway
    model, _ = izh_mon
    gw = Gateway(chunk=6, buckets=(2,), warm=False, clock=lambda: 42.0)
    gw.register("izh", model, stim_pops=("exc",))
    n = model.network.populations["exc"].n
    rng = np.random.default_rng(3)
    for i in range(3):
        stim = {"exc": (3.0 * rng.normal(size=(10, n))).astype(np.float32)}
        gw.submit("izh", stim, 10, seed=i, priority=i % 2)
    gw.run_until_drained()

    # the PR 6 renderer, verbatim — the dashboard contract this PR must
    # not break while migrating onto obs.telemetry's PromText
    m = gw.metrics()
    lines = [f"gateway_uptime_seconds {m['uptime_s']:.3f}"]
    for name, wm in sorted(m["models"].items()):
        lab = f'{{model="{name}"}}'
        for c, v in sorted(wm["counters"].items()):
            lines.append(f"gateway_{c}_total{lab} {v}")
        lines.append(f"gateway_slots{lab} {wm['bucket']}")
        lines.append(f"gateway_active_streams{lab} {wm['active']}")
        lines.append(f"gateway_queued_streams{lab} {wm['queued']}")
        lines.append(f"gateway_slot_occupancy{lab} {wm['occupancy']:.4f}")
        lines.append(f"gateway_chunks_total{lab} {wm['chunks']}")
        for metric, unit in (("queue_wait_s", 1.0),
                             ("total_latency_s", 1.0),
                             ("step_latency_us", 1e-6)):
            s = wm[metric]
            base = metric.rsplit("_", 1)[0]
            for q in ("p50", "p99"):
                lines.append(
                    f'gateway_{base}_seconds{{model="{name}",'
                    f'quantile="{q[1:]}"}} {s[q] * unit:.6f}')
            lines.append(f'gateway_{base}_seconds_count{lab} {s["count"]}')
    expected = "\n".join(lines) + "\n"

    assert gw.render_metrics() == expected   # byte-identical (frozen clock)
    assert 'gateway_completed_total{model="izh"} 3' in expected


def test_http_trace_endpoint(izh_mon):
    from repro.launch.gateway import Gateway
    from repro.launch.gateway_http import GatewayHTTP
    model, _ = izh_mon

    async def scenario():
        gw = Gateway(chunk=6, buckets=(2,), warm=False)
        gw.register("izh", model, stim_pops=("exc",))
        srv = GatewayHTTP(gw, "127.0.0.1", 0, idle_sleep_s=0.001)
        host, port = await srv.start()
        try:
            reader, writer = await asyncio.open_connection(host, port)
            writer.write(b"GET /v1/trace HTTP/1.1\r\nHost: t\r\n\r\n")
            await writer.drain()
            raw = await reader.read()
            writer.close()
            head, _, body = raw.partition(b"\r\n\r\n")
            assert int(head.split()[1]) == 200
            assert b"application/json" in head
            doc = json.loads(body)
            assert validate_chrome_trace(doc) is None
            assert {e["name"] for e in doc["traceEvents"]} >= {"build"}
        finally:
            await srv.stop()

    asyncio.run(scenario())


def test_snn_serve_cli_trace_flag(tmp_path, capsys):
    from repro.launch.snn_serve import main
    path = tmp_path / "cli_trace.json"
    argv = ["--model", "izhikevich", "--streams", "2", "--requests", "1",
            "--steps", "8", "--chunk", "8", "--health"]
    assert main(argv + ["--trace", str(path)]) == 0
    doc = json.loads(path.read_text())
    assert validate_chrome_trace(doc) is None
    assert {e["name"] for e in doc["traceEvents"]} >= {"build",
                                                       "serve_chunk"}
    out = capsys.readouterr().out
    assert "health stream0" in out

    bad = tmp_path / "no_such_dir" / "t.json"
    assert main(argv + ["--trace", str(bad)]) == 1
    assert "cannot write trace file" in capsys.readouterr().err
