"""ShardedEngine: multi-device runs must match the single-device Simulator.

In-process tests run on whatever devices exist (CI's multi-device job sets
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the collective paths
are exercised on every push; on a 1-device machine they still verify the
shard_map path end to end).  The subprocess test forces 8 host-platform
devices regardless of the parent interpreter's locked backend.
"""

import json
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.models.izhikevich_net import (IzhikevichNetConfig,
                                              compile_model)
from repro.core.snn.spec import ModelSpec
from repro.core.snn.synapses import ExpDecay, STDP
from repro.launch.mesh import make_snn_mesh, snn_axis
from repro.launch.sharding import neuron_pad
from repro.sparse.formats import (FixedFanout, FixedProbability, OneToOne,
                                  UniformWeight)

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _n_dev() -> int:
    """Devices for in-process engine tests, capped at 8: importing
    launch.dryrun (collection of other test files) forces 512 fake CPU
    devices, and a 512-way shard_map over a 100-neuron net is all
    rendezvous and no work."""
    return min(jax.device_count(), 8)


def _pair(cfg):
    """(single-device model, engine model over the local device mesh)."""
    ref = compile_model(cfg)
    eng = compile_model(cfg, mesh=make_snn_mesh(_n_dev()))
    return ref, eng


def test_engine_run_exact_vs_simulator():
    cfg = IzhikevichNetConfig(n_total=120, n_conn=24, seed=3)
    ref, eng = _pair(cfg)
    r1, r2 = ref.run(40), eng.run(40)
    for k in r1.spike_counts:
        assert np.array_equal(np.asarray(r1.spike_counts[k]),
                              np.asarray(r2.spike_counts[k])), k
    assert bool(r1.finite) == bool(r2.finite)


def test_engine_raster_and_gscales_exact():
    cfg = IzhikevichNetConfig(n_total=96, n_conn=12, seed=1)
    ref, eng = _pair(cfg)
    r1 = ref.run(30, gscales={"exc": 1.7}, record_raster=True)
    r2 = eng.run(30, gscales={"exc": 1.7}, record_raster=True)
    for k in r1.raster:
        assert np.array_equal(np.asarray(r1.raster[k]),
                              np.asarray(r2.raster[k])), k


def test_engine_step_parity():
    cfg = IzhikevichNetConfig(n_total=64, n_conn=8, seed=2)
    ref, eng = _pair(cfg)
    s1, s2 = ref.init_state(), eng.init_state()
    for _ in range(4):
        s1, spk1 = ref.step(s1)
        s2, spk2 = eng.step(s2)
        for k in spk1:
            assert np.array_equal(np.asarray(spk1[k]), np.asarray(spk2[k]))
    assert float(s1.t) == float(s2.t)


def test_engine_sweep_matches_single_device_counts():
    cfg = IzhikevichNetConfig(n_total=96, n_conn=12, seed=4)
    ref, eng = _pair(cfg)
    vals = [0.5, 1.0, 2.0]
    s1 = ref.sweep_gscale("exc", vals, n_steps=25)
    s2 = eng.sweep_gscale("exc", vals, n_steps=25)
    for k in s1.spike_counts:
        assert np.array_equal(np.asarray(s1.spike_counts[k]),
                              np.asarray(s2.spike_counts[k])), k
    assert np.array_equal(np.asarray(s1.finite), np.asarray(s2.finite))
    assert np.allclose(np.asarray(s1.rates_hz["exc"]),
                       np.asarray(s2.rates_hz["exc"]), rtol=1e-5)


def test_engine_full_feature_model_exact():
    """Delays, plasticity, conductance synapses, every initializer — the
    engine must track the oracle bit for bit through all of them."""

    def mk():
        s = ModelSpec("cover")
        s.add_neuron_population(
            "a", 48, "izhikevich",
            input_fn=lambda k, t, n: 6.0 * jax.random.normal(k, (n,)))
        s.add_neuron_population("b", 24, "izhikevich")
        s.add_synapse_population("ab", "a", "b", connect=FixedFanout(6),
                                 weight=UniformWeight(0, 0.8),
                                 psm=ExpDecay(4.0), delay_steps=2)
        s.add_synapse_population("aa", "a", "a",
                                 connect=FixedProbability(0.15),
                                 weight=UniformWeight(0, 0.4),
                                 wum=STDP(0.01))
        s.add_synapse_population("bb", "b", "b", connect=OneToOne(),
                                 weight=0.3)
        return s

    r1 = mk().build(dt=1.0, seed=11).run(40, record_raster=True)
    r2 = mk().build(dt=1.0, seed=11,
                    mesh=make_snn_mesh(_n_dev())).run(
                        40, record_raster=True)
    for k in r1.spike_counts:
        assert np.array_equal(np.asarray(r1.spike_counts[k]),
                              np.asarray(r2.spike_counts[k])), k
        assert np.array_equal(np.asarray(r1.raster[k]),
                              np.asarray(r2.raster[k])), k


def test_dendritic_ring_sharded_along_post_axis():
    """Acceptance contract: no replicated [delay+1, n_pre] buffer remains.
    Per-device delay state is the post-sharded dendritic ring
    [max_delay+1, n_post_local] — asserted on the engine's sharding specs
    and on the actual device-local shards — and per-synapse delay slots
    are partitioned with the connectivity blocks, never replicated."""
    import dataclasses

    from jax.sharding import PartitionSpec as P

    from repro.core.snn.synapses import SynapseState
    from repro.sparse.formats import UniformIntDelay

    # the old pre-side spike ring is gone from the state pytree itself
    assert "spike_buffer" not in {f.name
                                  for f in dataclasses.fields(SynapseState)}

    s = ModelSpec("ring")
    s.add_neuron_population(
        "a", 48, "izhikevich",
        input_fn=lambda k, t, n: 6.0 * jax.random.normal(k, (n,)))
    s.add_neuron_population("b", 24, "izhikevich")
    s.add_synapse_population("ab", "a", "b", connect=FixedFanout(6),
                             weight=UniformWeight(0, 0.8),
                             delay=UniformIntDelay(0, 3))
    s.add_synapse_population("bb", "b", "b", connect=OneToOne(),
                             weight=0.2, delay_steps=2)
    eng = s.build(dt=1.0, seed=0, mesh=make_snn_mesh(_n_dev())).engine
    D = _n_dev()
    st = eng.init_state()
    for gname, dmax in [("ab", 3), ("bb", 2)]:
        assert eng._state_specs.syn[gname].dendritic == P(None, eng.axis)
        g = next(g for g in eng.net.synapses if g.name == gname)
        ring = st.syn[gname].dendritic
        npad = eng._npad[g.post]
        assert ring.shape == (dmax + 1, npad)           # post-sized, global
        assert ring.sharding.spec == P(None, eng.axis)
        shard_shapes = {sh.data.shape for sh in ring.addressable_shards}
        assert shard_shapes == {(dmax + 1, npad // D)}  # local post shard
    # heterogeneous delay slots ride the partitioned connectivity blocks
    assert eng._block_specs["ab"]["delay"] == P(eng.axis, None, None)
    assert "delay" not in eng._block_specs["bb"]        # homogeneous: none


def test_engine_gscale_validation_and_memory_report():
    cfg = IzhikevichNetConfig(n_total=64, n_conn=8, seed=0)
    _, eng = _pair(cfg)
    # the declarative front-end rejects unknown names before the engine...
    with pytest.raises(Exception, match="unknown"):
        eng.run(5, gscales={"typo": 1.0})
    # ...and the engine itself validates too (direct use)
    with pytest.raises(ValueError, match="unknown gscale"):
        eng.engine.run(5, gscales={"typo": 1.0})
    rep = eng.engine.memory_report()
    assert all("local_elements_per_device" in r for r in rep)
    for r in rep:
        assert r["n_shards"] == _n_dev()


def test_neuron_pad_and_axis_helpers():
    assert neuron_pad(10, 4) == 12
    assert neuron_pad(8, 4) == 8
    mesh = make_snn_mesh(1)
    assert snn_axis(mesh) == "neuron"
    from repro.launch.mesh import make_mesh
    assert snn_axis(make_mesh((1,), ("x",))) == "x"
    with pytest.raises(ValueError, match="neuron"):
        snn_axis(make_mesh((1, 1), ("a", "b")))


def _stdp_spec():
    s = ModelSpec("plastic")
    s.add_neuron_population(
        "a", 48, "izhikevich",
        input_fn=lambda k, t, n: 6.0 * jax.random.normal(k, (n,)))
    s.add_neuron_population("b", 24, "izhikevich")
    s.add_synapse_population("ab", "a", "b", connect=FixedFanout(6),
                             weight=UniformWeight(0, 0.8),
                             psm=ExpDecay(4.0), wum=STDP(0.01),
                             delay_steps=2)
    s.add_synapse_population("aa", "a", "a",
                             connect=FixedProbability(0.15),
                             weight=UniformWeight(0, 0.4),
                             wum=STDP(0.01))
    s.probe("tr", "ab", "x_pre", every=5)
    return s


def test_no_replicated_plastic_state_in_sharding_specs():
    """Acceptance contract: every per-neuron / per-synapse plastic state
    leaf in the engine's sharding specs is partitioned along the neuron
    axis — nothing plastic is replicated.  In particular the STDP
    `wu_pre` traces (formerly a full-size replicated read) are sharded
    along the pre axis."""
    from jax.sharding import PartitionSpec as P

    eng = _stdp_spec().build(dt=1.0, seed=5,
                             mesh=make_snn_mesh(_n_dev())).engine
    ax = eng.axis
    checked = 0
    for g in eng.net.synapses:
        specs = eng._state_specs.syn[g.name]
        for k, sp in specs.wu_pre.items():
            assert sp == P(ax), (g.name, "wu_pre", k, sp)
            checked += 1
        for k, sp in specs.wu_post.items():
            assert sp == P(ax), (g.name, "wu_post", k, sp)
        for k, sp in specs.syn.items():
            assert sp == P(ax, None, None), (g.name, "syn", k, sp)
        if g.plastic:
            assert specs.g == P(ax, None, None), (g.name, "g", specs.g)
    assert checked >= 2  # both STDP groups contribute a sharded pre trace
    # the actual allocated state is sharded the same way
    st = eng.init_state()
    D = _n_dev()
    for g in eng.net.synapses:
        for k, v in st.syn[g.name].wu_pre.items():
            assert v.sharding.spec == P(ax)
            shard_shapes = {sh.data.shape for sh in v.addressable_shards}
            assert shard_shapes == {(eng._npad[g.pre] // D,)}


def test_engine_stdp_sharded_pre_trace_exact():
    """The pre-axis-sharded wu_pre path (trace updated locally, gathered
    only for the learn rule) must match the single-device oracle bit for
    bit: spikes, probed traces, and the final wu_pre state leaf."""
    r1 = _stdp_spec().build(dt=1.0, seed=5).run(40)
    r2 = _stdp_spec().build(dt=1.0, seed=5,
                            mesh=make_snn_mesh(_n_dev())).run(40)
    for k in r1.spike_counts:
        assert np.array_equal(np.asarray(r1.spike_counts[k]),
                              np.asarray(r2.spike_counts[k])), k
    assert np.array_equal(np.asarray(r1.recordings["tr"]),
                          np.asarray(r2.recordings["tr"]))


def test_engine_fused_local_init_bit_exact():
    """init="device" + mesh takes the fused device_init_local path; the
    resulting run (STDP state and delay slots included) must be
    bit-exact vs the host device-init build."""
    r1 = _stdp_spec().build(dt=1.0, seed=9, init="device").run(30)
    r2 = _stdp_spec().build(dt=1.0, seed=9, init="device",
                            mesh=make_snn_mesh(_n_dev())).run(30)
    for k in r1.spike_counts:
        assert np.array_equal(np.asarray(r1.spike_counts[k]),
                              np.asarray(r2.spike_counts[k])), k
    assert np.array_equal(np.asarray(r1.recordings["tr"]),
                          np.asarray(r2.recordings["tr"]))


_SUBPROCESS = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, sys
    sys.path.insert(0, {src!r})
    import numpy as np
    import jax
    from repro.core.models.izhikevich_net import (IzhikevichNetConfig,
                                                  compile_model)
    from repro.launch.mesh import make_snn_mesh
    assert jax.device_count() == 8
    cfg = IzhikevichNetConfig(n_total=200, n_conn=40, seed=7)
    ref = compile_model(cfg).run(60)
    eng = compile_model(cfg, mesh=make_snn_mesh(8)).run(60)
    exact = all(
        np.array_equal(np.asarray(ref.spike_counts[k]),
                       np.asarray(eng.spike_counts[k]))
        for k in ref.spike_counts)
    # device-init graphs must not depend on device count either
    g1 = compile_model(cfg, init="device").network.synapses
    g8 = compile_model(cfg, mesh=make_snn_mesh(8),
                       init="device").network.synapses
    graphs = all(
        np.array_equal(np.asarray(a.ell.post_ind),
                       np.asarray(b.ell.post_ind))
        and np.array_equal(np.asarray(a.ell.g), np.asarray(b.ell.g))
        for a, b in zip(g1, g8))
    print(json.dumps({{"exact": exact, "graphs": graphs,
                       "finite": bool(eng.finite)}}))
""")


@pytest.mark.slow
def test_engine_8_device_subprocess():
    code = _SUBPROCESS.format(src=SRC)
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["exact"], "8-device engine diverged from single-device run"
    assert res["graphs"], "device-init graph depends on device count"
    assert res["finite"]
