"""Sharding rules + a small-mesh dry-run in a subprocess (8 fake devices so
the main test process keeps its single-device view)."""

import json
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import pytest
from jax.sharding import PartitionSpec as P

SRC = str(Path(__file__).resolve().parents[1] / "src")


def test_param_spec_rules_unit():
    """Rule allocation on synthetic leaves (no mesh devices needed beyond 1
    -- use the real helper with a fake mesh namespace)."""
    from repro.launch import sharding as SH

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 4, "model": 4}

    # moe w_gate [E=8, d=16, f=32]: E not divisible by 4? 8%4==0 -> expert
    spec = SH._alloc((8, 16, 32), ["model", "fsdp", "model"], FakeMesh())
    assert spec == P("model", "data", None)
    # mixtral-like E=6 (not divisible) -> ffn gets the model axis
    spec = SH._alloc((6, 16, 32), ["model", "fsdp", "model"], FakeMesh())
    assert spec == P(None, "data", "model")
    # stacked dense mlp [L, d, f]: stack dim never sharded
    spec = SH._alloc((5, 16, 32), ["fsdp", "model"], FakeMesh())
    assert spec == P(None, "data", "model")
    # non-divisible dims dropped
    spec = SH._alloc((7, 9), ["fsdp", "model"], FakeMesh())
    assert spec == P(None, None)


_SUBPROCESS = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, dataclasses
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    import sys
    sys.path.insert(0, {src!r})
    from repro.configs import ARCHS, reduced
    from repro.launch import sharding as SH
    from repro.models import transformer as T
    from repro.models import model as M
    from repro.configs.base import ShapeConfig

    from repro.launch.mesh import make_mesh
    mesh = make_mesh((2, 4), ("data", "model"))
    results = {{}}
    for arch in ["qwen2-0.5b", "granite-moe-1b-a400m", "mamba2-2.7b"]:
        cfg = dataclasses.replace(reduced(ARCHS[arch]), d_model=256,
                                  vocab=1024, n_kv=2)
        shape = ShapeConfig("t", 64, 8, "train")
        with SH.activate(mesh):
            ps = jax.eval_shape(lambda: T.init_params(
                cfg, jax.random.PRNGKey(0)))
            pshard = SH.spec_tree_to_shardings(
                SH.param_specs(ps, mesh), mesh)
            specs = M.input_specs(cfg, shape)
            bshard = SH.spec_tree_to_shardings(
                SH.batch_specs(specs["batch"], mesh), mesh)
            def loss(p, b):
                return T.loss_fn(p, cfg, b)[0]
            lowered = jax.jit(loss, in_shardings=(pshard, bshard)).lower(
                ps, specs["batch"])
            compiled = lowered.compile()
            txt = compiled.as_text()
        results[arch] = {{
            "compiled": True,
            "has_collectives": ("all-reduce" in txt or
                                 "all-gather" in txt),
        }}
    print(json.dumps(results))
""")


@pytest.mark.slow
def test_small_mesh_dryrun_subprocess():
    code = _SUBPROCESS.format(src=SRC)
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    for arch, r in res.items():
        assert r["compiled"], arch
        assert r["has_collectives"], arch
