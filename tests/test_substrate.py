"""Substrate: data pipeline, checkpoint manager, optimizer, fault tolerance,
straggler policy, gradient compression, scaling policy."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis or fallback

from repro.checkpoint.manager import CheckpointManager
from repro.core.scaling import probe_and_fit, probe_scale_for_fanin
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.optim import adamw, grad_compression as gc, schedule
from repro.runtime.fault_tolerance import (ElasticPlanner, FailureDetector,
                                           HeartbeatMonitor)
from repro.runtime.straggler import StragglerPolicy


# -- data ---------------------------------------------------------------------

def test_pipeline_deterministic_and_resumable():
    cfg = DataConfig(vocab=100, seq_len=32, global_batch=4, seed=7)
    a = TokenPipeline(cfg)
    b1 = a.next_batch()["tokens"]
    b2 = a.next_batch()["tokens"]
    b = TokenPipeline.restore(cfg, {"step": 1, "shard_index": 0,
                                    "num_shards": 1, "seed": 7})
    np.testing.assert_array_equal(np.asarray(b.next_batch()["tokens"]),
                                  np.asarray(b2))


def test_pipeline_shards_disjoint_and_cover():
    cfg = DataConfig(vocab=100, seq_len=16, global_batch=8, seed=1)
    whole = TokenPipeline(cfg).next_batch()["tokens"]
    parts = [TokenPipeline(cfg, shard_index=i, num_shards=4).next_batch()
             ["tokens"] for i in range(4)]
    np.testing.assert_array_equal(np.asarray(jnp.concatenate(parts)),
                                  np.asarray(whole))


def test_pipeline_elastic_reshard_consistent():
    """Rows depend on (seed, step, global_row) only - resharding after a
    failure reproduces the same global batch."""
    cfg = DataConfig(vocab=50, seq_len=8, global_batch=6, seed=3)
    before = TokenPipeline(cfg, 0, 1, start_step=5).next_batch()["tokens"]
    after = jnp.concatenate([
        TokenPipeline(cfg, i, 3, start_step=5).next_batch()["tokens"]
        for i in range(3)])
    np.testing.assert_array_equal(np.asarray(before), np.asarray(after))


# -- checkpoint --------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, max_to_keep=2, async_writes=False)
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    mgr.save(10, tree, blocking=True)
    assert mgr.latest_step() == 10
    like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    out = mgr.restore(10, like)
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.asarray(tree["a"]))


def test_checkpoint_retention_and_atomicity(tmp_path):
    mgr = CheckpointManager(tmp_path, max_to_keep=2, async_writes=False)
    for s in (1, 2, 3):
        mgr.save(s, {"x": jnp.ones(3) * s}, blocking=True)
    assert mgr.steps() == [2, 3]
    # a partial (manifest-less) dir must be invisible
    (tmp_path / "step_000000099").mkdir()
    assert mgr.latest_step() == 3


def test_checkpoint_detects_shape_mismatch(tmp_path):
    mgr = CheckpointManager(tmp_path, async_writes=False)
    mgr.save(1, {"x": jnp.ones((2, 2))}, blocking=True)
    with pytest.raises(ValueError):
        mgr.restore(1, {"x": jnp.ones((3, 3))})


# -- optimizer -----------------------------------------------------------------

def test_adamw_decreases_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw.init(cfg, params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(60):
        g = jax.grad(loss)(params)
        params, state, _ = adamw.update(cfg, g, state, params)
    assert float(loss(params)) < 0.5


def test_adamw_grad_clip():
    cfg = adamw.AdamWConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(3)}
    state = adamw.init(cfg, params)
    g = {"w": jnp.array([100.0, 0.0, 0.0])}
    _, _, m = adamw.update(cfg, g, state, params)
    assert float(m["grad_norm"]) == pytest.approx(100.0)


def test_bf16_params_keep_fp32_master():
    cfg = adamw.AdamWConfig(lr=1e-4)
    params = {"w": jnp.ones(4, jnp.bfloat16)}
    state = adamw.init(cfg, params)
    g = {"w": jnp.full(4, 1e-5, jnp.float32)}
    p2, s2, _ = adamw.update(cfg, g, state, params)
    # master moves even when bf16 param quantizes the step away
    assert float(jnp.max(jnp.abs(s2.master["w"] - 1.0))) > 0
    assert p2["w"].dtype == jnp.bfloat16


def test_schedules():
    s = schedule.warmup_cosine(1.0, warmup=10, total=100)
    assert float(s(0)) == 0.0
    assert float(s(10)) == pytest.approx(1.0, abs=0.02)
    assert float(s(100)) == pytest.approx(0.0, abs=1e-6)


# -- fault tolerance -----------------------------------------------------------

def test_heartbeat_and_failure_detection():
    t = [0.0]
    mon = HeartbeatMonitor(["h0", "h1"], timeout_s=10.0,
                           clock=lambda: t[0])
    det = FailureDetector(mon)
    t[0] = 5.0
    mon.beat("h0")
    t[0] = 12.0
    events = det.poll(step=7)
    assert [e.host for e in events] == ["h1"]
    assert det.poll(step=8) == []   # reported once


def test_elastic_planner_shrinks_mesh():
    pl = ElasticPlanner(devices_per_host=4, model_parallel=4,
                        global_batch=64)
    plan = pl.plan([f"h{i}" for i in range(6)], ["h6", "h7"],
                   restore_step=120)
    assert plan.mesh_shape[1] == 4          # model width preserved
    assert 64 % plan.mesh_shape[0] == 0     # batch divisible
    assert plan.restore_step == 120
    assert plan.n_devices <= 24


def test_elastic_planner_refuses_below_model_width():
    pl = ElasticPlanner(devices_per_host=1, model_parallel=8,
                        global_batch=8)
    with pytest.raises(RuntimeError):
        pl.plan(["h0", "h1"], [], None)


def test_straggler_policy_tiers():
    pol = StragglerPolicy(window=8, slow_factor=1.5, evict_factor=3.0,
                          min_observations=3)
    for i in range(5):
        for h in ("fast", "fast2", "fast3"):   # majority healthy
            pol.observe(h, 1.0)
        pol.observe("slow", 2.0)
        pol.observe("dead", 10.0)
    d = {x.host: x for x in pol.directives()}
    assert d["slow"].action == "rebalance" and 0 < d["slow"].ratio <= 0.5
    assert d["dead"].action == "evict"
    assert "fast" not in d


# -- gradient compression --------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(10, 5000))
def test_property_error_feedback_unbiased(seed, n):
    """Quantize-with-residual: value + error carries full information —
    compressing x with error e, deq + new_err == x + e exactly."""
    r = np.random.default_rng(seed)
    g = jnp.asarray(r.standard_normal(n) * r.uniform(0.1, 10), jnp.float32)
    e = jnp.asarray(r.standard_normal(n) * 0.01, jnp.float32)
    q, s, new_e = gc.compress_leaf(g, e)
    deq = gc.decompress_leaf(q, s, g.shape)
    np.testing.assert_allclose(np.asarray(deq + new_e), np.asarray(g + e),
                               rtol=1e-5, atol=1e-5)


def test_compression_ratio_int8():
    g = jnp.ones((4096,), jnp.float32)
    q, s, _ = gc.compress_leaf(g, jnp.zeros_like(g))
    assert q.dtype == jnp.int8
    ratio = g.nbytes / (q.nbytes + s.nbytes)
    assert ratio > 3.5


# -- paper scaling policy on LM side ------------------------------------------

def test_probe_scale_tracks_inverse_sqrt():
    k = jax.random.PRNGKey(0)
    s64 = probe_scale_for_fanin(k, 64)
    s1024 = probe_scale_for_fanin(k, 1024)
    # dense Gaussian: scale ~ 1/sqrt(fan_in) -> ratio ~ 4
    assert 2.5 < s64 / s1024 < 6.0


def test_probe_and_fit_policy_usable():
    pol = probe_and_fit(jax.random.PRNGKey(1), fanins=(64, 256, 1024))
    s = pol.init_std(512)
    assert 0.0 < s < 1.0
    assert pol.residual_std(512, n_layers=10) < s
