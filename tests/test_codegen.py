"""Codegen DSL: validation, rewriting, generated update semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import codegen
from repro.core.codegen import CodegenError, NeuronModel, compile_sim


def _simple(sim="V = V + dt*Isyn", thr="V >= 1.0", reset="V = 0.0"):
    return NeuronModel(name="m", state={"V": 0.0}, params={},
                       sim_code=sim, threshold_code=thr, reset_code=reset)


def test_basic_update_and_reset():
    upd = compile_sim(_simple())
    state = {"V": jnp.array([0.5, 0.95])}
    ext = {"Isyn": jnp.array([0.1, 0.1]), "dt": jnp.float32(1.0),
           "t": jnp.float32(0.0)}
    new, spiked = upd(state, {}, ext)
    np.testing.assert_allclose(np.asarray(spiked), [False, True])
    np.testing.assert_allclose(np.asarray(new["V"]), [0.6, 0.0], atol=1e-6)


def test_reset_only_applies_where_spiked():
    m = NeuronModel(name="m", state={"V": 0.0, "U": 0.0}, params={"d": 2.0},
                    sim_code="V = V + Isyn", threshold_code="V > 1.0",
                    reset_code="U = U + d")
    upd = compile_sim(m)
    new, spiked = upd({"V": jnp.array([0.5, 2.0]), "U": jnp.zeros(2)},
                      {"d": 2.0},
                      {"Isyn": jnp.zeros(2), "dt": jnp.float32(1.0),
                       "t": jnp.float32(0.0)})
    np.testing.assert_allclose(np.asarray(new["U"]), [0.0, 2.0])


def test_temporaries_allowed():
    m = NeuronModel(name="m", state={"V": 0.0}, params={},
                    sim_code="tmp = Isyn * 2.0\nV = V + tmp",
                    threshold_code="V > 1.0")
    upd = compile_sim(m)
    new, _ = upd({"V": jnp.zeros(3)}, {},
                 {"Isyn": jnp.ones(3), "dt": jnp.float32(1.0),
                  "t": jnp.float32(0.0)})
    np.testing.assert_allclose(np.asarray(new["V"]), 2.0)


def test_bool_ops_rewritten():
    m = NeuronModel(name="m", state={"V": 0.0}, params={},
                    sim_code="V = V + Isyn",
                    threshold_code="(V > 1.0) and (V < 3.0)")
    upd = compile_sim(m)
    _, spk = upd({"V": jnp.array([0.0, 1.5, 4.0])}, {},
                 {"Isyn": jnp.zeros(3), "dt": jnp.float32(1.0),
                  "t": jnp.float32(0.0)})
    np.testing.assert_array_equal(np.asarray(spk), [False, True, False])


@pytest.mark.parametrize("bad", [
    "import os",
    "__import__('os')",
    "open('/etc/passwd')",
    "V.__class__",
    "[x for x in V]",
    "exec('1')",
    "V[0] = 1.0",
])
def test_rejects_malicious_code(bad):
    with pytest.raises((CodegenError, SyntaxError)):
        compile_sim(_simple(sim=bad))


def test_rejects_unknown_names():
    with pytest.raises(CodegenError):
        compile_sim(_simple(sim="V = V + mystery"))


def test_needs_rand_detection():
    m = NeuronModel(name="m", state={"x": 0.0}, params={},
                    sim_code="x = rand", threshold_code="x < 0.5")
    assert m.needs_rand
    assert not _simple().needs_rand


def test_generated_source_readable():
    src = codegen.generated_source(_simple())
    assert "def update_m" in src and "V" in src


def test_jit_and_vmap_compatible():
    upd = compile_sim(_simple())

    @jax.jit
    def step(v, isyn):
        new, spk = upd({"V": v}, {}, {"Isyn": isyn,
                                      "dt": jnp.float32(1.0),
                                      "t": jnp.float32(0.0)})
        return new["V"], spk

    v, s = step(jnp.zeros(4), jnp.ones(4) * 2.0)
    assert bool(jnp.all(s))
