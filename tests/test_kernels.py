"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref as R
from repro.kernels.ell_spmv import ell_spmv_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.flash_xla import flash_attention_xla
from repro.kernels.hh_step import hh_step_pallas
from repro.kernels.izhikevich_step import izhikevich_step_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas
from repro.models.ssm import ssd_chunked

RNG = np.random.default_rng(42)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n_pre,k,n_post,b", [
    (16, 4, 32, 1), (64, 16, 100, 4), (200, 50, 333, 2), (128, 128, 512, 8),
])
def test_ell_spmv_matches_ref(n_pre, k, n_post, b):
    g = RNG.standard_normal((n_pre, k)).astype(np.float32)
    idx = RNG.integers(0, n_post, (n_pre, k)).astype(np.int32)
    valid = RNG.random((n_pre, k)) < 0.8
    spk = (RNG.random((b, n_pre)) < 0.2).astype(np.float32)
    ref = R.ell_spmv_ref(jnp.asarray(g), jnp.asarray(idx),
                         jnp.asarray(valid), jnp.asarray(spk), n_post)
    out = ell_spmv_pallas(jnp.asarray(g), jnp.asarray(idx),
                          jnp.asarray(valid), jnp.asarray(spk),
                          n_post=n_post, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n,dt", [(100, 1.0), (1000, 0.5), (4096, 1.0)])
def test_izhikevich_step_matches_ref(n, dt):
    v = RNG.uniform(-80, 25, n).astype(np.float32)
    u = RNG.uniform(-20, 5, n).astype(np.float32)
    isyn = (RNG.standard_normal(n) * 5).astype(np.float32)
    a = np.full(n, 0.02, np.float32)
    b = np.full(n, 0.2, np.float32)
    c = np.full(n, -65.0, np.float32)
    d = np.full(n, 8.0, np.float32)
    args = tuple(map(jnp.asarray, (v, u, isyn, a, b, c, d)))
    rv, ru, rs = R.izhikevich_step_ref(*args, dt)
    pv, pu, ps = izhikevich_step_pallas(*args, dt=dt, interpret=True)
    np.testing.assert_allclose(np.asarray(pv), np.asarray(rv),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(pu), np.asarray(ru),
                               rtol=2e-4, atol=2e-4)
    # spike decisions may only differ within float noise of the threshold
    diff = np.asarray(ps) != np.asarray(rs)
    assert diff.mean() < 0.002


@pytest.mark.parametrize("n,substeps", [(128, 1), (1000, 5)])
def test_hh_step_matches_ref(n, substeps):
    v = RNG.uniform(-80, 30, n).astype(np.float32)
    m = RNG.random(n).astype(np.float32)
    h = RNG.random(n).astype(np.float32)
    nn = RNG.random(n).astype(np.float32)
    isyn = (RNG.standard_normal(n) * 2).astype(np.float32)
    args = tuple(map(jnp.asarray, (v, m, h, nn, isyn)))
    ref = R.hh_step_ref(*args, 0.1, substeps=substeps)
    out = hh_step_pallas(*args, dt=0.1, substeps=substeps, interpret=True)
    for a, b in zip(out, ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
ATTN_CASES = [
    # b, hq, hkv, tq, tk, d, causal, window, softcap, prefix
    (1, 4, 2, 256, 256, 64, True, None, None, None),
    (2, 2, 1, 128, 256, 32, True, 64, None, None),
    (1, 2, 2, 256, 256, 64, True, None, 30.0, None),
    (1, 2, 2, 256, 256, 64, True, None, None, 100),
    (2, 4, 4, 200, 200, 64, False, None, None, None),
]


@pytest.mark.parametrize("case", ATTN_CASES)
def test_flash_pallas_matches_ref(case):
    b, hq, hkv, tq, tk, d, causal, window, softcap, prefix = case
    q = jnp.asarray(RNG.standard_normal((b, hq, tq, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, hkv, tk, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, hkv, tk, d)), jnp.float32)
    qoff = tk - tq
    ref = R.flash_attention_ref(q, k, v, causal=causal, window=window,
                                softcap=softcap, prefix=prefix,
                                q_offset=qoff)
    out = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 softcap=softcap, prefix=prefix,
                                 q_offset=qoff, q_block=128, k_block=128,
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("case", ATTN_CASES[:3])
def test_flash_xla_grads_match_autodiff(case):
    b, hq, hkv, tq, tk, d, causal, window, softcap, prefix = case
    tq = min(tq, 96)
    tk = min(tk, 96)
    q = jnp.asarray(RNG.standard_normal((b, hq, tq, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, hkv, tk, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, hkv, tk, d)), jnp.float32)

    def f_ref(q, k, v):
        return R.flash_attention_ref(
            q, k, v, causal=causal, window=window, softcap=softcap,
            prefix=prefix).sum()

    def f_fl(q, k, v):
        return flash_attention_xla(q, k, v, causal, window, None, 0,
                                   softcap, prefix, 32, 32).sum()

    g_ref = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    g_fl = jax.grad(f_fl, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g_fl, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=5e-4, atol=5e-5)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,t,h,dh,ds,chunk", [
    (2, 128, 4, 16, 16, 32), (1, 256, 8, 32, 32, 64), (2, 64, 2, 8, 64, 64),
])
def test_ssd_chunked_and_pallas_match_naive(b, t, h, dh, ds, chunk):
    x = jnp.asarray(RNG.standard_normal((b, t, h, dh)), jnp.float32)
    dt = jnp.asarray(0.001 + 0.1 * RNG.random((b, t, h)), jnp.float32)
    A = jnp.asarray(-np.exp(RNG.uniform(0, 2, h)), jnp.float32)
    B = jnp.asarray(RNG.standard_normal((b, t, 1, ds)), jnp.float32)
    C = jnp.asarray(RNG.standard_normal((b, t, 1, ds)), jnp.float32)
    D = jnp.asarray(RNG.standard_normal(h), jnp.float32)
    ref = R.ssd_scan_ref(x, dt, A, B, C, D)
    chk = ssd_chunked(x, dt, A, B, C, D, chunk=chunk)
    pls = ssd_scan_pallas(x, dt, A, B, C, D, chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(chk), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(pls), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_ssd_state_carry_matches_two_halves():
    """Chunked SSD with initial_state == running the halves back to back."""
    b, t, h, dh, ds = 1, 128, 2, 8, 16
    x = jnp.asarray(RNG.standard_normal((b, t, h, dh)), jnp.float32)
    dt = jnp.asarray(0.01 + 0.05 * RNG.random((b, t, h)), jnp.float32)
    A = jnp.asarray([-1.0, -2.0], jnp.float32)
    B = jnp.asarray(RNG.standard_normal((b, t, 1, ds)), jnp.float32)
    C = jnp.asarray(RNG.standard_normal((b, t, 1, ds)), jnp.float32)
    full = ssd_chunked(x, dt, A, B, C, None, chunk=32)
    y1, s1 = ssd_chunked(x[:, :64], dt[:, :64], A, B[:, :64], C[:, :64],
                         None, chunk=32, return_final_state=True)
    y2 = ssd_chunked(x[:, 64:], dt[:, 64:], A, B[:, 64:], C[:, 64:],
                     None, chunk=32, initial_state=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(full), rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# occupancy-based tile selection for the ELL spmv (paper §3)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_pre,k,n_post,b", [
    (16, 4, 32, 1), (1000, 100, 1000, 1), (1000, 1000, 1000, 16),
    (100_000, 100, 100_000, 1), (8192, 512, 8192, 8),
    (50_000, 1000, 50_000, 4),
])
def test_spmv_chosen_tiles_are_vmem_feasible(n_pre, k, n_post, b):
    """The autotuned (bp, bn) must fit VMEM with Mosaic's double buffering
    and stay hardware-aligned — for every shape, including the paper's
    scalability-study sizes."""
    from repro.kernels.autotune import (V5E, choose_block_spmv,
                                        spmv_block_bytes)
    cfg = choose_block_spmv(n_pre, k, n_post, b)
    assert cfg["feasible"]
    assert cfg["bn"] % V5E.lane == 0
    assert cfg["bp"] % V5E.sublane_f32 == 0
    need = spmv_block_bytes(cfg["bp"], cfg["bn"], k, b) * V5E.double_buffer
    assert need <= V5E.vmem_bytes, (cfg, need)


def test_spmv_wide_k_chunks_to_feasible_tiles():
    """K beyond the one-hot kernel's full-row VMEM limit must be flagged
    infeasible and split into chunks whose tiling fits (e.g. the row widths
    FixedProbability produces at p=0.05, n_post=100k)."""
    from repro.kernels.autotune import (V5E, choose_block_spmv,
                                        spmv_block_bytes)
    from repro.kernels.ell_spmv import feasible_k_chunk
    wide = choose_block_spmv(10_000, 5000, 100_000, 1)
    assert not wide["feasible"]
    kc, cfg = feasible_k_chunk(10_000, 5000, 100_000, 1)
    assert kc < 5000 and cfg["feasible"]
    need = spmv_block_bytes(cfg["bp"], cfg["bn"], kc, 1) * V5E.double_buffer
    assert need <= V5E.vmem_bytes


def test_spmv_pallas_wide_k_correct():
    """Interpret-mode end to end through the K-chunked launch path."""
    n_pre, k, n_post, b = 24, 5000, 64, 2
    g = RNG.standard_normal((n_pre, k)).astype(np.float32)
    idx = RNG.integers(0, n_post, (n_pre, k)).astype(np.int32)
    valid = RNG.random((n_pre, k)) < 0.5
    spk = (RNG.random((b, n_pre)) < 0.4).astype(np.float32)
    ref = R.ell_spmv_ref(jnp.asarray(g), jnp.asarray(idx),
                         jnp.asarray(valid), jnp.asarray(spk), n_post)
    out = ell_spmv_pallas(jnp.asarray(g), jnp.asarray(idx),
                          jnp.asarray(valid), jnp.asarray(spk),
                          n_post=n_post, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_default_blocks_routes_through_autotune():
    from repro.kernels.autotune import choose_block_spmv
    from repro.kernels.ell_spmv import default_blocks
    for shape in [(64, 16, 100, 4), (4096, 128, 4096, 2)]:
        cfg = choose_block_spmv(*shape)
        assert default_blocks(*shape) == (cfg["bp"], cfg["bn"])


def test_spmv_pallas_correct_with_autotuned_blocks():
    """End to end: interpret-mode kernel with the chosen tiles == oracle."""
    n_pre, k, n_post, b = 96, 24, 260, 3
    g = RNG.standard_normal((n_pre, k)).astype(np.float32)
    idx = RNG.integers(0, n_post, (n_pre, k)).astype(np.int32)
    valid = RNG.random((n_pre, k)) < 0.7
    spk = (RNG.random((b, n_pre)) < 0.3).astype(np.float32)
    ref = R.ell_spmv_ref(jnp.asarray(g), jnp.asarray(idx),
                         jnp.asarray(valid), jnp.asarray(spk), n_post)
    out = ell_spmv_pallas(jnp.asarray(g), jnp.asarray(idx),
                          jnp.asarray(valid), jnp.asarray(spk),
                          n_post=n_post, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
