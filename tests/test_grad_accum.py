"""Gradient accumulation (microbatches) must preserve the training step."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, reduced
from repro.launch.dryrun import make_train_step
from repro.models import transformer as T
from repro.optim import adamw


def test_microbatched_step_matches_full_batch():
    cfg1 = dataclasses.replace(reduced(ARCHS["qwen2-0.5b"]), microbatches=1)
    cfg4 = dataclasses.replace(cfg1, microbatches=4)
    params = T.init_params(cfg1, jax.random.PRNGKey(0))
    ocfg = adamw.AdamWConfig(lr=1e-3)
    opt = adamw.init(ocfg, params)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg1.vocab, (8, 33)),
                                   jnp.int32)}

    p1, _, m1 = jax.jit(make_train_step(cfg1))(params, opt, batch)
    p4, _, m4 = jax.jit(make_train_step(cfg4))(params, opt, batch)

    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=5e-5)
