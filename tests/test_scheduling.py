"""SlotScheduler / RequestTiming unit tests.

The slot scheduler is the shared continuous-batching core of both serving
front-ends (launch/serve.py and launch/snn_serve.py); until now it was only
covered indirectly through tests/test_serving.py.  These tests pin down the
direct contract: FIFO admission under contention, slot reuse after release,
and the per-request wall-clock accounting.
"""

import dataclasses

import pytest

from repro.launch.scheduling import RequestTiming, SlotScheduler


@dataclasses.dataclass
class Req:
    rid: int


def test_constructor_rejects_nonpositive_slot_counts():
    for bad in (0, -3):
        with pytest.raises(ValueError, match="max_slots"):
            SlotScheduler(bad)


def test_fifo_admission_under_contention():
    """More queued requests than slots: admission is FIFO, fills exactly
    the free slots (lowest slot first), and leaves the rest queued."""
    sched = SlotScheduler(2)
    reqs = [Req(i) for i in range(5)]
    for r in reqs:
        sched.submit(r)
    assigned = sched.admit()
    assert [(s, r.rid) for s, r in assigned] == [(0, 0), (1, 1)]
    assert [r.rid for r in sched.queue] == [2, 3, 4]
    assert sched.admit() == []                  # no free slots -> no-op
    assert sched.free_slots == []
    assert sched.has_work()


def test_release_frees_slot_and_next_admit_refills_it():
    """Continuous batching: a finishing request frees its slot for the
    head of the queue while other slots keep running."""
    sched = SlotScheduler(2)
    for i in range(4):
        sched.submit(Req(i))
    sched.admit()
    done = sched.release(0)                     # rid 0 finishes first
    assert done.rid == 0
    assert sched.free_slots == [0]
    assert sched.active[1].rid == 1             # slot 1 untouched
    assigned = sched.admit()
    assert [(s, r.rid) for s, r in assigned] == [(0, 2)]
    # eviction order follows completion order, not slot order
    assert sched.release(1).rid == 1
    assert sched.release(0).rid == 2
    assigned = sched.admit()                    # one request, two free slots
    assert [(s, r.rid) for s, r in assigned] == [(0, 3)]
    sched.release(0)
    assert not sched.has_work()


def test_release_of_free_slot_raises():
    sched = SlotScheduler(1)
    with pytest.raises(KeyError):
        sched.release(0)


def test_duplicate_rid_rejected_until_forgotten():
    sched = SlotScheduler(1)
    sched.submit(Req(7))
    with pytest.raises(ValueError, match="duplicate request rid"):
        sched.submit(Req(7))
    sched.admit()
    sched.release(0)
    sched.forget(7)
    sched.submit(Req(7))                        # recycled after forget
    assert [r.rid for r in sched.queue] == [7]


def test_forget_keeps_unfinished_timings():
    """forget() must not drop accounting for queued/in-flight requests —
    only finished ones (their latency has been fully measured)."""
    sched = SlotScheduler(1)
    sched.submit(Req(0))
    sched.forget(0)                             # queued: kept
    assert 0 in sched.timings
    sched.admit()
    sched.forget(0)                             # in flight: kept
    assert 0 in sched.timings
    sched.release(0)
    sched.forget(0)                             # finished: dropped
    assert 0 not in sched.timings


def test_request_timing_milestones_and_accounting():
    sched = SlotScheduler(1)
    sched.submit(Req(0))
    sched.submit(Req(1))
    t0 = sched.timings[0]
    assert t0.admitted_at is None and t0.queue_wait_s is None
    assert t0.service_s is None and t0.total_s is None

    sched.admit()                               # rid 0 enters the slot
    t1 = sched.timings[1]
    assert t0.admitted_at is not None and t1.admitted_at is None
    assert t0.queue_wait_s >= 0.0

    sched.release(0)
    sched.admit()                               # rid 1 waited one service
    sched.release(0)
    for t in (sched.timings[0], sched.timings[1]):
        assert t.finished_at is not None
        assert t.service_s >= 0.0
        assert t.total_s >= t.service_s          # total includes queue wait
        assert abs(t.total_s - (t.queue_wait_s + t.service_s)) < 1e-9
    # rid 1 could not be admitted before rid 0 finished
    assert sched.timings[1].admitted_at >= sched.timings[0].finished_at

    summary = sched.latency_summary()
    assert summary["finished"] == 2
    assert summary["max_total_s"] >= summary["mean_total_s"] >= 0.0
    assert summary["mean_queue_wait_s"] >= 0.0


def test_latency_summary_empty_and_partial():
    sched = SlotScheduler(2)
    assert sched.latency_summary() == {"finished": 0, "evicted": 0}
    sched.submit(Req(0))
    sched.submit(Req(1))
    sched.admit()
    sched.release(0)                            # only rid 0 finished
    assert sched.latency_summary()["finished"] == 1


def test_timing_dataclass_properties_standalone():
    t = RequestTiming(submitted_at=10.0)
    assert t.queue_wait_s is None and t.service_s is None
    t.admitted_at = 12.5
    assert t.queue_wait_s == 2.5 and t.service_s is None
    t.finished_at = 20.0
    assert t.service_s == 7.5 and t.total_s == 10.0


# ---------------------------------------------------------------------------
# gateway primitives: priority, eviction, deadlines, slot re-packing
# ---------------------------------------------------------------------------

class FakeClock:
    """Injectable clock so deadline logic is deterministic under test."""

    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


def test_priority_admission_is_stable_within_class():
    """Lower priority value runs first; equal priorities stay FIFO — the
    default 0 everywhere must degrade to the plain FIFO the older servers
    were built against."""
    sched = SlotScheduler(4)
    sched.submit(Req(0), priority=1)
    sched.submit(Req(1), priority=0)
    sched.submit(Req(2), priority=1)
    sched.submit(Req(3), priority=0)
    assert [r.rid for r in sched.queue] == [1, 3, 0, 2]
    assigned = sched.admit()
    assert [r.rid for _, r in assigned] == [1, 3, 0, 2]


def test_evict_queued_request_never_admitted():
    """A queued-but-unadmitted request can be evicted: it leaves the queue,
    is stamped evicted (not completed), and never occupies a slot."""
    sched = SlotScheduler(1)
    sched.submit(Req(0))
    sched.submit(Req(1))
    sched.admit()                               # rid 0 takes the only slot
    assert sched.evict(1).rid == 1              # rid 1 still queued
    assert sched.queue == []
    t = sched.timings[1]
    assert t.evicted and t.finished_at is not None
    assert t.admitted_at is None                # never ran
    assert sched.evicted_total == 1
    assert sched.latency_summary() == {"finished": 0, "evicted": 1}


def test_evict_active_request_frees_slot_for_next_admit():
    sched = SlotScheduler(1)
    sched.submit(Req(0))
    sched.submit(Req(1))
    sched.admit()
    assert sched.evict(0).rid == 0              # mid-flight eviction
    assert sched.free_slots == [0]
    assert [r.rid for _, r in sched.admit()] == [1]


def test_evict_is_double_finish_safe():
    """Deadline sweeps race with completions: evicting a finished, already
    evicted, or unknown rid must be a no-op returning None."""
    sched = SlotScheduler(1)
    sched.submit(Req(0))
    sched.admit()
    sched.release(0)                            # completed normally
    assert sched.evict(0) is None               # raced: no double accounting
    assert sched.evicted_total == 0
    sched.submit(Req(1))
    assert sched.evict(1).rid == 1
    assert sched.evict(1) is None               # double evict: no-op
    assert sched.evicted_total == 1
    assert sched.evict(999) is None             # never submitted


def test_evicted_timing_consistency_and_forget():
    """Evicted requests get finished_at stamped (so forget() prunes them)
    but are excluded from completion-latency averages."""
    clk = FakeClock()
    sched = SlotScheduler(2, clock=clk)
    sched.submit(Req(0))
    sched.submit(Req(1))
    sched.admit()
    clk.advance(1.0)
    sched.release(0)                            # completes at t=1
    sched.evict(1)                              # evicted at t=1
    s = sched.latency_summary()
    assert s["finished"] == 1 and s["evicted"] == 1
    assert s["mean_total_s"] == pytest.approx(1.0)
    t1 = sched.timings[1]
    assert t1.evicted_at == t1.finished_at == 1.0
    sched.forget(1)                             # evicted => prunable
    assert 1 not in sched.timings


def test_expired_lists_queued_and_active_past_deadline():
    clk = FakeClock()
    sched = SlotScheduler(1, clock=clk)
    sched.submit(Req(0), deadline_at=5.0)       # will be active
    sched.submit(Req(1), deadline_at=2.0)       # stays queued
    sched.submit(Req(2))                        # no deadline: never expires
    sched.admit()
    assert sched.expired() == []                # t=0: nothing expired
    clk.advance(3.0)
    assert [r.rid for r in sched.expired()] == [1]
    clk.advance(3.0)                            # t=6: both past deadline
    assert sorted(r.rid for r in sched.expired()) == [0, 1]
    for r in sched.expired():
        sched.evict(r.rid)
    assert sched.expired() == []                # sweep converges
    assert [r.rid for r in sched.queue] == [2]


def test_move_and_resize_compact_then_shrink():
    """The elastic-capacity shrink: compact active slots low, then resize;
    shrinking with a stranded active slot must raise."""
    sched = SlotScheduler(4)
    for i in range(3):
        sched.submit(Req(i))
    sched.admit()                               # slots 0,1,2 active
    sched.release(0)
    sched.release(1)                            # only slot 2 active
    with pytest.raises(ValueError, match="stranded"):
        sched.resize(2)
    with pytest.raises(ValueError, match="occupied"):
        sched.move(2, 2)
    sched.move(2, 0)
    assert sched.active[0].rid == 2
    sched.resize(2)
    assert sched.max_slots == 2
    assert sched.free_slots == [1]
    with pytest.raises(ValueError, match="positive"):
        sched.resize(0)
    sched.resize(8)                             # growing is always safe
    assert len(sched.free_slots) == 7
