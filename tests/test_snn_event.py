"""Event-driven propagation: bit-exactness, packing, and the propagation= API.

The event path's whole contract is that it is an *optimization, not an
approximation*: compacting the spiking pre rows (with a dense fallback on
capacity overflow), fusing the delay scatter into one kernel, and packing
spikes into uint32 bitmasks for exchange/storage must all reproduce the
dense path bit for bit.  These tests pin that down against a numpy
event-queue oracle (integer weights -> exact float arithmetic), across the
overflow boundary, through full simulations with delays + STDP, across
1-vs-8-device runs, and through the packed spikes-probe ring.
"""

import json
import subprocess
import sys
import textwrap
import warnings
from pathlib import Path

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _hypothesis_compat import given, settings, st  # hypothesis or fallback
from repro import flags
from repro.core.snn import bitmask as BM
from repro.core.snn.errors import SpecError
from repro.core.snn.spec import ModelSpec
from repro.core.snn.synapses import (STDP, ExpDecay, LocalConnectivity,
                                     SynapseGroup)
from repro.kernels import ops as kops
from repro.sparse import formats as F

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _int_ell(rng, n_pre, k, n_post, n_slots=1, with_delay=False):
    """Random ELL with small integer weights: float adds are exact, so any
    reordering/fallback bug shows as hard inequality, not tolerance noise."""
    post_ind = rng.integers(0, n_post, (n_pre, k)).astype(np.int32)
    g = rng.integers(1, 8, (n_pre, k)).astype(np.float32)
    valid = rng.random((n_pre, k)) < 0.8
    delay = (rng.integers(0, n_slots, (n_pre, k)).astype(np.int32)
             if with_delay else None)
    if delay is not None:
        delay = np.where(valid, delay, 0).astype(np.int32)
    return F.triple_to_ell(np.where(valid, post_ind, 0).astype(np.int32),
                           np.where(valid, g, 0).astype(np.float32),
                           valid, n_post, delay=delay)


# ---------------------------------------------------------------------------
# numpy event-queue oracle: the fused delay kernel vs literal per-spike
# queue insertion
# ---------------------------------------------------------------------------

def test_fused_delay_matches_numpy_event_queue_oracle():
    rng = np.random.default_rng(0)
    n_pre, k, n_post, n_slots, T = 40, 8, 32, 6, 30
    ell = _int_ell(rng, n_pre, k, n_post, n_slots=n_slots, with_delay=True)
    raster = rng.random((T, n_pre)) < 0.15

    # oracle: per spiking pre neuron, push g onto the (t+delay) queue row
    queue = np.zeros((T + n_slots, n_post), np.float32)
    pi = np.asarray(ell.post_ind)
    gv = np.asarray(ell.g)
    vv = np.asarray(ell.valid)
    dv = np.asarray(ell.delay)
    for t in range(T):
        for i in np.nonzero(raster[t])[0]:
            for kk in range(k):
                if vv[i, kk]:
                    queue[t + dv[i, kk], pi[i, kk]] += gv[i, kk]

    # fused kernel: one [n_slots, n_post] scatter per step
    arrived = np.zeros_like(queue)
    for t in range(T):
        contrib = np.asarray(kops.ell_spmv_delay(
            ell, jnp.asarray(raster[t], jnp.float32), n_slots))
        arrived[t:t + n_slots] += contrib
    assert np.array_equal(arrived, queue)

    # event-driven fused kernel: identical again (integer weights -> exact)
    cap = int(np.max(raster.sum(axis=1))) + 2
    arrived_ev = np.zeros_like(queue)
    for t in range(T):
        contrib = np.asarray(kops.ell_spmv_event_delay(
            ell, jnp.asarray(raster[t], jnp.float32), n_slots, cap))
        arrived_ev[t:t + n_slots] += contrib
    assert np.array_equal(arrived_ev, queue)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), rate=st.floats(0.0, 0.6))
def test_event_spmv_bitexact_vs_dense(seed, rate):
    """Compaction never changes a bit, at any activity level, including the
    all-silent and near-dense extremes (random float weights this time —
    the per-post accumulation order must be preserved, not just the sums)."""
    rng = np.random.default_rng(seed)
    n_pre, k, n_post = 60, 7, 48
    post_ind = rng.integers(0, n_post, (n_pre, k)).astype(np.int32)
    g = rng.standard_normal((n_pre, k)).astype(np.float32)
    valid = rng.random((n_pre, k)) < 0.9
    ell = F.triple_to_ell(post_ind, g, valid, n_post)
    spk = jnp.asarray(rng.random(n_pre) < rate, jnp.float32)
    dense = kops.ell_spmv(ell, spk)
    for cap in (8, n_pre // 2, n_pre):
        ev = kops.ell_spmv_event(ell, spk, cap)
        if kops.backend() == "ref":
            # ref scatter-adds in ascending pre order on both paths: exact
            assert np.array_equal(np.asarray(ev), np.asarray(dense)), cap
        else:
            # compaction changes the tile shapes the MXU dot reduces over,
            # so cross-shape sums round differently by ~1 ulp
            np.testing.assert_allclose(np.asarray(ev), np.asarray(dense),
                                       rtol=1e-5, atol=1e-5)


def test_event_overflow_boundary():
    """count == capacity stays on the event path; count == capacity + 1
    falls back to the dense pass — both bit-exact vs dense."""
    rng = np.random.default_rng(3)
    n_pre, k, n_post = 32, 5, 24
    ell = _int_ell(rng, n_pre, k, n_post)
    for n_spk in (10, 11):
        spikes = np.zeros(n_pre, np.float32)
        spikes[rng.choice(n_pre, n_spk, replace=False)] = 1.0
        spk = jnp.asarray(spikes)
        dense = kops.ell_spmv(ell, spk)
        at_cap = kops.ell_spmv_event(ell, spk, 10)
        assert np.array_equal(np.asarray(at_cap), np.asarray(dense)), n_spk


def test_fused_delay_matches_masked_pass_loop():
    """The fused kernel replaces S+1 masked single-delay passes; per slot
    it must reproduce each masked pass bit for bit (random float weights)."""
    rng = np.random.default_rng(5)
    n_pre, k, n_post, n_slots = 48, 6, 40, 5
    post_ind = rng.integers(0, n_post, (n_pre, k)).astype(np.int32)
    g = rng.standard_normal((n_pre, k)).astype(np.float32)
    valid = rng.random((n_pre, k)) < 0.85
    delay = np.where(valid, rng.integers(0, n_slots, (n_pre, k)), 0)
    ell = F.triple_to_ell(post_ind, g, valid, n_post,
                          delay=delay.astype(np.int32))
    spk = jnp.asarray(rng.random(n_pre) < 0.3, jnp.float32)
    fused = np.asarray(kops.ell_spmv_delay(ell, spk, n_slots))
    for d in range(n_slots):
        mask = np.asarray(ell.valid) & (delay == d)
        ell_d = F.triple_to_ell(post_ind, np.where(mask, g, 0), mask, n_post)
        passed = np.asarray(kops.ell_spmv(ell_d, spk))
        if kops.backend() == "ref":
            assert np.array_equal(fused[d], passed), d
        else:       # different kernels, different tile shapes: ~1 ulp
            np.testing.assert_allclose(fused[d], passed,
                                       rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# full-simulation bit-exactness: dense vs event through delays + STDP
# ---------------------------------------------------------------------------

def _event_net(propagation):
    s = ModelSpec("ev")
    s.add_neuron_population(
        "a", 80, "izhikevich",
        input_fn=lambda key, t, n: 6.0 * jax.random.normal(key, (n,)))
    s.add_neuron_population("b", 40, "izhikevich")
    s.add_synapse_population("ab", "a", "b", connect=F.FixedFanout(10),
                             weight=F.UniformWeight(0, 0.8),
                             psm=ExpDecay(4.0),
                             delay=F.UniformIntDelay(0, 4),
                             propagation=propagation)
    s.add_synapse_population("aa", "a", "a",
                             connect=F.FixedProbability(0.15),
                             weight=F.UniformWeight(0, 0.4),
                             wum=STDP(0.01), propagation=propagation)
    s.probe("raster_b", "b", "spikes")
    # engine g lives in partitioned blocks (padded) — a max-reduction probe
    # is the bit-exact cross-backend view of the plastic weights
    s.probe("gmax", "aa", "g", reduce="max", every=5)
    return s


_REF_ONLY = pytest.mark.skipif(
    kops.backend() != "ref",
    reason="the bitwise dense-vs-event contract is defined per backend; on "
           "Pallas backends compaction changes MXU tile shapes (~1 ulp), "
           "which the kernel-level tolerance tests cover instead")


@_REF_ONLY
def test_simulator_event_bitexact_vs_dense_delays_stdp():
    rd = _event_net("dense").build(dt=1.0, seed=9).run(50)
    re_ = _event_net("event").build(dt=1.0, seed=9).run(50)
    for kname in rd.spike_counts:
        assert np.array_equal(np.asarray(rd.spike_counts[kname]),
                              np.asarray(re_.spike_counts[kname])), kname
    # plastic conductances advanced through the event path bit-exactly
    assert np.array_equal(np.asarray(rd.state.syn["aa"].g),
                          np.asarray(re_.state.syn["aa"].g))
    for pname, pop in (("a", 80), ("b", 40)):
        assert np.array_equal(np.asarray(rd.state.neurons[pname]["V"]),
                              np.asarray(re_.state.neurons[pname]["V"]))
    assert np.array_equal(np.asarray(rd.recordings["raster_b"]),
                          np.asarray(re_.recordings["raster_b"]))


def test_engine_event_bitexact_vs_host():
    from repro.launch.mesh import make_snn_mesh
    n_dev = min(jax.device_count(), 8)
    r1 = _event_net("event").build(dt=1.0, seed=4).run(40)
    r2 = _event_net("event").build(dt=1.0, seed=4,
                                   mesh=make_snn_mesh(n_dev)).run(40)
    for kname in r1.spike_counts:
        assert np.array_equal(np.asarray(r1.spike_counts[kname]),
                              np.asarray(r2.spike_counts[kname])), kname
    assert np.array_equal(np.asarray(r1.recordings["raster_b"]),
                          np.asarray(r2.recordings["raster_b"]))
    assert np.array_equal(np.asarray(r1.recordings["gmax"]),
                          np.asarray(r2.recordings["gmax"]))


_SUBPROCESS = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, sys
    sys.path.insert(0, {src!r})
    sys.path.insert(0, {testdir!r})
    import numpy as np
    import jax
    from test_snn_event import _event_net
    from repro.launch.mesh import make_snn_mesh
    assert jax.device_count() == 8
    r1 = _event_net("event").build(dt=1.0, seed=2).run(40)
    r8 = _event_net("event").build(dt=1.0, seed=2,
                                   mesh=make_snn_mesh(8)).run(40)
    exact = all(
        np.array_equal(np.asarray(r1.spike_counts[k]),
                       np.asarray(r8.spike_counts[k]))
        for k in r1.spike_counts)
    probes = np.array_equal(np.asarray(r1.recordings["raster_b"]),
                            np.asarray(r8.recordings["raster_b"]))
    g = np.array_equal(np.asarray(r1.recordings["gmax"]),
                       np.asarray(r8.recordings["gmax"]))
    print(json.dumps({{"exact": exact, "probes": probes, "g": g,
                       "finite": bool(r8.finite)}}))
""")


@pytest.mark.slow
def test_event_8_device_subprocess():
    code = _SUBPROCESS.format(src=SRC,
                              testdir=str(Path(__file__).resolve().parent))
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["exact"], "8-device event run diverged from host run"
    assert res["probes"], "packed spikes-probe ring diverged across shards"
    assert res["g"], "STDP conductances diverged across shards"
    assert res["finite"]


# ---------------------------------------------------------------------------
# uint32 bitmask packing
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 300), seed=st.integers(0, 10_000))
def test_bitmask_round_trip(n, seed):
    rng = np.random.default_rng(seed)
    bits = rng.random(n) < 0.3
    words = BM.pack_spikes(jnp.asarray(bits))
    assert words.dtype == jnp.uint32
    assert words.shape == (BM.words_for(n),)
    assert np.array_equal(np.asarray(BM.unpack_spikes(words, n)), bits)


def test_bitmask_rows_and_segments():
    rng = np.random.default_rng(1)
    # probe-ring row format: [cap, n] packs/unpacks row-independently
    rows = rng.random((7, 70)) < 0.4
    packed = BM.pack_rows(jnp.asarray(rows))
    assert packed.shape == (7, BM.words_for(70))
    assert np.array_equal(np.asarray(BM.unpack_rows(packed, 70)), rows)
    # exchange format: per-device segments concatenate like an all-gather
    segs = rng.random((4, 33)) < 0.5
    words = BM.pack_spikes(jnp.asarray(segs))
    flat = BM.unpack_segments(words, 33)
    assert np.array_equal(np.asarray(flat), segs.reshape(-1))


def test_spikes_probe_packed_storage_matches_raster():
    """The spikes-probe ring now stores uint32 rows; the user-facing
    Recordings must still be the bool raster, bit for bit."""
    s = ModelSpec("pk")
    s.add_neuron_population(
        "a", 70, "izhikevich",
        input_fn=lambda key, t, n: 6.0 * jax.random.normal(key, (n,)))
    s.add_synapse_population("aa", "a", "a", connect=F.FixedFanout(8),
                             weight=F.UniformWeight(0, 0.5))
    s.probe("spk", "a", "spikes")
    m = s.build(dt=1.0, seed=6)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        res = m.run(25, record_raster=True)
    rec = np.asarray(res.recordings["spk"])
    assert rec.dtype == np.bool_
    assert np.array_equal(rec, np.asarray(res.raster["a"]))


# ---------------------------------------------------------------------------
# the propagation= API surface
# ---------------------------------------------------------------------------

def test_propagation_validation_and_memory_report():
    s = ModelSpec("v")
    s.add_neuron_population("a", 16, "izhikevich")
    with pytest.raises(SpecError, match="propagation"):
        s.add_synapse_population("bad", "a", "a", connect=F.OneToOne(),
                                 propagation="evnt")
    with pytest.raises(SpecError, match="incompatible"):
        s.add_synapse_population("bad2", "a", "a", connect=F.OneToOne(),
                                 representation="dense",
                                 propagation="event")
    s.add_synapse_population("aa", "a", "a", connect=F.FixedFanout(4),
                             weight=0.1, propagation="event")
    m = s.build(dt=1.0, seed=0)
    rep = [r for r in m.memory_report()
           if r.get("kind") == "synapse_group"][0]
    assert rep["propagation"] == "event"
    assert rep["propagation_mode"] == "event"
    assert rep["event_capacity"] >= 8
    # a tiny group under "auto" resolves to dense (below the crossover)
    s2 = ModelSpec("v2")
    s2.add_neuron_population("a", 16, "izhikevich")
    s2.add_synapse_population("aa", "a", "a", connect=F.FixedFanout(4),
                              weight=0.1)
    rep2 = [r for r in s2.build(dt=1.0, seed=0).memory_report()
            if r.get("kind") == "synapse_group"][0]
    assert rep2["propagation"] == "auto"
    assert rep2["propagation_mode"] == "dense"
    assert rep2["event_capacity"] is None


def test_choose_propagation_crossover():
    from repro.kernels.autotune import choose_propagation
    small = choose_propagation(200, 32, 200)
    assert small["mode"] == "dense"          # 6400 slots: below crossover
    big = choose_propagation(2048, 32, 2048)
    assert big["mode"] == "event"            # 65536 slots at 10% activity
    assert big["capacity"] < 2048
    assert 2 * big["event_slots"] <= big["dense_slots"]


def test_deprecated_step_kwargs_warn_and_match():
    rng = np.random.default_rng(8)
    ell = _int_ell(rng, 24, 4, 24)
    grp = SynapseGroup(name="g", pre="p", post="p", ell=ell)
    st0 = grp.init_state()
    spk = jnp.asarray(rng.random(24) < 0.4)
    gs = jnp.float32(1.0)
    _, cur_new = grp.step(st0, spk, gs, 1.0,
                          conn=LocalConnectivity(ell=ell, dense=None))
    with pytest.warns(DeprecationWarning, match="conn=LocalConnectivity"):
        _, cur_old = grp.step(st0, spk, gs, 1.0, ell=ell)
    assert np.array_equal(np.asarray(cur_new), np.asarray(cur_old))
    # conflicting conn= AND deprecated ell= is a named SpecError
    with pytest.raises(SpecError, match="conflict"):
        grp.step(st0, spk, gs, 1.0,
                 conn=LocalConnectivity(ell=ell, dense=None), ell=ell)


def test_pallas_mode_parsing():
    PM = flags.PallasMode
    assert flags.pallas_mode("") is PM.OFF
    assert flags.pallas_mode("0") is PM.OFF
    assert flags.pallas_mode("off") is PM.OFF
    assert flags.pallas_mode("1") is PM.ON
    assert flags.pallas_mode("TPU") is PM.ON
    assert flags.pallas_mode("interpret") is PM.INTERPRET
    with pytest.raises(ValueError, match="REPRO_USE_PALLAS"):
        flags.pallas_mode("interperet")
    with pytest.raises(ValueError, match="REPRO_USE_PALLAS"):
        flags.pallas_mode("yes please")
