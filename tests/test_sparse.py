"""Sparse formats: CSR/ELL equivalence, memory model, hypothesis properties."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis or fallback

from repro.sparse import formats as F
from repro.sparse import ops as O


def _random_sparse(rng, n_pre, n_post, density):
    w = (rng.random((n_pre, n_post)) < density) * rng.standard_normal(
        (n_pre, n_post))
    return w.astype(np.float32)


def test_csr_dense_roundtrip(rng):
    w = _random_sparse(rng, 37, 53, 0.2)
    csr = F.dense_to_csr(w)
    np.testing.assert_allclose(np.asarray(F.csr_to_dense(csr)), w)


def test_ell_dense_roundtrip(rng):
    w = _random_sparse(rng, 23, 41, 0.3)
    ell = F.dense_to_ell(w)
    np.testing.assert_allclose(np.asarray(F.ell_to_dense(ell)), w)


def test_spmv_representations_agree(rng):
    w = _random_sparse(rng, 64, 80, 0.15)
    spikes = (rng.random(64) < 0.3).astype(np.float32)
    dense = O.accumulate_dense(jnp.asarray(w), jnp.asarray(spikes))
    csr = O.accumulate_csr(F.dense_to_csr(w), jnp.asarray(spikes))
    ell = O.accumulate_ell(F.dense_to_ell(w), jnp.asarray(spikes))
    np.testing.assert_allclose(np.asarray(csr), np.asarray(dense),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(ell), np.asarray(dense),
                               rtol=1e-5, atol=1e-5)


def test_compaction_exact_when_bounded(rng):
    w = _random_sparse(rng, 64, 32, 0.5)
    spikes = np.zeros(64, np.float32)
    spikes[rng.choice(64, 5, replace=False)] = 1.0
    ell = F.dense_to_ell(w)
    full = O.accumulate_ell(ell, jnp.asarray(spikes))
    comp = O.accumulate_ell_compacted(ell, jnp.asarray(spikes), max_active=8)
    np.testing.assert_allclose(np.asarray(comp), np.asarray(full),
                               rtol=1e-5, atol=1e-5)


def test_memory_model_eq12():
    # paper eq (1) vs (2): sparse wins iff 2*nNZ + nPre+1 < nPre*nPost
    assert F.choose_representation(1000, 1000, 10_000) == "sparse"
    assert F.choose_representation(10, 10, 90) == "dense"
    # paper's own example: 1000 neurons, 100..1000 fanout -> always sparse
    for n_conn in range(100, 1001, 50):
        assert F.choose_representation(1000, 1000, 1000 * n_conn) \
            == ("sparse" if 2 * 1000 * n_conn + 1001 < 1_000_000
                else "dense")


def test_fixed_fanout_exact(rng):
    post, g = F.fixed_fanout_connectivity(rng, 50, 200, 20)
    assert post.shape == (50, 20)
    for row in post:
        assert len(set(row.tolist())) == 20  # without replacement
    assert post.max() < 200


@settings(max_examples=25, deadline=None)
@given(
    n_pre=st.integers(2, 40), n_post=st.integers(2, 40),
    density=st.floats(0.05, 0.9), seed=st.integers(0, 2**31 - 1),
)
def test_property_spmv_equivalence(n_pre, n_post, density, seed):
    """ELL/CSR/dense accumulate identically for any connectivity."""
    r = np.random.default_rng(seed)
    w = _random_sparse(r, n_pre, n_post, density)
    spikes = (r.random(n_pre) < 0.5).astype(np.float32)
    dense = np.asarray(O.accumulate_dense(jnp.asarray(w),
                                          jnp.asarray(spikes)))
    ell = np.asarray(O.accumulate_ell(F.dense_to_ell(w),
                                      jnp.asarray(spikes)))
    csr = np.asarray(O.accumulate_csr(F.dense_to_csr(w),
                                      jnp.asarray(spikes)))
    np.testing.assert_allclose(ell, dense, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(csr, dense, rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(n_pre=st.integers(1, 100), n_post=st.integers(1, 100),
       density=st.floats(0.0, 1.0))
def test_property_memory_model_consistent(n_pre, n_post, density):
    nnz = int(n_pre * n_post * density)
    rep = F.choose_representation(n_pre, n_post, nnz)
    sparse_cost = F.sparse_memory_elements(nnz, n_pre, n_post)
    dense_cost = F.dense_memory_elements(n_pre, n_post)
    assert (rep == "sparse") == (sparse_cost < dense_cost)
