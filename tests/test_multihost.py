"""Multi-host construction + bit-exactness: 2-process jax.distributed.

Two subprocesses (2 fake CPU devices each) form a 4-device global mesh
via ``init_distributed`` and build the same model with ``init="device"``
— each process runs ``device_init_local`` for its own shards only.  The
parent splices their locally-addressable spike-count shards together and
compares bitwise against a single-process 4-device oracle, and checks
the construction checksums of the post-sharded connectivity blocks
(weights bit-cast to int, post indices, delay slots) match the oracle's.

Environment-level distributed failures (coordination service refusing
to come up in a sandbox) skip rather than fail; any divergence in the
constructed graph or the stepped dynamics is a hard failure.
"""

import json
import os
import socket
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

WORKER = str(Path(__file__).resolve().parent / "_multihost_worker.py")

# stderr markers of the distributed runtime failing to come up at all
# (vs. the model code failing, which must fail the test)
_ENV_FAILURES = ("DEADLINE_EXCEEDED", "UNAVAILABLE", "barrier",
                 "coordination service", "Connection refused")


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("localhost", 0))
        return s.getsockname()[1]


def _worker_env(n_local_devices: int) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n_local_devices}")
    env["JAX_PLATFORMS"] = "cpu"
    return env


def _parse(out: subprocess.CompletedProcess) -> dict:
    return json.loads(out.stdout.strip().splitlines()[-1])


def _assemble(shards, padded):
    """Splice [start, values] shard pieces into one array, checking the
    pieces tile the padded length exactly (no gap, no overlap)."""
    full = np.full(padded, -1, np.int64)
    for start, vals in shards:
        seg = np.asarray(vals, np.int64)
        assert np.all(full[start: start + len(seg)] == -1), "overlap"
        full[start: start + len(seg)] = seg
    assert np.all(full >= 0), "gap in shard coverage"
    return full


@pytest.mark.slow
def test_two_process_distributed_build_and_step_bit_exact():
    port = _free_port()
    workers = [
        subprocess.Popen([sys.executable, WORKER, str(port), str(pid), "2"],
                         env=_worker_env(2), text=True,
                         stdout=subprocess.PIPE, stderr=subprocess.PIPE)
        for pid in range(2)]
    try:
        outs = [p.communicate(timeout=560) for p in workers]
    except subprocess.TimeoutExpired:
        for p in workers:
            p.kill()
        pytest.skip("distributed workers timed out (sandboxed runtime?)")
    rcs = [p.returncode for p in workers]
    if any(rcs):
        err = "\n".join(o[1][-2000:] for o in outs)
        if any(m.lower() in err.lower() for m in _ENV_FAILURES):
            pytest.skip(f"jax.distributed unavailable here:\n{err[-500:]}")
        raise AssertionError(f"worker failed rc={rcs}:\n{err}")

    # single-process oracle: same model, same 4-device mesh, no distributed
    oracle_raw = subprocess.run([sys.executable, WORKER, "0", "0", "1"],
                                env=_worker_env(4), text=True,
                                capture_output=True, timeout=560)
    assert oracle_raw.returncode == 0, oracle_raw.stderr[-2000:]
    oracle = _parse(oracle_raw)
    assert oracle["nproc"] == 1 and oracle["ndev"] == 4

    results = []
    for pid, (stdout, _) in enumerate(outs):
        res = json.loads(stdout.strip().splitlines()[-1])
        assert res["pid"] == pid
        assert res["nproc"] == 2, "init_distributed did not span 2 processes"
        assert res["ndev"] == 4 and res["ndev_local"] == 2
        # construction is placement-independent: every process sees the
        # same global graph checksums as the single-process oracle
        assert res["csum"] == oracle["csum"], f"pid {pid} graph diverged"
        assert res["padded"] == oracle["padded"]
        results.append(res)

    for name, padded in oracle["padded"].items():
        ref = _assemble(oracle["shards"][name], padded)
        pieces = (results[0]["shards"][name] + results[1]["shards"][name])
        got = _assemble(pieces, padded)
        np.testing.assert_array_equal(got, ref, err_msg=name)
