"""Probes + CustomUpdates: the observation/intervention runtime API.

The load-bearing contracts (ISSUE 5 acceptance):

- a probe on a declared state variable returns bit-identical values under
  the host build, the sharded build, `sweep_gscale`'s candidate axis, and
  serving with masked partial chunks (strided / windowed / reduced);
- `run(record_raster=True)` still works through the deprecation shim and
  a "spikes" probe reproduces its raster bit for bit;
- a codegen'd custom update with a cross-neuron reduction matches a numpy
  oracle on both the host and sharded paths (psum/pmax inside shard_map).

Run standalone (the CI `multidevice` job does, on 8 fake CPU devices):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m pytest -q tests/test_probes.py
"""

import json
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.snn.spec import ModelSpec, SpecError
from repro.core.snn.synapses import ExpDecay, STDP
from repro.launch.mesh import make_snn_mesh
from repro.launch.snn_serve import SNNServer, StreamRequest
from repro.sparse.formats import FixedFanout, UniformWeight

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _n_dev() -> int:
    """Capped at 8: importing launch.dryrun elsewhere in the suite can
    force 512 fake devices, and a 512-way shard_map over a tiny net is
    all rendezvous and no work."""
    return min(jax.device_count(), 8)


def _spec(probes=(), custom=(), n_a=30, n_b=14, stdp=True):
    """A small two-population Izhikevich net covering every state kind a
    probe can target (neuron state, spikes, psm state, STDP traces,
    plastic g)."""
    s = ModelSpec("probe_net")
    s.add_neuron_population(
        "a", n_a, "izhikevich",
        input_fn=lambda k, t, n: 6.0 * jax.random.normal(k, (n,)))
    s.add_neuron_population("b", n_b, "izhikevich")
    s.add_synapse_population("ab", "a", "b", connect=FixedFanout(4),
                             weight=UniformWeight(0, 0.8),
                             psm=ExpDecay(4.0))
    if stdp:
        s.add_synapse_population("aa", "a", "a", connect=FixedFanout(5),
                                 weight=UniformWeight(0, 0.4),
                                 wum=STDP(0.01))
    for args, kw in probes:
        s.probe(*args, **kw)
    for args, kw in custom:
        s.add_custom_update(*args, **kw)
    return s


# ---------------------------------------------------------------------------
# probe semantics on the host build
# ---------------------------------------------------------------------------

def test_strided_probe_subsamples_the_full_probe():
    """every=k keeps exactly the k-th post-step samples, bit for bit."""
    s = _spec(probes=[(("v1", "a", "V"), {}),
                      (("v3", "a", "V"), {"every": 3})], stdp=False)
    r = s.build(dt=1.0, seed=0).run(10)
    full, stri = np.asarray(r.recordings["v1"]), np.asarray(r.recordings["v3"])
    assert full.shape == (10, 30) and stri.shape == (4, 30)
    assert int(r.recordings.count("v1")) == 10
    assert int(r.recordings.count("v3")) == 3          # steps 3, 6, 9
    assert np.array_equal(stri[:3], full[2::3])
    assert not np.any(stri[3])                         # unfilled tail: zeros


def test_spike_probe_reproduces_the_raster_oracle():
    """A 'spikes' probe IS the legacy raster (the record_raster shim's
    migration target), and the shim still works + warns."""
    s = _spec(probes=[(("spk_a", "a", "spikes"), {}),
                      (("spk_b", "b", "spikes"), {})])
    model = s.build(dt=1.0, seed=1)
    with pytest.warns(DeprecationWarning, match="record_raster"):
        r = model.run(12, record_raster=True)
    for pop, probe in (("a", "spk_a"), ("b", "spk_b")):
        raster = np.asarray(r.raster[pop])
        rec = np.asarray(r.recordings[probe])
        assert rec.dtype == bool
        assert np.array_equal(rec, raster), pop


def test_windowed_probe_keeps_last_samples_chronologically():
    s = _spec(probes=[(("v1", "a", "V"), {"every": 2}),
                      (("vw", "a", "V"), {"every": 2, "window": 3}),
                      (("vbig", "a", "V"), {"every": 2, "window": 9})],
              stdp=False)
    r = s.build(dt=1.0, seed=2).run(14)                # 7 samples
    full = np.asarray(r.recordings["v1"])
    wind = np.asarray(r.recordings["vw"])
    big = np.asarray(r.recordings["vbig"])
    assert wind.shape == (3, 30) and int(r.recordings.count("vw")) == 3
    assert np.array_equal(wind, full[-3:])             # last 3, in order
    # window larger than the sample count: chronological head + zero tail
    assert int(r.recordings.count("vbig")) == 7
    assert np.array_equal(big[:7], full) and not np.any(big[7:])


def test_reduced_probes_match_the_full_probe():
    s = _spec(probes=[(("v1", "a", "V"), {}),
                      (("vmax", "a", "V"), {"reduce": "max"}),
                      (("vmin", "a", "V"), {"reduce": "min"}),
                      (("vmean", "a", "V"), {"reduce": "mean"}),
                      (("nspk", "a", "spikes"), {"reduce": "sum"})],
              stdp=False)
    model = s.build(dt=1.0, seed=3)
    with pytest.warns(DeprecationWarning):
        r = model.run(9, record_raster=True)
    full = np.asarray(r.recordings["v1"], np.float32)
    assert np.array_equal(np.asarray(r.recordings["vmax"]),
                          full.max(axis=1))
    assert np.array_equal(np.asarray(r.recordings["vmin"]),
                          full.min(axis=1))
    np.testing.assert_allclose(np.asarray(r.recordings["vmean"]),
                               full.mean(axis=1), rtol=1e-6)
    # per-step population spike counts: integer-valued, exact in f32
    assert np.array_equal(np.asarray(r.recordings["nspk"]),
                          np.asarray(r.raster["a"]).sum(axis=1)
                          .astype(np.float32))


def test_probe_every_state_kind_matches_eager_step_loop():
    """Cross-check the sampled quantity itself (which array, which step)
    against an eager python step loop — allclose, since eager vs scan
    compilations differ in fusion rounding."""
    s = _spec(probes=[(("bv", "b", "V"), {}),
                      (("insyn", "ab", "in_syn"), {}),
                      (("xpre", "aa", "x_pre"), {}),
                      (("gmax", "aa", "g"), {"reduce": "max"})])
    model = s.build(dt=1.0, seed=4)
    r = model.run(8)
    st = model.init_state()
    bv, insyn, xpre, gmax = [], [], [], []
    valid = np.asarray(
        next(g for g in model.network.synapses if g.name == "aa").ell.valid)
    for _ in range(8):
        st, spk = model.step(st)
        bv.append(np.asarray(st.neurons["b"]["V"]))
        insyn.append(np.asarray(st.syn["ab"].psm["in_syn"]))
        xpre.append(np.asarray(st.syn["aa"].wu_pre["x_pre"]))
        gmax.append(np.asarray(st.syn["aa"].g)[valid].max())
    np.testing.assert_allclose(np.asarray(r.recordings["bv"]),
                               np.stack(bv), atol=1e-3)
    np.testing.assert_allclose(np.asarray(r.recordings["insyn"]),
                               np.stack(insyn), atol=1e-4)
    np.testing.assert_allclose(np.asarray(r.recordings["xpre"]),
                               np.stack(xpre), atol=1e-5)
    np.testing.assert_allclose(np.asarray(r.recordings["gmax"]),
                               np.asarray(gmax), atol=1e-6)


def test_run_resumed_from_state_keeps_global_schedule():
    """Probe schedules key off round(t/dt): two chained 5-step runs sample
    the same steps as one 10-step run (the serving invariant)."""
    probes = [(("v3", "a", "V"), {"every": 3})]
    m1 = _spec(probes=probes, stdp=False).build(dt=1.0, seed=5)
    m2 = _spec(probes=probes, stdp=False).build(dt=1.0, seed=5)
    whole = m1.run(10)
    first = m2.run(5)
    second = m2.run(5, state=first.state)
    w = np.asarray(whole.recordings["v3"])
    a, b = np.asarray(first.recordings["v3"]), np.asarray(
        second.recordings["v3"])
    ca, cb = int(first.recordings.count("v3")), int(
        second.recordings.count("v3"))
    assert ca == 1 and cb == 2                         # steps 3 | 6, 9
    assert np.array_equal(np.concatenate([a[:ca], b[:cb]]), w[:3])


# ---------------------------------------------------------------------------
# sharded build: bit-exact against the host build
# ---------------------------------------------------------------------------

_ALL_PROBES = [(("av", "a", "V"), {"every": 3}),
               (("aspk", "a", "spikes"), {}),
               (("insyn", "ab", "in_syn"), {"every": 2}),
               (("xpre", "aa", "x_pre"), {"every": 2}),
               (("vmean", "a", "V"), {"reduce": "mean"}),
               (("vmax", "b", "V"), {"reduce": "max", "window": 4}),
               (("gmax", "aa", "g"), {"reduce": "max", "every": 4})]


def test_engine_probes_bitwise_vs_host():
    host = _spec(probes=_ALL_PROBES).build(dt=1.0, seed=6)
    eng = _spec(probes=_ALL_PROBES).build(dt=1.0, seed=6,
                                          mesh=make_snn_mesh(_n_dev()))
    rh, re = host.run(13), eng.run(13)
    for name in rh.recordings.keys():
        a, b = np.asarray(rh.recordings[name]), np.asarray(
            re.recordings[name])
        assert a.shape == b.shape, name
        assert np.array_equal(a, b), name
        assert int(rh.recordings.count(name)) == int(
            re.recordings.count(name)), name


def test_sweep_recordings_per_candidate_and_sharded():
    probes = [(("bv", "b", "V"), {"every": 2}),
              (("vmean", "a", "V"), {"reduce": "mean"})]
    host = _spec(probes=probes, stdp=False).build(dt=1.0, seed=7)
    eng = _spec(probes=probes, stdp=False).build(
        dt=1.0, seed=7, mesh=make_snn_mesh(_n_dev()))
    vals = [0.5, 1.0, 2.0]
    sh, se = host.sweep_gscale("ab", vals, 9), eng.sweep_gscale("ab",
                                                                vals, 9)
    for name in ("bv", "vmean"):
        a, b = np.asarray(sh.recordings[name]), np.asarray(
            se.recordings[name])
        assert a.shape[0] == 3 and np.array_equal(a, b), name
    # candidate i == a plain run at that gscale, bit for bit
    r1 = host.run(9, gscales={"ab": 2.0})
    assert np.array_equal(np.asarray(sh.recordings["bv"][2]),
                          np.asarray(r1.recordings["bv"]))


# ---------------------------------------------------------------------------
# serving: masked partial chunks, stitched == offline
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("devices", [0, -1])
def test_served_probe_streams_exact_vs_offline(devices):
    """3 requests over 2 slots, chunk=5 (partial trailing chunks + slot
    reuse + an `every` that does not divide the chunk): stitched streamed
    samples == the offline run's Recordings rows, bitwise, host and
    sharded builds."""
    probes = [(("av", "a", "V"), {"every": 3}),
              (("insyn", "ab", "in_syn"), {"every": 2}),
              (("aspk", "a", "spikes"), {}),
              (("vwin", "b", "V"), {"window": 4})]
    mesh = None if devices == 0 else make_snn_mesh(_n_dev())
    model = _spec(probes=probes, stdp=False).build(dt=1.0, seed=8,
                                                   mesh=mesh)
    srv = SNNServer(model, max_streams=2, chunk=5, stim_pops=("a",))
    rng = np.random.default_rng(0)
    reqs = []
    for i, T in enumerate([12, 9, 7]):
        stim = {"a": (2.0 * rng.normal(size=(T, 30))).astype(np.float32)}
        reqs.append(srv.submit(StreamRequest(rid=i, n_steps=T, stim=stim,
                                             seed=50 + i)))
    finished = srv.run()
    assert len(finished) == 3
    full_offline = _spec(probes=[(("vwin_full", "b", "V"), {})],
                         stdp=False).build(dt=1.0, seed=8, mesh=mesh)
    for req in finished:
        res = model.run(req.n_steps, stim=req.stim,
                        state=model.init_state(
                            jax.random.PRNGKey(req.seed)))
        for name in ("av", "insyn", "aspk"):
            off = np.asarray(res.recordings[name])
            off = off[: int(res.recordings.counts[name])]
            assert np.array_equal(off, req.recordings[name]), (req.rid,
                                                               name)
        # window probes stream every sample (clients window); the stream
        # equals an unwindowed every-step probe's offline samples
        off = full_offline.run(req.n_steps, stim=req.stim,
                               state=full_offline.init_state(
                                   jax.random.PRNGKey(req.seed)))
        assert np.array_equal(np.asarray(off.recordings["vwin_full"]),
                              req.recordings["vwin"]), req.rid


def test_idle_and_masked_slots_take_no_samples():
    probes = [(("av", "a", "V"), {"every": 2})]
    model = _spec(probes=probes, stdp=False).build(dt=1.0, seed=9)
    st = model.init_stream_state(
        jnp.stack([jax.random.PRNGKey(0)] * 3))
    n = model.network.populations["a"].n
    stim = {"a": np.zeros((3, 6, n), np.float32)}
    st2, counts, raster, rec = model.serve_chunk(
        st, stim, np.array([6, 3, 0], np.int32), 6)
    assert raster is None
    cnt = np.asarray(rec.counts["av"])
    assert list(cnt) == [3, 1, 0]                   # steps 2,4,6 | 2 | none
    data = np.asarray(rec.data["av"])
    assert not np.any(data[1, 1:]) and not np.any(data[2])


# ---------------------------------------------------------------------------
# custom updates
# ---------------------------------------------------------------------------

_NORM = (("norm", "ab", "g = g * g_target / maximum(w_sum, 1e-9)"),
         {"params": {"g_target": 2.0},
          "reduce": {"w_sum": ("sum", "g", "post")}})


def _post_totals(model, gname, g):
    grp = next(x for x in model.network.synapses if x.name == gname)
    valid = np.asarray(grp.ell.valid)
    post = np.asarray(grp.ell.post_ind)
    tot = np.zeros(grp.ell.n_post, np.float32)
    np.add.at(tot, post[valid], np.asarray(g)[valid])
    return tot, valid, post


def test_custom_update_normalization_matches_numpy_oracle():
    """On-demand KC->EN-style incoming-weight normalization: per-post
    totals renormalized to g_target, numpy-oracle checked, host build."""
    model = _spec(custom=[_NORM], stdp=False).build(dt=1.0, seed=10)
    assert model.custom_update_names == ["norm"]
    st = model.run(5).state
    g0 = np.asarray(st.syn["ab"].g)
    st2 = model.custom_update("norm", st)
    g1 = np.asarray(st2.syn["ab"].g)
    tot0, valid, post = _post_totals(model, "ab", g0)
    expect = np.where(valid,
                      g0 * 2.0 / np.maximum(tot0[post], 1e-9), g0)
    np.testing.assert_allclose(g1, expect, rtol=1e-6)
    tot1, _, _ = _post_totals(model, "ab", g1)
    np.testing.assert_allclose(tot1, 2.0, rtol=1e-5)


def test_custom_update_sharded_reduction_matches_host():
    """The same normalization under shard_map (per-post reductions are
    device-local; psum combines 'all'/'pre' axes): post totals equal the
    host result to float rounding, and the subsequent dynamics stay
    finite."""
    host = _spec(custom=[_NORM], stdp=False).build(dt=1.0, seed=11)
    eng = _spec(custom=[_NORM], stdp=False).build(
        dt=1.0, seed=11, mesh=make_snn_mesh(_n_dev()))
    sh = host.custom_update("norm", host.run(4).state)
    se = eng.custom_update("norm", eng.run(4).state)
    tot_h, _, _ = _post_totals(host, "ab", sh.syn["ab"].g)
    np.testing.assert_allclose(tot_h, 2.0, rtol=1e-5)
    # engine g blocks are post-partitioned; compare via the invariant the
    # update enforces plus the resumed dynamics
    rh, re = host.run(6, state=sh), eng.run(6, state=se)
    for k in rh.spike_counts:
        assert np.array_equal(np.asarray(rh.spike_counts[k]),
                              np.asarray(re.spike_counts[k])), k


def _int_weight_spec():
    """Integer-valued weights: every reduction (even float sums) is
    order-independent, so host and sharded results are bit-comparable."""
    s = ModelSpec("axes")
    s.add_neuron_population("a", 12, "izhikevich")
    s.add_neuron_population("b", 6, "izhikevich")
    s.add_synapse_population(
        "ab", "a", "b", connect=FixedFanout(3),
        weight=lambda r, sh: r.integers(1, 7, size=sh).astype(np.float32))
    s.add_custom_update(
        "combine", "ab",
        update_code="g = g / maximum(col_max, 1.0) + 0.0 * (row_sum + g_mean)",
        reduce={"col_max": ("max", "g", "post"),
                "row_sum": ("sum", "g", "pre"),
                "g_mean": ("mean", "g", "all")})
    return s


def test_custom_update_axes_and_ops_match_numpy_oracle():
    """post/pre/all reduction axes against a numpy oracle on the host
    build (integer weights -> exact)."""
    m = _int_weight_spec().build(dt=1.0, seed=12)
    st = m.init_state()
    g0 = np.asarray(st.syn["ab"].g)
    st2 = m.custom_update("combine", st)
    grp = m.network.synapses[0]
    valid = np.asarray(grp.ell.valid)
    post = np.asarray(grp.ell.post_ind)
    colmax = np.full(6, -np.inf, np.float32)
    np.maximum.at(colmax, post[valid], g0[valid])
    expect = np.where(valid, g0 / np.maximum(colmax[post], 1.0), g0)
    np.testing.assert_allclose(np.asarray(st2.syn["ab"].g), expect,
                               rtol=1e-6)


def test_custom_update_axes_sharded_bitwise_with_integer_weights():
    """The same update sharded: integer-valued inputs make psum/pmax
    order-independent, so the resumed dynamics match the host bitwise."""
    host = _int_weight_spec().build(dt=1.0, seed=12)
    eng = _int_weight_spec().build(dt=1.0, seed=12,
                                   mesh=make_snn_mesh(_n_dev()))
    sh = host.custom_update("combine", host.init_state())
    se = eng.custom_update("combine", eng.init_state())
    rh, re = host.run(5, state=sh), eng.run(5, state=se)
    for k in rh.spike_counts:
        assert np.array_equal(np.asarray(rh.spike_counts[k]),
                              np.asarray(re.spike_counts[k])), k


def test_population_custom_update_with_reduction():
    """A homeostatic-style population update reading a cross-neuron
    reduction and the model's own params."""
    cu = (("recenter", "a", "V = V - (v_mean - c)"),
          {"reduce": {"v_mean": ("mean", "V")}})
    n = 30
    for mesh in (None, make_snn_mesh(_n_dev())):
        model = _spec(custom=[cu], stdp=False).build(dt=1.0, seed=13,
                                                     mesh=mesh)
        st = model.run(3).state
        # engine state is padded to a device-count multiple; the
        # reduction must only see the n real lanes
        v0 = np.asarray(st.neurons["a"]["V"])[:n]
        st2 = model.custom_update("recenter", st)
        v1 = np.asarray(st2.neurons["a"]["V"])[:n]
        c = np.asarray(model.network.populations["a"].params["c"])
        np.testing.assert_allclose(v1, v0 - (v0.mean() - c), atol=1e-4)
        # untouched state stays untouched
        assert np.array_equal(np.asarray(st.neurons["a"]["U"]),
                              np.asarray(st2.neurons["a"]["U"]))


def test_scheduled_custom_update_fires_on_global_schedule():
    """every=n fires after steps n, 2n, ... — observed through a V probe
    (sampling happens after the scheduled update), identically offline
    and across serving chunk boundaries."""
    cu = (("reset_v", "b", "V = -70.0"), {"every": 4})
    probes = [(("bv", "b", "V"), {})]
    model = _spec(probes=probes, custom=[cu], stdp=False).build(dt=1.0,
                                                                seed=14)
    r = model.run(9)
    bv = np.asarray(r.recordings["bv"])
    assert np.all(bv[3] == -70.0) and np.all(bv[7] == -70.0)
    assert not np.all(bv[4] == -70.0)
    # served stream: same schedule relative to the stream's own clock
    srv = SNNServer(model, max_streams=2, chunk=3, stim_pops=("a",))
    n = model.network.populations["a"].n
    req = srv.submit(StreamRequest(
        rid=0, n_steps=9,
        stim={"a": np.zeros((9, n), np.float32)}, seed=0))
    srv.run()
    res = model.run(9, stim=req.stim,
                    state=model.init_state(jax.random.PRNGKey(0)))
    assert np.array_equal(
        np.asarray(res.recordings["bv"]), req.recordings["bv"])


# ---------------------------------------------------------------------------
# validation: named SpecErrors
# ---------------------------------------------------------------------------

def test_custom_update_writes_trip_the_nan_guard():
    """An update whose writes go non-finite (here: a 0/0 reduction ratio)
    must trip `finite` exactly like an over-scaled conductance — even
    when it fires on the run's last step."""
    cu = (("poison", "b", "V = V + (v_max - v_max) / (v_min - v_min)"),
          {"reduce": {"v_max": ("max", "V"), "v_min": ("min", "V")},
           "every": 4})
    for mesh in (None, make_snn_mesh(_n_dev())):
        model = _spec(custom=[cu], stdp=False).build(dt=1.0, seed=17,
                                                     mesh=mesh)
        assert bool(model.run(3).finite)            # before first firing
        assert not bool(model.run(4).finite)        # fires on last step
        # on-demand writes are guarded too
        st = model.custom_update("poison", model.init_state())
        assert not bool(st.finite)


def test_probe_validation_errors():
    s = _spec()
    with pytest.raises(SpecError, match="unknown target"):
        s.probe("p", "nope", "V")
    with pytest.raises(SpecError, match="every must be a positive int"):
        s.probe("p", "a", "V", every=0)
    with pytest.raises(SpecError, match="window must be a positive int"):
        s.probe("p", "a", "V", window=-1)
    with pytest.raises(SpecError, match="unknown reduce"):
        s.probe("p", "a", "V", reduce="median")
    s.probe("p", "a", "V")
    with pytest.raises(SpecError, match="duplicate probe name"):
        s.probe("p", "a", "U")
    with pytest.raises(SpecError, match="non-empty string"):
        s.probe("", "a", "V")
    # deep (build-time) validation
    with pytest.raises(SpecError, match="no state variable 'W'"):
        _spec(probes=[(("q", "a", "W"), {})]).build()
    with pytest.raises(SpecError, match="no state variable 'bogus'"):
        _spec(probes=[(("q", "ab", "bogus"), {})]).build()
    with pytest.raises(SpecError, match="must declare reduce"):
        _spec(probes=[(("q", "aa", "g"), {})]).build()
    with pytest.raises(SpecError, match="constant"):
        _spec(probes=[(("q", "ab", "g"), {"reduce": "max"})]).build()


def test_probe_multi_post_target_names_concrete_groups():
    s = ModelSpec("mp")
    s.add_neuron_population("e", 10, "izhikevich")
    s.add_neuron_population("i", 5, "izhikevich")
    s.add_synapse_population("exc", "e", ["e", "i"],
                             connect=FixedFanout(3), weight=0.1)
    with pytest.raises(SpecError, match="exc_e"):
        s.probe("p", "exc", "in_syn")
    s.probe("p", "exc_e", "in_syn")                    # concrete group OK


def test_custom_update_validation_errors():
    s = _spec()
    with pytest.raises(SpecError, match="unknown target"):
        s.add_custom_update("c", "nope", "g = g")
    with pytest.raises(SpecError, match="every must be a positive int"):
        s.add_custom_update("c", "ab", "g = g * 0.5", every=0)
    s.add_custom_update("c", "ab", "g = g * 0.5")
    with pytest.raises(SpecError, match="duplicate custom update"):
        s.add_custom_update("c", "ab", "g = g * 0.5")
    # build-time: reductions and writability
    def build(custom):
        return _spec(custom=custom).build()
    with pytest.raises(SpecError, match="unknown reduction axis"):
        build([(("c", "ab", "g = g * s"),
                {"reduce": {"s": ("sum", "g", "diag")}})])
    with pytest.raises(SpecError, match="unknown reduction op"):
        build([(("c", "ab", "g = g * s"),
                {"reduce": {"s": ("median", "g", "post")}})])
    with pytest.raises(SpecError, match="unknown state variable"):
        build([(("c", "ab", "g = g * s"),
                {"reduce": {"s": ("sum", "w", "post")}})])
    with pytest.raises(SpecError, match="declared as \\(op, var\\)"):
        build([(("c", "a", "V = V - s"),
                {"reduce": {"s": ("sum", "V", "pop")}})])
    with pytest.raises(SpecError, match="no-op"):
        build([(("c", "ab", "tmp = g * 2.0"), {})])
    with pytest.raises(SpecError, match="shadows"):
        build([(("c", "a", "V = V - a"), {"params": {"a": 1.0}})])
    with pytest.raises(SpecError, match="reserved"):
        build([(("c", "a", "V = V - dt"), {"params": {"dt": 1.0}})])
    with pytest.raises(SpecError, match="non-whitelisted"):
        build([(("c", "ab", "g = eval(g)"), {})])


def test_custom_update_dense_representation_conflict():
    s = ModelSpec("dense_conflict")
    s.add_neuron_population("a", 10, "izhikevich")
    s.add_neuron_population("b", 5, "izhikevich")
    s.add_synapse_population("ab", "a", "b", connect=FixedFanout(3),
                             weight=0.1, representation="dense")
    s.add_custom_update("scale", "ab", "g = g * 0.5")
    with pytest.raises(SpecError, match="dense"):
        s.build()


# ---------------------------------------------------------------------------
# memory report: live usage, not just the connectivity matrix
# ---------------------------------------------------------------------------

def test_memory_report_covers_runtime_state():
    s = _spec(probes=[(("av", "a", "V"), {"every": 2}),
                      (("vm", "a", "V"), {"reduce": "max", "window": 8})],
              custom=[_NORM])
    s.add_synapse_population("abd", "a", "b", connect=FixedFanout(3),
                             weight=0.1, delay_steps=4)
    model = s.build(dt=1.0, seed=15)
    rep = model.memory_report(n_steps=100, max_streams=6)
    by_name = {r["name"]: r for r in rep}
    # the dendritic ring is accounted (bugfix: it used to be omitted
    # from the compiled-model view)
    delayed = by_name["abd"]
    assert delayed["dendritic_ring_elements"] == 5 * 14
    assert delayed["state_elements"] >= 5 * 14
    # populations carry their neuron state
    assert by_name["a"]["kind"] == "population"
    assert by_name["a"]["state_elements"] >= 3 * 30     # V, U, spikes
    # probes: strided buffer sized from n_steps, windowed from window
    assert by_name["av"]["buffer_elements"] == 50 * 30
    assert by_name["vm"]["buffer_elements"] == 8 * 1
    # custom updates are listed
    assert by_name["norm"]["kind"] == "custom_update"
    # serving state scales with max_streams
    streams = by_name["streams"]
    assert streams["max_streams"] == 6
    assert streams["stream_state_elements"] == \
        6 * streams["state_elements_per_stream"]
    per_stream = streams["state_elements_per_stream"]
    assert per_stream >= delayed["state_elements"]


def test_engine_memory_report_includes_ring_shards():
    s = ModelSpec("ring_shards")
    s.add_neuron_population("a", 16, "izhikevich")
    s.add_synapse_population("aa", "a", "a", connect=FixedFanout(3),
                             weight=0.1, delay_steps=3)
    model = s.build(dt=1.0, seed=16, mesh=make_snn_mesh(_n_dev()))
    rep = model.engine.memory_report()
    r = rep[0]
    D = _n_dev()
    assert r["ring_elements_per_device"] == 4 * (-(-16 // D))
    assert r["n_shards"] == D


# ---------------------------------------------------------------------------
# 1-vs-8-device subprocess agreement (forces 8 devices regardless of the
# parent interpreter's locked backend)
# ---------------------------------------------------------------------------

_SUBPROCESS = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, sys
    sys.path.insert(0, @SRC@)
    import numpy as np
    import jax
    from repro.core.snn.spec import ModelSpec
    from repro.launch.mesh import make_snn_mesh
    from repro.sparse.formats import FixedFanout, UniformWeight
    from repro.core.snn.synapses import ExpDecay, STDP
    assert jax.device_count() == 8

    def mk():
        s = ModelSpec("sub")
        s.add_neuron_population(
            "a", 30, "izhikevich",
            input_fn=lambda k, t, n: 6.0 * jax.random.normal(k, (n,)))
        s.add_neuron_population("b", 14, "izhikevich")
        s.add_synapse_population("ab", "a", "b", connect=FixedFanout(4),
                                 weight=UniformWeight(0, 0.8),
                                 psm=ExpDecay(4.0))
        s.add_synapse_population("aa", "a", "a", connect=FixedFanout(5),
                                 weight=UniformWeight(0, 0.4),
                                 wum=STDP(0.01))
        s.probe("av", "a", "V", every=3)
        s.probe("aspk", "a", "spikes")
        s.probe("vmean", "a", "V", reduce="mean")
        s.probe("gmax", "aa", "g", reduce="max", every=4)
        s.add_custom_update(
            "norm", "ab", "g = g * g_target / maximum(w_sum, 1e-9)",
            params={"g_target": 2.0},
            reduce={"w_sum": ("sum", "g", "post")})
        return s

    host = mk().build(dt=1.0, seed=21)
    eng = mk().build(dt=1.0, seed=21, mesh=make_snn_mesh(8))
    rh, re = host.run(12), eng.run(12)
    probes_exact = all(
        np.array_equal(np.asarray(rh.recordings[k]),
                       np.asarray(re.recordings[k]))
        for k in rh.recordings.keys())
    sh = host.custom_update("norm", rh.state)
    se = eng.custom_update("norm", re.state)
    r2h = host.run(6, state=sh)
    r2e = eng.run(6, state=se)
    post_norm_exact = all(
        np.array_equal(np.asarray(r2h.spike_counts[k]),
                       np.asarray(r2e.spike_counts[k]))
        for k in r2h.spike_counts)
    print(json.dumps({"probes_exact": probes_exact,
                      "post_norm_exact": post_norm_exact,
                      "finite": bool(re.finite)}))
""")


@pytest.mark.slow
def test_probes_and_custom_updates_8_device_subprocess():
    code = _SUBPROCESS.replace("@SRC@", repr(SRC))
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["probes_exact"], "8-device probe recordings diverged"
    assert res["post_norm_exact"], \
        "sharded custom-update reduction diverged"
    assert res["finite"]


# ---------------------------------------------------------------------------
# memory_report accounting vs the actual allocations (PR 9)
# ---------------------------------------------------------------------------

def test_memory_report_probe_bytes_match_allocated_buffers():
    """The capacity planner sizes hosts off memory_report, so every probe
    entry's buffer_bytes must equal the ring buffer _probe_init actually
    allocates — in particular unreduced spike rings are bit-packed to
    uint32 words (PR 8) and must not be accounted at the 32x larger
    logical bool [cap, n] size."""
    s = _spec(probes=[
        (("raster", "a", "spikes"), {}),                   # packed ring
        (("rate", "a", "spikes"), {"reduce": "sum"}),      # reduced scalar
        (("vm", "a", "V"), {"every": 3, "window": 2}),     # strided window
        (("tr", "aa", "x_pre"), {"every": 5}),             # wu_pre vector
    ])
    model = s.build(dt=1.0, seed=0)
    n_steps = 24
    bufs, caps = model.simulator._probe_init(n_steps)
    by_name = {r["name"]: r for r in model.memory_report(n_steps=n_steps)
               if r["kind"] == "probe"}
    assert set(by_name) == set(bufs)
    for name, buf in bufs.items():
        entry = by_name[name]
        assert entry["buffer_bytes"] == buf.nbytes, (
            name, entry, buf.shape, str(buf.dtype))
        assert entry["is_packed"] == (buf.dtype == jnp.uint32), name
    assert by_name["raster"]["is_packed"]          # ~32x smaller than bool
    assert not by_name["rate"]["is_packed"]
    assert by_name["rate"]["buffer_bytes"] == 24 * 4
    assert by_name["vm"]["buffer_bytes"] == 2 * 30 * 4


def test_memory_report_without_nsteps_reports_window_capacity():
    s = _spec(probes=[(("vm", "a", "V"), {"window": 5}),
                      (("raster", "a", "spikes"), {})], stdp=False)
    model = s.build(dt=1.0, seed=0)
    by_name = {r["name"]: r for r in model.memory_report()
               if r["kind"] == "probe"}
    assert by_name["vm"]["buffer_bytes"] == 5 * 30 * 4
    # unbounded ring without n_steps: per-sample cost is still reported
    assert "buffer_bytes" not in by_name["raster"]
    assert by_name["raster"]["bytes_per_sample"] == 4 * ((30 + 31) // 32)


# ---------------------------------------------------------------------------
# record_raster shim vs a user probe named "spikes" (PR 9)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("order", ["spikes_first", "spikes_last"])
def test_record_raster_collides_with_probe_named_spikes(order):
    """Two writers for the 'spikes' recordings key must be a loud
    SpecError, not a silent last-one-wins — in either declaration
    order."""
    probes = [(("spikes", "a", "spikes"), {}), (("vm", "a", "V"), {})]
    if order == "spikes_last":
        probes.reverse()
    model = _spec(probes=probes, stdp=False).build(dt=1.0, seed=0)
    with pytest.raises(SpecError, match="record_raster.*spikes"):
        model.run(5, record_raster=True)
    # without the shim the probe set is perfectly legal
    r = model.run(5)
    assert np.asarray(r.recordings["spikes"]).shape == (5, 30)


def test_record_raster_still_warns_when_no_probe_collides():
    """Probes on the spikes *variable* under other names do not collide:
    the shim keeps its DeprecationWarning path."""
    model = _spec(probes=[(("spk_a", "a", "spikes"), {}),
                          (("spk_b", "b", "spikes"), {})],
                  stdp=False).build(dt=1.0, seed=0)
    with pytest.warns(DeprecationWarning, match="record_raster"):
        r = model.run(5, record_raster=True)
    assert np.array_equal(np.asarray(r.raster["a"]),
                          np.asarray(r.recordings["spk_a"]))
