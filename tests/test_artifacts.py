"""Dry-run artifact sanity (skipped when the sweep hasn't been run)."""

import json
from pathlib import Path

import pytest

ART = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


@pytest.mark.parametrize("tag", ["pod16x16", "pod2x16x16"])
def test_dryrun_artifacts_complete_and_clean(tag):
    d = ART / tag
    if not d.exists():
        pytest.skip("dry-run sweep not present")
    cells = [json.loads(f.read_text()) for f in sorted(d.glob("*.json"))
             if not f.name.endswith(".isolate.json")]
    if len(cells) < 40:
        pytest.skip(f"sweep incomplete ({len(cells)}/40)")
    by_status = {}
    for c in cells:
        by_status.setdefault(c["status"], []).append(
            (c["arch"], c["shape"]))
    assert not by_status.get("FAIL"), by_status.get("FAIL")
    assert len(by_status.get("OK", [])) == 34
    assert len(by_status.get("SKIP", [])) == 6
    for c in cells:
        if c["status"] != "OK":
            continue
        assert c["cost_analysis"].get("flops", 0) > 0, (c["arch"],
                                                        c["shape"])
        assert "collectives" in c
