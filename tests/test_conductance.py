"""Conductance scaling: guarded search + hyperbola regression (paper §2/§5)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis or fallback

from repro.core.conductance import (fit_hyperbola, hyperbola, mape,
                                    search_bisect, search_sweep)


def test_fit_recovers_paper_table1_constants():
    """Synthetic data from the paper's Izhikevich fit constants."""
    n = np.arange(100, 1001, 50, dtype=float)
    g = hyperbola(n, 1.318e3, 1.099e2, -2.80e-1)
    k1, k2, k3, err = fit_hyperbola(n, g)
    assert err < 0.5
    np.testing.assert_allclose([k1, k2, k3], [1.318e3, 1.099e2, -0.28],
                               rtol=0.05)


def test_fit_robust_to_noise():
    r = np.random.default_rng(0)
    n = np.arange(100, 1001, 50, dtype=float)
    g = hyperbola(n, 1.318e3, 1.099e2, -0.28) \
        * (1 + 0.04 * r.standard_normal(n.shape))
    k1, k2, k3, err = fit_hyperbola(n, g)
    assert err < 5.0
    pred = hyperbola(n, k1, k2, k3)
    assert mape(pred, g) < 5.0


def test_fit_handles_negative_k2():
    """Paper Table 2 PN-LHI has k2 = -6.338 (pole left of data)."""
    n = np.arange(20, 201, 20, dtype=float)
    g = hyperbola(n, 1.354e3, -6.338, 1.672e-3)
    k1, k2, k3, err = fit_hyperbola(n, g)
    assert err < 1.0


@settings(max_examples=20, deadline=None)
@given(k1=st.floats(1.0, 1e4), k2=st.floats(1.0, 500.0),
       k3=st.floats(-1.0, 1.0))
def test_property_fit_recovers_exact_hyperbolas(k1, k2, k3):
    n = np.arange(50, 1001, 50, dtype=float)
    g = hyperbola(n, k1, k2, k3)
    if np.any(np.abs(g) < 1e-9):   # mape undefined at zeros
        return
    _, _, _, err = fit_hyperbola(n, g)
    assert err < 1.0


def test_bisect_respects_nan_guard():
    """Fig-1 logic: non-finite runs are treated as scale-too-high."""
    calls = []

    def run_fn(gs):
        gs = float(gs)
        calls.append(gs)
        if gs > 4.0:                       # overflow region
            return jnp.float32(np.nan), jnp.array(False)
        return jnp.float32(10.0 * gs), jnp.array(True)   # rate = 10*g

    res = search_bisect(run_fn, 0.0, 16.0, target_band=(18.0, 22.0))
    assert res.finite
    assert 18.0 <= res.rate_hz <= 22.0
    assert res.gscale < 4.0


def test_bisect_converges_monotone():
    run_fn = lambda g: (jnp.float32(5.0 * float(g)), jnp.array(True))
    res = search_bisect(run_fn, 0.0, 8.0, target_band=(9.5, 10.5))
    assert abs(res.gscale - 2.0) < 0.2


def test_sweep_picks_best_finite():
    def batched(gs):
        rates = 10.0 * gs
        finite = gs < 3.0
        return rates, finite

    res = search_sweep(batched, jnp.linspace(0.1, 5.0, 50), target_rate=20.0)
    assert res.finite
    assert abs(res.rate_hz - 20.0) < 1.0
