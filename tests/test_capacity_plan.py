"""ModelSpec.plan: the pre-flight capacity planner (PR 9).

Pure arithmetic — nothing here allocates device arrays or builds a
network, which is the point: a spec too big for this host must be
plannable on this host.
"""

import numpy as np
import pytest

from repro.core.snn.spec import ModelSpec, SpecError
from repro.core.snn.synapses import STDP
from repro.sparse.formats import FixedFanout, FixedProbability, \
    UniformIntDelay, UniformWeight


def _small():
    s = ModelSpec("small")
    s.add_neuron_population("a", 200, "izhikevich")
    s.add_neuron_population("b", 100, "izhikevich")
    s.add_synapse_population("ab", "a", "b", connect=FixedFanout(8),
                             weight=UniformWeight(0, 0.5),
                             wum=STDP(0.01), delay=UniformIntDelay(0, 3))
    s.probe("raster", "a", "spikes")
    s.probe("vm", "b", "V", every=2)
    return s


def _huge(n=4_000_000, fanout=64):
    s = ModelSpec("huge")
    s.add_neuron_population("a", n, "izhikevich")
    s.add_synapse_population("aa", "a", "a", connect=FixedFanout(fanout),
                             weight=UniformWeight(0, 0.5),
                             delay=UniformIntDelay(0, 7))
    return s


def test_plan_small_spec_fits_one_host():
    p = _small().plan(mesh_shape=1, host_gib=16.0, n_steps=100)
    assert p["fits"] and p["needs"] == "fits"
    assert p["min_devices"] == 1
    assert p["first_overflow"] is None
    pd = p["per_device"]
    assert 0 < pd["steady_state_bytes"] <= pd["peak_bytes"]
    assert pd["construction_fused_bytes"] > 0
    assert pd["construction_partition_bytes"] > 0
    names = {c["name"] for c in p["components"]}
    assert {"ab", "a", "b"} <= names


def test_plan_construction_bytes_scale_per_device():
    """The O(nnz/device) claim, stated in planner bytes: fused
    construction shrinks with the device count while the
    generate-then-partition column stays O(nnz)."""
    p1 = _huge().plan(mesh_shape=1, host_gib=1024.0)
    p8 = _huge().plan(mesh_shape=8, host_gib=1024.0)
    f1 = p1["per_device"]["construction_fused_bytes"]
    f8 = p8["per_device"]["construction_fused_bytes"]
    g1 = p1["per_device"]["construction_partition_bytes"]
    g8 = p8["per_device"]["construction_partition_bytes"]
    assert f8 < f1 / 2            # better than half at 8x the devices
    assert g8 > g1 / 2            # generate-then-partition barely moves


def test_plan_names_first_component_over_budget():
    """A multi-million-neuron net whose full ELL cannot fit one host:
    the planner says how many hosts it needs and which component tips
    the budget first."""
    p = _huge().plan(mesh_shape=1, host_gib=2.0)
    assert not p["fits"]
    assert p["first_overflow"] == "aa"
    assert p["min_devices"] > 1
    assert p["needs"].startswith(f"this spec needs {p['min_devices']} hosts")
    assert "aa" in p["needs"]
    # and at the suggested device count it does fit
    p2 = _huge().plan(mesh_shape=p["min_devices"], host_gib=2.0)
    assert p2["fits"]


def test_plan_min_devices_is_tight_up_to_doubling():
    p = _huge().plan(mesh_shape=1, host_gib=2.0)
    d = p["min_devices"]
    if d > 2:
        assert not _huge().plan(mesh_shape=d // 4 or 1,
                                host_gib=2.0)["fits"]


def test_plan_probe_rings_accounted_packed():
    """Unreduced spikes rings enter the plan at their uint32 bit-packed
    size (satellite 1: the planner must not overestimate by ~32x)."""
    def mk(with_probe):
        s = ModelSpec("pp")
        s.add_neuron_population("a", 32_000, "izhikevich")
        s.add_synapse_population("aa", "a", "a", connect=FixedFanout(4),
                                 weight=UniformWeight(0, 0.5))
        if with_probe:
            s.probe("raster", "a", "spikes")
        return s

    base = mk(False).plan(mesh_shape=1, n_steps=1000)
    with_p = mk(True).plan(mesh_shape=1, n_steps=1000)
    delta = (with_p["per_device"]["steady_state_bytes"]
             - base["per_device"]["steady_state_bytes"])
    packed = 1000 * ((32_000 + 31) // 32) * 4
    unpacked = 1000 * 32_000 * 4
    assert delta == packed
    assert delta < unpacked / 30


def test_plan_validates_mesh_shape():
    with pytest.raises(SpecError, match="mesh_shape"):
        _small().plan(mesh_shape=0)
    with pytest.raises(SpecError, match="mesh_shape"):
        _small().plan(mesh_shape=2.5)


def test_plan_matches_fixed_probability_slot_bound():
    """FixedProbability groups plan with the same binomial slot bound
    device_init pads to, so planned k is an upper bound on built k."""
    from repro.sparse import device_init as DI
    s = ModelSpec("fp")
    s.add_neuron_population("a", 512, "izhikevich")
    s.add_synapse_population("aa", "a", "a", connect=FixedProbability(0.1),
                             weight=UniformWeight(0, 0.5))
    p = s.plan(mesh_shape=4)
    comp = next(c for c in p["components"] if c["name"] == "aa")
    assert comp["k"] == DI._binomial_slots(512, 0.1)
    assert 1 <= comp["k_local"] <= comp["k"]
