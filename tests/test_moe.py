"""MoE invariants: routing, capacity, conservation, aux loss."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis or fallback

from repro.models.moe import MoEConfig, moe_apply, moe_init

RNG = np.random.default_rng(2)


def _setup(e=4, k=2, d=32, f=64, cf=8.0, gs=64):
    cfg = MoEConfig(d_model=d, d_ff=f, n_experts=e, top_k=k,
                    capacity_factor=cf, group_size=gs)
    p = moe_init(jax.random.PRNGKey(0), cfg)
    return cfg, p


def test_output_shape_and_finite():
    cfg, p = _setup()
    x = jnp.asarray(RNG.standard_normal((2, 16, 32)), jnp.float32)
    y, aux = moe_apply(p, cfg, x)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(aux)) and bool(jnp.all(jnp.isfinite(y)))


def test_aux_loss_uniform_router_near_one():
    """Balanced routing drives the Switch aux loss to ~ aux_weight * 1.0."""
    cfg, p = _setup(e=8, k=1)
    # router weights ~0 -> uniform probs -> f_e ~ 1/e, P_e = 1/e
    p = dict(p)
    p["router"] = jnp.zeros_like(p["router"])
    x = jnp.asarray(RNG.standard_normal((4, 64, 32)), jnp.float32)
    _, aux = moe_apply(p, cfg, x)
    np.testing.assert_allclose(float(aux) / cfg.aux_loss_weight, 1.0,
                               rtol=0.15)


def test_dropless_equals_dense_computation():
    """With top_k == n_experts and huge capacity, MoE == weighted sum of all
    experts (routing soft-combines everything)."""
    cfg, p = _setup(e=2, k=2, cf=16.0)
    x = jnp.asarray(RNG.standard_normal((1, 8, 32)), jnp.float32)
    y, _ = moe_apply(p, cfg, x)

    # manual dense computation
    xf = x.reshape(-1, 32)
    probs = jax.nn.softmax(xf @ p["router"], -1)
    outs = []
    for e in range(2):
        h = jax.nn.silu(xf @ p["w_gate"][e]) * (xf @ p["w_up"][e])
        outs.append(h @ p["w_out"][e])
    dense = sum(probs[:, e:e + 1] * outs[e] for e in range(2))
    np.testing.assert_allclose(np.asarray(y.reshape(-1, 32)),
                               np.asarray(dense), rtol=2e-3, atol=2e-3)


def test_capacity_drops_tokens_deterministically():
    cfg, p = _setup(e=2, k=1, cf=0.51, gs=8)   # cap ~ 2 per expert
    p = dict(p)
    # router forces everyone to expert 0
    p["router"] = jnp.zeros_like(p["router"]).at[:, 0].set(10.0)
    x = jnp.asarray(RNG.standard_normal((1, 8, 32)), jnp.float32)
    y, _ = moe_apply(p, cfg, x)
    # tokens beyond capacity get zero output
    norms = np.asarray(jnp.linalg.norm(y[0], axis=-1))
    assert (norms[: 2] > 1e-6).all()
    assert (norms[4:] < 1e-6).all()


@settings(max_examples=10, deadline=None)
@given(e=st.sampled_from([2, 4, 8]), k=st.sampled_from([1, 2]),
       seed=st.integers(0, 1000))
def test_property_gate_conservation(e, k, seed):
    """Kept tokens' outputs are convex combos: gates sum to <= 1 and the
    layer is linear in the gate values."""
    cfg = MoEConfig(d_model=16, d_ff=32, n_experts=e, top_k=k,
                    capacity_factor=8.0, group_size=32)
    p = moe_init(jax.random.PRNGKey(seed), cfg)
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.standard_normal((1, 16, 16)), jnp.float32)
    y, aux = moe_apply(p, cfg, x)
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(aux) >= 0.0


def test_gather_dispatch_equals_onehot():
    """The beyond-paper gather dispatch is numerically identical to the
    Switch one-hot dispatch, including capacity-drop semantics."""
    import dataclasses
    for cf in (8.0, 0.9):
        cfg_o = MoEConfig(d_model=32, d_ff=64, n_experts=4, top_k=2,
                          capacity_factor=cf, group_size=32,
                          dispatch="onehot")
        cfg_g = dataclasses.replace(cfg_o, dispatch="gather")
        p = moe_init(jax.random.PRNGKey(0), cfg_o)
        x = jnp.asarray(RNG.standard_normal((2, 48, 32)), jnp.float32)
        yo, ao = moe_apply(p, cfg_o, x)
        yg, ag = moe_apply(p, cfg_g, x)
        np.testing.assert_allclose(np.asarray(yg), np.asarray(yo),
                                   rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(float(ag), float(ao), rtol=1e-6)
