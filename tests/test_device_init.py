"""Device-resident construction: equivalence with the host-side oracle.

Three property families (the PR's acceptance contract):
  1. distribution: device initializers match the host numpy initializers on
     degree distributions (fanout exactly; probability statistically);
  2. determinism: the same seed reproduces the same graph bit for bit;
  3. partition invariance: generating rows in any chunking (1 vs N
     partitions) yields the identical graph — construction is independent
     of device count.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _hypothesis_compat import given, settings, st

from repro.sparse import device_init as DI
from repro.sparse import formats as F


def _key(seed=0):
    return jax.random.PRNGKey(seed)


# ---------------------------------------------------------------------------
# fixed fanout
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(n_pre=st.integers(1, 40), n_post=st.integers(2, 120),
       seed=st.integers(0, 3))
def test_fixed_fanout_degrees_and_distinctness(n_pre, n_post, seed):
    n_conn = max(1, min(n_post, n_post // 3))
    post, g, valid = DI.device_fixed_fanout(_key(seed), n_pre, n_post,
                                            n_conn)
    post = np.asarray(post)
    assert post.shape == (n_pre, n_conn)
    assert bool(np.asarray(valid).all())
    # out-degree is exactly n_conn with all-distinct targets (the host
    # FixedFanout contract)
    for row in post:
        assert len(set(row.tolist())) == n_conn
        assert row.min() >= 0 and row.max() < n_post


def test_fixed_fanout_bit_deterministic():
    a = DI.device_fixed_fanout(_key(7), 30, 200, 12,
                               F.UniformWeight(0.0, 0.5))
    b = DI.device_fixed_fanout(_key(7), 30, 200, 12,
                               F.UniformWeight(0.0, 0.5))
    for x, y in zip(a, b):
        assert (np.asarray(x) == np.asarray(y)).all()
    c = DI.device_fixed_fanout(_key(8), 30, 200, 12,
                               F.UniformWeight(0.0, 0.5))
    assert not (np.asarray(a[0]) == np.asarray(c[0])).all()


@pytest.mark.parametrize("splits", [1, 2, 5])
def test_fixed_fanout_partition_invariance(splits):
    """Row-chunked generation == whole-graph generation, for any chunking:
    the counter-based keying makes construction device-count independent."""
    n_pre, n_post, k = 40, 150, 9
    w = F.NormalWeight(0.0, 0.3)
    full = DI.device_fixed_fanout(_key(3), n_pre, n_post, k, w)
    bounds = np.linspace(0, n_pre, splits + 1).astype(int)
    parts = [DI.device_fixed_fanout(_key(3), n_pre, n_post, k, w,
                                    rows=jnp.arange(lo, hi))
             for lo, hi in zip(bounds[:-1], bounds[1:])]
    for i in range(3):
        cat = np.concatenate([np.asarray(p[i]) for p in parts])
        assert (cat == np.asarray(full[i])).all()


def test_fixed_fanout_matches_host_degree_distribution():
    """In-degree distribution of device vs host construction (same model:
    uniform fanout): means equal by construction, spreads statistically
    close."""
    rng = np.random.default_rng(0)
    n_pre, n_post, k = 400, 300, 20
    host_post, _ = F.fixed_fanout_connectivity(rng, n_pre, n_post, k)
    dev_post, _, _ = DI.device_fixed_fanout(_key(0), n_pre, n_post, k)
    host_in = np.bincount(host_post.reshape(-1), minlength=n_post)
    dev_in = np.bincount(np.asarray(dev_post).reshape(-1),
                         minlength=n_post)
    assert host_in.sum() == dev_in.sum() == n_pre * k
    assert abs(host_in.mean() - dev_in.mean()) < 1e-9
    # both are sums of without-replacement indicators: same variance model
    assert abs(host_in.std() - dev_in.std()) / host_in.std() < 0.25


def test_fixed_fanout_dense_regime_uses_topk_path():
    # n_conn > n_post/2 exercises the permutation path; n_conn == n_post
    # the iota shortcut
    post, _, _ = DI.device_fixed_fanout(_key(1), 8, 16, 12)
    for row in np.asarray(post):
        assert len(set(row.tolist())) == 12
    post, _, _ = DI.device_fixed_fanout(_key(1), 4, 8, 8)
    assert (np.asarray(post) == np.arange(8)).all()


# ---------------------------------------------------------------------------
# fixed probability
# ---------------------------------------------------------------------------

def test_fixed_probability_matches_host_degree_distribution():
    n_pre, n_post, p = 600, 400, 0.05
    rng = np.random.default_rng(0)
    _, _, host_valid = F.FixedProbability(p).resolve(rng, n_pre, n_post)
    dev_post, dev_g, dev_valid = DI.device_fixed_probability(
        _key(0), n_pre, n_post, p)
    host_deg = host_valid.sum(axis=1)
    dev_deg = np.asarray(dev_valid).sum(axis=1)
    mean = n_post * p
    std = np.sqrt(n_post * p * (1 - p))
    # both row-degree samples are Binomial(n_post, p): compare moments
    assert abs(host_deg.mean() - mean) < 4 * std / np.sqrt(n_pre)
    assert abs(dev_deg.mean() - mean) < 4 * std / np.sqrt(n_pre)
    assert 0.7 < dev_deg.std() / std < 1.3
    # per-row distinct targets; invalid slots zeroed like the host path
    dev_post, dev_valid = np.asarray(dev_post), np.asarray(dev_valid)
    for i in range(n_pre):
        vs = dev_post[i, dev_valid[i]]
        assert len(set(vs.tolist())) == len(vs)
    assert (np.asarray(dev_g)[~dev_valid] == 0).all()


def test_fixed_probability_target_uniformity():
    """Targets must be uniform over post neurons (a sorted-truncation bug
    would skew mass toward low indices)."""
    post, _, valid = DI.device_fixed_probability(_key(2), 2000, 50, 0.1)
    counts = np.bincount(np.asarray(post)[np.asarray(valid)],
                         minlength=50)
    frac_low = counts[:25].sum() / counts.sum()
    assert 0.45 < frac_low < 0.55


def test_fixed_probability_determinism_and_chunking():
    a = DI.device_fixed_probability(_key(5), 60, 300, 0.04, 2.0)
    b = DI.device_fixed_probability(_key(5), 60, 300, 0.04, 2.0)
    for x, y in zip(a, b):
        assert (np.asarray(x) == np.asarray(y)).all()
    lo = DI.device_fixed_probability(_key(5), 60, 300, 0.04, 2.0,
                                     rows=jnp.arange(0, 25))
    hi = DI.device_fixed_probability(_key(5), 60, 300, 0.04, 2.0,
                                     rows=jnp.arange(25, 60))
    for i in range(3):
        cat = np.concatenate([np.asarray(lo[i]), np.asarray(hi[i])])
        assert (cat == np.asarray(a[i])).all()


def test_fixed_probability_rejects_bad_p():
    with pytest.raises(ValueError, match="outside"):
        DI.device_fixed_probability(_key(0), 4, 4, 1.5)


# ---------------------------------------------------------------------------
# one-to-one / dispatch / weights
# ---------------------------------------------------------------------------

def test_one_to_one_device():
    post, g, valid = DI.device_one_to_one(_key(0), 9, 9, 0.25)
    assert (np.asarray(post)[:, 0] == np.arange(9)).all()
    assert np.allclose(np.asarray(g), 0.25)
    with pytest.raises(ValueError, match="n_pre == n_post"):
        DI.device_one_to_one(_key(0), 4, 5)


def test_device_resolve_dispatch_matches_kernels():
    for init, kw in [(F.FixedFanout(4), {}), (F.FixedProbability(0.2), {}),
                     (F.OneToOne(), {}), (F.DenseInit(), {})]:
        post, g, valid = DI.device_resolve(init, _key(1), 12, 12, 0.5)
        assert post.shape == g.shape == valid.shape


def test_device_resolve_rejects_unknown_init():
    class Weird(F.ConnectivityInit):
        pass

    with pytest.raises(NotImplementedError, match="device-side"):
        DI.device_resolve(Weird(), _key(0), 4, 4)


def test_as_device_weight_rejects_numpy_callables():
    with pytest.raises(TypeError, match="dual-backend"):
        DI.as_device_weight(lambda rng, shape: rng.random(shape))


def test_weight_snippets_dual_backend():
    rng = np.random.default_rng(0)
    for w in (F.ConstantWeight(0.3), F.UniformWeight(-1.0, 1.0),
              F.NormalWeight(0.0, 2.0)):
        h = w(rng, (50, 8))
        d = np.asarray(w.device(_key(0), (50, 8)))
        assert h.shape == d.shape and h.dtype == np.float32
        assert abs(h.mean() - d.mean()) < 0.3
    # host UniformWeight is bit-identical to the historical lambdas
    r1, r2 = np.random.default_rng(9), np.random.default_rng(9)
    assert (F.UniformWeight(0.0, 0.5)(r1, (20, 3))
            == (0.5 * r2.random((20, 3))).astype(np.float32)).all()


# ---------------------------------------------------------------------------
# post-sharding partition
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_shards", [1, 2, 4, 7])
def test_partition_ell_by_post_reconstructs(n_shards):
    post, g, valid = DI.device_fixed_probability(_key(4), 30, 53, 0.2,
                                                 F.UniformWeight(0, 1))
    ell = F.ELLSynapses(g=jnp.where(valid, g, 0.0), post_ind=post,
                        valid=valid, n_post=53)
    G, PL, V, DL, S, KL = DI.partition_ell_by_post(ell, n_shards)
    assert DL is None                     # delay-free ELL -> no delay block
    assert G.shape == (n_shards, 30, KL)
    # slot conservation and exact dense reconstruction
    assert int(np.asarray(V).sum()) == int(np.asarray(valid).sum())
    dense = np.asarray(F.ell_to_dense(ell))
    rec = np.zeros((30, S * n_shards), np.float32)
    for d in range(n_shards):
        sub = F.ELLSynapses(g=G[d], post_ind=PL[d], valid=V[d], n_post=S)
        rec[:, d * S:(d + 1) * S] = np.asarray(F.ell_to_dense(sub))
    assert np.array_equal(rec[:, :53], dense)
    # local indices in range
    assert np.asarray(PL)[np.asarray(V)].max() < S


def test_partition_preserves_slot_order():
    """Within-row slot order must survive compaction (scatter-accumulation
    order — and bit-exact currents — depend on it)."""
    post = jnp.asarray([[5, 0, 9, 2, 7]], jnp.int32)
    g = jnp.asarray([[1., 2., 3., 4., 5.]])
    valid = jnp.ones((1, 5), bool)
    ell = F.ELLSynapses(g=g, post_ind=post, valid=valid, n_post=10)
    G, PL, V, _, S, KL = DI.partition_ell_by_post(ell, 2)
    # shard 0 owns post 0..4: slots (0->g2, 2->g4) in original order
    g0 = np.asarray(G[0])[0][np.asarray(V[0])[0]]
    assert g0.tolist() == [2.0, 4.0]
    g1 = np.asarray(G[1])[0][np.asarray(V[1])[0]]
    assert g1.tolist() == [1.0, 3.0, 5.0]


# ---------------------------------------------------------------------------
# ModelSpec device build
# ---------------------------------------------------------------------------

def test_spec_device_build_runs_and_is_device_count_free():
    from repro.core.models.izhikevich_net import (IzhikevichNetConfig,
                                                  compile_model)
    cfg = IzhikevichNetConfig(n_total=80, n_conn=16, seed=5)
    m1 = compile_model(cfg, init="device")
    m2 = compile_model(cfg, init="device")
    for g1, g2 in zip(m1.network.synapses, m2.network.synapses):
        assert (np.asarray(g1.ell.post_ind)
                == np.asarray(g2.ell.post_ind)).all()
        assert (np.asarray(g1.ell.g) == np.asarray(g2.ell.g)).all()
    res = m1.run(20)
    assert bool(res.finite)


def test_spec_device_build_rejects_numpy_weight():
    from repro.core.snn.spec import ModelSpec, SpecError
    s = ModelSpec("bad")
    s.add_neuron_population("a", 8, "izhikevich")
    s.add_synapse_population("aa", "a", "a", connect=F.FixedFanout(2),
                             weight=lambda r, shape: r.random(shape))
    with pytest.raises(SpecError, match="dual-backend"):
        s.build(dt=1.0, seed=0, init="device")
    # ...but the same spec still builds host-side
    s.build(dt=1.0, seed=0, init="host")


def test_spec_build_rejects_bad_init():
    from repro.core.snn.spec import ModelSpec, SpecError
    s = ModelSpec("bad")
    s.add_neuron_population("a", 8, "izhikevich")
    with pytest.raises(SpecError, match="init"):
        s.build(init="gpu")
