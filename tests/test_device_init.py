"""Device-resident construction: equivalence with the host-side oracle.

Three property families (the PR's acceptance contract):
  1. distribution: device initializers match the host numpy initializers on
     degree distributions (fanout exactly; probability statistically);
  2. determinism: the same seed reproduces the same graph bit for bit;
  3. partition invariance: generating rows in any chunking (1 vs N
     partitions) yields the identical graph — construction is independent
     of device count.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _hypothesis_compat import given, settings, st

from repro.sparse import device_init as DI
from repro.sparse import formats as F


def _key(seed=0):
    return jax.random.PRNGKey(seed)


# ---------------------------------------------------------------------------
# fixed fanout
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(n_pre=st.integers(1, 40), n_post=st.integers(2, 120),
       seed=st.integers(0, 3))
def test_fixed_fanout_degrees_and_distinctness(n_pre, n_post, seed):
    n_conn = max(1, min(n_post, n_post // 3))
    post, g, valid = DI.device_fixed_fanout(_key(seed), n_pre, n_post,
                                            n_conn)
    post = np.asarray(post)
    assert post.shape == (n_pre, n_conn)
    assert bool(np.asarray(valid).all())
    # out-degree is exactly n_conn with all-distinct targets (the host
    # FixedFanout contract)
    for row in post:
        assert len(set(row.tolist())) == n_conn
        assert row.min() >= 0 and row.max() < n_post


def test_fixed_fanout_bit_deterministic():
    a = DI.device_fixed_fanout(_key(7), 30, 200, 12,
                               F.UniformWeight(0.0, 0.5))
    b = DI.device_fixed_fanout(_key(7), 30, 200, 12,
                               F.UniformWeight(0.0, 0.5))
    for x, y in zip(a, b):
        assert (np.asarray(x) == np.asarray(y)).all()
    c = DI.device_fixed_fanout(_key(8), 30, 200, 12,
                               F.UniformWeight(0.0, 0.5))
    assert not (np.asarray(a[0]) == np.asarray(c[0])).all()


@pytest.mark.parametrize("splits", [1, 2, 5])
def test_fixed_fanout_partition_invariance(splits):
    """Row-chunked generation == whole-graph generation, for any chunking:
    the counter-based keying makes construction device-count independent."""
    n_pre, n_post, k = 40, 150, 9
    w = F.NormalWeight(0.0, 0.3)
    full = DI.device_fixed_fanout(_key(3), n_pre, n_post, k, w)
    bounds = np.linspace(0, n_pre, splits + 1).astype(int)
    parts = [DI.device_fixed_fanout(_key(3), n_pre, n_post, k, w,
                                    rows=jnp.arange(lo, hi))
             for lo, hi in zip(bounds[:-1], bounds[1:])]
    for i in range(3):
        cat = np.concatenate([np.asarray(p[i]) for p in parts])
        assert (cat == np.asarray(full[i])).all()


def test_fixed_fanout_matches_host_degree_distribution():
    """In-degree distribution of device vs host construction (same model:
    uniform fanout): means equal by construction, spreads statistically
    close."""
    rng = np.random.default_rng(0)
    n_pre, n_post, k = 400, 300, 20
    host_post, _ = F.fixed_fanout_connectivity(rng, n_pre, n_post, k)
    dev_post, _, _ = DI.device_fixed_fanout(_key(0), n_pre, n_post, k)
    host_in = np.bincount(host_post.reshape(-1), minlength=n_post)
    dev_in = np.bincount(np.asarray(dev_post).reshape(-1),
                         minlength=n_post)
    assert host_in.sum() == dev_in.sum() == n_pre * k
    assert abs(host_in.mean() - dev_in.mean()) < 1e-9
    # both are sums of without-replacement indicators: same variance model
    assert abs(host_in.std() - dev_in.std()) / host_in.std() < 0.25


def test_fixed_fanout_dense_regime_uses_topk_path():
    # n_conn > n_post/2 exercises the permutation path; n_conn == n_post
    # the iota shortcut
    post, _, _ = DI.device_fixed_fanout(_key(1), 8, 16, 12)
    for row in np.asarray(post):
        assert len(set(row.tolist())) == 12
    post, _, _ = DI.device_fixed_fanout(_key(1), 4, 8, 8)
    assert (np.asarray(post) == np.arange(8)).all()


# ---------------------------------------------------------------------------
# fixed probability
# ---------------------------------------------------------------------------

def test_fixed_probability_matches_host_degree_distribution():
    n_pre, n_post, p = 600, 400, 0.05
    rng = np.random.default_rng(0)
    _, _, host_valid = F.FixedProbability(p).resolve(rng, n_pre, n_post)
    dev_post, dev_g, dev_valid = DI.device_fixed_probability(
        _key(0), n_pre, n_post, p)
    host_deg = host_valid.sum(axis=1)
    dev_deg = np.asarray(dev_valid).sum(axis=1)
    mean = n_post * p
    std = np.sqrt(n_post * p * (1 - p))
    # both row-degree samples are Binomial(n_post, p): compare moments
    assert abs(host_deg.mean() - mean) < 4 * std / np.sqrt(n_pre)
    assert abs(dev_deg.mean() - mean) < 4 * std / np.sqrt(n_pre)
    assert 0.7 < dev_deg.std() / std < 1.3
    # per-row distinct targets; invalid slots zeroed like the host path
    dev_post, dev_valid = np.asarray(dev_post), np.asarray(dev_valid)
    for i in range(n_pre):
        vs = dev_post[i, dev_valid[i]]
        assert len(set(vs.tolist())) == len(vs)
    assert (np.asarray(dev_g)[~dev_valid] == 0).all()


def test_fixed_probability_target_uniformity():
    """Targets must be uniform over post neurons (a sorted-truncation bug
    would skew mass toward low indices)."""
    post, _, valid = DI.device_fixed_probability(_key(2), 2000, 50, 0.1)
    counts = np.bincount(np.asarray(post)[np.asarray(valid)],
                         minlength=50)
    frac_low = counts[:25].sum() / counts.sum()
    assert 0.45 < frac_low < 0.55


def test_fixed_probability_determinism_and_chunking():
    a = DI.device_fixed_probability(_key(5), 60, 300, 0.04, 2.0)
    b = DI.device_fixed_probability(_key(5), 60, 300, 0.04, 2.0)
    for x, y in zip(a, b):
        assert (np.asarray(x) == np.asarray(y)).all()
    lo = DI.device_fixed_probability(_key(5), 60, 300, 0.04, 2.0,
                                     rows=jnp.arange(0, 25))
    hi = DI.device_fixed_probability(_key(5), 60, 300, 0.04, 2.0,
                                     rows=jnp.arange(25, 60))
    for i in range(3):
        cat = np.concatenate([np.asarray(lo[i]), np.asarray(hi[i])])
        assert (cat == np.asarray(a[i])).all()


def test_fixed_probability_rejects_bad_p():
    with pytest.raises(ValueError, match="outside"):
        DI.device_fixed_probability(_key(0), 4, 4, 1.5)


# ---------------------------------------------------------------------------
# one-to-one / dispatch / weights
# ---------------------------------------------------------------------------

def test_one_to_one_device():
    post, g, valid = DI.device_one_to_one(_key(0), 9, 9, 0.25)
    assert (np.asarray(post)[:, 0] == np.arange(9)).all()
    assert np.allclose(np.asarray(g), 0.25)
    with pytest.raises(ValueError, match="n_pre == n_post"):
        DI.device_one_to_one(_key(0), 4, 5)


def test_device_resolve_dispatch_matches_kernels():
    for init, kw in [(F.FixedFanout(4), {}), (F.FixedProbability(0.2), {}),
                     (F.OneToOne(), {}), (F.DenseInit(), {})]:
        post, g, valid = DI.device_resolve(init, _key(1), 12, 12, 0.5)
        assert post.shape == g.shape == valid.shape


def test_device_resolve_rejects_unknown_init():
    class Weird(F.ConnectivityInit):
        pass

    with pytest.raises(NotImplementedError, match="device-side"):
        DI.device_resolve(Weird(), _key(0), 4, 4)


def test_as_device_weight_rejects_numpy_callables():
    with pytest.raises(TypeError, match="dual-backend"):
        DI.as_device_weight(lambda rng, shape: rng.random(shape))


def test_weight_snippets_dual_backend():
    rng = np.random.default_rng(0)
    for w in (F.ConstantWeight(0.3), F.UniformWeight(-1.0, 1.0),
              F.NormalWeight(0.0, 2.0)):
        h = w(rng, (50, 8))
        d = np.asarray(w.device(_key(0), (50, 8)))
        assert h.shape == d.shape and h.dtype == np.float32
        assert abs(h.mean() - d.mean()) < 0.3
    # host UniformWeight is bit-identical to the historical lambdas
    r1, r2 = np.random.default_rng(9), np.random.default_rng(9)
    assert (F.UniformWeight(0.0, 0.5)(r1, (20, 3))
            == (0.5 * r2.random((20, 3))).astype(np.float32)).all()


# ---------------------------------------------------------------------------
# post-sharding partition
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_shards", [1, 2, 4, 7])
def test_partition_ell_by_post_reconstructs(n_shards):
    post, g, valid = DI.device_fixed_probability(_key(4), 30, 53, 0.2,
                                                 F.UniformWeight(0, 1))
    ell = F.ELLSynapses(g=jnp.where(valid, g, 0.0), post_ind=post,
                        valid=valid, n_post=53)
    G, PL, V, DL, S, KL = DI.partition_ell_by_post(ell, n_shards)
    assert DL is None                     # delay-free ELL -> no delay block
    assert G.shape == (n_shards, 30, KL)
    # slot conservation and exact dense reconstruction
    assert int(np.asarray(V).sum()) == int(np.asarray(valid).sum())
    dense = np.asarray(F.ell_to_dense(ell))
    rec = np.zeros((30, S * n_shards), np.float32)
    for d in range(n_shards):
        sub = F.ELLSynapses(g=G[d], post_ind=PL[d], valid=V[d], n_post=S)
        rec[:, d * S:(d + 1) * S] = np.asarray(F.ell_to_dense(sub))
    assert np.array_equal(rec[:, :53], dense)
    # local indices in range
    assert np.asarray(PL)[np.asarray(V)].max() < S


def test_partition_preserves_slot_order():
    """Within-row slot order must survive compaction (scatter-accumulation
    order — and bit-exact currents — depend on it)."""
    post = jnp.asarray([[5, 0, 9, 2, 7]], jnp.int32)
    g = jnp.asarray([[1., 2., 3., 4., 5.]])
    valid = jnp.ones((1, 5), bool)
    ell = F.ELLSynapses(g=g, post_ind=post, valid=valid, n_post=10)
    G, PL, V, _, S, KL = DI.partition_ell_by_post(ell, 2)
    # shard 0 owns post 0..4: slots (0->g2, 2->g4) in original order
    g0 = np.asarray(G[0])[0][np.asarray(V[0])[0]]
    assert g0.tolist() == [2.0, 4.0]
    g1 = np.asarray(G[1])[0][np.asarray(V[1])[0]]
    assert g1.tolist() == [1.0, 3.0, 5.0]


# ---------------------------------------------------------------------------
# ModelSpec device build
# ---------------------------------------------------------------------------

def test_spec_device_build_runs_and_is_device_count_free():
    from repro.core.models.izhikevich_net import (IzhikevichNetConfig,
                                                  compile_model)
    cfg = IzhikevichNetConfig(n_total=80, n_conn=16, seed=5)
    m1 = compile_model(cfg, init="device")
    m2 = compile_model(cfg, init="device")
    for g1, g2 in zip(m1.network.synapses, m2.network.synapses):
        assert (np.asarray(g1.ell.post_ind)
                == np.asarray(g2.ell.post_ind)).all()
        assert (np.asarray(g1.ell.g) == np.asarray(g2.ell.g)).all()
    res = m1.run(20)
    assert bool(res.finite)


def test_spec_device_build_rejects_numpy_weight():
    from repro.core.snn.spec import ModelSpec, SpecError
    s = ModelSpec("bad")
    s.add_neuron_population("a", 8, "izhikevich")
    s.add_synapse_population("aa", "a", "a", connect=F.FixedFanout(2),
                             weight=lambda r, shape: r.random(shape))
    with pytest.raises(SpecError, match="dual-backend"):
        s.build(dt=1.0, seed=0, init="device")
    # ...but the same spec still builds host-side
    s.build(dt=1.0, seed=0, init="host")


def test_spec_build_rejects_bad_init():
    from repro.core.snn.spec import ModelSpec, SpecError
    s = ModelSpec("bad")
    s.add_neuron_population("a", 8, "izhikevich")
    with pytest.raises(SpecError, match="init"):
        s.build(init="gpu")


# ---------------------------------------------------------------------------
# fused local construction (device_init_local)
# ---------------------------------------------------------------------------

def _mesh(n):
    from jax.sharding import Mesh
    return Mesh(np.array(jax.devices()[:n]), ("neuron",))


def _reference_blocks(connect, key, n_pre, n_post, n_shards, weight=None,
                      delay=None, window=None):
    """Generate-then-partition oracle, with the same multi-post window
    masking the spec build applies."""
    post, g, valid = DI.device_resolve(connect, key, n_pre, n_post, weight)
    dd = (None if delay is None
          else DI.device_delays(key, n_pre, post.shape[1], delay))
    if dd is not None:
        dd = jnp.where(valid, dd, 0).astype(jnp.int32)
    if window is not None:
        lo, hi = window
        mask = (post >= lo) & (post < hi) & valid
        post = jnp.where(mask, post - lo, 0).astype(jnp.int32)
        g = jnp.where(mask, g, 0.0).astype(jnp.float32)
        if dd is not None:
            dd = jnp.where(mask, dd, 0).astype(jnp.int32)
        valid = mask
        n_local = hi - lo
    else:
        n_local = n_post
    ell = F.ELLSynapses(g=g, post_ind=post, valid=valid, n_post=n_local,
                        delay=dd)
    return DI.partition_ell_by_post(ell, n_shards)


@pytest.mark.parametrize("n_dev", [1, 2, 8])
@pytest.mark.parametrize("case", ["fanout_delay", "prob", "window"])
def test_device_init_local_bit_exact_vs_partition(n_dev, case):
    """The tentpole contract: fused per-device generation + all_to_all
    exchange reproduces generate-then-partition bit for bit at any device
    count (delay slots included), because the per-row fold_in keys are
    placement-independent."""
    if len(jax.devices()) < n_dev:
        pytest.skip(f"needs {n_dev} devices")
    if case == "fanout_delay":
        connect, weight = F.FixedFanout(7), F.NormalWeight(0.1, 0.4)
        delay, window = F.UniformIntDelay(0, 3), None
        n_pre, n_post = 37, 53
    elif case == "prob":
        connect, weight = F.FixedProbability(0.15), F.UniformWeight(0, 1)
        delay, window = None, None
        n_pre, n_post = 41, 64
    else:
        connect, weight = F.FixedFanout(5), F.NormalWeight(0.0, 1.0)
        delay, window = F.ConstantDelay(2), (16, 40)
        n_pre, n_post = 29, 48
    key = _key(11)
    ref = _reference_blocks(connect, key, n_pre, n_post, n_dev,
                            weight=weight, delay=delay, window=window)
    got = DI.device_init_local(connect, key, n_pre, n_post, _mesh(n_dev),
                               weight=weight, delay=delay,
                               post_window=window)
    assert got[4] == ref[4] and got[5] == ref[5]     # shard_size, k_local
    for name, a, b in zip(("g", "post", "valid", "delay"), got[:4],
                          ref[:4]):
        if b is None:
            assert a is None
            continue
        assert np.array_equal(np.asarray(a), np.asarray(b)), \
            f"{name} differs at D={n_dev}"


def test_device_init_local_peak_model_scales_per_device():
    """O(nnz/device): the fused path's modeled peak construction bytes
    shrink as devices are added; generate-then-partition does not."""
    n_pre, k = 4096, 64
    fused, gen = [], []
    for D in (1, 2, 4, 8):
        m = DI.construction_peak_model(n_pre, k, D, k_local=max(1, k // D),
                                       has_delay=True)
        fused.append(m["fused_local_bytes"])
        gen.append(m["generate_partition_bytes"])
    # each doubling of D roughly halves the fused peak...
    assert fused[1] < 0.75 * fused[0]
    assert fused[3] < 0.25 * fused[0]
    # ...while the full-materialization path stays O(nnz) per device
    assert gen[3] > 0.5 * gen[0]
    assert fused[3] < gen[3]


# ---------------------------------------------------------------------------
# FixedProbability max_k overflow clamp (bugfix: silent out-of-slot writes)
# ---------------------------------------------------------------------------

def test_fixed_probability_overflow_clamps_and_flags():
    """Rows whose binomial draw exceeds the provided slot padding must be
    clamped (no out-of-slot indices) and flagged, not silently wrapped."""
    key = _key(0)
    # k far below the mean degree forces overflow on essentially every row
    post, counts, over = DI._fixed_probability_rows(
        key, jnp.arange(16), 100, 0.5, 10)
    counts = np.asarray(counts)
    assert counts.max() <= 10
    assert np.asarray(over).any()
    # flagged rows are exactly those whose raw draw exceeded k
    ckey = jax.random.fold_in(key, 0xDE)
    raw = np.asarray([
        jax.random.binomial(
            jax.random.fold_in(jax.random.fold_in(ckey, r), 1), 100, 0.5)
        for r in range(16)]).astype(np.int32)
    assert np.array_equal(np.asarray(over), raw > 10)


def test_fixed_probability_overflow_trace_instant():
    from repro.obs import trace
    trace.clear()
    DI._report_overflow(jnp.int32(3), n_pre=8, n_post=100, p=0.9, k=4)
    ev = [e for e in trace.events()
          if e.get("name") == "device_init.overflow"]
    assert len(ev) == 1
    args = ev[0]["args"]
    assert args["rows_clamped"] == 3 and args["max_k"] == 4
    trace.clear()
    # zero overflow -> no event
    DI._report_overflow(jnp.int32(0), n_pre=8, n_post=100, p=0.9, k=4)
    assert not [e for e in trace.events()
                if e.get("name") == "device_init.overflow"]


@pytest.mark.parametrize("p", [0.97, 1.0])
def test_fixed_probability_p_to_one_boundary(p):
    """At p -> 1 the slot bound saturates at n_post, so the public path
    never overflows: every row gets ~n_post distinct in-range targets and
    the degree matches Binomial(n_post, p) exactly at p == 1."""
    n_pre, n_post = 20, 40
    post, g, valid = DI.device_fixed_probability(_key(3), n_pre, n_post, p)
    post, valid = np.asarray(post), np.asarray(valid)
    assert post.shape[1] <= n_post
    deg = valid.sum(axis=1)
    if p == 1.0:
        assert (deg == n_post).all()
    else:
        assert deg.max() <= n_post and deg.min() >= 1
    for i in range(n_pre):
        vs = post[i, valid[i]]
        assert len(set(vs.tolist())) == len(vs)
        assert vs.min() >= 0 and vs.max() < n_post
